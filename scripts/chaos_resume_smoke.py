#!/usr/bin/env python
"""Chaos-resume smoke: SIGKILL a parallel sweep mid-run, resume, assert
ZERO recomputed points.

The resilient executor's core invariant (docs/sweep_resilience.md) is
that a killed run loses at most in-flight work: every completed point is
already committed to the config-hash cache atomically, so the re-run
computes exactly the complement.  This script proves it the hard way:

1. launch ``python -m repro.launch.sweep --grid tiny --workers 2`` as a
   subprocess in its own process group;
2. poll the cache directory until at least one point has committed, then
   SIGKILL the whole group (dispatcher AND workers — no drain, no
   handlers, the closest a CI runner gets to node loss);
3. count the committed cache entries C;
4. re-run the same command to completion and load the sweep JSON;
5. assert ``executor.cache_hits == C`` and ``executor.computed ==
   total - C`` — zero recomputed points.

Exit 0 on success, 1 with a diagnostic on any violated invariant.
Used by CI (see .github/workflows/ci.yml); runnable locally:

    PYTHONPATH=src python scripts/chaos_resume_smoke.py
"""

import json
import os
import signal
import subprocess
import sys
import tempfile
import time

GRID_SIZE = 6                      # --grid tiny
KILL_DEADLINE_S = 600.0            # give the first point time to compile
POLL_S = 0.25


def cache_entries(cache_dir: str) -> list[str]:
    try:
        return sorted(f for f in os.listdir(cache_dir)
                      if f.endswith(".json"))
    except FileNotFoundError:
        return []


def sweep_cmd(workdir: str, out: str) -> list[str]:
    return [sys.executable, "-m", "repro.launch.sweep",
            "--grid", "tiny", "--workers", "2",
            "--n-train", "512", "--n-test", "256",
            "--no-accuracy", "--no-kernel", "--no-serve",
            "--cache-dir", os.path.join(workdir, "cache"),
            "--out", out]


def main() -> int:
    workdir = tempfile.mkdtemp(prefix="chaos_resume_")
    cache_dir = os.path.join(workdir, "cache")
    out_json = os.path.join(workdir, "sweep.json")
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    env.setdefault("PYTHONPATH", "src")

    # -- phase 1: launch and SIGKILL mid-run ------------------------------
    print(f"[chaos] launching sweep (workdir {workdir})", flush=True)
    proc = subprocess.Popen(sweep_cmd(workdir, out_json), env=env,
                            start_new_session=True)   # own process group
    deadline = time.monotonic() + KILL_DEADLINE_S
    try:
        while not cache_entries(cache_dir):
            if proc.poll() is not None:
                print(f"[chaos] FAIL: sweep exited (rc={proc.returncode}) "
                      f"before any point committed", flush=True)
                return 1
            if time.monotonic() > deadline:
                print("[chaos] FAIL: no cache entry within "
                      f"{KILL_DEADLINE_S}s", flush=True)
                return 1
            time.sleep(POLL_S)
        # SIGKILL the whole group: dispatcher + every worker, no drain
        os.killpg(proc.pid, signal.SIGKILL)
    finally:
        if proc.poll() is None:
            try:
                os.killpg(proc.pid, signal.SIGKILL)
            except ProcessLookupError:
                pass
        proc.wait()
    committed = cache_entries(cache_dir)
    c = len(committed)
    print(f"[chaos] SIGKILLed run with {c}/{GRID_SIZE} point(s) committed",
          flush=True)
    if c >= GRID_SIZE:
        print("[chaos] FAIL: run finished before the kill landed; "
              "nothing to resume", flush=True)
        return 1

    # -- phase 2: resume to completion ------------------------------------
    rc = subprocess.call(sweep_cmd(workdir, out_json), env=env)
    if rc != 0:
        print(f"[chaos] FAIL: resume run exited {rc}", flush=True)
        return 1
    with open(out_json) as fh:
        result = json.load(fh)
    ex = result.get("executor") or {}
    hits, computed = ex.get("cache_hits"), ex.get("computed")
    points = len(result.get("points", []))
    print(f"[chaos] resume: cache_hits={hits} computed={computed} "
          f"points={points}", flush=True)

    # -- the invariant -----------------------------------------------------
    ok = True
    if points != GRID_SIZE:
        print(f"[chaos] FAIL: expected {GRID_SIZE} points, got {points}")
        ok = False
    if hits != c:
        print(f"[chaos] FAIL: resume should hit the cache for every "
              f"pre-kill point: cache_hits={hits} != committed={c}")
        ok = False
    if computed != GRID_SIZE - c:
        print(f"[chaos] FAIL: recomputed points detected: "
              f"computed={computed} != {GRID_SIZE - c} "
              f"(= total - committed)")
        ok = False
    if ex.get("failed"):
        print(f"[chaos] FAIL: failed points on resume: {ex['failed']}")
        ok = False
    if ok:
        print(f"[chaos] OK: killed at {c}/{GRID_SIZE}, resumed with "
              f"zero recomputed points", flush=True)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
