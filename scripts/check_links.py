#!/usr/bin/env python
"""Markdown link checker for README.md + docs/ (the CI docs job).

Validates every relative link and image target resolves to a real file,
and every intra-repo anchor (#section) matches a heading in the target
file.  External (http/https/mailto) links are not fetched — CI must work
offline.

Usage: python scripts/check_links.py [root]
Exit code: 0 when all links resolve, 1 otherwise.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


def anchor_of(heading: str) -> str:
    """GitHub's heading -> anchor rule (lowercase, drop punctuation,
    spaces to dashes)."""
    h = heading.strip().lower()
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def anchors_in(md: Path) -> set[str]:
    return {anchor_of(m.group(1))
            for m in HEADING_RE.finditer(md.read_text())}


def check_file(md: Path, root: Path) -> list[str]:
    errors = []
    for m in LINK_RE.finditer(md.read_text()):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, anchor = target.partition("#")
        if not path_part:                       # same-file anchor
            dest = md
        else:
            dest = (md.parent / path_part).resolve()
            if not dest.exists():
                errors.append(f"{md.relative_to(root)}: broken link "
                              f"-> {target}")
                continue
        if anchor and dest.suffix == ".md":
            if anchor_of(anchor) not in anchors_in(dest):
                errors.append(f"{md.relative_to(root)}: missing anchor "
                              f"-> {target}")
    return errors


def main(argv=None) -> int:
    root = Path((argv or sys.argv[1:] or ["."])[0]).resolve()
    files = [root / "README.md", *sorted((root / "docs").glob("*.md"))]
    files = [f for f in files if f.exists()]
    errors = []
    for md in files:
        errors.extend(check_file(md, root))
    for e in errors:
        print(f"BROKEN {e}")
    print(f"checked {len(files)} files: "
          f"{'all links ok' if not errors else f'{len(errors)} broken'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
