"""Quickstart: train a small DWN on the JSC surrogate, quantize it, emit
hardware reports and Verilog — the paper's full flow in ~2 minutes.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax

from repro.core import (JSC_PRESETS, train_dwn, freeze, eval_accuracy_hard,
                        ptq_bitwidth_search)
from repro.core.warmstart import warmstart_dwn
from repro.data.jsc import load_jsc
from repro.hw.cost import dwn_hw_report
from repro.hw.verilog import emit_dwn


def main():
    data = load_jsc(8000, 2000)
    cfg = JSC_PRESETS["sm-50"]

    print("== train (warm start + EFD refinement)")
    params, buffers = warmstart_dwn(jax.random.PRNGKey(0), cfg,
                                    data.x_train, data.y_train)
    res = train_dwn(cfg, data, epochs=6, batch=128, lr=1e-3,
                    params=params, buffers=buffers, verbose=True)

    frozen = freeze(res.params, res.buffers, cfg)
    acc = eval_accuracy_hard(frozen, data.x_test, data.y_test)
    print(f"float accuracy (hard datapath): {acc:.4f}")

    print("== PTQ: shrink the threshold bit-width (DWN-PEN)")
    ptq = ptq_bitwidth_search(res.params, res.buffers, cfg, data,
                              baseline_acc=acc, verbose=True)
    frozen_pen = freeze(res.params, res.buffers, cfg,
                        input_frac_bits=ptq.frac_bits)

    print("== hardware cost (our generator vs paper constants)")
    for variant, fr, bits in (("TEN", frozen, None),
                              ("PEN", frozen_pen, ptq.total_bits)):
        rep = dwn_hw_report(fr, variant=variant, name="sm-50",
                            input_bits=bits)
        print(f"  {variant:6s}: LUTs={rep.total_luts:5d} "
              f"FFs={rep.total_ffs:4d} delay~{rep.delay_ns:.1f}ns "
              f"breakdown={rep.luts}")

    print("== emit Verilog")
    src = emit_dwn(frozen_pen, name="dwn_sm50")
    out = Path(__file__).resolve().parents[1] / "results" / "dwn_sm50.v"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(src)
    print(f"  wrote {out} ({len(src.splitlines())} lines)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
