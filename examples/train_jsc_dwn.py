"""End-to-end reproduction of the paper's training pipeline (§III).

For each JSC model size (sm-10, sm-50, md-360, lg-2400):
  1. train the float DWN (EFD + learnable mapping; the two small models
     additionally use the documented data-driven warm start),
  2. DWN-PEN: post-training quantization of the thermometer thresholds to
     signed fixed point (1, n), shrinking n until baseline accuracy is
     lost,
  3. DWN-PEN+FT: fine-tune 10 epochs per width (Adam 1e-3, StepLR(30,0.1))
     and keep the smallest width that recovers baseline,
  4. freeze + save everything under results/dwn_models/ for the hardware
     benchmarks (tables I-III, figs 5-6).

Run:  PYTHONPATH=src python examples/train_jsc_dwn.py [--sizes sm-10,sm-50]
"""

import argparse
import json
import pickle
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro.core import (JSC_PRESETS, train_dwn, freeze, eval_accuracy_hard,
                        ptq_bitwidth_search, finetune_bitwidth_search)
from repro.core.warmstart import warmstart_dwn
from repro.data.jsc import load_jsc, bayes_accuracy

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dwn_models"

# training recipe per size: (epochs, lr, warm-start?)
RECIPE = {
    "sm-10": (40, 3e-4, True),
    "sm-50": (30, 1e-3, True),
    "md-360": (30, 3e-3, False),
    "lg-2400": (14, 3e-3, False),
}


def train_size(name: str, data, *, seed: int = 0) -> dict:
    cfg = JSC_PRESETS[name]
    epochs, lr, warm = RECIPE[name]
    t0 = time.time()
    if warm:
        params, buffers = warmstart_dwn(
            jax.random.PRNGKey(seed), cfg, data.x_train, data.y_train)
    else:
        params = buffers = None
    res = train_dwn(cfg, data, epochs=epochs, batch=128, lr=lr, seed=seed,
                    params=params, buffers=buffers, verbose=False)
    frozen = freeze(res.params, res.buffers, cfg)
    float_acc = eval_accuracy_hard(frozen, data.x_test, data.y_test)
    print(f"[{name}] float acc={float_acc:.4f} ({time.time()-t0:.0f}s)",
          flush=True)

    # --- DWN-PEN: PTQ bit-width search ---
    ptq = ptq_bitwidth_search(res.params, res.buffers, cfg, data,
                              baseline_acc=float_acc, verbose=False)
    print(f"[{name}] PEN: {ptq.total_bits}-bit acc={ptq.accuracy:.4f}",
          flush=True)

    # --- DWN-PEN+FT: fine-tune to lower widths ---
    ft = finetune_bitwidth_search(res.params, res.buffers, cfg, data,
                                  baseline_acc=float_acc,
                                  start_frac=ptq.frac_bits, epochs=10,
                                  verbose=False)
    print(f"[{name}] PEN+FT: {ft.total_bits}-bit acc={ft.accuracy:.4f}",
          flush=True)

    ft_params = ft.result.params if ft.result else res.params
    ft_buffers = ft.result.buffers if ft.result else res.buffers
    out = {
        "name": name,
        "float_acc": float_acc,
        "pen_bits": ptq.total_bits, "pen_acc": ptq.accuracy,
        "pen_sweep": ptq.sweep,
        "ft_bits": ft.total_bits, "ft_acc": ft.accuracy,
        "ft_sweep": ft.sweep,
        "frozen_ten": freeze(res.params, res.buffers, cfg),
        "frozen_pen": freeze(res.params, res.buffers, cfg,
                             input_frac_bits=ptq.frac_bits),
        "frozen_ft": freeze(ft_params, ft_buffers, cfg,
                            input_frac_bits=ft.frac_bits),
        "params": jax.device_get(ft_params),
        "buffers": jax.device_get(ft_buffers),
    }
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default="sm-10,sm-50,md-360,lg-2400")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    RESULTS.mkdir(parents=True, exist_ok=True)
    data = load_jsc()
    summary = {"bayes": bayes_accuracy()}
    print(f"surrogate Bayes ceiling: {summary['bayes']:.4f}", flush=True)
    for name in args.sizes.split(","):
        out = train_size(name, data, seed=args.seed)
        with open(RESULTS / f"{name}.pkl", "wb") as f:
            pickle.dump(out, f)
        summary[name] = {k: out[k] for k in
                         ("float_acc", "pen_bits", "pen_acc",
                          "ft_bits", "ft_acc")}
        (RESULTS / "summary.json").write_text(
            json.dumps(summary, indent=2, default=float))
    print(json.dumps(summary, indent=2, default=float))
    return 0


if __name__ == "__main__":
    sys.exit(main())
