"""Beyond-paper demo: a thermometer-encoded DWN classification head on an
LM backbone (the --dwn-head feature from DESIGN.md §6).

A reduced qwen3 backbone produces pooled features for a 5-way sequence-
classification task; the head is the paper's pipeline — thermometer encode
-> learnable-mapping LUT layer -> popcount — trained end-to-end with EFD
gradients flowing into the (frozen) backbone features.

Run:  PYTHONPATH=src python examples/dwn_head_lm.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.classifier import cross_entropy, group_popcount, predict
from repro.core.lut_layer import (LUTLayerSpec, init_lut_layer,
                                  lut_layer_apply)
from repro.models import api
from repro.optim.adam import Adam

FEATS = 16          # pooled backbone features fed to the DWN head
T_BITS = 64         # thermometer bits per feature
NUM_LUTS = 50
CLASSES = 5


def main():
    cfg = get_arch("qwen3-8b").reduced()
    mod = api.module_for(cfg)
    key = jax.random.PRNGKey(0)
    backbone = mod.init_params(key, cfg, tp=1)

    def features(toks):
        logits, _, _ = mod.forward(backbone, cfg, {"tokens": toks}, tp=1)
        # pool the final hidden logits into FEATS features
        pooled = logits.mean(axis=1)[:, :FEATS].astype(jnp.float32)
        return jnp.tanh(pooled * 0.3)          # squash to (-1, 1)

    # sequence-classification task: the label is a fixed (teacher)
    # projection of the backbone's pooled features — so the demo isolates
    # what the DWN head can learn on top of a frozen backbone.
    Wt = jax.random.normal(jax.random.PRNGKey(7), (FEATS, CLASSES)) * 2.0

    def make_batch(step, B=32, S=32):
        rng = np.random.default_rng(step)
        toks = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32))
        y = jnp.argmax(features(toks) @ Wt, axis=-1).astype(jnp.int32)
        return toks, y

    # DWN head: fixed uniform thresholds + learnable LUT layer
    th = jnp.tile(jnp.linspace(-1, 1, T_BITS + 2)[1:-1][None], (FEATS, 1))
    spec = LUTLayerSpec(NUM_LUTS, 6, FEATS * T_BITS)
    head = init_lut_layer(jax.random.PRNGKey(1), spec)
    opt = Adam(lr=5e-3, clamp=(-1, 1))
    opt_state = opt.init(head)

    @jax.jit
    def step(head, opt_state, toks, y):
        feats = features(toks)

        def loss(h):
            bits = (feats[:, :, None] > th[None]).astype(jnp.float32)
            bits = bits.reshape(feats.shape[0], -1)
            out = lut_layer_apply(h, bits)
            counts = group_popcount(out, CLASSES)
            return cross_entropy(counts / 0.8, y), counts

        (l, counts), g = jax.value_and_grad(loss, has_aux=True)(head)
        head, opt_state = opt.update(g, opt_state, head)
        acc = (predict(counts) == y).mean()
        return head, opt_state, l, acc

    accs = []
    for i in range(60):
        toks, y = make_batch(i)
        head, opt_state, l, acc = step(head, opt_state, toks, y)
        accs.append(float(acc))
        if (i + 1) % 20 == 0:
            print(f"step {i+1:3d} loss={float(l):.3f} "
                  f"acc(last20)={np.mean(accs[-20:]):.3f}")
    final = np.mean(accs[-20:])
    print(f"DWN-head accuracy {final:.3f} (chance = {1 / CLASSES:.3f})")
    assert final > 1.2 / CLASSES, "head should beat chance"
    return 0


if __name__ == "__main__":
    sys.exit(main())
