"""Batched serving example: four architecture families — KV-cache
attention, O(1)-state SSM, the hybrid RG-LRU, and the paper's own DWN
classifier — through one code path: the unified ServingEngine
submit/drain API.

LM archs serve one prompt batch (prefill + token-by-token decode); the
DWN arch serves a ragged stream of JSC classification requests that the
scheduler coalesces into power-of-two batch buckets.

Run:  PYTHONPATH=src python examples/serve_batch.py
"""

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.serving import ServingEngine

#: arch -> list of request sizes (LM: prompt batches; DWN: sample counts)
STREAMS = {
    "qwen3-8b": [4],
    "mamba2-1.3b": [4],
    "recurrentgemma-2b": [4],
    "dwn-jsc-sm": [5, 17, 64, 3, 100],
}


def main():
    for arch, sizes in STREAMS.items():
        print(f"\n== serving {arch} (reduced) ==", flush=True)
        engine = ServingEngine(arch, reduced=True, prompt_len=24, gen=12,
                               max_bucket=64)
        for i, size in enumerate(sizes):
            engine.submit(engine.make_request(size, seed=i))
        engine.drain()
        print(json.dumps(engine.report()), flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
