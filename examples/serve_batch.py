"""Batched serving example (deliverable b): prefill + decode across three
architecture families — KV-cache attention, O(1)-state SSM, and the
hybrid RG-LRU — through the production serving driver.

Run:  PYTHONPATH=src python examples/serve_batch.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch import serve as serve_mod


def main():
    rc = 0
    for arch in ("qwen3-8b", "mamba2-1.3b", "recurrentgemma-2b"):
        print(f"\n== serving {arch} (reduced) ==", flush=True)
        rc |= serve_mod.main(["--arch", arch, "--reduced", "--batch", "4",
                              "--prompt-len", "24", "--gen", "12"])
    return rc


if __name__ == "__main__":
    sys.exit(main())
