"""End-to-end LM training driver (deliverable b): trains a ~100M-class
model for a few hundred steps on the synthetic token stream through the
full production stack — sharded params, checkpoint/restart supervisor,
straggler monitor — on whatever devices exist.

By default runs a budget config sized for this CPU container
(~8M params, 300 steps); pass --full-100m on real hardware.

Run:  PYTHONPATH=src python examples/lm_train.py [--steps 300]
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch import train as train_mod


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args(argv)

    if args.full_100m:
        # ~100M params: qwen3-8b family, 12 layers, d=768 — needs a real
        # accelerator for a few hundred steps.
        import dataclasses
        from repro.configs import get_arch, register
        base = get_arch("qwen3-8b")
        cfg = dataclasses.replace(
            base, name="qwen3-100m", num_layers=12, d_model=768,
            num_heads=12, num_kv_heads=4, head_dim=64, d_ff=2048,
            vocab_size=32000, train_microbatches=1)
        register(cfg)
        arch, batch, seq = "qwen3-100m", 32, 512
    else:
        arch, batch, seq = "qwen3-8b", 16, 128   # reduced() inside train.py

    argv2 = ["--arch", arch, "--steps", str(args.steps),
             "--batch", str(batch), "--seq", str(seq),
             "--ckpt-dir", args.ckpt_dir, "--save-every", "100",
             "--log-every", "25"]
    if not args.full_100m:
        argv2.append("--reduced")
    return train_mod.main(argv2)


if __name__ == "__main__":
    sys.exit(main())
