from . import layers, transformer, mamba2, rglru, whisper, api
from .api import (module_for, abstract_params, param_axes, batch_specs,
                  batch_axes, abstract_cache, cache_axes, make_train_step,
                  make_prefill, make_decode_step)
