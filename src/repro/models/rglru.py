"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local attention.

Layer pattern (arXiv:2402.19427): repeating [recurrent, recurrent, attention]
superblocks; 26 layers = 8 superblocks + 2 trailing recurrent layers.
Attention layers use MQA (kv=1) with a local sliding window (2048) and RoPE.

RG-LRU (per channel):
    r_t = sigmoid(W_a x_t + b_a)          # recurrence gate
    i_t = sigmoid(W_x x_t + b_x)          # input gate
    a_t = exp(c * r_t * log(sigmoid(Lambda)))     (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training uses ``jax.lax.associative_scan`` on the linear recurrence
(log-depth on TPU); decode is the single-step recurrence (O(1) state —
this is why long_500k decode is valid for this arch).

TP: lru_width and d_ff shard over "model"; recurrence is element-wise so
no collective is introduced inside the scan.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..sharding.annotate import hint, hint_act
from ..sharding.partition import logical
from . import layers as L

Array = jax.Array

LRU_C = 8.0
CONV_K = 4


def _layout(cfg: ArchConfig, tp: int) -> L.HeadLayout:
    return L.make_head_layout(cfg.num_heads, cfg.num_kv_heads, tp)


def block_pattern(num_layers: int) -> list[str]:
    """['rec','rec','attn', ...] for the given depth."""
    return [("attn" if i % 3 == 2 else "rec") for i in range(num_layers)]


def _num_super(cfg: ArchConfig) -> tuple[int, int]:
    """(#full superblocks, #trailing rec layers)."""
    ns = cfg.num_layers // 3
    return ns, cfg.num_layers - 3 * ns


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_rec_layer(key: Array, cfg: ArchConfig):
    D, W = cfg.d_model, cfg.lru_width
    ks = jax.random.split(key, 5)
    std = D ** -0.5
    return {
        "ln1": L.init_rms_norm(D),
        "w_gate": jax.random.normal(ks[0], (D, W), L.PARAM_DTYPE) * std,
        "w_in": jax.random.normal(ks[1], (D, W), L.PARAM_DTYPE) * std,
        "conv_w": jax.random.normal(ks[2], (CONV_K, W), L.PARAM_DTYPE)
                  * CONV_K ** -0.5,
        "conv_b": jnp.zeros((W,), L.PARAM_DTYPE),
        "wa": jax.random.normal(ks[3], (W, W), L.PARAM_DTYPE) * W ** -0.5 * 0.1,
        "ba": jnp.zeros((W,), L.PARAM_DTYPE),
        "wx": jax.random.normal(ks[4], (W, W), L.PARAM_DTYPE) * W ** -0.5 * 0.1,
        "bx": jnp.zeros((W,), L.PARAM_DTYPE),
        # Lambda init so that a = sigmoid(Lambda) in (0.9, 0.999)
        "lam": jnp.linspace(2.2, 6.9, W).astype(L.PARAM_DTYPE),
        "w_out": jax.random.normal(jax.random.fold_in(key, 9), (W, D),
                                   L.PARAM_DTYPE) * W ** -0.5,
        "ln2": L.init_rms_norm(D),
        "mlp": L.init_swiglu(jax.random.fold_in(key, 10), D, cfg.d_ff),
    }


def _axes_rec_layer():
    return {
        "ln1": L.axes_rms_norm(),
        "w_gate": logical("embed", "lru", name="rec.w_gate"),
        "w_in": logical("embed", "lru", name="rec.w_in"),
        "conv_w": logical(None, "lru", name="rec.conv_w"),
        "conv_b": logical("lru", name="rec.conv_b"),
        "wa": logical(None, "lru", name="rec.wa"),
        "ba": logical("lru", name="rec.ba"),
        "wx": logical(None, "lru", name="rec.wx"),
        "bx": logical("lru", name="rec.bx"),
        "lam": logical("lru", name="rec.lam"),
        "w_out": logical("lru", "embed", name="rec.w_out"),
        "ln2": L.axes_rms_norm(),
        "mlp": L.axes_swiglu(),
    }


def _init_attn_layer(key: Array, cfg: ArchConfig, layout):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.init_rms_norm(cfg.d_model),
        "attn": L.init_attention(k1, cfg.d_model, layout, cfg.head_dim_),
        "ln2": L.init_rms_norm(cfg.d_model),
        "mlp": L.init_swiglu(k2, cfg.d_model, cfg.d_ff),
    }


def _axes_attn_layer():
    return {
        "ln1": L.axes_rms_norm(),
        "attn": L.axes_attention(),
        "ln2": L.axes_rms_norm(),
        "mlp": L.axes_swiglu(),
    }


def init_params(key: Array, cfg: ArchConfig, tp: int = 16):
    layout = _layout(cfg, tp)
    ns, nt = _num_super(cfg)
    ke, ku, k1, k2, k3, k4 = jax.random.split(key, 6)
    p = {
        "embed": L.init_embedding(ke, cfg.vocab_padded(tp), cfg.d_model),
        "super": {
            "rec1": jax.vmap(lambda k: _init_rec_layer(k, cfg))(
                jax.random.split(k1, ns)),
            "rec2": jax.vmap(lambda k: _init_rec_layer(k, cfg))(
                jax.random.split(k2, ns)),
            "attn": jax.vmap(lambda k: _init_attn_layer(k, cfg, layout))(
                jax.random.split(k3, ns)),
        },
        "final_norm": L.init_rms_norm(cfg.d_model),
    }
    if nt:
        p["tail"] = jax.vmap(lambda k: _init_rec_layer(k, cfg))(
            jax.random.split(k4, nt))
    if not cfg.tie_embeddings:
        p["unembed"] = L.init_unembed(ku, cfg.d_model, cfg.vocab_padded(tp))
    return p


def param_axes(cfg: ArchConfig):
    from .transformer import _stack_axes
    ns, nt = _num_super(cfg)
    a = {
        "embed": L.axes_embedding(),
        "super": {
            "rec1": _stack_axes(_axes_rec_layer()),
            "rec2": _stack_axes(_axes_rec_layer()),
            "attn": _stack_axes(_axes_attn_layer()),
        },
        "final_norm": L.axes_rms_norm(),
    }
    if nt:
        a["tail"] = _stack_axes(_axes_rec_layer())
    if not cfg.tie_embeddings:
        a["unembed"] = L.axes_unembed()
    return a


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

def _rglru_scan(xw: Array, r: Array, i: Array, lam: Array,
                h0: Array | None = None):
    """xw/r/i: (B, S, W) -> (y (B,S,W), h_last (B,W)).  Associative scan."""
    log_a = LRU_C * r.astype(jnp.float32) * jax.nn.log_sigmoid(
        lam.astype(jnp.float32))                          # (B,S,W), negative
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) \
        * (i.astype(jnp.float32) * xw.astype(jnp.float32))
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_c, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(xw.dtype), h[:, -1]


def _causal_conv(xw: Array, w: Array, b: Array) -> Array:
    K = w.shape[0]
    pad = jnp.pad(xw, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros(xw.shape, jnp.float32)
    for i in range(K):
        out = out + pad[:, i:i + xw.shape[1]].astype(jnp.float32) \
            * w[K - 1 - i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(xw.dtype)


def _rec_block(lp, cfg: ArchConfig, x: Array, *, h0=None, conv0=None,
               return_state: bool = False):
    """Griffin recurrent block (full-sequence).  x: (B,S,D)."""
    cd = L.COMPUTE_DTYPE
    h = L.rms_norm(x, lp["ln1"]["scale"], cfg.norm_eps)
    gate = jax.nn.gelu(hint(jnp.einsum(
        "bsd,dw->bsw", h.astype(cd), lp["w_gate"].astype(cd)),
        "dp", None, "model").astype(jnp.float32),
                       approximate=True).astype(cd)
    xw = hint(jnp.einsum("bsd,dw->bsw", h.astype(cd), lp["w_in"].astype(cd)),
              "dp", None, "model")
    if conv0 is not None:                                 # decode-time prepend
        xw_full = jnp.concatenate([conv0.astype(cd), xw], axis=1)
        conv_out = _causal_conv(xw_full, lp["conv_w"], lp["conv_b"])
        conv_out = conv_out[:, conv0.shape[1]:]
    else:
        conv_out = _causal_conv(xw, lp["conv_w"], lp["conv_b"])
    r = jax.nn.sigmoid(jnp.einsum("bsw,wu->bsu", conv_out.astype(jnp.float32),
                                  lp["wa"].astype(jnp.float32))
                       + lp["ba"].astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("bsw,wu->bsu", conv_out.astype(jnp.float32),
                                  lp["wx"].astype(jnp.float32))
                       + lp["bx"].astype(jnp.float32))
    y, h_last = _rglru_scan(conv_out, r, i, lp["lam"], h0=h0)
    y = y * gate
    out = jnp.einsum("bsw,wd->bsd", y.astype(cd), lp["w_out"].astype(cd))
    x = hint_act(x + out)
    # MLP
    hn = L.rms_norm(x, lp["ln2"]["scale"], cfg.norm_eps)
    x = x + L.swiglu(lp["mlp"], hn)
    if return_state:
        conv_tail = xw[:, -(CONV_K - 1):] if xw.shape[1] >= CONV_K - 1 \
            else jnp.pad(xw, ((0, 0), (CONV_K - 1 - xw.shape[1], 0), (0, 0)))
        return x, (h_last, conv_tail)
    return x, None


def _attn_block(lp, cfg: ArchConfig, layout, x: Array, positions: Array,
                *, collect_kv: bool = False):
    h = L.rms_norm(x, lp["ln1"]["scale"], cfg.norm_eps)
    q, k, v = L.qkv_project(lp["attn"], h, layout, positions=positions,
                            rope_theta=cfg.rope_theta or None)
    o = L.attention_chunked(q, k, v, layout, causal=True,
                            window=cfg.local_window, kv_chunk=cfg.attn_chunk)
    x = x + L.attn_output(lp["attn"], o)
    hn = L.rms_norm(x, lp["ln2"]["scale"], cfg.norm_eps)
    x = x + L.swiglu(lp["mlp"], hn)
    return x, ((k, v) if collect_kv else None)


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------

def forward(params, cfg: ArchConfig, batch, *, tp: int = 16,
            collect: bool = False):
    layout = _layout(cfg, tp)
    x = hint_act(L.embed(params["embed"], batch["tokens"]))
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def super_body(carry, lp):
        h = carry
        h, s1 = _rec_block(lp["rec1"], cfg, h, return_state=collect)
        h, s2 = _rec_block(lp["rec2"], cfg, h, return_state=collect)
        h, kv = _attn_block(lp["attn"], cfg, layout, h, positions,
                            collect_kv=collect)
        return h, (s1, s2, kv) if collect else None

    body = jax.checkpoint(super_body) if cfg.remat else super_body
    x, collected = jax.lax.scan(body, x, params["super"])

    tail_states = []
    if "tail" in params:
        def tail_body(carry, lp):
            h, st = _rec_block(lp, cfg, carry, return_state=collect)
            return h, st
        tbody = jax.checkpoint(tail_body) if cfg.remat else tail_body
        x, tail_states = jax.lax.scan(tbody, x, params["tail"])

    x = L.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x.astype(L.COMPUTE_DTYPE),
                            params["embed"]["table"].astype(L.COMPUTE_DTYPE))
    else:
        logits = L.unembed(params["unembed"], x)
    return logits, (collected, tail_states)


def loss_fn(params, cfg: ArchConfig, batch, *, tp: int = 16) -> Array:
    logits, _ = forward(params, cfg, batch, tp=tp)
    return L.cross_entropy_loss(logits[:, :-1], batch["labels"][:, 1:],
                                vocab_real=cfg.vocab_size)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch_size: int, cache_len: int,
               tp: int = 16):
    layout = _layout(cfg, tp)
    ns, nt = _num_super(cfg)
    W = cfg.lru_width
    Skv = min(cache_len, cfg.local_window)
    return {
        "lru1": jnp.zeros((ns, batch_size, W), jnp.float32),
        "conv1": jnp.zeros((ns, batch_size, CONV_K - 1, W), L.COMPUTE_DTYPE),
        "lru2": jnp.zeros((ns, batch_size, W), jnp.float32),
        "conv2": jnp.zeros((ns, batch_size, CONV_K - 1, W), L.COMPUTE_DTYPE),
        "k": jnp.zeros((ns, batch_size, Skv, layout.kv_padded, cfg.head_dim_),
                       L.COMPUTE_DTYPE),
        "v": jnp.zeros((ns, batch_size, Skv, layout.kv_padded, cfg.head_dim_),
                       L.COMPUTE_DTYPE),
        "lru_t": jnp.zeros((max(nt, 1), batch_size, W), jnp.float32),
        "conv_t": jnp.zeros((max(nt, 1), batch_size, CONV_K - 1, W),
                            L.COMPUTE_DTYPE),
        "pos": jnp.zeros((), jnp.int32),
    }


def cache_axes(cfg: ArchConfig, *, seq_shard: bool = False):
    kv = logical("layers", "batch", None, "kv_heads", "head_dim",
                 name="cache.kv")
    lru = logical("layers", "batch", "lru", name="cache.lru")
    conv = logical("layers", "batch", None, "lru", name="cache.conv")
    return {"lru1": lru, "conv1": conv, "lru2": lru, "conv2": conv,
            "k": kv, "v": kv, "lru_t": lru, "conv_t": conv,
            "pos": logical(name="cache.pos")}


def prefill(params, cfg: ArchConfig, batch, *, tp: int = 16,
            cache_len: int | None = None):
    tokens = batch["tokens"]
    B, S = tokens.shape
    logits, (collected, tail_states) = forward(params, cfg, batch, tp=tp,
                                               collect=True)
    (s1, s2, kvs) = collected
    k, v = kvs
    Skv = min(cache_len or S, cfg.local_window)
    if k.shape[2] > Skv:
        k, v = k[:, :, -Skv:], v[:, :, -Skv:]
    elif k.shape[2] < Skv:
        padn = Skv - k.shape[2]
        k = jnp.pad(k, ((0, 0), (0, 0), (0, padn), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, padn), (0, 0), (0, 0)))
    cache = {
        "lru1": s1[0], "conv1": s1[1], "lru2": s2[0], "conv2": s2[1],
        "k": k, "v": v, "pos": jnp.asarray(S, jnp.int32),
    }
    ns, nt = _num_super(cfg)
    if nt:
        cache["lru_t"] = tail_states[0]
        cache["conv_t"] = tail_states[1]
    else:
        cache["lru_t"] = jnp.zeros((1, B, cfg.lru_width), jnp.float32)
        cache["conv_t"] = jnp.zeros((1, B, CONV_K - 1, cfg.lru_width),
                                    L.COMPUTE_DTYPE)
    return logits[:, -1], cache


def _rec_step(lp, cfg, x, lru, conv):
    """Single-token recurrent block; x (B,1,D)."""
    x2, (h_last, _) = _rec_block(lp, cfg, x, h0=lru, conv0=conv,
                                 return_state=True)
    # ring-update conv state: append this token's xw
    cd = L.COMPUTE_DTYPE
    h = L.rms_norm(x, lp["ln1"]["scale"], cfg.norm_eps)
    xw = jnp.einsum("bsd,dw->bsw", h.astype(cd), lp["w_in"].astype(cd))
    conv_new = jnp.concatenate([conv[:, 1:], xw.astype(cd)], axis=1)
    return x2, h_last, conv_new


def decode_step(params, cfg: ArchConfig, cache, tokens: Array, *,
                tp: int = 16):
    layout = _layout(cfg, tp)
    x = L.embed(params["embed"], tokens)
    pos = cache["pos"]
    positions = jnp.full((x.shape[0], 1), pos, jnp.int32)
    Skv = cache["k"].shape[2]
    slot = pos % Skv

    def super_body(h, lc):
        lp, l1, c1, l2, c2, kc, vc = lc
        h, nl1, nc1 = _rec_step(lp["rec1"], cfg, h, l1, c1)
        h, nl2, nc2 = _rec_step(lp["rec2"], cfg, h, l2, c2)
        hn = L.rms_norm(h, lp["attn"]["ln1"]["scale"], cfg.norm_eps)
        q, k, v = L.qkv_project(lp["attn"]["attn"], hn, layout,
                                positions=positions,
                                rope_theta=cfg.rope_theta or None)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k, slot, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v, slot, axis=1)
        o = L.attention_decode(q, kc, vc, layout,
                               cur_len=jnp.full((h.shape[0],), pos + 1),
                               window=cfg.local_window)
        h = h + L.attn_output(lp["attn"]["attn"], o)
        hn = L.rms_norm(h, lp["attn"]["ln2"]["scale"], cfg.norm_eps)
        h = h + L.swiglu(lp["attn"]["mlp"], hn)
        return h, (nl1, nc1, nl2, nc2, kc, vc)

    h, (l1s, c1s, l2s, c2s, ks, vs) = jax.lax.scan(
        super_body, x,
        (params["super"], cache["lru1"], cache["conv1"],
         cache["lru2"], cache["conv2"], cache["k"], cache["v"]))

    lts, cts = cache["lru_t"], cache["conv_t"]
    if "tail" in params:
        def tail_body(hh, lc):
            lp, lt, ct = lc
            hh, nl, nc = _rec_step(lp, cfg, hh, lt, ct)
            return hh, (nl, nc)
        h, (lts, cts) = jax.lax.scan(
            tail_body, h, (params["tail"], cache["lru_t"], cache["conv_t"]))

    h = L.rms_norm(h, params["final_norm"]["scale"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", h.astype(L.COMPUTE_DTYPE),
                            params["embed"]["table"].astype(L.COMPUTE_DTYPE))
    else:
        logits = L.unembed(params["unembed"], h)
    new_cache = {"lru1": l1s, "conv1": c1s, "lru2": l2s, "conv2": c2s,
                 "k": ks, "v": vs, "lru_t": lts, "conv_t": cts,
                 "pos": pos + 1}
    return logits[:, 0], new_cache
