"""DWN as a production arch: the paper's accelerator on the TPU mesh.

The FPGA accelerator is fully parallel — one sample per cycle.  The TPU
equivalent is throughput serving/training over a very large sample batch,
data-parallel across the pod, with the LUT layer tensor-parallel over
"model" (selection matmul + table evaluation sharded by LUT).

Exposes the same module interface as the LM families so the dry-run can
lower it on the production meshes:

  * ``loss_fn``  — the differentiable DWN training step (EFD + learnable
    mapping + popcount CE), batch (B, 16) features;
  * ``prefill``  — batched hard inference (the accelerator datapath):
    thermometer encode -> one-hot selection matmul -> corner-product LUT
    eval -> popcount -> argmax.  Two variants:
      - staged (baseline): the (B, F*T) bit tensor is materialized, the
        exact analogue of a PEN design with a stand-alone encoder stage;
      - fused (beyond-paper): ``lax.map`` over batch blocks so the unary
        blow-up lives only in VMEM-sized tiles (the Pallas fused kernel
        expresses the same insight on real TPUs; this variant makes it
        visible to the dry-run/roofline on the CPU pipeline).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..sharding.annotate import hint
from ..sharding.partition import logical
from . import layers as L

Array = jax.Array

FUSED_BLOCK = 4096          # samples per VMEM-resident block (fused path)


def _dims(cfg: ArchConfig):
    F, T = cfg.d_model, cfg.dwn_bits
    m, n = cfg.dwn_luts, 6
    C = cfg.vocab_size
    return F, T, m, n, C


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def init_params(key: Array, cfg: ArchConfig, tp: int = 16):
    F, T, m, n, C = _dims(cfg)
    k1, k2 = jax.random.split(key)
    # uniform threshold grid stand-in (training fits distributive quantiles)
    th = jnp.linspace(-1.0, 1.0, T + 2)[1:-1]
    return {
        "thresholds": jnp.tile(th[None], (F, 1)).astype(jnp.float32),
        "scores": jax.random.normal(k1, (m, n, F * T), jnp.float32) * 0.01,
        "tables": jax.random.uniform(k2, (m, 2 ** n), jnp.float32,
                                     minval=-1, maxval=1),
    }


def param_axes(cfg: ArchConfig):
    return {
        "thresholds": logical(None, None, name="dwn.thresholds"),
        "scores": logical("ff", None, None, name="dwn.scores"),
        "tables": logical("ff", None, name="dwn.tables"),
    }


# ---------------------------------------------------------------------------
# training step (differentiable, EFD)
# ---------------------------------------------------------------------------

def loss_fn(params, cfg: ArchConfig, batch, *, tp: int = 16) -> Array:
    from ..core.lut_layer import lut_layer_apply
    from ..core.classifier import cross_entropy, group_popcount
    F, T, m, n, C = _dims(cfg)
    x = hint(batch["features"], "dp", None)          # (B, F)
    bits = (x[:, :, None] > params["thresholds"][None]).astype(jnp.float32)
    bits = jax.lax.stop_gradient(bits.reshape(x.shape[0], F * T))
    out = lut_layer_apply(
        {"scores": params["scores"], "tables": params["tables"]}, bits)
    counts = group_popcount(out, C)
    tau = max(0.3, (m // C) / 12.0)
    return cross_entropy(counts / tau, batch["labels"])


# ---------------------------------------------------------------------------
# serving (the accelerator datapath)
# ---------------------------------------------------------------------------

def _hard_forward(params, cfg: ArchConfig, x: Array) -> Array:
    """Hard inference datapath.

    baseline ("corner"/"contig"): bits fully materialized in f32, LUT
    read via the (B, m, 64) corner expansion, contiguous class groups
    (paper Fig. 1 layout) — whose reshape straddles the model shards and
    forces an all-gather of the LUT outputs.

    optimized ("gather"/"strided", §Perf iters 2-3): bf16 bits feed the
    selection matmul; the LUT read is an address gather (no (B, m, 64)
    tensor); LUTs are class-strided so the per-shard popcount partials
    all-reduce 5 floats per sample instead of gathering m bits.
    """
    F, T, m, n, C = _dims(cfg)
    B = x.shape[0]
    bits = (x[:, :, None] > params["thresholds"][None]).astype(
        L.COMPUTE_DTYPE).reshape(B, F * T)
    bits = hint(bits, "dp", None)
    # learned wiring as a dense selection matmul (MXU form)
    sel_oh = jax.nn.one_hot(jnp.argmax(params["scores"], -1), F * T,
                            dtype=L.COMPUTE_DTYPE)   # (m, n, F*T)
    s = hint(jnp.einsum("bc,mnc->bmn", bits, sel_oh), "dp", "model", None)
    if cfg.dwn_datapath == "gather":
        # address gather: no (B, m, 2^n) intermediate
        weights = (2 ** jnp.arange(n, dtype=jnp.int32))
        addr = jnp.sum(s.astype(jnp.int32) * weights, axis=-1)   # (B, m)
        tab_flat = (params["tables"] > 0).astype(jnp.float32).reshape(-1)
        flat_idx = jnp.arange(m, dtype=jnp.int32)[None] * (2 ** n) + addr
        out = jnp.take(tab_flat, flat_idx)            # (B, m)
    else:
        tab = (params["tables"] > 0).astype(jnp.float32)
        A = 2 ** n
        w = jnp.ones(s.shape[:2] + (A,), jnp.float32)
        corners = ((jnp.arange(A)[:, None] >> jnp.arange(n)[None]) & 1) \
            .astype(jnp.float32)                      # (A, n)
        for i in range(n):
            si = s[..., i:i + 1].astype(jnp.float32)
            w = w * (si * corners[None, None, :, i]
                     + (1 - si) * (1 - corners[None, None, :, i]))
        out = jnp.einsum("bma,ma->bm", w, tab)        # (B, m)
    out = hint(out, "dp", "model")
    if cfg.dwn_grouping == "strided":
        # LUT j -> class j % C: per-shard blocks stay class-complete, so
        # the group reduce partial-sums locally + all-reduces (B, C)
        counts = out.reshape(B, m // C, C).sum(1)
    else:
        counts = out.reshape(B, C, m // C).sum(-1)
    return counts


def prefill(params, cfg: ArchConfig, batch, *, tp: int = 16,
            cache_len: int | None = None):
    """Batched inference; returns (argmax 'logits', trivial cache)."""
    x = batch["features"]
    if cfg.dwn_fused:
        # block the batch so each chip's per-block bit tile is VMEM-sized
        # (~4k samples/chip); the Pallas fused kernel realizes the same
        # blocking natively on TPU with the selection matrix resident.
        nb = 16 if x.shape[0] % 16 == 0 else 1
        xb = x.reshape(nb, -1, x.shape[-1])
        counts = jax.lax.map(
            lambda xc: _hard_forward(params, cfg, xc), xb)
        counts = counts.reshape(x.shape[0], -1)
    else:
        counts = _hard_forward(params, cfg, x)
    cache = {"pos": jnp.zeros((), jnp.int32)}
    return counts, cache


def init_cache(cfg: ArchConfig, batch_size: int, cache_len: int,
               tp: int = 16):
    return {"pos": jnp.zeros((), jnp.int32)}


def cache_axes(cfg: ArchConfig, *, seq_shard: bool = False):
    return {"pos": logical(name="cache.pos")}


def decode_step(params, cfg: ArchConfig, cache, tokens, *, tp: int = 16):
    raise NotImplementedError("DWN is a feed-forward classifier; "
                              "serving = batched prefill")
