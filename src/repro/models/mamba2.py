"""Mamba-2 (SSD, state-space duality) — attention-free LM backbone.

Implements the chunked SSD algorithm of Dao & Gu (arXiv:2405.21060) in a
matmul-dominant form that maps onto the TPU MXU:

* the sequence is split into chunks of ``cfg.ssm_chunk``;
* within a chunk, outputs are computed with dense matmuls
  (C B^T ⊙ decay-mask) X — the "quadratic branch";
* across chunks, a ``lax.scan`` carries the (heads, headdim, state) SSM
  state — the "linear branch".

Decode is the plain SSM recurrence: h = a·h + (dt·x)·B^T;  y = C·h + D·x,
with a depthwise conv ring buffer of width ``ssm_conv``.

TP sharding: heads over "model" (64 heads / 16 = 4 per shard); B/C (the
``ngroups=1`` group dims) are replicated — they are dstate-sized vectors
per token, three orders of magnitude smaller than the head channels.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..sharding.annotate import hint, hint_act
from ..sharding.partition import logical
from . import layers as L

Array = jax.Array


def _dims(cfg: ArchConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    nheads = d_inner // cfg.ssm_headdim
    return d_inner, nheads


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_block(key: Array, cfg: ArchConfig):
    d_inner, nheads = _dims(cfg)
    D, N, G = cfg.d_model, cfg.ssm_state, cfg.ssm_ngroups
    ks = jax.random.split(key, 6)
    std = D ** -0.5
    conv_ch = d_inner + 2 * G * N
    p = {
        "ln": L.init_rms_norm(D),
        # split in_proj so each segment gets its natural sharding
        "w_z": jax.random.normal(ks[0], (D, d_inner), L.PARAM_DTYPE) * std,
        "w_x": jax.random.normal(ks[1], (D, d_inner), L.PARAM_DTYPE) * std,
        "w_bc": jax.random.normal(ks[2], (D, 2 * G * N), L.PARAM_DTYPE) * std,
        "w_dt": jax.random.normal(ks[3], (D, nheads), L.PARAM_DTYPE) * std,
        "dt_bias": jnp.log(jnp.expm1(                      # softplus^-1 grid
            jnp.linspace(1e-3, 0.1, nheads, dtype=L.PARAM_DTYPE))),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nheads, dtype=L.PARAM_DTYPE)),
        "D_skip": jnp.ones((nheads,), L.PARAM_DTYPE),
        "conv_w": jax.random.normal(ks[4], (cfg.ssm_conv, conv_ch),
                                    L.PARAM_DTYPE) * (cfg.ssm_conv ** -0.5),
        "conv_b": jnp.zeros((conv_ch,), L.PARAM_DTYPE),
        "out_norm": L.init_rms_norm(d_inner),
        "w_out": jax.random.normal(ks[5], (d_inner, D), L.PARAM_DTYPE)
                 * d_inner ** -0.5,
    }
    return p


def _block_axes(cfg: ArchConfig):
    return {
        "ln": L.axes_rms_norm(),
        "w_z": logical("embed", "conv_dim", name="ssm.w_z"),
        "w_x": logical("embed", "conv_dim", name="ssm.w_x"),
        "w_bc": logical("embed", None, name="ssm.w_bc"),
        "w_dt": logical("embed", "ssm_heads", name="ssm.w_dt"),
        "dt_bias": logical("ssm_heads", name="ssm.dt_bias"),
        "A_log": logical("ssm_heads", name="ssm.A_log"),
        "D_skip": logical("ssm_heads", name="ssm.D_skip"),
        "conv_w": logical(None, "conv_dim", name="ssm.conv_w"),
        "conv_b": logical("conv_dim", name="ssm.conv_b"),
        "out_norm": {"scale": logical("conv_dim", name="ssm.out_norm")},
        "w_out": logical("conv_dim", "embed", name="ssm.w_out"),
    }


def init_params(key: Array, cfg: ArchConfig, tp: int = 16):
    ke, ku, kl = jax.random.split(key, 3)
    lkeys = jax.random.split(kl, cfg.num_layers)
    layers_p = jax.vmap(lambda k: _init_block(k, cfg))(lkeys)
    p = {
        "embed": L.init_embedding(ke, cfg.vocab_padded(tp), cfg.d_model),
        "layers": layers_p,
        "final_norm": L.init_rms_norm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = L.init_unembed(ku, cfg.d_model, cfg.vocab_padded(tp))
    return p


def param_axes(cfg: ArchConfig):
    from .transformer import _stack_axes
    a = {
        "embed": L.axes_embedding(),
        "layers": _stack_axes(_block_axes(cfg)),
        "final_norm": L.axes_rms_norm(),
    }
    if not cfg.tie_embeddings:
        a["unembed"] = L.axes_unembed()
    return a


# ---------------------------------------------------------------------------
# chunked SSD forward
# ---------------------------------------------------------------------------

def _segsum(a: Array) -> Array:
    """a: (..., Q) log-decays -> (..., Q, Q) lower-tri cumulative sums:
    out[..., i, j] = sum_{k=j+1..i} a[k]  (i >= j), -inf above diagonal."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]           # sum_(j+1..i)
    mask = jnp.tril(jnp.ones((Q, Q), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def _ssd_chunked(x: Array, dt: Array, A: Array, B: Array, C: Array,
                 chunk: int, h0: Array | None = None):
    """Chunked SSD scan.

    x:  (Bt, S, H, P)   — value channels per head
    dt: (Bt, S, H)      — positive step sizes (softplus already applied)
    A:  (H,)            — positive decay rates (a_t = exp(-dt*A))
    B:  (Bt, S, G, N)   — input projections (G groups broadcast over H)
    C:  (Bt, S, G, N)   — output projections
    h0: optional initial state (Bt, H, P, N)
    Returns (y (Bt,S,H,P), h_last (Bt,H,P,N)).
    """
    Bt, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    if S % chunk:                       # pad: dt=0 => a=1, no contribution
        padn = chunk - S % chunk
        x = jnp.pad(x, ((0, 0), (0, padn), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, padn), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, padn), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, padn), (0, 0), (0, 0)))
        y, h_last = _ssd_chunked(x, dt, A, B, C, chunk, h0=h0)
        return y[:, :S], h_last
    nc = S // chunk
    rep = H // G

    def to_chunks(t):
        return t.reshape(Bt, nc, chunk, *t.shape[2:])

    xc, dtc = to_chunks(x), to_chunks(dt)
    Bc, Cc = to_chunks(B), to_chunks(C)
    # broadcast groups over heads
    Bh = jnp.repeat(Bc, rep, axis=3)                      # (Bt,nc,Q,H,N)
    Ch = jnp.repeat(Cc, rep, axis=3)

    la = (-dtc * A[None, None, None, :]).astype(jnp.float32)  # log decay (Bt,nc,Q,H)
    seg = _segsum(la.transpose(0, 1, 3, 2))               # (Bt,nc,H,Q,Q)
    decay_mask = jnp.exp(seg)

    cd = L.COMPUTE_DTYPE
    # intra-chunk (quadratic branch): Y = ((C B^T) ⊙ M) (dt·X)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Ch.astype(cd), Bh.astype(cd),
                        preferred_element_type=jnp.float32)
    scores = scores * decay_mask
    xdt = xc * dtc[..., None]
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", scores.astype(cd),
                         xdt.astype(cd), preferred_element_type=jnp.float32)

    # chunk summaries: state contribution of each chunk
    la_cum = jnp.cumsum(la, axis=2)                       # (Bt,nc,Q,H)
    la_tot = la_cum[:, :, -1]                             # (Bt,nc,H)
    # decay from position q to end of its chunk
    decay_to_end = jnp.exp(la_tot[:, :, None] - la_cum)   # (Bt,nc,Q,H)
    states = jnp.einsum("bcqhn,bcqhp->bchpn",
                        (Bh * (dtc * decay_to_end)[..., None]).astype(cd),
                        xc.astype(cd), preferred_element_type=jnp.float32)

    # inter-chunk scan over chunk states
    def scan_fn(h, xs):
        st, lt = xs                                       # (Bt,H,P,N), (Bt,H)
        h_new = h * jnp.exp(lt)[:, :, None, None] + st
        return h_new, h                                   # emit state *before* chunk

    h_init = (jnp.zeros((Bt, H, P, N), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))
    h_last, h_prevs = jax.lax.scan(
        scan_fn, h_init,
        (states.transpose(1, 0, 2, 3, 4), la_tot.transpose(1, 0, 2)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)            # (Bt,nc,H,P,N)

    # inter-chunk (linear branch): y += C · decayed incoming state
    decay_in = jnp.exp(la_cum)                            # decay 0..q
    y_inter = jnp.einsum("bcqhn,bchpn->bcqhp", Ch.astype(cd),
                         h_prevs.astype(cd),
                         preferred_element_type=jnp.float32)
    y_inter = y_inter * decay_in[..., None]

    y = (y_intra + y_inter).reshape(Bt, S, H, P)
    return y.astype(cd), h_last


def _causal_conv(xbc: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv along S.  xbc (Bt,S,C), w (K,C)."""
    K = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    for i in range(K):                                    # K is 4: unrolled
        out = out + pad[:, i:i + xbc.shape[1]].astype(jnp.float32) \
            * w[K - 1 - i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(xbc.dtype)


def _block_apply(lp, cfg: ArchConfig, x: Array, *, state=None,
                 conv_state=None):
    """Full-sequence SSD block.  state/conv_state: optional initial carry."""
    d_inner, nheads = _dims(cfg)
    G, N, P = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_headdim
    cd = L.COMPUTE_DTYPE
    h = L.rms_norm(x, lp["ln"]["scale"], cfg.norm_eps)
    z = hint(jnp.einsum("bsd,di->bsi", h.astype(cd), lp["w_z"].astype(cd)),
             "dp", None, "model")
    xin = hint(jnp.einsum("bsd,di->bsi", h.astype(cd), lp["w_x"].astype(cd)),
               "dp", None, "model")
    bc = hint(jnp.einsum("bsd,dg->bsg", h.astype(cd), lp["w_bc"].astype(cd)),
              "dp", None, None)
    dt_raw = hint(jnp.einsum("bsd,dh->bsh", h.astype(cd),
                             lp["w_dt"].astype(cd)), "dp", None, "model")

    conv_in = jnp.concatenate([xin, bc], axis=-1)         # (B,S,conv_ch)
    conv_out = _causal_conv(conv_in, lp["conv_w"], lp["conv_b"])
    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(cd)
    xin = conv_out[..., :d_inner]
    B_ = conv_out[..., d_inner:d_inner + G * N]
    C_ = conv_out[..., d_inner + G * N:]

    Bt, S = x.shape[0], x.shape[1]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + lp["dt_bias"].astype(jnp.float32))
    A = jnp.exp(lp["A_log"].astype(jnp.float32))
    from ..sharding.annotate import hint_heads
    xh = hint_heads(xin.reshape(Bt, S, nheads, P))
    Bh = B_.reshape(Bt, S, G, N)
    Ch = C_.reshape(Bt, S, G, N)
    y, h_last = _ssd_chunked(xh, dt, A, Bh, Ch, min(cfg.ssm_chunk, S),
                             h0=state)
    y = y + xh.astype(jnp.float32).astype(cd) \
        * lp["D_skip"].astype(cd)[None, None, :, None]
    y = y.reshape(Bt, S, d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(cd)  # gated
    y = L.rms_norm(y, lp["out_norm"]["scale"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, lp["w_out"].astype(cd))
    return hint_act(x + out), h_last


def forward(params, cfg: ArchConfig, batch, *, tp: int = 16,
            collect_state: bool = False):
    x = hint_act(L.embed(params["embed"], batch["tokens"]))

    def body(carry, lp):
        h, = carry
        h2, st = _block_apply(lp, cfg, h)
        return (h2,), st if collect_state else None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x,), states = jax.lax.scan(body_fn, (x,), params["layers"])
    x = L.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x.astype(L.COMPUTE_DTYPE),
                            params["embed"]["table"].astype(L.COMPUTE_DTYPE))
    else:
        logits = L.unembed(params["unembed"], x)
    return logits, states


def loss_fn(params, cfg: ArchConfig, batch, *, tp: int = 16) -> Array:
    logits, _ = forward(params, cfg, batch, tp=tp)
    return L.cross_entropy_loss(logits[:, :-1], batch["labels"][:, 1:],
                                vocab_real=cfg.vocab_size)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch_size: int, cache_len: int,
               tp: int = 16):
    """SSM 'cache' = per-layer state + conv ring buffer (+pos).  cache_len
    is irrelevant (O(1) state) — that is the whole point for long_500k."""
    d_inner, nheads = _dims(cfg)
    G, N, P = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_headdim
    conv_ch = d_inner + 2 * G * N
    Lc = cfg.num_layers
    return {
        "ssm": jnp.zeros((Lc, batch_size, nheads, P, N), jnp.float32),
        "conv": jnp.zeros((Lc, batch_size, cfg.ssm_conv - 1, conv_ch),
                          L.COMPUTE_DTYPE),
        "pos": jnp.zeros((), jnp.int32),
    }


def cache_axes(cfg: ArchConfig, *, seq_shard: bool = False):
    return {
        "ssm": logical("layers", "batch", "ssm_heads", None, None,
                       name="cache.ssm"),
        "conv": logical("layers", "batch", None, "conv_dim",
                        name="cache.conv"),
        "pos": logical(name="cache.pos"),
    }


def prefill(params, cfg: ArchConfig, batch, *, tp: int = 16,
            cache_len: int | None = None):
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = L.embed(params["embed"], tokens)
    d_inner, nheads = _dims(cfg)
    G, N = cfg.ssm_ngroups, cfg.ssm_state
    conv_ch = d_inner + 2 * G * N

    def body(h, lp):
        # recompute conv tail for the cache: cheap closed form — the last
        # (K-1) conv inputs of this layer
        hn = L.rms_norm(h, lp["ln"]["scale"], cfg.norm_eps)
        cd = L.COMPUTE_DTYPE
        xin = jnp.einsum("bsd,di->bsi", hn.astype(cd), lp["w_x"].astype(cd))
        bc = jnp.einsum("bsd,dg->bsg", hn.astype(cd), lp["w_bc"].astype(cd))
        conv_tail = jnp.concatenate([xin, bc], -1)[:, -(cfg.ssm_conv - 1):]
        h2, st = _block_apply(lp, cfg, h)
        return h2, (st, conv_tail)

    x, (states, conv_tails) = jax.lax.scan(body, x, params["layers"])
    x = L.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x.astype(L.COMPUTE_DTYPE),
                            params["embed"]["table"].astype(L.COMPUTE_DTYPE))
    else:
        logits = L.unembed(params["unembed"], x)
    cache = {"ssm": states, "conv": conv_tails,
             "pos": jnp.asarray(S, jnp.int32)}
    return logits[:, -1], cache


def decode_step(params, cfg: ArchConfig, cache, tokens: Array, *,
                tp: int = 16):
    """Single-token SSM recurrence."""
    d_inner, nheads = _dims(cfg)
    G, N, P = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_headdim
    cd = L.COMPUTE_DTYPE
    x = L.embed(params["embed"], tokens)                  # (B,1,D)

    def body(h, lc):
        lp, ssm, conv = lc                                # ssm (B,H,P,N)
        hn = L.rms_norm(h, lp["ln"]["scale"], cfg.norm_eps)
        z = jnp.einsum("bsd,di->bsi", hn.astype(cd), lp["w_z"].astype(cd))
        xin = jnp.einsum("bsd,di->bsi", hn.astype(cd), lp["w_x"].astype(cd))
        bc = jnp.einsum("bsd,dg->bsg", hn.astype(cd), lp["w_bc"].astype(cd))
        dt_raw = jnp.einsum("bsd,dh->bsh", hn.astype(cd), lp["w_dt"].astype(cd))
        cin = jnp.concatenate([xin, bc], -1)[:, 0]        # (B,C)
        # conv ring: full window = [conv_state, cin]; win[:, -1] is the
        # current token, which _causal_conv pairs with w[0] (w is stored
        # newest-first: tap j multiplies x_{t-j})
        win = jnp.concatenate([conv, cin[:, None]], axis=1)  # (B,K,C)
        w = lp["conv_w"].astype(jnp.float32)[::-1]        # oldest-first
        cout = (win.astype(jnp.float32) * w[None]).sum(1) \
            + lp["conv_b"].astype(jnp.float32)
        cout = jax.nn.silu(cout).astype(cd)
        xs = cout[:, :d_inner].reshape(-1, nheads, P)
        Bv = cout[:, d_inner:d_inner + G * N].reshape(-1, G, N)
        Cv = cout[:, d_inner + G * N:].reshape(-1, G, N)
        rep = nheads // G
        Bh = jnp.repeat(Bv, rep, 1)                       # (B,H,N)
        Ch = jnp.repeat(Cv, rep, 1)
        dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                             + lp["dt_bias"].astype(jnp.float32))  # (B,H)
        A = jnp.exp(lp["A_log"].astype(jnp.float32))
        a = jnp.exp(-dt * A[None])                        # (B,H)
        upd = jnp.einsum("bhn,bhp->bhpn", Bh.astype(jnp.float32),
                         (xs.astype(jnp.float32) * dt[..., None]))
        ssm_new = ssm * a[..., None, None] + upd
        y = jnp.einsum("bhn,bhpn->bhp", Ch.astype(jnp.float32), ssm_new)
        y = y + xs.astype(jnp.float32) * lp["D_skip"].astype(jnp.float32)[None, :, None]
        y = y.reshape(-1, 1, d_inner).astype(cd)
        y = y * jax.nn.silu(z.astype(jnp.float32)).astype(cd)
        y = L.rms_norm(y, lp["out_norm"]["scale"], cfg.norm_eps)
        out = jnp.einsum("bsi,id->bsd", y, lp["w_out"].astype(cd))
        return h + out, (ssm_new, win[:, 1:])

    h, (ssm_s, conv_s) = jax.lax.scan(
        body, x, (params["layers"], cache["ssm"], cache["conv"]))
    h = L.rms_norm(h, params["final_norm"]["scale"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", h.astype(cd),
                            params["embed"]["table"].astype(cd))
    else:
        logits = L.unembed(params["unembed"], h)
    new_cache = {"ssm": ssm_s, "conv": conv_s, "pos": cache["pos"] + 1}
    return logits[:, 0], new_cache
