"""Shared building blocks for the assigned-architecture model zoo.

Conventions
-----------
* Params are plain dicts of jnp arrays; every init function has a
  ``*_axes`` twin returning the same treedef of
  :class:`repro.sharding.partition.LogicalAxes` so the partitioner can
  derive NamedShardings without touching real memory.
* Compute dtype is bf16 (TPU MXU native), params fp32, softmax/normalizers
  fp32.
* Attention uses a *padded-head layout* decided at config time
  (``HeadLayout``): query heads are padded to ``q_padded`` (dead heads have
  zero weights and a zeroed o-projection, so they contribute nothing) and
  the KV heads are activation-repeated to ``kv_padded`` so every tensor-
  parallel shard owns an integer number of q heads *and* the kv head(s)
  they attend to.  Duplicated KV heads share one weight matrix (the
  repeat happens on activations), so GQA semantics are exactly those of
  the published architecture.
* ``attention_chunked`` is a pure-JAX flash-attention: an online-softmax
  ``lax.scan`` over KV chunks.  Causal masking costs ~2x the ideal
  triangle FLOPs at the HLO level; this is a recorded baseline
  inefficiency that the perf log attacks (EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from ..sharding.annotate import hint
from ..sharding.partition import logical

Array = jax.Array
COMPUTE_DTYPE = jnp.bfloat16
PARAM_DTYPE = jnp.float32


# ---------------------------------------------------------------------------
# Head layout (TP divisibility; DESIGN.md §6)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class HeadLayout:
    """Padded attention-head layout for a given tensor-parallel degree.

    q_padded   : query heads incl. dead padding (multiple of tp)
    kv_padded  : kv heads after activation-repeat (multiple of tp or == kv)
    slots      : q slots per original kv group (>= group size)
    """
    num_q: int
    num_kv: int
    q_padded: int
    kv_padded: int
    slots: int

    @property
    def kv_repeat(self) -> int:
        return self.kv_padded // self.num_kv

    @property
    def q_per_kvp(self) -> int:
        return self.q_padded // self.kv_padded


def make_head_layout(num_q: int, num_kv: int, tp: int) -> HeadLayout:
    """Choose (q_padded, kv_padded, slots) s.t. every TP shard owns whole
    q-head blocks aligned with the kv head (copy) they read.

    Three regimes (DESIGN.md §6):
      * MHA (kv == q): pad both to a multiple of tp, 1:1 q->kv mapping;
        dead kv heads are zero-padded activations.
      * GQA, kv divides tp: repeat each kv head r = tp/num_kv times
        (activation repeat — weights stay shared), pad q groups to
        ``slots = r * ceil(gs/r)`` slots; every shard then owns exactly one
        kv copy and ``slots/r`` q heads of its group.
      * GQA, kv >= tp: shard kv directly (pad kv to a multiple of tp if
        needed is not required for the assigned archs); no repeat.
    """
    assert num_q % num_kv == 0, (num_q, num_kv)
    gs = num_q // num_kv
    if num_kv == num_q:                       # MHA: pad both 1:1
        qp = _round_up(num_q, tp)
        return HeadLayout(num_q, num_kv, qp, qp, 1)
    if num_kv % tp == 0:                      # kv >= tp and divisible
        return HeadLayout(num_q, num_kv, num_q, num_kv, gs)
    if tp % num_kv == 0:                      # kv < tp: repeat kv
        r = tp // num_kv
        s = r * math.ceil(gs / r)
        qp = num_kv * s                       # multiple of tp by construction
        return HeadLayout(num_q, num_kv, qp, tp, s)
    # awkward kv (doesn't divide and isn't divisible by tp): replicate kv,
    # pad q to a multiple of tp.  The partitioner's divisibility fallback
    # will replicate the kv dims automatically.
    qp = _round_up(num_q, tp)
    return HeadLayout(num_q, num_kv, qp, num_kv, qp // num_kv)


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


def layer_norm(x: Array, scale: Array, bias: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def init_rms_norm(d: int):
    return {"scale": jnp.ones((d,), PARAM_DTYPE)}


def axes_rms_norm():
    return {"scale": logical("norm", name="norm.scale")}


def init_layer_norm(d: int):
    return {"scale": jnp.ones((d,), PARAM_DTYPE),
            "bias": jnp.zeros((d,), PARAM_DTYPE)}


def axes_layer_norm():
    return {"scale": logical("norm", name="ln.scale"),
            "bias": logical("norm", name="ln.bias")}


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    inv = rope_frequencies(hd, theta)                       # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., S, hd/2)
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (padded-head GQA, chunked flash, SWA/local windows)
# ---------------------------------------------------------------------------

def init_attention(key: Array, d_model: int, layout: HeadLayout,
                   head_dim: int, *, qkv_bias: bool = False,
                   qk_norm: bool = False, out_bias: bool = False):
    """Padded-layout attention params.  Dead q heads (slots beyond the real
    group size) are zero-initialized, including their o-proj rows."""
    kq, kk, kv, ko = jax.random.split(key, 4)
    std = d_model ** -0.5
    H, K, s = layout.q_padded, layout.num_kv, layout.slots
    gs = layout.num_q // layout.num_kv
    wq = jax.random.normal(kq, (d_model, H, head_dim), PARAM_DTYPE) * std
    # zero the dead q slots
    if layout.num_kv == layout.num_q:          # MHA padding: first num_q alive
        alive = (jnp.arange(H) < layout.num_q).astype(PARAM_DTYPE)
    else:                                      # GQA: slot-in-group >= gs dead
        alive = ((jnp.arange(H) % s) < gs).astype(PARAM_DTYPE)
    wq = wq * alive[None, :, None]
    p = {
        "wq": wq,
        "wk": jax.random.normal(kk, (d_model, K, head_dim), PARAM_DTYPE) * std,
        "wv": jax.random.normal(kv, (d_model, K, head_dim), PARAM_DTYPE) * std,
        "wo": jax.random.normal(ko, (H, head_dim, d_model), PARAM_DTYPE)
              * std * alive[:, None, None],
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((H, head_dim), PARAM_DTYPE)
        p["bk"] = jnp.zeros((K, head_dim), PARAM_DTYPE)
        p["bv"] = jnp.zeros((K, head_dim), PARAM_DTYPE)
    if out_bias:
        p["bo"] = jnp.zeros((d_model,), PARAM_DTYPE)
    if qk_norm:
        p["q_norm"] = jnp.ones((head_dim,), PARAM_DTYPE)
        p["k_norm"] = jnp.ones((head_dim,), PARAM_DTYPE)
    return p


def axes_attention(*, qkv_bias: bool = False, qk_norm: bool = False,
                   out_bias: bool = False):
    a = {
        "wq": logical("embed", "q_heads", "head_dim", name="attn.wq"),
        "wk": logical("embed", None, "head_dim", name="attn.wk"),
        "wv": logical("embed", None, "head_dim", name="attn.wv"),
        "wo": logical("q_heads", "head_dim", "embed", name="attn.wo"),
    }
    if qkv_bias:
        a["bq"] = logical("q_heads", "head_dim", name="attn.bq")
        a["bk"] = logical(None, "head_dim", name="attn.bk")
        a["bv"] = logical(None, "head_dim", name="attn.bv")
    if out_bias:
        a["bo"] = logical(None, name="attn.bo")
    if qk_norm:
        a["q_norm"] = logical("norm", name="attn.q_norm")
        a["k_norm"] = logical("norm", name="attn.k_norm")
    return a


def qkv_project(p, x: Array, layout: HeadLayout, *, positions: Array | None,
                rope_theta: float | None, qk_norm_eps: float = 1e-6):
    """x (B,S,D) -> q (B,S,Hp,hd), k/v (B,S,Kp,hd) in compute dtype.

    KV is computed with the *true* head count and activation-repeated to
    the padded layout, so duplicated heads share weights exactly.
    """
    cd = COMPUTE_DTYPE
    q = jnp.einsum("bsd,dhk->bshk", x.astype(cd), p["wq"].astype(cd))
    k = jnp.einsum("bsd,dhk->bshk", x.astype(cd), p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", x.astype(cd), p["wv"].astype(cd))
    if "bq" in p:
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], qk_norm_eps)
        k = rms_norm(k, p["k_norm"], qk_norm_eps)
    if rope_theta is not None:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    r = layout.kv_repeat
    if r > 1:
        k = jnp.repeat(k, r, axis=2)
        v = jnp.repeat(v, r, axis=2)
    if k.shape[2] < layout.kv_padded:          # MHA zero-pad (dead kv heads)
        padn = layout.kv_padded - k.shape[2]
        k = jnp.pad(k, ((0, 0), (0, 0), (0, padn), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, padn), (0, 0)))
    # The padded/repeated KV must be *sharded* over the model axis even
    # though its producing weights are replicated — otherwise SPMD
    # replicates the whole attention einsum (16x compute; §Perf iter 1).
    from ..sharding.annotate import hint_heads
    q = hint_heads(q)
    k = hint_heads(k)
    v = hint_heads(v)
    return q, k, v


def attention_chunked(q: Array, k: Array, v: Array, layout: HeadLayout, *,
                      causal: bool, window: int | None = None,
                      q_offset: Array | int = 0, kv_offset: Array | int = 0,
                      kv_chunk: int = 1024, kv_len: Array | None = None,
                      scores_dtype=jnp.float32) -> Array:
    """Online-softmax flash attention, pure JAX.

    q: (B, Sq, Hp, hd); k/v: (B, Skv, Kp, hd)  (already padded layout).
    window: sliding-window size (None = unbounded).
    kv_len: optional (B,) valid kv length (decode against partial cache).
    Returns (B, Sq, Hp, hd).
    """
    B, Sq, Hp, hd = q.shape
    Skv = k.shape[1]
    Kp = layout.kv_padded
    g = Hp // Kp
    scale = hd ** -0.5
    nchunk = -(-Skv // kv_chunk)
    pad = nchunk * kv_chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, nchunk, kv_chunk, Kp, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nchunk, kv_chunk, Kp, hd).transpose(1, 0, 2, 3, 4)

    qg = q.reshape(B, Sq, Kp, g, hd).astype(COMPUTE_DTYPE)
    q_pos = jnp.asarray(q_offset) + jnp.arange(Sq)              # (Sq,)

    def body(carry, xs):
        o, m, l = carry                                          # o:(B,Sq,Kp,g,hd)
        kci, vci, ci = xs                                        # (B,ck,Kp,hd)
        local_idx = ci * kv_chunk + jnp.arange(kv_chunk)         # (ck,)
        kv_pos = jnp.asarray(kv_offset) + local_idx
        s = jnp.einsum("bqkgd,bckd->bqkgc", qg, kci.astype(COMPUTE_DTYPE),
                       preferred_element_type=scores_dtype) \
            .astype(jnp.float32) * scale
        mask2d = jnp.broadcast_to((local_idx < Skv)[None, :],
                                  (Sq, kv_chunk))                # tail padding
        if causal:
            mask2d = mask2d & (q_pos[:, None] >= kv_pos[None, :])
        if window is not None:
            mask2d = mask2d & (q_pos[:, None] - kv_pos[None, :] < window)
        if kv_len is not None:
            mb = mask2d[None] & (kv_pos[None, None, :]
                                 < kv_len[:, None, None])        # (B,Sq,ck)
            mask = mb[:, :, None, None, :]
        else:
            mask = mask2d[None, :, None, None, :]
        s = jnp.where(mask, s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))                   # (B,Sq,Kp,g)
        # guard all-masked rows (m_new = -inf): keep them neutral
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l = l * alpha + p.sum(axis=-1)
        o = o * alpha[..., None] + jnp.einsum(
            "bqkgc,bckd->bqkgd", p.astype(COMPUTE_DTYPE),
            vci.astype(COMPUTE_DTYPE),
            preferred_element_type=jnp.float32)
        return (o, m_new, l), None

    o0 = jnp.zeros((B, Sq, Kp, g, hd), jnp.float32)
    m0 = jnp.full((B, Sq, Kp, g), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Sq, Kp, g), jnp.float32)
    (o, m, l), _ = jax.lax.scan(
        body, (o0, m0, l0), (kc, vc, jnp.arange(nchunk)))
    o = o / jnp.maximum(l[..., None], 1e-30)
    return o.reshape(B, Sq, Hp, hd).astype(COMPUTE_DTYPE)


def _attn_parts(q: Array, k: Array, v: Array, layout: HeadLayout, *,
                causal: bool, q_offset, kv_offset, kv_chunk: int,
                scores_dtype=jnp.float32):
    """attention_chunked's scan, returning unnormalized (o, m, l) parts
    so callers can combine disjoint KV ranges (online-softmax algebra)."""
    B, Sq, Hp, hd = q.shape
    Skv = k.shape[1]
    Kp = layout.kv_padded
    g = Hp // Kp
    scale = hd ** -0.5
    nchunk = -(-Skv // kv_chunk)
    pad = nchunk * kv_chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, nchunk, kv_chunk, Kp, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nchunk, kv_chunk, Kp, hd).transpose(1, 0, 2, 3, 4)
    qg = q.reshape(B, Sq, Kp, g, hd).astype(COMPUTE_DTYPE)
    q_pos = jnp.asarray(q_offset) + jnp.arange(Sq)

    def body(carry, xs):
        o, m, l = carry
        kci, vci, ci = xs
        local_idx = ci * kv_chunk + jnp.arange(kv_chunk)
        kv_pos = jnp.asarray(kv_offset) + local_idx
        s = jnp.einsum("bqkgd,bckd->bqkgc", qg, kci.astype(COMPUTE_DTYPE),
                       preferred_element_type=scores_dtype) \
            .astype(jnp.float32) * scale
        mask2d = jnp.broadcast_to((local_idx < Skv)[None, :], (Sq, kv_chunk))
        if causal:
            mask2d = mask2d & (q_pos[:, None] >= kv_pos[None, :])
        mask = mask2d[None, :, None, None, :]
        s = jnp.where(mask, s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l = l * alpha + p.sum(axis=-1)
        o = o * alpha[..., None] + jnp.einsum(
            "bqkgc,bckd->bqkgd", p.astype(COMPUTE_DTYPE),
            vci.astype(COMPUTE_DTYPE), preferred_element_type=jnp.float32)
        return (o, m_new, l), None

    o0 = jnp.zeros((B, Sq, Kp, g, hd), jnp.float32)
    m0 = jnp.full((B, Sq, Kp, g), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Sq, Kp, g), jnp.float32)
    (o, m, l), _ = jax.lax.scan(body, (o0, m0, l0),
                                (kc, vc, jnp.arange(nchunk)))
    return o, m, l


def _combine_parts(a, b):
    """Merge two online-softmax parts over disjoint KV ranges."""
    o1, m1, l1 = a
    o2, m2, l2 = b
    m = jnp.maximum(m1, m2)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    a1 = jnp.where(jnp.isfinite(m1), jnp.exp(m1 - m_safe), 0.0)
    a2 = jnp.where(jnp.isfinite(m2), jnp.exp(m2 - m_safe), 0.0)
    return (o1 * a1[..., None] + o2 * a2[..., None],
            m, l1 * a1 + l2 * a2)


def attention_causal_tri(q: Array, k: Array, v: Array, layout: HeadLayout,
                         *, kv_chunk: int = 1024, leaf: int = 4096,
                         scores_dtype=jnp.float32) -> Array:
    """Block-triangular causal attention (§Perf optimization).

    The masked-flash baseline computes the full S x S score grid and
    masks half of it away.  This recursion computes the causal triangle
    with ~0.5x + O(S*leaf) of those FLOPs, statically (no dynamic
    shapes): split the sequence in half — the upper-right block is never
    computed, the lower-left block is *dense* (mask-free), and the two
    diagonal blocks recurse.  Parts merge with the online-softmax
    algebra, so results are bit-comparable to the baseline.
    """
    B, S, Hp, hd = q.shape

    def rec(q_, k_, v_, off):
        Sq = q_.shape[1]
        if Sq <= leaf:
            return _attn_parts(q_, k_, v_, layout, causal=True,
                               q_offset=off, kv_offset=off,
                               kv_chunk=min(kv_chunk, Sq),
                               scores_dtype=scores_dtype)
        half = Sq // 2
        top = rec(q_[:, :half], k_[:, :half], v_[:, :half], off)
        cross = _attn_parts(q_[:, half:], k_[:, :half], v_[:, :half],
                            layout, causal=False, q_offset=off + half,
                            kv_offset=off, kv_chunk=kv_chunk,
                            scores_dtype=scores_dtype)
        diag = rec(q_[:, half:], k_[:, half:], v_[:, half:], off + half)
        bottom = _combine_parts(cross, diag)
        return (jnp.concatenate([top[0], bottom[0]], axis=1),
                jnp.concatenate([top[1], bottom[1]], axis=1),
                jnp.concatenate([top[2], bottom[2]], axis=1))

    o, m, l = rec(q, k, v, 0)
    o = o / jnp.maximum(l[..., None], 1e-30)
    return o.reshape(B, S, Hp, hd).astype(COMPUTE_DTYPE)


def attention_decode(q: Array, k_cache: Array, v_cache: Array,
                     layout: HeadLayout, *, cur_len: Array,
                     window: int | None = None) -> Array:
    """Single-token attention against a cache.

    q: (B, 1, Hp, hd); caches: (B, Skv, Kp, hd); cur_len: (B,) or scalar —
    number of valid cache entries (the new token's k/v must already be
    written).  Window semantics assume a ring buffer of size Skv when
    window is not None (every slot is valid once cur_len >= Skv).
    """
    B, _, Hp, hd = q.shape
    Skv, Kp = k_cache.shape[1], k_cache.shape[2]
    g = Hp // Kp
    qg = q.reshape(B, Kp, g, hd).astype(COMPUTE_DTYPE)
    s = jnp.einsum("bkgd,bckd->bkgc", qg, k_cache.astype(COMPUTE_DTYPE),
                   preferred_element_type=jnp.float32) * (hd ** -0.5)
    pos = jnp.arange(Skv)
    cur = jnp.asarray(cur_len)
    cur = cur[:, None] if cur.ndim else cur[None, None]
    valid = pos[None, :] < cur                                   # (B,Skv)
    if window is not None:
        # ring buffer: valid slots are the last `window` written
        valid &= pos[None, :] >= (cur - window)
        # (when cur > Skv the ring has wrapped; slot ages are implicit and
        #  every slot is within the window because Skv == window)
        valid |= (cur > Skv)
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    o = jnp.einsum("bkgc,bckd->bkgd", p.astype(COMPUTE_DTYPE),
                   v_cache.astype(COMPUTE_DTYPE),
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, Hp, hd).astype(COMPUTE_DTYPE)


def attn_output(p, o: Array) -> Array:
    """o (B,S,Hp,hd) -> (B,S,D)."""
    y = jnp.einsum("bshk,hkd->bsd", o.astype(COMPUTE_DTYPE),
                   p["wo"].astype(COMPUTE_DTYPE))
    if "bo" in p:
        y = y + p["bo"].astype(COMPUTE_DTYPE)
    return y


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_swiglu(key: Array, d_model: int, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    std_in, std_out = d_model ** -0.5, d_ff ** -0.5
    return {"w_gate": jax.random.normal(k1, (d_model, d_ff), PARAM_DTYPE) * std_in,
            "w_up": jax.random.normal(k2, (d_model, d_ff), PARAM_DTYPE) * std_in,
            "w_down": jax.random.normal(k3, (d_ff, d_model), PARAM_DTYPE) * std_out}


def axes_swiglu():
    return {"w_gate": logical("embed", "ff", name="mlp.w_gate"),
            "w_up": logical("embed", "ff", name="mlp.w_up"),
            "w_down": logical("ff", "embed", name="mlp.w_down")}


def swiglu(p, x: Array) -> Array:
    cd = COMPUTE_DTYPE
    g = hint(jnp.einsum("bsd,df->bsf", x.astype(cd), p["w_gate"].astype(cd)),
             "dp", None, "model")
    u = hint(jnp.einsum("bsd,df->bsf", x.astype(cd), p["w_up"].astype(cd)),
             "dp", None, "model")
    h = jax.nn.silu(g.astype(jnp.float32)).astype(cd) * u
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(cd))


def init_gelu_mlp(key: Array, d_model: int, d_ff: int, *, bias: bool = True):
    k1, k2 = jax.random.split(key)
    p = {"w_in": jax.random.normal(k1, (d_model, d_ff), PARAM_DTYPE) * d_model ** -0.5,
         "w_out": jax.random.normal(k2, (d_ff, d_model), PARAM_DTYPE) * d_ff ** -0.5}
    if bias:
        p["b_in"] = jnp.zeros((d_ff,), PARAM_DTYPE)
        p["b_out"] = jnp.zeros((d_model,), PARAM_DTYPE)
    return p


def axes_gelu_mlp(*, bias: bool = True):
    a = {"w_in": logical("embed", "ff", name="mlp.w_in"),
         "w_out": logical("ff", "embed", name="mlp.w_out")}
    if bias:
        a["b_in"] = logical("ff", name="mlp.b_in")
        a["b_out"] = logical(None, name="mlp.b_out")
    return a


def gelu_mlp(p, x: Array) -> Array:
    cd = COMPUTE_DTYPE
    h = hint(jnp.einsum("bsd,df->bsf", x.astype(cd), p["w_in"].astype(cd)),
             "dp", None, "model")
    if "b_in" in p:
        h = h + p["b_in"].astype(cd)
    h = jax.nn.gelu(h.astype(jnp.float32), approximate=True).astype(cd)
    y = jnp.einsum("bsf,fd->bsd", h, p["w_out"].astype(cd))
    if "b_out" in p:
        y = y + p["b_out"].astype(cd)
    return y


# ---------------------------------------------------------------------------
# Mixture of Experts (GShard-style capacity routing, per batch row;
# expert FFN hidden dim is tensor-parallel, tokens are data-parallel)
# ---------------------------------------------------------------------------

def init_moe(key: Array, d_model: int, d_ff: int, num_experts: int, *,
             pad_to: int = 0):
    """pad_to > num_experts adds dead experts (zero router effect via
    masking in moe_apply) so the expert dim can shard over "model" (EP)."""
    kr, k1, k2, k3 = jax.random.split(key, 4)
    std_in, std_out = d_model ** -0.5, d_ff ** -0.5
    E = max(num_experts, pad_to)
    return {
        "router": jax.random.normal(kr, (d_model, E), PARAM_DTYPE) * std_in,
        "w_gate": jax.random.normal(k1, (E, d_model, d_ff), PARAM_DTYPE) * std_in,
        "w_up": jax.random.normal(k2, (E, d_model, d_ff), PARAM_DTYPE) * std_in,
        "w_down": jax.random.normal(k3, (E, d_ff, d_model), PARAM_DTYPE) * std_out,
    }


def axes_moe(*, ep: bool = False):
    """ep=False: TP over the expert hidden dim (Megatron-style).
    ep=True:  EP — experts shard over "model", hidden dim full per shard
    (the right regime for many small experts; §Perf granite iter 3)."""
    e_ax = "experts_ep" if ep else "experts"
    f_ax = None if ep else "ff"
    return {
        "router": logical("embed", None, name="moe.router"),
        "w_gate": logical(e_ax, "embed", f_ax, name="moe.w_gate"),
        "w_up": logical(e_ax, "embed", f_ax, name="moe.w_up"),
        "w_down": logical(e_ax, f_ax, "embed", name="moe.w_down"),
    }


def moe_apply(p, x: Array, *, top_k: int, capacity_factor: float = 1.25,
              min_capacity: int = 4, num_real_experts: int = 0,
              ep: bool = False):
    """Token-choice top-k MoE with per-row capacity (drops overflow).

    x: (B, S, D).  Routing/dispatch is independent per batch row, so with
    batch-sharded activations no routing collective crosses shards; the
    only cross-device traffic is the TP all-reduce of the expert FFN
    (Megatron pattern) or, with ep=True, the partial-combine all-reduce.
    Padded (dead) experts beyond ``num_real_experts`` are masked out of
    the router.  Returns (y, aux_loss).
    """
    B, S, D = x.shape
    E = p["router"].shape[-1]
    E_real = num_real_experts or E
    cap = max(min_capacity,
              int(math.ceil(S * top_k / E_real * capacity_factor)))
    cap = min(cap, S * top_k)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    if E_real < E:
        logits = jnp.where(jnp.arange(E) < E_real, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)                      # (B,S,E)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)          # (B,S,k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # position of each (token, k) within its expert, token-major order
    flat_e = expert_idx.reshape(B, S * top_k)                    # (B,T)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)          # (B,T,E)
    pos = jnp.cumsum(onehot, axis=1) - 1                         # (B,T,E)
    pos_in_e = jnp.take_along_axis(pos, flat_e[..., None], -1)[..., 0]
    keep = pos_in_e < cap                                        # (B,T)

    # scatter token index + gate into (E, cap) slots, per batch row.
    # token-major flatten: slot j of token t is flat index t*top_k + j.
    tok_idx = (jnp.arange(S * top_k) // top_k).astype(jnp.int32)  # (T,)
    gate_flat = gate_vals.reshape(B, S * top_k)

    def scatter_row(fe, pie, kp, gv):
        # fe, pie, kp, gv: (T,) -> slot_tok (E, cap), slot_gate (E, cap)
        cols = jnp.where(kp, pie, cap)   # col `cap` is OOB -> dropped
        slot_tok = jnp.full((E, cap), S, jnp.int32) \
            .at[fe, cols].set(tok_idx, mode="drop")
        slot_gate = jnp.zeros((E, cap), jnp.float32) \
            .at[fe, cols].set(gv, mode="drop")
        return slot_tok, slot_gate

    slot_tok, slot_gate = jax.vmap(scatter_row)(
        flat_e, pos_in_e, keep, gate_flat)                       # (B,E,cap)

    # gather tokens into expert slots (index S = zero pad row)
    xpad = jnp.concatenate(
        [x, jnp.zeros((B, 1, D), x.dtype)], axis=1)              # (B,S+1,D)
    xe = _gather_slots(xpad, slot_tok)                           # (B,E,cap,D)
    e_ax = "model" if ep else None
    f_ax = None if ep else "model"
    xe = hint(xe, "dp", e_ax, None, None)

    cd = COMPUTE_DTYPE
    g = hint(jnp.einsum("becd,edf->becf", xe, p["w_gate"].astype(cd)),
             "dp", e_ax, None, f_ax)
    u = hint(jnp.einsum("becd,edf->becf", xe, p["w_up"].astype(cd)),
             "dp", e_ax, None, f_ax)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(cd) * u
    ye = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(cd))  # (B,E,cap,D)

    if ep:
        # EP combine: per-expert scatter-add back to token positions;
        # partial sums over the expert shards all-reduce a (B,S,D) tensor
        # (vs all-gathering the (B,E,cap,D) slots).
        ye = hint(ye, "dp", "model", None, None)
        yw = ye.astype(jnp.float32) * slot_gate[..., None]

        def combine_row(yw_r, tok_r):
            # yw_r (E,cap,D); tok_r (E,cap) token index (S = dropped)
            return jnp.zeros((S, D), jnp.float32).at[
                tok_r.reshape(-1)].add(yw_r.reshape(-1, D), mode="drop")

        y = jax.vmap(combine_row)(yw, slot_tok)
    else:
        # combine: for each (token, k) read its slot if kept
        flat_slot = flat_e * cap + jnp.where(keep, pos_in_e, 0)  # (B,T)
        ye_flat = ye.reshape(B, E * cap, D)
        yk = _gather_slots(ye_flat, flat_slot.reshape(B, S, top_k))
        w = (gate_vals * keep.reshape(B, S, top_k)).astype(jnp.float32)
        y = jnp.einsum("bskd,bsk->bsd", yk.astype(jnp.float32), w)

    # load-balance auxiliary loss (Switch-style)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(expert_idx[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * frac_probs)
    return y.astype(x.dtype), aux


def _gather_slots(src: Array, idx: Array) -> Array:
    """src (B, N, D), idx (B, ...) -> (B, ..., D) via per-row take."""
    B, N, D = src.shape
    flat = idx.reshape(B, -1)

    def row(s, i):
        return jnp.take(s, i, axis=0)
    out = jax.vmap(row)(src.astype(COMPUTE_DTYPE), flat)
    return out.reshape(*idx.shape, D)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embedding(key: Array, vocab_padded: int, d_model: int):
    return {"table": jax.random.normal(
        key, (vocab_padded, d_model), PARAM_DTYPE) * 0.01}


def axes_embedding():
    return {"table": logical("vocab", "embed", name="embed.table")}


def embed(p, tokens: Array) -> Array:
    return jnp.take(p["table"], tokens, axis=0).astype(COMPUTE_DTYPE)


def init_unembed(key: Array, d_model: int, vocab_padded: int):
    return {"w": jax.random.normal(
        key, (d_model, vocab_padded), PARAM_DTYPE) * d_model ** -0.5}


def axes_unembed():
    return {"w": logical("embed", "vocab", name="unembed.w")}


def unembed(p, x: Array) -> Array:
    return jnp.einsum("bsd,dv->bsv", x.astype(COMPUTE_DTYPE),
                      p["w"].astype(COMPUTE_DTYPE))


def cross_entropy_loss(logits: Array, labels: Array, *,
                       vocab_real: int, z_loss: float = 1e-4):
    """Next-token CE with padded-vocab masking + z-loss.

    logits: (B, S, Vp) (bf16 ok); labels: (B, S) int32 (-1 = ignore).
    """
    Vp = logits.shape[-1]
    lf = logits.astype(jnp.float32)
    if vocab_real < Vp:
        mask = jnp.arange(Vp) < vocab_real
        lf = jnp.where(mask, lf, -1e30)
    lse = jax.nn.logsumexp(lf, axis=-1)
    lab = jnp.clip(labels, 0, Vp - 1)
    picked = jnp.take_along_axis(lf, lab[..., None], -1)[..., 0]
    nll = lse - picked
    valid = (labels >= 0).astype(jnp.float32)
    nll = nll * valid
    z = (lse ** 2) * valid
    denom = jnp.maximum(valid.sum(), 1.0)
    return (nll.sum() + z_loss * z.sum()) / denom
