"""Model-zoo API: family registry + step factories.

Every architecture family module exposes the same interface
(init_params/param_axes/loss_fn/prefill/decode_step/init_cache/cache_axes);
this module dispatches on ``cfg.family`` and builds the jit-able steps the
launchers lower:

    make_train_step(cfg, tp, num_micro)  -> step(params, opt, batch)
    make_prefill(cfg, tp)                -> fn(params, batch)
    make_decode_step(cfg, tp)            -> fn(params, cache, tokens)
    input_specs(cfg, shape, tp)          -> ShapeDtypeStruct batch stand-ins
    abstract_params(cfg, tp)             -> eval_shape'd params
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeConfig
from ..optim.adam import Adam
from ..optim.grad import clip_by_global_norm
from . import transformer, mamba2, rglru, whisper, dwn_arch
from . import layers as L

MODULES = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "ssm": mamba2,
    "hybrid": rglru,
    "encdec": whisper,
    "dwn": dwn_arch,
}


def module_for(cfg: ArchConfig):
    return MODULES[cfg.family]


# ---------------------------------------------------------------------------
# abstract params / input specs (dry-run stand-ins; no allocation)
# ---------------------------------------------------------------------------

def abstract_params(cfg: ArchConfig, tp: int = 16):
    mod = module_for(cfg)
    return jax.eval_shape(
        lambda k: mod.init_params(k, cfg, tp), jax.random.PRNGKey(0))


def param_axes(cfg: ArchConfig):
    return module_for(cfg).param_axes(cfg)


def batch_specs(cfg: ArchConfig, shape: ShapeConfig, *,
                micro: bool = False) -> dict:
    """ShapeDtypeStructs for one step's data batch.

    For train shapes with gradient accumulation, ``micro=True`` prepends
    the (num_micro, batch/num_micro, ...) microbatch axes.
    """
    B, S = shape.global_batch, shape.seq_len
    lead: tuple = (B,)
    if micro and shape.num_microbatches > 1:
        m = shape.num_microbatches
        assert B % m == 0, (B, m)
        lead = (m, B // m)
    i32 = jnp.int32
    bf16 = L.COMPUTE_DTYPE
    if cfg.family == "dwn":
        # samples = global_batch x seq_len (feature vectors, not tokens)
        n = shape.global_batch * shape.seq_len
        if micro and shape.num_microbatches > 1:
            m = shape.num_microbatches
            batch = {"features": jax.ShapeDtypeStruct(
                (m, n // m, cfg.d_model), jnp.float32)}
            if shape.kind == "train":
                batch["labels"] = jax.ShapeDtypeStruct((m, n // m), i32)
            return batch
        batch = {"features": jax.ShapeDtypeStruct((n, cfg.d_model),
                                                  jnp.float32)}
        if shape.kind == "train":
            batch["labels"] = jax.ShapeDtypeStruct((n,), i32)
        return batch
    if shape.kind == "decode":
        batch = {"tokens": jax.ShapeDtypeStruct(lead + (1,), i32)}
        return batch
    batch = {"tokens": jax.ShapeDtypeStruct(lead + (S,), i32)}
    if shape.kind == "train":
        batch["labels"] = jax.ShapeDtypeStruct(lead + (S,), i32)
    if cfg.family == "encdec":
        batch["frames"] = jax.ShapeDtypeStruct(
            lead + (cfg.enc_frames, cfg.d_model), bf16)
    if cfg.family == "vlm":
        batch["patches"] = jax.ShapeDtypeStruct(
            lead + (cfg.num_patches, cfg.d_model), bf16)
    return batch


def batch_axes(cfg: ArchConfig, shape: ShapeConfig, *, micro: bool = False):
    """Logical axes for the batch pytree (see partition.DEFAULT_RULES)."""
    from ..sharding.partition import logical
    lead = ("micro", "batch") if (micro and shape.num_microbatches > 1) \
        else ("batch",)
    if cfg.family == "dwn":
        ax = {"features": logical(*lead, None, name="batch.features")}
        if shape.kind == "train":
            ax["labels"] = logical(*lead, name="batch.labels")
        return ax
    seq = "seq_sp" if shape.global_batch == 1 else None   # SP for B=1
    ax = {"tokens": logical(*lead, None if shape.kind == "decode" else seq,
                            name="batch.tokens")}
    if shape.kind == "train":
        ax["labels"] = logical(*lead, seq, name="batch.labels")
    if cfg.family == "encdec" and shape.kind != "decode":
        ax["frames"] = logical(*lead, None, None, name="batch.frames")
    if cfg.family == "vlm" and shape.kind != "decode":
        ax["patches"] = logical(*lead, None, None, name="batch.patches")
    return ax


def abstract_cache(cfg: ArchConfig, shape: ShapeConfig, tp: int = 16):
    mod = module_for(cfg)
    return jax.eval_shape(
        functools.partial(mod.init_cache, cfg, shape.global_batch,
                          shape.seq_len, tp))


def cache_axes(cfg: ArchConfig, shape: ShapeConfig):
    seq_shard = shape.global_batch == 1          # SP for long-context B=1
    return module_for(cfg).cache_axes(cfg, seq_shard=seq_shard)


# ---------------------------------------------------------------------------
# steps
# ---------------------------------------------------------------------------

def make_optimizer(lr: float = 3e-4) -> Adam:
    return Adam(lr=lr, b1=0.9, b2=0.95, weight_decay=0.1)


def make_train_step(cfg: ArchConfig, tp: int = 16, *, num_micro: int = 1,
                    opt: Adam | None = None, clip_norm: float = 1.0):
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics).

    With num_micro > 1, batch leaves carry a leading (num_micro, micro_b)
    pair and gradients are accumulated with a lax.scan — the FSDP/TP
    collectives for the weights still happen once per microbatch (gather)
    but the gradient all-reduce happens once per step.
    """
    mod = module_for(cfg)
    opt = opt or make_optimizer()

    def loss_of(params, data):
        return mod.loss_fn(params, cfg, data, tp=tp)

    def step(params, opt_state, batch):
        if num_micro == 1:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
        else:
            def micro_body(carry, data):
                acc_loss, acc_g = carry
                l, g = jax.value_and_grad(loss_of)(params, data)
                return (acc_loss + l, jax.tree.map(jnp.add, acc_g, g)), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss_sum, gsum), _ = jax.lax.scan(
                micro_body, (jnp.zeros(()), zeros), batch)
            inv = 1.0 / num_micro
            loss = loss_sum * inv
            grads = jax.tree.map(lambda g: g * inv, gsum)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return step, opt


def make_prefill(cfg: ArchConfig, tp: int = 16, *, cache_len: int | None = None):
    mod = module_for(cfg)

    def fn(params, batch):
        return mod.prefill(params, cfg, batch, tp=tp, cache_len=cache_len)

    return fn


def make_decode_step(cfg: ArchConfig, tp: int = 16):
    mod = module_for(cfg)

    def fn(params, cache, batch):
        return mod.decode_step(params, cfg, cache, batch["tokens"], tp=tp)

    return fn
