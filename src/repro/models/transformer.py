"""Decoder-only transformer LM (dense / MoE / VLM families).

* scan-over-layers with stacked params (compile-time O(1) in depth),
* per-layer remat (``jax.checkpoint``) so train activations are one
  (B, S, D) residual per layer,
* chunked flash attention (see layers.py) with optional sliding window,
* MoE FFN via capacity routing (layers.moe_apply),
* VLM: precomputed patch embeddings are prepended to the token embeddings
  (anyres frontend stub per the assignment).

Interface (shared by all arch modules):
    init_params(key, cfg, tp) -> params
    param_axes(cfg)           -> logical-axes pytree (same treedef)
    loss_fn(params, cfg, batch) -> scalar loss
    prefill(params, cfg, batch) -> (logits_last, cache)
    init_cache(cfg, batch_size, cache_len, tp) -> cache pytree (zeros)
    cache_axes(cfg)           -> logical axes for the cache
    decode_step(params, cfg, cache, tokens, pos) -> (logits, cache)
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..sharding.annotate import hint, hint_act
from ..sharding.partition import logical
from . import layers as L

Array = jax.Array


def _layout(cfg: ArchConfig, tp: int) -> L.HeadLayout:
    return L.make_head_layout(cfg.num_heads, cfg.num_kv_heads, tp)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_block(key: Array, cfg: ArchConfig, layout: L.HeadLayout):
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": L.init_rms_norm(cfg.d_model),
        "attn": L.init_attention(k1, cfg.d_model, layout, cfg.head_dim_,
                                 qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm),
        "ln2": L.init_rms_norm(cfg.d_model),
    }
    if cfg.family == "moe":
        pad = 0
        if cfg.moe_ep:
            from ..configs.base import round_up
            pad = round_up(cfg.num_experts, 16)
        p["moe"] = L.init_moe(k2, cfg.d_model, cfg.d_ff, cfg.num_experts,
                              pad_to=pad)
    else:
        p["mlp"] = L.init_swiglu(k2, cfg.d_model, cfg.d_ff)
    return p


def _block_axes(cfg: ArchConfig):
    a = {
        "ln1": L.axes_rms_norm(),
        "attn": L.axes_attention(qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm),
        "ln2": L.axes_rms_norm(),
    }
    if cfg.family == "moe":
        a["moe"] = L.axes_moe(ep=cfg.moe_ep)
    else:
        a["mlp"] = L.axes_swiglu()
    return a


def init_params(key: Array, cfg: ArchConfig, tp: int = 16):
    layout = _layout(cfg, tp)
    ke, ku, kl = jax.random.split(key, 3)
    lkeys = jax.random.split(kl, cfg.num_layers)
    layers_p = jax.vmap(lambda k: _init_block(k, cfg, layout))(lkeys)
    p = {
        "embed": L.init_embedding(ke, cfg.vocab_padded(tp), cfg.d_model),
        "layers": layers_p,
        "final_norm": L.init_rms_norm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = L.init_unembed(ku, cfg.d_model, cfg.vocab_padded(tp))
    return p


def _stack_axes(tree):
    """Prepend the scanned 'layers' logical axis to every leaf."""
    return jax.tree.map(
        lambda la: logical("layers", *tuple(la), name=la.name),
        tree, is_leaf=lambda x: isinstance(x, tuple) and hasattr(x, "name"))


def param_axes(cfg: ArchConfig):
    a = {
        "embed": L.axes_embedding(),
        "layers": _stack_axes(_block_axes(cfg)),
        "final_norm": L.axes_rms_norm(),
    }
    if not cfg.tie_embeddings:
        a["unembed"] = L.axes_unembed()
    return a


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _block_apply(lp, cfg: ArchConfig, layout, x: Array, positions: Array,
                 *, collect_kv: bool):
    h = L.rms_norm(x, lp["ln1"]["scale"], cfg.norm_eps)
    q, k, v = L.qkv_project(lp["attn"], h, layout, positions=positions,
                            rope_theta=cfg.rope_theta or None,
                            qk_norm_eps=cfg.norm_eps)
    import jax.numpy as _jnp
    sdt = _jnp.bfloat16 if cfg.attn_scores_bf16 else _jnp.float32
    if cfg.attn_impl == "pallas" and cfg.swa_window is None:
        # real-TPU path: causal block skipping + VMEM-resident tiles
        from ..kernels.flash_attn import ops as _fa
        o = _fa.attend(q, k, v, causal=True,
                       block=min(cfg.attn_chunk, q.shape[1]))
    elif cfg.attn_impl == "tri" and cfg.swa_window is None:
        o = L.attention_causal_tri(q, k, v, layout,
                                   kv_chunk=cfg.attn_chunk,
                                   scores_dtype=sdt)
    else:
        o = L.attention_chunked(q, k, v, layout, causal=True,
                                window=cfg.swa_window,
                                kv_chunk=cfg.attn_chunk,
                                scores_dtype=sdt)
    x = hint_act(x + L.attn_output(lp["attn"], o))
    h = L.rms_norm(x, lp["ln2"]["scale"], cfg.norm_eps)
    if cfg.family == "moe":
        y, aux = L.moe_apply(lp["moe"], h, top_k=cfg.top_k,
                             capacity_factor=cfg.capacity_factor,
                             num_real_experts=cfg.num_experts,
                             ep=cfg.moe_ep)
    else:
        y, aux = L.swiglu(lp["mlp"], h), 0.0
    x = hint_act(x + y)
    kv = (k, v) if collect_kv else None
    return x, aux, kv


def _embed_inputs(params, cfg: ArchConfig, batch) -> tuple[Array, Array]:
    """Returns (x, positions). VLM prepends patch embeddings."""
    x = hint_act(L.embed(params["embed"], batch["tokens"]))
    if cfg.family == "vlm" and "patches" in batch:
        patches = batch["patches"].astype(x.dtype)        # (B, P, D)
        x = jnp.concatenate([patches, x], axis=1)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    return x, positions


def forward(params, cfg: ArchConfig, batch, *, tp: int = 16,
            collect_kv: bool = False):
    """Full-sequence forward -> (logits, aux, cache_kv or None)."""
    layout = _layout(cfg, tp)
    x, positions = _embed_inputs(params, cfg, batch)

    def body(carry, lp):
        h, aux = carry
        h2, a, kv = _block_apply(lp, cfg, layout, h, positions,
                                 collect_kv=collect_kv)
        return (h2, aux + a), kv

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, aux), kvs = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                                 params["layers"])
    x = L.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x.astype(L.COMPUTE_DTYPE),
                            params["embed"]["table"].astype(L.COMPUTE_DTYPE))
    else:
        logits = L.unembed(params["unembed"], x)
    return hint(logits, "dp", None, "model"), aux, kvs


def loss_fn(params, cfg: ArchConfig, batch, *, tp: int = 16) -> Array:
    logits, aux, _ = forward(params, cfg, batch, tp=tp)
    if cfg.family == "vlm" and "patches" in batch:
        # only text positions carry labels; drop patch positions
        P = batch["patches"].shape[1]
        logits = logits[:, P:]
    ce = L.cross_entropy_loss(logits[:, :-1], batch["labels"][:, 1:],
                              vocab_real=cfg.vocab_size)
    return ce + 0.01 * aux


# ---------------------------------------------------------------------------
# serving: prefill + single-token decode against a KV cache
# ---------------------------------------------------------------------------

def cache_len_for(cfg: ArchConfig, seq_len: int) -> int:
    """Effective KV-cache length: SWA bounds it at the window size; VLM
    prompts carry num_patches extra (image) positions ahead of the text."""
    if cfg.family == "vlm":
        seq_len = seq_len + cfg.num_patches
    if cfg.swa_window is not None:
        return min(seq_len, cfg.swa_window)
    return seq_len


def init_cache(cfg: ArchConfig, batch_size: int, cache_len: int,
               tp: int = 16):
    layout = _layout(cfg, tp)
    Skv = cache_len_for(cfg, cache_len)
    shape = (cfg.num_layers, batch_size, Skv, layout.kv_padded, cfg.head_dim_)
    return {
        "k": jnp.zeros(shape, L.COMPUTE_DTYPE),
        "v": jnp.zeros(shape, L.COMPUTE_DTYPE),
        "pos": jnp.zeros((), jnp.int32),          # tokens written so far
    }


def cache_axes(cfg: ArchConfig, *, seq_shard: bool = False):
    seq_ax = "kv_seq_sp" if seq_shard else None
    kv = logical("layers", "batch", seq_ax, "kv_heads", "head_dim",
                 name="cache.kv")
    return {"k": kv, "v": kv, "pos": logical(name="cache.pos")}


def prefill(params, cfg: ArchConfig, batch, *, tp: int = 16,
            cache_len: int | None = None):
    """Process the full prompt; return (last-token logits, filled cache)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    logits, _, kvs = forward(params, cfg, batch, tp=tp, collect_kv=True)
    k, v = kvs                                      # (L, B, S(+P), Kp, hd)
    Skv = cache_len_for(cfg, cache_len or S)
    if k.shape[2] > Skv:                            # keep the last window
        k, v = k[:, :, -Skv:], v[:, :, -Skv:]
    elif k.shape[2] < Skv:
        padn = Skv - k.shape[2]
        k = jnp.pad(k, ((0, 0), (0, 0), (0, padn), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, padn), (0, 0), (0, 0)))
    cache = {"k": k, "v": v,
             "pos": jnp.asarray(logits.shape[1], jnp.int32)}
    return logits[:, -1], cache


def decode_step(params, cfg: ArchConfig, cache, tokens: Array, *,
                tp: int = 16):
    """One decode step: tokens (B, 1) against the cache.  Returns
    (logits (B, Vp), new cache).  SWA caches are ring buffers."""
    layout = _layout(cfg, tp)
    x = L.embed(params["embed"], tokens)            # (B, 1, D)
    pos = cache["pos"]                              # scalar: tokens so far
    positions = jnp.full((x.shape[0], 1), pos, jnp.int32)
    Skv = cache["k"].shape[2]
    slot = pos % Skv if cfg.swa_window is not None else jnp.minimum(pos, Skv - 1)

    def body(h, lc):
        lp, kc, vc = lc
        hn = L.rms_norm(h, lp["ln1"]["scale"], cfg.norm_eps)
        q, k, v = L.qkv_project(lp["attn"], hn, layout, positions=positions,
                                rope_theta=cfg.rope_theta or None,
                                qk_norm_eps=cfg.norm_eps)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k, slot, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v, slot, axis=1)
        cur = jnp.minimum(pos + 1, Skv) if cfg.swa_window is None else pos + 1
        o = L.attention_decode(q, kc, vc, layout,
                               cur_len=jnp.full((h.shape[0],), cur),
                               window=cfg.swa_window)
        h = h + L.attn_output(lp["attn"], o)
        hn = L.rms_norm(h, lp["ln2"]["scale"], cfg.norm_eps)
        if cfg.family == "moe":
            y, _ = L.moe_apply(lp["moe"], hn, top_k=cfg.top_k,
                               capacity_factor=cfg.capacity_factor,
                               num_real_experts=cfg.num_experts,
                               ep=cfg.moe_ep)
        else:
            y = L.swiglu(lp["mlp"], hn)
        return h + y, (kc, vc)

    def scan_body(h, lc):
        h, kv = body(h, lc)
        return h, kv

    h, (ks, vs) = jax.lax.scan(
        scan_body, x, (params["layers"], cache["k"], cache["v"]))
    h = L.rms_norm(h, params["final_norm"]["scale"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", h.astype(L.COMPUTE_DTYPE),
                            params["embed"]["table"].astype(L.COMPUTE_DTYPE))
    else:
        logits = L.unembed(params["unembed"], h)
    new_cache = {"k": ks, "v": vs, "pos": pos + 1}
    return logits[:, 0], new_cache
