"""Whisper-large-v3 backbone: encoder-decoder transformer.

Per the assignment the conv audio frontend is a STUB: ``input_specs()``
provides precomputed frame embeddings (B, enc_frames, d_model) — i.e. the
output of the two-conv downsampling stack.  We add sinusoidal positions to
the frames, run the (non-causal, MHA) encoder, and a causal decoder with
cross-attention.  Whisper uses LayerNorm (with bias), GELU MLPs and learned
absolute positions on the decoder (sinusoidal here; positions are buffers,
not trained — shapes and FLOPs are identical).

serve: prefill = encoder + decoder prompt pass (caches decoder self-attn KV
and the per-layer cross-attention K/V computed once from encoder states);
decode_step = one decoder token.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..sharding.annotate import hint, hint_act, hint_heads
from ..sharding.partition import logical
from . import layers as L

Array = jax.Array


def _layout(cfg: ArchConfig, tp: int) -> L.HeadLayout:
    return L.make_head_layout(cfg.num_heads, cfg.num_kv_heads, tp)


def sinusoid_positions(length: int, dim: int) -> np.ndarray:
    pos = np.arange(length)[:, None].astype(np.float32)
    i = np.arange(dim // 2)[None, :].astype(np.float32)
    angle = pos / np.power(10000.0, 2 * i / dim)
    return np.concatenate([np.sin(angle), np.cos(angle)], -1).astype(np.float32)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_enc_layer(key: Array, cfg: ArchConfig, layout):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.init_layer_norm(cfg.d_model),
        "attn": L.init_attention(k1, cfg.d_model, layout, cfg.head_dim_,
                                 qkv_bias=True, out_bias=True),
        "ln2": L.init_layer_norm(cfg.d_model),
        "mlp": L.init_gelu_mlp(k2, cfg.d_model, cfg.d_ff),
    }


def _axes_enc_layer():
    return {
        "ln1": L.axes_layer_norm(),
        "attn": L.axes_attention(qkv_bias=True, out_bias=True),
        "ln2": L.axes_layer_norm(),
        "mlp": L.axes_gelu_mlp(),
    }


def _init_dec_layer(key: Array, cfg: ArchConfig, layout):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": L.init_layer_norm(cfg.d_model),
        "self_attn": L.init_attention(k1, cfg.d_model, layout, cfg.head_dim_,
                                      qkv_bias=True, out_bias=True),
        "ln_x": L.init_layer_norm(cfg.d_model),
        "cross_attn": L.init_attention(k2, cfg.d_model, layout, cfg.head_dim_,
                                       qkv_bias=True, out_bias=True),
        "ln2": L.init_layer_norm(cfg.d_model),
        "mlp": L.init_gelu_mlp(k3, cfg.d_model, cfg.d_ff),
    }


def _axes_dec_layer():
    return {
        "ln1": L.axes_layer_norm(),
        "self_attn": L.axes_attention(qkv_bias=True, out_bias=True),
        "ln_x": L.axes_layer_norm(),
        "cross_attn": L.axes_attention(qkv_bias=True, out_bias=True),
        "ln2": L.axes_layer_norm(),
        "mlp": L.axes_gelu_mlp(),
    }


def init_params(key: Array, cfg: ArchConfig, tp: int = 16):
    layout = _layout(cfg, tp)
    ke, ku, k1, k2 = jax.random.split(key, 4)
    enc_keys = jax.random.split(k1, cfg.enc_layers)
    dec_keys = jax.random.split(k2, cfg.num_layers)
    return {
        "embed": L.init_embedding(ke, cfg.vocab_padded(tp), cfg.d_model),
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg, layout))(enc_keys),
        "enc_ln": L.init_layer_norm(cfg.d_model),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(k, cfg, layout))(dec_keys),
        "dec_ln": L.init_layer_norm(cfg.d_model),
        # whisper ties the output projection to the token embedding
    }


def param_axes(cfg: ArchConfig):
    from .transformer import _stack_axes
    return {
        "embed": L.axes_embedding(),
        "enc_layers": _stack_axes(_axes_enc_layer()),
        "enc_ln": L.axes_layer_norm(),
        "dec_layers": _stack_axes(_axes_dec_layer()),
        "dec_ln": L.axes_layer_norm(),
    }


# ---------------------------------------------------------------------------
# encoder / decoder
# ---------------------------------------------------------------------------

def encode(params, cfg: ArchConfig, frames: Array, *, tp: int = 16) -> Array:
    """frames: (B, F, D) stub embeddings -> encoder states (B, F, D)."""
    layout = _layout(cfg, tp)
    B, F, D = frames.shape
    pos_emb = jnp.asarray(sinusoid_positions(F, D))
    x = hint_act(frames.astype(L.COMPUTE_DTYPE)
                 + pos_emb.astype(L.COMPUTE_DTYPE))
    positions = jnp.broadcast_to(jnp.arange(F)[None], (B, F))

    def body(h, lp):
        hn = L.layer_norm(h, lp["ln1"]["scale"], lp["ln1"]["bias"],
                          cfg.norm_eps)
        q, k, v = L.qkv_project(lp["attn"], hn, layout, positions=positions,
                                rope_theta=None)
        o = L.attention_chunked(q, k, v, layout, causal=False,
                                kv_chunk=cfg.attn_chunk)
        h = h + L.attn_output(lp["attn"], o)
        hn = L.layer_norm(h, lp["ln2"]["scale"], lp["ln2"]["bias"],
                          cfg.norm_eps)
        h = hint_act(h + L.gelu_mlp(lp["mlp"], hn))
        return h, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["enc_layers"])
    return L.layer_norm(x, params["enc_ln"]["scale"], params["enc_ln"]["bias"],
                        cfg.norm_eps)


def _dec_block(lp, cfg, layout, x, positions, enc_kv, *, collect_kv=False):
    """enc_kv: (k_enc, v_enc) precomputed per layer (B, F, Kp, hd)."""
    hn = L.layer_norm(x, lp["ln1"]["scale"], lp["ln1"]["bias"], cfg.norm_eps)
    q, k, v = L.qkv_project(lp["self_attn"], hn, layout, positions=positions,
                            rope_theta=None)
    o = L.attention_chunked(q, k, v, layout, causal=True,
                            kv_chunk=cfg.attn_chunk)
    x = x + L.attn_output(lp["self_attn"], o)
    # cross-attention
    hn = L.layer_norm(x, lp["ln_x"]["scale"], lp["ln_x"]["bias"], cfg.norm_eps)
    cd = L.COMPUTE_DTYPE
    qx = hint_heads(jnp.einsum("bsd,dhk->bshk", hn.astype(cd),
                    lp["cross_attn"]["wq"].astype(cd)))
    if "bq" in lp["cross_attn"]:
        qx = qx + lp["cross_attn"]["bq"].astype(cd)
    k_enc, v_enc = enc_kv
    ox = L.attention_chunked(qx, k_enc, v_enc, layout, causal=False,
                             kv_chunk=cfg.attn_chunk)
    x = x + L.attn_output(lp["cross_attn"], ox)
    hn = L.layer_norm(x, lp["ln2"]["scale"], lp["ln2"]["bias"], cfg.norm_eps)
    x = hint_act(x + L.gelu_mlp(lp["mlp"], hn))
    return x, ((k, v) if collect_kv else None)


def cross_kv(params, cfg: ArchConfig, enc_states: Array, *, tp: int = 16):
    """Per-decoder-layer cross K/V from encoder states: (Ldec, B, F, Kp, hd)."""
    layout = _layout(cfg, tp)
    cd = L.COMPUTE_DTYPE

    def one(lp):
        ca = lp["cross_attn"]
        k = jnp.einsum("bfd,dhk->bfhk", enc_states.astype(cd),
                       ca["wk"].astype(cd))
        v = jnp.einsum("bfd,dhk->bfhk", enc_states.astype(cd),
                       ca["wv"].astype(cd))
        if "bk" in ca:
            k = k + ca["bk"].astype(cd)
            v = v + ca["bv"].astype(cd)
        r = layout.kv_repeat
        if r > 1:
            k, v = jnp.repeat(k, r, 2), jnp.repeat(v, r, 2)
        if k.shape[2] < layout.kv_padded:
            pad = layout.kv_padded - k.shape[2]
            k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        from ..sharding.annotate import hint_heads
        return hint_heads(k), hint_heads(v)

    return jax.lax.map(lambda lp: one(lp), params["dec_layers"])


def decode_train(params, cfg: ArchConfig, tokens: Array, enc_states: Array,
                 *, tp: int = 16, collect_kv: bool = False):
    layout = _layout(cfg, tp)
    B, S = tokens.shape
    D = cfg.d_model
    x = hint_act(L.embed(params["embed"], tokens))
    x = x + jnp.asarray(sinusoid_positions(S, D)).astype(x.dtype)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    ckv = cross_kv(params, cfg, enc_states, tp=tp)   # (L,B,F,Kp,hd) x2

    def body(h, lc):
        lp, kx, vx = lc
        h, kv = _dec_block(lp, cfg, layout, h, positions, (kx, vx),
                           collect_kv=collect_kv)
        return h, kv

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, kvs = jax.lax.scan(body_fn, x, (params["dec_layers"], ckv[0], ckv[1]))
    x = L.layer_norm(x, params["dec_ln"]["scale"], params["dec_ln"]["bias"],
                     cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x.astype(L.COMPUTE_DTYPE),
                        params["embed"]["table"].astype(L.COMPUTE_DTYPE))
    return logits, kvs, ckv


def forward(params, cfg: ArchConfig, batch, *, tp: int = 16):
    enc = encode(params, cfg, batch["frames"], tp=tp)
    logits, _, _ = decode_train(params, cfg, batch["tokens"], enc, tp=tp)
    return logits, 0.0


def loss_fn(params, cfg: ArchConfig, batch, *, tp: int = 16) -> Array:
    logits, _ = forward(params, cfg, batch, tp=tp)
    return L.cross_entropy_loss(logits[:, :-1], batch["labels"][:, 1:],
                                vocab_real=cfg.vocab_size)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch_size: int, cache_len: int,
               tp: int = 16):
    layout = _layout(cfg, tp)
    hd = cfg.head_dim_
    Ld, F = cfg.num_layers, cfg.enc_frames
    return {
        "k": jnp.zeros((Ld, batch_size, cache_len, layout.kv_padded, hd),
                       L.COMPUTE_DTYPE),
        "v": jnp.zeros((Ld, batch_size, cache_len, layout.kv_padded, hd),
                       L.COMPUTE_DTYPE),
        "xk": jnp.zeros((Ld, batch_size, F, layout.kv_padded, hd),
                        L.COMPUTE_DTYPE),
        "xv": jnp.zeros((Ld, batch_size, F, layout.kv_padded, hd),
                        L.COMPUTE_DTYPE),
        "pos": jnp.zeros((), jnp.int32),
    }


def cache_axes(cfg: ArchConfig, *, seq_shard: bool = False):
    kv = logical("layers", "batch", None, "kv_heads", "head_dim",
                 name="cache.kv")
    return {"k": kv, "v": kv, "xk": kv, "xv": kv,
            "pos": logical(name="cache.pos")}


def prefill(params, cfg: ArchConfig, batch, *, tp: int = 16,
            cache_len: int | None = None):
    tokens = batch["tokens"]
    B, S = tokens.shape
    enc = encode(params, cfg, batch["frames"], tp=tp)
    logits, kvs, ckv = decode_train(params, cfg, tokens, enc, tp=tp,
                                    collect_kv=True)
    k, v = kvs
    Skv = cache_len or S
    if k.shape[2] < Skv:
        padn = Skv - k.shape[2]
        k = jnp.pad(k, ((0, 0), (0, 0), (0, padn), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, padn), (0, 0), (0, 0)))
    cache = {"k": k, "v": v, "xk": ckv[0], "xv": ckv[1],
             "pos": jnp.asarray(S, jnp.int32)}
    return logits[:, -1], cache


def decode_step(params, cfg: ArchConfig, cache, tokens: Array, *,
                tp: int = 16):
    layout = _layout(cfg, tp)
    B = tokens.shape[0]
    pos = cache["pos"]
    x = L.embed(params["embed"], tokens)
    D = cfg.d_model
    # sinusoidal position of the current token
    pe_table = jnp.asarray(sinusoid_positions(cache["k"].shape[2] + 1, D))
    x = x + jax.lax.dynamic_slice_in_dim(
        pe_table, jnp.minimum(pos, pe_table.shape[0] - 1), 1, 0
    )[None].astype(x.dtype)
    positions = jnp.full((B, 1), pos, jnp.int32)
    Skv = cache["k"].shape[2]
    slot = jnp.minimum(pos, Skv - 1)

    def body(h, lc):
        lp, kc, vc, kx, vx = lc
        hn = L.layer_norm(h, lp["ln1"]["scale"], lp["ln1"]["bias"],
                          cfg.norm_eps)
        q, k, v = L.qkv_project(lp["self_attn"], hn, layout,
                                positions=positions, rope_theta=None)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k, slot, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v, slot, axis=1)
        o = L.attention_decode(q, kc, vc, layout,
                               cur_len=jnp.full((B,), jnp.minimum(pos + 1, Skv)))
        h = h + L.attn_output(lp["self_attn"], o)
        hn = L.layer_norm(h, lp["ln_x"]["scale"], lp["ln_x"]["bias"],
                          cfg.norm_eps)
        cd = L.COMPUTE_DTYPE
        qx = jnp.einsum("bsd,dhk->bshk", hn.astype(cd),
                        lp["cross_attn"]["wq"].astype(cd))
        if "bq" in lp["cross_attn"]:
            qx = qx + lp["cross_attn"]["bq"].astype(cd)
        ox = L.attention_decode(qx, kx, vx, layout,
                                cur_len=jnp.full((B,), kx.shape[1]))
        h = h + L.attn_output(lp["cross_attn"], ox)
        hn = L.layer_norm(h, lp["ln2"]["scale"], lp["ln2"]["bias"],
                          cfg.norm_eps)
        h = h + L.gelu_mlp(lp["mlp"], hn)
        return h, (kc, vc)

    h, (ks, vs) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    h = L.layer_norm(h, params["dec_ln"]["scale"], params["dec_ln"]["bias"],
                     cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", h.astype(L.COMPUTE_DTYPE),
                        params["embed"]["table"].astype(L.COMPUTE_DTYPE))
    new_cache = {"k": ks, "v": vs, "xk": cache["xk"], "xv": cache["xv"],
                 "pos": pos + 1}
    return logits[:, 0], new_cache
