"""Production meshes.

A function, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS *before* first init).

Topology (TPU v5e pods):
    single pod : (16, 16)    axes ("data", "model")   = 256 chips
    two pods   : (2, 16, 16) axes ("pod", "data", "model") = 512 chips

"pod" composes with "data" for DP/FSDP; collectives crossing "pod" are the
slow (inter-pod) links, so gradient reduction is hierarchical by
construction (reduce-scatter within pod, then cross-pod all-reduce over
shards).  "model" carries TP/EP and stays inside the pod's dense ICI.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Small mesh over whatever devices exist (tests / examples on CPU)."""
    n = len(jax.devices())
    mp = max(1, min(model_parallel, n))
    return jax.make_mesh((n // mp, mp), ("data", "model"))


def make_data_mesh():
    """1-D ("data",) mesh over every host device.

    The DWN classify path is embarrassingly data-parallel (no weights to
    shard: the whole frozen model fits any single device), so serving
    shards only the batch axis; ``ServingEngine`` lays batch buckets over
    this mesh with ``shard_map``.
    """
    return jax.make_mesh((len(jax.devices()),), ("data",))


# TPU v5e hardware constants for the roofline (per chip).
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_BW_PER_LINK = 50e9            # bytes/s/link
