"""Serving CLI: a thin argparse front-end over ``repro.serving``.

All serving logic lives in the subsystem — ``serving/backends.py``
(pluggable DWN datapaths + compile cache + oracle cross-check),
``serving/scheduler.py`` (admission-order microbatching into power-of-two
batch buckets), ``serving/engine.py`` (unified submit/drain engine, DWN
buckets sharded data-parallel over the host mesh).  This module only
parses flags, synthesizes a request stream, and prints the JSON report.

LM archs: batches of prompts are prefilled once, then decoded
token-by-token with the per-arch cache (KV / SSM state / LRU state).

DWN archs (family="dwn", e.g. --arch dwn-jsc-lg): batches of JSC feature
vectors are classified through the selected datapath backend
(--backend fused-packed | packed-xla | float-oracle); every non-oracle
backend is checked bit-exactly against the ``apply_hard`` oracle before
timing starts.  --ragged draws mixed request sizes in [1, batch] so the
scheduler's coalescing/padding is exercised.  --continuous serves the
same stream through the continuous-batching async engine (scheduler
thread, out-of-order futures, optional --deadline-ms SLO) instead of the
sync submit/drain facade.

Usage:
    python -m repro.launch.serve --arch mamba2-1.3b --reduced \
        --batch 4 --prompt-len 32 --gen 16
    python -m repro.launch.serve --arch dwn-jsc-lg --reduced
    python -m repro.launch.serve --arch dwn-jsc-sm --reduced --ragged \
        --backend packed-xla
    python -m repro.launch.serve --reduced \
        --spec '{"preset": "sm-50", "variant": "PEN", "input_bits": 9}'

DWN ``--arch`` strings are deprecated shims: they resolve to registered
``repro.dwn.DWNSpec`` presets (``--spec`` constructs one inline).
"""

from __future__ import annotations

import argparse
import json
import sys

import numpy as np

from ..configs import get_arch
from ..serving import ServingEngine, available_backends
from ..serving.scheduler import next_pow2


def dwn_serve(target, args) -> int:
    """DWN classification serving through the engine + scheduler.

    ``target`` is anything the engine accepts: a registered arch name /
    ArchConfig (legacy), a ``DWNSpec`` (from ``--spec``), or a packed
    ``DWNArtifact``.
    """
    import dataclasses
    import warnings

    from ..dwn import resolve_spec
    workload = getattr(args, "workload", None)
    if workload is None:
        if resolve_spec(target).workload == "jsc":
            warnings.warn(
                "serving a DWN without --workload falls back to the "
                "implicit JSC default; pass --workload jsc (or any "
                "registered workload) explicitly",
                DeprecationWarning, stacklevel=2)
    else:
        spec = resolve_spec(target)
        if workload != spec.workload:
            # validated override: the preset must exist for that workload
            target = dataclasses.replace(spec, workload=workload)
    # --reduced shrinks the request volume, not the model: the datapath
    # (T=200 encode, m LUTs) is the thing being served.
    n_train = 2000 if args.reduced else 20000
    requests = args.requests if args.requests else (8 if args.reduced else 64)
    batch = args.batch if args.batch else (256 if args.reduced else 4096)
    max_bucket = next_pow2(batch)

    engine = ServingEngine(
        target, backend=args.backend or None, max_bucket=max_bucket,
        min_bucket=min(8, max_bucket), n_train=n_train, seed=args.seed,
        data_parallel=not args.no_data_parallel)
    # compile the serve bucket before timing starts (ragged streams may
    # still compile smaller ladder buckets in-band, one per bucket)
    engine.warmup(batch)

    rng = np.random.default_rng(args.seed)
    payloads = []
    for _ in range(requests):
        size = int(rng.integers(1, batch + 1)) if args.ragged else batch
        payloads.append(engine.make_request(
            size, seed=int(rng.integers(2**31))))
    if args.continuous:
        # continuous-batching path: futures resolve out of order while
        # the scheduler thread keeps steps in flight; a deadline makes
        # admission control + shedding part of the run
        with engine.serve():
            pending = [engine.submit_async(
                p, deadline_ms=args.deadline_ms or None) for p in payloads]
            results = [r.future.result() for r in pending]
        done = [r for r in results if r.ok]
    else:
        for p in payloads:
            engine.submit(p)
        done = engine.drain()

    rep = engine.report()
    rep["batch"] = batch
    rep["ragged"] = bool(args.ragged)
    rep["continuous"] = bool(args.continuous)
    # headline keys keep their pre-refactor meaning: *datapath* (compute)
    # latency per microbatch step.  Queue wait — which grows with the
    # pre-submitted stream length — stays separate under "latency".
    lat = rep.get("latency", {}).get("compute_ms", {})
    rep["latency_ms_p50"] = lat.get("p50")
    rep["latency_ms_p99"] = lat.get("p99")
    if done:
        first = done[0].value if args.continuous else done[0].result
        rep["sample"] = np.asarray(first[1][:8]).tolist()
    print(json.dumps(rep))
    return 0


def lm_serve(cfg, args) -> int:
    """LM prefill + decode serving through the engine.

    With ``--dwn-head`` (a DWNArtifact checkpoint path or a spec preset
    name like ``dwn-lm-head``) the engine also serves DWN classification
    on its own backbone features: the same drain serves the LM batch and
    a ``classify`` batch — one process, both request kinds.
    """
    engine = ServingEngine(
        cfg, reduced=args.reduced, prompt_len=args.prompt_len, gen=args.gen,
        model_parallel=args.model_parallel, seed=args.seed,
        dwn_head=args.dwn_head or None)
    B = args.batch or 4
    engine.submit(engine.make_request(B, seed=args.seed))
    if args.dwn_head:
        engine.submit(engine.make_request(B, seed=args.seed + 1,
                                          classify=True))
    done = engine.drain()

    rep = engine.report()
    tokens = done[0].result["tokens"]
    assert tokens.shape == (B, args.gen)
    rep["batch"] = B
    rep["sample"] = tokens[0, :8].tolist()
    if args.dwn_head:
        head = [r for r in done if "pred" in r.result]
        assert head and head[0].result["pred"].shape == (B,)
        rep["head_sample"] = head[0].result["pred"][:8].tolist()
    print(json.dumps(rep))
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="",
                    help="registered arch name (LM or DWN); DWN aliases "
                         "are deprecated shims over DWNSpec presets")
    ap.add_argument("--spec", default="",
                    help="DWN only: a DWNSpec as JSON, e.g. "
                         '\'{"preset": "sm-50", "variant": "PEN", '
                         '"input_bits": 9}\' — the typed replacement for '
                         "--arch dwn-jsc-* strings")
    ap.add_argument("--workload", default=None,
                    help="DWN mode: registered workload to serve "
                         "(jsc | mnist | ...; default: the spec's own "
                         "workload — omitting it for a JSC spec warns, "
                         "the implicit default is deprecated)")
    ap.add_argument("--dwn-head", default="",
                    help="LM mode: attach a packed DWN classification "
                         "head (DWNArtifact checkpoint path or spec "
                         "preset name, e.g. dwn-lm-head) and serve "
                         "classify requests alongside LM decode in the "
                         "same engine")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=0,
                    help="request batch size (default: 4 for LM archs, "
                         "256/4096 reduced/full for DWN archs)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--requests", type=int, default=0,
                    help="DWN mode: number of requests to serve")
    ap.add_argument("--ragged", action="store_true",
                    help="DWN mode: draw request sizes uniformly in "
                         "[1, batch] instead of a fixed batch")
    ap.add_argument("--continuous", action="store_true",
                    help="DWN mode: serve through the continuous-batching "
                         "async engine (scheduler thread + per-request "
                         "futures) instead of the sync submit/drain "
                         "facade")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="DWN mode with --continuous: per-request SLO "
                         "deadline; requests that provably cannot meet it "
                         "are shed at admission (0 = no deadline)")
    ap.add_argument("--backend", default="",
                    choices=["", "auto"] + available_backends(),
                    help="DWN datapath backend (default: the arch's "
                         "dwn_datapath, else fused-packed; 'auto' "
                         "calibrates per batch bucket at startup and "
                         "serves each bucket on the fastest bit-exact "
                         "backend)")
    ap.add_argument("--no-data-parallel", action="store_true",
                    help="DWN mode: disable shard_map data parallelism")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--greedy", action="store_true", default=True)
    args = ap.parse_args(argv)

    if args.spec:
        if args.arch:
            ap.error("--arch and --spec are mutually exclusive")
        from ..dwn import DWNSpec
        return dwn_serve(DWNSpec(**json.loads(args.spec)), args)
    if not args.arch:
        ap.error("one of --arch or --spec is required")
    cfg = get_arch(args.arch)
    if cfg.family == "dwn":
        import warnings
        warnings.warn(
            f"--arch {args.arch!r} is a legacy DWN alias; it now "
            f"delegates to the registered DWNSpec preset of the same "
            f"name (prefer --spec or repro.dwn.get_spec)",
            DeprecationWarning, stacklevel=2)
        return dwn_serve(cfg, args)
    return lm_serve(cfg, args)


if __name__ == "__main__":
    sys.exit(main())
