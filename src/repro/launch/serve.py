"""Batched serving driver: prefill + decode loop on the host mesh.

Runs a reduced (or full, on TPU) config: batches of prompts are
prefilled once, then decoded token-by-token with the per-arch cache
(KV / SSM state / LRU state).  Used by examples/serve_batch.py and the
integration tests; the full-size serving cells are proven by the
dry-run (prefill_32k / decode_32k / long_500k).

Usage:
    python -m repro.launch.serve --arch mamba2-1.3b --reduced \
        --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_arch
from ..models import api
from ..sharding.partition import Partitioner
from .mesh import make_host_mesh


def build(cfg, mesh, *, cache_len: int):
    tp = mesh.shape["model"]
    part = Partitioner(mesh)
    aparams = api.abstract_params(cfg, tp)
    p_shard = part.tree_shardings(aparams, api.param_axes(cfg))
    prefill = api.make_prefill(cfg, tp, cache_len=cache_len)
    decode = api.make_decode_step(cfg, tp)
    jprefill = jax.jit(prefill, in_shardings=(p_shard, None))
    jdecode = jax.jit(decode, in_shardings=(p_shard, None, None),
                      donate_argnums=(1,))
    return jprefill, jdecode, p_shard, tp


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--greedy", action="store_true", default=True)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh(args.model_parallel)
    cache_len = args.prompt_len + args.gen
    jprefill, jdecode, p_shard, tp = build(cfg, mesh, cache_len=cache_len)

    key = jax.random.PRNGKey(args.seed)
    mod = api.module_for(cfg)
    with mesh:
        params = jax.jit(lambda k: mod.init_params(k, cfg, tp),
                         out_shardings=p_shard)(key)

    B = args.batch
    batch = {"tokens": jax.random.randint(
        key, (B, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.enc_frames, cfg.d_model), jnp.bfloat16) * 0.1
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (B, cfg.num_patches, cfg.d_model), jnp.bfloat16) * 0.02

    t0 = time.time()
    with mesh:
        logits, cache = jprefill(params, batch)
    t_prefill = time.time() - t0

    generated = []
    nxt = jnp.argmax(logits[:, :cfg.vocab_size], -1)[:, None].astype(jnp.int32)
    t0 = time.time()
    for _ in range(args.gen):
        generated.append(np.asarray(nxt))
        with mesh:
            logits, cache = jdecode(params, cache, {"tokens": nxt})
        nxt = jnp.argmax(logits[:, :cfg.vocab_size], -1)[:, None].astype(jnp.int32)
    t_decode = time.time() - t0

    out = np.concatenate(generated, 1)
    assert out.shape == (B, args.gen)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    print(json.dumps({
        "arch": cfg.name, "batch": B, "prompt_len": args.prompt_len,
        "generated": args.gen,
        "prefill_s": round(t_prefill, 3),
        "decode_s_per_tok": round(t_decode / args.gen, 4),
        "sample": out[0, :8].tolist(),
    }))
    return 0


if __name__ == "__main__":
    sys.exit(main())
