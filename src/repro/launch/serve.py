"""Batched serving driver: LM prefill+decode loop, or DWN classification.

LM archs: batches of prompts are prefilled once, then decoded
token-by-token with the per-arch cache (KV / SSM state / LRU state).
Used by examples/serve_batch.py and the integration tests; the full-size
serving cells are proven by the dry-run (prefill_32k / decode_32k /
long_500k).

DWN archs (family="dwn", e.g. --arch dwn-jsc-lg): batches of JSC feature
vectors are classified through the *fused packed* Pallas kernel — encode
-> LUT layer(s) -> popcount in one pallas_call with bits packed 32/word
in VMEM — and the loop reports throughput + latency percentiles.  The
first batch is cross-checked bit-exactly against the float
``apply_hard`` oracle before timing starts.

Usage:
    python -m repro.launch.serve --arch mamba2-1.3b --reduced \
        --batch 4 --prompt-len 32 --gen 16
    python -m repro.launch.serve --arch dwn-jsc-lg --reduced
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_arch
from ..models import api
from ..sharding.partition import Partitioner
from .mesh import make_host_mesh


def build(cfg, mesh, *, cache_len: int):
    tp = mesh.shape["model"]
    part = Partitioner(mesh)
    aparams = api.abstract_params(cfg, tp)
    p_shard = part.tree_shardings(aparams, api.param_axes(cfg))
    prefill = api.make_prefill(cfg, tp, cache_len=cache_len)
    decode = api.make_decode_step(cfg, tp)
    jprefill = jax.jit(prefill, in_shardings=(p_shard, None))
    jdecode = jax.jit(decode, in_shardings=(p_shard, None, None),
                      donate_argnums=(1,))
    return jprefill, jdecode, p_shard, tp


def dwn_serve(cfg, args) -> int:
    """DWN classification serving loop on the fused packed kernel."""
    from ..core.model import DWNConfig, init_dwn, freeze, apply_hard
    from ..core.classifier import predict
    from ..data.jsc import load_jsc
    from ..kernels.fused import ops as fused_ops

    # --reduced shrinks the request volume, not the model: the datapath
    # (T=200 encode, m LUTs) is the thing being served.
    n_train = 2000 if args.reduced else 20000
    requests = args.requests if args.requests else (8 if args.reduced else 64)
    batch = args.batch if args.batch else (256 if args.reduced else 4096)

    data = load_jsc(n_train, max(batch, 512))
    dcfg = DWNConfig(lut_counts=(cfg.dwn_luts,),
                     bits_per_feature=cfg.dwn_bits)
    key = jax.random.PRNGKey(args.seed)
    params, buffers = init_dwn(key, dcfg, data.x_train)
    frozen = freeze(params, buffers, dcfg)
    thresholds = jnp.asarray(frozen.thresholds)
    mappings = [jnp.asarray(i) for i in frozen.mapping_idx]
    tables = [jnp.asarray(t) for t in frozen.tables_bin]

    def classify(xb):
        return fused_ops.forward_packed(xb, thresholds, mappings, tables,
                                        dcfg.num_classes)

    jclassify = jax.jit(classify)

    # Bit-exactness gate before timing: fused packed == float oracle.
    x0 = jnp.asarray(data.x_test[:batch])
    counts0, idx0 = jclassify(x0)
    oracle = apply_hard(frozen, x0)
    bit_exact = (np.array_equal(np.asarray(counts0), np.asarray(oracle))
                 and np.array_equal(np.asarray(idx0),
                                    np.asarray(predict(oracle))))
    if not bit_exact:
        raise RuntimeError(
            "fused packed kernel diverged from the apply_hard oracle; "
            "refusing to serve a broken datapath")

    rng = np.random.default_rng(args.seed)
    lat = []
    served = 0
    t_total0 = time.time()
    for _ in range(requests):
        sel = rng.integers(0, data.x_test.shape[0], batch)
        xb = jnp.asarray(data.x_test[sel])
        t0 = time.time()
        counts, idx = jclassify(xb)
        idx.block_until_ready()
        lat.append(time.time() - t0)
        served += batch
    t_total = time.time() - t_total0

    lat_ms = np.sort(np.asarray(lat)) * 1e3
    print(json.dumps({
        "arch": cfg.name, "mode": "dwn-classify", "datapath": "fused-packed",
        "luts": cfg.dwn_luts, "bits_per_feature": cfg.dwn_bits,
        "batch": batch, "requests": requests, "served": served,
        "bit_exact_vs_oracle": bit_exact,
        "throughput_samples_per_s": round(served / t_total, 1),
        "latency_ms_p50": round(float(np.percentile(lat_ms, 50)), 3),
        "latency_ms_p99": round(float(np.percentile(lat_ms, 99)), 3),
        "sample": np.asarray(idx0[:8]).tolist(),
    }))
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=0,
                    help="request batch size (default: 4 for LM archs, "
                         "256/4096 reduced/full for DWN archs)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--requests", type=int, default=0,
                    help="DWN mode: number of request batches to serve")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--greedy", action="store_true", default=True)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if cfg.family == "dwn":
        return dwn_serve(cfg, args)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh(args.model_parallel)
    cache_len = args.prompt_len + args.gen
    jprefill, jdecode, p_shard, tp = build(cfg, mesh, cache_len=cache_len)

    key = jax.random.PRNGKey(args.seed)
    mod = api.module_for(cfg)
    with mesh:
        params = jax.jit(lambda k: mod.init_params(k, cfg, tp),
                         out_shardings=p_shard)(key)

    B = args.batch or 4
    batch = {"tokens": jax.random.randint(
        key, (B, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.enc_frames, cfg.d_model), jnp.bfloat16) * 0.1
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (B, cfg.num_patches, cfg.d_model), jnp.bfloat16) * 0.02

    t0 = time.time()
    with mesh:
        logits, cache = jprefill(params, batch)
    t_prefill = time.time() - t0

    generated = []
    nxt = jnp.argmax(logits[:, :cfg.vocab_size], -1)[:, None].astype(jnp.int32)
    t0 = time.time()
    for _ in range(args.gen):
        generated.append(np.asarray(nxt))
        with mesh:
            logits, cache = jdecode(params, cache, {"tokens": nxt})
        nxt = jnp.argmax(logits[:, :cfg.vocab_size], -1)[:, None].astype(jnp.int32)
    t_decode = time.time() - t0

    out = np.concatenate(generated, 1)
    assert out.shape == (B, args.gen)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    print(json.dumps({
        "arch": cfg.name, "batch": B, "prompt_len": args.prompt_len,
        "generated": args.gen,
        "prefill_s": round(t_prefill, 3),
        "decode_s_per_tok": round(t_decode / args.gen, 4),
        "sample": out[0, :8].tolist(),
    }))
    return 0


if __name__ == "__main__":
    sys.exit(main())
