"""Open-loop load generator for the DWN serving engine.

Closed-loop benchmarks (submit a fixed stream, drain, divide) measure a
*point*; they cannot say what the engine sustains when traffic does not
wait for it.  This module generates **open-loop** traffic — arrivals
follow a seeded Poisson process whose timeline never reacts to engine
latency — and drives either serving mode with it:

* ``run_async``: the continuous-batching path (``submit_async`` with
  per-tenant deadlines/priorities; ``QueueFull`` rejections count as
  shed — backpressure is part of the operating envelope);
* ``run_sync``: the synchronous submit/drain facade, the baseline the
  latency–throughput curve is compared against.  Arrivals falling due
  while ``drain()`` blocks are submitted when it returns, but their
  latency is still measured **from the intended arrival time** — the
  standard correction for coordinated omission, applied identically in
  both modes.

Traffic shape: exponential inter-arrivals at ``rate_rps``, optionally
multiplied by ``burst_factor`` inside periodic burst windows; per-arrival
size/deadline/priority drawn from a weighted multi-tenant mix (tenants
can also target different presets — the harness routes each to its own
engine).  Everything is derived from one ``numpy`` generator seeded by
``LoadSpec.seed``, so a schedule is reproducible bit-for-bit.

CLI::

    PYTHONPATH=src python -m repro.launch.loadgen --preset dwn-jsc-sm \
        --levels 0.5,1.0,1.3 --duration 2 --mode both --out curve.json

``benchmarks/load_harness.py`` wraps this to record the per-preset
latency–throughput curve into ``BENCH_serve.json``.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time

import numpy as np

from ..serving.continuous import QueueFull, SLOConfig
from ..serving.scheduler import percentiles


@dataclasses.dataclass(frozen=True)
class Tenant:
    """One traffic class in the mix.

    ``size`` is a distribution spec: ``"fixed:N"`` or ``"uniform:LO:HI"``
    (inclusive).  ``preset`` routes the tenant to a named engine (None =
    the single default engine).  ``deadline_ms`` / ``priority`` are
    forwarded to ``submit_async`` (the sync baseline ignores both — it
    has no admission control, which is the point of the comparison).
    """

    name: str = "default"
    weight: float = 1.0
    size: str = "uniform:32:256"
    deadline_ms: float | None = None
    priority: int = 0
    preset: str | None = None

    def sample_size(self, rng: np.random.Generator) -> int:
        kind, *args = self.size.split(":")
        if kind == "fixed":
            return int(args[0])
        if kind == "uniform":
            lo, hi = int(args[0]), int(args[1])
            return int(rng.integers(lo, hi + 1))
        raise ValueError(f"unknown size distribution {self.size!r}")


@dataclasses.dataclass(frozen=True)
class LoadSpec:
    """One offered-load level: a Poisson arrival process over a tenant
    mix, optionally burstier inside periodic windows."""

    rate_rps: float
    duration_s: float
    seed: int = 0
    #: rate multiplier inside bursts (1.0 = stationary Poisson)
    burst_factor: float = 1.0
    burst_every_s: float = 0.0      # burst window period (0 = no bursts)
    burst_len_s: float = 0.0        # burst window length
    tenants: tuple[Tenant, ...] = (Tenant(),)


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One scheduled request: when, how big, for whom."""

    t: float                        # seconds after stream start
    size: int
    tenant: str
    deadline_ms: float | None
    priority: int
    preset: str | None


def make_arrivals(spec: LoadSpec) -> list[Arrival]:
    """The deterministic open-loop schedule for one load level.

    Thinning-free piecewise-Poisson: inter-arrival gaps are exponential
    at the instantaneous rate (base, or base*burst_factor inside a burst
    window).  Same ``LoadSpec`` -> identical schedule, always.
    """
    rng = np.random.default_rng(spec.seed)
    weights = np.asarray([t.weight for t in spec.tenants], np.float64)
    weights = weights / weights.sum()
    out: list[Arrival] = []
    t = 0.0
    while True:
        in_burst = (spec.burst_every_s > 0
                    and (t % spec.burst_every_s) < spec.burst_len_s)
        rate = spec.rate_rps * (spec.burst_factor if in_burst else 1.0)
        t += float(rng.exponential(1.0 / rate))
        if t >= spec.duration_s:
            return out
        tenant = spec.tenants[int(rng.choice(len(spec.tenants), p=weights))]
        out.append(Arrival(t=t, size=tenant.sample_size(rng),
                           tenant=tenant.name,
                           deadline_ms=tenant.deadline_ms,
                           priority=tenant.priority, preset=tenant.preset))


def _engine_for(engines, arrival: Arrival):
    if arrival.preset is None:
        assert len(engines) == 1, \
            "tenant without preset needs a single-engine run"
        return next(iter(engines.values()))
    return engines[arrival.preset]


def _sleep_until(t_abs: float) -> None:
    # plain sleep only: it releases the GIL, which the scheduler thread
    # needs (a spin-wait here measurably starves the step loop).  Sleep
    # granularity (~0.1-1ms) just shifts submits late; the lateness is
    # recorded per arrival and latency is measured from the intended
    # time, so the timeline stays honest
    dt = t_abs - time.perf_counter()
    if dt > 0:
        time.sleep(dt)


def run_async(engines: dict, arrivals: list[Arrival], payloads: list, *,
              slo: SLOConfig | None = None,
              submit_timeout_s: float = 0.0) -> dict:
    """Drive the continuous-batching path with one open-loop schedule.

    ``engines`` maps preset name -> ServingEngine; every engine gets its
    own serve() session for the run.  ``payloads[i]`` is the pre-built
    feature array for ``arrivals[i]`` (generation cost must not pollute
    the timeline).  ``submit_timeout_s=0`` makes backpressure a shed, not
    a stall — the open-loop producer never waits.
    """
    for eng in engines.values():
        eng.start_serving(slo=slo)
    lateness, reqs, rejected = [], [], 0
    # the producer shares the GIL with the scheduler thread; the default
    # 5ms switch interval lets a behind-schedule producer stall the step
    # loop's Python sections for whole step-times at once
    switch = sys.getswitchinterval()
    sys.setswitchinterval(0.0005)
    try:
        t0 = time.perf_counter()
        for n, (arr, payload) in enumerate(zip(arrivals, payloads)):
            t_target = t0 + arr.t
            _sleep_until(t_target)
            late = time.perf_counter() - t_target
            lateness.append(late)
            if late > 0.001 and n % 32 == 31:
                time.sleep(0.0002)   # behind: yield the GIL periodically
            try:
                reqs.append((arr, _engine_for(engines, arr).submit_async(
                    payload, deadline_ms=arr.deadline_ms,
                    priority=arr.priority, timeout=submit_timeout_s)))
            except QueueFull:
                rejected += 1
                reqs.append((arr, None))
        for _, req in reqs:
            if req is not None:
                req.future.result()
        t_end = time.perf_counter()
    finally:
        sys.setswitchinterval(switch)
        for eng in engines.values():
            eng.stop_serving()
    return _metrics(reqs, t0, t_end, rejected=rejected,
                    lateness_s=lateness)


def run_sync(engines: dict, arrivals: list[Arrival], payloads: list) -> dict:
    """Drive the synchronous submit/drain facade with the same schedule.

    One thread alternates "submit everything due" and "drain the queue";
    arrivals due while drain blocks are submitted on return, and their
    latency counts from the intended arrival (no coordinated omission).
    """
    lateness, reqs = [], []
    i = 0
    t0 = time.perf_counter()
    while i < len(arrivals):
        t_target = t0 + arrivals[i].t
        now = time.perf_counter()
        if now < t_target and all(
                eng.scheduler.pending == 0 for eng in engines.values()):
            _sleep_until(t_target)
            now = time.perf_counter()
        submitted = False
        while i < len(arrivals) and t0 + arrivals[i].t <= now:
            arr = arrivals[i]
            lateness.append(now - (t0 + arr.t))
            reqs.append((arr, _engine_for(engines, arr).submit(payloads[i])))
            i += 1
            submitted = True
        if submitted or any(eng.scheduler.pending
                            for eng in engines.values()):
            for eng in engines.values():
                if eng.scheduler.pending:
                    eng.drain()
    for eng in engines.values():
        if eng.scheduler.pending:
            eng.drain()
    t_end = time.perf_counter()
    return _metrics(reqs, t0, t_end, rejected=0, lateness_s=lateness)


def _metrics(reqs, t0: float, t_end: float, *, rejected: int,
             lateness_s) -> dict:
    """Shared per-level metrics: same keys as the per-backend bench rows.

    Latency is measured from the *intended* arrival time (t0 + arrival.t)
    to results-ready, for both modes.  ``throughput_samples_per_s`` is
    served (non-shed) samples over the span from stream start to last
    completion; ``shed_rate`` is shed samples (admission + expiry + late
    + queue-full rejections) over offered samples.
    """
    offered_samples = sum(arr.size for arr, _ in reqs)
    served_lat_ms, served_samples = [], 0
    shed_samples = sum(arr.size for arr, r in reqs if r is None)
    for arr, r in reqs:
        if r is None:                     # backpressure rejection
            continue
        shed = getattr(r, "shed", None)
        if shed is not None:
            shed_samples += arr.size
            continue
        served_samples += arr.size
        served_lat_ms.append((r.t_done - (t0 + arr.t)) * 1e3)
    wall = max(t_end - t0, 1e-9)
    out = {
        "offered_rps": round(len(reqs) / max(
            (reqs[-1][0].t if reqs else 0.0), 1e-9), 1),
        "offered_samples_per_s": round(offered_samples / max(
            (reqs[-1][0].t if reqs else 0.0), 1e-9), 1),
        "throughput_samples_per_s": round(served_samples / wall, 1),
        "served_requests": len(served_lat_ms),
        "shed_requests": sum(1 for arr, r in reqs
                             if r is None or getattr(r, "shed", None)),
        "rejected_requests": rejected,
        "shed_rate": round(shed_samples / offered_samples, 4)
        if offered_samples else 0.0,
        "wall_s": round(wall, 3),
        #: submit-loop lag behind the intended timeline (open-loop health:
        #: large p99 here means the generator, not the engine, was the
        #: bottleneck and the offered load is understated)
        "submit_lag_ms": percentiles([v * 1e3 for v in lateness_s])
        if lateness_s else {},
    }
    if served_lat_ms:
        lat = percentiles(served_lat_ms)
        out["latency_ms_p50"] = lat["p50"]
        out["latency_ms_p99"] = lat["p99"]
        out["latency_ms_p999"] = lat["p999"]
    return out


def measure_capacity(engine, *, requests: int = 24,
                     size: int | None = None) -> float:
    """Closed-loop samples/s ceiling: one warm max-bucket stream through
    the sync facade.  The load levels are fractions of this."""
    size = size if size is not None else engine.scheduler.max_bucket
    engine.warmup(size)
    payloads = [engine.make_request(size, seed=i) for i in range(requests)]
    t0 = time.perf_counter()
    for p in payloads:
        engine.submit(p)
    done = engine.drain()
    wall = time.perf_counter() - t0
    return sum(r.size for r in done) / wall


def run_level(engines: dict, spec: LoadSpec, *, mode: str = "both",
              slo: SLOConfig | None = None) -> dict:
    """One offered-load level end to end: schedule, payloads, run(s)."""
    arrivals = make_arrivals(spec)
    payloads = []
    for i, arr in enumerate(arrivals):
        eng = _engine_for(engines, arr)
        payloads.append(eng.make_request(arr.size, seed=spec.seed + i))
    out = {"rate_rps": round(spec.rate_rps, 1),
           "arrivals": len(arrivals),
           "duration_s": spec.duration_s}
    if mode in ("both", "async"):
        out["continuous"] = run_async(engines, arrivals, payloads, slo=slo)
    if mode in ("both", "sync"):
        out["sync"] = run_sync(engines, arrivals, payloads)
    return out


def main(argv=None):
    from ..serving import ServingEngine

    ap = argparse.ArgumentParser(
        description="open-loop Poisson load generator for DWN serving")
    ap.add_argument("--preset", action="append", default=[],
                    help="DWN preset(s) to serve; repeat for a "
                         "multi-tenant mix (default: dwn-jsc-sm)")
    ap.add_argument("--levels", default="0.5,1.0,1.3",
                    help="offered-load levels as fractions of measured "
                         "closed-loop capacity")
    ap.add_argument("--duration", type=float, default=2.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mode", default="both",
                    choices=["both", "async", "sync"])
    ap.add_argument("--deadline-ms", type=float, default=50.0,
                    help="per-request SLO deadline (continuous mode)")
    ap.add_argument("--sizes", default="uniform:32:256")
    ap.add_argument("--burst-factor", type=float, default=1.0)
    ap.add_argument("--burst-every", type=float, default=0.0)
    ap.add_argument("--burst-len", type=float, default=0.0)
    ap.add_argument("--max-bucket", type=int, default=256)
    ap.add_argument("--backend", default="auto")
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)

    presets = args.preset or ["dwn-jsc-sm"]
    engines = {p: ServingEngine(p, backend=args.backend,
                                max_bucket=args.max_bucket, n_train=2000)
               for p in presets}
    capacity = {p: measure_capacity(eng) for p, eng in engines.items()}
    total_cap = sum(capacity.values())
    tenants = tuple(
        Tenant(name=p, weight=capacity[p], size=args.sizes,
               deadline_ms=args.deadline_ms, preset=p) for p in presets)
    mean_size = float(np.mean([t.sample_size(np.random.default_rng(0))
                               for t in tenants for _ in range(256)]))
    record = {"presets": presets, "capacity_samples_per_s":
              {p: round(c, 1) for p, c in capacity.items()},
              "levels": []}
    for frac in [float(s) for s in args.levels.split(",")]:
        rate = frac * total_cap / mean_size
        spec = LoadSpec(rate_rps=rate, duration_s=args.duration,
                        seed=args.seed, burst_factor=args.burst_factor,
                        burst_every_s=args.burst_every,
                        burst_len_s=args.burst_len, tenants=tenants)
        level = run_level(engines, spec, mode=args.mode)
        level["load_fraction"] = frac
        record["levels"].append(level)
        print(json.dumps(level))
    if args.out:
        with open(args.out, "w") as fh:
            json.dump(record, fh, indent=2)
        print(f"written {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
