import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.
#   Do not set this anywhere else (tests/benches must see 1 device).

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the production mesh, abstract params/optimizer
state/batch (ShapeDtypeStructs — no allocation), resolves NamedShardings
from the logical-axis rules, and runs

    jax.jit(step, in_shardings=..., out_shardings=..., donate...)\
        .lower(*specs).compile()

then records memory_analysis(), cost_analysis() and the collective bytes
parsed from the optimized HLO into results/dryrun/<cell>.json (the roofline
table and §Perf read these).

Usage:
    python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k [--multi-pod]
    python -m repro.launch.dryrun --all [--multi-pod] [--force]
    python -m repro.launch.dryrun --list
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback
from pathlib import Path

import jax

from ..configs import SHAPES, DWN_SHAPES, cell_supported, get_arch
from ..configs.registry import assigned_archs
from ..models import api
from ..roofline.analyze import analyze, model_flops
from ..sharding.partition import Partitioner
from .mesh import make_production_mesh

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _cell_id(arch: str, shape: str, multi_pod: bool) -> str:
    return f"{arch}__{shape}__{'pod2' if multi_pod else 'pod1'}"


def _opt_state_axes(params_axes):
    """AdamState(step, mu, nu): moments shard like params."""
    from ..optim.adam import AdamState
    from ..sharding.partition import logical
    return AdamState(logical(name="opt.step"), params_axes, params_axes)


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               perf_variant: str = "baseline", extra: dict | None = None):
    """Lower+compile one cell; returns the result record.

    perf_variant (§Perf hillclimb knobs, comma-separated):
      * "logits_sharded": decode/prefill logits stay vocab-sharded on the
        model axis (sampling happens on sharded logits) instead of being
        all-gathered;
      * "serve_tp_only": serving weights are replicated over the DP axes
        (TP-only placement) — no per-layer FSDP all-gathers on the decode
        path (weights must fit HBM, which every assigned arch does in
        fp32/256 chips and bf16 would halve again);
      * "serve_bf16": serving weights in bf16 — halves the per-token
        weight-streaming bytes that bound batch-1 decode.
    """
    cfg = get_arch(arch)
    shape = {**SHAPES, **DWN_SHAPES}[shape_name]
    ok, reason = cell_supported(cfg, shape)
    if not ok:
        return {"cell": _cell_id(arch, shape_name, multi_pod),
                "skipped": True, "reason": reason}

    variants = set(perf_variant.split(",")) if perf_variant else set()
    import dataclasses as _dc2
    if "attn_tri" in variants:
        cfg = _dc2.replace(cfg, attn_impl="tri")
    if "scores_bf16" in variants:
        cfg = _dc2.replace(cfg, attn_scores_bf16=True)
    if "moe_ep" in variants:
        cfg = _dc2.replace(cfg, moe_ep=True)
    if "cf1" in variants:
        cfg = _dc2.replace(cfg, capacity_factor=1.0)
    mesh = make_production_mesh(multi_pod=multi_pod)
    tp = mesh.shape["model"]
    chips = mesh.size
    rules = {}
    if "serve_tp_only" in variants and shape.kind != "train":
        rules["embed"] = None          # replicate the FSDP dim for serving
    part = Partitioner(mesh, rules=rules)

    t0 = time.time()
    aparams = api.abstract_params(cfg, tp)
    if "serve_bf16" in variants and shape.kind != "train":
        import jax.numpy as jnp
        aparams = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, jnp.bfloat16 if s.dtype == jnp.float32 else s.dtype),
            aparams)
    p_axes = api.param_axes(cfg)
    p_shard = part.tree_shardings(aparams, p_axes)

    record = {
        "cell": _cell_id(arch, shape_name, multi_pod),
        "arch": arch, "shape": shape_name,
        "mesh": dict(zip(mesh.axis_names, [mesh.shape[a] for a in mesh.axis_names])),
        "chips": chips,
        "perf_variant": perf_variant,
        "params": cfg.num_params(),
        "active_params": cfg.num_active_params(),
    }

    import contextlib
    mesh_ctx = mesh  # `with mesh:` makes it ambient for sharding hints

    if shape.kind == "train":
        micro = shape_train_micro(cfg, shape)
        step_fn, opt = api.make_train_step(cfg, tp, num_micro=micro)
        aopt = jax.eval_shape(opt.init, aparams)
        o_shard = part.tree_shardings(
            aopt, _opt_state_axes(p_axes))
        import dataclasses as _dc
        shp = _dc.replace(shape, num_microbatches=micro)
        bspecs = api.batch_specs(cfg, shp, micro=True)
        b_axes = api.batch_axes(cfg, shp, micro=True)
        b_shard = part.tree_shardings(bspecs, b_axes)
        fn = jax.jit(step_fn,
                     in_shardings=(p_shard, o_shard, b_shard),
                     out_shardings=(p_shard, o_shard, None),
                     donate_argnums=(0, 1))
        with mesh:
            lowered = fn.lower(aparams, aopt, bspecs)
        record["num_microbatches"] = micro
    elif shape.kind == "prefill":
        prefill_fn = api.make_prefill(cfg, tp, cache_len=shape.seq_len)
        bspecs = api.batch_specs(cfg, shape)
        b_axes = api.batch_axes(cfg, shape)
        b_shard = part.tree_shardings(bspecs, b_axes)
        acache = api.abstract_cache(cfg, shape, tp)
        c_shard = part.tree_shardings(acache, api.cache_axes(cfg, shape))
        from jax.sharding import NamedSharding, PartitionSpec as P
        logit_shard = (NamedSharding(mesh, P(None, "model"))
                       if "logits_sharded" in variants else None)
        fn = jax.jit(prefill_fn, in_shardings=(p_shard, b_shard),
                     out_shardings=(logit_shard, c_shard))
        with mesh:
            lowered = fn.lower(aparams, bspecs)
    else:  # decode
        decode_fn = api.make_decode_step(cfg, tp)
        acache = api.abstract_cache(cfg, shape, tp)
        c_shard = part.tree_shardings(acache, api.cache_axes(cfg, shape))
        bspecs = api.batch_specs(cfg, shape)
        b_axes = api.batch_axes(cfg, shape)
        b_shard = part.tree_shardings(bspecs, b_axes)
        from jax.sharding import NamedSharding, PartitionSpec as P
        logit_shard = (NamedSharding(mesh, P(None, "model"))
                       if "logits_sharded" in variants else None)
        fn = jax.jit(decode_fn,
                     in_shardings=(p_shard, c_shard, b_shard),
                     out_shardings=(logit_shard, c_shard),
                     donate_argnums=(1,))
        with mesh:
            lowered = fn.lower(aparams, acache, bspecs)

    t_lower = time.time() - t0
    t0 = time.time()
    with mesh:
        compiled = lowered.compile()
    t_compile = time.time() - t0

    hlo_text = compiled.as_text()
    stats = analyze(compiled, chips, hlo_text=hlo_text)
    record.update(stats)
    # keep the optimized HLO for offline perf analysis (§Perf digs here)
    import gzip
    RESULTS.mkdir(parents=True, exist_ok=True)
    hlo_path = RESULTS / (record["cell"] +
                          (f"__{perf_variant}" if perf_variant != "baseline"
                           else "") + ".hlo.txt.gz")
    with gzip.open(hlo_path, "wt") as f:
        f.write(hlo_text)
    record["fallbacks"] = [dataclasses.asdict(f) for f in part.fallbacks]
    record["lower_s"] = round(t_lower, 1)
    record["compile_s"] = round(t_compile, 1)
    mf = model_flops(cfg, shape,
                     include_backward=shape.kind == "train")
    record["model_flops_total"] = mf
    hlo_total = stats["flops_per_chip"] * chips
    record["useful_flops_ratio"] = mf / hlo_total if hlo_total else 0.0
    if extra:
        record.update(extra)
    return record


def shape_train_micro(cfg, shape) -> int:
    return max(1, cfg.train_microbatches) if shape.kind == "train" else 1


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             force: bool = False, tag: str = "",
             perf_variant: str = "baseline") -> dict:
    RESULTS.mkdir(parents=True, exist_ok=True)
    cid = _cell_id(arch, shape_name, multi_pod) + (f"__{tag}" if tag else "")
    out = RESULTS / f"{cid}.json"
    if out.exists() and not force:
        rec = json.loads(out.read_text())
        print(f"[cached] {cid}: {rec.get('roofline', rec.get('reason', ''))}")
        return rec
    print(f"[lower ] {cid} ...", flush=True)
    try:
        rec = lower_cell(arch, shape_name, multi_pod=multi_pod,
                         perf_variant=perf_variant)
    except Exception as e:
        rec = {"cell": cid, "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
        out.write_text(json.dumps(rec, indent=2))
        print(f"[FAIL  ] {cid}: {rec['error']}", flush=True)
        return rec
    out.write_text(json.dumps(rec, indent=2, default=float))
    if rec.get("skipped"):
        print(f"[skip  ] {cid}: {rec['reason']}", flush=True)
    else:
        r = rec["roofline"]
        print(f"[ok    ] {cid}: bound={r['bound']} "
              f"c={r['compute_s']:.4f}s m={r['memory_s']:.4f}s "
              f"x={r['collective_s']:.4f}s "
              f"(lower {rec['lower_s']}s compile {rec['compile_s']}s)",
              flush=True)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--dwn", action="store_true",
                    help="sweep the paper's DWN archs x DWN shapes")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--variant", default="baseline",
                    help="perf-variant knobs, comma separated "
                         "(logits_sharded,serve_tp_only)")
    ap.add_argument("--tag", default="")
    args = ap.parse_args(argv)

    if args.list:
        for a in assigned_archs():
            for s in SHAPES:
                ok, why = cell_supported(get_arch(a), SHAPES[s])
                print(f"{a:24s} {s:12s} {'ok' if ok else 'SKIP: ' + why}")
        return 0

    if args.dwn:
        failures = 0
        for a in ("dwn-jsc-sm10", "dwn-jsc-sm50", "dwn-jsc-md360",
                  "dwn-jsc-lg2400", "dwn-jsc-lg2400-fused",
                  "dwn-jsc-md360-fused"):
            for s in DWN_SHAPES:
                if a.endswith("-fused") and s == "dwn_train_1m":
                    continue          # fused variant is a serving datapath
                rec = run_cell(a, s, multi_pod=args.multi_pod,
                               force=args.force)
                failures += 1 if "error" in rec else 0
        print(f"done; failures={failures}")
        return 1 if failures else 0

    if args.all:
        failures = 0
        for a in assigned_archs():
            for s in SHAPES:
                rec = run_cell(a, s, multi_pod=args.multi_pod,
                               force=args.force)
                failures += 1 if "error" in rec else 0
        print(f"done; failures={failures}")
        return 1 if failures else 0

    assert args.arch and args.shape, "--arch and --shape (or --all/--list)"
    rec = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                   force=args.force, tag=args.tag,
                   perf_variant=args.variant)
    return 1 if "error" in rec else 0


if __name__ == "__main__":
    sys.exit(main())
