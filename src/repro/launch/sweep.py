"""Sweep CLI: a thin argparse front-end over ``repro.sweep``.

Runs an encoding design-space grid through the shared pipeline (accuracy x
FPGA cost x kernel/serving throughput), prints the result table + Pareto
fronts, checks any paper-referenced points against their documented
tolerances, and writes everything as one JSON artifact.

Usage:
    python -m repro.launch.sweep --grid tiny --out sweep.json
    python -m repro.launch.sweep --grid paper --out sweep.json --plots
    python -m repro.launch.sweep --grid encoding --epochs 2 --no-serve
    python -m repro.launch.sweep --grid my_points.json --fresh
    python -m repro.launch.sweep --grid encoding --autodesign --acc-floor 0.70
    python -m repro.launch.sweep --grid paper --workers 4 --artifact-dir \
        results/sweep_artifacts

``--workers N`` switches to the resilient parallel executor
(``repro.sweep.executor``): grid points shard across N worker processes,
each point runs under a bounded restart policy, completed points commit
to the cache atomically (a killed run resumes with zero recomputed
points), straggler points are speculatively re-dispatched, and SIGTERM
drains gracefully (exit 0, resumable).  ``--chaos kill-after-N`` injects
worker deaths for testing.  See docs/sweep_resilience.md.

``--autodesign`` walks the accuracy-vs-LUTs Pareto front (min LUTs at an
accuracy floor, or max accuracy under ``--lut-budget``), rebuilds the
winner, co-simulates its emitted Verilog against the packed oracle
(``repro.hw.cosim``), and writes the verified RTL — non-zero exit on any
mismatch or unmet objective.
"""

from __future__ import annotations

import argparse
import math
import sys

from ..sweep import SweepSettings, run_grid
from ..sweep.artifacts import TABLE1_TEN_TOLERANCE


def ascii_scatter(points, *, x_of, y_of, mark_of=lambda p: "*",
                  y_lo: float, y_hi: float, y_step: float,
                  x_label: str, log_x: bool = True, width: int = 70):
    """Print a log-x ASCII scatter (the repo's house plot style)."""
    xs = [x_of(p) for p in points if y_of(p) is not None]
    if not xs:
        print("  (no points with this axis measured)")
        return
    x_min = min(xs)
    x_max = max(x_min + 1, max(xs))

    def col(x):
        if log_x:
            span = math.log10(max(x_max, 10)) - math.log10(max(x_min, 1))
            f = ((math.log10(max(x, 1)) - math.log10(max(x_min, 1)))
                 / max(span, 1e-9))
        else:
            f = (x - x_min) / max(x_max - x_min, 1e-9)
        return min(int(f * (width - 1)), width - 1)

    y = y_hi
    while y > y_lo:
        line = [" "] * width
        for p in points:
            v = y_of(p)
            if v is not None and y - y_step <= v < y:
                line[col(x_of(p))] = mark_of(p)
        print(f"{y - y_step:8.1f} |" + "".join(line))
        y -= y_step
    print(" " * 9 + "-" * width)
    print(" " * 9 + x_label)


def check_paper_points(result) -> list[str]:
    """Tolerance check of every paper-referenced TEN point.

    Returns a list of failure strings (empty = all TEN references are
    within the documented tolerance, docs/reproduction.md).
    """
    failures = []
    for r in result.points:
        if r.paper_luts is None or r.point.variant != "TEN":
            continue
        tol = TABLE1_TEN_TOLERANCE.get(r.point.preset)
        if tol is None:
            continue
        err = abs(r.total_luts - r.paper_luts) / r.paper_luts
        status = "ok" if err <= tol else "FAIL"
        print(f"  Table I TEN {r.point.preset}: ours={r.total_luts} "
              f"paper={r.paper_luts} err={100 * err:.1f}% "
              f"(tol {100 * tol:.0f}%) {status}")
        if err > tol:
            failures.append(f"{r.point.preset}: {100 * err:.1f}% "
                            f"> {100 * tol:.0f}%")
    return failures


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--grid", default="tiny",
                    help="named grid (tiny|paper|encoding|mnist-tiny|"
                         "mnist) or a JSON file of point dicts")
    ap.add_argument("--out", default="",
                    help="write the SweepResult JSON here")
    ap.add_argument("--plots", action="store_true",
                    help="print ASCII Pareto plots (acc vs LUTs, "
                         "throughput vs LUTs)")
    ap.add_argument("--epochs", type=int, default=0,
                    help="training epochs per model (0 = warmstart only; "
                         "hardware axes don't need training)")
    ap.add_argument("--n-train", type=int, default=4000)
    ap.add_argument("--n-test", type=int, default=2000)
    ap.add_argument("--no-accuracy", action="store_true",
                    help="skip the packed hard-accuracy pass")
    ap.add_argument("--no-kernel", action="store_true",
                    help="skip fused-kernel timing")
    ap.add_argument("--serve", dest="serve", action="store_true",
                    default=True, help="time the serving engine (default)")
    ap.add_argument("--no-serve", dest="serve", action="store_false")
    ap.add_argument("--serve-backend", default="fused-packed")
    ap.add_argument("--cache-dir", default="results/sweep_cache",
                    help="incremental result cache ('' disables)")
    ap.add_argument("--fresh", action="store_true",
                    help="recompute every point (cache is still refreshed)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--workers", type=int, default=0,
                    help="worker processes for the resilient parallel "
                         "executor (0 = serial in-process, -1 = auto)")
    ap.add_argument("--max-restarts", type=int, default=2,
                    help="per-point failure budget (worker deaths + "
                         "in-worker retries) before the point is "
                         "reported failed")
    ap.add_argument("--artifact-dir", default="",
                    help="checkpoint every computed point's packed "
                         "DWNArtifact here (runtime.checkpoint."
                         "save_artifact; '' disables)")
    ap.add_argument("--no-speculate", action="store_true",
                    help="disable straggler speculative re-dispatch")
    ap.add_argument("--chaos", default="",
                    help="fault injection: kill-after-N | raise-after-N | "
                         "raise-always | stall-I:S (testing only)")
    ap.add_argument("--autodesign", action="store_true",
                    help="pick a design from the accuracy-vs-LUTs Pareto "
                         "front and emit its co-simulation-verified "
                         "Verilog (needs --acc-floor or --lut-budget)")
    ap.add_argument("--acc-floor", type=float, default=None,
                    help="autodesign objective: minimum LUTs subject to "
                         "accuracy >= FLOOR")
    ap.add_argument("--lut-budget", type=int, default=None,
                    help="autodesign objective: maximum accuracy subject "
                         "to total LUTs <= BUDGET")
    ap.add_argument("--autodesign-out", default="results/autodesign",
                    help="directory for the verified RTL + summary JSON")
    ap.add_argument("--cosim-n", type=int, default=256,
                    help="workload test vectors for the RTL verification")
    ap.add_argument("--cosim-backend", default="auto",
                    choices=["auto", "python", "iverilog"])
    args = ap.parse_args(argv)

    settings = SweepSettings(
        n_train=args.n_train, n_test=args.n_test, seed=args.seed,
        train_epochs=args.epochs, accuracy=not args.no_accuracy,
        kernel=not args.no_kernel, serve=args.serve,
        serve_backend=args.serve_backend)
    log = lambda m: print(m, flush=True)                      # noqa: E731
    if args.workers:
        from ..sweep.executor import ExecutorSettings, run_grid_parallel
        ex = ExecutorSettings(
            workers=None if args.workers < 0 else args.workers,
            max_restarts=args.max_restarts,
            speculate=not args.no_speculate,
            artifact_dir=args.artifact_dir or None,
            chaos=args.chaos or None)
        result = run_grid_parallel(args.grid, settings,
                                   cache_dir=args.cache_dir or None,
                                   fresh=args.fresh, executor=ex, log=log)
    else:
        result = run_grid(args.grid, settings,
                          cache_dir=args.cache_dir or None,
                          fresh=args.fresh, log=log,
                          artifact_dir=args.artifact_dir or None)

    print()
    print(result.table())
    exb = result.executor or {}
    if exb:
        print(f"\nexecutor: mode={exb.get('mode')} "
              f"computed={exb.get('computed')} "
              f"cache_hits={exb.get('cache_hits')} "
              f"failed={len(exb.get('failed', []))} "
              f"restarts={exb.get('restarts')} "
              f"stragglers={exb.get('stragglers_redispatched')} "
              f"wall={exb.get('wall_s')}s")
    if exb.get("interrupted"):
        print(f"PREEMPTED: {exb.get('remaining')} point(s) not run; "
              f"completed work is cached — re-run the same command to "
              f"resume with zero recomputed points")
        if args.out:
            result.save(args.out)
            print(f"written partial {args.out}")
        return 0

    shares = [r for r in result.points
              if not r.failed and r.encoder_share is not None]
    if shares:
        # the paper's core finding, reported per grid: how much of the
        # total LUT cost the thermometer encoder is (PEN pays it
        # on-chip; TEN's encoder share is 0 by construction)
        print("\nencoder LUT share (encoder / total):")
        for r in sorted(shares, key=lambda r: -r.encoder_share)[:8]:
            enc = r.luts.get("encoder", 0)
            rest = max(r.total_luts - enc, 1)
            print(f"  {100 * r.encoder_share:5.1f}%  ({enc} of "
                  f"{r.total_luts} LUTs, {enc / rest:.2f}x the rest)  "
                  f"{r.point.label}")

    front_a = result.accuracy_vs_luts_front()
    if front_a:
        print("\nPareto front (accuracy vs LUTs):")
        for r in front_a:
            print(f"  {r.total_luts:>8d} LUT  acc={r.accuracy:.3f}  "
                  f"{r.point.label}")
    front_t = result.throughput_vs_luts_front()
    if front_t:
        print("\nPareto front (serving throughput vs LUTs):")
        for r in front_t:
            print(f"  {r.total_luts:>8d} LUT  {r.serve_throughput:>9.0f} "
                  f"samples/s  {r.point.label}")

    print("\nPaper reference check:")
    failures = check_paper_points(result)
    refs = [r for r in result.points if r.paper_luts is not None]
    if not refs:
        print("  (no paper-referenced points in this grid)")

    if args.plots:
        accs = [r.accuracy for r in result.points
                if r.accuracy is not None]
        if accs:
            print("\naccuracy vs log10(LUTs):  T=TEN  P=PEN")
            lo = math.floor(min(accs) * 20) / 20
            hi = math.ceil(max(accs) * 20) / 20 + 0.05
            ascii_scatter(result.points, x_of=lambda r: r.total_luts,
                          y_of=lambda r: r.accuracy,
                          mark_of=lambda r: r.point.variant[0],
                          y_lo=lo, y_hi=hi, y_step=0.05,
                          x_label="LUTs (log scale)")
        if any(r.serve_throughput is not None for r in result.points):
            thr = [r.serve_throughput for r in result.points
                   if r.serve_throughput is not None]
            step = max(max(thr) / 10, 1.0)
            print("\nserving samples/s vs log10(LUTs):")
            ascii_scatter(result.points, x_of=lambda r: r.total_luts,
                          y_of=lambda r: r.serve_throughput,
                          mark_of=lambda r: r.point.variant[0],
                          y_lo=0.0, y_hi=max(thr) + step, y_step=step,
                          x_label="LUTs (log scale)")

    if args.out:
        result.save(args.out)
        cached = sum(r.cached for r in result.points)
        print(f"\nwritten {args.out}: {len(result.points)} points "
              f"({cached} from cache)")

    if args.autodesign:
        from ..hw.cosim import RTLMismatch
        from ..sweep.autodesign import (AutodesignError, choose_design,
                                        emit_verified)
        print("\nAutodesign:")
        try:
            choice = choose_design(result, acc_floor=args.acc_floor,
                                   lut_budget=args.lut_budget)
            emit_verified(choice, settings, out_dir=args.autodesign_out,
                          n_vectors=args.cosim_n,
                          backend=args.cosim_backend,
                          log=lambda m: print(f"  {m}", flush=True))
        except AutodesignError as e:
            print(f"  autodesign FAILED: {e}")
            return 1
        except RTLMismatch as e:
            print(f"  autodesign RTL VERIFICATION FAILED:\n{e}")
            return 1

    if failures:
        print(f"\npaper-tolerance FAILURES: {failures}")
        return 1
    failed_pts = [r.point.label for r in result.points if r.failed]
    if failed_pts:
        # the grid completed around them (no abort), but a failed point
        # is still a failed run for CI purposes
        print(f"\nFAILED points (restart budget exhausted): {failed_pts}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
