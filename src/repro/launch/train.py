"""Distributed LM training driver (FSDP+TP via pjit on the host mesh).

This is the *runnable* trainer: it composes the model zoo, sharding
rules, optimizer, token pipeline, checkpoint/restart supervisor and
straggler monitor.  On this CPU container it runs reduced configs
end-to-end (tests/examples); on a pod the same driver runs the full
configs (the dry-run proves every full (arch x shape) cell lowers and
compiles on the production meshes).

Usage:
    python -m repro.launch.train --arch qwen3-8b --reduced --steps 50 \
        [--batch 8] [--seq 128] [--ckpt-dir /tmp/ckpt] [--model-parallel 2]
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np

from ..configs import get_arch
from ..data.tokens import TokenStream
from ..models import api
from ..runtime.fault import Supervisor, PreemptionHandler
from ..runtime.straggler import StragglerMonitor
from ..sharding.partition import Partitioner
from .mesh import make_host_mesh


def build(cfg, mesh, *, lr: float, num_micro: int = 1):
    """Returns (init_fn, jitted step, shardings)."""
    tp = mesh.shape["model"]
    part = Partitioner(mesh)
    aparams = api.abstract_params(cfg, tp)
    p_axes = api.param_axes(cfg)
    p_shard = part.tree_shardings(aparams, p_axes)
    step_fn, opt = api.make_train_step(
        cfg, tp, num_micro=num_micro, opt=api.make_optimizer(lr))
    aopt = jax.eval_shape(opt.init, aparams)
    from ..optim.adam import AdamState
    from ..sharding.partition import logical
    o_axes = AdamState(logical(name="opt.step"), p_axes, p_axes)
    o_shard = part.tree_shardings(aopt, o_axes)

    jstep = jax.jit(step_fn,
                    in_shardings=(p_shard, o_shard, None),
                    out_shardings=(p_shard, o_shard, None),
                    donate_argnums=(0, 1))

    def init(seed: int = 0):
        key = jax.random.PRNGKey(seed)
        mod = api.module_for(cfg)
        with mesh:
            params = jax.jit(
                lambda k: mod.init_params(k, cfg, tp),
                out_shardings=p_shard)(key)
            opt_state = jax.jit(opt.init, out_shardings=o_shard)(params)
        return params, opt_state

    return init, jstep, (p_shard, o_shard)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh(args.model_parallel)
    init, jstep, (p_shard, o_shard) = build(cfg, mesh, lr=args.lr)

    stream = TokenStream(cfg.vocab_size, args.seq, args.batch,
                         seed=args.seed)

    def make_batch(raw):
        import jax.numpy as jnp
        b = {"tokens": jnp.asarray(raw["tokens"]),
             "labels": jnp.asarray(raw["labels"])}
        if cfg.family == "encdec":
            b["frames"] = jnp.zeros(
                (args.batch, cfg.enc_frames, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            b["patches"] = jnp.zeros(
                (args.batch, cfg.num_patches, cfg.d_model), jnp.bfloat16)
        return b

    monitor = StragglerMonitor()
    losses = []

    def step_once(handle):
        params, opt_state = handle.state
        stream.restore(handle.extra.get("data", {"step": handle.step}))
        raw = stream.next_batch()
        monitor.step_start()
        with mesh:
            params, opt_state, metrics = jstep(params, opt_state,
                                               make_batch(raw))
        loss = float(metrics["loss"])
        monitor.step_end()
        losses.append(loss)
        handle.state = (params, opt_state)
        handle.step += 1
        handle.extra["data"] = stream.state()
        if handle.step % args.log_every == 0:
            print(f"step {handle.step:5d} loss={loss:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f}", flush=True)
        return handle

    params, opt_state = init(args.seed)
    if args.ckpt_dir:
        sup = Supervisor(args.ckpt_dir, save_every=args.save_every,
                         preemption=PreemptionHandler(),
                         shardings=(p_shard, o_shard))
        handle = sup.run(step_once, init_state=(params, opt_state),
                         total_steps=args.steps)
    else:
        from ..runtime.fault import TrainHandle
        handle = TrainHandle((params, opt_state), 0, {})
        while handle.step < args.steps:
            handle = step_once(handle)

    print(json.dumps({
        "arch": cfg.name, "steps": handle.step,
        "first_loss": losses[0] if losses else None,
        "last_loss": float(np.mean(losses[-5:])) if losses else None,
        "straggler_events": len(monitor.events),
    }))
    return 0


if __name__ == "__main__":
    sys.exit(main())
