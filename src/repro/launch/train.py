"""Training driver: distributed LM training and scan-compiled DWN training.

LM archs (FSDP+TP via pjit on the host mesh): composes the model zoo,
sharding rules, optimizer, token pipeline, checkpoint/restart supervisor
and straggler monitor.  On this CPU container it runs reduced configs
end-to-end (tests/examples); on a pod the same driver runs the full
configs (the dry-run proves every full (arch x shape) cell lowers and
compiles on the production meshes).

DWN archs (family="dwn", e.g. --arch dwn-jsc-md): the scan-compiled
trainer from ``repro.training`` — device-resident epochs with donated
optimizer state; multiple --seeds train as ONE vmapped program
(``train_dwn_batch``), data-parallel over the host mesh when it has
devices.  Prints a JSON summary (per-seed soft accuracy, epoch seconds,
steps/s).

Usage:
    python -m repro.launch.train --arch qwen3-8b --reduced --steps 50 \
        [--batch 8] [--seq 128] [--ckpt-dir /tmp/ckpt] [--model-parallel 2]
    python -m repro.launch.train --arch dwn-jsc-md --reduced \
        --epochs 4 --seeds 0,1,2,3 [--batch 128]
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np

from ..configs import get_arch
from ..data.tokens import TokenStream
from ..models import api
from ..runtime.fault import Supervisor, PreemptionHandler
from ..runtime.straggler import StragglerMonitor
from ..sharding.partition import Partitioner
from .mesh import make_host_mesh


def build(cfg, mesh, *, lr: float, num_micro: int = 1):
    """Returns (init_fn, jitted step, shardings)."""
    tp = mesh.shape["model"]
    part = Partitioner(mesh)
    aparams = api.abstract_params(cfg, tp)
    p_axes = api.param_axes(cfg)
    p_shard = part.tree_shardings(aparams, p_axes)
    step_fn, opt = api.make_train_step(
        cfg, tp, num_micro=num_micro, opt=api.make_optimizer(lr))
    aopt = jax.eval_shape(opt.init, aparams)
    from ..optim.adam import AdamState
    from ..sharding.partition import logical
    o_axes = AdamState(logical(name="opt.step"), p_axes, p_axes)
    o_shard = part.tree_shardings(aopt, o_axes)

    jstep = jax.jit(step_fn,
                    in_shardings=(p_shard, o_shard, None),
                    out_shardings=(p_shard, o_shard, None),
                    donate_argnums=(0, 1))

    def init(seed: int = 0):
        key = jax.random.PRNGKey(seed)
        mod = api.module_for(cfg)
        with mesh:
            params = jax.jit(
                lambda k: mod.init_params(k, cfg, tp),
                out_shardings=p_shard)(key)
            opt_state = jax.jit(opt.init, out_shardings=o_shard)(params)
        return params, opt_state

    return init, jstep, (p_shard, o_shard)


def dwn_train(cfg, args) -> int:
    """Scan-compiled DWN training: one device program per epoch block,
    multi-seed runs vmapped into a single program.

    The arch string resolves to a typed ``repro.dwn.DWNSpec``; the spec's
    workload (or ``--workload``) picks the dataset through the registry
    (``repro.workloads``).  With ``--artifact-dir`` each trained model is
    carried through the full lifecycle (freeze → pack) and checkpointed
    as a ``DWNArtifact``.
    """
    import dataclasses
    import warnings

    from ..dwn import DWNArtifact, resolve_spec
    from ..training import ScanTrainer, train_dwn_batch
    from ..workloads import get_workload

    spec = resolve_spec(args.arch)
    workload = getattr(args, "workload", None)
    if workload is None:
        if spec.workload == "jsc":
            warnings.warn(
                "training a DWN without --workload falls back to the "
                "implicit JSC default; pass --workload jsc (or any "
                "registered workload) explicitly",
                DeprecationWarning, stacklevel=2)
    elif workload != spec.workload:
        # validated override: the preset must exist for that workload
        spec = dataclasses.replace(spec, workload=workload)
    dcfg = spec.dwn_config()
    wl = get_workload(spec.workload)
    n_train = 4000 if args.reduced else 20000
    data = wl.load(n_train, max(1000, n_train // 4), seed=args.seed)
    n_train = data.x_train.shape[0]              # workload caps may clamp
    seeds = [int(s) for s in str(args.seeds).split(",") if s != ""]
    batch = args.batch if args.batch > 0 else 128
    epochs = args.epochs

    rep = {"arch": cfg.name, "engine": "scan", "epochs": epochs,
           "batch": batch, "n_train": n_train, "seeds": seeds,
           "workload": spec.workload,
           "spec": spec.to_dict(), "spec_fingerprint": spec.fingerprint()}
    trained: list[tuple[int, object, object, float]] = []
    if len(seeds) == 1:
        trainer = ScanTrainer(dcfg, data, batch=batch, lr=args.lr,
                              seed=seeds[0])
        res = trainer.train(epochs, eval_every=args.eval_every,
                            verbose=not args.quiet)
        secs = [h["sec"] for h in res.history]
        trained.append((seeds[0], res.params, res.buffers,
                        res.soft_test_acc))
        rep.update({
            "soft_test_acc": [round(res.soft_test_acc, 4)],
            "epoch_s": round(float(np.median(secs)), 3) if secs else None,
            "steps_per_epoch": trainer.steps_per_epoch,
            "steps_per_s": round(
                trainer.steps_per_epoch / float(np.median(secs)), 1)
            if secs else None,
        })
    else:
        out = train_dwn_batch(dcfg, data, epochs=epochs, seeds=seeds,
                              batch=batch, lr=args.lr)
        spe = data.x_train.shape[0] // batch
        trained.extend((s, r.params, r.buffers, r.soft_test_acc)
                       for s, r in zip(seeds, out.results))
        rep.update({
            "soft_test_acc": [round(r.soft_test_acc, 4)
                              for r in out.results],
            "vmapped": True,
            "data_parallel": out.data_parallel,
            "wall_s": round(out.wall_s, 3),
            "epoch_s_per_model": round(
                out.wall_s / max(1, epochs) / len(seeds), 3),
            "steps_per_epoch": spe,
        })
    if args.artifact_dir:
        saved = []
        for seed, params, buffers, acc in trained:
            art = DWNArtifact(spec).adopt(params, buffers,
                                          note="launch.train")
            art.calibration.update(seed=seed, epochs=epochs,
                                   soft_test_acc=round(float(acc), 4))
            path = art.freeze().pack().save(
                f"{args.artifact_dir}/seed{seed}")
            saved.append({"seed": seed, "path": str(path),
                          "stage": art.stage})
        rep["artifacts"] = saved
    print(json.dumps(rep))
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--workload", default=None,
                    help="DWN mode: registered workload to train on "
                         "(jsc | mnist | lm-head | ...; default: the "
                         "spec's own workload — omitting it for a JSC "
                         "spec warns, the implicit default is deprecated)")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=0,
                    help="batch size (default: 8 for LM archs, 128 for "
                         "DWN archs)")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=None,
                    help="learning rate (default: 3e-4 for LM archs, "
                         "1e-3 for DWN archs, the paper protocol)")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--epochs", type=int, default=4,
                    help="DWN mode: training epochs")
    ap.add_argument("--seeds", default="0",
                    help="DWN mode: comma-separated seeds; more than one "
                         "trains all of them as one vmapped program")
    ap.add_argument("--eval-every", type=int, default=1,
                    help="DWN mode: eval cadence (0 = final only, whole "
                         "run as one device program)")
    ap.add_argument("--artifact-dir", default="",
                    help="DWN mode: checkpoint each trained model as a "
                         "DWNArtifact (freeze + pack + save) under "
                         "<dir>/seed<N>")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if cfg.family == "dwn":
        if args.lr is None:
            args.lr = 1e-3       # DWN paper protocol
        return dwn_train(cfg, args)
    if args.lr is None:
        args.lr = 3e-4
    if args.batch <= 0:
        args.batch = 8
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh(args.model_parallel)
    init, jstep, (p_shard, o_shard) = build(cfg, mesh, lr=args.lr)

    stream = TokenStream(cfg.vocab_size, args.seq, args.batch,
                         seed=args.seed)

    def make_batch(raw):
        import jax.numpy as jnp
        b = {"tokens": jnp.asarray(raw["tokens"]),
             "labels": jnp.asarray(raw["labels"])}
        if cfg.family == "encdec":
            b["frames"] = jnp.zeros(
                (args.batch, cfg.enc_frames, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            b["patches"] = jnp.zeros(
                (args.batch, cfg.num_patches, cfg.d_model), jnp.bfloat16)
        return b

    monitor = StragglerMonitor()
    losses = []

    def step_once(handle):
        params, opt_state = handle.state
        stream.restore(handle.extra.get("data", {"step": handle.step}))
        raw = stream.next_batch()
        monitor.step_start()
        with mesh:
            params, opt_state, metrics = jstep(params, opt_state,
                                               make_batch(raw))
        loss = float(metrics["loss"])
        monitor.step_end()
        losses.append(loss)
        handle.state = (params, opt_state)
        handle.step += 1
        handle.extra["data"] = stream.state()
        if handle.step % args.log_every == 0:
            print(f"step {handle.step:5d} loss={loss:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f}", flush=True)
        return handle

    params, opt_state = init(args.seed)
    if args.ckpt_dir:
        sup = Supervisor(args.ckpt_dir, save_every=args.save_every,
                         preemption=PreemptionHandler(),
                         shardings=(p_shard, o_shard))
        handle = sup.run(step_once, init_state=(params, opt_state),
                         total_steps=args.steps)
    else:
        from ..runtime.fault import TrainHandle
        handle = TrainHandle((params, opt_state), 0, {})
        while handle.step < args.steps:
            handle = step_once(handle)

    print(json.dumps({
        "arch": cfg.name, "steps": handle.step,
        "first_loss": losses[0] if losses else None,
        "last_loss": float(np.mean(losses[-5:])) if losses else None,
        "straggler_events": len(monitor.events),
    }))
    return 0


if __name__ == "__main__":
    sys.exit(main())
