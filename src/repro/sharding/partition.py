"""Logical-axis sharding rules -> PartitionSpec / NamedSharding.

Every parameter and activation in the model zoo is annotated with *logical*
axis names ("embed", "q_heads", "ff", ...).  This module maps logical names
onto physical mesh axes per the production parallelism plan (DESIGN.md §7):

    data-parallel + FSDP     ->  ("pod", "data")   (pod only when present)
    tensor parallel          ->  ("model",)
    sequence parallel (SP)   ->  ("data",)  for long-context inference

with a *divisibility fallback*: if a tensor dimension is not divisible by
the product of its assigned mesh axes, that dimension degrades to
replication (and the event is recorded so lowering logs it).  This is what
keeps every (arch x shape x mesh) dry-run cell compilable even for awkward
head counts / vocab sizes.
"""

from __future__ import annotations

import dataclasses
import logging
from typing import Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

logger = logging.getLogger(__name__)

# ---------------------------------------------------------------------------
# Logical rules.  Order matters only for documentation; lookup is by name.
# "fsdp" is resolved to the mesh's data-ish axes at spec-build time.
# ---------------------------------------------------------------------------

#: logical axis -> physical mesh axis-or-axes (None = replicate)
DEFAULT_RULES: dict[str, object] = {
    # parameter axes
    "layers": None,            # scan-stacked layer dim: never sharded
    "vocab": "model",          # embedding/lm-head vocab dim: TP
    "embed": "fsdp",           # d_model dim of params: FSDP (ZeRO-3)
    "embed_r": None,           # d_model dim where FSDP would double-shard
    "q_heads": "model",        # attention query heads: TP
    "kv_heads": "model",       # attention kv heads (padded/repeated): TP
    "head_dim": None,
    "ff": "model",             # MLP hidden: TP
    "experts": None,           # MoE expert dim: FSDP'd via embed dim instead
    "experts_ep": "model",     # MoE EP: experts shard over the model axis
    "ssm_heads": "model",      # mamba2 heads: TP
    "ssm_state": None,
    "conv_dim": "model",
    "lru": "model",            # RG-LRU width: TP
    "norm": None,              # norm scales: replicated
    # activation axes
    "batch": "dp",             # global batch: DP  (pod x data)
    "seq": None,               # sequence: replicated by default
    "seq_sp": "data",          # sequence-parallel shards (long-context)
    "act_embed": None,
    "act_heads": "model",
    "act_ff": "model",
    "act_vocab": "model",
    "kv_seq": None,            # kv-cache seq dim (decode)
    "kv_seq_sp": "data",       # kv-cache seq dim, sequence-sharded
    "dwn_batch": "dp",         # DWN serving batch buckets: data-parallel
}


def _resolve(axis: object, mesh: Mesh) -> tuple[str, ...]:
    """Resolve a rule value to a tuple of physical mesh axis names."""
    if axis is None:
        return ()
    names = mesh.axis_names
    if axis == "fsdp" or axis == "dp":
        # pod composes with data when present.
        return tuple(a for a in ("pod", "data") if a in names)
    if isinstance(axis, (tuple, list)):
        return tuple(a for a in axis if a in names)
    return (axis,) if axis in names else ()


def _axes_size(mesh: Mesh, axes: tuple[str, ...]) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


@dataclasses.dataclass
class FallbackEvent:
    tensor: str
    dim: int
    logical: str
    wanted: tuple
    size: int
    divisor: int


class Partitioner:
    """Builds PartitionSpecs from logical axis annotations for one mesh."""

    def __init__(self, mesh: Mesh, rules: Mapping[str, object] | None = None):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES)
        if rules:
            self.rules.update(rules)
        self.fallbacks: list[FallbackEvent] = []

    def spec(self, logical: Sequence[str | None], shape: Sequence[int] | None = None,
             name: str = "?") -> P:
        """PartitionSpec for a tensor with the given logical axes.

        If ``shape`` is provided, dimensions not divisible by their mesh
        axes degrade to replication (recorded in ``self.fallbacks``).
        """
        parts = []
        used: set[str] = set()
        for d, ax in enumerate(logical):
            if ax is None:
                parts.append(None)
                continue
            phys = _resolve(self.rules.get(ax, None), self.mesh)
            # an axis may appear at most once in a PartitionSpec
            phys = tuple(a for a in phys if a not in used)
            if not phys:
                parts.append(None)
                continue
            if shape is not None:
                div = _axes_size(self.mesh, phys)
                if div > 1 and shape[d] % div != 0:
                    self.fallbacks.append(FallbackEvent(
                        name, d, ax, phys, shape[d], div))
                    logger.info("sharding fallback: %s dim %d (%s=%d) not "
                                "divisible by %s (=%d); replicating",
                                name, d, ax, shape[d], phys, div)
                    parts.append(None)
                    continue
            used.update(phys)
            parts.append(phys if len(phys) > 1 else phys[0])
        return P(*parts)

    def sharding(self, logical: Sequence[str | None],
                 shape: Sequence[int] | None = None,
                 name: str = "?") -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical, shape, name))

    # -- pytree helpers ------------------------------------------------------

    def tree_shardings(self, abstract_params, logical_tree):
        """Map a pytree of abstract arrays + parallel logical-axes pytree
        (tuples of logical names, same treedef) to NamedShardings."""
        def one(leaf, logical):
            path = getattr(logical, "name", "?")
            return self.sharding(tuple(logical), tuple(leaf.shape), path)
        return jax.tree.map(one, abstract_params, logical_tree,
                            is_leaf=lambda x: isinstance(x, LogicalAxes))


class LogicalAxes(tuple):
    """A tuple of logical axis names acting as a pytree *leaf*."""

    name: str = "?"

    def __new__(cls, axes: Sequence[str | None], name: str = "?"):
        obj = super().__new__(cls, axes)
        obj.name = name
        return obj

    def __repr__(self):
        return f"LogicalAxes({tuple(self)}, name={self.name!r})"


def logical(*axes: str | None, name: str = "?") -> LogicalAxes:
    return LogicalAxes(axes, name)
