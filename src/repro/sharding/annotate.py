"""Sharding hints inside model code.

``hint(x, spec)`` applies ``with_sharding_constraint`` when an ambient
mesh is available (pjit lowering under ``with mesh:``) and is a no-op
otherwise (single-device tests, reduced configs).

Why this exists (found via the roofline, §Perf iteration 1): with only
parameter in_shardings, SPMD propagation chose *weight-stationary*
activation layouts — d_model sharded like the FSDP weight dim and the
token batch replicated — so every chip computed attention for the full
batch (16x attention FLOPs, and 16x the flash workspace).  Constraining
activations to (batch over DP axes, heads/ff over "model") restores the
Megatron/FSDP execution: weights are gathered per layer, activations stay
batch-sharded.
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P


def _ambient_axis_names() -> tuple[str, ...]:
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return tuple(m.axis_names)
        am = mesh_lib.get_abstract_mesh()
        if am is not None and am.axis_names:
            return tuple(am.axis_names)
    except Exception:
        pass
    return ()


def dp_axes() -> tuple[str, ...]:
    """The data-parallel mesh axes present in the ambient mesh."""
    names = _ambient_axis_names()
    return tuple(a for a in ("pod", "data") if a in names)


def hint(x, *spec_dims):
    """Constrain ``x`` to PartitionSpec(*spec_dims) if a mesh is ambient.

    ``"dp"`` in spec_dims resolves to the ambient ("pod","data") axes;
    any axis name missing from the mesh degrades that dim to None.
    """
    names = _ambient_axis_names()
    if not names:
        return x
    dims = []
    for d in spec_dims:
        if d == "dp":
            dp = dp_axes()
            dims.append(dp if len(dp) > 1 else (dp[0] if dp else None))
        elif isinstance(d, str) and d not in names:
            dims.append(None)
        else:
            dims.append(d)
    try:
        return jax.lax.with_sharding_constraint(x, P(*dims))
    except (ValueError, RuntimeError, TypeError):
        return x


def hint_act(x):
    """(B, S, D) residual-stream activations: batch over DP."""
    return hint(x, "dp", *([None] * (x.ndim - 1)))


def hint_heads(x, axis: int = 2):
    """(B, S, H, hd)-style tensors: batch over DP, heads over model."""
    dims: list = ["dp"] + [None] * (x.ndim - 1)
    dims[axis] = "model"
    return hint(x, *dims)
