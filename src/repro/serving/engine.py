"""ServingEngine: one submit/drain API over both served families.

DWN archs (``family == "dwn"``) serve batched classification of their
spec's workload (``repro.workloads``: JSC, MNIST, ...) through a
pluggable datapath backend (``serving.backends``), microbatched into
power-of-two buckets (``serving.scheduler``), and sharded data-parallel
across the host mesh with ``shard_map`` when a bucket divides the device
count.  Every non-oracle backend is cross-checked bit-exactly against the
``apply_hard`` float oracle at startup — the engine refuses to construct a
broken datapath.

LM archs serve the existing prefill + token-by-token decode loop (KV /
SSM / LRU caches) one request per step, through the same queue and the
same per-request queue/compute latency accounting.  With ``dwn_head=``
an LM engine *also* serves a packed DWN classification head on its own
backbone's pooled features (``classify`` requests), so one process
serves LM decode and DWN classification side by side.

Two serving modes share the datapath and its compile/autotune caches:

* **sync facade** (``submit`` + ``drain``): closed-loop, admission-order
  microbatching — unchanged semantics, bit-exact with the async path;
* **continuous batching** (``serve()`` / ``submit_async``): a dedicated
  scheduler thread keeps steps in flight while requests stream in,
  results complete out of order via per-request futures, deadlines are
  enforced by SLO-aware admission control, and a bounded queue exerts
  backpressure (``serving.continuous``).

Usage (sync):
    engine = ServingEngine("dwn-jsc-sm", max_bucket=256)
    for xb in request_stream:
        engine.submit(xb)
    results = engine.drain()
    print(engine.report())

Usage (continuous):
    with engine.serve(slo=SLOConfig(max_queue_samples=2048)):
        futs = [engine.submit_async(xb, deadline_ms=50).future
                for xb in request_stream]
        results = [f.result() for f in futs]   # ServeResult: ok or shed
"""

from __future__ import annotations

import contextlib
import os
import time
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map

from ..configs import get_arch
from ..configs.base import ArchConfig
from ..models import api
from ..runtime.straggler import StragglerMonitor
from ..sharding.partition import Partitioner
from ..launch.mesh import make_data_mesh, make_host_mesh
from .backends import (AutoSelector, BoundBackend, DWNModelBundle,
                       StepTimeEstimator, available_backends,
                       estimator_from_calibration, get_backend,
                       time_backend_step, verify_backends)
from .continuous import AsyncRequest, ContinuousScheduler, SLOConfig
from .scheduler import MicrobatchScheduler, Request, latency_stats


class ServingEngine:
    """Unified serving engine; family dispatch happens at construction.

    Args:
      arch: what to serve — an arch name or ``ArchConfig`` (``family``
        selects the path), a ``repro.dwn.DWNSpec`` (the engine builds
        the artifact lifecycle itself), or a ``repro.dwn.DWNArtifact``
        (served as-is; trained/frozen state is reused, missing stages
        are completed in place).
      backend: DWN datapath backend name.  ``None`` resolves from the
        spec's validated ``datapath`` field (legacy archs bridge through
        ``DWNSpec.from_arch``, which keeps the old fused-packed
        fallback).  ``"auto"`` calibrates every bit-exact backend per
        batch bucket at startup and serves each bucket on the fastest
        (see ``backends.AutoSelector``); explicit names remain the
        override.
      max_bucket / min_bucket: the power-of-two batch-bucket ladder.
      data_parallel: shard DWN buckets over the ("data",) host mesh with
        ``shard_map`` (buckets not divisible by the device count fall back
        to single-device execution for that bucket).
      verify: run the startup bit-exactness cross-check of every
        registered non-oracle backend against the float oracle.
      autotune: run the fused-kernel autotuner over the bucket ladder at
        startup (``backends.autotune_model``): each bucket serves the
        fastest (variant, rows-per-step) fused config, cache-hit from
        the persistent config cache (docs/autotune.md) or timed once on
        miss.  ``None`` (default) resolves to True exactly when
        ``backend == "auto"``; ``REPRO_AUTOTUNE=0`` force-disables.
      reduced: LM archs: serve the tiny same-family variant.  DWN archs:
        kept for CLI symmetry (the model is never shrunk — the datapath
        is the thing being served; callers shrink the request volume).
      n_train: training rows (of the spec's workload) used to fit
        thermometer thresholds.
      prompt_len / gen / model_parallel: LM serving shape knobs.
      dwn_head: LM engines only — attach a packed DWN classification
        head on the backbone's pooled features (a ``DWNArtifact``, a
        checkpoint path, or a spec-preset name like ``"dwn-lm-head"``).
        ``classify`` requests then route through the same queue as LM
        decode: one engine, both request kinds, one process.
    """

    def __init__(self, arch: str | ArchConfig, *,
                 backend: str | None = None,
                 max_bucket: int = 256, min_bucket: int = 8,
                 data_parallel: bool = True, verify: bool = True,
                 autotune: bool | None = None,
                 reduced: bool = False, n_train: int = 2000,
                 seed: int = 0, prompt_len: int = 32, gen: int = 16,
                 model_parallel: int = 1, dwn_head=None):
        from ..dwn import DWNArtifact, DWNSpec, has_spec, get_spec
        self.artifact: "DWNArtifact | None" = None
        self.spec: "DWNSpec | None" = None
        if isinstance(arch, DWNArtifact):
            self.artifact, self.spec = arch, arch.spec
            cfg = self.spec.arch_config()
        elif isinstance(arch, DWNSpec):
            self.spec = arch
            cfg = arch.arch_config()
        else:
            cfg = get_arch(arch) if isinstance(arch, str) else arch
            if cfg.family == "dwn":
                # registered spec presets are the blessed route for the
                # old --arch strings; raw ArchConfigs bridge via from_arch
                self.spec = (get_spec(cfg.name) if has_spec(cfg.name)
                             else DWNSpec.from_arch(cfg))
        self.cfg = cfg
        self.seed = seed
        self.family = "dwn" if cfg.family == "dwn" else "lm"
        self.scheduler = MicrobatchScheduler(
            max_bucket=max_bucket, min_bucket=min(min_bucket, max_bucket))
        self.bit_exact: dict[str, bool] = {}
        self.tuned_configs: dict = {}
        self._autotune_arg = autotune
        self._drain_wall = 0.0
        self._lm_stats: list[tuple[float, float]] = []
        #: anomalous step times surface as counters in report(); fed by
        #: both the sync drain loop and the continuous-batching loop
        self.straggler = StragglerMonitor()
        self._cont: ContinuousScheduler | None = None
        self.estimator: StepTimeEstimator | None = None
        #: slim async requests from finished serve() sessions + the last
        #: session's loop counters (report() merges the live session in)
        self._async_done: list[AsyncRequest] = []
        self._async_counters: dict = {}
        self.head_artifact = None
        self.head_bit_exact: bool | None = None
        self._head_served = 0
        if self.family == "dwn":
            assert dwn_head is None, \
                "dwn_head attaches to an LM engine (the head rides the " \
                "backbone); DWN archs already serve classification"
            self._init_dwn(cfg, backend, n_train, data_parallel, verify)
        else:
            if reduced:
                self.cfg = cfg = cfg.reduced()
            self._init_lm(cfg, prompt_len, gen, model_parallel)
            if dwn_head is not None:
                self._init_dwn_head(dwn_head, verify)

    # ------------------------------------------------------------------
    # DWN classification path
    # ------------------------------------------------------------------

    def _init_dwn(self, cfg: ArchConfig, backend: str | None,
                  n_train: int, data_parallel: bool, verify: bool):
        from ..dwn import DWNArtifact
        from ..workloads import load_workload
        self.data = load_workload(self.spec.workload, n_train,
                                  max(self.scheduler.max_bucket, 512),
                                  seed=self.seed)
        # one construction path: the artifact lifecycle.  A caller-built
        # artifact is served as-is; a spec-only engine fits thresholds on
        # its own data split (exactly the pre-spec build_dwn_model init).
        art = self.artifact if self.artifact is not None \
            else DWNArtifact(self.spec)
        if art.stage == "spec":
            art.fit(self.data.x_train, seed=self.seed)
        if art.stage == "trained":
            art.freeze()
        art.pack()
        self.artifact = art
        self.model: DWNModelBundle = art.serving_model(cfg=cfg)
        self.mesh = make_data_mesh()
        self.n_data = self.mesh.shape["data"]
        self._part = Partitioner(self.mesh)
        self.data_parallel = bool(data_parallel) and self.n_data > 1
        wrap = self._shard_wrap if self.data_parallel else None
        self.backends = {name: BoundBackend(get_backend(name), self.model,
                                            wrap=wrap)
                         for name in available_backends()}
        if backend is None:
            # the spec's datapath is validated at construction, so no
            # arch-name-suffix parsing or registry fallback is needed
            backend = self.spec.datapath
        self.auto: AutoSelector | None = None
        probe = self.data.x_test[:self.scheduler.max_bucket]
        do_tune = self._autotune_arg
        if do_tune is None:
            do_tune = backend == "auto"
        if os.environ.get("REPRO_AUTOTUNE") == "0":
            do_tune = False
        if do_tune:
            # tune BEFORE anything compiles: BoundBackend jits one entry
            # per bucket and each trace binds the tuned config it sees.
            # The startup verification below then cross-checks the tuned
            # variant, not the default one.
            from .backends import autotune_model
            self.tuned_configs = autotune_model(
                self.model, self.scheduler.buckets, probe,
                spec_fingerprint=self.spec.fingerprint())
        if verify or backend == "auto":
            # probe at the largest bucket: the multi-block grid path that
            # serving actually uses is the one cross-checked, and the
            # probe's compile is the one the serve loop reuses.  Auto
            # selection always verifies: it only picks among bit-exact
            # datapaths.
            self.bit_exact = verify_backends(
                self.model, list(self.backends.values()), probe)
        if backend == "auto":
            # calibrate the whole bucket ladder at startup so no timed
            # request ever pays calibration (compiles + timing probes)
            # inside its compute window; the per-bucket compiles are the
            # same ones a ragged stream would pay lazily anyway
            self.auto = AutoSelector(self.backends, self.bit_exact)
            for bucket in self.scheduler.buckets:
                self.auto.calibrate(jnp.asarray(probe[:bucket]))
            self.backend = self.backends[
                self.auto.choice[self.scheduler.max_bucket]]
        else:
            self.backend = self.backends[backend]
        # survive use_backend() round-trips: pinning a backend then
        # returning to "auto" restores this calibrated selector instead
        # of re-timing the ladder
        self._auto_saved = self.auto

    def _shard_wrap(self, fn, bucket: int):
        """shard_map a backend step over the ("data",) mesh for one bucket.

        Buckets that don't divide the device count run unsharded (the
        ladder is powers of two, so with a power-of-two device count only
        buckets below the device count fall back).
        """
        if bucket % self.n_data != 0:
            return fn
        spec_x = self._part.spec(("dwn_batch", None), name="dwn.serve.x")
        spec_counts = self._part.spec(("dwn_batch", None),
                                      name="dwn.serve.counts")
        spec_pred = self._part.spec(("dwn_batch",), name="dwn.serve.pred")
        return shard_map(fn, mesh=self.mesh, in_specs=(spec_x,),
                         out_specs=(spec_counts, spec_pred),
                         check_rep=False)

    def use_backend(self, name: str) -> None:
        """Switch the active DWN datapath (compile caches are kept).

        ``"auto"`` switches to per-bucket auto-selection among the
        bit-exact backends (requires the startup verification to have
        run); any registered backend name pins that datapath.
        """
        assert self.family == "dwn"
        if name == "auto":
            if self.auto is None:
                assert self.bit_exact, "auto-select needs verify=True"
                saved = getattr(self, "_auto_saved", None)
                self.auto = saved if saved is not None \
                    else AutoSelector(self.backends, self.bit_exact)
                self._auto_saved = self.auto
            return
        self.auto = None
        self.backend = self.backends[name]

    def warmup(self, size: int | None = None) -> None:
        """Compile + execute the active backend's bucket outside timing.

        Warms the bucket that ``size``-sample requests land in (default:
        the largest bucket) without touching the request queue or the
        latency accounting, so a serve loop's first timed request measures
        steady-state serving rather than the one-time XLA trace.  Ragged
        streams may still hit other ladder buckets inside timing — bounded
        by one compile per bucket.
        """
        assert self.family == "dwn"
        if size is None:
            bucket = self.scheduler.max_bucket
        else:
            bucket = self.scheduler.bucket_for(
                min(size, self.scheduler.max_bucket))
        self._dwn_step(np.asarray(self.data.x_test[:bucket]))

    def _dwn_step(self, x: np.ndarray):
        xd = jnp.asarray(x)
        backend = (self.auto.backend_for(xd) if self.auto is not None
                   else self.backend)
        counts, pred = backend.step_for(x.shape[0])(xd)
        pred.block_until_ready()             # compute timing is this call
        return np.asarray(counts), np.asarray(pred)

    # ------------------------------------------------------------------
    # LM prefill/decode path
    # ------------------------------------------------------------------

    def _init_lm(self, cfg: ArchConfig, prompt_len: int, gen: int,
                 model_parallel: int):
        self.prompt_len, self.gen = prompt_len, gen
        self.mesh = make_host_mesh(model_parallel)
        tp = self.mesh.shape["model"]
        part = Partitioner(self.mesh)
        aparams = api.abstract_params(cfg, tp)
        p_shard = part.tree_shardings(aparams, api.param_axes(cfg))
        prefill = api.make_prefill(cfg, tp, cache_len=prompt_len + gen)
        decode = api.make_decode_step(cfg, tp)
        self._jprefill = jax.jit(prefill, in_shardings=(p_shard, None))
        self._jdecode = jax.jit(decode, in_shardings=(p_shard, None, None),
                                donate_argnums=(1,))
        self.tp = tp
        mod = api.module_for(cfg)
        key = jax.random.PRNGKey(self.seed)
        with self.mesh:
            self.params = jax.jit(lambda k: mod.init_params(k, cfg, tp),
                                  out_shardings=p_shard)(key)

    # ------------------------------------------------------------------
    # DWN head on the LM backbone (dwn_head=)
    # ------------------------------------------------------------------

    def _init_dwn_head(self, head, verify: bool) -> None:
        """Attach a packed DWN classification head on this engine's own
        backbone: pooled-feature extraction (``workloads.lm_head.
        pool_features`` — the same pooling the head trained on) feeds
        ``apply_hard_packed`` of the head artifact.  ``classify``
        requests then serve through the same queue/drain as LM decode.
        """
        from pathlib import Path

        from ..core.model import apply_hard, apply_hard_packed
        from ..core.classifier import predict
        from ..dwn import DWNArtifact, resolve_spec
        from ..workloads.lm_head import pool_features
        if isinstance(head, DWNArtifact):
            art = head
        elif Path(str(head)).exists():
            from ..runtime.checkpoint import load_artifact
            art = load_artifact(head)
        else:
            art = DWNArtifact(resolve_spec(head))
        if art.stage == "spec":
            from ..workloads import load_workload
            data = load_workload(art.spec.workload, 512, 64, seed=self.seed)
            art.fit(data.x_train, seed=self.seed)
        if art.stage == "trained":
            art.freeze()
        art.pack()
        self.head_artifact = art
        cfg, tp = self.cfg, self.tp
        mod = api.module_for(cfg)
        frozen = art.frozen

        @jax.jit
        def head_step(params, toks):
            logits, _, _ = mod.forward(params, cfg, {"tokens": toks}, tp=tp)
            feats = pool_features(logits)
            counts = apply_hard_packed(frozen, feats)
            return feats, counts, predict(counts)

        self._jhead = head_step
        if verify:
            # startup cross-check: the packed head must agree bit-exactly
            # with the float oracle on this backbone's real features
            rng = np.random.default_rng(self.seed)
            toks = jnp.asarray(rng.integers(
                0, cfg.vocab_size, (8, self.prompt_len)).astype(np.int32))
            with self.mesh:
                feats, counts, _ = self._jhead(self.params, toks)
            oracle = np.asarray(apply_hard(frozen, feats))
            self.head_bit_exact = bool(
                np.array_equal(np.asarray(counts), oracle))
            assert self.head_bit_exact, \
                "packed DWN head disagrees with the apply_hard oracle"

    def _head_step(self, batch: dict) -> dict:
        """Serve one classify request: tokens -> backbone features ->
        packed DWN head (counts + predictions)."""
        assert self.head_artifact is not None, \
            "no DWN head attached: construct with dwn_head=..."
        toks = jnp.asarray(batch["tokens"])
        with self.mesh:
            feats, counts, pred = self._jhead(self.params, toks)
        pred.block_until_ready()
        self._head_served += int(toks.shape[0])
        return {"counts": np.asarray(counts), "pred": np.asarray(pred),
                "features": np.asarray(feats)}

    def _lm_or_head_step(self, batch: dict) -> dict:
        if isinstance(batch, dict) and batch.get("classify"):
            return self._head_step(batch)
        return self._lm_step(batch)

    def _lm_step(self, batch: dict) -> dict:
        cfg = self.cfg
        t0 = time.perf_counter()
        with self.mesh:
            logits, cache = self._jprefill(self.params, batch)
        logits.block_until_ready()
        t_prefill = time.perf_counter() - t0
        generated = []
        nxt = jnp.argmax(logits[:, :cfg.vocab_size],
                         -1)[:, None].astype(jnp.int32)
        t0 = time.perf_counter()
        for _ in range(self.gen):
            generated.append(np.asarray(nxt))
            with self.mesh:
                logits, cache = self._jdecode(self.params, cache,
                                              {"tokens": nxt})
            nxt = jnp.argmax(logits[:, :cfg.vocab_size],
                             -1)[:, None].astype(jnp.int32)
        t_decode = time.perf_counter() - t0
        tokens = np.concatenate(generated, 1)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        return {"tokens": tokens, "prefill_s": t_prefill,
                "decode_s_per_tok": t_decode / max(self.gen, 1)}

    # ------------------------------------------------------------------
    # unified submit / drain API
    # ------------------------------------------------------------------

    def make_request(self, size: int, seed: int = 0, *,
                     classify: bool = False) -> Any:
        """Synthesize one request payload.

        Args:
          size: samples (DWN: feature rows drawn from the test split) or
            sequences (LM: random token prompts of ``prompt_len``).
          seed: draw seed, so streams are reproducible.
          classify: LM engines with a ``dwn_head``: mark the request for
            the DWN head (tokens -> pooled features -> packed classify)
            instead of prefill/decode.

        Returns the payload in the shape :meth:`submit` expects.
        """
        rng = np.random.default_rng(seed)
        if self.family == "dwn":
            sel = rng.integers(0, self.data.x_test.shape[0], size)
            return self.data.x_test[sel]
        if classify:
            assert self.head_artifact is not None, \
                "classify requests need dwn_head= at construction"
            return {"tokens": rng.integers(
                0, self.cfg.vocab_size,
                (size, self.prompt_len)).astype(np.int32),
                "classify": True}
        key = jax.random.PRNGKey(seed)
        batch = {"tokens": np.asarray(jax.random.randint(
            key, (size, self.prompt_len), 0, self.cfg.vocab_size))}
        if self.cfg.family == "encdec":
            batch["frames"] = jax.random.normal(
                key, (size, self.cfg.enc_frames, self.cfg.d_model),
                jnp.bfloat16) * 0.1
        if self.cfg.family == "vlm":
            batch["patches"] = jax.random.normal(
                key, (size, self.cfg.num_patches, self.cfg.d_model),
                jnp.bfloat16) * 0.02
        return batch

    def submit(self, payload: Any) -> Request:
        """Enqueue one request (admission order is service order).

        Args:
          payload: (size, F) feature array (DWN) or an LM batch dict with
            a (size, prompt_len) ``tokens`` entry.

        Returns the queued :class:`Request` (latency fields filled in by
        the drain that serves it; ``queue_ms``/``compute_ms`` are
        milliseconds).
        """
        if self.family == "dwn":
            payload = np.asarray(payload)
            return self.scheduler.submit(payload, payload.shape[0])
        size = int(np.asarray(payload["tokens"]).shape[0])
        return self.scheduler.submit(payload, size)

    def drain(self) -> list[Request]:
        """Serve every queued request; blocks until all results ready."""
        t0 = time.perf_counter()
        if self.family == "dwn":
            done = self.scheduler.drain_batched(self._monitored_step)
        else:
            done = self.scheduler.drain_serial(self._lm_or_head_step)
            self._lm_stats.extend((r.result["prefill_s"],
                                   r.result["decode_s_per_tok"])
                                  for r in done
                                  if "prefill_s" in r.result)
        self._drain_wall += time.perf_counter() - t0
        return done

    def _monitored_step(self, x: np.ndarray):
        """The DWN step with its wall time fed to the straggler monitor
        (the sync drain loop's half of the satellite wiring; the
        continuous loop reports through the same monitor)."""
        t0 = time.perf_counter()
        out = self._dwn_step(x)
        self.straggler.report(time.perf_counter() - t0)
        return out

    # ------------------------------------------------------------------
    # continuous-batching async API (DWN only)
    # ------------------------------------------------------------------

    def start_serving(self, *, slo: SLOConfig | None = None) -> None:
        """Start the continuous-batching loop (a dedicated thread).

        Requests then stream in through :meth:`submit_async` and complete
        out of order via their futures; batch formation happens at step
        boundaries over the same bucket ladder (compile + autotune caches
        shared with the sync facade).  Admission control's step-time
        estimates seed from the ``AutoSelector`` calibration when
        ``backend="auto"``, else from one probe of the active backend at
        ``max_bucket``; every step refines them online.
        """
        assert self.family == "dwn", "continuous batching is the DWN path"
        assert self._cont is None, "serving loop already running"
        if self.estimator is None:
            if self.auto is not None:
                self.estimator = estimator_from_calibration(self.auto)
            else:
                self.estimator = StepTimeEstimator()
                probe = jnp.asarray(
                    self.data.x_test[:self.scheduler.max_bucket])
                self.estimator.seed(
                    self.scheduler.max_bucket,
                    time_backend_step(self.backend, probe, iters=2))
        self._cont = ContinuousScheduler(
            self._dwn_step, max_bucket=self.scheduler.max_bucket,
            min_bucket=self.scheduler.min_bucket, slo=slo,
            estimator=self.estimator, monitor=self.straggler)
        self._cont.start()

    def stop_serving(self, *, drain: bool = True) -> None:
        """Stop the loop; ``drain=True`` serves the queue first.  Loop
        counters survive in :meth:`report` (sessions accumulate)."""
        assert self._cont is not None, "serving loop not running"
        self._cont.stop(drain=drain)
        self._async_done.extend(self._cont.completed)
        self._async_counters = self._cont.counters()
        self._cont = None

    @contextlib.contextmanager
    def serve(self, *, slo: SLOConfig | None = None):
        """Context manager over one continuous-batching session::

            with engine.serve(slo=SLOConfig(deadline_default_ms=50)):
                req = engine.submit_async(xb, deadline_ms=20)
                res = req.future.result()      # ServeResult
        """
        self.start_serving(slo=slo)
        try:
            yield self
        finally:
            self.stop_serving()

    def submit_async(self, payload: Any, *,
                     deadline_ms: float | None = None, priority: int = 0,
                     timeout: float | None = None) -> AsyncRequest:
        """Admit one request into the continuous-batching loop.

        Requires :meth:`start_serving` / :meth:`serve`.  Returns the
        :class:`AsyncRequest`; its ``future`` resolves to a
        ``ServeResult`` — ``ok`` with ``value == (counts, pred)``, or
        typed shed when the deadline was unmeetable (admission), expired
        in queue, or missed at completion.  Raises ``QueueFull`` after
        ``timeout`` when backpressure applies.
        """
        assert self._cont is not None, \
            "submit_async needs the serving loop: use engine.serve()"
        payload = np.asarray(payload)
        return self._cont.submit(payload, payload.shape[0],
                                 deadline_ms=deadline_ms,
                                 priority=priority, timeout=timeout)

    # ------------------------------------------------------------------
    # reporting
    # ------------------------------------------------------------------

    def compile_counts(self) -> dict[str, dict[int, int]]:
        """Per-backend {bucket: XLA traces} (DWN; empty for LM)."""
        if self.family != "dwn":
            return {}
        return {name: dict(b.compiles)
                for name, b in self.backends.items() if b.compiles}

    def report(self) -> dict:
        """JSON-able serving report over everything served so far.

        Units: ``throughput_samples_per_s`` is samples (DWN) or sequences
        (LM) per wall-clock second (sync drains + async session wall);
        ``latency.{queue,compute,total}_ms`` are per-request millisecond
        percentiles (p50/p99/p999) over *served* requests — shed requests
        are excluded from latency and counted in ``shed``;
        ``queue_depth`` / ``shed`` / ``straggler`` cover both serving
        modes; LM ``prefill_s`` / ``decode_s_per_tok`` are seconds.
        """
        async_all = list(self._async_done)
        async_counters = dict(self._async_counters)
        if self._cont is not None:
            async_all += list(self._cont.completed)
            async_counters = self._cont.counters()
        async_ok = [r for r in async_all if r.shed is None]
        shed = [r for r in async_all if r.shed is not None]
        reqs: Sequence[Request] = (list(self.scheduler.completed)
                                   + async_ok)
        served = sum(r.size for r in reqs)
        wall = self._drain_wall + async_counters.get("session_wall_s", 0.0)
        shed_by: dict[str, int] = {}
        for r in shed:
            shed_by[r.shed] = shed_by.get(r.shed, 0) + 1
        finished = len(reqs) + len(shed)
        out = {
            "arch": self.cfg.name,
            "family": self.cfg.family,
            "requests": len(reqs),
            "served": served,
            "throughput_samples_per_s":
                round(served / wall, 1) if wall else 0.0,
            "latency": latency_stats(list(reqs)),
            "queue_depth": {
                "pending": self.scheduler.pending
                + (self._cont.pending if self._cont is not None else 0),
                "max_requests": max(
                    self.scheduler.max_pending,
                    async_counters.get("queue_depth_max_requests", 0)),
            },
            "shed": {
                "requests": len(shed),
                "rate": round(len(shed) / finished, 4) if finished
                else 0.0,
                "by_reason": shed_by,
            },
            "straggler": {
                "window": len(self.straggler.times),
                "events": len(self.straggler.events),
                "last_z": round(self.straggler.events[-1].z, 2)
                if self.straggler.events else None,
            },
        }
        if async_counters:
            out["async"] = async_counters
            if self.estimator is not None:
                out["async"]["step_estimates_ms"] = \
                    self.estimator.snapshot()
        if self.family == "dwn":
            out.update({
                "mode": "dwn-classify",
                "datapath": ("auto" if self.auto is not None
                             else self.backend.name),
                "backends": available_backends(),
                "bit_exact_vs_oracle": self.bit_exact,
                "buckets": list(self.scheduler.buckets),
                "compiles": self.compile_counts(),
                "data_parallel": self.data_parallel,
                "devices": self.n_data,
                "luts": self.cfg.dwn_luts,
                "bits_per_feature": self.cfg.dwn_bits,
                "spec": self.spec.to_dict(),
                "spec_fingerprint": self.spec.fingerprint(),
                "artifact_stage": self.artifact.stage,
            })
            if self.tuned_configs:
                out["autotune"] = {int(b): cfg.to_dict()
                                   for b, cfg in self.tuned_configs.items()}
            if self.auto is not None:
                out["auto"] = {
                    "choice": dict(self.auto.choice),
                    "configs": {b: (cfg.to_dict() if cfg else None)
                                for b, cfg in self.auto.configs.items()},
                    "timings_ms": {b: {n: round(t * 1e3, 3)
                                       for n, t in times.items()}
                                   for b, times in
                                   self.auto.timings.items()},
                }
        else:
            out.update({
                "mode": "lm-generate",
                "prompt_len": self.prompt_len,
                "generated": self.gen,
                "model_parallel": self.tp,
            })
            if self._lm_stats:
                out["prefill_s"] = round(
                    float(np.mean([s[0] for s in self._lm_stats])), 3)
                out["decode_s_per_tok"] = round(
                    float(np.mean([s[1] for s in self._lm_stats])), 4)
            if self.head_artifact is not None:
                out["dwn_head"] = {
                    "spec": self.head_artifact.spec.to_dict(),
                    "spec_fingerprint":
                        self.head_artifact.spec.fingerprint(),
                    "artifact_stage": self.head_artifact.stage,
                    "bit_exact_vs_oracle": self.head_bit_exact,
                    "served": self._head_served,
                }
        return out


__all__ = ["ServingEngine"]
