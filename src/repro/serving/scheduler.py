"""Request queue with dynamic microbatching into power-of-two buckets.

Serving traffic is ragged: requests carry anywhere from one sample to
thousands.  Compiling one XLA executable per observed batch size would
recompile constantly, and padding everything to one giant batch wastes
compute on small requests.  The middle ground implemented here:

* requests are drained strictly in **admission order** (FIFO — no
  reordering, so latency is predictable and starvation impossible);
* consecutive requests are **coalesced** into a microbatch as long as the
  combined sample count fits the largest bucket;
* the microbatch is **padded up to the smallest power-of-two bucket** that
  holds it, so the set of shapes XLA ever sees is the fixed bucket ladder
  ``{min_bucket, 2*min_bucket, ..., max_bucket}`` — bounding JIT
  recompiles to at most one per (backend, bucket);
* oversized requests (> max_bucket) are split into max_bucket chunks.

Every request records wall-clock (``time.perf_counter`` — monotonic, the
correct timer for sub-ms latencies) for **queue** time (submit -> step
launch) and **compute** time (step launch -> results ready) separately,
so a serving report can distinguish "waiting behind other traffic" from
"the datapath is slow".

The scheduler is model-agnostic: ``drain_batched`` is for array payloads
that coalesce along a batch axis (DWN feature batches); ``drain_serial``
is for opaque payloads served one request per step (LM prefill/decode).
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable

import numpy as np


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (bucket math lives in this module)."""
    p = 1
    while p < n:
        p *= 2
    return p


def power_of_two_buckets(min_bucket: int, max_bucket: int) -> tuple[int, ...]:
    """The bucket ladder: powers of two in [min_bucket, max_bucket]."""
    assert min_bucket > 0 and max_bucket >= min_bucket
    assert min_bucket & (min_bucket - 1) == 0, min_bucket
    assert max_bucket & (max_bucket - 1) == 0, max_bucket
    out, b = [], min_bucket
    while b <= max_bucket:
        out.append(b)
        b *= 2
    return tuple(out)


def bucket_for(n: int, buckets: tuple[int, ...]) -> int:
    """Smallest ladder bucket holding n samples (n <= buckets[-1])."""
    assert 0 < n <= buckets[-1], (n, buckets)
    for b in buckets:
        if n <= b:
            return b
    raise AssertionError  # unreachable: ladder ends at max_bucket


@dataclasses.dataclass
class Request:
    """One serving request plus its latency accounting."""

    rid: int
    payload: Any                       # (size, F) features | LM batch dict
    size: int                          # samples (DWN) / sequences (LM)
    t_submit: float
    t_start: float = 0.0               # first step launch
    t_done: float = 0.0                # last result ready
    buckets: tuple = ()                # bucket(s) this request ran in
    result: Any = None

    @property
    def queue_ms(self) -> float:
        return (self.t_start - self.t_submit) * 1e3

    @property
    def compute_ms(self) -> float:
        return (self.t_done - self.t_start) * 1e3

    @property
    def total_ms(self) -> float:
        return (self.t_done - self.t_submit) * 1e3


class MicrobatchScheduler:
    """Admission-order FIFO with power-of-two batch bucketing.

    ``timer`` is injectable (default ``time.perf_counter``) so latency
    attribution — queue time from *original submit* even across oversize
    chunk splits — is testable with a deterministic clock.
    """

    def __init__(self, *, max_bucket: int = 256, min_bucket: int = 8,
                 timer: Callable[[], float] = time.perf_counter):
        self.buckets = power_of_two_buckets(min_bucket, max_bucket)
        self.min_bucket = min_bucket
        self.max_bucket = max_bucket
        self._timer = timer
        self._queue: deque[Request] = deque()
        self._next_rid = 0
        #: high-water mark of queued requests (serving report observable)
        self.max_pending = 0
        #: accounting history: slim copies (payload/result dropped) so a
        #: long-lived server's latency stats don't pin every array served.
        #: Full requests — payloads and results included — are returned to
        #: the caller by the drain call that served them.
        self.completed: list[Request] = []

    def _record(self, done: list[Request]) -> None:
        self.completed.extend(
            dataclasses.replace(r, payload=None, result=None) for r in done)

    # -- admission ----------------------------------------------------------

    def submit(self, payload: Any, size: int | None = None) -> Request:
        if size is None:
            size = int(np.asarray(payload).shape[0])
        req = Request(rid=self._next_rid, payload=payload, size=size,
                      t_submit=self._timer())
        self._next_rid += 1
        self._queue.append(req)
        self.max_pending = max(self.max_pending, len(self._queue))
        return req

    @property
    def pending(self) -> int:
        return len(self._queue)

    def bucket_for(self, n: int) -> int:
        """Smallest ladder bucket holding n samples (n <= max_bucket)."""
        return bucket_for(n, self.buckets)

    # -- draining -----------------------------------------------------------

    def _take_microbatch(self) -> list[Request]:
        """Pop the next admission-order run of requests fitting max_bucket."""
        group = [self._queue.popleft()]
        total = group[0].size            # <= max_bucket: oversize heads
        # take the split path in drain_batched before reaching here
        while self._queue and total + self._queue[0].size <= self.max_bucket:
            nxt = self._queue.popleft()
            group.append(nxt)
            total += nxt.size
        return group

    def _run_chunk(self, step: Callable, xs: list[np.ndarray],
                   total: int):
        """Pad a coalesced chunk to its bucket and run one step."""
        bucket = self.bucket_for(total)
        x = np.concatenate(xs, axis=0) if len(xs) > 1 else np.asarray(xs[0])
        if bucket > total:
            pad = np.zeros((bucket - total,) + x.shape[1:], x.dtype)
            x = np.concatenate([x, pad], axis=0)
        out = step(x)
        return bucket, [np.asarray(o)[:total] for o in out]

    def drain_batched(self, step: Callable) -> list[Request]:
        """Serve every queued request; returns them in completion order.

        ``step(x)`` takes a bucket-padded (bucket, ...) array and returns a
        tuple of per-sample result arrays; it must block until the results
        are ready (the scheduler's compute timing is the step call).
        """
        done: list[Request] = []
        while self._queue:
            head = self._queue[0]
            if head.size > self.max_bucket:
                # oversize: serve alone, split into max_bucket chunks.
                # The clock does NOT restart per chunk: t_start is
                # stamped once at first step launch (before the payload
                # conversion, which is compute-side work — the group path
                # converts inside _run_chunk, after its t_start), so
                # queue_ms spans original submit -> first launch and
                # compute_ms spans every chunk.
                req = self._queue.popleft()
                req.t_start = self._timer()
                x = np.asarray(req.payload)
                chunks, buckets = [], []
                for i in range(0, req.size, self.max_bucket):
                    bucket, outs = self._run_chunk(
                        step, [x[i:i + self.max_bucket]],
                        min(self.max_bucket, req.size - i))
                    buckets.append(bucket)
                    chunks.append(outs)
                req.result = tuple(np.concatenate(parts, axis=0)
                                   for parts in zip(*chunks))
                req.t_done = self._timer()
                req.buckets = tuple(buckets)
                done.append(req)
                continue
            group = self._take_microbatch()
            total = sum(r.size for r in group)
            t_start = self._timer()
            for r in group:
                r.t_start = t_start
            bucket, outs = self._run_chunk(
                step, [np.asarray(r.payload) for r in group], total)
            t_done = self._timer()
            off = 0
            for r in group:
                r.result = tuple(o[off:off + r.size] for o in outs)
                r.t_done = t_done
                r.buckets = (bucket,)
                off += r.size
                done.append(r)
        self._record(done)
        return done

    def drain_serial(self, step: Callable) -> list[Request]:
        """Serve queued requests one per step (LM prefill/decode path).

        ``step(payload)`` returns the request's result and blocks until
        ready.  Same queue/compute accounting as the batched path.
        """
        done: list[Request] = []
        while self._queue:
            req = self._queue.popleft()
            req.t_start = self._timer()
            req.result = step(req.payload)
            req.t_done = self._timer()
            req.buckets = (req.size,)
            done.append(req)
        self._record(done)
        return done


def percentiles(values, *, round_to: int = 3) -> dict:
    """{p50, p99, p999, mean} over a value sequence (shared schema between
    the per-backend rows and the load-harness curve levels)."""
    vals = np.asarray(list(values), np.float64)
    return {"p50": round(float(np.percentile(vals, 50)), round_to),
            "p99": round(float(np.percentile(vals, 99)), round_to),
            "p999": round(float(np.percentile(vals, 99.9)), round_to),
            "mean": round(float(vals.mean()), round_to)}


def latency_stats(requests: list[Request]) -> dict:
    """Queue/compute/total latency percentiles over completed requests."""
    if not requests:
        return {}
    return {kind: percentiles(getattr(r, kind) for r in requests)
            for kind in ("queue_ms", "compute_ms", "total_ms")}


__all__ = ["MicrobatchScheduler", "Request", "bucket_for", "latency_stats",
           "next_pow2", "percentiles", "power_of_two_buckets"]
