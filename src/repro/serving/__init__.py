"""Serving subsystem: pluggable DWN datapath backends, a microbatching
request scheduler, and the engine that unifies DWN classification with LM
prefill/decode serving behind one submit/drain API.

Layering (each importable on its own):

    backends.py    datapath registry + per-(arch, bucket) compile cache
                   + startup bit-exactness cross-check vs the oracle
                   + per-bucket step-time estimates (StepTimeEstimator)
    scheduler.py   admission-order request queue, power-of-two batch
                   buckets, per-request queue/compute latency accounting
                   (the synchronous submit/drain facade)
    continuous.py  continuous-batching loop: scheduler thread, futures,
                   SLO-aware admission + deadline shedding, bounded-queue
                   backpressure
    engine.py      ServingEngine: sync submit/drain AND async
                   serve()/submit_async over either family, DWN batches
                   sharded data-parallel across the host mesh

``repro.launch.serve`` is a thin CLI over :class:`ServingEngine`;
``repro.launch.loadgen`` is the open-loop load generator that drives it
to saturation.
"""

from .backends import (Backend, BoundBackend, StepTimeEstimator,
                       available_backends, get_backend, register_backend,
                       build_dwn_model, verify_backends)
from .continuous import (AsyncRequest, ContinuousScheduler, QueueFull,
                         SLOConfig, ServeResult)
from .scheduler import MicrobatchScheduler, Request, power_of_two_buckets
from .engine import ServingEngine

__all__ = [
    "AsyncRequest", "Backend", "BoundBackend", "ContinuousScheduler",
    "MicrobatchScheduler", "QueueFull", "Request", "SLOConfig",
    "ServeResult", "ServingEngine", "StepTimeEstimator",
    "available_backends", "build_dwn_model", "get_backend",
    "power_of_two_buckets", "register_backend", "verify_backends",
]
