"""Serving subsystem: pluggable DWN datapath backends, a microbatching
request scheduler, and the engine that unifies DWN classification with LM
prefill/decode serving behind one submit/drain API.

Layering (each importable on its own):

    backends.py   datapath registry + per-(arch, bucket) compile cache
                  + startup bit-exactness cross-check vs the oracle
    scheduler.py  admission-order request queue, power-of-two batch
                  buckets, per-request queue/compute latency accounting
    engine.py     ServingEngine: submit/drain over either family, DWN
                  batches sharded data-parallel across the host mesh

``repro.launch.serve`` is a thin CLI over :class:`ServingEngine`.
"""

from .backends import (Backend, BoundBackend, available_backends,
                       get_backend, register_backend, build_dwn_model,
                       verify_backends)
from .scheduler import MicrobatchScheduler, Request, power_of_two_buckets
from .engine import ServingEngine

__all__ = [
    "Backend", "BoundBackend", "available_backends", "get_backend",
    "register_backend", "build_dwn_model", "verify_backends",
    "MicrobatchScheduler", "Request", "power_of_two_buckets",
    "ServingEngine",
]
