"""Continuous-batching request loop with SLO-aware admission control.

The synchronous ``MicrobatchScheduler`` coalesces requests in strict
admission order and blocks the caller in ``drain()`` — a closed-loop
measurement device, not a serving engine.  This module is the open-loop
core: a dedicated scheduler thread keeps device steps in flight while new
requests stream in from any number of submitting threads, and every
request completes **out of order** through its own future.

Design:

* **Batch formation at step boundaries.**  At each step the loop takes
  whatever is queued, ordered by (priority desc, deadline asc, admission
  order) — earliest-deadline-first within a priority class — coalesces
  up to ``max_bucket`` samples, and pads to the same power-of-two bucket
  ladder the sync scheduler uses, so the per-(backend, bucket) compile
  cache and the autotuned kernel configs carry over unchanged.
* **Oversize chunking without clock restarts.**  A request larger than
  ``max_bucket`` is served in max-bucket chunks across consecutive
  steps; its queue time is attributed from the *original submit* to the
  *first* chunk launch, and its future resolves once after the last
  chunk.
* **SLO-aware admission.**  A request may declare a deadline.  Admission
  rejects (types the result as shed, never raises) work that provably
  cannot meet its deadline given the samples queued ahead of it and the
  per-bucket step-time estimates (``backends.StepTimeEstimator`` — seeded
  from the ``AutoSelector`` calibration, refined online from every step).
  Queued work whose deadline expires before it can launch is shed at the
  step boundary instead of being served late; work that still completes
  past its deadline (estimates are estimates) is returned **marked
  shed** — a deadline-constrained request is never returned late without
  the marking.
* **Backpressure.**  Queue depth is bounded in *samples*; ``submit``
  blocks up to ``timeout`` for space and then raises :class:`QueueFull`,
  so an open-loop producer feels the engine's capacity instead of
  growing an unbounded heap.

The loop is model-agnostic: ``step(x)`` takes a bucket-padded array and
returns a tuple of per-sample result arrays, exactly the
``drain_batched`` contract.  ``step_once()`` runs one scheduling decision
plus one step synchronously — the unit tests drive it without threads,
so ordering assertions are deterministic.
"""

from __future__ import annotations

import bisect
import dataclasses
import math
import threading
import time
from concurrent.futures import Future
from typing import Any, Callable

import numpy as np

from .scheduler import Request, bucket_for, power_of_two_buckets

#: shed reasons (the typed result's ``shed`` field)
SHED_ADMISSION = "admission"      # provably unmeetable deadline at submit
SHED_EXPIRED = "expired"          # deadline passed while queued
SHED_LATE = "late"                # served, but results ready past deadline
SHED_SHUTDOWN = "shutdown"        # scheduler stopped without draining


class QueueFull(RuntimeError):
    """Backpressure: the bounded queue had no room within the timeout."""


@dataclasses.dataclass(frozen=True)
class ServeResult:
    """What an async request's future resolves to.

    ``ok`` means served on time (or no deadline declared).  ``shed`` is
    one of the SHED_* reasons otherwise; ``value`` still carries the
    results for ``SHED_LATE`` (the work was done, just late) and is None
    for requests that never ran.
    """

    ok: bool
    value: Any
    shed: str | None
    rid: int


@dataclasses.dataclass(frozen=True)
class SLOConfig:
    """Service-level objectives for the continuous-batching loop.

    Attributes:
      max_queue_samples: backpressure bound on queued (not yet launched)
        samples; ``submit`` blocks then raises :class:`QueueFull`.
      submit_timeout_s: default time ``submit`` waits for queue space
        when the caller passes ``timeout=None``.
      admission_slack: multiplier on the step-time estimates used by
        admission control.  < 1.0 is optimistic (sheds only work that is
        provably late even under a rosy estimate), > 1.0 sheds earlier.
      deadline_default_ms: deadline applied to requests that don't
        declare one (None = no implicit deadline).
    """

    max_queue_samples: int = 4096
    submit_timeout_s: float = 1.0
    admission_slack: float = 1.0
    deadline_default_ms: float | None = None


@dataclasses.dataclass
class AsyncRequest(Request):
    """A :class:`Request` plus async-serving state.

    ``future`` resolves to a :class:`ServeResult` — possibly before the
    request ever reaches the queue (admission shed).  ``deadline`` is an
    absolute ``timer()`` timestamp or None.
    """

    priority: int = 0
    deadline: float | None = None
    future: Future = dataclasses.field(default_factory=Future)
    shed: str | None = None
    #: samples already launched (oversize requests span several steps)
    offset: int = 0
    #: per-chunk result tuples, concatenated at completion
    parts: list = dataclasses.field(default_factory=list)

    @property
    def remaining(self) -> int:
        return self.size - self.offset

    def sort_key(self):
        # priority classes first, earliest deadline within a class,
        # admission order for deadline ties / no-deadline traffic
        return (-self.priority,
                self.deadline if self.deadline is not None else math.inf,
                self.rid)


class ContinuousScheduler:
    """The continuous-batching loop behind ``ServingEngine.serve()``.

    Args:
      step: ``step(x) -> tuple[per-sample arrays]`` on a bucket-padded
        batch; must block until results are ready (its wall time is the
        compute measurement and the estimator update).
      max_bucket / min_bucket: the power-of-two bucket ladder (identical
        to the sync scheduler's, so compiles are shared).
      slo: :class:`SLOConfig`; None = defaults (large queue, no implicit
        deadlines).
      estimator: per-bucket step-time estimates for admission control
        (``backends.StepTimeEstimator`` or any object with
        ``estimate(bucket) -> float | None`` and ``update(bucket, s)``).
        None disables admission-time shedding (expiry and late marking
        still apply: those need no estimate).
      monitor: optional ``runtime.straggler.StragglerMonitor``; every
        step's wall time is reported, anomalies surface in
        ``counters()``.
      timer: injectable clock (tests use a deterministic one).
    """

    def __init__(self, step: Callable, *, max_bucket: int = 256,
                 min_bucket: int = 8, slo: SLOConfig | None = None,
                 estimator=None, monitor=None,
                 timer: Callable[[], float] = time.perf_counter):
        self.buckets = power_of_two_buckets(
            min(min_bucket, max_bucket), max_bucket)
        self.max_bucket = max_bucket
        self.slo = slo if slo is not None else SLOConfig()
        self.estimator = estimator
        self.monitor = monitor
        self._step = step
        self._timer = timer
        # RLock: _finish() takes the lock for the completed/shed counters
        # and is reached both from submit() (admission shed, lock held)
        # and from the scheduler thread (lock not held)
        self._lock = threading.RLock()
        self._cond = threading.Condition(self._lock)
        #: kept sorted by sort_key at insert time (bisect.insort), so the
        #: step loop never re-sorts; partial takes stay at the front
        self._pending: list[AsyncRequest] = []
        self._queued_samples = 0
        self._deadline_pending = 0   # queued requests carrying a deadline
        self._next_rid = 0
        self._thread: threading.Thread | None = None
        self._stopping = False
        # -- observables -------------------------------------------------
        #: slim copies of every finished request (served or shed), the
        #: report()/latency_stats source; payload/result dropped
        self.completed: list[AsyncRequest] = []
        self.shed_counts: dict[str, int] = {}
        self.steps = 0
        self.busy_s = 0.0            # sum of step wall times
        self.session_wall_s = 0.0    # start() -> stop() wall, accumulated
        self.max_depth_samples = 0
        self.max_depth_requests = 0
        self._t_session = None

    # ------------------------------------------------------------------
    # submission (any thread)
    # ------------------------------------------------------------------

    def submit(self, payload: Any, size: int | None = None, *,
               deadline_ms: float | None = None, priority: int = 0,
               timeout: float | None = None) -> AsyncRequest:
        """Admit one request; returns it with ``future`` attached.

        Blocks up to ``timeout`` seconds (None = ``slo.submit_timeout_s``)
        when the bounded queue is full, then raises :class:`QueueFull`.
        A request whose deadline provably cannot be met is *not* queued:
        its future resolves immediately to a ``ServeResult`` with
        ``shed == SHED_ADMISSION``.
        """
        if size is None:
            size = int(np.asarray(payload).shape[0])
        timeout = self.slo.submit_timeout_s if timeout is None else timeout
        if deadline_ms is None:
            deadline_ms = self.slo.deadline_default_ms
        t_submit = self._timer()
        deadline = (t_submit + deadline_ms / 1e3
                    if deadline_ms is not None else None)
        with self._cond:
            if self._stopping:
                raise RuntimeError("scheduler is stopped")
            t_wait_end = t_submit + timeout
            while self._queued_samples + size > self.slo.max_queue_samples:
                left = t_wait_end - self._timer()
                if left <= 0 or self._stopping:
                    raise QueueFull(
                        f"queue full: {self._queued_samples} samples "
                        f"queued, bound {self.slo.max_queue_samples}, "
                        f"request of {size} timed out after {timeout}s")
                self._cond.wait(left)
            req = AsyncRequest(rid=self._next_rid, payload=payload,
                               size=size, t_submit=t_submit,
                               priority=priority, deadline=deadline)
            self._next_rid += 1
            if deadline is not None:
                est = self._admission_estimate_locked(req)
                if (est is not None and
                        self._timer() + est * self.slo.admission_slack
                        > deadline):
                    self._finish(req, shed=SHED_ADMISSION)
                    return req
            bisect.insort(self._pending, req, key=AsyncRequest.sort_key)
            self._queued_samples += size
            if deadline is not None:
                self._deadline_pending += 1
            self.max_depth_samples = max(self.max_depth_samples,
                                         self._queued_samples)
            self.max_depth_requests = max(self.max_depth_requests,
                                          len(self._pending))
            self._cond.notify_all()
        return req

    def _admission_estimate_locked(self, req: AsyncRequest) -> float | None:
        """Lower-bound seconds until ``req`` could complete, or None.

        The bound assumes perfect batching of everything scheduled ahead
        of the request (same-or-better sort key) into max-bucket steps,
        plus the request's own chunks — optimistic, so a shed on this
        estimate means the deadline was provably unmeetable.
        """
        if self.estimator is None:
            return None
        est_max = self.estimator.estimate(self.max_bucket)
        if est_max is None:
            return None
        idx = bisect.bisect_left(self._pending, req.sort_key(),
                                 key=AsyncRequest.sort_key)
        ahead = sum(r.size - r.offset for r in self._pending[:idx])
        wait = math.ceil(ahead / self.max_bucket) * est_max
        own = 0.0
        remaining = req.size
        while remaining > 0:
            chunk = min(remaining, self.max_bucket)
            b = bucket_for(chunk, self.buckets)
            own += self.estimator.estimate(b) or est_max
            remaining -= chunk
        return wait + own

    # ------------------------------------------------------------------
    # completion plumbing
    # ------------------------------------------------------------------

    def _finish(self, req: AsyncRequest, *, shed: str | None,
                value: Any = None) -> None:
        """Record + resolve one request (safe from any thread)."""
        req.shed = shed
        with self._lock:
            if shed is not None:
                self.shed_counts[shed] = self.shed_counts.get(shed, 0) + 1
            self.completed.append(dataclasses.replace(
                req, payload=None, result=None, parts=[]))
        req.future.set_result(ServeResult(ok=shed is None, value=value,
                                          shed=shed, rid=req.rid))

    # ------------------------------------------------------------------
    # the step loop (scheduler thread, or step_once from tests)
    # ------------------------------------------------------------------

    def _form_batch_locked(self, now: float):
        """One scheduling decision: (batch slices, expired requests).

        ``batch`` is a list of ``(request, lo, hi)`` payload row slices
        totalling <= max_bucket, packed **densely**: requests are taken
        in sort order and the one straddling the bucket boundary is
        split — its head rows fill this step, the rest stays queued
        (front of its priority class) for the next step.  Oversize
        requests fall out of the same rule as max-bucket chunks.  Dense
        packing is what makes the continuous path's steady-state
        samples/step match the sync facade's instead of padding away
        ~half of each bucket on ragged sizes.  Requests whose deadline
        can no longer be met even if launched immediately are pulled out
        as ``expired``.
        """
        expired: list[AsyncRequest] = []
        if self._deadline_pending:
            est_max = (self.estimator.estimate(self.max_bucket)
                       if self.estimator is not None else None)
            # any request whose deadline clears now + the max possible
            # floor cannot expire this step — skip its bucket math
            cutoff = now + (est_max or 0.0) * self.slo.admission_slack
            floors: dict[int, float] = {}
            keep: list[AsyncRequest] = []
            for r in self._pending:
                if r.deadline is not None and r.deadline < cutoff:
                    floor = 0.0
                    if est_max is not None:
                        b = bucket_for(min(r.size - r.offset,
                                           self.max_bucket), self.buckets)
                        floor = floors.get(b)
                        if floor is None:
                            floor = ((self.estimator.estimate(b) or est_max)
                                     * self.slo.admission_slack)
                            floors[b] = floor
                    if now + floor > r.deadline:
                        expired.append(r)
                        self._queued_samples -= r.size - r.offset
                        self._deadline_pending -= 1
                        continue
                keep.append(r)
            if expired:
                self._pending = keep
        batch: list[tuple[AsyncRequest, int, int]] = []
        total = 0
        for r in self._pending:
            if total >= self.max_bucket:
                break
            take = min(r.size - r.offset, self.max_bucket - total)
            batch.append((r, r.offset, r.offset + take))
            r.offset += take
            self._queued_samples -= take
            total += take
        if batch:
            still: list[AsyncRequest] = []
            for r in self._pending:
                if r.offset < r.size:
                    still.append(r)
                else:
                    if r.deadline is not None:
                        self._deadline_pending -= 1
            self._pending = still
        if batch or expired:
            self._cond.notify_all()    # space freed: wake submitters
        return batch, expired

    def step_once(self, *, wait_s: float = 0.0) -> int:
        """Run one scheduling decision + one device step synchronously.

        Returns the number of samples launched (0 if the queue was empty
        after waiting ``wait_s``).  The thread loop is just this method
        on repeat; tests call it directly for deterministic ordering.
        """
        with self._cond:
            if not self._pending and wait_s > 0:
                self._cond.wait(wait_s)
            now = self._timer()
            batch, expired = self._form_batch_locked(now)
        for r in expired:
            self._finish(r, shed=SHED_EXPIRED)
        if not batch:
            return 0
        t_start = self._timer()
        for r, _, _ in batch:
            if r.t_start == 0.0:      # first launch only: no clock restart
                r.t_start = t_start
        xs = [np.asarray(r.payload)[lo:hi] for r, lo, hi in batch]
        total = sum(x.shape[0] for x in xs)
        bucket = bucket_for(total, self.buckets)
        x = np.concatenate(xs, axis=0) if len(xs) > 1 else xs[0]
        if bucket > total:
            pad = np.zeros((bucket - total,) + x.shape[1:], x.dtype)
            x = np.concatenate([x, pad], axis=0)
        outs = self._step(x)
        t_done = self._timer()
        step_s = t_done - t_start
        self.steps += 1
        self.busy_s += step_s
        if self.estimator is not None:
            self.estimator.update(bucket, step_s)
        if self.monitor is not None:
            self.monitor.report(step_s)
        off = 0
        for r, lo, hi in batch:
            n = hi - lo
            r.parts.append(tuple(np.asarray(o)[off:off + n] for o in outs))
            r.buckets = r.buckets + (bucket,)
            off += n
            if r.offset >= r.size:    # fully served: resolve the future
                r.t_done = t_done
                if len(r.parts) == 1:
                    result = r.parts[0]
                else:
                    result = tuple(np.concatenate(parts, axis=0)
                                   for parts in zip(*r.parts))
                r.result = result
                late = r.deadline is not None and t_done > r.deadline
                self._finish(r, shed=SHED_LATE if late else None,
                                    value=result)
        return total

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------

    def _loop(self) -> None:
        while True:
            with self._lock:
                idle = not self._pending
                if self._stopping and idle:
                    return
            self.step_once(wait_s=0.002 if idle else 0.0)

    def start(self) -> None:
        assert self._thread is None, "already started"
        self._stopping = False
        self._t_session = self._timer()
        self._thread = threading.Thread(target=self._loop,
                                        name="repro-serve-loop",
                                        daemon=True)
        self._thread.start()

    def stop(self, *, drain: bool = True) -> None:
        """Stop the loop.  ``drain=True`` serves everything queued first;
        ``drain=False`` sheds queued requests with ``SHED_SHUTDOWN``."""
        assert self._thread is not None, "not started"
        if not drain:
            with self._cond:
                dropped, self._pending = self._pending, []
                self._queued_samples = 0
                self._deadline_pending = 0
                self._cond.notify_all()
            for r in dropped:
                self._finish(r, shed=SHED_SHUTDOWN)
        with self._cond:
            self._stopping = True
            self._cond.notify_all()
        self._thread.join()
        self._thread = None
        self.session_wall_s += self._timer() - self._t_session
        self._t_session = None

    # ------------------------------------------------------------------
    # observables
    # ------------------------------------------------------------------

    @property
    def pending_samples(self) -> int:
        return self._queued_samples

    @property
    def pending(self) -> int:
        return len(self._pending)

    def counters(self) -> dict:
        """JSON-able loop counters for ``ServingEngine.report()``."""
        served = [r for r in self.completed if r.shed is None]
        shed = len(self.completed) - len(served)
        out = {
            "steps": self.steps,
            "busy_s": round(self.busy_s, 4),
            "session_wall_s": round(
                self.session_wall_s + (self._timer() - self._t_session
                                       if self._t_session is not None
                                       else 0.0), 4),
            "served_requests": len(served),
            "served_samples": sum(r.size for r in served),
            "shed_requests": shed,
            "shed_by_reason": dict(self.shed_counts),
            "shed_rate": round(shed / len(self.completed), 4)
            if self.completed else 0.0,
            "queue_depth_max_samples": self.max_depth_samples,
            "queue_depth_max_requests": self.max_depth_requests,
        }
        if self.monitor is not None:
            out["straggler"] = {
                "window": len(self.monitor.times),
                "events": len(self.monitor.events),
                "last_z": round(self.monitor.events[-1].z, 2)
                if self.monitor.events else None,
            }
        return out


__all__ = [
    "AsyncRequest", "ContinuousScheduler", "QueueFull", "SLOConfig",
    "ServeResult", "SHED_ADMISSION", "SHED_EXPIRED", "SHED_LATE",
    "SHED_SHUTDOWN",
]
