"""Pluggable DWN datapath backends.

A *backend* is one implementation of the serving datapath
``features -> (class counts, argmax)`` over a frozen DWN.  All backends
share the same hardware semantics (paper §IV); they differ in how the
bits move:

    fused-packed   one Pallas ``pallas_call``: encode -> LUT layer(s) ->
                   masked popcount with every bit packed uint32 and
                   VMEM-resident (the serving fast path from PR 1)
    packed-xla     the same packed uint32 word format, but expressed as
                   plain XLA ops via ``core.bitpack`` /
                   ``apply_hard_packed`` — no ``pallas_call``, so it runs
                   anywhere XLA does and is the data-parallel reference
    float-oracle   ``apply_hard``: every bit a float32.  Slow, but the
                   bit-exactness oracle every other backend is checked
                   against at engine startup.

``BoundBackend`` binds a backend to one model and owns the
per-(arch, batch-bucket) compile cache: each bucket size gets exactly one
``jax.jit`` entry, and the number of XLA traces actually taken is counted
so the scheduler's no-recompile guarantee is testable.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..core.classifier import predict
from ..core.model import DWNConfig, FrozenDWN, apply_hard, apply_hard_packed
from ..core.thermometer import quantize_fixed_point
from ..kernels import autotune
from ..kernels.fused import ops as fused_ops

Array = jax.Array


# ---------------------------------------------------------------------------
# model bundle
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DWNModelBundle:
    """A frozen DWN plus its device-resident operand arrays.

    Built once per served arch; every backend reads from the same bundle so
    cross-backend comparisons are comparisons of *datapaths*, not weights.
    """

    cfg: ArchConfig
    dcfg: DWNConfig
    frozen: FrozenDWN
    thresholds: Array                 # (F, T)
    mappings: list                    # per layer (m, n) int32
    tables: list                      # per layer (m, 2^n) int32
    #: bucket -> tuned fused-kernel config (``autotune_model`` fills it;
    #: empty = every bucket serves on the default blocks).  Configs are
    #: resolved at trace time, so tune *before* the first step compiles.
    tuned_configs: dict = dataclasses.field(default_factory=dict)

    @property
    def num_classes(self) -> int:
        return self.dcfg.num_classes

    @property
    def arch_name(self) -> str:
        return self.cfg.name


def build_dwn_model(cfg: ArchConfig, x_train: np.ndarray,
                    seed: int = 0) -> DWNModelBundle:
    """Deprecated shim: init + freeze an arch's DWN and stage operands.

    The canonical construction path is the ``repro.dwn`` lifecycle::

        spec = DWNSpec.from_arch(cfg)            # or a spec preset
        bundle = (DWNArtifact(spec).fit(x_train, seed=seed)
                  .freeze().pack().serving_model())

    This shim delegates there (bit-identical output — same init PRNG,
    same freeze) and warns.
    """
    import warnings
    warnings.warn(
        "serving.backends.build_dwn_model is deprecated; construct a "
        "repro.dwn.DWNSpec and use DWNArtifact(spec).fit(...).freeze()"
        ".pack().serving_model() instead", DeprecationWarning,
        stacklevel=2)
    from ..dwn import DWNArtifact, DWNSpec
    art = DWNArtifact(DWNSpec.from_arch(cfg)).fit(x_train, seed=seed)
    return art.freeze().pack().serving_model(cfg=cfg)


# ---------------------------------------------------------------------------
# backend protocol + registry
# ---------------------------------------------------------------------------

class Backend:
    """One DWN serving datapath.  Subclass + :func:`register_backend`.

    ``make_step(model)`` returns ``fn(x) -> (counts, pred)`` for a feature
    batch ``x (B, F)``; the callable must be pure and jit-able (it is
    wrapped in ``jax.jit`` — and, data-parallel, in ``shard_map`` — by
    :class:`BoundBackend`).
    """

    name: str = "?"
    is_oracle: bool = False

    def make_step(self, model: DWNModelBundle) -> Callable:
        raise NotImplementedError


_REGISTRY: dict[str, Backend] = {}


def register_backend(cls):
    """Class decorator: register a Backend subclass under ``cls.name``."""
    assert cls.name not in _REGISTRY, cls.name
    _REGISTRY[cls.name] = cls()
    return cls


def get_backend(name: str) -> Backend:
    if name not in _REGISTRY:
        raise KeyError(f"unknown serving backend {name!r}; "
                       f"registered: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def available_backends() -> list[str]:
    return sorted(_REGISTRY)


@register_backend
class FusedPackedBackend(Backend):
    """Fused Pallas kernel, bits VMEM-resident end-to-end.

    The kernel variant and rows-per-grid-step come from the model's
    ``tuned_configs`` (per batch bucket, filled by
    :func:`autotune_model`); buckets without a tuned entry serve on
    ``autotune.DEFAULT_CONFIG``'s blocks.  The config is resolved at
    trace time — ``BoundBackend`` jits once per bucket, so each bucket's
    trace closes over its own config.
    """

    name = "fused-packed"

    def make_step(self, model: DWNModelBundle) -> Callable:
        fwd_cache: dict = {}

        def fwd_for(config):
            if config not in fwd_cache:
                # fn() below runs inside a jit trace; without this guard
                # omnistaging would stage the one-time operand prep into
                # whichever bucket traces first and leak its tracers into
                # the memoized closure
                with jax.ensure_compile_time_eval():
                    fwd_cache[config] = fused_ops.make_forward_packed(
                        model.thresholds, model.mappings, model.tables,
                        model.num_classes, config=config)
            return fwd_cache[config]

        # PEN models quantize inputs to the (1, n) grid before the
        # comparator bank (apply_hard semantics); the fused kernel sees
        # already-quantized rows so it stays bit-exact vs the oracle.
        frac = model.frozen.input_frac_bits

        def fn(x: Array):
            # x.shape[0] is static at trace time: per-bucket jit entries
            # each bind their bucket's tuned config here
            fwd = fwd_for(model.tuned_configs.get(x.shape[0]))
            if frac is not None:
                x = quantize_fixed_point(x, frac)
            counts, pred = fwd(x)
            return counts.astype(jnp.float32), pred
        return fn


@register_backend
class PackedXLABackend(Backend):
    """Packed uint32 words through plain XLA ops (no pallas_call)."""

    name = "packed-xla"

    def make_step(self, model: DWNModelBundle) -> Callable:
        frozen = model.frozen

        def fn(x: Array):
            counts = apply_hard_packed(frozen, x)
            return counts, predict(counts)
        return fn


@register_backend
class FloatOracleBackend(Backend):
    """``apply_hard``: the float bit-exactness oracle."""

    name = "float-oracle"
    is_oracle = True

    def make_step(self, model: DWNModelBundle) -> Callable:
        frozen = model.frozen

        def fn(x: Array):
            counts = apply_hard(frozen, x)
            return counts, predict(counts)
        return fn


# ---------------------------------------------------------------------------
# bound backend: per-(arch, bucket) compile cache
# ---------------------------------------------------------------------------

class BoundBackend:
    """A backend bound to one model, with a per-bucket compile cache.

    ``step_for(bucket)`` returns the jitted step for that batch-bucket,
    compiling at most once per bucket; ``wrap(fn, bucket)`` (optional,
    supplied by the engine) may interpose ``shard_map`` for data-parallel
    buckets.  ``compiles`` maps bucket -> number of XLA traces taken, the
    observable the scheduler tests pin down.
    """

    def __init__(self, backend: Backend, model: DWNModelBundle, *,
                 wrap: Callable | None = None):
        self.backend = backend
        self.model = model
        self._fn = backend.make_step(model)
        self._wrap = wrap
        self._jitted: dict[int, Callable] = {}
        self.compiles: dict[int, int] = {}

    @property
    def name(self) -> str:
        return self.backend.name

    @property
    def is_oracle(self) -> bool:
        return self.backend.is_oracle

    def step_for(self, bucket: int) -> Callable:
        if bucket not in self._jitted:
            self.compiles[bucket] = 0
            inner = self._fn

            def traced(x, _bucket=bucket):
                # the python body runs once per XLA trace: count them
                self.compiles[_bucket] += 1
                return inner(x)

            fn = traced
            if self._wrap is not None:
                fn = self._wrap(fn, bucket)
            self._jitted[bucket] = jax.jit(fn)
        return self._jitted[bucket]

    def __call__(self, x: Array):
        return self.step_for(x.shape[0])(x)


# ---------------------------------------------------------------------------
# per-bucket step-time estimates (admission control's model of the device)
# ---------------------------------------------------------------------------

class StepTimeEstimator:
    """Per-bucket step wall-time estimates for SLO-aware admission.

    The continuous-batching loop needs to answer "how long until this
    request could complete?" *before* serving it.  The estimate has two
    sources, in order of freshness:

    * a **seed** from startup calibration — ``AutoSelector`` already
      times every backend at every bucket for ``backend="auto"``, so the
      winning backend's time per bucket is free; pinned backends seed
      from one ``time_backend_step`` probe at ``max_bucket`` (step time
      is overhead-dominated at these model sizes, so one bucket's time
      is a usable prior for the whole ladder);
    * an **online EWMA** over the actual step times the loop observes
      (``update`` after every step), which quickly overrides the seed
      and tracks drift (thermal, contention, interpret-vs-compiled).

    ``estimate`` returns seconds or None when nothing is known for the
    bucket (or any larger one — a larger bucket's time upper-bounds a
    smaller one's here, so it stands in rather than admit blindly).
    """

    def __init__(self, *, alpha: float = 0.25):
        self.alpha = alpha
        self._seed: dict[int, float] = {}
        self._ewma: dict[int, float] = {}
        self.updates = 0

    def seed(self, bucket: int, seconds: float) -> None:
        """Install a calibration prior (ignored once EWMA data exists)."""
        self._seed[int(bucket)] = float(seconds)

    def update(self, bucket: int, seconds: float) -> None:
        """Fold one observed step time into the bucket's EWMA."""
        b = int(bucket)
        prev = self._ewma.get(b)
        self._ewma[b] = (seconds if prev is None
                         else prev + self.alpha * (seconds - prev))
        self.updates += 1

    def estimate(self, bucket: int) -> float | None:
        """Best current estimate (s) for one step at ``bucket``, or None."""
        b = int(bucket)
        for table in (self._ewma, self._seed):
            if b in table:
                return table[b]
        # fall back to the nearest known larger bucket (upper bound)
        for table in (self._ewma, self._seed):
            larger = [v for k, v in table.items() if k > b]
            if larger:
                return min(larger)
        return None

    def snapshot(self) -> dict:
        """JSON-able {bucket: est_ms} view for reports."""
        buckets = sorted(set(self._seed) | set(self._ewma))
        return {int(b): round((self.estimate(b) or 0.0) * 1e3, 4)
                for b in buckets}


def estimator_from_calibration(auto: "AutoSelector") -> StepTimeEstimator:
    """Seed an estimator from an ``AutoSelector``'s startup calibration:
    each bucket's prior is the *chosen* backend's measured step time."""
    est = StepTimeEstimator()
    for bucket, times in auto.timings.items():
        choice = auto.choice.get(bucket)
        if choice in times:
            est.seed(bucket, times[choice])
    return est


# ---------------------------------------------------------------------------
# per-(arch, bucket) backend auto-select
# ---------------------------------------------------------------------------

def time_backend_step(bound: "BoundBackend", x: Array, *,
                      iters: int = 3) -> float:
    """Best-of-``iters`` seconds of one bound step at x's bucket size.

    The first (untimed) call warms the (backend, bucket) compile cache,
    so the measurement sees steady-state serving, exactly like a running
    server would.  The timing loop itself is ``autotune.time_step`` —
    the same machinery the kernel autotuner sweeps candidates with —
    with a 1 ms accumulation floor so microsecond-scale steps (small
    models, small buckets) are raced over enough reps to beat scheduler
    jitter.
    """
    return autotune.time_step(bound.step_for(x.shape[0]), x, iters=iters,
                              min_time_s=1e-3)


def autotune_model(model: DWNModelBundle, buckets, x_probe, *,
                   spec_fingerprint: str,
                   cache: "autotune.AutotuneCache | None" = None,
                   iters: int = 5, timer=None,
                   force: bool = False) -> dict:
    """Fill ``model.tuned_configs`` with the fastest fused config per
    bucket (cache-hit first, timed sweep on miss).

    Must run before the first fused step compiles: ``BoundBackend`` jits
    one entry per bucket and each trace binds the config it sees then.

    Args:
      model: the served bundle; mutated in place.
      buckets: bucket ladder to tune (e.g. ``scheduler.buckets``).
      x_probe: (>= max(buckets), F) probe rows; each bucket tunes on its
        leading slice.
      spec_fingerprint: ``DWNSpec.fingerprint()`` — the cache identity.
      cache / iters / timer / force: passed to ``autotune.tune_fused``.

    Returns {bucket: FusedConfig} (also left on the model).
    """
    cache = cache if cache is not None else autotune.AutotuneCache()
    kwargs = {} if timer is None else {"timer": timer}
    for bucket in buckets:
        cfg = autotune.tune_fused(
            model.thresholds, model.mappings, model.tables,
            model.num_classes, jnp.asarray(x_probe[:bucket]),
            spec_fingerprint=spec_fingerprint,
            input_frac_bits=model.frozen.input_frac_bits,
            cache=cache, iters=iters, min_time_s=1e-3, force=force,
            **kwargs)
        model.tuned_configs[bucket] = cfg
    return dict(model.tuned_configs)


class AutoSelector:
    """Per-(arch, bucket) fastest-bit-exact-backend chooser.

    The serving benchmarks show the fastest datapath is *size dependent*
    (e.g. ``BENCH_serve.json``: on dwn-jsc-sm the float oracle outruns the
    packed paths, on md/lg the packed paths win).  Instead of hardcoding,
    the selector times every backend that passed the startup bit-exactness
    gate (the oracle is exact by definition) on probe rows at each bucket
    size and serves that bucket on the winner.  Calibration runs once per
    (arch, bucket): the engine calibrates its whole bucket ladder at
    startup, so no timed request pays calibration (compiles + timing
    probes) inside its compute window; ``backend_for`` keeps a lazy
    fallback for selectors created mid-session via
    ``use_backend("auto")``.

    Calibration consults the model's *tuned* fused configs, not just the
    backend choice: ``autotune_model`` runs first, so the fused-packed
    candidate being timed at each bucket is the autotuned variant/blocks
    for that bucket, and ``configs`` records what was actually timed.

    Near-ties break toward ``fused-packed``: at small buckets the real
    spread between datapaths is a few microseconds — below the jitter of
    the CPU interpret-mode emulation the timings run under — and the
    fused kernel is the deployment-target path the emulation stands in
    for.  A backend only displaces it by beating it past
    ``tie_break_pct``.

    Attributes:
      choice: bucket -> winning backend name (filled by calibration).
      timings: bucket -> {backend: best step seconds} for reporting.
      configs: bucket -> tuned ``FusedConfig`` in effect at calibration
        time (None for untuned buckets).
    """

    #: preferred backend on near-ties (the deployment-target kernel).
    TIE_BREAK_BACKEND = "fused-packed"

    def __init__(self, backends: dict[str, "BoundBackend"],
                 bit_exact: dict[str, bool], *, iters: int = 5,
                 tie_break_pct: float = 10.0):
        self.backends = backends
        self.eligible = [name for name, b in backends.items()
                         if b.is_oracle or bit_exact.get(name, False)]
        assert self.eligible, "no bit-exact backend to select from"
        self.iters = iters
        self.tie_break_pct = tie_break_pct
        self.choice: dict[int, str] = {}
        self.timings: dict[int, dict[str, float]] = {}
        self.configs: dict[int, "autotune.FusedConfig | None"] = {}

    def calibrate(self, x: Array) -> str:
        """Time every eligible backend at x's bucket; returns the winner."""
        bucket = x.shape[0]
        times = {name: time_backend_step(self.backends[name], x,
                                         iters=self.iters)
                 for name in self.eligible}
        self.timings[bucket] = times
        best = min(times, key=times.get)
        tb = self.TIE_BREAK_BACKEND
        if (tb in times and tb != best
                and times[tb] <= times[best]
                * (1 + self.tie_break_pct / 100)):
            best = tb
        self.choice[bucket] = best
        model = self.backends[best].model
        self.configs[bucket] = model.tuned_configs.get(bucket)
        return best

    def backend_for(self, x: Array) -> "BoundBackend":
        """The calibrated winner for x's bucket (calibrating on first
        encounter — bounded one calibration per bucket, like compiles)."""
        bucket = x.shape[0]
        if bucket not in self.choice:
            self.calibrate(x)
        return self.backends[self.choice[bucket]]


# ---------------------------------------------------------------------------
# startup cross-check
# ---------------------------------------------------------------------------

def verify_backends(model: DWNModelBundle,
                    backends: Sequence[BoundBackend],
                    x_probe: np.ndarray) -> dict[str, bool]:
    """Bit-exactness gate: every non-oracle backend vs the float oracle.

    Runs each backend on the same probe batch (through its bucket cache,
    so the compile is reused by serving) and compares counts *and*
    predictions exactly.  Raises ``RuntimeError`` on any divergence —
    refusing to serve a broken datapath — and returns {name: True} for
    the checked backends otherwise.
    """
    x = jnp.asarray(x_probe)
    oracle = get_backend("float-oracle")
    oracle_bound = next((b for b in backends if b.is_oracle),
                        BoundBackend(oracle, model))
    counts_ref, pred_ref = jax.device_get(oracle_bound(x))
    results: dict[str, bool] = {}
    for b in backends:
        if b.is_oracle:
            continue
        counts, pred = jax.device_get(b(x))
        ok = (np.array_equal(np.asarray(counts, np.float32),
                             np.asarray(counts_ref, np.float32))
              and np.array_equal(pred, pred_ref))
        results[b.name] = bool(ok)
        if not ok:
            raise RuntimeError(
                f"serving backend {b.name!r} diverged from the apply_hard "
                f"oracle on arch {model.arch_name!r}; refusing to serve a "
                f"broken datapath")
    return results


__all__ = [
    "AutoSelector", "Backend", "BoundBackend", "DWNModelBundle",
    "StepTimeEstimator", "autotune_model", "available_backends",
    "build_dwn_model", "estimator_from_calibration", "get_backend",
    "register_backend", "time_backend_step", "verify_backends",
]
