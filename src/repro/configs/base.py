"""Config system: one frozen dataclass describing every assigned arch.

Every architecture in the pool is an ``ArchConfig`` instance registered in
``repro.configs.registry``; ``--arch <id>`` on the launchers resolves here.
``reduced()`` derives the CPU smoke-test variant of the same family (same
code paths, tiny dims) used by tests/ and examples/.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional


def round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    rope_theta: float = 10_000.0
    qk_norm: bool = False
    qkv_bias: bool = False
    swa_window: Optional[int] = None  # sliding-window attention (Mixtral)
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    source: str = ""                 # provenance tag from the assignment

    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_ep: bool = False             # expert parallelism over "model"
                                     # (experts padded to the TP degree)

    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_chunk: int = 256

    # --- hybrid (RecurrentGemma: RG-LRU + local attention, 1 attn : 2 rec) ---
    lru_width: int = 0
    local_window: int = 0

    # --- enc-dec (Whisper; frontend is a stub producing frame embeddings) ---
    enc_layers: int = 0
    enc_frames: int = 1500

    # --- VLM (LLaVA-NeXT; anyres tiling stub producing patch embeddings) ---
    num_patches: int = 0

    # --- DWN (the paper's own models; family="dwn") ---
    dwn_luts: int = 0                # m (LUT-layer width)
    dwn_bits: int = 200              # thermometer bits per feature (T) —
                                     # the encoder *resolution*, first-class
                                     # so repro.sweep can sweep it
    dwn_encoding: str = "distributive"  # threshold placement: "distributive"
                                        # (quantile) | "uniform" | "gaussian"
    dwn_fused: bool = False          # fused (VMEM-blocked) serving datapath
    dwn_datapath: str = "corner"     # "corner" (baseline) | "gather" (opt)
    dwn_grouping: str = "contig"     # "contig" (paper Fig.1) | "strided"
                                     # (shard-aligned popcount; opt)

    # --- training defaults ---
    attn_impl: str = "masked"        # "masked" flash | "tri" (block-triangular)
    attn_scores_bf16: bool = False   # bf16 score tiles (halves flash traffic)
    attn_chunk: int = 1024           # flash kv-chunk
    remat: bool = True
    train_microbatches: int = 4      # gradient-accumulation for train_4k
                                     # (sized so remat'd residuals fit HBM)

    # ------------------------------------------------------------------

    @property
    def head_dim_(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    def vocab_padded(self, tp: int = 16) -> int:
        return round_up(self.vocab_size, max(256, tp))

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context with bounded state?"""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.swa_window is not None

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs autoregress (whisper via its decoder)

    def num_params(self, tp: int = 16) -> int:
        """Approximate *real* (unpadded) parameter count."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        hd = self.head_dim_
        emb = V * D * (1 if self.tie_embeddings else 2)
        if self.family == "dwn":
            m, n, T = self.dwn_luts, 6, self.dwn_bits
            return m * n * D * T + m * 2 ** n + D * T
        if self.family == "ssm":
            di = self.ssm_expand * D
            nh = di // self.ssm_headdim
            per = (D * (2 * di + 2 * self.ssm_ngroups * self.ssm_state + nh)
                   + di * D + 2 * nh + di)
            return L * per + emb
        attn = D * hd * (self.num_heads + 2 * self.num_kv_heads) \
            + self.num_heads * hd * D
        if self.family == "moe":
            ffn = self.num_experts * 3 * D * F + D * self.num_experts
        else:
            ffn = 3 * D * F
        per = attn + ffn + 2 * D
        if self.family == "hybrid":
            n_attn = L // 3
            n_rec = L - n_attn
            W = self.lru_width
            rec = 2 * D * W + W * D + 7 * W  # proj in x2, out, lru gates/conv
            per = n_attn * (attn + 3 * D * F + 2 * D) \
                + n_rec * (rec + 3 * D * F + 2 * D)
            return per + emb
        total = L * per + emb
        if self.family == "encdec":
            enc_per = D * hd * 3 * self.num_heads + self.num_heads * hd * D \
                + 2 * D * F + 2 * D
            total += self.enc_layers * enc_per
            total += L * (attn + 2 * D)      # cross-attention blocks
        return total

    def num_active_params(self) -> int:
        """Params touched per token (MoE: top_k experts)."""
        if self.family != "moe":
            return self.num_params()
        D, F, L = self.d_model, self.d_ff, self.num_layers
        hd = self.head_dim_
        emb = self.vocab_size * D * (1 if self.tie_embeddings else 2)
        attn = D * hd * (self.num_heads + 2 * self.num_kv_heads) \
            + self.num_heads * hd * D
        ffn = self.top_k * 3 * D * F + D * self.num_experts
        return L * (attn + ffn + 2 * D) + emb

    # ------------------------------------------------------------------

    def reduced(self) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=min(self.num_layers, 2 if self.family != "hybrid" else 3),
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads
            < self.num_heads else 4,
            head_dim=16,
            d_ff=96 if self.family != "moe" else 32,
            vocab_size=251,
            num_experts=min(self.num_experts, 4),
            top_k=min(self.top_k, 2),
            ssm_state=16 if self.ssm_state else 0,
            ssm_headdim=16 if self.ssm_state else 64,
            ssm_chunk=8,
            lru_width=64 if self.lru_width else 0,
            local_window=8 if self.local_window else 0,
            swa_window=16 if self.swa_window else None,
            enc_layers=2 if self.enc_layers else 0,
            enc_frames=12 if self.enc_layers else 1500,
            num_patches=6 if self.num_patches else 0,
            attn_chunk=16,
        )


# ---------------------------------------------------------------------------
# Input shapes assigned to the LM pool (seq_len x global_batch).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode
    num_microbatches: int = 1        # gradient-accumulation (train only)


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

#: extra shapes for the paper's own DWN models (family="dwn"): samples =
#: seq_len x global_batch feature vectors; the FPGA accelerator's
#: one-sample-per-cycle throughput maps to huge-batch streaming on TPU.
DWN_SHAPES = {
    "dwn_train_1m": ShapeConfig("dwn_train_1m", 4096, 256, "train",
                                num_microbatches=4),
    "dwn_serve_1m": ShapeConfig("dwn_serve_1m", 4096, 256, "prefill"),
}


def cell_supported(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Is (arch x shape) a valid dry-run cell?  Returns (ok, reason)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention arch: 524k dense KV decode is the "
                       "quadratic regime this shape excludes (DESIGN.md §6)")
    return True, ""
