"""DWN-head-on-LM spec preset (``dwn-lm-head``).

The served form of ``examples/dwn_head_lm.py``: a 16-feature 5-class DWN
head whose features are pooled from a frozen reduced qwen3-8b backbone
(the ``lm-head`` workload).  Registered as an arch alias (for report
shapes) and a ``DWNSpec`` preset with ``workload="lm-head"`` and
``backbone="qwen3-8b"``; ``ServingEngine(..., dwn_head=...)`` serves a
packed artifact of this spec alongside LM decode in one process.
"""
from .base import ArchConfig
from .registry import register

register(ArchConfig(
    name="dwn-lm-head",
    family="dwn",
    num_layers=1,
    d_model=16,               # pooled backbone features
    num_heads=0, num_kv_heads=0, d_ff=0,
    vocab_size=5,             # teacher-projection classes
    dwn_luts=50,
    dwn_bits=64,
    dwn_fused=True,
    dwn_datapath="fused-packed",
    source="examples/dwn_head_lm.py promoted (DESIGN.md §6)",
))


# --- spec preset (repro.dwn) -----------------------------------------------
from ..dwn.spec import register_preset as _register_spec

_register_spec("dwn-lm-head", preset="lm-head-50", workload="lm-head",
               bits=64, placement="uniform", backbone="qwen3-8b",
               datapath="fused-packed")
