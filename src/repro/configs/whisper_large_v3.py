"""whisper-large-v3 — enc-dec, 32 encoder + 32 decoder layers, d_model=1280,
20H (MHA), d_ff=5120, vocab 51866.  Conv audio frontend is a STUB per the
assignment: input_specs() provides precomputed (B, frames, d_model) frame
embeddings.  [arXiv:2212.04356; unverified]
"""
from .base import ArchConfig
from .registry import register

CONFIG = register(ArchConfig(
    name="whisper-large-v3",
    family="encdec",
    num_layers=32,            # decoder layers
    enc_layers=32,
    enc_frames=1500,          # 30 s of audio after the conv stub
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,          # MHA
    d_ff=5120,
    vocab_size=51866,
    head_dim=64,
    rope_theta=0.0,           # whisper uses learned/sinusoidal positions
    norm_eps=1e-5,
    train_microbatches=2,
    source="arXiv:2212.04356; unverified",
))
