"""mamba2-1.3b — attention-free SSD (state-space duality), 48L d_model=2048,
ssm_state=128, headdim=64 (d_inner = 2*d_model = 4096 => 64 heads),
vocab 50280.  [arXiv:2405.21060; unverified]
"""
from .base import ArchConfig
from .registry import register

CONFIG = register(ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=0,              # attention-free
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    tie_embeddings=True,
    ssm_state=128,
    ssm_headdim=64,
    ssm_ngroups=1,
    ssm_conv=4,
    ssm_expand=2,
    ssm_chunk=256,
    train_microbatches=4,
    source="arXiv:2405.21060; unverified",
))
