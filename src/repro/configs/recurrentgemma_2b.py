"""recurrentgemma-2b — hybrid RG-LRU + local attention (1 attn : 2 rec),
26L d_model=2560 10H (MQA kv=1) d_ff=7680, vocab 256000, lru_width=2560,
local window 2048.  [arXiv:2402.19427; hf]
"""
from .base import ArchConfig
from .registry import register

CONFIG = register(ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,            # pattern: [rec, rec, attn] x 8 + [rec, rec]
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    d_ff=7680,
    vocab_size=256000,
    head_dim=256,
    rope_theta=10_000.0,
    tie_embeddings=True,
    lru_width=2560,
    local_window=2048,
    train_microbatches=2,
    source="arXiv:2402.19427; hf",
))
