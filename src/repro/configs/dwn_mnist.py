"""MNIST DWN serving aliases + spec presets (``dwn-mnist-{sm,md,lg}``).

The second workload's analogue of ``dwn_jsc.py``: short serving archs
(196 pooled-pixel features, 10 digit classes) and the registered
``DWNSpec`` presets the CLIs, sweep grids, and cosim gate resolve.
Spec registration is deferred kwargs, same as the JSC shims.
"""
import dataclasses as _dc

from .base import ArchConfig
from .registry import register

#: tier -> (LUT-layer width m, default thermometer bits T).  m divides
#: by 10 classes (the popcount-grouping constraint); T defaults follow
#: the workload presets in ``repro.workloads.mnist``.
_MNIST_TIERS = {"sm": (100, 8), "md": (500, 8), "lg": (2000, 16)}


def _dwn_mnist(name: str, luts: int, bits: int) -> ArchConfig:
    return ArchConfig(
        name=name,
        family="dwn",
        num_layers=1,
        d_model=196,              # 14x14 pooled MNIST pixels
        num_heads=0, num_kv_heads=0, d_ff=0,
        vocab_size=10,            # digit classes
        dwn_luts=luts,
        dwn_bits=bits,
        dwn_fused=True,
        dwn_datapath="fused-packed",
        source="DWN MNIST tiers (Bacellar et al. model family)",
    )


for _tier, (_l, _b) in _MNIST_TIERS.items():
    register(_dwn_mnist(f"dwn-mnist-{_tier}", _l, _b))
    register(_dc.replace(_dwn_mnist(f"dwn-mnist-{_tier}-x", _l, _b),
                         name=f"dwn-mnist-{_tier}-xla",
                         dwn_datapath="packed-xla"))


# --- spec presets (repro.dwn) ----------------------------------------------
from ..dwn.spec import register_preset as _register_spec

for _tier, (_l, _b) in _MNIST_TIERS.items():
    _register_spec(f"dwn-mnist-{_tier}", preset=f"mnist-{_tier}",
                   workload="mnist", bits=_b, datapath="fused-packed")
    _register_spec(f"dwn-mnist-{_tier}-xla", preset=f"mnist-{_tier}",
                   workload="mnist", bits=_b, datapath="packed-xla")
