from .base import ArchConfig, ShapeConfig, SHAPES, DWN_SHAPES, cell_supported
from .registry import get_arch, list_archs, assigned_archs, register
