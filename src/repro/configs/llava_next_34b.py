"""llava-next-34b — VLM, 60L d_model=7168 56H (GQA kv=8) d_ff=20480,
vocab 64000; anyres tiling frontend is a STUB per the assignment:
input_specs() provides precomputed (B, num_patches, d_model) patch
embeddings prepended to the text sequence.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
"""
from .base import ArchConfig
from .registry import register

CONFIG = register(ArchConfig(
    name="llava-next-34b",
    family="vlm",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    head_dim=128,
    rope_theta=5_000_000.0,
    num_patches=2880,         # anyres 2x2 grid + base, 576 each
    train_microbatches=8,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
))
