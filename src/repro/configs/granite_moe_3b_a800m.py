"""granite-moe-3b-a800m — 32L d_model=1536 24H (GQA kv=8) d_ff=512/expert,
vocab 49155, MoE 40 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]

Assignment note: the spec line reads "MoE 40e top-8" while its comment says
"32 experts top-8"; we implement the primary spec (40 experts, top-8) and
record the discrepancy here and in DESIGN.md.
"""
from .base import ArchConfig
from .registry import register

CONFIG = register(ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,                 # per-expert hidden
    vocab_size=49155,
    head_dim=64,
    rope_theta=10_000.0,
    tie_embeddings=True,
    num_experts=40,
    top_k=8,
    train_microbatches=2,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
))
