"""The paper's own DWN JSC models as selectable production archs.

These are *extra* cells beyond the assigned 40: the paper's technique on
the production mesh (dwn_train / dwn_serve shapes), including the fused
serving variants used by the §Perf hillclimb.
"""
from .base import ArchConfig
from .registry import register


def _dwn(name: str, luts: int, fused: bool = False) -> ArchConfig:
    return ArchConfig(
        name=name + ("-fused" if fused else ""),
        family="dwn",
        num_layers=1,
        d_model=16,               # JSC features
        num_heads=0, num_kv_heads=0, d_ff=0,
        vocab_size=5,             # JSC jet classes
        dwn_luts=luts,
        dwn_bits=200,
        dwn_fused=fused,
        source="Mecik & Kumm 2025 (this paper); [13] model sizes",
    )


for _m, _l in (("dwn-jsc-sm10", 10), ("dwn-jsc-sm50", 50),
               ("dwn-jsc-md360", 360), ("dwn-jsc-lg2400", 2400)):
    register(_dwn(_m, _l))
    register(_dwn(_m, _l, fused=True))


# §Perf hillclimb variants of the serving datapath (lg-2400 target cell)
import dataclasses as _dc

# Short serving aliases (launch/serve.py --arch dwn-jsc-{sm,md,lg}): the
# paper's size tiers wired to a serving backend via ``dwn_datapath``
# (resolved by repro.serving.engine against the backend registry; values
# that aren't registered backends — "corner"/"gather" — keep selecting
# the dryrun datapath variants below and serve on the default backend).
# The plain alias serves on the fused packed Pallas kernel; the -xla
# twin serves the same packed word format through plain XLA ops.
for _m, _l in (("dwn-jsc-sm", 50), ("dwn-jsc-md", 360),
               ("dwn-jsc-lg", 2400)):
    register(_dc.replace(_dwn(_m, _l, fused=True), name=_m,
                         dwn_datapath="fused-packed"))
    register(_dc.replace(_dwn(_m, _l, fused=True), name=_m + "-xla",
                         dwn_datapath="packed-xla"))

_BASE = _dwn("dwn-jsc-lg2400-x", 2400)
register(_dc.replace(_BASE, name="dwn-jsc-lg2400-opt1",
                     dwn_datapath="gather"))
register(_dc.replace(_BASE, name="dwn-jsc-lg2400-opt2",
                     dwn_datapath="gather", dwn_grouping="strided"))
register(_dc.replace(_BASE, name="dwn-jsc-lg2400-opt3",
                     dwn_datapath="gather", dwn_grouping="strided",
                     dwn_fused=True))


# Encoder-column pruning (the paper's future-work item (i)): only the
# thermometer columns actually wired by the trained mapping are encoded.
# Counts measured from the trained models (examples/train_jsc_dwn.py):
# sm-50 uses 209/3200 distinct columns (paper's bound: "300 or fewer"),
# md-360 uses 1237/3200.  dwn_bits is the per-feature ceiling.
register(_dc.replace(_dwn("dwn-jsc-md360-x", 360), name="dwn-jsc-md360-pruned",
                     dwn_bits=78, dwn_datapath="gather",
                     dwn_grouping="strided"))
register(_dc.replace(_dwn("dwn-jsc-sm50-x", 50), name="dwn-jsc-sm50-pruned",
                     dwn_bits=14, dwn_datapath="gather",
                     dwn_grouping="strided"))
register(_dc.replace(_BASE, name="dwn-jsc-lg2400-opt4",
                     dwn_datapath="gather", dwn_grouping="strided",
                     dwn_bits=170))   # lg-2400: ~2700/3200 used -> 169/feature


# --- encoding design-space axis (repro.sweep) ------------------------------
# Encoder resolution (T) and threshold placement are first-class fields of
# ``repro.dwn.DWNSpec``; ``DWNSpec(...).arch_config()`` derives a servable
# ArchConfig for any {preset tier} x {T} x {placement} grid point, so the
# sweep's throughput axis runs the *same* serving engine + backends as
# production, not a side copy of the datapath.

#: serving-alias tiers the sweep grids draw from: tier -> LUT-layer width m
SWEEP_TIERS = {"sm-10": 10, "sm-50": 50, "md-360": 360, "lg-2400": 2400}


def sweep_arch(preset: str, *, bits: int = 200,
               placement: str = "distributive",
               datapath: str = "fused-packed") -> ArchConfig:
    """Deprecated shim: the ArchConfig of one encoding-sweep grid point.

    The typed route is ``repro.dwn.DWNSpec(preset=..., bits=...,
    placement=..., datapath=...).arch_config()`` — this shim delegates
    there (same dwn_* field values) and warns.
    """
    import warnings
    warnings.warn(
        "configs.dwn_jsc.sweep_arch is deprecated; construct a "
        "repro.dwn.DWNSpec and use spec.arch_config() (the sweep "
        "pipeline passes DWNArtifacts to the ServingEngine directly)",
        DeprecationWarning, stacklevel=2)
    from ..dwn.spec import DWNSpec
    spec = DWNSpec(preset=preset, bits=bits, placement=placement,
                   datapath=datapath)
    return spec.arch_config(name=f"sweep-{preset}-T{bits}-{placement}-fused")


# Durable placement variants of the serving aliases, so the placement axis
# is also reachable from the serve CLI (--arch dwn-jsc-sm-uniform etc.).
for _pl in ("uniform", "gaussian"):
    register(_dc.replace(_dwn("dwn-jsc-sm-x", 50, fused=True),
                         name=f"dwn-jsc-sm-{_pl}", dwn_encoding=_pl,
                         dwn_datapath="fused-packed"))


# --- spec presets (repro.dwn) ----------------------------------------------
# The serving aliases double as *registered DWNSpec presets*: CLIs and the
# ServingEngine resolve ``--arch dwn-jsc-sm`` to a typed spec here instead
# of parsing arch-name suffixes.  Registration is deferred kwargs (specs
# validate against the serving-backend registry, which config loading must
# not import).
from ..dwn.spec import register_preset as _register_spec

for _tier, _preset in (("sm", "sm-50"), ("md", "md-360"), ("lg", "lg-2400")):
    _register_spec(f"dwn-jsc-{_tier}", preset=_preset,
                   datapath="fused-packed")
    _register_spec(f"dwn-jsc-{_tier}-xla", preset=_preset,
                   datapath="packed-xla")
for _pl in ("uniform", "gaussian"):
    _register_spec(f"dwn-jsc-sm-{_pl}", preset="sm-50", placement=_pl,
                   datapath="fused-packed")
