"""Architecture registry: ``--arch <id>`` resolves here."""

from __future__ import annotations

from .base import ArchConfig, SHAPES, ShapeConfig, cell_supported

_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    assert cfg.name not in _REGISTRY, cfg.name
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list[str]:
    _load_all()
    return sorted(_REGISTRY)


def assigned_archs() -> list[str]:
    """The 10 pool architectures (excludes the paper's own DWN models)."""
    _load_all()
    return sorted(n for n, c in _REGISTRY.items() if c.family != "dwn")


_LOADED = False


def _load_all():
    global _LOADED
    if _LOADED:
        return
    from . import (granite_moe_3b_a800m, mixtral_8x7b, whisper_large_v3,  # noqa
                   mamba2_1_3b, qwen3_8b, phi3_mini_3_8b, qwen2_7b,
                   qwen3_14b, recurrentgemma_2b, llava_next_34b, dwn_jsc,
                   dwn_mnist, dwn_lm_head)
    _LOADED = True
