"""mixtral-8x7b — 32L d_model=4096 32H (GQA kv=8) d_ff=14336, vocab 32000,
MoE 8 experts top-2, sliding-window attention (W=4096).
[arXiv:2401.04088; hf]
"""
from .base import ArchConfig
from .registry import register

CONFIG = register(ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    head_dim=128,
    rope_theta=1_000_000.0,
    swa_window=4096,
    num_experts=8,
    top_k=2,
    train_microbatches=4,
    source="arXiv:2401.04088; hf",
))
