"""Thermometer encoding: uniform and distributive (percentile) variants.

Faithful to Mecik & Kumm §III / Bacellar et al. (ESANN 2022, [23]):

* features are normalized to [-1, 1) before encoding;
* *distributive* encoding places the T thresholds of each feature at the
  (i+1)/(T+1) quantiles of the training distribution of that feature,
  producing non-uniform thresholds (each one an independent comparator in
  hardware — Fig. 3 of the paper);
* *uniform* encoding spaces thresholds evenly over [-1, 1);
* *gaussian* encoding (DWN [13] / Bacellar et al.) places thresholds at
  the normal quantiles of a per-feature N(mean, std) fit — the closed-form
  stand-in for distributive placement when only two moments of the
  training distribution are available.  A design-space axis swept by
  ``repro.sweep``.

The encode path is pure JAX so it is differentiable-adjacent (the bits are a
stop-gradient boundary; thresholds are buffers, never trained) and is the
oracle for the Pallas kernel in ``repro.kernels.thermometer``.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .bitpack import PackedBits

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ThermometerSpec:
    """Static description of a thermometer encoder bank.

    Attributes:
      num_features: F, number of real-valued input features.
      bits_per_feature: T, thresholds (= output bits) per feature. The paper
        uses T=200 for JSC.
      mode: "distributive" (percentile thresholds) or "uniform".
    """

    num_features: int
    bits_per_feature: int
    mode: str = "distributive"

    @property
    def total_bits(self) -> int:
        return self.num_features * self.bits_per_feature


#: Threshold-placement modes accepted by :func:`fit_thresholds` — the
#: encoding axis of the ``repro.sweep`` design space.
PLACEMENTS = ("distributive", "uniform", "gaussian")


def _norm_ppf(q: np.ndarray) -> np.ndarray:
    """Inverse standard-normal CDF (Acklam's rational approximation).

    Args:
      q: probabilities in (0, 1).

    Returns float64 z-scores with |relative error| < 1.2e-9 — more than
    enough for threshold placement (thresholds are float32 and then PTQ
    quantized anyway).  Implemented locally so the gaussian placement mode
    needs no scipy dependency.
    """
    q = np.asarray(q, np.float64)
    a = (-3.969683028665376e+01, 2.209460984245205e+02,
         -2.759285104469687e+02, 1.383577518672690e+02,
         -3.066479806614716e+01, 2.506628277459239e+00)
    b = (-5.447609879822406e+01, 1.615858368580409e+02,
         -1.556989798598866e+02, 6.680131188771972e+01,
         -1.328068155288572e+01)
    c = (-7.784894002430293e-03, -3.223964580411365e-01,
         -2.400758277161838e+00, -2.549732539343734e+00,
         4.374664141464968e+00, 2.938163982698783e+00)
    d = (7.784695709041462e-03, 3.224671290700398e-01,
         2.445134137142996e+00, 3.754408661907416e+00)
    plow, phigh = 0.02425, 1.0 - 0.02425
    x = np.empty_like(q)
    lo, hi = q < plow, q > phigh
    mid = ~(lo | hi)
    if lo.any():
        u = np.sqrt(-2.0 * np.log(q[lo]))
        x[lo] = ((((((c[0] * u + c[1]) * u + c[2]) * u + c[3]) * u + c[4])
                  * u + c[5])
                 / ((((d[0] * u + d[1]) * u + d[2]) * u + d[3]) * u + 1.0))
    if hi.any():
        u = np.sqrt(-2.0 * np.log(1.0 - q[hi]))
        x[hi] = -((((((c[0] * u + c[1]) * u + c[2]) * u + c[3]) * u + c[4])
                   * u + c[5])
                  / ((((d[0] * u + d[1]) * u + d[2]) * u + d[3]) * u + 1.0))
    if mid.any():
        u = q[mid] - 0.5
        r = u * u
        x[mid] = ((((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4])
                   * r + a[5]) * u
                  / (((((b[0] * r + b[1]) * r + b[2]) * r + b[3]) * r + b[4])
                     * r + 1.0))
    return x


def normalize_to_unit(x: np.ndarray, lo: np.ndarray | None = None,
                      hi: np.ndarray | None = None):
    """Affine-map features to [-1, 1) per paper §III. Returns (x, lo, hi)."""
    x = np.asarray(x, np.float32)
    if lo is None:
        lo = x.min(axis=0)
    if hi is None:
        hi = x.max(axis=0)
    span = np.maximum(hi - lo, 1e-12)
    xn = (x - lo) / span * 2.0 - 1.0
    # right-open interval [-1, 1)
    xn = np.clip(xn, -1.0, np.nextafter(np.float32(1.0), np.float32(0.0)))
    return xn.astype(np.float32), lo, hi


def fit_thresholds(x_train: np.ndarray, spec: ThermometerSpec) -> np.ndarray:
    """Fit per-feature thresholds on (already normalized) training data.

    Args:
      x_train: (N, F) float features, normalized to [-1, 1).
      spec: encoder shape + placement mode (one of :data:`PLACEMENTS`).

    Returns float32 array of shape (F, T), ascending along T.
    """
    x = np.asarray(x_train, np.float32)
    assert x.ndim == 2 and x.shape[1] == spec.num_features, x.shape
    T = spec.bits_per_feature
    qs = (np.arange(1, T + 1, dtype=np.float64)) / (T + 1)
    if spec.mode == "uniform":
        # Evenly spaced interior thresholds over [-1, 1).
        edges = np.linspace(-1.0, 1.0, T + 2, dtype=np.float32)[1:-1]
        th = np.tile(edges[None, :], (spec.num_features, 1))
    elif spec.mode == "distributive":
        th = np.quantile(x.astype(np.float64), qs, axis=0).T  # (F, T)
    elif spec.mode == "gaussian":
        # Normal quantiles of a per-feature N(mean, std) fit, clipped back
        # into the normalized feature range.
        mu = x.mean(axis=0, dtype=np.float64)                 # (F,)
        sd = np.maximum(x.std(axis=0, dtype=np.float64), 1e-6)
        z = _norm_ppf(qs)                                     # (T,)
        th = mu[:, None] + sd[:, None] * z[None, :]
        th = np.clip(th, -1.0,
                     np.nextafter(np.float32(1.0), np.float32(0.0)))
    else:
        raise ValueError(f"unknown thermometer mode: {spec.mode!r}; "
                         f"expected one of {PLACEMENTS}")
    # Ascending thresholds (quantile already is; enforce for safety).
    th = np.sort(th.astype(np.float32), axis=1)
    return th


@partial(jax.jit, static_argnames=("flatten",))
def encode(x: Array, thresholds: Array, *, flatten: bool = True) -> Array:
    """Thermometer-encode ``x`` against fixed ``thresholds``.

    Args:
      x: (..., F) float features in [-1, 1).
      thresholds: (F, T) ascending thresholds.
      flatten: if True return (..., F*T), else (..., F, T).

    Returns float32 bits in {0, 1}: bit t of feature f is ``x_f > th[f, t]``.
    """
    bits = (x[..., :, None] > thresholds).astype(jnp.float32)
    if flatten:
        bits = bits.reshape(*x.shape[:-1], -1)
    return bits


def encode_np(x: np.ndarray, thresholds: np.ndarray, flatten: bool = True):
    """NumPy twin of :func:`encode` for data-pipeline-side preprocessing."""
    bits = (x[..., :, None] > thresholds).astype(np.float32)
    if flatten:
        bits = bits.reshape(*x.shape[:-1], -1)
    return bits


def encode_packed(x: Array, thresholds: Array) -> PackedBits:
    """Thermometer-encode directly into packed uint32 bitplanes.

    Same compare as :func:`encode` — bit ``f*T + t`` of the flattened output
    is ``x_f > th[f, t]`` — but the result is a :class:`PackedBits` of
    ``F*T`` logical bits (LSB-first words, see ``bitpack``), 32x smaller
    than the float bit tensor.  Bit-exact with ``encode``:
    ``encode_packed(x, th).unpack() == encode(x, th)``.
    """
    bits = x[..., :, None] > thresholds                     # bool (..., F, T)
    flat = bits.reshape(*x.shape[:-1], -1)
    return PackedBits.pack(flat)


# ---------------------------------------------------------------------------
# Fixed-point quantization of thresholds and inputs — the PEN path.
# ---------------------------------------------------------------------------

def quantize_fixed_point(v: Array | np.ndarray, frac_bits: int):
    """Quantize to signed fixed point (1, n): 1 sign bit + n fractional bits.

    Representable grid: {-1, -1+2^-n, ..., 1-2^-n}. Total bit-width is
    ``1 + frac_bits`` (the paper quotes total width, e.g. "9-Bit" = (1, 8)).
    """
    scale = float(2 ** frac_bits)
    lib = jnp if isinstance(v, jax.Array) else np
    q = lib.round(v * scale) / scale
    return lib.clip(q, -1.0, (scale - 1.0) / scale)


def total_bits_for_frac(frac_bits: int) -> int:
    return 1 + frac_bits


def quantize_thresholds(thresholds, frac_bits: int):
    """PTQ of encoder thresholds to (1, n) — paper §III.

    After quantization, adjacent thresholds may collide; hardware generation
    deduplicates them (a collided threshold is one comparator, reused), and
    the encode() semantics are unchanged.
    """
    return quantize_fixed_point(thresholds, frac_bits)


def quantize_inputs(x, frac_bits: int):
    """Quantize the PEN input features to the same (1, n) grid."""
    return quantize_fixed_point(x, frac_bits)


def used_threshold_mask(mapping_idx: np.ndarray, spec: ThermometerSpec):
    """Which encoder output bits are actually wired into the LUT layer.

    Args:
      mapping_idx: (m, n) int array of candidate-bit indices chosen by the
        learnable mapping (finalized), indexing the flattened (F*T) bits.

    Returns boolean (F, T) mask of used thresholds. Only these comparators
    are emitted by the hardware generator (paper Fig. 3 discussion).
    """
    mask = np.zeros(spec.total_bits, dtype=bool)
    flat = np.asarray(mapping_idx).reshape(-1)
    flat = flat[(flat >= 0) & (flat < spec.total_bits)]
    mask[flat] = True
    return mask.reshape(spec.num_features, spec.bits_per_feature)


def distinct_used_thresholds(thresholds: np.ndarray, mask: np.ndarray,
                             frac_bits: int | None = None):
    """Count distinct (feature, threshold-value) comparators after CSE.

    Quantization collapses nearby thresholds onto the same fixed-point value;
    the generator emits one comparator per distinct value per feature.
    Returns (count, per_feature_counts).
    """
    th = np.asarray(thresholds)
    if frac_bits is not None:
        th = np.asarray(quantize_fixed_point(th, frac_bits))
    per_feature = []
    for f in range(th.shape[0]):
        vals = th[f][np.asarray(mask[f], bool)]
        per_feature.append(len(np.unique(vals)))
    return int(np.sum(per_feature)), per_feature
