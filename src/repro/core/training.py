"""DWN training loop (paper §III protocol) — single-host reference trainer.

The at-scale distributed trainer lives in ``repro.launch.train``; this module
is the faithful reproduction path for the JSC experiments: Adam, StepLR,
cross-entropy over τ-scaled popcounts, EFD gradients through the LUT layer.
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .model import (DWNConfig, init_dwn, loss_fn, apply_train, freeze,
                    eval_accuracy_hard)
from .classifier import accuracy as _acc
from .thermometer import quantize_fixed_point
from ..data.jsc import JSCData, batches
from ..optim.adam import Adam
from ..optim.schedule import step_lr, constant

Array = jax.Array


@dataclasses.dataclass
class TrainResult:
    params: dict
    buffers: dict
    cfg: DWNConfig
    history: list
    soft_test_acc: float


def _make_update(cfg: DWNConfig, opt: Adam, input_frac_bits: int | None):
    @jax.jit
    def update(params, opt_state, buffers, x, y):
        if input_frac_bits is not None:
            x = quantize_fixed_point(x, input_frac_bits)
        (loss, logits), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, buffers, cfg, x, y)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss, _acc(logits, y)
    return update


def _make_eval(cfg: DWNConfig, input_frac_bits: int | None):
    @jax.jit
    def evaluate(params, buffers, x, y):
        if input_frac_bits is not None:
            x = quantize_fixed_point(x, input_frac_bits)
        logits = apply_train(params, buffers, cfg, x)
        return _acc(logits, y)
    return evaluate


def eval_soft(params, buffers, cfg, x, y, input_frac_bits=None,
              batch: int = 4096) -> float:
    ev = _make_eval(cfg, input_frac_bits)
    accs, ns = [], []
    for i in range(0, x.shape[0], batch):
        xb, yb = jnp.asarray(x[i:i + batch]), jnp.asarray(y[i:i + batch])
        accs.append(float(ev(params, buffers, xb, yb)))
        ns.append(xb.shape[0])
    return float(np.average(accs, weights=ns))


def train_dwn(cfg: DWNConfig, data: JSCData, *, epochs: int = 30,
              batch: int = 128, lr: float = 1e-3, seed: int = 0,
              params=None, buffers=None, input_frac_bits: int | None = None,
              sched: str = "steplr", verbose: bool = True) -> TrainResult:
    """Train (or fine-tune, if params given) a DWN on JSC data."""
    key = jax.random.PRNGKey(seed)
    if params is None:
        params, buffers = init_dwn(key, cfg, data.x_train)
    steps_per_epoch = max(1, data.x_train.shape[0] // batch)
    schedule = (step_lr(lr, 30, 0.1, steps_per_epoch) if sched == "steplr"
                else constant(lr))
    # Tables clamp keeps the clipped-STE linear region meaningful.
    opt = Adam(lr=schedule, clamp=(-1.0, 1.0))
    opt_state = opt.init(params)
    update = _make_update(cfg, opt, input_frac_bits)

    history = []
    for epoch in range(epochs):
        t0 = time.time()
        losses = []
        for xb, yb in batches(data.x_train, data.y_train, batch,
                              seed=seed, epoch=epoch):
            params, opt_state, loss, acc = update(
                params, opt_state, buffers, jnp.asarray(xb), jnp.asarray(yb))
            losses.append(float(loss))
        te_acc = eval_soft(params, buffers, cfg, data.x_test, data.y_test,
                           input_frac_bits)
        history.append({"epoch": epoch, "loss": float(np.mean(losses)),
                        "test_acc": te_acc, "sec": time.time() - t0})
        if verbose:
            print(f"  epoch {epoch:3d} loss={np.mean(losses):.4f} "
                  f"test_acc={te_acc:.4f} ({time.time()-t0:.1f}s)", flush=True)
    return TrainResult(params, buffers, cfg, history,
                       history[-1]["test_acc"] if history else float("nan"))
