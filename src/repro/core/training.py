"""DWN training (paper §III protocol) — scan-compiled engine front-end.

``train_dwn`` keeps its historical signature but now runs on the
scan-compiled engine in :mod:`repro.training.engine`: a whole epoch is a
single device program (on-device ``lax.scan`` over minibatches, donated
params/optimizer state, StepLR folded into the optimizer-step counter,
losses fetched once per epoch) instead of a python-per-minibatch loop.
At fixed seed the loss/accuracy trajectory matches the pre-PR loop within
fp tolerance — same batch order, same schedule step count — so this is a
replacement, not a fork; the frozen pre-PR loop survives as
``repro.training.reference`` for parity tests and benchmarks.

``eval_soft`` keeps its pre-PR batching/averaging exactly, but reads its
jitted evaluator from the process-wide cache
(:mod:`repro.training.evaluator`): one compile per (cfg, input_frac_bits)
per process instead of one per call.

The at-scale distributed LM trainer lives in ``repro.launch.train``;
multi-seed / multi-grid-point DWN training lives in
``repro.training.batch``.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .model import DWNConfig
from ..data.jsc import JSCData

Array = jax.Array


@dataclasses.dataclass
class TrainResult:
    params: dict
    buffers: dict
    cfg: DWNConfig
    history: list
    soft_test_acc: float


def _make_eval(cfg: DWNConfig, input_frac_bits: int | None):
    """The compiled soft evaluator for (cfg, input_frac_bits) — one
    compile per process per key (see ``repro.training.evaluator``)."""
    from ..training.evaluator import cached_evaluator
    return cached_evaluator(cfg, input_frac_bits)


def eval_soft(params, buffers, cfg, x, y, input_frac_bits=None,
              batch: int = 4096) -> float:
    """Soft (training-path) accuracy, streamed in ``batch`` chunks.

    Same batching and sample-weighted averaging as pre-PR; the evaluator
    itself is cached, so repeated calls (per-epoch eval, PTQ probes)
    reuse one XLA executable per (cfg, input_frac_bits).
    """
    ev = _make_eval(cfg, input_frac_bits)
    accs, ns = [], []
    for i in range(0, x.shape[0], batch):
        xb, yb = jnp.asarray(x[i:i + batch]), jnp.asarray(y[i:i + batch])
        accs.append(float(ev(params, buffers, xb, yb)))
        ns.append(xb.shape[0])
    return float(np.average(accs, weights=ns))


def train_dwn(cfg: DWNConfig, data: JSCData, *, epochs: int = 30,
              batch: int = 128, lr: float = 1e-3, seed: int = 0,
              params=None, buffers=None, input_frac_bits: int | None = None,
              sched: str = "steplr", verbose: bool = True,
              eval_every: int = 1) -> TrainResult:
    """Train (or fine-tune, if params given) a DWN on JSC data.

    Runs on the scan-compiled engine; ``eval_every=0`` evaluates only
    after the last epoch and executes the whole run as one device
    program (the sweep's fast path).  Caller-held ``params``/``buffers``
    are copied before the engine's donated calls, never invalidated.
    """
    from ..training.engine import train_dwn_scan
    return train_dwn_scan(cfg, data, epochs=epochs, batch=batch, lr=lr,
                          seed=seed, params=params, buffers=buffers,
                          input_frac_bits=input_frac_bits, sched=sched,
                          eval_every=eval_every, verbose=verbose)
