"""DWNModel: thermometer encoder -> LUT layer stack -> popcount classifier.

Mirrors the architecture of Fig. 1 in the paper. The JSC variants used by the
paper (single LUT layer) are provided as presets:

    sm-10   m=10      sm-50   m=50
    md-360  m=360     lg-2400 m=2400

all with F=16 features, T=200 thermometer bits/feature, n=6 LUT fan-in and 5
classes. Multi-layer stacks are supported (DWN [13] allows them); layer l+1
draws its candidate bits from layer l's outputs.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .thermometer import (ThermometerSpec, encode, encode_packed,
                          fit_thresholds, quantize_fixed_point)
from .lut_layer import (LUTLayerSpec, init_lut_layer, lut_layer_apply,
                        lut_layer_apply_stopgrad, finalize_mapping,
                        binarize_tables, lut_eval_hard, lut_eval_hard_packed)
from .classifier import (group_popcount, group_popcount_packed,
                         logits_from_counts, cross_entropy, accuracy,
                         predict)

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DWNConfig:
    num_features: int = 16
    bits_per_feature: int = 200
    encoding: str = "distributive"          # or "uniform"
    lut_counts: tuple = (50,)               # per LUT layer; last must % classes == 0
    fan_in: int = 6
    num_classes: int = 5
    tau: float | None = None                # softmax temperature; None = auto

    @property
    def thermometer(self) -> ThermometerSpec:
        return ThermometerSpec(self.num_features, self.bits_per_feature,
                               self.encoding)

    @property
    def group_size(self) -> int:
        return self.lut_counts[-1] // self.num_classes

    @property
    def tau_value(self) -> float:
        if self.tau is not None:
            return self.tau
        return max(0.3, self.group_size / 12.0)

    def layer_specs(self) -> list[LUTLayerSpec]:
        specs, C = [], self.thermometer.total_bits
        for m in self.lut_counts:
            specs.append(LUTLayerSpec(m, self.fan_in, C))
            C = m
        assert self.lut_counts[-1] % self.num_classes == 0
        return specs


# Paper presets (Table I / §II): name -> lut count of the single LUT layer.
JSC_PRESETS = {
    "sm-10": DWNConfig(lut_counts=(10,)),
    "sm-50": DWNConfig(lut_counts=(50,)),
    "md-360": DWNConfig(lut_counts=(360,)),
    "lg-2400": DWNConfig(lut_counts=(2400,)),
}

# Baseline accuracies the paper holds PTQ to (§III).
PAPER_BASELINE_ACC = {"sm-10": 0.711, "sm-50": 0.740, "md-360": 0.756,
                      "lg-2400": 0.763}


def init_dwn(key: Array, cfg: DWNConfig, x_train: np.ndarray):
    """Returns (params, buffers): params trainable, buffers = thresholds."""
    thresholds = fit_thresholds(x_train, cfg.thermometer)
    keys = jax.random.split(key, len(cfg.lut_counts))
    layers = [init_lut_layer(k, s) for k, s in zip(keys, cfg.layer_specs())]
    return {"layers": layers}, {"thresholds": jnp.asarray(thresholds)}


def apply_train_from_bits(params, cfg: DWNConfig, bits: Array) -> Array:
    """Differentiable forward from pre-encoded bits: (B, F*T) -> logits.

    The scan-friendly entry point: thermometer thresholds are buffers
    (never trained), so the training engine encodes the dataset once and
    streams {0,1} bit rows here instead of re-encoding every minibatch.
    Accepts any dtype whose values are {0, 1} (uint8 storage is 4x
    smaller on device); bit-identical to ``apply_train`` on the same rows.
    """
    bits = jax.lax.stop_gradient(bits.astype(jnp.float32))
    first = True
    for layer in params["layers"]:
        bits = (lut_layer_apply_stopgrad(layer, bits) if first
                else lut_layer_apply(layer, bits))
        first = False
    counts = group_popcount(bits, cfg.num_classes)
    return logits_from_counts(counts, cfg.tau_value)


def apply_train(params, buffers, cfg: DWNConfig, x: Array) -> Array:
    """Differentiable forward: raw features -> class logits."""
    bits = encode(x, buffers["thresholds"])                  # (B, F*T)
    return apply_train_from_bits(params, cfg, bits)


def loss_fn(params, buffers, cfg: DWNConfig, x: Array, y: Array):
    logits = apply_train(params, buffers, cfg, x)
    return cross_entropy(logits, y), logits


def loss_fn_from_bits(params, cfg: DWNConfig, bits: Array, y: Array):
    """Cross-entropy twin of :func:`loss_fn` over pre-encoded bits."""
    logits = apply_train_from_bits(params, cfg, bits)
    return cross_entropy(logits, y), logits


@dataclasses.dataclass
class FrozenDWN:
    """Hardware-semantics model: what the generator emits as RTL."""
    cfg: DWNConfig
    thresholds: np.ndarray                   # (F, T), possibly quantized
    mapping_idx: list                        # per layer (m, n) int32
    tables_bin: list                         # per layer (m, 2^n) int {0,1}
    input_frac_bits: int | None = None       # (1, n) PEN quantization, None=TEN


def freeze(params, buffers, cfg: DWNConfig,
           input_frac_bits: int | None = None) -> FrozenDWN:
    mapping = [np.asarray(finalize_mapping(l)) for l in params["layers"]]
    tables = [np.asarray(binarize_tables(l)) for l in params["layers"]]
    th = np.asarray(buffers["thresholds"])
    if input_frac_bits is not None:
        th = np.asarray(quantize_fixed_point(th, input_frac_bits))
    return FrozenDWN(cfg, th, mapping, tables, input_frac_bits)


def apply_hard(frozen: FrozenDWN, x: Array) -> Array:
    """Bit-exact inference path (counts). Quantizes inputs if PEN."""
    if frozen.input_frac_bits is not None:
        x = quantize_fixed_point(x, frozen.input_frac_bits)
    bits = encode(x, jnp.asarray(frozen.thresholds))
    for idx, tab in zip(frozen.mapping_idx, frozen.tables_bin):
        bits = lut_eval_hard(bits, jnp.asarray(idx), jnp.asarray(tab))
    return group_popcount(bits, frozen.cfg.num_classes)


def apply_hard_packed(frozen: FrozenDWN, x: Array) -> Array:
    """Packed-bitplane twin of :func:`apply_hard` (counts, bit-exact).

    Same comparisons, same LUT reads, same counts — but every intermediate
    bit tensor is a ``PackedBits`` of uint32 words (32x smaller than the
    float path).  ``apply_hard`` stays the oracle; the Pallas fast path is
    ``repro.kernels.fused.ops.forward_packed``.
    """
    if frozen.input_frac_bits is not None:
        x = quantize_fixed_point(x, frozen.input_frac_bits)
    packed = encode_packed(x, jnp.asarray(frozen.thresholds))
    for idx, tab in zip(frozen.mapping_idx, frozen.tables_bin):
        packed = lut_eval_hard_packed(packed, jnp.asarray(idx),
                                      jnp.asarray(tab))
    return group_popcount_packed(packed, frozen.cfg.num_classes)


def _eval_accuracy(fn, x: np.ndarray, y: np.ndarray, batch: int) -> float:
    hits = 0
    n = x.shape[0]
    for i in range(0, n, batch):
        pred = np.asarray(fn(jnp.asarray(x[i:i + batch])))
        hits += int((pred == y[i:i + batch]).sum())
    return hits / n


def eval_accuracy_hard(frozen: FrozenDWN, x: np.ndarray, y: np.ndarray,
                       batch: int = 4096) -> float:
    """Streaming hard-path accuracy (hardware semantics).

    Args:
      frozen: frozen model (the RTL semantics).
      x: (N, F) float features; y: (N,) int labels.
      batch: evaluation batch size (one jit trace per distinct tail size).

    Returns accuracy in [0, 1].
    """
    fn = jax.jit(lambda xb: predict(apply_hard(frozen, xb)))
    return _eval_accuracy(fn, x, y, batch)


def eval_accuracy_hard_packed(frozen: FrozenDWN, x: np.ndarray,
                              y: np.ndarray, batch: int = 4096) -> float:
    """Packed-bitplane twin of :func:`eval_accuracy_hard`.

    Same accuracy bit-for-bit (``apply_hard_packed`` is exact vs
    ``apply_hard``) but every intermediate bit tensor is uint32 words —
    the evaluator the ``repro.sweep`` accuracy axis runs on.
    """
    fn = jax.jit(lambda xb: predict(apply_hard_packed(frozen, xb)))
    return _eval_accuracy(fn, x, y, batch)
