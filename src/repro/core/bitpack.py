"""Packed-bitplane representation: 32 logical bits per uint32 word.

The float inference path stores every thermometer/LUT bit as a float32 — a
32x memory blow-up that makes the encode/LUT hot path bandwidth-bound (the
TPU-side analogue of the paper's "encoding dominates LUT usage" finding).
This module is the single source of truth for the packed bit-format used by
``apply_hard_packed`` and the packed Pallas kernels:

Bit-format convention
---------------------
* A logical bit-vector of length ``N`` packs along its **last axis** into
  ``W = ceil(N / 32)`` little-endian words: logical bit ``i`` lives in word
  ``i >> 5`` at bit position ``i & 31`` (**LSB-first** within a word).
* When ``N % 32 != 0`` the trailing pad bits of the last word are **zero**;
  every producer must maintain this invariant (popcounts rely on it).
* Thermometer outputs pack the *flattened* ``(F*T,)`` bit order — feature-
  major, bit ``f*T + t`` — so LUT mapping indices address packed words
  directly as ``(idx >> 5, idx & 31)`` with no per-feature padding.

``PackedBits`` is a pytree (words traced, ``num_bits`` static) so packed
values flow through ``jax.jit`` unchanged.  NumPy twins (`pack_bits_np`,
`unpack_bits_np`, `popcount_u32_np`) serve the data-pipeline side.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

WORD_BITS = 32

# SWAR popcount constants (Hacker's Delight fig. 5-2).
_M1, _M2, _M4, _H01 = 0x55555555, 0x33333333, 0x0F0F0F0F, 0x01010101


def words_for_bits(num_bits: int) -> int:
    """ceil(num_bits / 32): uint32 words holding a num_bits-long vector."""
    return (num_bits + WORD_BITS - 1) // WORD_BITS


def pack_bits(bits: Array) -> Array:
    """Pack {0,1} values (..., N) -> (..., ceil(N/32)) uint32, LSB-first.

    Accepts any numeric/bool dtype; any non-zero entry is a set bit.
    """
    bits = jnp.asarray(bits)
    n = bits.shape[-1]
    w = words_for_bits(n)
    pad = [(0, 0)] * (bits.ndim - 1) + [(0, w * WORD_BITS - n)]
    b = jnp.pad((bits != 0).astype(jnp.uint32), pad)
    b = b.reshape(*bits.shape[:-1], w, WORD_BITS)
    weights = jnp.left_shift(jnp.uint32(1),
                             jnp.arange(WORD_BITS, dtype=jnp.uint32))
    return jnp.sum(b * weights, axis=-1, dtype=jnp.uint32)


def unpack_bits(words: Array, num_bits: int,
                dtype=jnp.float32) -> Array:
    """Inverse of :func:`pack_bits`.

    Args:
      words: (..., W) uint32 packed words, LSB-first.
      num_bits: logical bit count N (pad bits beyond N are dropped).
      dtype: output dtype of the {0, 1} values.

    Returns (..., num_bits) bits.
    """
    words = jnp.asarray(words, jnp.uint32)
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    b = jnp.bitwise_and(jnp.right_shift(words[..., :, None], shifts),
                        jnp.uint32(1))
    b = b.reshape(*words.shape[:-1], words.shape[-1] * WORD_BITS)
    return b[..., :num_bits].astype(dtype)


def pack_bits_np(bits: np.ndarray) -> np.ndarray:
    """NumPy twin of :func:`pack_bits` (data-pipeline side)."""
    bits = np.asarray(bits)
    n = bits.shape[-1]
    w = words_for_bits(n)
    pad = [(0, 0)] * (bits.ndim - 1) + [(0, w * WORD_BITS - n)]
    b = np.pad((bits != 0).astype(np.uint32), pad)
    b = b.reshape(*bits.shape[:-1], w, WORD_BITS)
    weights = (np.uint32(1) << np.arange(WORD_BITS, dtype=np.uint32))
    return np.sum(b * weights, axis=-1, dtype=np.uint32)


def unpack_bits_np(words: np.ndarray, num_bits: int,
                   dtype=np.float32) -> np.ndarray:
    """NumPy twin of :func:`unpack_bits`."""
    words = np.asarray(words, np.uint32)
    shifts = np.arange(WORD_BITS, dtype=np.uint32)
    b = (words[..., :, None] >> shifts) & np.uint32(1)
    b = b.reshape(*words.shape[:-1], words.shape[-1] * WORD_BITS)
    return b[..., :num_bits].astype(dtype)


def popcount_u32(v: Array) -> Array:
    """Per-word popcount of a uint32 array (SWAR; VPU/kernel-safe).

    Args:
      v: uint32 words (any shape).

    Returns uint32 set-bit counts per word, in [0, 32], same shape.
    """
    v = jnp.asarray(v, jnp.uint32)
    v = v - jnp.bitwise_and(jnp.right_shift(v, 1), jnp.uint32(_M1))
    v = (jnp.bitwise_and(v, jnp.uint32(_M2))
         + jnp.bitwise_and(jnp.right_shift(v, 2), jnp.uint32(_M2)))
    v = jnp.bitwise_and(v + jnp.right_shift(v, 4), jnp.uint32(_M4))
    return jnp.right_shift(v * jnp.uint32(_H01), 24)


def popcount_u32_np(v: np.ndarray) -> np.ndarray:
    """NumPy twin of :func:`popcount_u32`."""
    v = np.asarray(v, np.uint32)
    v = v - ((v >> np.uint32(1)) & np.uint32(_M1))
    v = (v & np.uint32(_M2)) + ((v >> np.uint32(2)) & np.uint32(_M2))
    v = (v + (v >> np.uint32(4))) & np.uint32(_M4)
    return (v * np.uint32(_H01)) >> np.uint32(24)


def select_packed_bits(words: Array, word_idx: Array,
                       bit_off: Array) -> Array:
    """Read mapped bits out of packed words with shift/AND.

    words (..., W) uint32; word_idx / bit_off (m, n) int32 — the wire's
    word index ``idx >> 5`` and LSB-first position ``idx & 31``.
    Returns (..., m, n) int32 {0,1}.  Pure jnp: shared by the core packed
    path and the Pallas kernels so the addressing convention lives once.
    """
    m, n = word_idx.shape
    g = jnp.take(words, word_idx.reshape(-1), axis=-1)       # (..., m*n)
    off = bit_off.reshape(-1).astype(jnp.uint32)
    sel = jnp.bitwise_and(jnp.right_shift(g, off), jnp.uint32(1))
    return sel.reshape(*words.shape[:-1], m, n).astype(jnp.int32)


def lut_addresses(sel: Array) -> Array:
    """(..., m, n) {0,1} int32 -> (..., m) LUT address via shift/OR."""
    n = sel.shape[-1]
    addr = jnp.zeros(sel.shape[:-1], jnp.int32)
    for i in range(n):
        addr = jnp.bitwise_or(addr, jnp.left_shift(sel[..., i], i))
    return addr


def masked_group_counts(words: Array, masks: Array) -> Array:
    """Masked SWAR popcount: words (..., W) uint32, masks (G, W) uint32 ->
    (..., G) float32 per-group set-bit counts.  The packed classifier core,
    shared by ``group_popcount_packed`` and the popcount/fused kernels."""
    masked = jnp.bitwise_and(words[..., None, :], masks)     # (..., G, W)
    counts = jnp.sum(popcount_u32(masked), axis=-1, dtype=jnp.uint32)
    return counts.astype(jnp.float32)


def group_masks_np(num_bits: int, num_groups: int) -> np.ndarray:
    """(G, W) uint32 masks selecting each group's contiguous bit-range.

    Group ``g`` owns logical bits ``[g*gs, (g+1)*gs)`` with
    ``gs = num_bits // num_groups`` — the classifier's class groups.  Word
    boundaries need not align with group boundaries; masked popcount handles
    arbitrary ``gs``.
    """
    assert num_bits % num_groups == 0, (num_bits, num_groups)
    gs = num_bits // num_groups
    w = words_for_bits(num_bits)
    bit_of = np.arange(w * WORD_BITS)
    group_of = np.where(bit_of < num_bits, bit_of // gs, -1)
    masks = np.zeros((num_groups, w), np.uint32)
    weights = (np.uint32(1) << np.arange(WORD_BITS, dtype=np.uint32))
    for g in range(num_groups):
        sel = (group_of == g).reshape(w, WORD_BITS).astype(np.uint32)
        masks[g] = np.sum(sel * weights, axis=-1, dtype=np.uint32)
    return masks


@functools.lru_cache(maxsize=None)
def _group_masks_np_cached(num_bits: int, num_groups: int) -> np.ndarray:
    return group_masks_np(num_bits, num_groups)


def group_masks(num_bits: int, num_groups: int) -> Array:
    """Memoized twin of :func:`group_masks_np` staged for device use.

    The masks depend only on (num_bits, num_groups) — per model, not per
    batch — so every classifier call site shares one cached numpy build.
    Only the *numpy* array is memoized: the ``jnp.asarray`` happens per
    call so a first call from inside a ``jit`` trace can never cache a
    tracer (leaked tracers poison every later trace).
    """
    return jnp.asarray(_group_masks_np_cached(num_bits, num_groups))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedBits:
    """A logical bit-vector in packed uint32 words (see module docstring).

    Attributes:
      words: (..., W) uint32 with W = ceil(num_bits / 32); pad bits zero.
      num_bits: logical bit count N (static under jit).
    """

    words: Array
    num_bits: int

    @classmethod
    def pack(cls, bits: Array) -> "PackedBits":
        return cls(pack_bits(bits), bits.shape[-1])

    def unpack(self, dtype=jnp.float32) -> Array:
        return unpack_bits(self.words, self.num_bits, dtype)

    @property
    def num_words(self) -> int:
        return self.words.shape[-1]

    @property
    def batch_shape(self) -> tuple:
        return self.words.shape[:-1]

    def tree_flatten(self):
        return (self.words,), self.num_bits

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux)


__all__ = [
    "WORD_BITS", "words_for_bits", "pack_bits", "unpack_bits",
    "pack_bits_np", "unpack_bits_np", "popcount_u32", "popcount_u32_np",
    "select_packed_bits", "lut_addresses", "masked_group_counts",
    "group_masks_np", "group_masks", "PackedBits",
]
