"""DWN core: the paper's contribution as composable JAX modules."""

from .thermometer import (ThermometerSpec, fit_thresholds, encode, encode_np,
                          quantize_fixed_point, quantize_thresholds,
                          quantize_inputs, used_threshold_mask,
                          distinct_used_thresholds, normalize_to_unit,
                          total_bits_for_frac)
from .lut_layer import (LUTLayerSpec, init_lut_layer, lut_layer_apply,
                        finalize_mapping, binarize_tables, lut_eval_hard)
from .classifier import (group_popcount, logits_from_counts, predict,
                         cross_entropy, accuracy)
from .model import (DWNConfig, JSC_PRESETS, PAPER_BASELINE_ACC, init_dwn,
                    apply_train, loss_fn, freeze, FrozenDWN, apply_hard,
                    eval_accuracy_hard)
from .training import train_dwn, TrainResult, eval_soft
from .quantize import (ptq_bitwidth_search, finetune_bitwidth_search,
                       PTQResult, FTResult)
