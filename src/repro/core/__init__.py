"""DWN core: the paper's contribution as composable JAX modules."""

from .bitpack import (PackedBits, pack_bits, unpack_bits, pack_bits_np,
                      unpack_bits_np, popcount_u32, popcount_u32_np,
                      words_for_bits, group_masks_np)
from .thermometer import (PLACEMENTS, ThermometerSpec, fit_thresholds,
                          encode, encode_np,
                          encode_packed, quantize_fixed_point,
                          quantize_thresholds, quantize_inputs,
                          used_threshold_mask, distinct_used_thresholds,
                          normalize_to_unit, total_bits_for_frac)
from .lut_layer import (LUTLayerSpec, init_lut_layer, lut_layer_apply,
                        finalize_mapping, binarize_tables, lut_eval_hard,
                        lut_eval_hard_packed)
from .classifier import (group_popcount, group_popcount_packed,
                         logits_from_counts, predict, cross_entropy,
                         accuracy)
from .model import (DWNConfig, JSC_PRESETS, PAPER_BASELINE_ACC, init_dwn,
                    apply_train, loss_fn, freeze, FrozenDWN, apply_hard,
                    apply_hard_packed, eval_accuracy_hard,
                    eval_accuracy_hard_packed)
from .training import train_dwn, TrainResult, eval_soft
from .quantize import (ptq_bitwidth_search, finetune_bitwidth_search,
                       PTQResult, FTResult)
