"""Data-driven warm start for DWN training (documented training addition).

The DWN learnable mapping starts from random wiring in [13].  On tasks
whose signal is concentrated in a few dominant cuts (real JSC, and our
surrogate by construction) the smallest models (sm-10: two LUT6 per
class) are severely optimization-limited from a random start: SGD+EFD
must discover ~10 informative bits out of 3,200 candidates, and early
table noise pushes the mapping away from them.

This module builds a principled warm start:

* **wiring**: the LUTs of class ``c`` see (a) the top thermometer bits by
  |corr| with ``1[y=c]`` (distinct features, near-duplicate thresholds
  suppressed) and (b) the top bit of *each other class* — so a LUT can
  realize "my class fires and the others don't", which is what the
  popcount/argmax head needs;
* **tables**: the empirical majority vote  P(y=c | address) > P(y=c)
  per truth-table entry (the Bayes-optimal boolean function for the
  chosen wiring);
* **scores**: biased (+`score_bias`) at the chosen wires so the learnable
  mapping starts there but remains free to move.

Gradient training (EFD + learnable mapping, unchanged) then refines both.
EXPERIMENTS.md §Repro reports the paper-faithful random-init results
next to the warm-started ones.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .model import DWNConfig, init_dwn
from .thermometer import fit_thresholds, encode_np


def bit_label_correlation(bits: np.ndarray, y: np.ndarray,
                          num_classes: int) -> np.ndarray:
    """(n, C_bits) bits, labels -> (C_bits, classes) |corr|-signed matrix."""
    b = (bits - bits.mean(0)) / (bits.std(0) + 1e-9)
    out = np.zeros((bits.shape[1], num_classes), np.float32)
    for c in range(num_classes):
        t = (y == c).astype(np.float32)
        t = (t - t.mean()) / (t.std() + 1e-9)
        out[:, c] = b.T @ t / len(y)
    return out


def _top_bits(corr_c: np.ndarray, T: int, k: int, *, suppress: int = 20,
              max_per_feature: int = 1) -> list[int]:
    """Top-k bits by |corr|, distinct-ish: suppress near thresholds and
    cap per-feature picks so wiring spans features."""
    order = np.argsort(-np.abs(corr_c))
    chosen: list[int] = []
    taken: dict[int, list[int]] = {}
    for cand in order:
        f, t = int(cand // T), int(cand % T)
        ts = taken.setdefault(f, [])
        if len(ts) >= max_per_feature:
            continue
        if any(abs(t - t2) <= suppress for t2 in ts):
            continue
        chosen.append(int(cand))
        ts.append(t)
        if len(chosen) >= k:
            break
    return chosen


def warmstart_dwn(key, cfg: DWNConfig, x_train: np.ndarray,
                  y_train: np.ndarray, *, score_bias: float = 1.0,
                  sample_cap: int = 10_000):
    """Returns (params, buffers) warm-started for the first LUT layer."""
    params, buffers = init_dwn(key, cfg, x_train)
    th = np.asarray(buffers["thresholds"])
    n_fit = min(sample_cap, x_train.shape[0])
    bits = encode_np(x_train[:n_fit], th)
    y = y_train[:n_fit]
    C = cfg.num_classes
    T = cfg.bits_per_feature
    corr = bit_label_correlation(bits, y, C)

    m, n, Cand = params["layers"][0]["scores"].shape
    gs = m // C
    scores = np.asarray(params["layers"][0]["scores"]).copy()
    tables = np.asarray(params["layers"][0]["tables"]).copy()

    own_bits = {c: _top_bits(corr[:, c], T, max(2 * gs, 6)) for c in range(C)}

    for c in range(C):
        for j in range(gs):
            lut = c * gs + j
            # cross-class bits diversify across this class's LUTs
            others = [own_bits[o][j % len(own_bits[o])]
                      for o in range(C) if o != c and own_bits[o]]
            own = own_bits[c][2 * j:2 * j + 2] or own_bits[c][:2]
            wires = (own + others)[:n]
            while len(wires) < n:
                wires.append(own_bits[c][len(wires) % len(own_bits[c])])
            # scores: bias the chosen wiring
            for i, w in enumerate(wires):
                scores[lut, i, w] += score_bias
            # tables: empirical majority vote at each address
            sel = bits[:, wires]                                # (nfit, n)
            addr = (sel.astype(np.int64)
                    * (1 << np.arange(n))[None, :]).sum(1)
            base = (y == c).mean()
            tab = np.full(2 ** n, -0.5, np.float32)
            for a in np.unique(addr):
                mask = addr == a
                p = (y[mask] == c).mean()
                tab[a] = 0.5 if p > base else -0.5
            tables[lut] = tab

    params["layers"][0]["scores"] = jnp.asarray(scores)
    params["layers"][0]["tables"] = jnp.asarray(tables)
    return params, buffers
