"""Differentiable LUT layer with Extended-Finite-Difference gradients.

Implements the DWN LUT layer of Bacellar et al. 2024 ([13] in the paper):

* each of the ``m`` LUTs has ``n`` (default 6) binary inputs selected from a
  pool of ``C`` candidate bits by a **learnable mapping** — a score matrix
  (m, n, C); forward uses the hard argmax selection (what the hardware
  wires), backward relaxes it through a softmax (straight-through);
* each LUT holds a real-valued truth table θ ∈ R^{2^n}; forward reads
  ``θ[addr]`` at the address formed by the selected bits and binarizes with
  sign; backward uses the **Extended Finite Difference** (EFD): the gradient
  w.r.t. input bit *i* is the table difference between the two addresses that
  flip bit *i*, and the gradient w.r.t. θ is routed straight-through to the
  addressed entry.

TPU-native notes (DESIGN.md §3): the hard selection is a gather in the
forward pass (cheap) and a one-hot-matmul in the backward pass (MXU). The
binarized inference path (``lut_eval_hard``) is the oracle mirrored by the
Pallas kernel in ``repro.kernels.lut_eval``.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from .bitpack import PackedBits, select_packed_bits, lut_addresses

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class LUTLayerSpec:
    num_luts: int          # m
    fan_in: int = 6        # n (physical LUT6)
    num_candidates: int = 0  # C — set from the encoder / previous layer

    @property
    def table_size(self) -> int:
        return 2 ** self.fan_in


def init_lut_layer(key: Array, spec: LUTLayerSpec):
    """Initialize {scores, tables}. Tables ~ U(-1,1); scores small normal."""
    k1, k2 = jax.random.split(key)
    scores = jax.random.normal(k1, (spec.num_luts, spec.fan_in,
                                    spec.num_candidates), jnp.float32) * 0.01
    tables = jax.random.uniform(k2, (spec.num_luts, spec.table_size),
                                jnp.float32, minval=-1.0, maxval=1.0)
    return {"scores": scores, "tables": tables}


def _addresses(sel_bits: Array, fan_in: int) -> Array:
    """(B, m, n) {0,1} -> (B, m) int32 address; bit i has weight 2^i."""
    weights = (2 ** jnp.arange(fan_in, dtype=jnp.int32))
    return jnp.sum(sel_bits.astype(jnp.int32) * weights, axis=-1)


def first_max_index(x: Array, vmax: Array | None = None) -> Array:
    """First index of the row maximum over the last axis (== jnp.argmax).

    Bit-identical to ``jnp.argmax(x, axis=-1)`` — the output is an integer,
    so there is no fp ambiguity — but lowers to plain vectorized max/min
    reductions instead of XLA's variadic (value, index) reduce, which is
    several times slower on CPU for the (m, n, C) score tensors the
    training hot loop argmaxes every step.

    Args:
      x: (..., C) values.
      vmax: optional precomputed ``jnp.max(x, -1, keepdims=True)`` when the
        caller needs the row max anyway (saves one full reduction).
    """
    if vmax is None:
        vmax = jnp.max(x, axis=-1, keepdims=True)
    idx = jnp.arange(x.shape[-1], dtype=jnp.int32)
    return jnp.min(jnp.where(x == vmax, idx, x.shape[-1]), axis=-1)


# ---------------------------------------------------------------------------
# Core custom-VJP op: binarized table lookup with EFD backward.
# Inputs: sel_bits (B, m, n) in {0,1} float; tables (m, 2^n) float.
# Output: bits (B, m) in {0,1} float.
# ---------------------------------------------------------------------------

@jax.custom_vjp
def _lut_lookup_efd(sel_bits: Array, tables: Array) -> Array:
    fan_in = sel_bits.shape[-1]
    addr = _addresses(sel_bits, fan_in)                      # (B, m)
    vals = _gather_tables(tables, addr)                      # (B, m)
    return (vals > 0.0).astype(jnp.float32)


def _gather_tables(tables: Array, addr: Array) -> Array:
    """tables (m, S), addr (B, m) -> (B, m) gathered real values.

    Flat-index take: ``flat[lut * S + addr]`` gathers the same entries as a
    broadcast + take_along_axis but without staging the (B, m, S) broadcast.
    """
    m, S = tables.shape
    offs = (jnp.arange(m, dtype=jnp.int32) * S)[None, :]     # (1, m)
    return jnp.take(tables.reshape(-1), addr + offs, axis=0)


def _lut_lookup_fwd(sel_bits, tables):
    fan_in = sel_bits.shape[-1]
    addr = _addresses(sel_bits, fan_in)
    vals = _gather_tables(tables, addr)
    out = (vals > 0.0).astype(jnp.float32)
    # vals ride in the residuals: the backward needs them for the
    # clipped-STE mask and re-gathering inside lax.scan is pure waste
    return out, (tables, addr, vals)


def _lut_lookup_bwd(res, g):
    tables, addr, vals = res
    m, S = tables.shape
    n = S.bit_length() - 1                                   # S == 2^n

    # Straight-through binarize: dL/dvals = g, clipped to the linear region
    # (standard clipped-STE; tables are kept in [-1, 1] by the optimizer).
    g_vals = g * (jnp.abs(vals) <= 1.0).astype(g.dtype)

    # Gradient to tables: scatter g at (lut, addr). One-hot einsum keeps it
    # MXU-friendly and avoids scatter.
    onehot = jax.nn.one_hot(addr, S, dtype=g.dtype)          # (B, m, S)
    d_tables = jnp.einsum("bm,bms->ms", g_vals, onehot)

    # EFD gradient to each selected input bit i:
    #   d vals / d bit_i = tables[lut, addr | 2^i] - tables[lut, addr & ~2^i]
    bit_w = (2 ** jnp.arange(n, dtype=jnp.int32))            # (n,)
    addr_hi = addr[..., None] | bit_w                        # (B, m, n)
    addr_lo = addr[..., None] & (~bit_w)
    t_hi = _gather_tables_multi(tables, addr_hi)             # (B, m, n)
    t_lo = _gather_tables_multi(tables, addr_lo)
    d_sel = g_vals[..., None] * (t_hi - t_lo)                # (B, m, n)
    return d_sel, d_tables


def _gather_tables_multi(tables: Array, addr: Array) -> Array:
    """tables (m, S), addr (B, m, n) -> (B, m, n) via flat-index take."""
    m, S = tables.shape
    offs = (jnp.arange(m, dtype=jnp.int32) * S)[None, :, None]  # (1, m, 1)
    return jnp.take(tables.reshape(-1), addr + offs, axis=0)


_lut_lookup_efd.defvjp(_lut_lookup_fwd, _lut_lookup_bwd)


# ---------------------------------------------------------------------------
# Learnable mapping: hard argmax selection forward, softmax STE backward.
# ---------------------------------------------------------------------------

def _softmax_from_max(scores: Array, vmax: Array) -> Array:
    """softmax(scores, -1) given the row max (same expression as
    ``jax.nn.softmax``; the max is shared with the forward's argmax so
    the backward does one fewer full reduction over (m, n, C))."""
    e = jnp.exp(scores - vmax)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def _d_scores(scores: Array, vmax: Array, g: Array, bits: Array) -> Array:
    """dL/dscores of the softmax-STE relaxation, reassociated.

    With p = softmax(scores) the textbook form is
    ``p * (gb - gx)`` where x_soft[b,m,n] = Σ_c p[m,n,c]·bits[b,c],
    gb = Σ_b g·bits and gx = Σ_b g·x_soft.  Two reassociations, both
    O(1e-9)-level fp-neutral and large on a bandwidth-bound CPU step:

    * gx = Σ_c p·gb — folds the second (B·m·n·C)-flop x_soft einsum into
      a multiply-reduce over an array we need anyway;
    * p is never materialized: with e = exp(scores - max), s = Σe the
      result is e·(gb - gxn/s)/s with gxn = Σ_c e·gb — one fewer full
      (m, n, C) division pass.

    This is the training hot loop's dominant cost; the pre-PR form
    survives verbatim in ``repro.training.reference`` as the baseline.
    """
    e = jnp.exp(scores - vmax)                               # (m, n, C)
    se = jnp.sum(e, axis=-1, keepdims=True)
    gb = jnp.einsum("bmn,bc->mnc", g, bits)                  # Σ_b g·bits
    gxn = jnp.sum(e * gb, axis=-1, keepdims=True)
    return e * (gb - gxn / se) / se


def _select_with_max(bits: Array, scores: Array):
    vmax = jnp.max(scores, axis=-1, keepdims=True)
    idx = first_max_index(scores, vmax)
    sel = jnp.take(bits, idx.reshape(-1), axis=1).reshape(
        bits.shape[0], *idx.shape)
    return sel, vmax


@jax.custom_vjp
def _select_bits(bits: Array, scores: Array) -> Array:
    """bits (B, C), scores (m, n, C) -> selected (B, m, n) via argmax."""
    return _select_with_max(bits, scores)[0]


def _select_bits_fwd(bits, scores):
    out, vmax = _select_with_max(bits, scores)
    return out, (bits, scores, vmax)


def _select_bits_bwd(res, g):
    bits, scores, vmax = res
    # Soft relaxation p = softmax(scores): x_soft[b,m,n] = Σ_c p[m,n,c] b[b,c]
    # dL/dbits[b,c]   = Σ_{m,n} g[b,m,n] p[m,n,c]
    # dL/dscores[m,n,c] = Σ_b g[b,m,n] p[m,n,c] (bits[b,c] - x_soft[b,m,n])
    p = _softmax_from_max(scores, vmax)                      # (m, n, C)
    d_bits = jnp.einsum("bmn,mnc->bc", g, p)
    return d_bits, _d_scores(scores, vmax, g, bits)


_select_bits.defvjp(_select_bits_fwd, _select_bits_bwd)


# First-layer variant: the encoder bits arrive through stop_gradient, so the
# d_bits cotangent is dropped by construction.  Declaring that here (instead
# of relying on XLA to dead-code the einsum) keeps the (B·m·n·C) d_bits GEMM
# out of the compiled step for every single-hidden-layer JSC model.

@jax.custom_vjp
def _select_bits_stopgrad(bits: Array, scores: Array) -> Array:
    return _select_with_max(bits, scores)[0]


def _select_bits_sg_fwd(bits, scores):
    out, vmax = _select_with_max(bits, scores)
    return out, (bits, scores, vmax)


def _select_bits_sg_bwd(res, g):
    bits, scores, vmax = res
    return jnp.zeros_like(bits), _d_scores(scores, vmax, g, bits)


_select_bits_stopgrad.defvjp(_select_bits_sg_fwd, _select_bits_sg_bwd)


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------

def lut_layer_apply(params, bits: Array) -> Array:
    """Differentiable DWN LUT layer: (B, C) bits -> (B, m) bits."""
    sel = _select_bits(bits, params["scores"])               # (B, m, n)
    return _lut_lookup_efd(sel, params["tables"])            # (B, m)


def lut_layer_apply_stopgrad(params, bits: Array) -> Array:
    """First-layer twin of :func:`lut_layer_apply` for stop-gradient inputs.

    Identical forward; the backward skips the d_bits GEMM that a
    stop_gradient boundary would discard anyway.  Use for the layer fed
    directly by the (never-trained) thermometer encoder.
    """
    sel = _select_bits_stopgrad(bits, params["scores"])      # (B, m, n)
    return _lut_lookup_efd(sel, params["tables"])            # (B, m)


def finalize_mapping(params) -> Array:
    """Freeze the learnable mapping to int32 wire indices (m, n)."""
    return first_max_index(params["scores"]).astype(jnp.int32)


def binarize_tables(params) -> Array:
    """Freeze truth tables to {0,1} int32 (m, 2^n) — the hardware LUT INIT."""
    return (params["tables"] > 0.0).astype(jnp.int32)


def lut_eval_hard_packed(packed: PackedBits, mapping_idx: Array,
                         tables_bin: Array) -> PackedBits:
    """Packed twin of :func:`lut_eval_hard`: bits stay in uint32 words.

    A mapped candidate bit ``idx`` is read from word ``idx >> 5`` at bit
    position ``idx & 31`` (the bitpack convention); the LUT address is then
    formed with shift/OR — no float math anywhere.  Output is the packed
    (B, m)-bit layer output.  Bit-exact with the float path:
    ``lut_eval_hard_packed(p, i, t).unpack() == lut_eval_hard(p.unpack(), i, t)``.
    """
    words = packed.words                                     # (B, W) uint32
    B = words.shape[0]
    sel = select_packed_bits(words, jnp.right_shift(mapping_idx, 5),
                             jnp.bitwise_and(mapping_idx, 31))
    addr = lut_addresses(sel)                                # (B, m)
    out = jnp.take_along_axis(
        jnp.broadcast_to(tables_bin[None], (B,) + tables_bin.shape),
        addr[..., None], axis=-1)[..., 0]                    # (B, m) {0,1}
    return PackedBits.pack(out)


def lut_eval_hard(bits: Array, mapping_idx: Array, tables_bin: Array) -> Array:
    """Pure inference path (the hardware semantics; Pallas-kernel oracle).

    Args:
      bits: (B, C) float or int {0,1}.
      mapping_idx: (m, n) int32 wire indices.
      tables_bin: (m, 2^n) int32 {0,1} truth tables.
    Returns (B, m) float32 bits.
    """
    B = bits.shape[0]
    m, n = mapping_idx.shape
    sel = jnp.take(bits, mapping_idx.reshape(-1), axis=1).reshape(B, m, n)
    addr = _addresses(sel, n)
    out = jnp.take_along_axis(
        jnp.broadcast_to(tables_bin[None], (B,) + tables_bin.shape),
        addr[..., None], axis=-1)[..., 0]
    return out.astype(jnp.float32)
