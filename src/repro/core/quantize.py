"""PTQ bit-width search and fine-tuning — the DWN-PEN / DWN-PEN+FT recipe.

Paper §III: thresholds are quantized to signed fixed point (1, n); n is
reduced progressively until the quantized model no longer meets its baseline
accuracy (DWN-PEN). Fine-tuning (10 epochs, Adam lr=1e-3, StepLR(30, 0.1))
then recovers accuracy at lower bit-widths (DWN-PEN+FT).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .model import DWNConfig, freeze, eval_accuracy_hard
from .training import train_dwn, TrainResult
from .thermometer import quantize_fixed_point, total_bits_for_frac
from ..data.jsc import JSCData


@dataclasses.dataclass
class PTQResult:
    total_bits: int            # 1 + frac bits (paper quotes total width)
    frac_bits: int
    accuracy: float
    sweep: list                # [(total_bits, acc)] descending


def ptq_bitwidth_search(params, buffers, cfg: DWNConfig, data: JSCData,
                        baseline_acc: float, *, max_frac: int = 12,
                        tol: float = 0.002, verbose: bool = True) -> PTQResult:
    """DWN-PEN: smallest (1, n) meeting ``baseline_acc`` (within tol)."""
    sweep = []
    best = None
    for frac in range(max_frac, 0, -1):
        frozen = freeze(params, buffers, cfg, input_frac_bits=frac)
        acc = eval_accuracy_hard(frozen, data.x_test, data.y_test)
        tb = total_bits_for_frac(frac)
        sweep.append((tb, acc))
        if verbose:
            print(f"  PTQ {tb:2d}-bit: acc={acc:.4f} "
                  f"(baseline {baseline_acc:.4f})", flush=True)
        if acc + tol >= baseline_acc:
            best = PTQResult(tb, frac, acc, sweep)
        else:
            break
    if best is None:  # even max_frac failed; report max anyway
        tb, acc = sweep[0]
        best = PTQResult(tb, max_frac, acc, sweep)
    return best


@dataclasses.dataclass
class FTResult:
    total_bits: int
    frac_bits: int
    accuracy: float
    result: TrainResult
    sweep: list


def finetune_bitwidth_search(params, buffers, cfg: DWNConfig, data: JSCData,
                             baseline_acc: float, *, start_frac: int,
                             min_frac: int = 3, epochs: int = 10,
                             tol: float = 0.002, seed: int = 1,
                             verbose: bool = True) -> FTResult:
    """DWN-PEN+FT: descend bit-width, fine-tune 10 epochs at each level,
    keep the smallest width whose fine-tuned accuracy meets baseline."""
    best = None
    sweep = []
    for frac in range(start_frac, min_frac - 1, -1):
        q_buffers = {"thresholds": quantize_fixed_point(
            buffers["thresholds"], frac)}
        res = train_dwn(cfg, data, epochs=epochs, lr=1e-3, seed=seed,
                        params=params, buffers=q_buffers,
                        input_frac_bits=frac, sched="steplr",
                        verbose=False)
        frozen = freeze(res.params, res.buffers, cfg, input_frac_bits=frac)
        acc = eval_accuracy_hard(frozen, data.x_test, data.y_test)
        tb = total_bits_for_frac(frac)
        sweep.append((tb, acc))
        if verbose:
            print(f"  FT {tb:2d}-bit: acc={acc:.4f} "
                  f"(baseline {baseline_acc:.4f})", flush=True)
        if acc + tol >= baseline_acc:
            best = FTResult(tb, frac, acc, res, sweep)
        else:
            break
    if best is None:
        tb, acc = sweep[0]
        best = FTResult(tb, start_frac, acc, None, sweep)
    return best
