"""DWN classification logic: group popcount + argmax (paper Fig. 1/4).

The LUT-layer output bits are partitioned into ``classes`` contiguous groups
of ``group_size = m // classes`` bits; each group's popcount is that class's
score. Inference takes the argmax, ties resolved toward the lower class index
(paper §IV: "If two inputs have the same popcount value, the class with the
lower index is selected" — ``jnp.argmax`` returns the first maximum, which
matches). Training divides the counts by a temperature τ and applies a
softmax cross-entropy, following [13].
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .bitpack import PackedBits, group_masks, masked_group_counts

Array = jax.Array


def group_popcount(bits: Array, num_classes: int) -> Array:
    """(B, m) {0,1} -> (B, classes) counts; m must divide evenly."""
    B, m = bits.shape
    assert m % num_classes == 0, (m, num_classes)
    return bits.reshape(B, num_classes, m // num_classes).sum(axis=-1)


def group_popcount_packed(packed: PackedBits, num_classes: int) -> Array:
    """Packed twin of :func:`group_popcount`: masked SWAR word popcounts.

    Class groups need not align with word boundaries — each class ANDs a
    precomputed (classes, W) mask against the packed words and popcounts the
    result.  Returns float32 counts identical to the float path.
    """
    return masked_group_counts(packed.words,
                               group_masks(packed.num_bits, num_classes))


def logits_from_counts(counts: Array, tau: float) -> Array:
    return counts / jnp.asarray(tau, counts.dtype)


def predict(counts: Array) -> Array:
    """Hardware argmax semantics: first (lowest-index) maximum wins."""
    return jnp.argmax(counts, axis=-1)


def cross_entropy(logits: Array, labels: Array) -> Array:
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return nll.mean()


def accuracy(counts: Array, labels: Array) -> Array:
    return (predict(counts) == labels).astype(jnp.float32).mean()
