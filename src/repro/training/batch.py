"""Vmapped multi-seed / multi-point DWN training.

``train_dwn_batch`` trains a whole stack of same-shape models — different
init seeds, or different sweep grid points whose configs agree on every
array shape (same preset and encoder resolution T; thresholds/placement
may differ, they are arrays) — in ONE compiled device program:

* every member's params / optimizer state / encoded dataset are stacked
  on a leading model axis;
* the single-model epoch block (``engine.build_epoch_block``) is ``vmap``-ed
  over that axis — one XLA program, one dispatch per epoch block, params
  and optimizer state donated;
* per-member minibatch permutations follow each member's own seed stream,
  so member ``i``'s trajectory matches a sequential ``train_dwn(seed=i)``
  run of the same model (within vmap fp tolerance);
* when the host mesh has multiple devices and the model axis divides the
  device count, the vmapped block is wrapped in ``shard_map`` over the
  ``("data",)`` mesh from ``launch.mesh.make_data_mesh()`` — the same
  machinery DWN serving shards batches with — so members train
  data-parallel with zero cross-device collectives.

This is what lets ``repro.sweep.pipeline`` train a grid slice in one
compiled call instead of N sequential python loops.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..core.model import DWNConfig, init_dwn
from ..data.jsc import JSCData
from ..launch.mesh import make_data_mesh
from .engine import build_epoch_block, encode_dataset, epoch_permutation

_BATCH_PROGRAMS: dict = {}


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _member(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


def _batch_program(cfg: DWNConfig, n: int, num_bits: int, batch: int,
                   lr: float, sched: str, n_models: int,
                   data_parallel: bool):
    """jit(vmap(block)) over the stacked model axis, optionally laid over
    the ("data",) mesh with shard_map.  Cached process-wide."""
    key = ("batch", cfg, n, num_bits, batch, lr, sched, n_models,
           data_parallel)
    if key in _BATCH_PROGRAMS:
        return _BATCH_PROGRAMS[key]

    block, opt, steps = build_epoch_block(cfg, n, batch, lr, sched)
    fn = jax.vmap(block, in_axes=(0, 0, 0, None, 0))
    mesh = None
    if data_parallel:
        mesh = make_data_mesh()
        ndev = mesh.shape["data"]
        if ndev > 1 and n_models % ndev == 0:
            from jax.experimental.shard_map import shard_map
            fn = shard_map(
                fn, mesh=mesh,
                in_specs=(P("data"), P("data"), P("data"), P(), P("data")),
                out_specs=(P("data"), P("data"), P("data")),
                check_rep=False)
        else:
            mesh = None
    prog = jax.jit(fn, donate_argnums=(0, 1))
    _BATCH_PROGRAMS[key] = (prog, opt, steps, mesh is not None)
    return _BATCH_PROGRAMS[key]


@dataclasses.dataclass
class BatchTrainOutcome:
    """Results of one vmapped training run.

    Attributes:
      results: per-member ``TrainResult`` (params/buffers unstacked).
      wall_s: wall-clock of the whole batched run (all members together).
      data_parallel: whether the run was laid over a multi-device mesh.
    """
    results: list
    wall_s: float
    data_parallel: bool


def train_dwn_batch(cfg: DWNConfig, data: JSCData, *, epochs: int,
                    seeds=(0,), models=None, batch: int = 128,
                    lr: float = 1e-3, sched: str = "steplr",
                    input_frac_bits: int | None = None,
                    data_parallel: bool = True,
                    eval_final: bool = True) -> BatchTrainOutcome:
    """Train ``len(seeds)`` same-shape DWNs in one compiled program.

    Args:
      cfg: the shared model config (shapes must agree across members).
      data: shared JSC splits.
      epochs / batch / lr / sched: paper-protocol knobs, shared.
      seeds: per-member seed — drives the member's init (when ``models``
        is None) and its minibatch permutation stream, exactly like a
        sequential ``train_dwn(seed=s)`` run.
      models: optional list of (params, buffers) warm starts, one per
        seed; buffers may differ per member (e.g. threshold placements),
        shapes may not.
      input_frac_bits: PEN quantization folded into the one-time encode.
      data_parallel: lay the model axis over the ("data",) mesh when the
        host has multiple devices and the axis divides them.
      eval_final: run the cached evaluator on every member after training.

    Returns a :class:`BatchTrainOutcome`; ``results[i]`` corresponds to
    ``seeds[i]``.
    """
    from ..core.training import TrainResult
    seeds = list(seeds)
    if models is None:
        models = [init_dwn(jax.random.PRNGKey(s), cfg, data.x_train)
                  for s in seeds]
    assert len(models) == len(seeds), "one (params, buffers) per seed"
    S = len(models)

    t0 = time.time()
    params = _stack([jax.tree.map(jnp.array, p) for p, _ in models])
    buffers = _stack([jax.tree.map(jnp.array, b) for _, b in models])
    bits = jnp.stack([
        encode_dataset(data.x_train, b["thresholds"],
                       input_frac_bits=input_frac_bits)
        for _, b in models])                                 # (S, N, C)
    y = jnp.asarray(data.y_train)
    n = data.x_train.shape[0]

    prog, opt, steps, used_dp = _batch_program(
        cfg, n, int(bits.shape[-1]), batch, lr, sched, S, data_parallel)
    opt_state = _stack([opt.init(_member(params, i)) for i in range(S)])

    if epochs > 0:
        perms = jnp.asarray(np.stack([
            np.stack([epoch_permutation(n, steps, batch, seed=s, epoch=e)
                      for e in range(epochs)])
            for s in seeds]))                                # (S, E, L)
        params, opt_state, losses = prog(params, opt_state, bits, y, perms)
        losses = np.asarray(losses)                          # (S, E, steps)
    else:
        losses = np.zeros((S, 0, steps), np.float32)
    wall = time.time() - t0

    results = []
    for i, s in enumerate(seeds):
        p_i = _member(params, i)
        b_i = _member(buffers, i)
        acc = float("nan")
        if eval_final:
            from ..core.training import eval_soft
            acc = eval_soft(p_i, b_i, cfg, data.x_test, data.y_test,
                            input_frac_bits)
        history = [{"epoch": e, "loss": float(np.mean(losses[i, e])),
                    "test_acc": acc if e == epochs - 1 else None,
                    "sec": wall / max(1, epochs) / S}
                   for e in range(epochs)]
        results.append(TrainResult(p_i, b_i, cfg, history, acc))
    return BatchTrainOutcome(results, wall, used_dp)


__all__ = ["train_dwn_batch", "BatchTrainOutcome"]
