"""Scan-compiled DWN trainer: one device program per epoch block.

Pre-PR, ``core.training.train_dwn`` dispatched one jitted update per
minibatch and synced ``float(loss)`` to the host every step; the epoch was
Python-bound and re-encoded the (never-trained) thermometer bits on every
batch.  This engine restructures the same protocol — identical batch
order, identical schedule step count, loss trajectory equal within fp
tolerance — into a single compiled program per epoch block:

* the dataset is thermometer-encoded **once** (uint8 bit rows, device
  resident) — ``loss_fn_from_bits`` consumes gathered rows;
* an outer ``lax.scan`` over the epochs of the block and an inner
  ``lax.scan`` over minibatches run entirely on device; per-step losses
  accumulate in-carry and are fetched **once per epoch block**;
* params and optimizer state are **donated** into the program, so the
  update is in-place where the backend supports it;
* the StepLR schedule is folded in through the optimizer-step counter
  carried in ``AdamState`` (no host-side schedule bookkeeping);
* periodic eval reuses the process-wide compiled evaluator
  (:mod:`repro.training.evaluator`) instead of re-jitting per epoch.

Batch order matches ``repro.data.jsc.batches`` exactly: the per-epoch
permutation is drawn host-side from the same ``SeedSequence([seed,
epoch])`` stream and shipped to the device as an index array (a few
hundred KB — the only per-epoch host->device traffic).

Compiled epoch programs cache process-wide by
``(cfg, data shape, batch, lr, sched)``, so repeated trainings of the
same shape (the fine-tune bit-width search, sweep grid points) compile
once.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..core.model import DWNConfig, init_dwn, loss_fn_from_bits
from ..core.thermometer import encode, quantize_fixed_point
from ..data.jsc import JSCData
from ..optim.adam import Adam
from ..optim.schedule import step_lr, constant

Array = jax.Array


def epoch_permutation(n: int, steps: int, batch: int, *, seed: int,
                      epoch: int) -> np.ndarray:
    """The (steps*batch,) sample order of one epoch — byte-identical to the
    order ``repro.data.jsc.batches`` yields (drop-remainder)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, epoch]))
    return rng.permutation(n)[:steps * batch].astype(np.int32)


def encode_dataset(x: np.ndarray, thresholds, *,
                   input_frac_bits: int | None = None) -> Array:
    """Thermometer-encode a whole split once: (N, F) -> (N, F*T) uint8.

    Quantizes features first when PEN ``input_frac_bits`` is set — the
    same values the per-batch path produces, hoisted out of the hot loop.
    uint8 storage is 4x smaller than float32 bit planes; the cast back is
    exact, so downstream logits are bit-identical.
    """
    @jax.jit
    def enc(xd):
        if input_frac_bits is not None:
            xd = quantize_fixed_point(xd, input_frac_bits)
        return encode(xd, thresholds).astype(jnp.uint8)
    return enc(jnp.asarray(x))


# -- compiled epoch-block programs, keyed by everything graph-shaping -----

_PROGRAMS: dict = {}


def build_epoch_block(cfg: DWNConfig, n: int, batch: int, lr: float,
                      sched: str):
    """The (unjitted) epoch-block function of one model.

    Returns ``(block, opt, steps)`` where
    ``block(params, opt_state, bits (N,C), y (N,), perms (k, steps*batch))
    -> (params, opt_state, losses (k, steps))``: an outer ``lax.scan``
    over the block's epochs, an inner ``lax.scan`` over minibatches, the
    StepLR schedule folded in through the ``AdamState`` step counter.
    ``repro.training.batch`` vmaps this same function over stacked models.
    """
    steps = n // batch
    schedule = (step_lr(lr, 30, 0.1, max(1, steps)) if sched == "steplr"
                else constant(lr))
    opt = Adam(lr=schedule, clamp=(-1.0, 1.0))

    def one_step(carry, xy):
        params, opt_state = carry
        xb, yb = xy
        (loss, _), grads = jax.value_and_grad(
            loss_fn_from_bits, has_aux=True)(params, cfg, xb, yb)
        params, opt_state = opt.update(grads, opt_state, params)
        return (params, opt_state), loss

    def one_epoch(carry, perm, *, bits, y):
        xb = jnp.take(bits, perm, axis=0).reshape(steps, batch, -1)
        yb = jnp.take(y, perm, axis=0).reshape(steps, batch)
        return lax.scan(one_step, carry, (xb, yb))

    def block(params, opt_state, bits, y, perms):
        def body(carry, perm):
            return one_epoch(carry, perm, bits=bits, y=y)
        (params, opt_state), losses = lax.scan(
            body, (params, opt_state), perms)
        return params, opt_state, losses

    return block, opt, steps


def _epoch_block_program(cfg: DWNConfig, n: int, num_bits: int, batch: int,
                         lr: float, sched: str):
    """Process-wide cache of jitted single-model epoch-block programs
    (params/opt_state donated)."""
    key = ("single", cfg, n, num_bits, batch, lr, sched)
    if key not in _PROGRAMS:
        block, opt, steps = build_epoch_block(cfg, n, batch, lr, sched)
        _PROGRAMS[key] = (jax.jit(block, donate_argnums=(0, 1)), opt, steps)
    return _PROGRAMS[key]


class ScanTrainer:
    """Resumable scan-compiled trainer for one DWN.

    Args:
      cfg: model config.
      data: JSC splits; thresholds fit on ``x_train`` when initializing.
      batch / lr / sched: paper-protocol knobs (identical meaning to the
        pre-PR loop; ``sched`` is "steplr" or "constant").
      seed: init PRNG seed *and* the minibatch-permutation stream seed.
      params / buffers: warm-start state.  Copied before the first donated
        call, so caller-held arrays are never invalidated.
      input_frac_bits: PEN (1, n) feature quantization, folded into the
        one-time dataset encode.

    ``run_epochs`` advances any number of epochs in one device program
    (one host fetch for the whole block); ``train`` drives the standard
    eval-every-epoch protocol and returns a ``TrainResult``.
    """

    def __init__(self, cfg: DWNConfig, data: JSCData, *, batch: int = 128,
                 lr: float = 1e-3, sched: str = "steplr", seed: int = 0,
                 params=None, buffers=None,
                 input_frac_bits: int | None = None):
        self.cfg, self.data = cfg, data
        self.batch, self.lr, self.sched, self.seed = batch, lr, sched, seed
        self.input_frac_bits = input_frac_bits
        if params is None:
            params, buffers = init_dwn(jax.random.PRNGKey(seed), cfg,
                                       data.x_train)
        # private copy: the engine donates params/opt_state every block, so
        # it must own the buffers (callers reuse warm-start trees, e.g. the
        # fine-tune bit-width search passes the same params repeatedly)
        self.params = jax.tree.map(lambda a: jnp.array(a), params)
        self.buffers = jax.tree.map(lambda a: jnp.array(a), buffers)
        self.bits_train = encode_dataset(data.x_train,
                                         self.buffers["thresholds"],
                                         input_frac_bits=input_frac_bits)
        self.y_train = jnp.asarray(data.y_train)
        n = data.x_train.shape[0]
        self._program, self.opt, self.steps_per_epoch = _epoch_block_program(
            cfg, n, int(self.bits_train.shape[1]), batch, lr, sched)
        self.opt_state = self.opt.init(self.params)
        self.epoch = 0

    def run_epochs(self, k: int = 1) -> np.ndarray:
        """Advance ``k`` epochs in one compiled call; returns the (k, steps)
        per-step losses (the single host fetch of the block)."""
        n = self.data.x_train.shape[0]
        perms = np.stack([
            epoch_permutation(n, self.steps_per_epoch, self.batch,
                              seed=self.seed, epoch=self.epoch + i)
            for i in range(k)])
        self.params, self.opt_state, losses = self._program(
            self.params, self.opt_state, self.bits_train, self.y_train,
            jnp.asarray(perms))
        self.epoch += k
        return np.asarray(losses)

    def evaluate(self) -> float:
        """Soft test accuracy through the cached compiled evaluator —
        the same numbers ``core.training.eval_soft`` reports."""
        from ..core.training import eval_soft
        return eval_soft(self.params, self.buffers, self.cfg,
                         self.data.x_test, self.data.y_test,
                         self.input_frac_bits)

    def train(self, epochs: int, *, eval_every: int = 1,
              verbose: bool = False):
        """Run the paper protocol: per-epoch history with periodic eval.

        ``eval_every=0`` evaluates only after the final epoch and runs all
        epochs as one device program (zero host syncs until the end).
        """
        from ..core.training import TrainResult
        history = []
        if eval_every <= 0:
            t0 = time.time()
            losses = self.run_epochs(epochs) if epochs else \
                np.zeros((0, self.steps_per_epoch))
            acc = self.evaluate()
            # units convention (docs/training.md): epoch seconds include
            # the run's eval, same as the eval_every >= 1 branch
            sec = (time.time() - t0) / max(1, epochs)
            for e in range(epochs):
                history.append({
                    "epoch": e, "loss": float(np.mean(losses[e])),
                    "test_acc": acc if e == epochs - 1 else None,
                    "sec": sec})
        else:
            done = 0
            while done < epochs:
                k = min(eval_every, epochs - done)
                t0 = time.time()
                losses = self.run_epochs(k)
                acc = self.evaluate()
                sec = (time.time() - t0) / k
                for i in range(k):
                    e = done + i
                    evaluated = i == k - 1
                    history.append({
                        "epoch": e, "loss": float(np.mean(losses[i])),
                        "test_acc": acc if evaluated else None,
                        "sec": sec})
                    if verbose:
                        acc_s = f"test_acc={acc:.4f} " if evaluated else ""
                        print(f"  epoch {e:3d} "
                              f"loss={history[-1]['loss']:.4f} "
                              f"{acc_s}({sec:.1f}s)", flush=True)
                done += k
        final = history[-1]["test_acc"] if history else float("nan")
        return TrainResult(self.params, self.buffers, self.cfg, history,
                           final if final is not None else float("nan"))


def train_dwn_scan(cfg: DWNConfig, data: JSCData, *, epochs: int = 30,
                   batch: int = 128, lr: float = 1e-3, seed: int = 0,
                   params=None, buffers=None,
                   input_frac_bits: int | None = None,
                   sched: str = "steplr", eval_every: int = 1,
                   verbose: bool = True):
    """Drop-in scan-compiled replacement for the pre-PR ``train_dwn``."""
    trainer = ScanTrainer(cfg, data, batch=batch, lr=lr, sched=sched,
                          seed=seed, params=params, buffers=buffers,
                          input_frac_bits=input_frac_bits)
    return trainer.train(epochs, eval_every=eval_every, verbose=verbose)


__all__ = ["ScanTrainer", "train_dwn_scan", "encode_dataset",
           "epoch_permutation"]
