"""The pre-PR python-per-minibatch trainer, frozen verbatim.

This module preserves the exact pre-scan-engine training path — including
its cost structure — as (a) the parity oracle the scan engine is tested
against and (b) the baseline ``benchmarks/train_bench.py`` measures the
speedup over.  Deliberately kept, not deleted, characteristics:

* one jitted update call per minibatch, ``float(loss)`` host sync per step;
* thermometer re-encode of every batch inside the update;
* ``jnp.argmax`` (variadic-reduce) mapping selection and the textbook
  two-einsum softmax-STE backward (the x_soft form);
* a **fresh** ``@jax.jit`` eval closure per epoch (the recompile the
  evaluator cache fixes).

Do not "improve" this file — its whole value is staying byte-for-byte
faithful to the pre-PR semantics *and* performance profile.  The live
ops in ``core.lut_layer`` compute the same math reassociated; the parity
tests pin the two trajectories together at fixed seed.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from ..core.classifier import (accuracy as _acc, cross_entropy,
                               group_popcount, logits_from_counts)
from ..core.model import DWNConfig, init_dwn
from ..core.thermometer import encode, quantize_fixed_point
from ..data.jsc import JSCData, batches
from ..optim.adam import Adam, AdamState
from ..optim.schedule import step_lr, constant

Array = jax.Array


def _adam_update_ref(opt: Adam, grads, state: AdamState, params):
    """Pre-PR Adam step: three separate tree traversals (mu, nu, then the
    parameter update reading the materialized mhat/vhat) — numerically
    identical to the fused one-pass ``Adam.update``, kept verbatim for
    its pre-PR memory-pass structure."""
    step = state.step + 1
    b1, b2 = opt.b1, opt.b2
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g,
                      state.nu, grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    lr = opt._lr(step)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        new = p - lr * (mhat / (jnp.sqrt(vhat) + opt.eps)
                        + opt.weight_decay * p)
        if opt.clamp is not None:
            new = jnp.clip(new, opt.clamp[0], opt.clamp[1])
        return new

    return jax.tree.map(upd, params, mu, nu), AdamState(step, mu, nu)


# -- pre-PR LUT-layer ops (old formulations, local copies) ----------------

@jax.custom_vjp
def _select_bits_ref(bits: Array, scores: Array) -> Array:
    idx = jnp.argmax(scores, axis=-1)                        # (m, n)
    return jnp.take(bits, idx.reshape(-1), axis=1).reshape(
        bits.shape[0], *idx.shape)


def _select_bits_ref_fwd(bits, scores):
    return _select_bits_ref(bits, scores), (bits, scores)


def _select_bits_ref_bwd(res, g):
    bits, scores = res
    p = jax.nn.softmax(scores, axis=-1)                      # (m, n, C)
    d_bits = jnp.einsum("bmn,mnc->bc", g, p)
    x_soft = jnp.einsum("mnc,bc->bmn", p, bits)
    gb = jnp.einsum("bmn,bc->mnc", g, bits)
    gx = jnp.einsum("bmn,bmn->mn", g, x_soft)
    d_scores = p * (gb - gx[..., None])
    return d_bits, d_scores


_select_bits_ref.defvjp(_select_bits_ref_fwd, _select_bits_ref_bwd)


def _addresses_ref(sel_bits: Array, fan_in: int) -> Array:
    weights = (2 ** jnp.arange(fan_in, dtype=jnp.int32))
    return jnp.sum(sel_bits.astype(jnp.int32) * weights, axis=-1)


def _gather_tables_ref(tables: Array, addr: Array) -> Array:
    return jnp.take_along_axis(
        jnp.broadcast_to(tables[None], (addr.shape[0],) + tables.shape),
        addr[..., None], axis=-1)[..., 0]


def _gather_tables_multi_ref(tables: Array, addr: Array) -> Array:
    B = addr.shape[0]
    t = jnp.broadcast_to(tables[None], (B,) + tables.shape)
    return jnp.take_along_axis(t, addr, axis=-1)


@jax.custom_vjp
def _lut_lookup_ref(sel_bits: Array, tables: Array) -> Array:
    addr = _addresses_ref(sel_bits, sel_bits.shape[-1])
    return (_gather_tables_ref(tables, addr) > 0.0).astype(jnp.float32)


def _lut_lookup_ref_fwd(sel_bits, tables):
    addr = _addresses_ref(sel_bits, sel_bits.shape[-1])
    out = (_gather_tables_ref(tables, addr) > 0.0).astype(jnp.float32)
    return out, (sel_bits, tables, addr)


def _lut_lookup_ref_bwd(res, g):
    sel_bits, tables, addr = res
    n = sel_bits.shape[-1]
    S = tables.shape[-1]
    vals = _gather_tables_ref(tables, addr)                  # re-gathered
    g_vals = g * (jnp.abs(vals) <= 1.0).astype(g.dtype)
    onehot = jax.nn.one_hot(addr, S, dtype=g.dtype)
    d_tables = jnp.einsum("bm,bms->ms", g_vals, onehot)
    bit_w = (2 ** jnp.arange(n, dtype=jnp.int32))
    addr_hi = addr[..., None] | bit_w
    addr_lo = addr[..., None] & (~bit_w)
    t_hi = _gather_tables_multi_ref(tables, addr_hi)
    t_lo = _gather_tables_multi_ref(tables, addr_lo)
    d_sel = g_vals[..., None] * (t_hi - t_lo)
    return d_sel, d_tables


_lut_lookup_ref.defvjp(_lut_lookup_ref_fwd, _lut_lookup_ref_bwd)


def apply_train_ref(params, buffers, cfg: DWNConfig, x: Array) -> Array:
    """Pre-PR differentiable forward (per-batch encode, old ops)."""
    bits = encode(x, buffers["thresholds"])
    bits = jax.lax.stop_gradient(bits)
    for layer in params["layers"]:
        sel = _select_bits_ref(bits, layer["scores"])
        bits = _lut_lookup_ref(sel, layer["tables"])
    counts = group_popcount(bits, cfg.num_classes)
    return logits_from_counts(counts, cfg.tau_value)


def _loss_ref(params, buffers, cfg, x, y):
    logits = apply_train_ref(params, buffers, cfg, x)
    return cross_entropy(logits, y), logits


def _make_update_ref(cfg: DWNConfig, opt: Adam, input_frac_bits):
    @jax.jit
    def update(params, opt_state, buffers, x, y):
        if input_frac_bits is not None:
            x = quantize_fixed_point(x, input_frac_bits)
        (loss, logits), grads = jax.value_and_grad(
            _loss_ref, has_aux=True)(params, buffers, cfg, x, y)
        params, opt_state = _adam_update_ref(opt, grads, opt_state, params)
        return params, opt_state, loss, _acc(logits, y)
    return update


def eval_soft_ref(params, buffers, cfg, x, y, input_frac_bits=None,
                  batch: int = 4096) -> float:
    """Pre-PR eval: a fresh jit closure per call (the recompile bug)."""
    @jax.jit
    def evaluate(params, buffers, xb, yb):
        if input_frac_bits is not None:
            xb = quantize_fixed_point(xb, input_frac_bits)
        logits = apply_train_ref(params, buffers, cfg, xb)
        return _acc(logits, yb)
    accs, ns = [], []
    for i in range(0, x.shape[0], batch):
        xb, yb = jnp.asarray(x[i:i + batch]), jnp.asarray(y[i:i + batch])
        accs.append(float(evaluate(params, buffers, xb, yb)))
        ns.append(xb.shape[0])
    return float(np.average(accs, weights=ns))


class ReferenceTrainer:
    """Resumable wrapper over the pre-PR loop (epoch-at-a-time), so the
    benchmark can interleave reference and scan epochs under identical
    machine conditions."""

    def __init__(self, cfg: DWNConfig, data: JSCData, *, batch: int = 128,
                 lr: float = 1e-3, sched: str = "steplr", seed: int = 0,
                 params=None, buffers=None,
                 input_frac_bits: int | None = None):
        self.cfg, self.data = cfg, data
        self.batch, self.seed = batch, seed
        self.input_frac_bits = input_frac_bits
        if params is None:
            params, buffers = init_dwn(jax.random.PRNGKey(seed), cfg,
                                       data.x_train)
        self.params, self.buffers = params, buffers
        steps = max(1, data.x_train.shape[0] // batch)
        schedule = (step_lr(lr, 30, 0.1, steps) if sched == "steplr"
                    else constant(lr))
        opt = Adam(lr=schedule, clamp=(-1.0, 1.0))
        self.opt_state = opt.init(params)
        self._update = _make_update_ref(cfg, opt, input_frac_bits)
        self.epoch = 0

    def run_epoch(self) -> list:
        """One pre-PR epoch: per-step jit dispatch + float(loss) sync."""
        losses = []
        for xb, yb in batches(self.data.x_train, self.data.y_train,
                              self.batch, seed=self.seed, epoch=self.epoch):
            self.params, self.opt_state, loss, _ = self._update(
                self.params, self.opt_state, self.buffers,
                jnp.asarray(xb), jnp.asarray(yb))
            losses.append(float(loss))
        self.epoch += 1
        return losses

    def evaluate(self) -> float:
        """Pre-PR eval (fresh jit per call, by design)."""
        return eval_soft_ref(self.params, self.buffers, self.cfg,
                             self.data.x_test, self.data.y_test,
                             self.input_frac_bits)


def train_dwn_reference(cfg: DWNConfig, data: JSCData, *, epochs: int = 30,
                        batch: int = 128, lr: float = 1e-3, seed: int = 0,
                        params=None, buffers=None,
                        input_frac_bits: int | None = None,
                        sched: str = "steplr", verbose: bool = False):
    """The pre-PR ``train_dwn``, end to end (loop + per-epoch fresh-jit
    eval), returning the same ``TrainResult`` shape."""
    from ..core.training import TrainResult
    t = ReferenceTrainer(cfg, data, batch=batch, lr=lr, sched=sched,
                         seed=seed, params=params, buffers=buffers,
                         input_frac_bits=input_frac_bits)
    history = []
    for epoch in range(epochs):
        t0 = time.time()
        losses = t.run_epoch()
        te_acc = t.evaluate()
        history.append({"epoch": epoch, "loss": float(np.mean(losses)),
                        "test_acc": te_acc, "sec": time.time() - t0})
        if verbose:
            print(f"  epoch {epoch:3d} loss={np.mean(losses):.4f} "
                  f"test_acc={te_acc:.4f} ({time.time()-t0:.1f}s)",
                  flush=True)
    return TrainResult(t.params, t.buffers, cfg, history,
                       history[-1]["test_acc"] if history else float("nan"))


__all__ = ["ReferenceTrainer", "train_dwn_reference", "eval_soft_ref",
           "apply_train_ref"]
