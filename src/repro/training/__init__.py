"""Scan-compiled DWN training engine.

The paper-protocol trainer as a single device program per epoch:

* ``engine``    — :class:`ScanTrainer` / :func:`train_dwn_scan`: on-device
  ``lax.scan`` over minibatches with donated params/optimizer state, the
  StepLR schedule folded into the optimizer-step counter, metrics
  accumulated in-carry and fetched once per epoch.
* ``batch``     — :func:`train_dwn_batch`: vmapped multi-seed / multi-point
  training (one compiled program trains a whole stack of same-shape
  models), sharded data-parallel over the host mesh when it has devices.
* ``evaluator`` — the process-wide compiled-evaluator cache shared by
  ``core.training.eval_soft``, the sweep pipeline and the PTQ/FT search.
* ``reference`` — the frozen pre-PR python-per-minibatch loop, kept
  verbatim as the parity oracle and the ``benchmarks/train_bench.py``
  baseline.

``repro.core.training.train_dwn`` delegates here: the scan engine *is*
the paper-protocol trainer (same batch order, same schedule step count,
loss trajectory equal within fp tolerance), not a fork of it.
"""

from .engine import ScanTrainer, train_dwn_scan, encode_dataset
from .batch import train_dwn_batch
from .evaluator import cached_evaluator, evaluator_cache_info
from .reference import ReferenceTrainer, train_dwn_reference

__all__ = [
    "ScanTrainer", "train_dwn_scan", "encode_dataset", "train_dwn_batch",
    "cached_evaluator", "evaluator_cache_info", "ReferenceTrainer",
    "train_dwn_reference",
]
