"""Process-wide cache of compiled DWN evaluators.

Pre-PR, ``core.training._make_eval`` built a fresh ``@jax.jit`` closure on
every call — one XLA retrace + recompile per epoch per training run, and
again for every PTQ/FT probe and sweep point.  The evaluator graph depends
only on ``(cfg, input_frac_bits)`` (shapes retrace inside one jit wrapper
for free), so one compiled callable per such pair serves every caller:
``core.training.eval_soft``, the scan engine's per-epoch eval, the sweep
pipeline, and the fine-tune bit-width search.

``DWNConfig`` is a frozen dataclass of hashables, so it is the cache key
directly.  The cache is intentionally unbounded: a process sees a handful
of distinct configs (a sweep grid is the worst case, ~dozens).
"""

from __future__ import annotations

import functools

import jax

from ..core.classifier import accuracy as _acc
from ..core.model import DWNConfig, apply_train
from ..core.thermometer import quantize_fixed_point


@functools.lru_cache(maxsize=None)
def cached_evaluator(cfg: DWNConfig, input_frac_bits: int | None):
    """The jitted soft-accuracy evaluator for ``(cfg, input_frac_bits)``.

    Returns ``evaluate(params, buffers, x, y) -> scalar accuracy``; the
    same compiled callable is returned on every call with equal keys, so
    per-epoch eval costs one execution, not one compile.
    """
    @jax.jit
    def evaluate(params, buffers, x, y):
        if input_frac_bits is not None:
            x = quantize_fixed_point(x, input_frac_bits)
        logits = apply_train(params, buffers, cfg, x)
        return _acc(logits, y)
    return evaluate


def evaluator_cache_info():
    """lru_cache statistics — lets tests pin the no-recompile guarantee."""
    return cached_evaluator.cache_info()


__all__ = ["cached_evaluator", "evaluator_cache_info"]
