"""Synthetic Jet-Substructure-Classification (JSC) surrogate.

The real JSC dataset (Duarte et al. 2018 [1]: 16 physics features, 5 jet
classes) is not available in this offline container.  This module generates
a *statistically analogous* surrogate with a fixed (seeded) ground truth:

* per-class scores built from sparse single-feature threshold-indicator
  rules — the same hypothesis class a DWN popcount realizes, so small
  models can be competitive, exactly as on real JSC;
* plus a smooth nonlinear residual (capacity headroom for larger LUT
  layers);
* plus Gumbel score noise that sets the Bayes ceiling (the paper's
  71–76.3% accuracy band).

``bayes_accuracy`` evaluates the noiseless argmax — the exact Bayes
classifier of this generative process — which we use to calibrate the
noise so the ceiling lands just above the paper's best model (76.3%).
The substitution is documented in EXPERIMENTS.md §Repro.

Deterministic by seed; features are normalized to [-1, 1) with train-split
statistics, per paper §III.
"""

from __future__ import annotations

import dataclasses

import numpy as np

NUM_FEATURES = 16
NUM_CLASSES = 5

# ground-truth knobs (fixed; master seed makes the truth split-invariant).
# Rule weights fall off steeply: on real JSC each jet class is ~70%
# decidable from one or two feature cuts (which is why the paper's sm-10
# reaches 71.1%); the weight profile reproduces that property, the Gumbel
# noise sets the Bayes ceiling just above the paper's best model (76.3%).
RULE_WEIGHTS = (4.5, 0.5, 0.3, 0.2, 0.15)
BETA = 0.22           # smooth-residual weight
GUMBEL = 0.50         # score noise scale -> Bayes ceiling (calibrated)


def normalize_to_unit(x, lo=None, hi=None):
    # Matches repro.core.thermometer.normalize_to_unit (local copy avoids a
    # core<->data import cycle).
    x = np.asarray(x, np.float32)
    if lo is None:
        lo = x.min(axis=0)
    if hi is None:
        hi = x.max(axis=0)
    span = np.maximum(hi - lo, 1e-12)
    xn = (x - lo) / span * 2.0 - 1.0
    xn = np.clip(xn, -1.0, np.nextafter(np.float32(1.0), np.float32(0.0)))
    return xn.astype(np.float32), lo, hi


@dataclasses.dataclass
class JSCData:
    x_train: np.ndarray
    y_train: np.ndarray
    x_test: np.ndarray
    y_test: np.ndarray

    @property
    def num_features(self) -> int:
        return self.x_train.shape[1]


class _Truth:
    """The fixed generative ground truth (split-invariant, master seed)."""

    def __init__(self):
        master = np.random.default_rng(1234)
        M = master.normal(0.0, 1.0, (NUM_FEATURES, NUM_FEATURES))
        cov = M @ M.T / NUM_FEATURES + 0.6 * np.eye(NUM_FEATURES)
        self.L = np.linalg.cholesky(cov)
        R = len(RULE_WEIGHTS)
        # distinct rule features within each class (dominant cut first)
        self.feats = np.stack([master.permutation(NUM_FEATURES)[:R]
                               for _ in range(NUM_CLASSES)])
        self.thr = master.normal(0.0, 0.45, (NUM_CLASSES, R))
        self.sgn = master.choice([-1.0, 1.0], (NUM_CLASSES, R))
        jitter = master.uniform(0.9, 1.1, (NUM_CLASSES, R))
        self.w = np.asarray(RULE_WEIGHTS)[None, :] * jitter
        self.W1 = master.normal(0.0, 0.6, (NUM_FEATURES, 24))
        self.W2 = master.normal(0.0, 0.8, (24, NUM_CLASSES))
        # class-balancing offsets from a fixed calibration draw
        cal = np.random.default_rng(99)
        xc = self._features(cal, 20000)
        self.offs = np.zeros(NUM_CLASSES)
        self.offs = self.scores(xc).mean(0)

    def _features(self, rng: np.random.Generator, n: int) -> np.ndarray:
        u = rng.normal(0.0, 1.0, (n, NUM_FEATURES)) @ self.L.T
        return np.tanh(0.8 * u).astype(np.float32)

    def scores(self, x: np.ndarray) -> np.ndarray:
        ind = (x[:, self.feats] * self.sgn[None]
               > self.thr[None] * self.sgn[None])            # (n, C, R)
        s = (ind * self.w[None]).sum(-1)                      # (n, C)
        s = s + BETA * np.tanh(x @ self.W1) @ self.W2
        return s - self.offs[None]


_TRUTH: _Truth | None = None


def _truth() -> _Truth:
    global _TRUTH
    if _TRUTH is None:
        _TRUTH = _Truth()
    return _TRUTH


def _sample(n: int, rng: np.random.Generator):
    t = _truth()
    x = t._features(rng, n)
    score = t.scores(x)
    g = rng.gumbel(0.0, GUMBEL, (n, NUM_CLASSES))
    y = np.argmax(score + g, axis=1).astype(np.int32)
    return x, y


def bayes_accuracy(n: int = 50_000, seed: int = 7) -> float:
    """Accuracy of the exact Bayes classifier (noiseless argmax)."""
    rng = np.random.default_rng(seed)
    x, y = _sample(n, rng)
    pred = np.argmax(_truth().scores(x), axis=1)
    return float((pred == y).mean())


def oracle_tiny_accuracy(n: int = 50_000, seed: int = 7,
                         bits_per_class: int = 2) -> float:
    """Accuracy of a hand-wired sm-10-capacity DWN: each class counts its
    top-`bits_per_class` rule indicators.  Calibration target ~= the
    paper's sm-10 accuracy (71.1%)."""
    t = _truth()
    rng = np.random.default_rng(seed)
    x, y = _sample(n, rng)
    ind = (x[:, t.feats] * t.sgn[None] > t.thr[None] * t.sgn[None])
    counts = ind[:, :, :bits_per_class].sum(-1)          # (n, C)
    pred = np.argmax(counts, axis=1)                     # ties -> lower idx
    return float((pred == y).mean())


def load_jsc(n_train: int = 20000, n_test: int = 5000,
             seed: int = 0) -> JSCData:
    rng = np.random.default_rng(seed)
    x_tr, y_tr = _sample(n_train, rng)
    x_te, y_te = _sample(n_test, rng)
    x_tr, lo, hi = normalize_to_unit(x_tr)
    x_te, _, _ = normalize_to_unit(x_te, lo, hi)
    return JSCData(x_tr, y_tr, x_te, y_te)


def batches(x: np.ndarray, y: np.ndarray, batch: int, *, seed: int,
            epoch: int, drop_remainder: bool = True):
    """Deterministic shuffled minibatch iterator (resumable by (seed, epoch))."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, epoch]))
    order = rng.permutation(x.shape[0])
    end = (x.shape[0] // batch) * batch if drop_remainder else x.shape[0]
    for i in range(0, end, batch):
        idx = order[i:i + batch]
        yield x[idx], y[idx]
