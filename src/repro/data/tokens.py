"""Deterministic synthetic LM token pipeline (shardable, resumable).

No datasets ship in this container, so the LM examples/tests train on a
synthetic integer-sequence language with learnable structure (a mixture
of n-gram-ish Markov chains + copy motifs), generated deterministically
from (seed, step, host) — which makes the iterator:

* **shardable**: each data-parallel host draws its own disjoint batch
  slice by construction (no coordination, no file system),
* **resumable**: state is just the step counter (rides in the checkpoint
  manifest), skip-ahead is O(1),
* **order-robust**: batch content depends only on (seed, step), not on
  worker scheduling.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TokenStream:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0
    step: int = 0                      # iterator state (checkpointable)

    def __post_init__(self):
        assert self.global_batch % self.num_hosts == 0
        master = np.random.default_rng(self.seed ^ 0x5EED)
        # fixed Markov backbone: per-state preferred successors
        self._trans = master.integers(
            0, self.vocab_size, (min(self.vocab_size, 4096), 4))

    @property
    def host_batch(self) -> int:
        return self.global_batch // self.num_hosts

    def state(self) -> dict:
        return {"step": self.step}

    def restore(self, state: dict) -> None:
        self.step = int(state["step"])

    def next_batch(self) -> dict:
        """Returns {tokens, labels} of shape (host_batch, seq_len)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, self.step, self.host_id]))
        B, S, V = self.host_batch, self.seq_len, self.vocab_size
        toks = np.empty((B, S), np.int32)
        toks[:, 0] = rng.integers(0, V, B)
        follow = rng.random((B, S)) < 0.85          # Markov vs random
        rand = rng.integers(0, V, (B, S))
        choice = rng.integers(0, 4, (B, S))
        for t in range(1, S):
            prev = toks[:, t - 1] % self._trans.shape[0]
            nxt = self._trans[prev, choice[:, t]]
            toks[:, t] = np.where(follow[:, t], nxt, rand[:, t])
        self.step += 1
        return {"tokens": toks, "labels": toks.copy()}
