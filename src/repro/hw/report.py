"""Assembled hardware reports: the paper's Tables I/III and Fig. 5 rows.

Paper reference values (AMD xcvu9p, Vivado OOC, Flow_PerfOptimized_high)
are kept here as constants so every benchmark prints our generator's
numbers *next to* the paper's with % error.
"""

from __future__ import annotations

import dataclasses

from .cost import dwn_hw_report, HWReport

# --- paper constants (Tables I and III) -----------------------------------

#: Table I — TEN vs PEN+FT. (LUT, FF, Fmax MHz, latency ns, AxD LUT*ns)
PAPER_TABLE1 = {
    ("lg-2400", "TEN"): dict(luts=4972, ffs=3305, fmax=827, lat=7.3, axd=36296),
    ("lg-2400", "PEN+FT"): dict(luts=7011, ffs=961, fmax=947, lat=2.1,
                                axd=14723, bits=9),
    ("md-360", "TEN"): dict(luts=720, ffs=457, fmax=827, lat=3.6, axd=2592),
    ("md-360", "PEN+FT"): dict(luts=1697, ffs=198, fmax=696, lat=2.6,
                               axd=4412, bits=9),
    ("sm-50", "TEN"): dict(luts=110, ffs=72, fmax=1094, lat=1.5, axd=165),
    ("sm-50", "PEN+FT"): dict(luts=311, ffs=52, fmax=1011, lat=2.0,
                              axd=622, bits=8),
    ("sm-10", "TEN"): dict(luts=20, ffs=22, fmax=3030, lat=0.6, axd=12),
    ("sm-10", "PEN+FT"): dict(luts=64, ffs=18, fmax=1251, lat=1.6,
                              axd=102, bits=6),
}

#: Table III — LUTs and input bit-width for PEN+FT / PEN / TEN.
PAPER_TABLE3 = {
    "sm-10": dict(ft_acc=71.2, ft_luts=64, ft_bits=6,
                  pen_acc=71.3, pen_luts=106, pen_bits=9,
                  ten_acc=71.1, ten_luts=20),
    "sm-50": dict(ft_acc=74.0, ft_luts=311, ft_bits=8,
                  pen_acc=74.0, pen_luts=345, pen_bits=9,
                  ten_acc=74.0, ten_luts=110),
    "md-360": dict(ft_acc=75.6, ft_luts=1697, ft_bits=9,
                   pen_acc=75.6, pen_luts=1994, pen_bits=11,
                   ten_acc=75.6, ten_luts=720),
    "lg-2400": dict(ft_acc=76.3, ft_luts=7011, ft_bits=9,
                    pen_acc=76.3, pen_luts=18330, pen_bits=12,
                    ten_acc=76.3, ten_luts=4972),
}

#: Table II — competing LUT-based architectures on JSC (literature rows).
PAPER_TABLE2 = [
    # (model, acc %, LUT, FF, Fmax MHz, latency ns, AxD)
    ("DWN-PEN+FT (lg-2400) (9-Bit)", 76.3, 7011, 961, 947, 2.1, 14723),
    ("NeuraLUT-Assemble", 76.0, 1780, 540, 941, 2.1, 3738),
    ("TreeLUT", 76.0, 2234, 347, 735, 2.7, 6032),
    ("DWN-PEN+FT (md-360) (9-Bit)", 75.6, 1697, 198, 696, 2.6, 4412),
    ("TreeLUT", 75.0, 796, 74, 887, 1.1, 876),
    ("PolyLUT-Add", 75.0, 36484, 1209, 315, 16.0, 583744),
    ("NeuraLUT", 75.0, 92357, 4885, 368, 14.0, 1292998),
    ("PolyLUT", 75.0, 236541, 2775, 235, 21.0, 4967361),
    ("LLNN", 75.0, 13926, 0, 153, 6.5, 90519),
    ("ReducedLUT", 74.9, 58409, 0, 303, 17.0, 992963),
    ("AmigoLUT-NeuraLUT-S", 74.4, 42742, 4717, 520, 9.6, 410323),
    ("DWN-PEN+FT (sm-50) (8-Bit)", 74.0, 311, 52, 1011, 2.0, 622),
    ("LogicNets*", 73.1, 36415, 2790, 390, 6.0, 218490),
    ("AmigoLUT-NeuraLUT-XS", 72.9, 1243, 1240, 1008, 5.0, 6215),
    ("ReducedLUT", 72.5, 2786, 0, 409, 4.9, 13651),
    ("LogicNets*", 72.1, 15526, 881, 577, 5.0, 77630),
    ("PolyLUT", 72.0, 12436, 773, 646, 5.0, 62180),
    ("NeuraLUT", 72.0, 4684, 341, 727, 3.0, 14148),
    ("PolyLUT-Add", 72.0, 895, 189, 750, 4.0, 3580),
    ("LLNN", 72.0, 6431, 0, 449, 2.2, 14148),
    ("DWN-PEN+FT (sm-10) (6-Bit)", 71.2, 64, 18, 1307, 1.6, 102),
    ("AmigoLUT-NeuraLUT-XS", 71.1, 320, 482, 1445, 3.5, 1120),
]

#: paper accuracy baselines (§III)
PAPER_BASELINES = {"sm-10": 71.1, "sm-50": 74.0, "md-360": 75.6,
                   "lg-2400": 76.3}


@dataclasses.dataclass
class ComparisonRow:
    model: str
    variant: str
    ours: HWReport
    paper_luts: int | None = None

    @property
    def lut_error_pct(self) -> float | None:
        if not self.paper_luts:
            return None
        return 100.0 * (self.ours.total_luts - self.paper_luts) / self.paper_luts


def compare_with_paper(frozen, *, model_name: str, variant: str,
                       input_bits: int | None = None) -> ComparisonRow:
    rep = dwn_hw_report(frozen, variant=variant, name=model_name,
                        input_bits=input_bits)
    paper = None
    if variant == "TEN":
        paper = PAPER_TABLE3.get(model_name, {}).get("ten_luts")
    elif variant == "PEN":
        paper = PAPER_TABLE3.get(model_name, {}).get("pen_luts")
    else:
        paper = PAPER_TABLE3.get(model_name, {}).get("ft_luts")
    return ComparisonRow(model_name, variant, rep, paper)
