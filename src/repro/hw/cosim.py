"""Co-simulation harness: golden-model verification of emitted RTL.

Closes the hardware-generator loop (ROADMAP item 2): the Verilog that
``hw.verilog.emit_dwn`` produces is parsed and *executed* against the
packed inference oracle (``core.model.apply_hard_packed``), asserting
bit-exact agreement on real JSC vectors — per-class counts, the winning
count, and the tie-to-lower argmax index.

Two backends:

* **python** (always available, zero dependencies) — a structural
  interpreter for the restricted Verilog subset the emitter produces:

      =====================  ===========================================
      construct              semantics evaluated
      =====================  ===========================================
      PEN comparator         ``($signed(x[f]) > $signed(W'hC))`` as a
                             signed two's-complement integer compare
      dup-threshold alias    ``assign enc[i] = enc[j];`` (CSE fan-out)
      TEN input alias        ``wire enc = ten_bits;``
      LUT6 lookup            ``INIT_l_j[{sel5, ..., sel0}]`` — MSB-first
                             concat selects bit ``sum(sel_i << i)`` of
                             the 64-bit truth-table constant
      popcount               ``pc_c = prev[a] + prev[b] + ...`` (the
                             adder chain synthesis maps to a compressor
                             tree; evaluated as an exact integer sum)
      pipeline register      ``always @(posedge clk) q <= d;`` — the
                             datapath is feed-forward, so steady state
                             is ``q == d`` (the simulator backend clocks
                             the pipeline for real)
      argmax                 the strict-``>`` comparator chain; ties
                             keep the lower class index
      =====================  ===========================================

  Any line outside this subset raises :class:`CosimParseError` — the
  evaluator refuses to silently skip constructs it does not model.

* **iverilog** (optional, auto-detected at runtime) — emits a
  self-checking testbench (:func:`emit_testbench`), compiles DUT + bench
  with Icarus Verilog, runs ``vvp``, and compares ``$display`` output
  lines (no VCD parsing).  Same comparison, real event-driven
  simulation, real clocked pipeline registers.

Entry points: :func:`verify_rtl` (library; also exposed as the
``DWNArtifact.verify_rtl`` lifecycle method) and
``python -m repro.hw.cosim`` (CLI — the CI gate over the
``dwn-jsc-{sm,md,lg}`` presets, TEN and PEN).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import re
import shutil
import subprocess
import sys
import tempfile
from pathlib import Path

import numpy as np

from .verilog import emit_dwn


class CosimError(RuntimeError):
    """Base class for co-simulation failures."""


class CosimParseError(CosimError):
    """The netlist contains a construct outside the supported subset."""


class SimulatorError(CosimError):
    """The external simulator is missing or failed to compile/run."""


class RTLMismatch(CosimError):
    """The emitted RTL disagrees with the packed oracle."""


# ---------------------------------------------------------------------------
# evaluator primitives (property-tested against direct numpy models)
# ---------------------------------------------------------------------------

def as_signed(value, width: int):
    """Reinterpret ``width``-bit patterns as two's-complement integers."""
    v = np.asarray(value, np.int64)
    sign = np.int64(1) << np.int64(width - 1)
    return np.where(v & sign, v - (np.int64(1) << np.int64(width)), v)


def eval_comparator(x, const: int, width: int):
    """The PEN comparator: ``$signed(x) > $signed(width'h const)``.

    ``x`` are already-signed integers on the (1, n) grid; ``const`` is
    the raw two's-complement literal from the netlist.
    """
    thr = int(as_signed(const, width))
    return (np.asarray(x, np.int64) > thr).astype(np.uint8)


def eval_lut(init: int, sel):
    """LUT lookup: bit ``sum(sel[..., i] << i)`` of the ``init`` constant.

    ``sel[..., i]`` is address bit i (LSB); matches the emitted MSB-first
    concat ``INIT[{sel_{n-1}, ..., sel_0}]``.
    """
    sel = np.asarray(sel, np.int64)
    n = sel.shape[-1]
    table = np.array([(init >> a) & 1 for a in range(1 << n)], np.uint8)
    addr = np.zeros(sel.shape[:-1], np.int64)
    for i in range(n):
        addr |= sel[..., i] << i
    return table[addr]


def eval_popcount(bits):
    """Exact integer sum over the last axis of a {0,1} array."""
    return np.asarray(bits, np.int64).sum(axis=-1)


def eval_argmax(counts):
    """(max_count, argmax) with ties resolved to the LOWER class index —
    the strict-``>`` chain the RTL implements, and ``np.argmax``'s
    first-maximum rule."""
    c = np.asarray(counts, np.int64)
    return c.max(axis=-1), c.argmax(axis=-1)


def fixed_point_int(values, frac_bits: int):
    """Float features -> signed integers on the (1, n) grid.

    Mirrors the oracle's ``quantize_fixed_point`` (round in float32,
    clip to [-1, (2^n - 1)/2^n]) then scales to the integer the hardware
    comparator sees.  Exact for ``frac_bits <= 23`` (the grid values are
    float32-representable).
    """
    from ..core.thermometer import quantize_fixed_point
    q = np.asarray(quantize_fixed_point(np.asarray(values, np.float32),
                                        frac_bits))
    return np.round(q.astype(np.float64) * (1 << frac_bits)).astype(np.int64)


# ---------------------------------------------------------------------------
# netlist parser
# ---------------------------------------------------------------------------

_RE_PORT_PEN = re.compile(
    r"^input\s+wire\s+signed\s+\[(\d+):0\]\s+(\w+)\s+\[(\d+)\],?$")
_RE_PORT_TEN = re.compile(r"^input\s+wire\s+\[(\d+):0\]\s+(\w+),?$")
_RE_PORT_OUT = re.compile(r"^output\s+wire\s+\[(\d+):0\]\s+(\w+),?$")
_RE_WIRE = re.compile(r"^wire\s+\[(\d+):0\]\s+(\w+);$")
_RE_WIRE_EQ = re.compile(r"^wire\s+\[(\d+):0\]\s+(\w+)\s*=\s*(.+);$")
_RE_REG = re.compile(r"^reg\s+\[(\d+):0\]\s+(\w+);$")
_RE_FF = re.compile(r"^always\s+@\(posedge clk\)\s+(\w+)\s*<=\s*(\w+);$")
_RE_ASSIGN = re.compile(r"^assign\s+(\w+)(?:\[(\d+)\])?\s*=\s*(.+);$")
_RE_LOCALPARAM = re.compile(
    r"^localparam\s+\[(\d+):0\]\s+(\w+)\s*=\s*\d+'h([0-9a-fA-F]+);$")
_RE_CMP = re.compile(
    r"^\(\$signed\((\w+)\[(\d+)\]\)\s*>\s*\$signed\((\d+)'h([0-9a-fA-F]+)\)\)$")
_RE_BITREF = re.compile(r"^(\w+)\[(\d+)\]$")
_RE_LUTREF = re.compile(r"^(\w+)\[\{(.+)\}\]$")
_RE_AM_INIT = re.compile(r"^best_v\s*=\s*(\w+);\s*best_i\s*=\s*\d+'d0;$")
_RE_AM_IF = re.compile(
    r"^if\s+\((\w+)\s*>\s*best_v\)\s+begin\s+best_v\s*=\s*(\w+);\s*"
    r"best_i\s*=\s*\d+'d(\d+);\s+end$")


@dataclasses.dataclass
class ParsedNetlist:
    """Structural view of one emitted DWN module (python backend IR)."""

    name: str
    pen: bool
    input_name: str
    input_width: int              # per-element width (PEN) / total (TEN)
    num_features: int             # PEN array size; 0 for TEN
    out_count: str                # max_count port name
    out_index: str                # argmax_idx port name
    widths: dict                  # bit-vector signal -> width
    ops: list                     # ordered evaluation plan
    argmax_srcs: list             # per-class count signal names, in order
    meta: dict                    # parsed // header metadata


def _bitrefs(expr: str) -> list[tuple[str, int]]:
    refs = []
    for part in expr.split("+"):
        m = _RE_BITREF.match(part.strip())
        if not m:
            raise CosimParseError(f"unsupported sum term: {part.strip()!r}")
        refs.append((m.group(1), int(m.group(2))))
    return refs


def parse_netlist(src: str) -> ParsedNetlist:
    """Parse one emitted DWN module into an ordered evaluation plan.

    Raises :class:`CosimParseError` on any construct outside the
    supported subset (see module docstring).
    """
    meta: dict = {}
    name = ""
    pen = False
    input_name, input_width, num_features = "", 0, 0
    outs: list[tuple[str, int]] = []
    widths: dict = {}
    ops: list = []
    argmax_srcs: list = []
    in_ports = False
    in_argmax = False

    for raw in src.splitlines():
        line = raw.strip()
        if not line:
            continue
        if line.startswith("//"):
            for kv in line[2:].split():
                if "=" in kv:
                    k, _, v = kv.partition("=")
                    meta.setdefault(k, v)
            continue
        code = line.split("//", 1)[0].strip()
        if not code:
            continue

        if in_argmax:
            if code == "end":
                in_argmax = False
                continue
            m = _RE_AM_INIT.match(code)
            if m:
                argmax_srcs = [m.group(1)]
                continue
            m = _RE_AM_IF.match(code)
            if m:
                if m.group(1) != m.group(2):
                    raise CosimParseError(f"argmax update reads "
                                          f"{m.group(1)} but assigns "
                                          f"{m.group(2)}")
                c = int(m.group(3))
                if c != len(argmax_srcs):
                    raise CosimParseError(
                        f"argmax class {c} out of order "
                        f"(expected {len(argmax_srcs)})")
                argmax_srcs.append(m.group(1))
                continue
            raise CosimParseError(f"unsupported argmax statement: {code!r}")

        if code.startswith("module "):
            name = code.split()[1]
            in_ports = True
            continue
        if in_ports:
            if code == ");":
                in_ports = False
                continue
            if code == "input  wire clk," or code == "input wire clk,":
                continue
            m = _RE_PORT_PEN.match(code)
            if m:
                pen = True
                input_width = int(m.group(1)) + 1
                input_name = m.group(2)
                num_features = int(m.group(3))
                continue
            m = _RE_PORT_OUT.match(code)
            if m:
                outs.append((m.group(2), int(m.group(1)) + 1))
                continue
            m = _RE_PORT_TEN.match(code)
            if m:
                input_name = m.group(2)
                input_width = int(m.group(1)) + 1
                widths[input_name] = input_width
                continue
            raise CosimParseError(f"unsupported port: {code!r}")
        if code == "endmodule":
            continue

        m = _RE_WIRE.match(code) or _RE_REG.match(code)
        if m:
            widths[m.group(2)] = int(m.group(1)) + 1
            continue
        m = _RE_WIRE_EQ.match(code)
        if m:
            w, dst, rhs = int(m.group(1)) + 1, m.group(2), m.group(3).strip()
            widths[dst] = w
            if re.fullmatch(r"\w+", rhs):
                ops.append(("vec", dst, rhs))        # wire enc = ten_bits;
            else:
                ops.append(("sum", dst, _bitrefs(rhs)))
            continue
        m = _RE_FF.match(code)
        if m:
            ops.append(("vec", m.group(1), m.group(2)))
            continue
        m = _RE_LOCALPARAM.match(code)
        if m:
            widths[m.group(2)] = int(m.group(1)) + 1
            ops.append(("const", m.group(2), int(m.group(3), 16)))
            continue
        m = _RE_ASSIGN.match(code)
        if m:
            dst, bit, rhs = m.group(1), m.group(2), m.group(3).strip()
            if bit is None:                          # assign max_count = ...
                if not re.fullmatch(r"\w+", rhs):
                    raise CosimParseError(f"unsupported assign RHS: {rhs!r}")
                ops.append(("out", dst, rhs))
                continue
            bit = int(bit)
            mc = _RE_CMP.match(rhs)
            if mc:
                ops.append(("cmp", dst, bit, mc.group(1), int(mc.group(2)),
                            int(mc.group(3)), int(mc.group(4), 16)))
                continue
            ml = _RE_LUTREF.match(rhs)
            if ml:
                sels = [s.strip() for s in ml.group(2).split(",")]
                refs = []
                for s in sels:
                    mb = _RE_BITREF.match(s)
                    if not mb:
                        raise CosimParseError(f"unsupported LUT select: "
                                              f"{s!r}")
                    refs.append((mb.group(1), int(mb.group(2))))
                ops.append(("lut", dst, bit, ml.group(1), refs))
                continue
            mb = _RE_BITREF.match(rhs)
            if mb:                                   # dup-threshold alias
                ops.append(("bit", dst, bit, mb.group(1), int(mb.group(2))))
                continue
            raise CosimParseError(f"unsupported assign RHS: {rhs!r}")
        if code == "always @* begin":
            in_argmax = True
            continue
        raise CosimParseError(f"unsupported statement: {code!r}")

    if not name:
        raise CosimParseError("no module declaration found")
    if len(outs) != 2:
        raise CosimParseError(f"expected max_count + argmax_idx outputs, "
                              f"found {[o[0] for o in outs]}")
    if not argmax_srcs:
        raise CosimParseError("no argmax block found")
    return ParsedNetlist(
        name=name, pen=pen, input_name=input_name, input_width=input_width,
        num_features=num_features, out_count=outs[0][0],
        out_index=outs[1][0], widths=widths, ops=ops,
        argmax_srcs=argmax_srcs, meta=meta)


# ---------------------------------------------------------------------------
# pure-Python netlist evaluator
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EvalResult:
    """Batch outputs of one netlist evaluation."""

    max_count: np.ndarray         # (B,) int64
    argmax_idx: np.ndarray        # (B,) int64
    class_counts: np.ndarray      # (B, classes) int64


def evaluate_netlist(src_or_parsed, *, x=None, ten_bits=None) -> EvalResult:
    """Evaluate an emitted DWN netlist on a batch of inputs.

    Args:
      src_or_parsed: Verilog source (or an already-:func:`parse_netlist`
        result).
      x: (B, F) float features — PEN modules only; quantized to the
        module's (1, n) grid exactly like the oracle.
      ten_bits: (B, F*T) {0,1} thermometer bits — TEN modules only.

    Returns an :class:`EvalResult`.  Statements evaluate in source order
    (the emitter is topologically ordered); pipeline registers are
    steady-state copies (the datapath is feed-forward).
    """
    net = (src_or_parsed if isinstance(src_or_parsed, ParsedNetlist)
           else parse_netlist(src_or_parsed))
    env: dict = {}
    consts: dict = {}
    outs: dict = {}
    if net.pen:
        if x is None:
            raise ValueError(f"module {net.name} is PEN: pass x=(B, F) "
                             f"float features")
        x = np.asarray(x)
        if x.ndim != 2 or x.shape[1] != net.num_features:
            raise ValueError(f"x has shape {x.shape}; module {net.name} "
                             f"expects (B, {net.num_features})")
        B = x.shape[0]
        env[net.input_name] = fixed_point_int(x, net.input_width - 1)
    else:
        if ten_bits is None:
            raise ValueError(f"module {net.name} is TEN: pass "
                             f"ten_bits=(B, {net.input_width}) bits")
        bits = np.asarray(ten_bits)
        if bits.ndim != 2 or bits.shape[1] != net.input_width:
            raise ValueError(f"ten_bits has shape {bits.shape}; module "
                             f"{net.name} expects (B, {net.input_width})")
        B = bits.shape[0]
        env[net.input_name] = bits.astype(np.uint8)

    def vec(name: str) -> np.ndarray:
        if name not in env:
            if name not in net.widths:
                raise CosimParseError(f"signal {name!r} read before "
                                      f"declaration")
            # unassigned bits of a declared vector default to 0; the
            # emitter guarantees every *read* bit was assigned (LUT
            # selects are exactly the used-threshold mask)
            env[name] = np.zeros((B, net.widths[name]), np.uint8)
        return env[name]

    for op in net.ops:
        tag = op[0]
        if tag == "const":
            consts[op[1]] = op[2]
        elif tag == "cmp":
            _, dst, bit, src, feat, w, c = op
            vec(dst)[:, bit] = eval_comparator(env[src][:, feat], c, w)
        elif tag == "bit":
            _, dst, bit, s, sbit = op
            vec(dst)[:, bit] = vec(s)[:, sbit]
        elif tag == "lut":
            _, dst, bit, table_name, refs = op
            if table_name not in consts:
                raise CosimParseError(f"LUT constant {table_name!r} read "
                                      f"before its localparam")
            # refs are MSB-first in the concat: refs[p] is address bit
            # (n - 1 - p)
            sel = np.stack([vec(s)[:, b] for s, b in reversed(refs)],
                           axis=-1)
            vec(dst)[:, bit] = eval_lut(consts[table_name], sel)
        elif tag == "vec":
            _, dst, s = op
            env[dst] = vec(s).copy()
        elif tag == "sum":
            _, dst, refs = op
            env[dst] = eval_popcount(
                np.stack([vec(s)[:, b] for s, b in refs], axis=-1))
        elif tag == "out":
            # output-port assigns read the argmax registers, which settle
            # after the full combinational pass — resolve them at the end
            outs[op[1]] = op[2]
        else:                                        # pragma: no cover
            raise CosimParseError(f"unknown op {tag!r}")
        if tag == "sum":
            env[op[1]] = np.asarray(env[op[1]], np.int64)

    counts = np.stack([np.asarray(env[s], np.int64)
                       for s in net.argmax_srcs], axis=-1)
    best_v, best_i = eval_argmax(counts)
    env["best_v"], env["best_i"] = best_v, best_i
    for dst, s in outs.items():
        if s not in env:
            raise CosimParseError(f"output {dst!r} reads unassigned {s!r}")
        env[dst] = env[s]
    if net.out_count not in env or net.out_index not in env:
        raise CosimParseError("output ports never assigned")
    return EvalResult(max_count=np.asarray(env[net.out_count], np.int64),
                      argmax_idx=np.asarray(env[net.out_index], np.int64),
                      class_counts=counts)


# ---------------------------------------------------------------------------
# simulator backend (iverilog, auto-detected)
# ---------------------------------------------------------------------------

def simulator_available() -> str | None:
    """Name of the detected external simulator, or None.

    Currently Icarus Verilog (``iverilog`` + ``vvp``); the testbench is
    plain SystemVerilog-2012, so a verilator flow could slot in later —
    the pure-Python evaluator is the guaranteed CI path either way.
    """
    if shutil.which("iverilog") and shutil.which("vvp"):
        return "iverilog"
    return None


def emit_testbench(frozen, x, *, name: str = "dwn_top",
                   tb_name: str = "tb_dwn", pipeline: bool = True) -> str:
    """Emit a self-checking testbench driving ``x`` through the DUT.

    One ``COSIM <max_count> <argmax_idx>`` stdout line per vector (no
    VCD); each vector is held for enough clock cycles to flush the
    pipeline before sampling.
    """
    from ..core.thermometer import encode_np

    if hasattr(frozen, "spec"):
        frozen = frozen.frozen
    cfg = frozen.cfg
    spec = cfg.thermometer
    F, T = spec.num_features, spec.bits_per_feature
    classes = cfg.num_classes
    group = cfg.lut_counts[-1] // classes
    cnt_w = max(1, int(np.ceil(np.log2(group + 1))))
    idx_w = max(1, int(np.ceil(np.log2(classes))))
    pen = frozen.input_frac_bits is not None
    x = np.asarray(x)
    # pipeline depth: enc_q + one register per LUT layer + pc_q
    cycles = (2 + len(cfg.lut_counts)) + 2 if pipeline else 2

    lines: list[str] = []
    w = lines.append
    w("`timescale 1ns/1ps")
    w(f"module {tb_name};")
    w("  reg clk = 0;")
    w("  always #5 clk = ~clk;")
    if pen:
        in_w = 1 + frozen.input_frac_bits
        w(f"  reg signed [{in_w - 1}:0] x [0:{F - 1}];")
        port = ".x(x)"
        vals = fixed_point_int(x, frozen.input_frac_bits)
    else:
        w(f"  reg [{F * T - 1}:0] ten_bits;")
        port = ".ten_bits(ten_bits)"
        bits = encode_np(x, frozen.thresholds).astype(np.uint64)
    w(f"  wire [{cnt_w - 1}:0] max_count;")
    w(f"  wire [{idx_w - 1}:0] argmax_idx;")
    w(f"  {name} dut (.clk(clk), {port}, .max_count(max_count), "
      f".argmax_idx(argmax_idx));")
    w("  initial begin")
    for i in range(x.shape[0]):
        w(f"    // vector {i}")
        if pen:
            mask = (1 << in_w) - 1
            for f in range(F):
                w(f"    x[{f}] = {in_w}'h{int(vals[i, f]) & mask:x};")
        else:
            word = 0
            for k in range(F * T):
                if bits[i, k]:
                    word |= 1 << k
            w(f"    ten_bits = {F * T}'h{word:x};")
        w(f"    repeat ({cycles}) @(posedge clk);")
        w('    #1 $display("COSIM %0d %0d", max_count, argmax_idx);')
    w("    $finish;")
    w("  end")
    w("endmodule")
    return "\n".join(lines) + "\n"


def run_iverilog(dut_src: str, tb_src: str, *, tb_name: str = "tb_dwn",
                 timeout: float = 600.0) -> list[tuple[int, int]]:
    """Compile DUT + testbench with iverilog, run vvp, parse COSIM lines.

    Returns [(max_count, argmax_idx), ...] in vector order.  Raises
    :class:`SimulatorError` when the toolchain is missing or fails.
    """
    if simulator_available() is None:
        raise SimulatorError("no Verilog simulator found (need iverilog "
                             "+ vvp on PATH); use backend='python'")
    with tempfile.TemporaryDirectory(prefix="cosim_") as tmp:
        tmp = Path(tmp)
        (tmp / "dut.v").write_text(dut_src)
        (tmp / "tb.v").write_text(tb_src)
        out = tmp / "sim.out"
        comp = subprocess.run(
            ["iverilog", "-g2012", "-s", tb_name, "-o", str(out),
             str(tmp / "dut.v"), str(tmp / "tb.v")],
            capture_output=True, text=True, timeout=timeout)
        if comp.returncode != 0:
            raise SimulatorError(f"iverilog compile failed:\n{comp.stderr}")
        run = subprocess.run(["vvp", str(out)], capture_output=True,
                             text=True, timeout=timeout)
        if run.returncode != 0:
            raise SimulatorError(f"vvp failed:\n{run.stderr}")
    results = []
    for line in run.stdout.splitlines():
        if line.startswith("COSIM "):
            _, a, b = line.split()
            results.append((int(a), int(b)))
    if not results:
        raise SimulatorError(f"no COSIM output lines from vvp:\n"
                             f"{run.stdout[:2000]}")
    return results


# ---------------------------------------------------------------------------
# verify_rtl: the golden-model gate
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CosimReport:
    """Outcome of one :func:`verify_rtl` run (only returned on success —
    any disagreement raises :class:`RTLMismatch` instead)."""

    model: str
    variant: str
    n_vectors: int
    backends: list
    counts_checked: bool          # per-class counts compared (python path)
    spec: str | None = None
    src: str = dataclasses.field(default="", repr=False)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.pop("src")
        return d


def _resolve_frozen(target):
    """(frozen, spec_label) from a FrozenDWN or a DWNArtifact."""
    if hasattr(target, "spec"):
        if target.frozen is None:
            raise ValueError(
                f"artifact {target.spec.label} is at stage "
                f"{target.stage!r}; call freeze() before verify_rtl()")
        return target.frozen, target.spec.label
    return target, None


def verify_rtl(target, x=None, *, n: int = 256, backend: str = "auto",
               pipeline: bool = True, name: str = "dwn_top",
               seed: int = 0, src: str | None = None,
               max_report: int = 5) -> CosimReport:
    """Prove the emitted RTL computes what ``apply_hard_packed`` computes.

    Args:
      target: a ``DWNArtifact`` at stage >= frozen, or a ``FrozenDWN``.
      x: (B, F) float feature vectors; defaults to ``n`` real test
        vectors of the artifact spec's workload (JSC for a bare
        16-feature ``FrozenDWN``, seeded uniform vectors otherwise).
      n: number of default vectors when ``x`` is None.
      backend: "python" (pure evaluator), "iverilog" (external simulator,
        raises :class:`SimulatorError` if absent), or "auto" (python
        always + the simulator when detected).
      pipeline: emit/verify the pipelined module.
      name: emitted module name.
      src: pre-emitted Verilog to verify instead of emitting here (for
        mutation testing — must match ``name``/``pipeline``).
      max_report: mismatching vectors quoted in the failure message.

    Returns a :class:`CosimReport` (carrying the verified source in
    ``.src``).  Raises :class:`RTLMismatch` on ANY disagreement in
    argmax index, winning count, or (python backend) per-class counts.
    """
    import jax.numpy as jnp

    from ..core.model import apply_hard_packed
    from ..core.thermometer import encode_np

    frozen, spec_label = _resolve_frozen(target)
    if x is None:
        if hasattr(target, "spec"):
            # artifact: real test vectors of the spec's own workload
            from ..workloads import load_workload
            x = load_workload(target.spec.workload, 512, max(n, 1),
                              seed=seed).x_test[:n]
        elif frozen.cfg.num_features == 16:
            # bare FrozenDWN at the JSC geometry: the legacy default
            from ..data.jsc import load_jsc
            x = load_jsc(512, max(n, 1), seed=seed).x_test[:n]
        else:
            # bare FrozenDWN of unknown provenance: seeded vectors over
            # the encoder's input domain
            rng = np.random.default_rng(seed)
            x = rng.uniform(-1.0, 1.0,
                            (n, frozen.cfg.num_features)).astype(np.float32)
    x = np.asarray(x, np.float32)
    if src is None:
        src = emit_dwn(frozen, name=name, pipeline=pipeline)

    counts = np.asarray(apply_hard_packed(frozen, jnp.asarray(x)))
    oracle_max, oracle_idx = eval_argmax(counts)

    if backend == "auto":
        backends = ["python"] + (["iverilog"] if simulator_available()
                                 else [])
    elif backend in ("python", "iverilog"):
        backends = [backend]
    else:
        raise ValueError(f"unknown cosim backend {backend!r}; choose "
                         f"'python', 'iverilog', or 'auto'")

    pen = frozen.input_frac_bits is not None
    counts_checked = False
    for b in backends:
        if b == "python":
            if pen:
                res = evaluate_netlist(src, x=x)
            else:
                res = evaluate_netlist(
                    src, ten_bits=encode_np(x, frozen.thresholds))
            got_max, got_idx = res.max_count, res.argmax_idx
            got_counts = res.class_counts
            counts_checked = True
        else:
            tb = emit_testbench(frozen, x, name=name, pipeline=pipeline)
            pairs = run_iverilog(src, tb)
            if len(pairs) != x.shape[0]:
                raise RTLMismatch(
                    f"[iverilog] {len(pairs)} output lines for "
                    f"{x.shape[0]} vectors")
            got_max = np.array([p[0] for p in pairs], np.int64)
            got_idx = np.array([p[1] for p in pairs], np.int64)
            got_counts = None

        bad = np.nonzero((got_idx != oracle_idx)
                         | (got_max != oracle_max))[0]
        if got_counts is not None and bad.size == 0:
            bad = np.nonzero((got_counts != counts.astype(np.int64))
                             .any(axis=-1))[0]
        if bad.size:
            rows = []
            for i in bad[:max_report]:
                rows.append(
                    f"  vector {i}: oracle argmax={oracle_idx[i]} "
                    f"max={oracle_max[i]} counts={counts[i].tolist()}; "
                    f"rtl argmax={got_idx[i]} max={got_max[i]}"
                    + (f" counts={got_counts[i].tolist()}"
                       if got_counts is not None else ""))
            raise RTLMismatch(
                f"[{b}] emitted RTL disagrees with apply_hard_packed on "
                f"{bad.size}/{x.shape[0]} vectors "
                f"({spec_label or name}):\n" + "\n".join(rows))

    return CosimReport(
        model=name, variant="PEN" if pen else "TEN",
        n_vectors=int(x.shape[0]), backends=backends,
        counts_checked=counts_checked, spec=spec_label, src=src)


# ---------------------------------------------------------------------------
# CLI: the CI co-simulation gate
# ---------------------------------------------------------------------------

def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Co-simulate emitted DWN RTL against the packed "
                    "oracle on real vectors of each preset's workload.")
    ap.add_argument("--presets", default="dwn-jsc-sm,dwn-jsc-md,dwn-jsc-lg",
                    help="comma-separated registered spec presets (any "
                         "workload, e.g. dwn-mnist-sm)")
    ap.add_argument("--variants", default="TEN,PEN",
                    help="encoding variants to verify per preset")
    ap.add_argument("--input-bits", type=int, default=9,
                    help="PEN fixed-point input width (total bits)")
    ap.add_argument("--n", type=int, default=256,
                    help="workload test vectors per verification")
    ap.add_argument("--n-train", type=int, default=2000,
                    help="workload training samples (threshold fit)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "python", "iverilog"])
    ap.add_argument("--no-pipeline", action="store_true",
                    help="verify the unpipelined (combinational) module")
    ap.add_argument("--require-simulator", action="store_true",
                    help="fail (exit 2) instead of skipping when no "
                         "external simulator is on PATH")
    ap.add_argument("--out", default="",
                    help="write the per-preset report JSON here")
    args = ap.parse_args(argv)

    import dataclasses as dc

    from ..dwn import DWNArtifact
    from ..dwn.spec import get_spec
    from ..workloads import load_workload

    if args.require_simulator and simulator_available() is None:
        print("cosim: --require-simulator set but no iverilog/vvp on "
              "PATH", file=sys.stderr)
        return 2
    if args.backend == "iverilog" and simulator_available() is None:
        print("cosim: backend=iverilog requested but no iverilog/vvp on "
              "PATH", file=sys.stderr)
        return 2

    splits: dict = {}                          # workload name -> split

    def data_for(workload: str):
        if workload not in splits:
            splits[workload] = load_workload(
                workload, args.n_train, max(args.n, 1), seed=args.seed)
        return splits[workload]

    models: dict = {}
    rows, failures = [], 0
    for preset in [p for p in args.presets.split(",") if p]:
        base = get_spec(preset)
        data = data_for(base.workload)
        for variant in [v for v in args.variants.split(",") if v]:
            spec = base if base.variant == variant else dc.replace(
                base, variant=variant,
                input_bits=None if variant == "TEN" else args.input_bits)
            mkey = (spec.workload, spec.preset, spec.bits, spec.placement)
            if mkey not in models:
                ten = dc.replace(spec, variant="TEN", input_bits=None)
                a = DWNArtifact(ten).fit(data.x_train, seed=args.seed)
                models[mkey] = (a.params, a.buffers)
            art = DWNArtifact(spec)
            art.adopt(*models[mkey], note="cosim").freeze()
            try:
                rep = verify_rtl(art, data.x_test[:args.n],
                                 backend=args.backend,
                                 pipeline=not args.no_pipeline)
                rows.append(rep.to_dict() | {"agree": True})
                print(f"cosim OK   {spec.label}: {rep.n_vectors} vectors "
                      f"bit-exact on {'+'.join(rep.backends)}", flush=True)
            except RTLMismatch as e:
                failures += 1
                rows.append({"spec": spec.label, "agree": False,
                             "error": str(e)})
                print(f"cosim FAIL {spec.label}:\n{e}", file=sys.stderr,
                      flush=True)
    if args.out:
        Path(args.out).write_text(json.dumps(
            {"n_vectors": args.n, "backend": args.backend,
             "simulator": simulator_available(), "results": rows},
            indent=1))
        print(f"written {args.out}")
    return 1 if failures else 0


__all__ = [
    "CosimError", "CosimParseError", "CosimReport", "EvalResult",
    "ParsedNetlist", "RTLMismatch", "SimulatorError", "as_signed",
    "emit_testbench", "eval_argmax", "eval_comparator", "eval_lut",
    "eval_popcount", "evaluate_netlist", "fixed_point_int", "main",
    "parse_netlist", "run_iverilog", "simulator_available", "verify_rtl",
]

if __name__ == "__main__":
    sys.exit(main())
