"""Technology-mapped FPGA cost model (Xilinx UltraScale+ xcvu9p, LUT6).

Mirrors the paper's FloPoCo-based generator structurally:

* thermometer encoder  -> one constant comparator per *distinct, used*
  (feature, threshold) pair (Fig. 3; dedup after PTQ quantization);
* LUT layer            -> m physical LUT6 (exact);
* popcount             -> GPC compressor tree (6:3 and 3:2 compressors,
  3 resp. 1 LUT each, per FloPoCo's compressor-tree chapter [24]) run to
  completion, then a final carry adder (1 LUT/bit);
* argmax               -> pairwise comparator/mux reduction tree (Fig. 4).

All constants are given explicitly below and the calibration against the
paper's Table I TEN rows is reported by ``benchmarks/table1_hardware.py``
(our counts next to the paper's with % error).  Fmax/FF figures are
estimates from pipeline-register placement and logic depth and are
labelled as such.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

# --- technology constants ---------------------------------------------------
# All delays in nanoseconds (xcvu9p speed-grade-2 ballpark figures).
T_LUT_NS = 0.20          # LUT6 switching delay
T_ROUTE_NS = 0.45        # average routed-net delay
T_CARRY_NS = 0.05        # per CARRY8 block


def comparator_luts(width: int) -> int:
    """Physical LUT6 count of a constant comparator ``x >= const``.

    width<=6 : any boolean function of <=6 inputs is exactly one LUT6.
    Wider    : 6-bit segments produce (gt, eq) via dual-output LUT6_2;
               the combine chain folds into one extra LUT per segment pair
               (carry-assisted).  Net effect: ceil(width/6) + segments-1.

    Args:
      width: input bit-width of ``x`` (total bits, sign included).

    Returns the LUT6 count (0 for non-positive widths).
    """
    if width <= 0:
        return 0
    seg = math.ceil(width / 6)
    return seg + max(0, seg - 1)


def comparator_levels(width: int) -> int:
    """Combinational logic depth (LUT levels, unitless) of the same
    constant comparator: one level of segment LUTs plus a log2 combine
    tree over the segments."""
    seg = math.ceil(width / 6)
    return 1 + (0 if seg == 1 else math.ceil(math.log2(seg)))


def two_input_comparator_luts(width: int) -> int:
    """LUT6 count of a two-variable comparator ``x > y``.

    Args:
      width: bit-width of *each* operand — the function sees 2*width
        input bits, segmented six at a time like :func:`comparator_luts`.

    Returns the LUT6 count.
    """
    if width <= 0:
        return 0
    seg = math.ceil(2 * width / 6)
    return seg + max(0, seg - 1)


def mux2_luts(width: int) -> int:
    """LUT6 count of a 2:1 mux of a ``width``-bit value: sel+2 data = 3
    inputs/bit; one dual-output LUT6_2 packs two bits."""
    return math.ceil(width / 2)


# --- popcount: GPC compressor-tree simulation --------------------------------

@dataclasses.dataclass
class CompressorTreeResult:
    luts: int                  # physical LUT6 count
    stages: int                # compressor stages (logic levels, unitless)
    out_bits: int              # result bit-width


def popcount_tree(n_bits: int) -> CompressorTreeResult:
    """Greedy GPC schedule: per stage, cover each column with 6:3 (3 LUTs)
    then 3:2 (1 LUT) compressors until every column has <= 2 bits, then a
    final ripple-carry add (1 LUT/bit via CARRY8).

    Args:
      n_bits: number of 1-bit inputs to count.

    Returns a :class:`CompressorTreeResult` (total LUT6s, compressor
    stages, result width in bits).
    """
    if n_bits <= 1:
        return CompressorTreeResult(0, 0, max(n_bits, 1))
    if n_bits <= 3:
        # half/full adder: sum+carry are two functions of <=3 shared
        # inputs -> one dual-output LUT6_2
        return CompressorTreeResult(1, 1, 2)
    if n_bits <= 6:
        # one 6:3 compressor = the 3-bit count (3 x 6-input functions)
        return CompressorTreeResult(3, 1, 3)
    out_width = math.ceil(math.log2(n_bits + 1))
    cols = [n_bits] + [0] * (out_width - 1)   # bits per column (weight 2^i)
    luts = 0
    stages = 0
    while max(cols) > 2:
        stages += 1
        nxt = [0] * len(cols)
        for c, h in enumerate(cols):
            while h >= 6:
                h -= 6
                luts += 3
                for d in range(3):            # 3-bit count -> cols c..c+2
                    if c + d < len(nxt):
                        nxt[c + d] += 1
            while h >= 3:
                h -= 3
                luts += 1
                for d in range(2):
                    if c + d < len(nxt):
                        nxt[c + d] += 1
            nxt[c] += h                       # passthrough leftovers
        cols = nxt
    # final 2-row carry-propagate add
    width = max(i for i, h in enumerate(cols) if h) + 1
    luts += width
    return CompressorTreeResult(luts, stages + 1, out_width)


# --- component-level costs ----------------------------------------------


@dataclasses.dataclass
class ComponentCost:
    """One component's price: physical LUT6s, flip-flops, and
    combinational logic levels (unitless depth; multiply by per-level
    delay to get ns)."""

    luts: int
    ffs: int
    levels: int                # combinational logic levels

    def __add__(self, o: "ComponentCost") -> "ComponentCost":
        return ComponentCost(self.luts + o.luts, self.ffs + o.ffs,
                             self.levels + o.levels)


def encoder_cost(distinct_per_feature: list[int], input_bits: int,
                 used_bits: int, *, pipeline: bool = True) -> ComponentCost:
    """Thermometer encoder bank (the PEN on-chip encoder).

    Args:
      distinct_per_feature: number of *distinct used* threshold values per
        feature after PTQ dedup (CSE); each is one constant comparator.
      input_bits: fixed-point input width in total bits (sign included) —
        sets the per-comparator LUT count.
      used_bits: encoder output bits actually wired to the LUT layer
        (registered at the component boundary when pipelined — the FF
        count).

    Returns the encoder's :class:`ComponentCost`.
    """
    n_cmp = int(sum(distinct_per_feature))
    luts = n_cmp * comparator_luts(input_bits)
    ffs = used_bits if pipeline else 0
    return ComponentCost(luts, ffs, comparator_levels(input_bits))


def lut_layer_cost(num_luts: int, *, pipeline: bool = True) -> ComponentCost:
    """One LUT layer: ``num_luts`` (m) physical LUT6s exactly, one logic
    level, one output register per LUT when pipelined."""
    return ComponentCost(num_luts, num_luts if pipeline else 0, 1)


def popcount_cost(group_size: int, num_classes: int,
                  *, pipeline: bool = True) -> ComponentCost:
    """Per-class popcount bank: one ``group_size``-input GPC compressor
    tree per class (see :func:`popcount_tree`); FFs register each class's
    count when pipelined."""
    tree = popcount_tree(group_size)
    luts = tree.luts * num_classes
    ffs = tree.out_bits * num_classes if pipeline else 0
    return ComponentCost(luts, ffs, tree.stages)


def argmax_cost(num_classes: int, count_bits: int,
                *, pipeline: bool = True) -> ComponentCost:
    """Pairwise reduction (Fig. 4): c-1 nodes of (comparator + value mux +
    index mux); index width grows toward the root.

    Args:
      num_classes: number of class counts reduced.
      count_bits: bit-width of each count (ceil(log2(group_size + 1))).

    Returns the argmax tree's :class:`ComponentCost`.
    """
    luts = 0
    idx_bits = 1
    n = num_classes
    level = 0
    while n > 1:
        pairs = n // 2
        luts += pairs * (two_input_comparator_luts(count_bits)
                         + mux2_luts(count_bits)
                         + mux2_luts(idx_bits))
        n = pairs + n % 2
        idx_bits += 1
        level += 1
    ffs = (count_bits + math.ceil(math.log2(num_classes))) if pipeline else 0
    lv = level * (1 + 1)          # compare + mux per tree level
    return ComponentCost(luts, ffs, lv)


# --- whole-accelerator reports -------------------------------------------


@dataclasses.dataclass
class HWReport:
    """Whole-accelerator cost report.

    Attributes:
      variant: "TEN" | "PEN" | "PEN+FT".
      model: model/preset name the report describes.
      input_bits: PEN fixed-point input width in total bits; None for TEN.
      luts / ffs: per-component physical LUT6 / flip-flop counts, keyed
        "encoder" | "lut_layer" | "popcount" | "argmax".
      levels: end-to-end combinational logic depth (unitless).
      distinct_comparators: encoder comparators after PTQ dedup.
    """

    variant: str                         # "TEN" | "PEN" | "PEN+FT"
    model: str
    input_bits: int | None
    luts: dict                           # component -> LUTs
    ffs: dict
    levels: int
    distinct_comparators: int = 0

    @property
    def total_luts(self) -> int:
        """Total physical LUT6 count over all components."""
        return int(sum(self.luts.values()))

    @property
    def total_ffs(self) -> int:
        """Total flip-flop count over all components."""
        return int(sum(self.ffs.values()))

    @property
    def delay_ns(self) -> float:
        """Unpipelined end-to-end combinational delay estimate in **ns**
        (levels x per-level LUT+route delay) — the latency column."""
        return self.levels * (T_LUT_NS + T_ROUTE_NS)

    @property
    def fmax_mhz(self) -> float:
        """Pipelined clock estimate in **MHz**: with registers between
        components the critical stage is the deepest single component."""
        return 1e3 / max(self.delay_ns / max(self.levels, 1) *  # per level
                         self._max_stage_levels(), 0.1)

    def _max_stage_levels(self) -> int:
        return max(1, self._stage_levels)

    _stage_levels: int = 1

    @property
    def area_delay(self) -> float:
        """A x D product in **LUT·ns** at the pipelined critical-stage
        delay (Table I's AxD column)."""
        return self.total_luts * (1e3 / self.fmax_mhz)


def dwn_hw_report(frozen, *, variant: str | None = None,
                  name: str | None = None,
                  input_bits: int | None = None,
                  pipeline: bool = True) -> HWReport:
    """Full-accelerator cost for a FrozenDWN or a ``repro.dwn`` artifact.

    TEN: inputs are already thermometer bits -> no encoder.
    PEN/PEN+FT: on-chip encoder at `input_bits` total width (1, n).

    Args:
      frozen: the FrozenDWN whose mapping/thresholds set encoder dedup —
        or a ``repro.dwn.DWNArtifact`` at stage >= "frozen", in which
        case ``variant``/``name``/``input_bits`` default to its spec.
      variant: "TEN" | "PEN" | "PEN+FT" (PEN variants price the encoder);
        required unless an artifact is given.
      name: model name recorded in the report; required unless an
        artifact is given.
      input_bits: PEN input width in total bits (required unless TEN).
      pipeline: register component boundaries (sets FF counts and makes
        ``fmax_mhz`` the per-stage estimate).

    Returns the :class:`HWReport` (LUT/FF counts, depth, ns/MHz figures).
    """
    from ..core.thermometer import used_threshold_mask, distinct_used_thresholds
    from ..core.model import DWNConfig  # noqa: F401  (type only)

    spec = getattr(frozen, "spec", None)
    if spec is not None:                 # a DWNArtifact, not a FrozenDWN
        art = frozen
        if art.frozen is None:
            raise ValueError(
                f"artifact {spec.label} is at stage {art.stage!r}; call "
                f"freeze() before hw_report")
        frozen = art.frozen
        variant = variant if variant is not None else spec.variant
        name = name if name is not None else spec.preset
        if input_bits is None:
            input_bits = spec.input_bits
    if variant is None or name is None:
        raise TypeError("dwn_hw_report needs variant= and name= when "
                        "given a bare FrozenDWN (or pass a DWNArtifact, "
                        "whose spec carries both)")

    cfg = frozen.cfg
    luts: dict = {}
    ffs: dict = {}
    levels = 0
    n_cmp = 0

    m_final = cfg.lut_counts[-1]
    group = m_final // cfg.num_classes
    count_bits = math.ceil(math.log2(group + 1))

    if variant != "TEN":
        assert input_bits is not None
        spec = cfg.thermometer
        mask = used_threshold_mask(np.asarray(frozen.mapping_idx[0]), spec)
        frac = input_bits - 1
        n_cmp, per_feature = distinct_used_thresholds(
            frozen.thresholds, mask, frac_bits=frac)
        used_bits = int(mask.sum())
        c = encoder_cost(per_feature, input_bits, used_bits,
                         pipeline=pipeline)
        luts["encoder"], ffs["encoder"] = c.luts, c.ffs
        levels += c.levels
        enc_levels = c.levels
    else:
        # inputs arrive as TEN bits; register them at the boundary
        used = used_threshold_mask(np.asarray(frozen.mapping_idx[0]),
                                   cfg.thermometer)
        luts["encoder"], ffs["encoder"] = 0, int(used.sum()) if pipeline else 0
        enc_levels = 0

    lut_total = 0
    for m in cfg.lut_counts:
        lut_total += m
    c = lut_layer_cost(lut_total, pipeline=pipeline)
    luts["lut_layer"], ffs["lut_layer"] = c.luts, c.ffs
    levels += c.levels * len(cfg.lut_counts)

    c = popcount_cost(group, cfg.num_classes, pipeline=pipeline)
    luts["popcount"], ffs["popcount"] = c.luts, c.ffs
    pop_levels = c.levels
    levels += c.levels

    c = argmax_cost(cfg.num_classes, count_bits, pipeline=pipeline)
    luts["argmax"], ffs["argmax"] = c.luts, c.ffs
    levels += c.levels

    rep = HWReport(variant, name, input_bits, luts, ffs, levels,
                   distinct_comparators=n_cmp)
    rep._stage_levels = max(enc_levels, 1, pop_levels, c.levels)
    return rep
