from .cost import (comparator_luts, popcount_tree, encoder_cost,
                   lut_layer_cost, popcount_cost, argmax_cost,
                   dwn_hw_report, HWReport, ComponentCost)
from .verilog import emit_dwn, well_formed
from .cosim import (CosimError, CosimParseError, CosimReport, RTLMismatch,
                    SimulatorError, emit_testbench, evaluate_netlist,
                    parse_netlist, simulator_available, verify_rtl)
from .report import (PAPER_TABLE1, PAPER_TABLE2, PAPER_TABLE3,
                     PAPER_BASELINES, compare_with_paper, ComparisonRow)
