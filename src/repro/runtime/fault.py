"""Fault tolerance: restart supervisor + preemption handling.

At datacenter scale the failure domains are: worker process crash, node
loss (checkpoint/restart), and preemption notice (drain + final
checkpoint).  This module implements the control logic in-process so it
is testable on CPU; the same supervisor wraps the per-host launcher in a
real deployment.

* ``Supervisor.run(step_fn, ...)`` drives the training loop, catches
  worker exceptions, restores from the latest committed checkpoint and
  resumes, with bounded restarts within a sliding window (a crash loop
  aborts rather than burning the cluster);
* ``PreemptionHandler`` converts SIGTERM into a cooperative "save and
  exit" at the next step boundary (cloud TPU preemption semantics);
* injected failures are used by tests (``FaultInjector``).
"""

from __future__ import annotations

import dataclasses
import logging
import signal
import time
from typing import Callable

from . import checkpoint as ckpt

logger = logging.getLogger(__name__)


class PreemptionHandler:
    """SIGTERM -> drain at the next step boundary.

    ``register`` is the signal-installation function (default
    ``signal.signal``) — injectable so tests cover both the installed
    path and the off-main-thread fallback without touching process
    signal state.  When installation fails (``signal.signal`` raises
    ``ValueError`` off the main thread), the handler degrades to a
    cooperative flag: ``installed`` stays False, the fallback is
    *logged* (not silent), and callers may still set ``requested``
    directly.
    """

    def __init__(self, install: bool = True, *, register=None,
                 signum: int = signal.SIGTERM):
        self.requested = False
        self.installed = False
        self.signum = signum
        if install:
            register = register or signal.signal
            try:
                register(signum, self._on_signal)
                self.installed = True
            except ValueError:                   # non-main thread
                logger.warning(
                    "cannot install signal %d handler off the main thread; "
                    "falling back to the cooperative `requested` flag",
                    signum)

    def _on_signal(self, signum, frame):
        logger.warning("preemption signal received; draining")
        self.requested = True


@dataclasses.dataclass
class RestartPolicy:
    max_restarts: int = 5
    window_s: float = 3600.0                     # sliding window
    backoff_s: float = 1.0


@dataclasses.dataclass
class TrainHandle:
    """What the supervised step function operates on."""
    state: object                                # (params, opt_state, ...)
    step: int
    extra: dict


class Supervisor:
    """Checkpoint-restart supervisor around a step function.

    step_fn(handle) -> handle  advances exactly one optimizer step and
    may raise; save_every controls checkpoint cadence.
    """

    def __init__(self, ckpt_dir: str, *, policy: RestartPolicy | None = None,
                 save_every: int = 50, keep: int = 3,
                 preemption: PreemptionHandler | None = None,
                 shardings=None):
        self.ckpt_dir = ckpt_dir
        self.policy = policy or RestartPolicy()
        self.save_every = save_every
        self.keep = keep
        self.preemption = preemption or PreemptionHandler(install=False)
        self.shardings = shardings
        self.restart_times: list[float] = []
        self.restarts = 0

    # -- state management -------------------------------------------------

    def _restore_or(self, init_state, init_extra) -> TrainHandle:
        step, tree, extra = ckpt.restore_latest(
            self.ckpt_dir, init_state, shardings=self.shardings)
        if step is None:
            return TrainHandle(init_state, 0, dict(init_extra))
        logger.info("restored checkpoint step %d", step)
        return TrainHandle(tree, step, extra or {})

    def _save(self, handle: TrainHandle) -> None:
        ckpt.save(self.ckpt_dir, handle.step, handle.state,
                  extra=handle.extra)
        ckpt.garbage_collect(self.ckpt_dir, keep=self.keep)

    def _register_crash(self) -> bool:
        """True if the restart budget allows another attempt."""
        now = time.time()
        self.restart_times = [t for t in self.restart_times
                              if now - t < self.policy.window_s]
        self.restart_times.append(now)
        self.restarts += 1
        return len(self.restart_times) <= self.policy.max_restarts

    # -- main loop ----------------------------------------------------------

    def run(self, step_fn: Callable[[TrainHandle], TrainHandle], *,
            init_state, total_steps: int, init_extra: dict | None = None,
            on_step=None) -> TrainHandle:
        handle = self._restore_or(init_state, init_extra or {})
        while handle.step < total_steps:
            if self.preemption.requested:
                logger.warning("draining at step %d", handle.step)
                self._save(handle)
                return handle
            try:
                handle = step_fn(handle)
            except Exception:
                logger.exception("worker failure at step %d", handle.step)
                if not self._register_crash():
                    logger.error("restart budget exhausted; aborting")
                    raise
                time.sleep(self.policy.backoff_s)
                handle = self._restore_or(init_state, init_extra or {})
                continue
            if handle.step % self.save_every == 0:
                self._save(handle)
            if on_step:
                on_step(handle)
        self._save(handle)
        return handle


    # -- supervised unit of work -------------------------------------------

    def supervise(self, fn: Callable[[], object], *,
                  label: str = "task", on_retry=None):
        """Run an arbitrary callable under the restart policy.

        The checkpointed ``run`` loop above supervises a *step function*;
        ``supervise`` is the same bounded-restart control logic for a
        one-shot unit of work whose durable state lives elsewhere (e.g. a
        sweep grid point, persisted through the result cache + artifact
        store rather than a step checkpoint).  Retries ``fn`` with
        backoff until it returns; when the restart budget is exhausted
        the last exception propagates to the caller.
        """
        while True:
            try:
                return fn()
            except Exception:
                logger.exception("supervised %s failed", label)
                if not self._register_crash():
                    logger.error("restart budget exhausted for %s", label)
                    raise
                if on_retry:
                    on_retry(self.restarts)
                time.sleep(self.policy.backoff_s)


class FaultInjector:
    """Deterministic crash injection for tests: raises on given steps.

    ``every_step=True`` makes the injector fire on *every* visit to a
    crash step, not just the first — the crash-loop shape a bounded
    ``RestartPolicy`` must abort on instead of spinning forever.
    """

    def __init__(self, crash_steps: set[int], *, every_step: bool = False):
        self.crash_steps = set(crash_steps)
        self.every_step = every_step
        self.crashed: set[int] = set()
        self.fired = 0

    def maybe_crash(self, step: int):
        if step in self.crash_steps and (self.every_step
                                         or step not in self.crashed):
            self.crashed.add(step)
            self.fired += 1
            raise RuntimeError(f"injected fault at step {step}")
