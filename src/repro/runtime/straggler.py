"""Straggler detection & mitigation hooks.

On a 1000+-node job, a single slow host gates every synchronous
collective.  The monitor keeps a ring buffer of per-step wall times and
flags outliers with a robust z-score (median/MAD); the configured action
is invoked after ``patience`` consecutive flags.  In this repo the action
is the supervisor's evict+restart-from-checkpoint path (runtime.fault);
on a real cluster the same hook calls the cluster manager to replace the
host.  Per-host step times arrive via the ``report`` call — here from the
local loop; at scale from a lightweight all-gather of host timestamps
(the metadata is 8 bytes/host/step, negligible next to gradients).
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable


@dataclasses.dataclass
class StragglerEvent:
    step: int
    step_time_s: float
    median_s: float
    mad_s: float
    z: float


class StragglerMonitor:
    def __init__(self, *, window: int = 64, z_threshold: float = 4.0,
                 patience: int = 3, min_samples: int = 16,
                 action: Callable[[StragglerEvent], None] | None = None):
        self.times: deque[float] = deque(maxlen=window)
        self.z_threshold = z_threshold
        self.patience = patience
        self.min_samples = min_samples
        self.action = action
        self.consecutive = 0
        self.events: list[StragglerEvent] = []
        self._t0: float | None = None
        self._step = 0

    # -- timing interface ---------------------------------------------------

    def step_start(self) -> None:
        self._t0 = time.perf_counter()

    def step_end(self) -> StragglerEvent | None:
        assert self._t0 is not None, "step_start() not called"
        dt = time.perf_counter() - self._t0
        self._t0 = None
        return self.report(dt)

    def _baseline(self) -> tuple[float, float, float] | None:
        """(median, MAD, z-scale) of the window, or None below min_samples.

        The z-scale is floored at 5% of the median (and an absolute 1e-6):
        a *constant-time* window has MAD == 0, and without the floor the
        robust z would divide by ~zero and flag sub-percent jitter as a
        straggler (or, at median 0, divide by exactly zero).
        """
        if len(self.times) < self.min_samples:
            return None
        s = sorted(self.times)
        med = s[len(s) // 2]
        mad = sorted(abs(t - med) for t in s)[len(s) // 2]
        scale = max(1.4826 * mad, 1e-6, 0.05 * med)
        return med, mad, scale

    def threshold_s(self) -> float | None:
        """Wall time above which the *next* report would flag, or None
        while the window is below ``min_samples``.  Lets a dispatcher
        check *in-flight* work against the flag rule without waiting for
        the slow step to finish (speculative re-dispatch)."""
        base = self._baseline()
        if base is None:
            return None
        med, _, scale = base
        return med + self.z_threshold * scale

    def report(self, step_time_s: float) -> StragglerEvent | None:
        """Feed one step time; returns an event iff this step is flagged."""
        self._step += 1
        ev = None
        base = self._baseline()
        if base is not None:
            med, mad, scale = base
            z = (step_time_s - med) / scale
            if z > self.z_threshold:
                self.consecutive += 1
                ev = StragglerEvent(self._step, step_time_s, med, mad, z)
                self.events.append(ev)
                if self.action and self.consecutive >= self.patience:
                    self.action(ev)
                    self.consecutive = 0
            else:
                self.consecutive = 0
        # slow samples are *not* added to the window (they would poison
        # the baseline during a long degradation)
        if ev is None:
            self.times.append(step_time_s)
        return ev
