"""Mesh-elastic sharded checkpointing (no orbax in this container).

Layout on disk:
    <dir>/step_<N>/
        manifest.json     # treedef paths, shapes, dtypes, step, extra
                          # metadata (data-iterator state, config digest),
                          # sha256 of every shard file
        <leafpath>.npy    # one file per pytree leaf (full logical array)
    <dir>/step_<N>.COMMITTED   # atomic commit marker (written last)

Design points for 1000+-node deployments (scaled down to this container):

* **atomic**: writes go to ``step_<N>.tmp-<pid>`` and are renamed after
  the commit marker's shard hashes are fully written — a preempted writer
  never corrupts the latest checkpoint;
* **mesh-elastic**: leaves are stored as full logical arrays
  (``jax.device_get`` assembles sharded arrays); ``restore`` re-shards
  onto whatever mesh/sharding the caller provides, so restore works onto
  a different topology than the one that saved (tested 1<->4<->8 devices);
* **integrity**: sha256 per shard file, verified on restore;
* **resumable input pipeline**: the data-iterator state rides in the
  manifest (``extra``).

At real pod scale the same layout maps to per-host shard files keyed by
``jax.process_index()`` + a distributed commit barrier; the single-host
implementation keeps those seams explicit (``_leaf_files``).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import time
from pathlib import Path
from typing import Any

import jax
import numpy as np


def _leaf_paths(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for kp, leaf in flat:
        name = "/".join(_key_str(k) for k in kp)
        out.append((name, leaf))
    return out


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def _sha256(path: Path) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def save(directory: str | os.PathLike, step: int, tree, *,
         extra: dict | None = None) -> Path:
    """Atomically save a pytree checkpoint.  Returns the final path."""
    base = Path(directory)
    base.mkdir(parents=True, exist_ok=True)
    final = base / f"step_{step:08d}"
    tmp = base / f"step_{step:08d}.tmp-{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    manifest: dict = {"step": step, "extra": extra or {},
                      "created": time.time(), "leaves": {}}
    for name, leaf in _leaf_paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        fn = name.replace("/", "__") + ".npy"
        np.save(tmp / fn, arr)
        manifest["leaves"][name] = {
            "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "sha256": _sha256(tmp / fn),
        }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    marker = base / f"step_{step:08d}.COMMITTED"
    marker.write_text(str(time.time()))
    return final


def latest_step(directory: str | os.PathLike) -> int | None:
    base = Path(directory)
    if not base.exists():
        return None
    steps = []
    for marker in base.glob("step_*.COMMITTED"):
        s = int(marker.stem.split("_")[1])
        if (base / f"step_{s:08d}" / "manifest.json").exists():
            steps.append(s)
    return max(steps) if steps else None


def restore(directory: str | os.PathLike, step: int, like, *,
            shardings=None, verify: bool = True):
    """Restore a checkpoint into the structure of ``like``.

    ``shardings``: optional pytree of NamedShardings (same treedef) to
    place leaves onto — this is what makes restore mesh-elastic.
    Returns (tree, extra).
    """
    base = Path(directory) / f"step_{step:08d}"
    manifest = json.loads((base / "manifest.json").read_text())
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_flat = None
    if shardings is not None:
        shard_flat = jax.tree_util.tree_flatten(shardings)[0]
    leaves = []
    for i, (kp, leaf) in enumerate(flat):
        name = "/".join(_key_str(k) for k in kp)
        meta = manifest["leaves"].get(name)
        if meta is None:
            raise KeyError(f"checkpoint missing leaf {name!r}")
        path = base / meta["file"]
        if verify and _sha256(path) != meta["sha256"]:
            raise IOError(f"checksum mismatch for {name} ({path})")
        arr = np.load(path)
        want_shape = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != want_shape:
            raise ValueError(
                f"shape mismatch for {name}: ckpt {arr.shape} vs {want_shape}")
        if shard_flat is not None:
            leaves.append(jax.device_put(arr, shard_flat[i]))
        else:
            leaves.append(jax.numpy.asarray(arr))
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    return tree, manifest["extra"]


def restore_latest(directory, like, *, shardings=None):
    s = latest_step(directory)
    if s is None:
        return None, None, None
    tree, extra = restore(directory, s, like, shardings=shardings)
    return s, tree, extra


# ---------------------------------------------------------------------------
# DWN artifact checkpoints (repro.dwn lifecycle)
# ---------------------------------------------------------------------------

def _artifact_like(spec, leaves: dict) -> dict:
    """Zero-filled like-trees for the artifact groups present in a
    manifest, with shapes/dtypes derived from the spec alone (no data
    needed to restore)."""
    dcfg = spec.dwn_config()
    layer_specs = dcfg.layer_specs()
    F, T = dcfg.num_features, dcfg.bits_per_feature
    like: dict = {}
    if any(name.startswith("params/") for name in leaves):
        like["params"] = {"layers": [
            {"scores": np.zeros((s.num_luts, s.fan_in, s.num_candidates),
                                np.float32),
             "tables": np.zeros((s.num_luts, s.table_size), np.float32)}
            for s in layer_specs]}
        like["buffers"] = {"thresholds": np.zeros((F, T), np.float32)}
    if any(name.startswith("frozen/") for name in leaves):
        like["frozen"] = {
            "thresholds": np.zeros((F, T), np.float32),
            "mapping_idx": [np.zeros((s.num_luts, s.fan_in), np.int32)
                            for s in layer_specs],
            "tables_bin": [np.zeros((s.num_luts, s.table_size), np.int32)
                           for s in layer_specs]}
    return like


def save_artifact(directory: str | os.PathLike, artifact, *,
                  step: int = 0) -> Path:
    """Save a ``repro.dwn.DWNArtifact`` (atomic, sha256-verified).

    The pytree holds whichever stage state exists (params/buffers and/or
    the frozen arrays); the spec, stage and calibration ride in the
    manifest ``extra`` so :func:`load_artifact` reconstructs the exact
    build without external context.  Returns the checkpoint path.
    """
    tree: dict = {}
    if artifact.params is not None:
        tree["params"] = artifact.params
        tree["buffers"] = artifact.buffers
    if artifact.frozen is not None:
        f = artifact.frozen
        tree["frozen"] = {"thresholds": np.asarray(f.thresholds),
                          "mapping_idx": [np.asarray(i)
                                          for i in f.mapping_idx],
                          "tables_bin": [np.asarray(t)
                                         for t in f.tables_bin]}
    extra = {"kind": "dwn-artifact",
             "spec": artifact.spec.to_dict(),
             "spec_fingerprint": artifact.spec.fingerprint(),
             "stage": artifact.stage,
             "calibration": dict(artifact.calibration)}
    return save(directory, step, tree, extra=extra)


def load_artifact(directory: str | os.PathLike, *,
                  step: int | None = None):
    """Restore a ``repro.dwn.DWNArtifact`` saved by :func:`save_artifact`.

    The spec is read from the manifest and re-validated at construction;
    an artifact saved at stage "packed" is re-staged on device so its
    packed serving outputs are bit-exact vs the saved model.
    """
    from ..dwn import DWNArtifact, DWNSpec
    from ..core.model import FrozenDWN

    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(
                f"no committed artifact checkpoint under {directory}")
    base = Path(directory) / f"step_{step:08d}"
    manifest = json.loads((base / "manifest.json").read_text())
    extra = manifest["extra"]
    if extra.get("kind") != "dwn-artifact":
        raise ValueError(f"checkpoint at {base} is not a DWN artifact "
                         f"(kind={extra.get('kind')!r})")
    spec = DWNSpec.from_dict(extra["spec"])
    like = _artifact_like(spec, manifest["leaves"])
    tree, _ = restore(directory, step, like)
    art = DWNArtifact(spec)
    art.calibration = dict(extra.get("calibration", {}))
    if "params" in tree:
        art.params, art.buffers = tree["params"], tree["buffers"]
    if "frozen" in tree:
        f = tree["frozen"]
        art.frozen = FrozenDWN(
            spec.dwn_config(), np.asarray(f["thresholds"]),
            [np.asarray(i) for i in f["mapping_idx"]],
            [np.asarray(t) for t in f["tables_bin"]],
            input_frac_bits=spec.frac_bits)
    if extra.get("stage") == "packed" and art.frozen is not None:
        art.pack()
    return art


def garbage_collect(directory: str | os.PathLike, keep: int = 3) -> None:
    """Delete all but the newest ``keep`` committed checkpoints (plus any
    orphaned tmp dirs from crashed writers)."""
    base = Path(directory)
    if not base.exists():
        return
    for tmp in base.glob("step_*.tmp-*"):
        shutil.rmtree(tmp, ignore_errors=True)
    steps = sorted(
        int(m.stem.split("_")[1]) for m in base.glob("step_*.COMMITTED"))
    for s in steps[:-keep] if keep else steps:
        shutil.rmtree(base / f"step_{s:08d}", ignore_errors=True)
        (base / f"step_{s:08d}.COMMITTED").unlink(missing_ok=True)
