"""Mesh-elastic sharded checkpointing (no orbax in this container).

Layout on disk:
    <dir>/step_<N>/
        manifest.json     # treedef paths, shapes, dtypes, step, extra
                          # metadata (data-iterator state, config digest),
                          # sha256 of every shard file
        <leafpath>.npy    # one file per pytree leaf (full logical array)
    <dir>/step_<N>.COMMITTED   # atomic commit marker (written last)

Design points for 1000+-node deployments (scaled down to this container):

* **atomic**: writes go to ``step_<N>.tmp-<pid>`` and are renamed after
  the commit marker's shard hashes are fully written — a preempted writer
  never corrupts the latest checkpoint;
* **mesh-elastic**: leaves are stored as full logical arrays
  (``jax.device_get`` assembles sharded arrays); ``restore`` re-shards
  onto whatever mesh/sharding the caller provides, so restore works onto
  a different topology than the one that saved (tested 1<->4<->8 devices);
* **integrity**: sha256 per shard file, verified on restore;
* **resumable input pipeline**: the data-iterator state rides in the
  manifest (``extra``).

At real pod scale the same layout maps to per-host shard files keyed by
``jax.process_index()`` + a distributed commit barrier; the single-host
implementation keeps those seams explicit (``_leaf_files``).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import time
from pathlib import Path
from typing import Any

import jax
import numpy as np


def _leaf_paths(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for kp, leaf in flat:
        name = "/".join(_key_str(k) for k in kp)
        out.append((name, leaf))
    return out


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def _sha256(path: Path) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def save(directory: str | os.PathLike, step: int, tree, *,
         extra: dict | None = None) -> Path:
    """Atomically save a pytree checkpoint.  Returns the final path."""
    base = Path(directory)
    base.mkdir(parents=True, exist_ok=True)
    final = base / f"step_{step:08d}"
    tmp = base / f"step_{step:08d}.tmp-{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    manifest: dict = {"step": step, "extra": extra or {},
                      "created": time.time(), "leaves": {}}
    for name, leaf in _leaf_paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        fn = name.replace("/", "__") + ".npy"
        np.save(tmp / fn, arr)
        manifest["leaves"][name] = {
            "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "sha256": _sha256(tmp / fn),
        }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    marker = base / f"step_{step:08d}.COMMITTED"
    marker.write_text(str(time.time()))
    return final


def latest_step(directory: str | os.PathLike) -> int | None:
    base = Path(directory)
    if not base.exists():
        return None
    steps = []
    for marker in base.glob("step_*.COMMITTED"):
        s = int(marker.stem.split("_")[1])
        if (base / f"step_{s:08d}" / "manifest.json").exists():
            steps.append(s)
    return max(steps) if steps else None


def restore(directory: str | os.PathLike, step: int, like, *,
            shardings=None, verify: bool = True):
    """Restore a checkpoint into the structure of ``like``.

    ``shardings``: optional pytree of NamedShardings (same treedef) to
    place leaves onto — this is what makes restore mesh-elastic.
    Returns (tree, extra).
    """
    base = Path(directory) / f"step_{step:08d}"
    manifest = json.loads((base / "manifest.json").read_text())
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_flat = None
    if shardings is not None:
        shard_flat = jax.tree_util.tree_flatten(shardings)[0]
    leaves = []
    for i, (kp, leaf) in enumerate(flat):
        name = "/".join(_key_str(k) for k in kp)
        meta = manifest["leaves"].get(name)
        if meta is None:
            raise KeyError(f"checkpoint missing leaf {name!r}")
        path = base / meta["file"]
        if verify and _sha256(path) != meta["sha256"]:
            raise IOError(f"checksum mismatch for {name} ({path})")
        arr = np.load(path)
        want_shape = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != want_shape:
            raise ValueError(
                f"shape mismatch for {name}: ckpt {arr.shape} vs {want_shape}")
        if shard_flat is not None:
            leaves.append(jax.device_put(arr, shard_flat[i]))
        else:
            leaves.append(jax.numpy.asarray(arr))
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    return tree, manifest["extra"]


def restore_latest(directory, like, *, shardings=None):
    s = latest_step(directory)
    if s is None:
        return None, None, None
    tree, extra = restore(directory, s, like, shardings=shardings)
    return s, tree, extra


def garbage_collect(directory: str | os.PathLike, keep: int = 3) -> None:
    """Delete all but the newest ``keep`` committed checkpoints (plus any
    orphaned tmp dirs from crashed writers)."""
    base = Path(directory)
    if not base.exists():
        return
    for tmp in base.glob("step_*.tmp-*"):
        shutil.rmtree(tmp, ignore_errors=True)
    steps = sorted(
        int(m.stem.split("_")[1]) for m in base.glob("step_*.COMMITTED"))
    for s in steps[:-keep] if keep else steps:
        shutil.rmtree(base / f"step_{s:08d}", ignore_errors=True)
        (base / f"step_{s:08d}.COMMITTED").unlink(missing_ok=True)
