from . import checkpoint, fault, straggler
from .checkpoint import save, restore, restore_latest, latest_step
from .fault import Supervisor, RestartPolicy, PreemptionHandler, FaultInjector, TrainHandle
from .straggler import StragglerMonitor, StragglerEvent
