"""End-to-end artifact lifecycle smoke: the CI gate for ``repro.dwn``.

One run exercises the whole API surface in order::

    DWNSpec → train (scan engine) → freeze → pack → serve (ServingEngine)
            → hw_report → Verilog → checkpoint save → load → bit-exact
              packed re-serve

and writes a single JSON artifact describing every stage.  Exits
non-zero if the checkpoint roundtrip is not bit-exact (packed serving
counts/predictions compared exactly) or any stage fails.

Usage:
    python -m repro.dwn.smoke --out artifact_smoke.json --epochs 1
    python -m repro.dwn.smoke --preset sm-10 --variant TEN --epochs 0
    python -m repro.dwn.smoke --workload mnist --preset mnist-sm \
        --variant TEN --bits 8 --epochs 1
"""

from __future__ import annotations

import argparse
import json
import sys
import tempfile

import numpy as np

from .artifact import DWNArtifact
from .spec import DWNSpec


def run(spec: DWNSpec, *, epochs: int, n_train: int, n_test: int,
        batch: int, seed: int, ckpt_dir: str, log=print) -> dict:
    """Drive one spec through the full lifecycle; returns the JSON-able
    stage-by-stage record (key ``roundtrip_bit_exact`` is the gate)."""
    from ..workloads import load_workload
    out: dict = {"spec": spec.to_dict(), "fingerprint": spec.fingerprint(),
                 "workload": spec.workload}
    data = load_workload(spec.workload, n_train, n_test, seed=seed)

    log(f"[1/6] train: {spec.label}, {epochs} epoch(s)")
    art = DWNArtifact(spec).train(data, epochs=epochs, batch=batch,
                                  seed=seed)
    art.freeze().pack()
    out["stage"] = art.stage
    out["calibration"] = dict(art.calibration)

    log("[2/6] hw report")
    rep = art.hw_report()
    out["hw"] = {"variant": rep.variant, "total_luts": rep.total_luts,
                 "total_ffs": rep.total_ffs, "luts": dict(rep.luts),
                 "fmax_mhz": round(rep.fmax_mhz, 1),
                 "delay_ns": round(rep.delay_ns, 3)}
    out["verilog_lines"] = art.verilog().count("\n")

    log("[3/6] serve through the engine")
    from ..serving import ServingEngine
    engine = ServingEngine(art, max_bucket=64, min_bucket=8,
                           n_train=min(n_train, 512), seed=seed)
    engine.warmup(64)
    for i in range(3):
        engine.submit(engine.make_request(64, seed=i))
    engine.drain()
    srep = engine.report()
    out["serve"] = {"datapath": srep["datapath"],
                    "throughput_samples_per_s":
                        srep["throughput_samples_per_s"],
                    "bit_exact_vs_oracle": srep["bit_exact_vs_oracle"]}

    log(f"[4/6] checkpoint -> {ckpt_dir}")
    path = art.save(ckpt_dir)
    out["checkpoint"] = str(path)

    log("[5/6] reload")
    art2 = DWNArtifact.load(ckpt_dir)
    out["reloaded_stage"] = art2.stage

    log("[6/6] bit-exact packed re-serve check")
    from ..serving.backends import BoundBackend, get_backend
    x = data.x_test[: min(64, n_test)]
    b1 = BoundBackend(get_backend("packed-xla"), art.serving_model())
    b2 = BoundBackend(get_backend("packed-xla"), art2.serving_model())
    c1, p1 = (np.asarray(a) for a in b1(x))
    c2, p2 = (np.asarray(a) for a in b2(x))
    out["roundtrip_bit_exact"] = bool(np.array_equal(c1, c2)
                                      and np.array_equal(p1, p2))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--workload", default="jsc",
                    help="registered workload the spec trains/serves on "
                         "(jsc | mnist | ...; --preset must be one of "
                         "its tiers)")
    ap.add_argument("--preset", default="sm-50")
    ap.add_argument("--variant", default="PEN", choices=["TEN", "PEN"])
    ap.add_argument("--bits", type=int, default=64,
                    help="thermometer bits per feature T")
    ap.add_argument("--placement", default="distributive")
    ap.add_argument("--input-bits", type=int, default=9,
                    help="PEN input width (ignored for TEN)")
    ap.add_argument("--epochs", type=int, default=1)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--n-train", type=int, default=2000)
    ap.add_argument("--n-test", type=int, default=512)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="",
                    help="checkpoint directory (default: a temp dir)")
    ap.add_argument("--out", default="",
                    help="write the lifecycle JSON record here")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    spec = DWNSpec(
        preset=args.preset, variant=args.variant, bits=args.bits,
        placement=args.placement,
        input_bits=args.input_bits if args.variant == "PEN" else None,
        workload=args.workload)
    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="dwn_artifact_")
    log = (lambda *a, **k: None) if args.quiet else print
    out = run(spec, epochs=args.epochs, n_train=args.n_train,
              n_test=args.n_test, batch=args.batch, seed=args.seed,
              ckpt_dir=ckpt, log=log)
    print(json.dumps(out))
    if args.out:
        with open(args.out, "w") as fh:
            json.dump(out, fh, indent=1)
    return 0 if out["roundtrip_bit_exact"] else 1


if __name__ == "__main__":
    sys.exit(main())
