"""DWNSpec: the single typed description of a DWN build.

Everything the paper shows can dominate DWN hardware cost — encoding
variant (TEN/PEN), thermometer resolution T, threshold placement, PEN
input width — plus the serving knobs (datapath backend, popcount
grouping) lives in one frozen, *validated-at-construction* dataclass.
A spec is the key of the whole lifecycle: ``DWNArtifact(spec)`` carries
it through train → freeze → pack → serve / hw-report, the sweep cache
fingerprints it, and checkpoints embed it so a reload reconstructs the
exact build.

Spec presets replace the old ``--arch dwn-jsc-*`` string glue: the
serving aliases are registered here (by ``repro.configs.dwn_jsc``) as
named specs, so CLIs resolve ``dwn-jsc-sm`` to a ``DWNSpec`` instead of
parsing arch-name suffixes.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json

from ..configs.base import ArchConfig
from ..core.model import DWNConfig, JSC_PRESETS
from ..core.thermometer import PLACEMENTS

#: encoding variants: TEN receives pre-encoded thermometer bits, PEN
#: receives fixed-point features and encodes on chip (paper §II).
VARIANTS = ("TEN", "PEN")

#: popcount grouping modes (contig = paper Fig. 1; strided = the
#: shard-aligned optimization variant).
GROUPINGS = ("contig", "strided")

#: JSC tier -> LUT-layer width m (Table I model sizes).  Kept for
#: back-compat; per-workload tiers live on the workload registry entries
#: (``repro.workloads.get_workload(name).presets``).
TIERS = {name: cfg.lut_counts[-1] for name, cfg in JSC_PRESETS.items()}

_LUTS_TO_TIER = {m: name for name, m in TIERS.items()}


def _workload_presets(workload: str):
    """Tier name -> base DWNConfig for a workload (registry lookup)."""
    from ..workloads import get_workload
    return get_workload(workload).presets


def _serving_datapaths() -> list[str]:
    """Registered serving backend names (imported lazily so constructing
    a spec is what pulls in the serving registry, not importing this
    module)."""
    from ..serving.backends import available_backends
    return available_backends()


@dataclasses.dataclass(frozen=True)
class DWNSpec:
    """One validated DWN build point.

    Attributes:
      preset: JSC tier ("sm-10" | "sm-50" | "md-360" | "lg-2400") — fixes
        the LUT-layer width m.
      variant: "TEN" (bits arrive pre-encoded) or "PEN" (on-chip encoder).
      bits: thermometer bits per feature T (encoder resolution), >= 1.
      placement: threshold placement ("distributive" | "uniform" |
        "gaussian").
      input_bits: PEN fixed-point input width in *total* bits (1 sign +
        n fractional); must be set iff ``variant == "PEN"``.
      datapath: serving backend name ("fused-packed" | "packed-xla" |
        "float-oracle" | "auto") — validated against the registry at
        construction.
      grouping: popcount grouping ("contig" | "strided").
      workload: registered workload name the spec trains/serves on
        ("jsc" | "mnist" | "lm-head" | ...); fixes the feature/class
        geometry and which preset tiers are valid.
      backbone: arch name of a feature-extractor backbone stage, for
        specs whose features come from a model (the DWN-head LM); None
        inherits the workload's backbone (also None for plain datasets).

    Raises ``ValueError`` at construction for any invalid combination;
    every message says what to change.
    """

    preset: str
    variant: str = "TEN"
    bits: int = 200
    placement: str = "distributive"
    input_bits: int | None = None
    datapath: str = "fused-packed"
    grouping: str = "contig"
    workload: str = "jsc"
    backbone: str | None = None

    def __post_init__(self):
        try:
            presets = _workload_presets(self.workload)
        except KeyError as e:
            raise ValueError(str(e.args[0])) from None
        if self.preset not in presets:
            raise ValueError(
                f"unknown DWN preset {self.preset!r} for workload "
                f"{self.workload!r}; known tiers: {sorted(presets)} "
                f"(each fixes the LUT-layer width m)")
        if self.variant not in VARIANTS:
            raise ValueError(
                f"unknown encoding variant {self.variant!r}; choose 'TEN' "
                f"(pre-encoded thermometer bits) or 'PEN' (on-chip encoder)")
        if not isinstance(self.bits, int) or self.bits < 1:
            raise ValueError(
                f"thermometer resolution bits={self.bits!r} is invalid: T "
                f"must be an integer >= 1 (thresholds per feature; the "
                f"paper uses T=200)")
        if self.placement not in PLACEMENTS:
            raise ValueError(
                f"unknown threshold placement {self.placement!r}; "
                f"supported placements: {list(PLACEMENTS)}")
        if self.variant == "PEN":
            if self.input_bits is None:
                raise ValueError(
                    "variant='PEN' requires input_bits (total fixed-point "
                    "input width, sign included — e.g. input_bits=9 for "
                    "the paper's (1, 8) grid)")
            if not isinstance(self.input_bits, int) or self.input_bits < 2:
                raise ValueError(
                    f"input_bits={self.input_bits!r} is invalid for PEN: "
                    f"need at least 2 (1 sign bit + >= 1 fractional bit)")
        elif self.input_bits is not None:
            raise ValueError(
                f"variant='TEN' must not set input_bits (got "
                f"{self.input_bits}): TEN models receive pre-encoded "
                f"thermometer bits, there is no on-chip comparator width. "
                f"Use variant='PEN' for on-chip encoding")
        if self.grouping not in GROUPINGS:
            raise ValueError(
                f"unknown popcount grouping {self.grouping!r}; supported: "
                f"{list(GROUPINGS)}")
        allowed = _serving_datapaths() + ["auto"]
        if self.datapath not in allowed:
            raise ValueError(
                f"unregistered serving datapath {self.datapath!r}; "
                f"registered backends: {sorted(allowed)} (register new "
                f"ones via repro.serving.backends.register_backend)")

    # -- derived views -------------------------------------------------

    @property
    def luts(self) -> int:
        """LUT-layer width m of the preset tier."""
        return _workload_presets(self.workload)[self.preset].lut_counts[-1]

    @property
    def frac_bits(self) -> int | None:
        """Fractional bits of the (1, n) fixed-point grid; None for TEN."""
        return None if self.input_bits is None else self.input_bits - 1

    @property
    def label(self) -> str:
        b = "" if self.input_bits is None else f"@{self.input_bits}b"
        wl = "" if self.workload == "jsc" else f"{self.workload}:"
        return (f"{wl}{self.preset}/{self.variant}{b}/T{self.bits}/"
                f"{self.placement}")

    @property
    def effective_backbone(self) -> str | None:
        """Backbone arch name: the explicit ``backbone`` field, else the
        workload's registered backbone, else None (plain dataset)."""
        if self.backbone is not None:
            return self.backbone
        from ..workloads import get_workload
        return get_workload(self.workload).backbone

    def dwn_config(self) -> DWNConfig:
        """The core model config (``repro.core.model.DWNConfig``) this
        spec trains and freezes — bit-identical to what the pre-spec glue
        constructed by hand."""
        return dataclasses.replace(_workload_presets(self.workload)[self.preset],
                                   bits_per_feature=self.bits,
                                   encoding=self.placement)

    def arch_config(self, name: str | None = None) -> ArchConfig:
        """A servable (unregistered) ArchConfig view of this spec, for
        code that still speaks ``ArchConfig`` (ServingEngine reports,
        dryrun shapes)."""
        cfg = self.dwn_config()
        return ArchConfig(
            name=name or f"dwn-{self.preset}-T{self.bits}-{self.placement}",
            family="dwn",
            num_layers=1, d_model=cfg.num_features,
            num_heads=0, num_kv_heads=0, d_ff=0,
            vocab_size=cfg.num_classes,
            dwn_luts=self.luts, dwn_bits=self.bits,
            dwn_encoding=self.placement, dwn_fused=True,
            dwn_datapath=self.datapath, dwn_grouping=self.grouping,
            source="repro.dwn.DWNSpec")

    # -- (de)serialization ---------------------------------------------

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        # default workload/backbone are *omitted* so every pre-workload
        # fingerprint, sweep-cache key, and checkpoint stays valid
        if d["workload"] == "jsc":
            del d["workload"]
        if d["backbone"] is None:
            del d["backbone"]
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "DWNSpec":
        return cls(**d)

    def fingerprint(self) -> str:
        """Stable 16-hex-char content hash of the spec — the cache /
        checkpoint identity."""
        blob = json.dumps(self.to_dict(), sort_keys=True,
                          separators=(",", ":"))
        return hashlib.sha256(blob.encode()).hexdigest()[:16]

    # -- bridges from the legacy surfaces ------------------------------

    @classmethod
    def from_arch(cls, cfg: ArchConfig, *, variant: str = "TEN",
                  input_bits: int | None = None) -> "DWNSpec":
        """Derive the spec of a DWN ``ArchConfig`` (the supported bridge
        for legacy arch objects).

        ``dwn_datapath`` values that are not registered serving backends
        (the dryrun-only "corner"/"gather" variants) normalize to
        "fused-packed", exactly like the engine's pre-spec fallback.
        """
        if cfg.family != "dwn":
            raise ValueError(f"arch {cfg.name!r} is family={cfg.family!r}, "
                             f"not a DWN — no spec can be derived")
        preset = _LUTS_TO_TIER.get(cfg.dwn_luts)
        if preset is None:
            raise ValueError(
                f"arch {cfg.name!r} has dwn_luts={cfg.dwn_luts}, which is "
                f"not a JSC tier width ({sorted(_LUTS_TO_TIER)}); register "
                f"a preset tier first")
        datapath = cfg.dwn_datapath
        if datapath not in _serving_datapaths() + ["auto"]:
            datapath = "fused-packed"
        grouping = cfg.dwn_grouping if cfg.dwn_grouping in GROUPINGS \
            else "contig"
        return cls(preset=preset, variant=variant, bits=cfg.dwn_bits,
                   placement=cfg.dwn_encoding, input_bits=input_bits,
                   datapath=datapath, grouping=grouping)

    @classmethod
    def from_point(cls, point, *, datapath: str = "fused-packed",
                   grouping: str = "contig") -> "DWNSpec":
        """The spec of one ``repro.sweep.grid.SweepPoint`` (adds the
        serving knobs a grid point doesn't carry)."""
        return cls(preset=point.preset, variant=point.variant,
                   bits=point.bits, placement=point.placement,
                   input_bits=point.input_bits, datapath=datapath,
                   grouping=grouping,
                   workload=getattr(point, "workload", "jsc"))


# ---------------------------------------------------------------------------
# spec presets: named specs replacing the --arch dwn-jsc-* string glue
# ---------------------------------------------------------------------------

#: name -> DWNSpec (constructed) or dict of DWNSpec kwargs (deferred —
#: validation imports the serving registry, which config loading should
#: not pull in).
_PRESETS: dict[str, "DWNSpec | dict"] = {}


def register_preset(name: str, spec: DWNSpec | None = None,
                    **kwargs) -> None:
    """Register a named spec preset (``spec`` or deferred ``kwargs``).

    Deferred kwargs are validated (the spec is constructed) on first
    :func:`get_spec` access, so registering presets stays import-light.
    """
    assert (spec is None) != (not kwargs), "pass a spec OR kwargs"
    assert name not in _PRESETS, name
    _PRESETS[name] = spec if spec is not None else kwargs


def spec_presets() -> list[str]:
    """Names of every registered spec preset (loads the config registry
    so the ``dwn-jsc-*`` shims are visible)."""
    _ensure_presets()
    return sorted(_PRESETS)


def has_spec(name: str) -> bool:
    _ensure_presets()
    return name in _PRESETS


def get_spec(name: str) -> DWNSpec:
    """Resolve a registered spec preset by name."""
    _ensure_presets()
    if name not in _PRESETS:
        raise KeyError(f"unknown DWN spec preset {name!r}; registered: "
                       f"{sorted(_PRESETS)}")
    entry = _PRESETS[name]
    if isinstance(entry, dict):
        entry = DWNSpec(**entry)
        _PRESETS[name] = entry
    return entry


def _ensure_presets() -> None:
    # preset registration rides on the arch registry load (the thin shims
    # live in repro.configs.dwn_jsc)
    from ..configs import registry
    registry._load_all()


def resolve_spec(target) -> DWNSpec:
    """Normalize any legacy handle to a :class:`DWNSpec`.

    Accepts a DWNSpec (returned as-is), a registered preset / arch name,
    or a DWN ``ArchConfig``.
    """
    if isinstance(target, DWNSpec):
        return target
    if isinstance(target, str):
        if has_spec(target):
            return get_spec(target)
        from ..configs import get_arch
        target = get_arch(target)
    # ArchConfigs that shadow a registered spec preset (the non-JSC
    # families register both) resolve by name; only nameless/legacy
    # configs bridge through the JSC-tier from_arch path.
    if has_spec(getattr(target, "name", "")):
        return get_spec(target.name)
    return DWNSpec.from_arch(target)


__all__ = [
    "DWNSpec", "GROUPINGS", "TIERS", "VARIANTS", "get_spec", "has_spec",
    "register_preset", "resolve_spec", "spec_presets",
]
