"""Unified DWN artifact API: typed ``DWNSpec`` → ``DWNArtifact`` lifecycle.

This package is the single construction path for DWN models.  A
:class:`DWNSpec` (preset tier, TEN/PEN, thermometer bits T, threshold
placement, PEN input width, serving datapath, popcount grouping —
validated at construction) flows through a :class:`DWNArtifact`'s
explicit stage methods::

    spec = DWNSpec(preset="sm-50", variant="PEN", input_bits=9)
    art = DWNArtifact(spec).train(data, epochs=4).freeze().pack()
    engine = ServingEngine(art)           # serve the packed datapath
    report = art.hw_report()              # FPGA LUT/FF/fmax breakdown
    art.save("ckpt/")                     # atomic, spec-embedded

Every consumer — serving backends, the sweep pipeline, the launch CLIs,
the hw cost model / Verilog emitter — delegates here; the old scattered
glue (``build_dwn_model``, ``sweep_arch``, arch-name suffix parsing)
survives only as deprecated shims.
"""

from .artifact import DWNArtifact, LifecycleError, PackedOperands, STAGES
from .spec import (DWNSpec, GROUPINGS, TIERS, VARIANTS, get_spec, has_spec,
                   register_preset, resolve_spec, spec_presets)

__all__ = [
    "DWNArtifact", "DWNSpec", "GROUPINGS", "LifecycleError",
    "PackedOperands", "STAGES", "TIERS", "VARIANTS", "get_spec",
    "has_spec", "register_preset", "resolve_spec", "spec_presets",
]
