"""DWNArtifact: the full spec → serve / hw-report lifecycle in one object.

An artifact owns every stage of a DWN build and enforces their order::

    spec ──fit/train/adopt──▶ trained ──freeze()──▶ frozen ──pack()──▶ packed
                                                      │                  │
                                                hw_report()       serving_model()
                                                verilog()         (DWNModelBundle)
                                                verify_rtl()

* **trained** — ``params`` (LUT scores/tables) + ``buffers`` (thermometer
  thresholds fit on training features).  ``fit`` initializes without
  gradient epochs (enough for the hardware axes); ``train`` runs the
  scan-compiled paper-protocol trainer; ``adopt`` accepts externally
  trained state (the sweep's vmapped batch trainer).
* **frozen** — hardware semantics (``core.model.FrozenDWN``): int32
  wires, {0,1} tables, thresholds quantized to the spec's (1, n) grid
  for PEN.
* **packed** — the frozen operands staged on device as the packed-uint32
  serving datapath expects them.

``save``/``load`` ride on ``repro.runtime.checkpoint`` (atomic commit,
sha256-verified shards) with the spec embedded in the manifest, so a
reloaded artifact reproduces bit-exact packed serving outputs.

Calling a stage method out of order raises :class:`LifecycleError` with
the method to call first; re-running an earlier stage (e.g. ``adopt``
after ``freeze``) invalidates the later stages.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..core.model import DWNConfig, FrozenDWN
from ..core.model import freeze as freeze_dwn
from ..core.model import init_dwn
from .spec import DWNSpec

Array = jax.Array

#: lifecycle stages in order.
STAGES = ("spec", "trained", "frozen", "packed")


class LifecycleError(RuntimeError):
    """A stage method was called before its prerequisite stage."""


@dataclasses.dataclass
class PackedOperands:
    """Frozen operands staged on device for the packed serving datapath:
    thresholds (F, T) float32, per-layer mapping (m, n) int32 and binary
    tables (m, 2^n) int32."""

    thresholds: Array
    mappings: list
    tables: list


@dataclasses.dataclass
class DWNArtifact:
    """Lifecycle state for one :class:`~repro.dwn.spec.DWNSpec`.

    Attributes:
      spec: the validated build point (immutable identity).
      params / buffers: trainable LUT state + thermometer thresholds.
      frozen: hardware-semantics model (after :meth:`freeze`).
      packed: device-staged serving operands (after :meth:`pack`).
      calibration: provenance of the trained state (seed, epochs, fit
        sample count, soft accuracy when trained) — rides in checkpoints.
      history: per-epoch training history (loss/acc rows).
    """

    spec: DWNSpec
    params: dict | None = None
    buffers: dict | None = None
    frozen: FrozenDWN | None = None
    packed: PackedOperands | None = None
    calibration: dict = dataclasses.field(default_factory=dict)
    history: list = dataclasses.field(default_factory=list)

    # -- stage bookkeeping ---------------------------------------------

    @property
    def stage(self) -> str:
        if self.packed is not None:
            return "packed"
        if self.frozen is not None:
            return "frozen"
        if self.params is not None:
            return "trained"
        return "spec"

    def _require(self, stage: str, method: str, hint: str) -> None:
        if STAGES.index(self.stage) < STAGES.index(stage):
            raise LifecycleError(
                f"{method}() needs the artifact at stage {stage!r} but it "
                f"is at {self.stage!r} ({self.spec.label}); call {hint} "
                f"first")

    def _invalidate_downstream(self) -> None:
        self.frozen = None
        self.packed = None

    # -- stage: trained ------------------------------------------------

    def fit(self, x_train: np.ndarray, *, seed: int = 0,
            warmstart: bool = False, y_train: np.ndarray | None = None
            ) -> "DWNArtifact":
        """Fit thresholds + initialize LUT params without gradient epochs.

        Bit-identical to the pre-spec ``build_dwn_model`` init: thresholds
        from ``x_train`` under the spec's placement, LUT scores/tables
        from ``PRNGKey(seed)``.  ``warmstart=True`` uses the correlation
        warm start instead (requires ``y_train``).
        """
        cfg = self.spec.dwn_config()
        key = jax.random.PRNGKey(seed)
        if warmstart:
            if y_train is None:
                raise ValueError("fit(warmstart=True) needs y_train for "
                                 "the correlation warm start")
            from ..core.warmstart import warmstart_dwn
            self.params, self.buffers = warmstart_dwn(key, cfg, x_train,
                                                      y_train)
        else:
            self.params, self.buffers = init_dwn(key, cfg, x_train)
        self.calibration = {"seed": seed, "epochs": 0,
                            "warmstart": bool(warmstart),
                            "n_fit": int(np.asarray(x_train).shape[0])}
        self.history = []
        self._invalidate_downstream()
        return self

    def train(self, data, *, epochs: int, batch: int = 128,
              lr: float = 1e-3, seed: int = 0, warmstart: bool = False,
              eval_every: int = 0, verbose: bool = False) -> "DWNArtifact":
        """Train on JSC data with the scan-compiled paper-protocol trainer.

        Args:
          data: ``repro.data.jsc.JSCData`` split.
          epochs: gradient epochs; 0 degrades to :meth:`fit` alone.
          batch / lr / seed: paper-protocol training shape.
          warmstart: correlation warm start before training.
          eval_every: eval cadence (0 = final only, one device program).
          verbose: per-epoch prints.

        Returns self (stage "trained"); downstream stages invalidated.
        """
        if self.params is None:
            self.fit(data.x_train, seed=seed, warmstart=warmstart,
                     y_train=data.y_train)
        if epochs > 0:
            from ..core.training import train_dwn
            res = train_dwn(self.spec.dwn_config(), data, epochs=epochs,
                            batch=batch, lr=lr, seed=seed,
                            params=self.params, buffers=self.buffers,
                            eval_every=eval_every, verbose=verbose)
            self.params, self.buffers = res.params, res.buffers
            self.history = list(res.history)
            self.calibration.update(
                epochs=epochs, batch=batch, lr=lr,
                soft_test_acc=round(float(res.soft_test_acc), 4))
        self._invalidate_downstream()
        return self

    def adopt(self, params, buffers, *, note: str = "external"
              ) -> "DWNArtifact":
        """Adopt externally trained state (e.g. the vmapped multi-seed /
        multi-point batch trainer) without re-running training here."""
        self.params, self.buffers = params, buffers
        self.calibration.setdefault("trained_by", note)
        self.history = []
        self._invalidate_downstream()
        return self

    # -- stage: frozen -------------------------------------------------

    def freeze(self) -> "DWNArtifact":
        """Freeze to hardware semantics; PEN specs quantize thresholds to
        the spec's (1, n) fixed-point grid."""
        self._require("trained", "freeze", "train()/fit()/adopt()")
        self.frozen = freeze_dwn(self.params, self.buffers,
                                 self.spec.dwn_config(),
                                 input_frac_bits=self.spec.frac_bits)
        self.packed = None
        return self

    # -- stage: packed -------------------------------------------------

    def pack(self) -> "DWNArtifact":
        """Stage the frozen operands on device for the packed serving
        datapath (idempotent)."""
        self._require("frozen", "pack", "freeze()")
        if self.packed is None:
            f = self.frozen
            self.packed = PackedOperands(
                thresholds=jnp.asarray(f.thresholds),
                mappings=[jnp.asarray(i) for i in f.mapping_idx],
                tables=[jnp.asarray(t) for t in f.tables_bin])
        return self

    # -- consumers -----------------------------------------------------

    def serving_model(self, cfg=None):
        """The staged :class:`~repro.serving.backends.DWNModelBundle`
        every serving backend reads from.

        Args:
          cfg: optional ArchConfig recorded in the bundle (defaults to
            the spec's arch view) — lets engines keep their registered
            arch name in reports.
        """
        self._require("packed", "serving_model", "pack()")
        from ..serving.backends import DWNModelBundle
        return DWNModelBundle(
            cfg=cfg if cfg is not None else self.spec.arch_config(),
            dcfg=self.spec.dwn_config(), frozen=self.frozen,
            thresholds=self.packed.thresholds,
            mappings=self.packed.mappings, tables=self.packed.tables)

    def hw_report(self, *, pipeline: bool = True):
        """The FPGA cost report (``hw.cost.HWReport``) of the frozen
        model at the spec's operating point."""
        self._require("frozen", "hw_report", "freeze()")
        from ..hw.cost import dwn_hw_report
        return dwn_hw_report(self.frozen, variant=self.spec.variant,
                             name=self.spec.preset,
                             input_bits=self.spec.input_bits,
                             pipeline=pipeline)

    def verilog(self, *, name: str = "dwn_top",
                pipeline: bool = True) -> str:
        """Emit the synthesizable accelerator RTL for the frozen model."""
        self._require("frozen", "verilog", "freeze()")
        from ..hw.verilog import emit_dwn
        return emit_dwn(self.frozen, name=name, pipeline=pipeline)

    def verify_rtl(self, x=None, *, n: int = 256, backend: str = "auto",
                   pipeline: bool = True, name: str = "dwn_top"):
        """Co-simulate the emitted RTL against ``apply_hard_packed``.

        Proves bit-exact agreement (argmax, winning count, and — on the
        pure-Python evaluator path — per-class counts) on real JSC
        vectors; raises ``hw.cosim.RTLMismatch`` on any disagreement.
        Returns the ``hw.cosim.CosimReport`` and records the outcome in
        ``calibration["rtl_verified"]``.
        """
        self._require("frozen", "verify_rtl", "freeze()")
        from ..hw.cosim import verify_rtl as _verify
        report = _verify(self, x, n=n, backend=backend,
                         pipeline=pipeline, name=name)
        self.calibration["rtl_verified"] = report.to_dict()
        return report

    # -- persistence ---------------------------------------------------

    def save(self, directory, *, step: int = 0):
        """Checkpoint the artifact (atomic, sha256-verified); the spec
        and stage ride in the manifest.  Returns the checkpoint path."""
        from ..runtime.checkpoint import save_artifact
        return save_artifact(directory, self, step=step)

    @classmethod
    def load(cls, directory, *, step: int | None = None) -> "DWNArtifact":
        """Restore an artifact saved by :meth:`save` (its packed operands
        are re-staged so packed serving outputs are bit-exact)."""
        from ..runtime.checkpoint import load_artifact
        return load_artifact(directory, step=step)

    def summary(self) -> dict[str, Any]:
        """JSON-able one-glance description (spec + stage + calibration)."""
        return {"spec": self.spec.to_dict(),
                "fingerprint": self.spec.fingerprint(),
                "stage": self.stage, "calibration": dict(self.calibration)}


__all__ = ["DWNArtifact", "LifecycleError", "PackedOperands", "STAGES"]
