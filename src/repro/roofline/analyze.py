"""Roofline term derivation from compiled dry-run artifacts.

Sources:
* ``compiled.cost_analysis()``  -> per-device HLO FLOPs and bytes accessed
* optimized HLO text            -> collective wire bytes (all-gather /
  all-reduce / reduce-scatter / all-to-all / collective-permute), with
  ring-algorithm wire multipliers per op kind and the replica-group size
  parsed from the op attributes.

Terms (seconds, per chip; TPU v5e constants from launch.mesh):
    compute    = flops_per_chip / 197e12
    memory     = hbm_bytes_per_chip / 819e9
    collective = wire_bytes_per_chip / (links * 50e9)

The optimized HLO of an SPMD-partitioned module is the *per-device*
program, so shapes parsed from it are already per-chip.
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Any

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# effective wire bytes per device, as a multiple of the (per-device) payload
# bytes, for bidirectional-ring implementations with group size n:
#   all-gather(out B): receives (n-1)/n * B
#   reduce-scatter(in B): sends/receives (n-1)/n * B
#   all-reduce(B): RS + AG = 2 (n-1)/n * B
#   all-to-all(B): (n-1)/n * B
#   collective-permute(B): B


def _shape_bytes(shape_str: str) -> int:
    """'bf16[2,4096,512]{...}' -> byte count.  Token shapes 'u32[]' ok."""
    m = re.match(r"([a-z0-9]+)\[([0-9,]*)\]", shape_str.strip())
    if not m:
        return 0
    dt, dims = m.group(1), m.group(2)
    if dt not in DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * DTYPE_BYTES[dt]


def _tuple_bytes(result: str) -> int:
    """Result type may be a tuple '(f32[..], f32[..])' or single shape."""
    result = result.strip()
    if result.startswith("("):
        return sum(_shape_bytes(s) for s in result[1:-1].split(","))
    return _shape_bytes(result)


_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.M)

_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:                                   # [num_groups, group_size]<=[...]
        return max(1, int(m.group(2)))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return max(1, len([t for t in m.group(1).split(",") if t.strip()]))
    return default


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    payload_bytes: dict                     # per-device payload per op kind
    wire_bytes: float                       # ring-effective wire bytes/device

    def total_payload(self) -> float:
        return float(sum(self.payload_bytes.values()))


def parse_collectives(hlo_text: str, total_devices: int) -> CollectiveStats:
    counts: dict = {}
    payload: dict = {}
    wire = 0.0
    # `-done` ops repeat the shape of `-start`; count only starts + sync ops.
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        result, kind = m.group(1), m.group(2)
        b = _tuple_bytes(result)
        n = _group_size(line, total_devices)
        if n <= 1:
            continue
        counts[kind] = counts.get(kind, 0) + 1
        payload[kind] = payload.get(kind, 0) + b
        frac = (n - 1) / n
        if kind == "all-reduce":
            wire += 2 * frac * b
        elif kind == "collective-permute":
            wire += b
        elif kind == "reduce-scatter":
            # result is the scattered (small) shard; wire moves ~n shards
            wire += frac * b * n
        else:                               # all-gather, all-to-all
            wire += frac * b
    return CollectiveStats(counts, payload, wire)


@dataclasses.dataclass
class RooflineTerms:
    flops_per_chip: float
    hbm_bytes_per_chip: float
    wire_bytes_per_chip: float
    chips: int
    links: int = 4                          # v5e: 4 ICI links per chip (2D torus x2 dirs)

    def seconds(self) -> dict:
        from ..launch.mesh import PEAK_FLOPS_BF16, HBM_BW, ICI_BW_PER_LINK
        t_c = self.flops_per_chip / PEAK_FLOPS_BF16
        t_m = self.hbm_bytes_per_chip / HBM_BW
        t_x = self.wire_bytes_per_chip / (self.links * ICI_BW_PER_LINK)
        dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))
        return {"compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
                "bound": dom[1],
                "step_s_lower_bound": max(t_c, t_m, t_x)}


def analyze(compiled, total_devices: int, hlo_text: str | None = None) -> dict:
    """Collect cost/memory/collective stats from a compiled executable.

    Primary costing comes from the trip-count-aware HLO analyzer
    (roofline.hlo_costs) — XLA's own cost_analysis counts while-loop
    bodies once, which under-reports scanned models by the layer count;
    the raw XLA numbers are kept in the record for reference.
    """
    from .hlo_costs import analyze_hlo
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    xla_flops = float(cost.get("flops", 0.0))
    xla_hbm = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    costs = analyze_hlo(text, total_devices)
    mem = {}
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "generated_code_bytes": int(
                getattr(ma, "generated_code_size_in_bytes", 0)),
            "peak_bytes": int(getattr(ma, "peak_memory_in_bytes", 0) or 0),
        }
    except Exception as e:                                  # pragma: no cover
        mem = {"error": str(e)}
    terms = RooflineTerms(costs.flops, costs.hbm_bytes, costs.coll_wire,
                          total_devices)
    return {
        "flops_per_chip": costs.flops,
        "hbm_bytes_per_chip": costs.hbm_bytes,
        "collectives": {
            "counts": costs.coll_counts,
            "payload_bytes": costs.coll_payload,
            "wire_bytes_per_chip": costs.coll_wire,
        },
        "xla_cost_analysis": {"flops": xla_flops, "bytes_accessed": xla_hbm},
        "memory_analysis": mem,
        "roofline": terms.seconds(),
    }


def model_flops(cfg, shape, *, include_backward: bool) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); forward = 2*N*D."""
    n = cfg.num_active_params() if cfg.family == "moe" else cfg.num_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch * 1
    return 2.0 * n * tokens
