"""Trip-count-aware cost analysis from optimized HLO text.

XLA's ``HloCostAnalysis`` (what ``compiled.cost_analysis()`` reports)
counts every while-loop body ONCE — a scan-over-layers transformer
therefore under-reports FLOPs/bytes/collectives by the trip count
(verified experimentally: a lax.scan of 8 matmuls reports 1/8th the
unrolled FLOPs).  Since this framework scans layers *and* microbatches
*and* KV chunks, we re-derive costs ourselves:

* parse every computation in the optimized HLO text,
* build the call tree (while bodies/conditions, fusions, calls,
  conditionals) with multipliers = ``known_trip_count`` (emitted by XLA
  in the while op's backend_config) or 1,
* FLOPs: 2*M*N*K for every ``dot`` (batch dims included in M·N), summed
  bottom-up with multipliers.  Elementwise FLOPs are ignored (documented;
  the models are matmul-dominated),
* HBM bytes: every non-structural op reads its operands and writes its
  result once — post-fusion this is exactly XLA's memory model (fusion
  internals stay in registers/VMEM); structural ops (tuple, parameter,
  gte, bitcast, while, call, constant) are free,
* collectives: payload bytes × multiplier, with the same ring-wire model
  as roofline.analyze.

This is the costing the roofline table uses; ``compiled.cost_analysis``
numbers are kept in the records for reference.
"""

from __future__ import annotations

import dataclasses
import math
import re

DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

STRUCTURAL_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "call", "conditional", "custom-call", "after-all", "domain",
    "opt-barrier", "copy-start", "copy-done",
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_info(s: str):
    """'bf16[2,3]{1,0}' -> (dtype, dims tuple) or None."""
    m = _SHAPE_RE.match(s.strip())
    if not m or m.group(1) not in DTYPE_BYTES:
        return None
    dims = tuple(int(d) for d in m.group(2).split(",")) if m.group(2) else ()
    return m.group(1), dims


def _shape_bytes(s: str) -> int:
    info = _shape_info(s)
    if info is None:
        return 0
    dt, dims = info
    return DTYPE_BYTES[dt] * math.prod(dims) if dims else DTYPE_BYTES[dt]


def _result_bytes(result: str) -> int:
    result = result.strip()
    if result.startswith("("):
        return sum(_shape_bytes(p) for p in result[1:-1].split(","))
    return _shape_bytes(result)


# one op line: "  %name = TYPE opcode(operands), attrs"
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?))\s*"
    r"([\w\-]+)\(")

_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


@dataclasses.dataclass
class OpLine:
    name: str
    result: str
    opcode: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: list
    is_fusion_body: bool = False


def _parse_computations(text: str) -> dict:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        stripped = line.rstrip()
        if cur is None:
            m = _COMP_HDR_RE.match(stripped.strip())
            if m and stripped.strip().endswith("{"):
                cur = Computation(m.group(1), [])
            continue
        if stripped.strip() == "}" or stripped.strip().startswith("} //"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(stripped)
        if m:
            cur.ops.append(OpLine(m.group(1), m.group(2), m.group(3),
                                  stripped))
    if cur is not None:
        comps[cur.name] = cur
    return comps


def _dot_flops(op: OpLine, shapes: dict) -> float:
    """dot flops = 2 * prod(result dims) * contraction size."""
    out = _shape_info(op.result)
    if out is None:
        return 0.0
    operands = _operand_names(op)
    k = 1
    cm = _CONTRACT_RE.search(op.line)
    if operands and cm:
        lhs = shapes.get(operands[0])
        if lhs:
            dims = [int(d) for d in cm.group(1).split(",") if d != ""]
            for d in dims:
                if d < len(lhs[1]):
                    k *= lhs[1][d]
    return 2.0 * math.prod(out[1]) * k if out[1] else 0.0


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return max(1, int(m.group(2)))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return max(1, len([t for t in m.group(1).split(",") if t.strip()]))
    return default


def _operand_span(line: str, opcode: str) -> str | None:
    """The text between the parentheses of ``opcode(...)``, bracket-aware.

    Anchors on "opcode(" — the op *name* may itself contain the opcode as a
    substring (e.g. "%dot.0 = ... dot(...)") — and scans to the *matching*
    close paren (operand shapes may nest parens/brackets/braces).
    """
    start = line.find(opcode + "(")
    if start < 0:
        return None
    i = start + len(opcode) + 1
    depth, j = 1, i
    while j < len(line) and depth:
        ch = line[j]
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        j += 1
    return line[i:j - 1]


def _split_top_level(s: str) -> list[str]:
    parts, depth, cur = [], 0, []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur))
    return parts


def _operand_names(op: OpLine) -> list[str]:
    span = _operand_span(op.line, op.opcode)
    if span is None:
        return []
    # Operands are typed: "f32[16,128]{1,0} %name" — keep only the name.
    names = []
    for tok in _split_top_level(span):
        tok = tok.strip()
        if not tok:
            continue
        names.append(tok.split()[-1].lstrip("%"))
    return names


def _named_bytes(nm: str, shapes: dict) -> int:
    if nm not in shapes:
        return 0
    dt, dims = shapes[nm]
    return DTYPE_BYTES[dt] * math.prod(dims) if dims else DTYPE_BYTES[dt]


def _op_bytes(op: OpLine, shapes: dict, comps: dict) -> float:
    """HBM traffic model for one top-level op (post-fusion).

    Special cases mirror XLA's HloCostAnalysis:
      * dynamic-slice reads only the slice, not the whole operand;
      * dynamic-update-slice (in-place on TPU) touches ~2x the update;
      * fusions charge, per input parameter, the bytes its consumers
        inside the body actually touch (capped at the full operand) —
        so a fused cache-slice read is priced as the slice; a fusion
        whose root is a DUS is priced as an in-place update.
    """
    rb = _result_bytes(op.result)
    operands = _operand_names(op)
    if op.opcode == "dynamic-slice":
        return 2.0 * rb
    if op.opcode == "dynamic-update-slice":
        upd = _named_bytes(operands[1], shapes) if len(operands) > 1 else rb
        return 2.0 * upd
    if op.opcode in ("gather", "scatter"):
        return 2.0 * rb + (_named_bytes(operands[-1], shapes)
                           if operands else 0)
    if op.opcode == "fusion":
        body_name = None
        m = _CALLS_RE.search(op.line)
        if m:
            body_name = m.group(1)
        body = comps.get(body_name) if body_name else None
        if body is None:
            return rb + sum(_named_bytes(nm, shapes) for nm in operands)
        # map body parameter index -> consumed bytes
        body_shapes = {}
        param_of: dict[str, int] = {}
        for bop in body.ops:
            info = _shape_info(bop.result) if not bop.result.startswith("(") \
                else None
            if info:
                body_shapes[bop.name] = info
            if bop.opcode == "parameter":
                pm = re.search(r"parameter\((\d+)\)", bop.line)
                if pm:
                    param_of[bop.name] = int(pm.group(1))
        by_name = {bop.name: bop for bop in body.ops}
        unary = {"convert", "copy", "bitcast", "reshape", "transpose"}

        def trace_to_param(nm: str) -> str | None:
            """Follow a unary chain upward to a parameter name."""
            seen = 0
            while nm in by_name and seen < 32:
                bop = by_name[nm]
                if bop.opcode == "parameter":
                    return nm
                if bop.opcode not in unary:
                    return None
                ops_ = _operand_names(bop)
                if not ops_:
                    return None
                nm = ops_[0]
                seen += 1
            return nm if nm in param_of else None

        consumed = [0.0] * len(operands)
        inplace_buffer: dict[int, float] = {}     # param idx -> update bytes
        dus_write = None
        root_op = next((b for b in body.ops
                        if b.line.lstrip().startswith("ROOT")), None)
        for bop in body.ops:
            if bop.opcode == "parameter":
                continue
            bops = _operand_names(bop)
            if bop.opcode == "dynamic-update-slice":
                # buffer operand is updated in place: touched ~ update size
                upd_b = 0
                if len(bops) > 1:
                    upd_b = _named_bytes(bops[1], body_shapes) \
                        or _named_bytes(bops[1], shapes)
                src = trace_to_param(bops[0]) if bops else None
                if src is not None and param_of.get(src, 99) < len(operands):
                    inplace_buffer[param_of[src]] = upd_b
                # does the fusion root reduce to this DUS (unary chain)?
                if root_op is not None:
                    r = root_op.name
                    chain = {bop.name}
                    cur = root_op
                    hops = 0
                    while cur is not None and hops < 32:
                        if cur.name == bop.name:
                            dus_write = upd_b or None
                            break
                        if cur.opcode not in unary and \
                                not cur.line.lstrip().startswith("ROOT"):
                            break
                        nxt = _operand_names(cur)
                        cur = by_name.get(nxt[0]) if nxt else None
                        hops += 1
                for nm in bops[1:2]:
                    p = trace_to_param(nm)
                    if p is not None and param_of.get(p, 99) < len(consumed):
                        consumed[param_of[p]] += upd_b
                continue
            touch = _result_bytes(bop.result)
            for nm in bops:
                if nm in param_of and param_of[nm] < len(consumed):
                    consumed[param_of[nm]] += touch
        ob = 0.0
        for i, nm in enumerate(operands):
            full = _named_bytes(nm, shapes)
            if i in inplace_buffer:
                ob += min(full, inplace_buffer[i])
            else:
                ob += min(full, consumed[i] if i < len(consumed) else full)
        if dus_write is not None:
            # in-place update: write ~ the update, not the whole buffer
            rb = min(rb, dus_write)
        return rb + ob
    return rb + sum(_named_bytes(nm, shapes) for nm in operands)


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_payload: dict = dataclasses.field(default_factory=dict)
    coll_wire: float = 0.0
    coll_counts: dict = dataclasses.field(default_factory=dict)

    def scaled(self, k: float) -> "Costs":
        return Costs(self.flops * k, self.hbm_bytes * k,
                     {o: b * k for o, b in self.coll_payload.items()},
                     self.coll_wire * k,
                     {o: c * k for o, c in self.coll_counts.items()})

    def add(self, o: "Costs") -> None:
        self.flops += o.flops
        self.hbm_bytes += o.hbm_bytes
        self.coll_wire += o.coll_wire
        for k, v in o.coll_payload.items():
            self.coll_payload[k] = self.coll_payload.get(k, 0) + v
        for k, v in o.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0) + v


def analyze_hlo(text: str, total_devices: int,
                entry: str | None = None) -> Costs:
    comps = _parse_computations(text)
    if not comps:
        return Costs()
    # mark fusion bodies (their internals are free except dot flops)
    fusion_bodies: set[str] = set()
    called_by: set[str] = set()
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode == "fusion":
                for cm in _CALLS_RE.finditer(op.line):
                    fusion_bodies.add(cm.group(1))
            for cm in _CALLS_RE.finditer(op.line):
                called_by.add(cm.group(1))
            cc = _COND_RE.search(op.line)
            if cc:
                called_by.add(cc.group(1))
            bm = _BRANCHES_RE.search(op.line)
            if bm:
                for b in bm.group(1).split(","):
                    called_by.add(b.strip().lstrip("%"))

    # global shape table (names are unique module-wide in practice)
    shapes: dict[str, tuple] = {}
    for comp in comps.values():
        for op in comp.ops:
            info = _shape_info(op.result) if not op.result.startswith("(") \
                else None
            if info:
                shapes[op.name] = info

    memo: dict[str, Costs] = {}

    def comp_costs(name: str) -> Costs:
        if name in memo:
            return memo[name]
        memo[name] = Costs()                     # cycle guard
        comp = comps.get(name)
        if comp is None:
            return memo[name]
        total = Costs()
        in_fusion = name in fusion_bodies
        for op in comp.ops:
            if op.opcode == "dot":
                total.flops += _dot_flops(op, shapes)
            if op.opcode == "while":
                m = _TRIP_RE.search(op.line)
                trips = int(m.group(1)) if m else 1
                body = _CALLS_RE.search(op.line)
                if body:
                    total.add(comp_costs(body.group(1)).scaled(trips))
                cond = _COND_RE.search(op.line)
                if cond:
                    total.add(comp_costs(cond.group(1)).scaled(trips))
                continue
            if op.opcode in ("call", "fusion"):
                for cm in _CALLS_RE.finditer(op.line):
                    sub = comp_costs(cm.group(1))
                    # fusion body dots count; bytes counted at this level
                    total.flops += sub.flops
                    total.coll_wire += sub.coll_wire
                    for k, v in sub.coll_payload.items():
                        total.coll_payload[k] = \
                            total.coll_payload.get(k, 0) + v
            if op.opcode == "conditional":
                bm = _BRANCHES_RE.search(op.line)
                if bm:
                    subs = [comp_costs(b.strip().lstrip("%"))
                            for b in bm.group(1).split(",")]
                    if subs:                     # worst-case branch
                        worst = max(subs, key=lambda c: c.flops)
                        total.add(worst)
                continue
            # collectives
            base = op.opcode.replace("-start", "")
            if base in COLLECTIVES and "-done" not in op.opcode:
                b = _result_bytes(op.result)
                if base in ("all-reduce", "reduce-scatter"):
                    # result of AR = payload; RS result is the shard
                    pass
                n = _group_size(op.line, total_devices)
                if n > 1:
                    total.coll_counts[base] = \
                        total.coll_counts.get(base, 0) + 1
                    total.coll_payload[base] = \
                        total.coll_payload.get(base, 0) + b
                    frac = (n - 1) / n
                    if base == "all-reduce":
                        total.coll_wire += 2 * frac * b
                    elif base == "collective-permute":
                        total.coll_wire += b
                    elif base == "reduce-scatter":
                        total.coll_wire += frac * b * n
                    else:
                        total.coll_wire += frac * b
            # HBM bytes: non-structural ops read operands + write result.
            # Inside fusion bodies only the dot flops matter (the fusion
            # op at the call site accounts for the traffic).
            if not in_fusion and op.opcode not in STRUCTURAL_OPS:
                total.hbm_bytes += _op_bytes(op, shapes, comps)
        memo[name] = total
        return total

    if entry is None:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
        entry = m.group(1) if m else next(iter(comps))
    # parameters of the entry are read once (weights/cache stream-in)
    c = comp_costs(entry)
    return c
