"""Public op wrapper for the thermometer kernel (pad + backend switch)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.bitpack import WORD_BITS, PackedBits
from .kernel import thermometer_encode, thermometer_encode_packed
from .ref import thermometer_ref, thermometer_packed_ref


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def encode(x: jax.Array, thresholds: jax.Array, *,
           interpret: bool | None = None, flatten: bool = True) -> jax.Array:
    """Thermometer-encode with the Pallas kernel.

    Pads T to a 128-lane multiple and B/F to block multiples, then slices
    back.  On CPU (no TPU available) runs the kernel in interpret mode.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, F = x.shape
    T = thresholds.shape[1]
    Tp = _round_up(T, 128)
    bb = min(256, _round_up(B, 8))
    Bp = _round_up(B, bb)
    bf = min(8, F)
    Fp = _round_up(F, bf)
    xp = jnp.pad(x, ((0, Bp - B), (0, Fp - F)))
    # pad thresholds with +inf so padded bits are 0
    thp = jnp.pad(thresholds, ((0, Fp - F), (0, Tp - T)),
                  constant_values=jnp.inf)
    bits = thermometer_encode(xp, thp, block_b=bb, block_f=bf,
                              interpret=interpret)
    bits = bits[:B, :F, :T]
    return bits.reshape(B, F * T) if flatten else bits


def encode_packed(x: jax.Array, thresholds: jax.Array, *,
                  interpret: bool | None = None) -> PackedBits:
    """Thermometer-encode straight into packed uint32 words.

    Pads B to a block multiple; the flat bit layout (bit f*T + t) is a
    hard contract, so T is *not* padded — when F*T is not a 32-multiple
    the kernel grid can't pack cleanly and we fall back to the jnp packed
    oracle (same result, no Pallas).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, F = x.shape
    T = thresholds.shape[1]
    if (F * T) % WORD_BITS != 0:
        return PackedBits(thermometer_packed_ref(x, thresholds), F * T)
    bb = min(256, _round_up(B, 8))
    Bp = _round_up(B, bb)
    xp = jnp.pad(x, ((0, Bp - B), (0, 0)))
    words = thermometer_encode_packed(xp, thresholds, block_b=bb,
                                      interpret=interpret)
    return PackedBits(words[:B], F * T)


__all__ = ["encode", "encode_packed", "thermometer_ref",
           "thermometer_packed_ref"]
