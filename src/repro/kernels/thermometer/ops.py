"""Public op wrapper for the thermometer kernel (pad + backend switch)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import thermometer_encode
from .ref import thermometer_ref


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def encode(x: jax.Array, thresholds: jax.Array, *,
           interpret: bool | None = None, flatten: bool = True) -> jax.Array:
    """Thermometer-encode with the Pallas kernel.

    Pads T to a 128-lane multiple and B/F to block multiples, then slices
    back.  On CPU (no TPU available) runs the kernel in interpret mode.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, F = x.shape
    T = thresholds.shape[1]
    Tp = _round_up(T, 128)
    bb = min(256, _round_up(B, 8))
    Bp = _round_up(B, bb)
    bf = min(8, F)
    Fp = _round_up(F, bf)
    xp = jnp.pad(x, ((0, Bp - B), (0, Fp - F)))
    # pad thresholds with +inf so padded bits are 0
    thp = jnp.pad(thresholds, ((0, Fp - F), (0, Tp - T)),
                  constant_values=jnp.inf)
    bits = thermometer_encode(xp, thp, block_b=bb, block_f=bf,
                              interpret=interpret)
    bits = bits[:B, :F, :T]
    return bits.reshape(B, F * T) if flatten else bits


__all__ = ["encode", "thermometer_ref"]
