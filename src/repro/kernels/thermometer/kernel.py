"""Pallas TPU kernel: thermometer encoding.

FPGA -> TPU adaptation (DESIGN.md §3): the comparator bank becomes a
VPU broadcast-compare over a VMEM tile.  The (B, F) feature tile and the
(F, T) threshold bank tile live in VMEM; each grid step emits a
(B_blk, F_blk, T) bit tile.  T is padded to a lane multiple (128) by
ops.py so the compare vectorizes cleanly onto the 8x128 VREGs.

Grid: (B / B_blk, F / F_blk).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _thermometer_kernel(x_ref, th_ref, out_ref):
    # x_ref: (B_blk, F_blk); th_ref: (F_blk, T); out: (B_blk, F_blk, T)
    x = x_ref[...]                                   # (B_blk, F_blk)
    th = th_ref[...]                                 # (F_blk, T)
    out_ref[...] = (x[:, :, None] > th[None]).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "block_f",
                                             "interpret"))
def thermometer_encode(x: jax.Array, thresholds: jax.Array, *,
                       block_b: int = 256, block_f: int = 8,
                       interpret: bool = False) -> jax.Array:
    """x (B, F) f32, thresholds (F, T) f32 -> (B, F, T) f32 bits."""
    B, F = x.shape
    T = thresholds.shape[1]
    bb, bf = min(block_b, B), min(block_f, F)
    assert B % bb == 0 and F % bf == 0, (x.shape, bb, bf)
    grid = (B // bb, F // bf)
    return pl.pallas_call(
        _thermometer_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bf), lambda i, j: (i, j)),
            pl.BlockSpec((bf, T), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bb, bf, T), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((B, F, T), jnp.float32),
        interpret=interpret,
    )(x, thresholds)
