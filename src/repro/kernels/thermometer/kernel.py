"""Pallas TPU kernel: thermometer encoding.

FPGA -> TPU adaptation (DESIGN.md §3): the comparator bank becomes a
VPU broadcast-compare over a VMEM tile.  The (B, F) feature tile and the
(F, T) threshold bank tile live in VMEM; each grid step emits a
(B_blk, F_blk, T) bit tile.  T is padded to a lane multiple (128) by
ops.py so the compare vectorizes cleanly onto the 8x128 VREGs.

Grid: (B / B_blk, F / F_blk).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core.bitpack import WORD_BITS


def _pack_words(bits, rows: int):
    """In-kernel bitpack: (rows, N) {0,1} -> (rows, N/32) uint32, LSB-first.

    N must be a 32-multiple (the op wrappers guarantee it); the whole pack
    is a VPU multiply-reduce, no gathers.
    """
    w = bits.reshape(rows, -1, WORD_BITS).astype(jnp.uint32)
    weights = jnp.left_shift(jnp.uint32(1),
                             jnp.arange(WORD_BITS, dtype=jnp.uint32))
    return jnp.sum(w * weights, axis=-1, dtype=jnp.uint32)


def _thermometer_kernel(x_ref, th_ref, out_ref):
    # x_ref: (B_blk, F_blk); th_ref: (F_blk, T); out: (B_blk, F_blk, T)
    x = x_ref[...]                                   # (B_blk, F_blk)
    th = th_ref[...]                                 # (F_blk, T)
    out_ref[...] = (x[:, :, None] > th[None]).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "block_f",
                                             "interpret"))
def thermometer_encode(x: jax.Array, thresholds: jax.Array, *,
                       block_b: int = 256, block_f: int = 8,
                       interpret: bool = False) -> jax.Array:
    """x (B, F) f32, thresholds (F, T) f32 -> (B, F, T) f32 bits."""
    B, F = x.shape
    T = thresholds.shape[1]
    bb, bf = min(block_b, B), min(block_f, F)
    assert B % bb == 0 and F % bf == 0, (x.shape, bb, bf)
    grid = (B // bb, F // bf)
    return pl.pallas_call(
        _thermometer_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bf), lambda i, j: (i, j)),
            pl.BlockSpec((bf, T), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bb, bf, T), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((B, F, T), jnp.float32),
        interpret=interpret,
    )(x, thresholds)


def _thermometer_packed_kernel(x_ref, th_ref, out_ref):
    # x: (B_blk, F); th: (F, T); out: (B_blk, F*T/32) uint32.  The compare
    # produces the (B_blk, F, T) bit tile in VMEM only; what reaches the
    # output (and HBM) is the packed words — 32x fewer bytes than the float
    # kernel above, and the (B, F, T) float tensor is never materialized.
    x = x_ref[...]
    th = th_ref[...]
    bits = (x[:, :, None] > th[None])                # bool (B_blk, F, T)
    out_ref[...] = _pack_words(bits.reshape(x.shape[0], -1), x.shape[0])


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def thermometer_encode_packed(x: jax.Array, thresholds: jax.Array, *,
                              block_b: int = 256,
                              interpret: bool = False) -> jax.Array:
    """x (B, F) f32, thresholds (F, T) f32 -> (B, F*T/32) uint32 words.

    Bit f*T + t of the flat bit-vector (word (f*T+t)>>5, position
    (f*T+t)&31) is ``x[b,f] > thresholds[f,t]``.  F*T must be a
    32-multiple (ops.py gates on this).
    """
    B, F = x.shape
    T = thresholds.shape[1]
    assert (F * T) % WORD_BITS == 0, (F, T)
    W = F * T // WORD_BITS
    bb = min(block_b, B)
    assert B % bb == 0, (B, bb)
    return pl.pallas_call(
        _thermometer_packed_kernel,
        grid=(B // bb,),
        in_specs=[
            pl.BlockSpec((bb, F), lambda i: (i, 0)),
            pl.BlockSpec((F, T), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, W), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, W), jnp.uint32),
        interpret=interpret,
    )(x, thresholds)
