"""Pure-jnp oracle for the thermometer-encode kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def thermometer_ref(x: jax.Array, thresholds: jax.Array) -> jax.Array:
    """x (B, F) float; thresholds (F, T) ascending -> bits (B, F, T) f32.

    bit[b, f, t] = x[b, f] > thresholds[f, t]  (matches core.thermometer).
    """
    return (x[:, :, None] > thresholds[None]).astype(jnp.float32)
