"""Pure-jnp oracle for the thermometer-encode kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def thermometer_ref(x: jax.Array, thresholds: jax.Array) -> jax.Array:
    """x (B, F) float; thresholds (F, T) ascending -> bits (B, F, T) f32.

    bit[b, f, t] = x[b, f] > thresholds[f, t]  (matches core.thermometer).
    """
    return (x[:, :, None] > thresholds[None]).astype(jnp.float32)


def thermometer_packed_ref(x: jax.Array, thresholds: jax.Array) -> jax.Array:
    """Packed oracle: (B, ceil(F*T/32)) uint32 words of the flat bits."""
    from ...core.bitpack import pack_bits
    bits = (x[:, :, None] > thresholds[None]).reshape(x.shape[0], -1)
    return pack_bits(bits)
