from . import ops, ref, kernel
