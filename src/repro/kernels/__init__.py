"""Pallas TPU kernels for the DWN hot spots the paper optimizes:
thermometer encoding, LUT-layer evaluation, popcount/argmax — plus the
fused whole-accelerator kernel (beyond-paper; bits never leave VMEM).
Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper with interpret/TPU switch + padding), ref.py (pure-jnp oracle)."""
from . import thermometer, lut_eval, popcount, fused, flash_attn
