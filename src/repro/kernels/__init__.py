"""Pallas TPU kernels for the DWN hot spots the paper optimizes:
thermometer encoding, LUT-layer evaluation, popcount/argmax — plus the
fused whole-accelerator kernel (beyond-paper; bits never leave VMEM).
Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper with interpret/TPU switch + padding), ref.py (pure-jnp oracle).

Every stage also has a *packed* variant operating on uint32 bitplanes
(32 logical bits per word — see ``repro.core.bitpack`` for the format):
``encode_packed`` emits packed words straight from the compare,
``evaluate_packed`` forms LUT addresses with shift/AND on the words,
``classify_packed`` popcounts masked words (SWAR), and
``fused.ops.forward_packed`` runs the whole model in one pallas_call.
``autotune`` picks the fused kernel variant + block shapes per
(model, batch bucket, device) and persists winners (docs/autotune.md)."""
from . import thermometer, lut_eval, popcount, fused, flash_attn, autotune
