"""Pure-jnp oracle for the fused DWN-accelerator kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..thermometer.ref import thermometer_ref
from ..lut_eval.ref import lut_eval_ref
from ..popcount.ref import popcount_ref


def fused_dwn_ref(x: jax.Array, thresholds: jax.Array, mapping: jax.Array,
                  tables: jax.Array, num_classes: int) -> jax.Array:
    """x (B,F); thresholds (F,T); mapping (m,n); tables (m,2^n) ->
    counts (B, classes).  Composition of the three stage oracles."""
    bits = thermometer_ref(x, thresholds).reshape(x.shape[0], -1)
    out = lut_eval_ref(bits, mapping, tables)
    return popcount_ref(out, num_classes)


def fused_dwn_packed_ref(x: jax.Array, thresholds: jax.Array,
                         mappings, tables, num_classes: int):
    """Multi-layer float-oracle composition for the packed fused kernel.

    mappings/tables: per-layer lists (single arrays accepted).  Returns
    (counts, argmax) with the tie-to-lower-index rule.
    """
    if not isinstance(mappings, (list, tuple)):
        mappings, tables = [mappings], [tables]
    bits = thermometer_ref(x, thresholds).reshape(x.shape[0], -1)
    for mp, tb in zip(mappings, tables):
        bits = lut_eval_ref(bits, mp, tb)
    counts = popcount_ref(bits, num_classes)
    return counts, jnp.argmax(counts, axis=-1).astype(jnp.int32)
