"""Pallas TPU kernel: fused DWN accelerator (beyond-paper optimization).

The paper's central finding is that thermometer *encoding* dominates
small-model hardware cost.  On TPU the same phenomenon appears as a
memory-bound unary blow-up: encoding inflates a (B, 16) feature tile into
a (B, 3200) bit tensor (x200 bytes) that a staged implementation writes
to and re-reads from HBM.  This kernel keeps the bits in VMEM for their
entire life: encode -> selection matmul (MXU) -> corner-product table
eval (VPU) -> per-class popcount, emitting only the (B, classes) counts.

Grid: (B / B_blk, m / m_blk); the m axis is the innermost (sequential)
loop and accumulates partial class counts into the same output block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core.bitpack import (WORD_BITS, select_packed_bits, lut_addresses,
                             masked_group_counts)
from ..thermometer.kernel import _pack_words
from ..popcount.kernel import _first_argmax


def _fused_kernel(x_ref, th_ref, sel_ref, tab_ref, cls_ref, counts_ref, *,
                  fan_in: int):
    j = pl.program_id(1)
    x = x_ref[...]                                    # (B_blk, F)
    th = th_ref[...]                                  # (F, T)
    B_blk, F = x.shape
    T = th.shape[1]
    bits = (x[:, :, None] > th[None]).astype(jnp.float32)
    bits = bits.reshape(B_blk, F * T)                 # stays in VMEM
    sel = sel_ref[...]                                # (F*T, m_blk*n)
    tab = tab_ref[...]                                # (m_blk, 2^n)
    cls = cls_ref[...]                                # (m_blk, classes)
    mn = sel.shape[1]
    m_blk = mn // fan_in
    A = 2 ** fan_in
    s = jnp.dot(bits, sel, preferred_element_type=jnp.float32)
    s = s.reshape(B_blk, m_blk, fan_in)
    w = jnp.ones((B_blk, m_blk, A), jnp.float32)
    for i in range(fan_in):
        si = s[:, :, i:i + 1]
        corner_i = ((jnp.arange(A, dtype=jnp.int32) >> i) & 1).astype(
            jnp.float32)
        w = w * (si * corner_i + (1.0 - si) * (1.0 - corner_i))
    out_bits = jnp.sum(w * tab[None].astype(jnp.float32), axis=-1)
    partial = jnp.dot(out_bits, cls.astype(jnp.float32),
                      preferred_element_type=jnp.float32)

    @pl.when(j == 0)
    def _init():
        counts_ref[...] = partial

    @pl.when(j > 0)
    def _acc():
        counts_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("fan_in", "block_b", "block_m",
                                             "interpret"))
def fused_dwn(x: jax.Array, thresholds: jax.Array, sel_onehot: jax.Array,
              tables: jax.Array, class_map: jax.Array, *, fan_in: int = 6,
              block_b: int = 256, block_m: int = 128,
              interpret: bool = False) -> jax.Array:
    """x (B,F); thresholds (F,T); sel_onehot (F*T, m*n); tables (m, 2^n);
    class_map (m, classes) one-hot -> counts (B, classes) f32."""
    B, F = x.shape
    T = thresholds.shape[1]
    m, classes = class_map.shape
    A = 2 ** fan_in
    bb, bm = min(block_b, B), min(block_m, m)
    assert B % bb == 0 and m % bm == 0, (B, m, bb, bm)
    grid = (B // bb, m // bm)
    kernel = functools.partial(_fused_kernel, fan_in=fan_in)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, F), lambda i, j: (i, 0)),
            pl.BlockSpec((F, T), lambda i, j: (0, 0)),
            pl.BlockSpec((F * T, bm * fan_in), lambda i, j: (0, j)),
            pl.BlockSpec((bm, A), lambda i, j: (j, 0)),
            pl.BlockSpec((bm, classes), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bb, classes), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, classes), jnp.float32),
        interpret=interpret,
    )(x, thresholds, sel_onehot, tables, class_map)


def _fused_packed_kernel(x_ref, th_ref, *refs, num_layers: int):
    # refs: per layer (widx, boff, tab), then class masks, then the two
    # output refs appended by pallas_call (counts, idx).
    #
    # The whole accelerator on packed words: the encode compare produces
    # the (B_blk, F, T) bool tile in VMEM, is immediately packed to
    # (B_blk, F*T/32) uint32 — the only bit representation that persists —
    # then every LUT layer is gather + shift/AND addressing + table read +
    # repack, and the classifier is a masked SWAR popcount.  Bits never
    # touch HBM in any dtype; only the (B, classes) counts leave.
    cls_ref = refs[3 * num_layers]
    counts_ref = refs[3 * num_layers + 1]
    idx_ref = refs[3 * num_layers + 2]
    x = x_ref[...]                                   # (B_blk, F)
    th = th_ref[...]                                 # (F, T)
    B_blk = x.shape[0]
    bits = (x[:, :, None] > th[None])                # bool, VMEM-resident
    words = _pack_words(bits.reshape(B_blk, -1), B_blk)
    for l in range(num_layers):
        widx = refs[3 * l][...]                      # (m_l, n_l) i32
        boff = refs[3 * l + 1][...]
        tab = refs[3 * l + 2][...]                   # (m_l, 2^n_l) i32
        sel = select_packed_bits(words, widx, boff)
        addr = lut_addresses(sel)
        out_bits = jnp.take_along_axis(
            jnp.broadcast_to(tab[None], (B_blk,) + tab.shape),
            addr[..., None], axis=-1)[..., 0]
        words = _pack_words(out_bits, B_blk)
    mask = cls_ref[...]                              # (classes, W)
    counts = masked_group_counts(words, mask)
    counts_ref[...] = counts
    idx_ref[...] = _first_argmax(counts)[:, None]


@functools.partial(jax.jit, static_argnames=("num_layers", "block_b",
                                             "interpret"))
def fused_dwn_packed(x: jax.Array, thresholds: jax.Array,
                     layer_arrays: tuple, class_masks: jax.Array, *,
                     num_layers: int, block_b: int = 256,
                     interpret: bool = False):
    """Whole-model packed inference in ONE pallas_call.

    x (B, F); thresholds (F, T) with F*T a 32-multiple; layer_arrays a
    flat tuple (widx_0, boff_0, tab_0, widx_1, ...) with every m_l a
    32-multiple; class_masks (classes, W_last) uint32.
    Returns (counts (B, classes) f32, idx (B,) i32).
    """
    B, F = x.shape
    T = thresholds.shape[1]
    assert (F * T) % WORD_BITS == 0, (F, T)
    assert len(layer_arrays) == 3 * num_layers
    classes, W_last = class_masks.shape
    bb = min(block_b, B)
    assert B % bb == 0, (B, bb)
    kernel = functools.partial(_fused_packed_kernel, num_layers=num_layers)
    in_specs = [
        pl.BlockSpec((bb, F), lambda i: (i, 0)),
        pl.BlockSpec((F, T), lambda i: (0, 0)),
    ]
    for arr in layer_arrays:
        in_specs.append(pl.BlockSpec(
            arr.shape, lambda i, nd=arr.ndim: (0,) * nd))
    in_specs.append(pl.BlockSpec((classes, W_last), lambda i: (0, 0)))
    counts, idx = pl.pallas_call(
        kernel,
        grid=(B // bb,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((bb, classes), lambda i: (i, 0)),
            pl.BlockSpec((bb, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, classes), jnp.float32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
        ],
        interpret=interpret,
    )(x, thresholds, *layer_arrays, class_masks)
    return counts, idx[:, 0]
