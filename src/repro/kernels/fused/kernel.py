"""Pallas TPU kernel: fused DWN accelerator (beyond-paper optimization).

The paper's central finding is that thermometer *encoding* dominates
small-model hardware cost.  On TPU the same phenomenon appears as a
memory-bound unary blow-up: encoding inflates a (B, 16) feature tile into
a (B, 3200) bit tensor (x200 bytes) that a staged implementation writes
to and re-reads from HBM.  These kernels keep the bits in VMEM for their
entire life; three variants trade off how the bits are materialized:

``fused_dwn``
    float datapath: encode -> selection matmul (MXU) -> corner-product
    table eval (VPU) -> per-class popcount.  Grid (B/bb, m/bm); the m
    axis is the innermost (sequential) loop accumulating partial class
    counts, and the first-argmax prediction is emitted in-kernel on the
    last m step.

``fused_dwn_packed``
    packed datapath: the encode compare packs straight to uint32 words
    in VMEM, every LUT layer is gather + shift/AND addressing, and the
    classifier is a masked SWAR popcount.  Grid over sample tiles only.

``fused_dwn_batch_major``
    batch-major direct-wire datapath: the first LUT layer reads only
    m*n of the F*T thermometer bits, so for small models materializing
    (let alone packing) the full bit tensor is pure overhead.  This
    variant gathers the *features and thresholds of the wired bits* and
    compares exactly those — one grid step processes a whole
    (rows x bucket) sample tile with the entire model state VMEM-
    resident.  Single-layer models (all JSC presets) never touch a
    packed word at all; deeper stacks pack the first layer's outputs
    and continue on the packed datapath.

Every wrapper pads the batch internally to a block multiple and masks /
slices the tail, so any batch size works without caller-side bucket
rounding (the old ``B % bb == 0`` hard asserts are gone).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core.bitpack import (WORD_BITS, select_packed_bits, lut_addresses,
                             masked_group_counts)
from ..thermometer.kernel import _pack_words
from ..popcount.kernel import _first_argmax


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _row_mask(i, rows: int, total_b: int):
    """(rows, 1) bool: which rows of grid step ``i`` are real samples."""
    r = jax.lax.broadcasted_iota(jnp.int32, (rows, 1), 0)
    return (i * rows + r) < total_b


def _fused_kernel(x_ref, th_ref, sel_ref, tab_ref, cls_ref, counts_ref,
                  idx_ref, *, fan_in: int):
    j = pl.program_id(1)
    x = x_ref[...]                                    # (B_blk, F)
    th = th_ref[...]                                  # (F, T)
    B_blk, F = x.shape
    T = th.shape[1]
    bits = (x[:, :, None] > th[None]).astype(jnp.float32)
    bits = bits.reshape(B_blk, F * T)                 # stays in VMEM
    sel = sel_ref[...]                                # (F*T, m_blk*n)
    tab = tab_ref[...]                                # (m_blk, 2^n)
    cls = cls_ref[...]                                # (m_blk, classes)
    mn = sel.shape[1]
    m_blk = mn // fan_in
    A = 2 ** fan_in
    s = jnp.dot(bits, sel, preferred_element_type=jnp.float32)
    s = s.reshape(B_blk, m_blk, fan_in)
    w = jnp.ones((B_blk, m_blk, A), jnp.float32)
    for i in range(fan_in):
        si = s[:, :, i:i + 1]
        corner_i = ((jnp.arange(A, dtype=jnp.int32) >> i) & 1).astype(
            jnp.float32)
        w = w * (si * corner_i + (1.0 - si) * (1.0 - corner_i))
    out_bits = jnp.sum(w * tab[None].astype(jnp.float32), axis=-1)
    partial = jnp.dot(out_bits, cls.astype(jnp.float32),
                      preferred_element_type=jnp.float32)

    @pl.when(j == 0)
    def _init():
        counts_ref[...] = partial

    @pl.when(j > 0)
    def _acc():
        counts_ref[...] += partial

    # the m loop is innermost/sequential, so once the last m block has
    # accumulated, the counts block is final: emit the first-argmax
    # prediction here instead of making every caller re-derive it
    @pl.when(j == pl.num_programs(1) - 1)
    def _emit_idx():
        idx_ref[...] = _first_argmax(counts_ref[...])[:, None]


@functools.partial(jax.jit, static_argnames=("fan_in", "block_b", "block_m",
                                             "interpret"))
def fused_dwn(x: jax.Array, thresholds: jax.Array, sel_onehot: jax.Array,
              tables: jax.Array, class_map: jax.Array, *, fan_in: int = 6,
              block_b: int = 256, block_m: int = 128,
              interpret: bool = False):
    """x (B,F); thresholds (F,T); sel_onehot (F*T, m*n); tables (m, 2^n);
    class_map (m, classes) one-hot -> (counts (B, classes) f32,
    idx (B,) i32 first-argmax).  Any B works: the batch is padded
    internally to a block multiple and the tail sliced off."""
    B, F = x.shape
    T = thresholds.shape[1]
    m, classes = class_map.shape
    A = 2 ** fan_in
    bb, bm = min(block_b, B), min(block_m, m)
    assert m % bm == 0, (m, bm)
    Bp = _round_up(B, bb)
    xp = jnp.pad(x, ((0, Bp - B), (0, 0)))
    grid = (Bp // bb, m // bm)
    kernel = functools.partial(_fused_kernel, fan_in=fan_in)
    counts, idx = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, F), lambda i, j: (i, 0)),
            pl.BlockSpec((F, T), lambda i, j: (0, 0)),
            pl.BlockSpec((F * T, bm * fan_in), lambda i, j: (0, j)),
            pl.BlockSpec((bm, A), lambda i, j: (j, 0)),
            pl.BlockSpec((bm, classes), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb, classes), lambda i, j: (i, 0)),
            pl.BlockSpec((bb, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, classes), jnp.float32),
            jax.ShapeDtypeStruct((Bp, 1), jnp.int32),
        ],
        interpret=interpret,
    )(xp, thresholds, sel_onehot, tables, class_map)
    return counts[:B], idx[:B, 0]


def _fused_packed_kernel(x_ref, th_ref, *refs, num_layers: int,
                         total_b: int):
    # refs: per layer (widx, boff, tab), then class masks, then the two
    # output refs appended by pallas_call (counts, idx).
    #
    # The whole accelerator on packed words: the encode compare produces
    # the (B_blk, F, T) bool tile in VMEM, is immediately packed to
    # (B_blk, F*T/32) uint32 — the only bit representation that persists —
    # then every LUT layer is gather + shift/AND addressing + table read +
    # repack, and the classifier is a masked SWAR popcount.  Bits never
    # touch HBM in any dtype; only the (B, classes) counts leave.
    cls_ref = refs[3 * num_layers]
    counts_ref = refs[3 * num_layers + 1]
    idx_ref = refs[3 * num_layers + 2]
    x = x_ref[...]                                   # (B_blk, F)
    th = th_ref[...]                                 # (F, T)
    B_blk = x.shape[0]
    bits = (x[:, :, None] > th[None])                # bool, VMEM-resident
    words = _pack_words(bits.reshape(B_blk, -1), B_blk)
    for l in range(num_layers):
        widx = refs[3 * l][...]                      # (m_l, n_l) i32
        boff = refs[3 * l + 1][...]
        tab = refs[3 * l + 2][...]                   # (m_l, 2^n_l) i32
        sel = select_packed_bits(words, widx, boff)
        addr = lut_addresses(sel)
        out_bits = jnp.take_along_axis(
            jnp.broadcast_to(tab[None], (B_blk,) + tab.shape),
            addr[..., None], axis=-1)[..., 0]
        words = _pack_words(out_bits, B_blk)
    mask = cls_ref[...]                              # (classes, W)
    counts = masked_group_counts(words, mask)
    # masked popcount tail: internally-padded rows emit zero counts
    # (and idx 0) instead of whatever the zero-padded features encode to
    counts = jnp.where(_row_mask(pl.program_id(0), B_blk, total_b),
                       counts, 0.0)
    counts_ref[...] = counts
    idx_ref[...] = _first_argmax(counts)[:, None]


@functools.partial(jax.jit, static_argnames=("num_layers", "block_b",
                                             "interpret"))
def fused_dwn_packed(x: jax.Array, thresholds: jax.Array,
                     layer_arrays: tuple, class_masks: jax.Array, *,
                     num_layers: int, block_b: int = 256,
                     interpret: bool = False):
    """Whole-model packed inference in ONE pallas_call.

    x (B, F); thresholds (F, T) with F*T a 32-multiple; layer_arrays a
    flat tuple (widx_0, boff_0, tab_0, widx_1, ...) with every m_l a
    32-multiple; class_masks (classes, W_last) uint32.
    Returns (counts (B, classes) f32, idx (B,) i32).  Any B works: the
    batch pads internally to a ``block_b`` multiple, padded rows popcount
    to zero under the row mask, and the tail is sliced off.
    """
    B, F = x.shape
    T = thresholds.shape[1]
    assert (F * T) % WORD_BITS == 0, (F, T)
    assert len(layer_arrays) == 3 * num_layers
    classes, W_last = class_masks.shape
    bb = min(block_b, B)
    Bp = _round_up(B, bb)
    xp = jnp.pad(x, ((0, Bp - B), (0, 0)))
    kernel = functools.partial(_fused_packed_kernel, num_layers=num_layers,
                               total_b=B)
    in_specs = [
        pl.BlockSpec((bb, F), lambda i: (i, 0)),
        pl.BlockSpec((F, T), lambda i: (0, 0)),
    ]
    for arr in layer_arrays:
        in_specs.append(pl.BlockSpec(
            arr.shape, lambda i, nd=arr.ndim: (0,) * nd))
    in_specs.append(pl.BlockSpec((classes, W_last), lambda i: (0, 0)))
    counts, idx = pl.pallas_call(
        kernel,
        grid=(Bp // bb,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((bb, classes), lambda i: (i, 0)),
            pl.BlockSpec((bb, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, classes), jnp.float32),
            jax.ShapeDtypeStruct((Bp, 1), jnp.int32),
        ],
        interpret=interpret,
    )(xp, thresholds, *layer_arrays, class_masks)
    return counts[:B], idx[:B, 0]


def _fused_bm_kernel(x_ref, wf_ref, wth_ref, tab0_ref, *refs,
                     num_layers: int, num_classes: int, total_b: int):
    # refs: per *extra* layer (widx, boff, tab), then class masks (only
    # when num_layers > 1), then counts_ref, idx_ref.
    k = 3 * (num_layers - 1)
    counts_ref = refs[k + (1 if num_layers > 1 else 0)]
    idx_ref = refs[k + (2 if num_layers > 1 else 1)]
    x = x_ref[...]                                   # (rows, F)
    rows = x.shape[0]
    wf = wf_ref[...]                                 # (m0, n) i32 feature
    wth = wth_ref[...]                               # (m0, n) f32 threshold
    m0, n = wf.shape
    # direct-wire encode: gather the wired feature per LUT input and
    # compare against that wire's threshold — m0*n compares instead of
    # F*T compares + a full pack + word addressing
    xg = jnp.take(x, wf.reshape(-1), axis=-1)        # (rows, m0*n)
    sel = (xg > wth.reshape(-1)[None]).astype(jnp.int32)
    addr = lut_addresses(sel.reshape(rows, m0, n))   # (rows, m0)
    tab0 = tab0_ref[...]                             # (m0, 2^n) i32
    out_bits = jnp.take_along_axis(
        jnp.broadcast_to(tab0[None], (rows,) + tab0.shape),
        addr[..., None], axis=-1)[..., 0]            # (rows, m0) i32
    if num_layers == 1:
        # contiguous class groups (group_masks semantics): plain VPU
        # group-sum, no packed word ever materialized
        g = m0 // num_classes
        counts = out_bits.reshape(rows, num_classes, g).sum(
            axis=-1).astype(jnp.float32)
    else:
        words = _pack_words(out_bits, rows)
        for l in range(num_layers - 1):
            widx = refs[3 * l][...]
            boff = refs[3 * l + 1][...]
            tab = refs[3 * l + 2][...]
            s = select_packed_bits(words, widx, boff)
            a = lut_addresses(s)
            ob = jnp.take_along_axis(
                jnp.broadcast_to(tab[None], (rows,) + tab.shape),
                a[..., None], axis=-1)[..., 0]
            words = _pack_words(ob, rows)
        counts = masked_group_counts(words, refs[k][...])
    counts = jnp.where(_row_mask(pl.program_id(0), rows, total_b),
                       counts, 0.0)
    counts_ref[...] = counts
    idx_ref[...] = _first_argmax(counts)[:, None]


@functools.partial(jax.jit, static_argnames=("num_layers", "num_classes",
                                             "block_b", "interpret"))
def fused_dwn_batch_major(x: jax.Array, wire_f: jax.Array,
                          wire_th: jax.Array, table0: jax.Array,
                          layer_arrays: tuple, class_masks, *,
                          num_layers: int, num_classes: int,
                          block_b: int = 256, interpret: bool = False):
    """Batch-major direct-wire fused inference in ONE pallas_call.

    x (B, F); wire_f / wire_th (m0, n): the feature index and threshold
    value of every first-layer LUT input wire (``ops.py`` derives them
    from ``mappings[0]`` and the threshold bank); table0 (m0, 2^n) i32.
    ``layer_arrays`` holds (widx, boff, tab) triples for layers 1.. and
    ``class_masks`` the (classes, W_last) uint32 masks — both empty/None
    for single-layer models, where no packed word is ever built and no
    32-multiple constraint exists.  Grid is over sample tiles only: one
    step runs ``block_b`` samples through the whole model.  Returns
    (counts (B, classes) f32, idx (B,) i32); any B works (internal pad +
    row-masked popcount).
    """
    B, F = x.shape
    m0, n = wire_f.shape
    assert len(layer_arrays) == 3 * (num_layers - 1)
    if num_layers == 1:
        assert m0 % num_classes == 0, (m0, num_classes)
    bb = min(block_b, B)
    Bp = _round_up(B, bb)
    xp = jnp.pad(x, ((0, Bp - B), (0, 0)))
    kernel = functools.partial(_fused_bm_kernel, num_layers=num_layers,
                               num_classes=num_classes, total_b=B)
    A = table0.shape[1]
    in_specs = [
        pl.BlockSpec((bb, F), lambda i: (i, 0)),
        pl.BlockSpec((m0, n), lambda i: (0, 0)),
        pl.BlockSpec((m0, n), lambda i: (0, 0)),
        pl.BlockSpec((m0, A), lambda i: (0, 0)),
    ]
    operands = [xp, wire_f, wire_th, table0]
    for arr in layer_arrays:
        in_specs.append(pl.BlockSpec(
            arr.shape, lambda i, nd=arr.ndim: (0,) * nd))
        operands.append(arr)
    if num_layers > 1:
        classes, W_last = class_masks.shape
        in_specs.append(pl.BlockSpec((classes, W_last), lambda i: (0, 0)))
        operands.append(class_masks)
    counts, idx = pl.pallas_call(
        kernel,
        grid=(Bp // bb,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((bb, num_classes), lambda i: (i, 0)),
            pl.BlockSpec((bb, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, num_classes), jnp.float32),
            jax.ShapeDtypeStruct((Bp, 1), jnp.int32),
        ],
        interpret=interpret,
    )(*operands)
    return counts[:B], idx[:B, 0]
