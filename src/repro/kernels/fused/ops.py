"""Public op wrappers for the fused DWN-accelerator kernels.

``make_forward_packed`` is the serving entry point: it hoists all
batch-independent operand prep out of the per-call path and returns a
closure running one of the fused kernel variants.  Which variant and
which block shapes come from an optional
:class:`repro.kernels.autotune.FusedConfig` — the autotuner sweeps
(variant, rows-per-step) per (spec, bucket, device) and persists the
winner; with no config the historical defaults apply.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.bitpack import WORD_BITS, group_masks
from ..lut_eval.ref import selection_onehot
from ..lut_eval.ops import packed_wire_indices
from .kernel import fused_dwn, fused_dwn_packed, fused_dwn_batch_major
from .ref import fused_dwn_ref, fused_dwn_packed_ref


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def forward(x: jax.Array, thresholds: jax.Array, mapping: jax.Array,
            tables: jax.Array, num_classes: int, *,
            interpret: bool | None = None, config=None):
    """Whole-accelerator DWN inference: features -> (counts, argmax).

    The first-argmax prediction is emitted in-kernel (ties -> lower
    class index), so callers never re-derive it.  ``config`` (a
    ``FusedConfig``) overrides the (block_b, block_m) tile shapes.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, F = x.shape
    T = thresholds.shape[1]
    m, n = mapping.shape
    g = m // num_classes
    block_b = config.block_b if config is not None else 256
    block_m = config.block_m if config is not None else 128
    Tp = _round_up(T, 128)
    bb = min(block_b, _round_up(B, 8))
    bm = min(block_m, _round_up(m, 8))
    mp = _round_up(m, bm)
    thp = jnp.pad(thresholds, ((0, 0), (0, Tp - T)), constant_values=jnp.inf)
    # selection over the padded bit layout (F, Tp)
    f_of = mapping // T
    t_of = mapping % T
    mapping_p = f_of * Tp + t_of
    sel = selection_onehot(mapping_p, F * Tp)
    sel = jnp.pad(sel, ((0, 0), (0, (mp - m) * n)))
    tabs = jnp.pad(tables.astype(jnp.float32), ((0, mp - m), (0, 0)))
    cls = jax.nn.one_hot(jnp.arange(m) // g, num_classes, dtype=jnp.float32)
    cls = jnp.pad(cls, ((0, mp - m), (0, 0)))        # padded LUTs count 0
    return fused_dwn(x, thp, sel, tabs, cls, fan_in=n, block_b=bb,
                     block_m=bm, interpret=interpret)


def _packed_layer_arrays(mappings, tables):
    """32-multiple-padded (widx, boff, tab) triples (all-zero pad LUTs)."""
    arrays = []
    for mp_arr, tb in zip(mappings, tables):
        m, n = mp_arr.shape
        mp = _round_up(m, WORD_BITS)
        widx, boff = packed_wire_indices(mp_arr)
        arrays += [
            jnp.pad(widx, ((0, mp - m), (0, 0))),
            jnp.pad(boff, ((0, mp - m), (0, 0))),
            jnp.pad(jnp.asarray(tb, jnp.int32), ((0, mp - m), (0, 0))),
        ]
    return tuple(arrays)


def make_forward_packed(thresholds: jax.Array, mappings, tables,
                        num_classes: int, *,
                        interpret: bool | None = None, config=None):
    """Build ``fn(x) -> (counts, argmax)`` with operand prep done once.

    Hoists everything batch-independent out of the per-call path: wire
    indices, layer padding, class masks.  The serving backends call this
    once per (model, tuned config) and reuse the closure across batches;
    ``forward_packed`` below stays as the one-shot convenience wrapper.

    Args:
      config: optional ``repro.kernels.autotune.FusedConfig`` selecting
        the kernel variant and rows-per-grid-step:

        * ``variant="packed"`` (default): encode packs the full F*T bit
          tensor to uint32 words in VMEM, then word-addressed LUT layers
          and a masked SWAR popcount.  Requires F*T to be a 32-multiple
          (true for all JSC presets: 16*200); falls back to the jnp
          oracle otherwise.
        * ``variant="batch-major"``: direct-wire first layer — only the
          m*n wired bits are ever compared, single-layer models never
          build a packed word, and the grid is over sample tiles only.
          No F*T constraint.

    Batches of any size work: the kernels pad internally and mask the
    ragged tail, so callers need no bucket rounding.
    """
    if not isinstance(mappings, (list, tuple)):
        mappings, tables = [mappings], [tables]
    mappings, tables = list(mappings), list(tables)
    F, T = thresholds.shape
    num_layers = len(mappings)
    variant = config.variant if config is not None else "packed"
    block_b = config.block_b if config is not None else 256

    if variant == "batch-major":
        m0, n = mappings[0].shape
        mp0 = mappings[0]
        # wire operands: the feature index and threshold value of every
        # first-layer input wire (bit f*T + t  <=>  x[:, f] > th[f, t])
        wire_f = jnp.asarray(mp0, jnp.int32) // T
        wire_th = jnp.asarray(thresholds).reshape(-1)[
            jnp.asarray(mp0, jnp.int32)]
        tab0 = jnp.asarray(tables[0], jnp.int32)
        if num_layers > 1:
            # deeper stacks pack layer 0's outputs: pad m0 to a word
            # multiple with wires that always read 0 (+inf thresholds,
            # all-zero LUTs) so the zero-pad word invariant holds
            mp = _round_up(m0, WORD_BITS)
            wire_f = jnp.pad(wire_f, ((0, mp - m0), (0, 0)))
            wire_th = jnp.pad(wire_th, ((0, mp - m0), (0, 0)),
                              constant_values=jnp.inf)
            tab0 = jnp.pad(tab0, ((0, mp - m0), (0, 0)))
            rest = _packed_layer_arrays(mappings[1:], tables[1:])
            masks = group_masks(mappings[-1].shape[0], num_classes)
        else:
            rest, masks = (), None

        def fn(x: jax.Array):
            interp = interpret
            if interp is None:
                interp = jax.default_backend() != "tpu"
            return fused_dwn_batch_major(
                x, wire_f, wire_th, tab0, rest, masks,
                num_layers=num_layers, num_classes=num_classes,
                block_b=block_b, interpret=interp)
        return fn

    if (F * T) % WORD_BITS != 0:
        def fallback(x: jax.Array):
            return fused_dwn_packed_ref(x, thresholds, mappings, tables,
                                        num_classes)
        return fallback

    layer_arrays = _packed_layer_arrays(mappings, tables)
    masks = group_masks(mappings[-1].shape[0], num_classes)

    def fn(x: jax.Array):
        interp = interpret
        if interp is None:
            interp = jax.default_backend() != "tpu"
        return fused_dwn_packed(x, thresholds, layer_arrays, masks,
                                num_layers=num_layers, block_b=block_b,
                                interpret=interp)
    return fn


def forward_packed(x: jax.Array, thresholds: jax.Array, mappings, tables,
                   num_classes: int, *, interpret: bool | None = None,
                   config=None):
    """Whole-accelerator packed DWN inference: features -> (counts, argmax).

    The serving fast path: one fused pallas_call runs encode -> every LUT
    layer -> group popcount with all bit tensors VMEM-resident.  One-shot
    wrapper over :func:`make_forward_packed`.
    """
    return make_forward_packed(thresholds, mappings, tables, num_classes,
                               interpret=interpret, config=config)(x)


__all__ = ["forward", "forward_packed", "make_forward_packed",
           "fused_dwn_ref", "fused_dwn_packed_ref"]
