"""Public op wrapper for the fused DWN-accelerator kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.bitpack import WORD_BITS, group_masks
from ..lut_eval.ref import selection_onehot
from ..lut_eval.ops import packed_wire_indices
from .kernel import fused_dwn, fused_dwn_packed
from .ref import fused_dwn_ref, fused_dwn_packed_ref


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def forward(x: jax.Array, thresholds: jax.Array, mapping: jax.Array,
            tables: jax.Array, num_classes: int, *,
            interpret: bool | None = None) -> jax.Array:
    """Whole-accelerator DWN inference: features -> class counts."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, F = x.shape
    T = thresholds.shape[1]
    m, n = mapping.shape
    g = m // num_classes
    Tp = _round_up(T, 128)
    bb = min(256, _round_up(B, 8))
    Bp = _round_up(B, bb)
    bm = min(128, _round_up(m, 8))
    mp = _round_up(m, bm)
    xp = jnp.pad(x, ((0, Bp - B), (0, 0)))
    thp = jnp.pad(thresholds, ((0, 0), (0, Tp - T)), constant_values=jnp.inf)
    # selection over the padded bit layout (F, Tp)
    f_of = mapping // T
    t_of = mapping % T
    mapping_p = f_of * Tp + t_of
    sel = selection_onehot(mapping_p, F * Tp)
    sel = jnp.pad(sel, ((0, 0), (0, (mp - m) * n)))
    tabs = jnp.pad(tables.astype(jnp.float32), ((0, mp - m), (0, 0)))
    cls = jax.nn.one_hot(jnp.arange(m) // g, num_classes, dtype=jnp.float32)
    cls = jnp.pad(cls, ((0, mp - m), (0, 0)))        # padded LUTs count 0
    counts = fused_dwn(xp, thp, sel, tabs, cls, fan_in=n, block_b=bb,
                       block_m=bm, interpret=interpret)
    return counts[:B]


def make_forward_packed(thresholds: jax.Array, mappings, tables,
                        num_classes: int, *,
                        interpret: bool | None = None):
    """Build ``fn(x) -> (counts, argmax)`` with operand prep done once.

    Hoists everything batch-independent out of the per-call path: wire
    indices, 32-multiple layer padding with all-zero LUTs, and the class
    masks built from the *logical* final width so padding never
    mis-counts.  The serving backends call this once per model and reuse
    the closure across every batch bucket; ``forward_packed`` below stays
    as the one-shot convenience wrapper.

    Requires F*T to be a 32-multiple (true for all JSC presets: 16*200);
    falls back to the jnp oracle otherwise.
    """
    if not isinstance(mappings, (list, tuple)):
        mappings, tables = [mappings], [tables]
    mappings, tables = list(mappings), list(tables)
    F, T = thresholds.shape
    if (F * T) % WORD_BITS != 0:
        def fallback(x: jax.Array):
            return fused_dwn_packed_ref(x, thresholds, mappings, tables,
                                        num_classes)
        return fallback

    layer_arrays = []
    for mp_arr, tb in zip(mappings, tables):
        m, n = mp_arr.shape
        mp = _round_up(m, WORD_BITS)
        widx, boff = packed_wire_indices(mp_arr)
        layer_arrays += [
            jnp.pad(widx, ((0, mp - m), (0, 0))),
            jnp.pad(boff, ((0, mp - m), (0, 0))),
            jnp.pad(jnp.asarray(tb, jnp.int32), ((0, mp - m), (0, 0))),
        ]
    layer_arrays = tuple(layer_arrays)
    m_last = mappings[-1].shape[0]
    masks = group_masks(m_last, num_classes)
    num_layers = len(mappings)

    def fn(x: jax.Array):
        interp = interpret
        if interp is None:
            interp = jax.default_backend() != "tpu"
        B = x.shape[0]
        bb = min(256, _round_up(B, 8))
        Bp = _round_up(B, bb)
        xp = jnp.pad(x, ((0, Bp - B), (0, 0)))
        counts, idx = fused_dwn_packed(xp, thresholds, layer_arrays,
                                       masks, num_layers=num_layers,
                                       block_b=bb, interpret=interp)
        return counts[:B], idx[:B]
    return fn


def forward_packed(x: jax.Array, thresholds: jax.Array, mappings, tables,
                   num_classes: int, *, interpret: bool | None = None):
    """Whole-accelerator packed DWN inference: features -> (counts, argmax).

    The serving fast path: one fused pallas_call runs encode -> every LUT
    layer -> group popcount with all bit tensors packed uint32 and
    VMEM-resident.  One-shot wrapper over :func:`make_forward_packed`.
    """
    return make_forward_packed(thresholds, mappings, tables, num_classes,
                               interpret=interpret)(x)


__all__ = ["forward", "forward_packed", "make_forward_packed",
           "fused_dwn_ref", "fused_dwn_packed_ref"]
