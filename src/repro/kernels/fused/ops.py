"""Public op wrapper for the fused DWN-accelerator kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..lut_eval.ref import selection_onehot
from .kernel import fused_dwn
from .ref import fused_dwn_ref


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def forward(x: jax.Array, thresholds: jax.Array, mapping: jax.Array,
            tables: jax.Array, num_classes: int, *,
            interpret: bool | None = None) -> jax.Array:
    """Whole-accelerator DWN inference: features -> class counts."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, F = x.shape
    T = thresholds.shape[1]
    m, n = mapping.shape
    g = m // num_classes
    Tp = _round_up(T, 128)
    bb = min(256, _round_up(B, 8))
    Bp = _round_up(B, bb)
    bm = min(128, _round_up(m, 8))
    mp = _round_up(m, bm)
    xp = jnp.pad(x, ((0, Bp - B), (0, 0)))
    thp = jnp.pad(thresholds, ((0, 0), (0, Tp - T)), constant_values=jnp.inf)
    # selection over the padded bit layout (F, Tp)
    f_of = mapping // T
    t_of = mapping % T
    mapping_p = f_of * Tp + t_of
    sel = selection_onehot(mapping_p, F * Tp)
    sel = jnp.pad(sel, ((0, 0), (0, (mp - m) * n)))
    tabs = jnp.pad(tables.astype(jnp.float32), ((0, mp - m), (0, 0)))
    cls = jax.nn.one_hot(jnp.arange(m) // g, num_classes, dtype=jnp.float32)
    cls = jnp.pad(cls, ((0, mp - m), (0, 0)))        # padded LUTs count 0
    counts = fused_dwn(xp, thp, sel, tabs, cls, fan_in=n, block_b=bb,
                       block_m=bm, interpret=interpret)
    return counts[:B]


__all__ = ["forward", "fused_dwn_ref"]
