"""Persistent per-(arch, bucket, device) autotuner for the fused kernels.

The fused DWN datapath has real shape knobs — which kernel variant
(``packed`` full-bit-tensor vs ``batch-major`` direct-wire) and how many
sample rows one grid step processes — and the winner is *size dependent*:
``BENCH_serve.json`` history shows the packed layout winning at lg-2400
while small presets drown in per-bit overhead.  Instead of hardcoding,
this module times the candidate configs on probe rows and persists the
winner in a JSON cache, keyed exactly like the sweep result cache
(``repro.sweep.cache``): a content fingerprint of the thing being tuned
(the ``DWNSpec`` fingerprint), the batch bucket, the device kind, and a
source fingerprint of the kernel modules — editing the kernels
invalidates stale configs instead of silently serving them.

Cache location: ``$REPRO_AUTOTUNE_CACHE`` if set, else
``~/.cache/repro/autotune/fused_configs.json`` (next to where the sweep
compile cache lives by convention).  A corrupt or absent cache file is a
miss, never an error — consumers fall back to the default blocks.

The timing loop is deliberately tiny and injectable (``timer=``) so the
tuner is deterministic under a stubbed clock in tests.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from pathlib import Path

import jax
import jax.numpy as jnp


#: kernel variants the tuner may select (see ``fused/ops.py``).
VARIANTS = ("packed", "batch-major")


@dataclasses.dataclass(frozen=True)
class FusedConfig:
    """One point in the fused-kernel tuning space.

    Attributes:
      variant: "packed" (full bit tensor in uint32 words) or
        "batch-major" (direct-wire first layer, grid over sample tiles).
      block_b: sample rows processed per grid step.
      block_m: m-tile width — used only by the *float* fused kernel
        (``ops.forward``); the packed variants keep the whole model
        state resident per step.
    """

    variant: str = "packed"
    block_b: int = 256
    block_m: int = 128

    def __post_init__(self):
        assert self.variant in VARIANTS, self.variant

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "FusedConfig":
        return cls(**{k: d[k] for k in ("variant", "block_b", "block_m")
                      if k in d})

    @property
    def label(self) -> str:
        return f"{self.variant}/b{self.block_b}"


#: what an untuned model serves with — the historical hardcoded blocks.
DEFAULT_CONFIG = FusedConfig()


# ---------------------------------------------------------------------------
# fingerprints and keys
# ---------------------------------------------------------------------------

_FP: str | None = None


def kernel_fingerprint() -> str:
    """Source hash of the modules whose edits change kernel numbers.

    Same invalidation scheme as ``repro.sweep.cache._code_fingerprint``:
    cached configs were tuned against those kernels, so editing them must
    invalidate, not silently serve, stale block shapes.
    """
    global _FP
    if _FP is not None:
        return _FP
    from .fused import kernel as m1, ops as m2
    from ..core import bitpack as m3
    h = hashlib.sha256()
    for mod in (m1, m2, m3):
        try:
            with open(mod.__file__, "rb") as fh:
                h.update(fh.read())
        except OSError:
            h.update(mod.__name__.encode())
    _FP = h.hexdigest()[:16]
    return _FP


def device_kind() -> str:
    """Platform string the timings are valid on (tunings don't transfer
    between a real TPU and the CPU interpret-mode emulation)."""
    platform = jax.devices()[0].platform
    return platform if platform == "tpu" else f"{platform}-interpret"


def cache_key(spec_fingerprint: str, bucket: int,
              device: str | None = None) -> str:
    return f"{spec_fingerprint}:{bucket}:{device or device_kind()}"


def default_cache_path() -> Path:
    env = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if env:
        return Path(env)
    return Path.home() / ".cache" / "repro" / "autotune" / \
        "fused_configs.json"


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------

class AutotuneCache:
    """JSON-file cache of winning :class:`FusedConfig` per cache key.

    One flat file (atomic-rename writes) holding every tuned entry::

        {"entries": {"<spec_fp>:<bucket>:<device>": {
            "code": "<kernel fingerprint at tune time>",
            "config": {"variant": ..., "block_b": ..., "block_m": ...},
            "timings_us": {"packed/b64": 812.3, ...}}}}

    ``get`` misses (returns None) when the file is absent/corrupt or the
    stored ``code`` no longer matches :func:`kernel_fingerprint` — the
    caller re-tunes or falls back to :data:`DEFAULT_CONFIG`.
    """

    def __init__(self, path: str | Path | None = None):
        self.path = Path(path) if path is not None else default_cache_path()
        self._entries: dict | None = None

    def _load(self) -> dict:
        if self._entries is None:
            try:
                with open(self.path) as fh:
                    data = json.load(fh)
                self._entries = dict(data.get("entries", {}))
            except (OSError, json.JSONDecodeError, AttributeError):
                self._entries = {}
        return self._entries

    def get(self, spec_fingerprint: str, bucket: int,
            device: str | None = None) -> FusedConfig | None:
        entry = self._load().get(cache_key(spec_fingerprint, bucket, device))
        if not entry or entry.get("code") != kernel_fingerprint():
            return None
        try:
            return FusedConfig.from_dict(entry["config"])
        except (KeyError, TypeError, AssertionError):
            return None

    def put(self, spec_fingerprint: str, bucket: int, config: FusedConfig,
            timings_us: dict[str, float] | None = None,
            device: str | None = None) -> None:
        entries = self._load()
        entries[cache_key(spec_fingerprint, bucket, device)] = {
            "code": kernel_fingerprint(),
            "config": config.to_dict(),
            "timings_us": {k: round(v, 1)
                           for k, v in (timings_us or {}).items()},
        }
        self.path.parent.mkdir(parents=True, exist_ok=True)
        tmp = self.path.with_suffix(".tmp")
        with open(tmp, "w") as fh:
            json.dump({"entries": entries}, fh, indent=1, sort_keys=True)
        tmp.replace(self.path)


# ---------------------------------------------------------------------------
# timing + tuning
# ---------------------------------------------------------------------------

def time_step(fn, x, *, iters: int = 3, timer=time.perf_counter,
              min_time_s: float = 0.0, max_iters: int = 50) -> float:
    """Best-of-``iters`` seconds of ``fn(x)`` after one untimed warmup.

    The warmup call absorbs the compile, so the measurement sees
    steady-state serving — the same protocol as
    ``serving.backends.time_backend_step`` (which delegates here).

    ``min_time_s > 0`` keeps sampling past ``iters`` (up to
    ``max_iters``) until that much measured time has accumulated:
    microsecond-scale steps get tens of reps — without it, scheduler
    jitter at small buckets swamps the real spread between candidates —
    while millisecond-scale steps stop at ``iters``.
    """
    jax.block_until_ready(fn(x))
    best, total, n = float("inf"), 0.0, 0
    while n < max(1, iters) or (total < min_time_s and n < max_iters):
        t0 = timer()
        jax.block_until_ready(fn(x))
        dt = timer() - t0
        best = min(best, dt)
        total += dt
        n += 1
    return best


def candidate_configs(bucket: int) -> list[FusedConfig]:
    """The (variant, rows-per-step) sweep for one batch bucket.

    Both variants at the full bucket (one grid step per call) and, when
    the bucket is large enough to split, at half — kept deliberately
    small so startup tuning stays cheap; the cache amortizes it to zero
    on later runs.
    """
    rows = [bucket]
    if bucket >= 16:
        rows.append(bucket // 2)
    return [FusedConfig(variant=v, block_b=r)
            for v in VARIANTS for r in rows]


def tune_fused(thresholds, mappings, tables, num_classes: int, x_probe, *,
               spec_fingerprint: str, input_frac_bits: int | None = None,
               cache: AutotuneCache | None = None,
               candidates: list[FusedConfig] | None = None,
               iters: int = 2, timer=time.perf_counter,
               min_time_s: float = 0.0,
               interpret: bool | None = None,
               force: bool = False) -> FusedConfig:
    """Pick (and persist) the fastest fused config for one bucket.

    Args:
      thresholds/mappings/tables/num_classes: the packed model operands,
        exactly as ``serving.backends.DWNModelBundle`` stages them.
      x_probe: (bucket, F) probe rows; the bucket is its leading dim.
      spec_fingerprint: ``DWNSpec.fingerprint()`` of the served model —
        the cache identity.
      input_frac_bits: PEN input quantization (None = TEN), applied
        before the kernel exactly like the serving backend does.
      cache: config cache (None = default path); hits skip timing.
      candidates: explicit sweep list (default
        :func:`candidate_configs`).
      iters / timer / min_time_s: timing knobs, injectable for
        deterministic tests (see :func:`time_step`).
      force: re-tune even on a cache hit.

    Returns the winning config (cached or freshly timed).  A candidate
    that fails to build/run is skipped, so a bad variant can never brick
    startup; if every candidate fails, :data:`DEFAULT_CONFIG` wins.
    """
    from .fused import ops as fused_ops
    from ..core.thermometer import quantize_fixed_point

    bucket = int(x_probe.shape[0])
    cache = cache if cache is not None else AutotuneCache()
    if not force:
        hit = cache.get(spec_fingerprint, bucket)
        if hit is not None:
            return hit
    cands = candidates if candidates is not None \
        else candidate_configs(bucket)
    x = jnp.asarray(x_probe)
    if input_frac_bits is not None:
        x = quantize_fixed_point(x, input_frac_bits)
    timings: dict[str, float] = {}
    best_cfg, best_t = None, float("inf")
    for cfg in cands:
        try:
            fwd = fused_ops.make_forward_packed(
                thresholds, mappings, tables, num_classes,
                interpret=interpret, config=cfg)
            t = time_step(fwd, x, iters=iters, timer=timer,
                          min_time_s=min_time_s)
        except Exception:                      # noqa: BLE001 — skip, don't brick
            continue
        timings[cfg.label] = t * 1e6
        if t < best_t:
            best_cfg, best_t = cfg, t
    if best_cfg is None:
        return DEFAULT_CONFIG
    cache.put(spec_fingerprint, bucket, best_cfg, timings)
    return best_cfg


__all__ = [
    "AutotuneCache", "DEFAULT_CONFIG", "FusedConfig", "VARIANTS",
    "cache_key", "candidate_configs", "default_cache_path", "device_kind",
    "kernel_fingerprint", "time_step", "tune_fused",
]
