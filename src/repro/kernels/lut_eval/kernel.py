"""Pallas TPU kernel: DWN LUT-layer evaluation.

FPGA -> TPU adaptation (DESIGN.md §3).  Two stages fused in one kernel,
both operands resident in VMEM:

  stage A (MXU): the learned sparse wiring is a gather on FPGA; on TPU we
  recast it as a dense matmul with the one-hot selection matrix:
      sel (B_blk, mn_blk) = bits (B_blk, C) @ onehot (C, mn_blk)

  stage B (VPU): LUT read without gather — the truth-table read at a
  binary address equals the multilinear corner expansion
      out[b,l] = sum_a table[l,a] * prod_i (s_i if bit_i(a) else 1-s_i)
  evaluated with 6 fused multiplies over the (B_blk, m_blk, 64) tile.

Grid: (B / B_blk, m / m_blk).  The MXU matmul dims are 128-aligned by
ops.py padding; fan_in n is a compile-time constant (6 for LUT6).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core.bitpack import WORD_BITS, select_packed_bits, lut_addresses
from ..thermometer.kernel import _pack_words


def _lut_eval_kernel(bits_ref, sel_ref, tab_ref, out_ref, *, fan_in: int):
    bits = bits_ref[...]                              # (B_blk, C)
    sel = sel_ref[...]                                # (C, m_blk*n)
    tab = tab_ref[...]                                # (m_blk, 2^n)
    B_blk = bits.shape[0]
    mn = sel.shape[1]
    m_blk = mn // fan_in
    A = 2 ** fan_in
    # stage A: one-hot selection matmul (MXU)
    s = jnp.dot(bits, sel, preferred_element_type=jnp.float32)
    s = s.reshape(B_blk, m_blk, fan_in)
    # stage B: corner-product table evaluation (VPU)
    w = jnp.ones((B_blk, m_blk, A), jnp.float32)
    for i in range(fan_in):
        si = s[:, :, i:i + 1]                         # (B_blk, m_blk, 1)
        corner_i = ((jnp.arange(A, dtype=jnp.int32) >> i) & 1).astype(
            jnp.float32)                              # (A,)
        w = w * (si * corner_i + (1.0 - si) * (1.0 - corner_i))
    out_ref[...] = jnp.sum(w * tab[None].astype(jnp.float32), axis=-1)


@functools.partial(jax.jit, static_argnames=("fan_in", "block_b", "block_m",
                                             "interpret"))
def lut_eval(bits: jax.Array, sel_onehot: jax.Array, tables: jax.Array, *,
             fan_in: int = 6, block_b: int = 256, block_m: int = 128,
             interpret: bool = False) -> jax.Array:
    """bits (B, C); sel_onehot (C, m*n); tables (m, 2^n) -> (B, m) f32."""
    B, C = bits.shape
    m = tables.shape[0]
    A = 2 ** fan_in
    assert sel_onehot.shape == (C, m * fan_in), sel_onehot.shape
    bb, bm = min(block_b, B), min(block_m, m)
    assert B % bb == 0 and m % bm == 0, (B, m, bb, bm)
    grid = (B // bb, m // bm)
    kernel = functools.partial(_lut_eval_kernel, fan_in=fan_in)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, C), lambda i, j: (i, 0)),
            pl.BlockSpec((C, bm * fan_in), lambda i, j: (0, j)),
            pl.BlockSpec((bm, A), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bb, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, m), jnp.float32),
        interpret=interpret,
    )(bits, sel_onehot, tables)


def _lut_eval_packed_kernel(words_ref, widx_ref, boff_ref, tab_ref, out_ref):
    # words: (B_blk, W_in) uint32; widx/boff: (m, n) i32; tab: (m, 2^n) i32
    # {0,1}; out: (B_blk, m/32) uint32.  Addresses are formed with
    # shift/AND on the packed words (core.bitpack helpers — the addressing
    # convention lives once) — no one-hot matmul, no float bits.
    words = words_ref[...]
    widx = widx_ref[...]
    boff = boff_ref[...]
    tab = tab_ref[...]
    B_blk = words.shape[0]
    sel = select_packed_bits(words, widx, boff)
    addr = lut_addresses(sel)                                # (B_blk, m)
    out_bits = jnp.take_along_axis(
        jnp.broadcast_to(tab[None], (B_blk,) + tab.shape),
        addr[..., None], axis=-1)[..., 0]                    # (B_blk, m)
    out_ref[...] = _pack_words(out_bits, B_blk)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def lut_eval_packed(words: jax.Array, word_idx: jax.Array,
                    bit_off: jax.Array, tables: jax.Array, *,
                    block_b: int = 256, interpret: bool = False) -> jax.Array:
    """words (B, W_in) uint32; word_idx/bit_off (m, n) i32; tables (m, 2^n)
    i32 {0,1} -> packed layer output (B, m/32) uint32.  m must be a
    32-multiple (ops.py pads with zero-table LUTs)."""
    B = words.shape[0]
    W_in = words.shape[1]
    m, n = word_idx.shape
    A = tables.shape[1]
    assert m % WORD_BITS == 0, m
    bb = min(block_b, B)
    assert B % bb == 0, (B, bb)
    return pl.pallas_call(
        _lut_eval_packed_kernel,
        grid=(B // bb,),
        in_specs=[
            pl.BlockSpec((bb, W_in), lambda i: (i, 0)),
            pl.BlockSpec((m, n), lambda i: (0, 0)),
            pl.BlockSpec((m, n), lambda i: (0, 0)),
            pl.BlockSpec((m, A), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, m // WORD_BITS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, m // WORD_BITS), jnp.uint32),
        interpret=interpret,
    )(words, word_idx, bit_off, tables)
