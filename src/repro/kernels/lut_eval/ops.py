"""Public op wrapper for the LUT-eval kernel (padding + backend switch)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.bitpack import WORD_BITS, PackedBits
from .kernel import lut_eval, lut_eval_packed
from .ref import lut_eval_ref, lut_eval_packed_ref, selection_onehot


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def evaluate(bits: jax.Array, mapping: jax.Array, tables: jax.Array, *,
             interpret: bool | None = None) -> jax.Array:
    """Hard LUT-layer inference via the Pallas kernel.

    bits (B, C) {0,1}; mapping (m, n) int32; tables (m, 2^n) {0,1}.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, C = bits.shape
    m, n = mapping.shape
    bb = min(256, _round_up(B, 8))
    Bp = _round_up(B, bb)
    bm = min(128, _round_up(m, 8))
    mp = _round_up(m, bm)
    Cp = _round_up(C, 128)
    sel = selection_onehot(mapping, C)                       # (C, m*n)
    sel = jnp.pad(sel, ((0, Cp - C), (0, (mp - m) * n)))
    bitsp = jnp.pad(bits.astype(jnp.float32), ((0, Bp - B), (0, Cp - C)))
    tabsp = jnp.pad(tables.astype(jnp.float32), ((0, mp - m), (0, 0)))
    out = lut_eval(bitsp, sel, tabsp, fan_in=n, block_b=bb, block_m=bm,
                   interpret=interpret)
    return out[:B, :m]


def packed_wire_indices(mapping: jax.Array):
    """(m, n) logical bit indices -> (word_idx, bit_off) per the bitpack
    convention: word ``idx >> 5``, LSB-first position ``idx & 31``."""
    mapping = jnp.asarray(mapping, jnp.int32)
    return jnp.right_shift(mapping, 5), jnp.bitwise_and(mapping, 31)


def evaluate_packed(packed: PackedBits, mapping: jax.Array,
                    tables: jax.Array, *,
                    interpret: bool | None = None) -> PackedBits:
    """Hard LUT-layer inference on packed words via the Pallas kernel.

    packed: PackedBits of C candidate bits; mapping (m, n) int32 into the
    logical bit indices; tables (m, 2^n) {0,1}.  Pads B to a block
    multiple and m to a 32-multiple with all-zero LUTs (their output bits
    are 0, preserving the zero-pad invariant of the word format).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    words = packed.words
    B = words.shape[0]
    m, n = mapping.shape
    bb = min(256, _round_up(B, 8))
    Bp = _round_up(B, bb)
    mp = _round_up(m, WORD_BITS)
    widx, boff = packed_wire_indices(mapping)
    widx = jnp.pad(widx, ((0, mp - m), (0, 0)))
    boff = jnp.pad(boff, ((0, mp - m), (0, 0)))
    tabs = jnp.pad(tables.astype(jnp.int32), ((0, mp - m), (0, 0)))
    wordsp = jnp.pad(words, ((0, Bp - B), (0, 0)))
    out = lut_eval_packed(wordsp, widx, boff, tabs, block_b=bb,
                          interpret=interpret)
    return PackedBits(out[:B], m)


__all__ = ["evaluate", "evaluate_packed", "packed_wire_indices",
           "lut_eval_ref", "lut_eval_packed_ref", "selection_onehot"]
