"""Public op wrapper for the LUT-eval kernel (padding + backend switch)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import lut_eval
from .ref import lut_eval_ref, selection_onehot


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def evaluate(bits: jax.Array, mapping: jax.Array, tables: jax.Array, *,
             interpret: bool | None = None) -> jax.Array:
    """Hard LUT-layer inference via the Pallas kernel.

    bits (B, C) {0,1}; mapping (m, n) int32; tables (m, 2^n) {0,1}.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, C = bits.shape
    m, n = mapping.shape
    bb = min(256, _round_up(B, 8))
    Bp = _round_up(B, bb)
    bm = min(128, _round_up(m, 8))
    mp = _round_up(m, bm)
    Cp = _round_up(C, 128)
    sel = selection_onehot(mapping, C)                       # (C, m*n)
    sel = jnp.pad(sel, ((0, Cp - C), (0, (mp - m) * n)))
    bitsp = jnp.pad(bits.astype(jnp.float32), ((0, Bp - B), (0, Cp - C)))
    tabsp = jnp.pad(tables.astype(jnp.float32), ((0, mp - m), (0, 0)))
    out = lut_eval(bitsp, sel, tabsp, fan_in=n, block_b=bb, block_m=bm,
                   interpret=interpret)
    return out[:B, :m]


__all__ = ["evaluate", "lut_eval_ref", "selection_onehot"]
