"""Pure-jnp oracle for the LUT-layer evaluation kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def lut_eval_ref(bits: jax.Array, mapping: jax.Array,
                 tables: jax.Array) -> jax.Array:
    """bits (B, C) {0,1} f32; mapping (m, n) int32; tables (m, 2^n) {0,1}.

    Returns (B, m) f32 — identical semantics to core.lut_layer.lut_eval_hard.
    """
    B = bits.shape[0]
    m, n = mapping.shape
    sel = jnp.take(bits, mapping.reshape(-1), axis=1).reshape(B, m, n)
    weights = (2 ** jnp.arange(n, dtype=jnp.int32))
    addr = jnp.sum(sel.astype(jnp.int32) * weights, axis=-1)
    out = jnp.take_along_axis(
        jnp.broadcast_to(tables[None], (B,) + tables.shape), addr[..., None],
        axis=-1)[..., 0]
    return out.astype(jnp.float32)


def lut_eval_packed_ref(packed, mapping: jax.Array,
                        tables: jax.Array):
    """Packed oracle: unpack -> float oracle -> repack (PackedBits in/out)."""
    from ...core.bitpack import PackedBits
    bits = packed.unpack()
    out = lut_eval_ref(bits, mapping, tables)
    return PackedBits.pack(out)


def selection_onehot(mapping: jax.Array, num_candidates: int) -> jax.Array:
    """(m, n) wire indices -> (C, m*n) one-hot selection matrix (the
    'learned sparse wiring recast as a dense systolic matmul')."""
    m, n = mapping.shape
    flat = mapping.reshape(-1)                       # (m*n,)
    return jax.nn.one_hot(flat, num_candidates, dtype=jnp.float32).T
