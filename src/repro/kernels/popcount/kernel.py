"""Pallas TPU kernel: grouped popcount + argmax classification head.

FPGA -> TPU adaptation: the GPC compressor tree becomes a VPU group-sum
over the (B_blk, classes, group) VMEM tile; the argmax comparator tree
becomes a lane reduction.  Ties resolve to the lower class index (paper
§IV) via the standard max-then-first-index idiom.

Grid: (B / B_blk,).  One pass, bits never revisit HBM after the load.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core.bitpack import masked_group_counts


def _first_argmax(counts):
    """First index achieving the max (ties -> lower class index),
    max-then-first-index idiom; shared by all classifier kernels."""
    best = jnp.max(counts, axis=-1, keepdims=True)
    is_best = counts >= best
    return jnp.argmax(is_best.astype(jnp.int32), axis=-1).astype(jnp.int32)


def _popcount_kernel(bits_ref, counts_ref, idx_ref, *, num_classes: int):
    bits = bits_ref[...]                                 # (B_blk, m)
    B_blk, m = bits.shape
    g = m // num_classes
    counts = bits.reshape(B_blk, num_classes, g).sum(-1)  # f32
    counts_ref[...] = counts
    idx_ref[...] = _first_argmax(counts)[:, None]


@functools.partial(jax.jit, static_argnames=("num_classes", "block_b",
                                             "interpret"))
def popcount_classify(bits: jax.Array, num_classes: int, *,
                      block_b: int = 512, interpret: bool = False):
    """bits (B, m) {0,1} f32 -> (counts (B, classes) f32, idx (B, 1) i32)."""
    B, m = bits.shape
    assert m % num_classes == 0, (m, num_classes)
    bb = min(block_b, B)
    assert B % bb == 0, (B, bb)
    kernel = functools.partial(_popcount_kernel, num_classes=num_classes)
    counts, idx = pl.pallas_call(
        kernel,
        grid=(B // bb,),
        in_specs=[pl.BlockSpec((bb, m), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bb, num_classes), lambda i: (i, 0)),
            pl.BlockSpec((bb, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, num_classes), jnp.float32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
        ],
        interpret=interpret,
    )(bits)
    return counts, idx[:, 0]


def _popcount_packed_kernel(words_ref, mask_ref, counts_ref, idx_ref):
    # words: (B_blk, W) uint32 packed layer-output bits; mask: (classes, W)
    # uint32 class-group masks (word boundaries need not align with group
    # boundaries).  SWAR popcount per masked word, summed over W — the GPC
    # compressor tree on 32-bit lanes.
    words = words_ref[...]
    mask = mask_ref[...]
    counts = masked_group_counts(words, mask)                # (B_blk, C)
    counts_ref[...] = counts
    idx_ref[...] = _first_argmax(counts)[:, None]


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def popcount_classify_packed(words: jax.Array, class_masks: jax.Array, *,
                             block_b: int = 512, interpret: bool = False):
    """words (B, W) uint32; class_masks (classes, W) uint32 ->
    (counts (B, classes) f32, idx (B, 1) i32).  Ties -> lower class."""
    B, W = words.shape
    classes = class_masks.shape[0]
    bb = min(block_b, B)
    assert B % bb == 0, (B, bb)
    counts, idx = pl.pallas_call(
        _popcount_packed_kernel,
        grid=(B // bb,),
        in_specs=[
            pl.BlockSpec((bb, W), lambda i: (i, 0)),
            pl.BlockSpec((classes, W), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb, classes), lambda i: (i, 0)),
            pl.BlockSpec((bb, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, classes), jnp.float32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
        ],
        interpret=interpret,
    )(words, class_masks)
    return counts, idx[:, 0]
