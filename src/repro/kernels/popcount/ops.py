"""Public op wrapper for the popcount/classifier kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import popcount_classify
from .ref import popcount_ref, classify_ref


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def classify(bits: jax.Array, num_classes: int, *,
             interpret: bool | None = None):
    """(B, m) bits -> (counts (B, classes), argmax (B,)).  Pads B."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B = bits.shape[0]
    bb = min(512, _round_up(B, 8))
    Bp = _round_up(B, bb)
    bitsp = jnp.pad(bits.astype(jnp.float32), ((0, Bp - B), (0, 0)))
    counts, idx = popcount_classify(bitsp, num_classes, block_b=bb,
                                    interpret=interpret)
    return counts[:B], idx[:B]


__all__ = ["classify", "popcount_ref", "classify_ref"]
