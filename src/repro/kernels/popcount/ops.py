"""Public op wrapper for the popcount/classifier kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.bitpack import PackedBits, group_masks
from .kernel import popcount_classify, popcount_classify_packed
from .ref import popcount_ref, classify_ref, classify_packed_ref


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def classify(bits: jax.Array, num_classes: int, *,
             interpret: bool | None = None):
    """(B, m) bits -> (counts (B, classes), argmax (B,)).  Pads B."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B = bits.shape[0]
    bb = min(512, _round_up(B, 8))
    Bp = _round_up(B, bb)
    bitsp = jnp.pad(bits.astype(jnp.float32), ((0, Bp - B), (0, 0)))
    counts, idx = popcount_classify(bitsp, num_classes, block_b=bb,
                                    interpret=interpret)
    return counts[:B], idx[:B]


def classify_packed(packed: PackedBits, num_classes: int, *,
                    interpret: bool | None = None):
    """Packed classify: (PackedBits of m bits) -> (counts, argmax).

    Pads B; the class masks absorb any group/word misalignment, and the
    word format's zero pad bits guarantee padded lanes count nothing.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    words = packed.words
    B = words.shape[0]
    bb = min(512, _round_up(B, 8))
    Bp = _round_up(B, bb)
    wordsp = jnp.pad(words, ((0, Bp - B), (0, 0)))
    masks = group_masks(packed.num_bits, num_classes)
    counts, idx = popcount_classify_packed(wordsp, masks, block_b=bb,
                                           interpret=interpret)
    return counts[:B], idx[:B]


__all__ = ["classify", "classify_packed", "popcount_ref", "classify_ref",
           "classify_packed_ref"]
