"""Pure-jnp oracle for the popcount/classifier kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def popcount_ref(bits: jax.Array, num_classes: int) -> jax.Array:
    """(B, m) {0,1} -> (B, classes) group counts (f32)."""
    B, m = bits.shape
    return bits.reshape(B, num_classes, m // num_classes).sum(-1)


def classify_ref(bits: jax.Array, num_classes: int):
    """(B, m) -> (counts (B, classes), argmax (B,)); ties -> lower index."""
    counts = popcount_ref(bits, num_classes)
    return counts, jnp.argmax(counts, axis=-1).astype(jnp.int32)


def classify_packed_ref(packed, num_classes: int):
    """Packed oracle: unpack -> float oracle (PackedBits in)."""
    return classify_ref(packed.unpack(), num_classes)
