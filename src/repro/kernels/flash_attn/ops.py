"""Public op wrapper for the flash-attention kernel (GQA fold + padding)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import flash_attention
from .ref import attention_ref


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def attend(q: jax.Array, k: jax.Array, v: jax.Array, *,
           causal: bool = True, block: int = 512,
           interpret: bool | None = None) -> jax.Array:
    """q: (B, S, H, hd); k/v: (B, S, K, hd) with H % K == 0 (GQA).

    Folds (batch, head) into the kernel's leading dim, repeating KV per
    group; pads S to a block multiple (padded keys are masked out by
    causality for the padded queries only, which are then sliced off).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, S, H, hd = q.shape
    K = k.shape[2]
    g = H // K
    kr = jnp.repeat(k, g, axis=2) if g > 1 else k
    vr = jnp.repeat(v, g, axis=2) if g > 1 else v
    bq = min(block, _round_up(S, 8))
    Sp = _round_up(S, bq)
    qt = jnp.pad(q, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    kt = jnp.pad(kr, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    vt = jnp.pad(vr, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(B * H, Sp, hd)
    out = flash_attention(fold(qt), fold(kt), fold(vt), causal=causal,
                          block_q=bq, block_k=bq, interpret=interpret)
    out = out.reshape(B, H, Sp, hd).transpose(0, 2, 1, 3)
    return out[:, :S]


__all__ = ["attend", "attention_ref"]
