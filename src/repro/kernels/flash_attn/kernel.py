"""Pallas TPU kernel: causal flash attention with block skipping.

This is the real fix for the two §Perf findings the XLA pipeline could
not credit: (a) masked-flash computes the full S x S grid — here the
strictly-upper KV blocks are *skipped* with ``pl.when`` (half the MXU
work); (b) XLA materializes every score tile to HBM — here the (bq, bk)
score tile, the running max/denominator and the output accumulator live
in VMEM scratch for the whole KV sweep.

Grid: (BH, S/bq, S/bk) with the KV dimension innermost ("arbitrary"
semantics — sequential, carrying scratch).  Block j is skipped when the
whole tile sits above the diagonal; the diagonal tile applies the
in-tile causal mask.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  bq: int, bk: int, causal: bool, scale: float):
    i = pl.program_id(1)
    j = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal block skip: KV block strictly above the diagonal contributes
    # nothing — pl.when elides the MXU work entirely.
    run = (j * bk <= i * bq + (bq - 1)) if causal else True

    @pl.when(run)
    def _block():
        q = q_ref[0].astype(jnp.float32)             # (bq, hd)
        k = k_ref[0].astype(jnp.float32)             # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)
        if causal:
            qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 512,
                    block_k: int = 512, interpret: bool = False):
    """q/k/v: (BH, S, hd) -> (BH, S, hd).

    BH folds batch x heads; GQA callers fold the repeated KV layout
    before the call (see ops.py).
    """
    BH, S, hd = q.shape
    bq, bk = min(block_q, S), min(block_k, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    grid = (BH, S // bq, S // bk)
    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, causal=causal,
                               scale=hd ** -0.5)
    from jax.experimental.pallas import tpu as pltpu
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            # fp32 accumulators resident in VMEM across the KV sweep
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
