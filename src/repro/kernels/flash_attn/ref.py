"""Pure-jnp oracle for the flash-attention kernel (single head-batch)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True) -> jax.Array:
    """q/k/v: (BH, S, hd) -> (BH, S, hd), fp32 internally."""
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqd,bkd->bqk", qf, kf) / (q.shape[-1] ** 0.5)
    if causal:
        S = q.shape[1]
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, vf).astype(q.dtype)
