"""Resilient sweep execution: fault-tolerant, resumable, straggler-aware.

``run_grid_parallel`` shards a sweep grid across worker *processes* and
keeps the run alive through every failure domain the serial loop dies on:

* **worker crash / node loss** — each point runs inside the worker under
  ``runtime.fault.Supervisor.supervise`` with a bounded
  :class:`~repro.runtime.fault.RestartPolicy` (in-process faults retry
  with backoff); a worker *process* death is detected by the dispatcher,
  the in-flight point is re-dispatched to a fresh worker, and a point
  whose workers die ``max_restarts + 1`` times is reported as **failed**
  in the :class:`~repro.sweep.results.SweepResult` without aborting the
  remaining grid;
* **run kill / preemption** — every completed point is persisted through
  the config-hash cache (atomic ``os.replace`` writes) the moment it
  finishes, and optionally as a packed ``DWNArtifact`` via
  ``runtime.checkpoint.save_artifact``; a killed run resumes with zero
  recomputed points, and SIGTERM (``runtime.fault.PreemptionHandler``)
  converts to "finish in-flight points, flush, return partial result" —
  the CLI exits 0 and the next invocation continues from the cache;
* **stragglers** — per-point wall times feed a
  ``runtime.straggler.StragglerMonitor``; an in-flight point that
  exceeds the robust-z flag threshold is speculatively re-dispatched to
  a fresh worker and the first result wins, so one slow host never gates
  the grid.

Chaos modes (``ExecutorSettings.chaos``) make all of this testable:

* ``kill-after-N``  — each worker hard-exits (``os._exit``) after
  completing N points: simulated node loss *after* the cache commit;
* ``raise-after-N`` — a ``runtime.fault.FaultInjector`` raises once in
  each worker after N completed points (exercises the in-worker
  ``Supervisor`` retry path);
* ``raise-always``  — every computation attempt raises: the crash-loop
  shape that must end in per-point *failure*, not an infinite spin;
* ``raise-point-I`` — grid index I raises on *every* attempt (one failed
  point must not abort the remaining grid);
* ``stall-I:S``     — the first attempt at grid index I sleeps S seconds
  before computing (exercises straggler speculation).

Workers are spawned (never forked — JAX state does not survive a fork)
and lazily build their own :class:`~repro.sweep.pipeline.SweepRunner`
(data + model memo).  On hosts with multiple accelerator devices each
worker is pinned round-robin via ``CUDA_VISIBLE_DEVICES`` before its
first JAX operation; on CPU the processes are plain multiprocessing.
See docs/sweep_resilience.md for the full architecture.
"""

from __future__ import annotations

import dataclasses
import logging
import multiprocessing as mp
import os
import queue as queue_mod
import time

from .cache import SweepCache, point_key
from .grid import SweepPoint, load_grid
from .pipeline import SweepSettings, persist_artifact, scan_cache
from .results import PointResult, SweepResult

logger = logging.getLogger(__name__)


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ChaosSpec:
    """Parsed fault-injection directive (see module docstring)."""

    kill_after: int | None = None
    raise_after: int | None = None
    raise_always: bool = False
    raise_point: int | None = None
    stall_index: int | None = None
    stall_s: float = 0.0

    @classmethod
    def parse(cls, text: str | None) -> "ChaosSpec":
        if not text:
            return cls()
        if text == "raise-always":
            return cls(raise_always=True)
        if text.startswith("kill-after-"):
            return cls(kill_after=int(text.rsplit("-", 1)[1]))
        if text.startswith("raise-after-"):
            return cls(raise_after=int(text.rsplit("-", 1)[1]))
        if text.startswith("raise-point-"):
            return cls(raise_point=int(text.rsplit("-", 1)[1]))
        if text.startswith("stall-"):
            idx, _, secs = text[len("stall-"):].partition(":")
            return cls(stall_index=int(idx), stall_s=float(secs or 1.0))
        raise ValueError(
            f"unknown chaos spec {text!r} (kill-after-N | raise-after-N | "
            f"raise-always | raise-point-I | stall-I:S)")


@dataclasses.dataclass(frozen=True)
class ExecutorSettings:
    """Knobs of the parallel executor (fidelity knobs stay in
    :class:`~repro.sweep.pipeline.SweepSettings`).

    Attributes:
      workers: worker processes; None = min(grid size, CPU count, 4).
      max_restarts: per-point failure budget — counts both in-worker
        retries and re-dispatches after a worker death; a point is failed
        after ``max_restarts + 1`` attempts.
      backoff_s: in-worker retry backoff (seconds).
      straggler_*: StragglerMonitor window/threshold over per-point wall
        times; ``speculate=False`` disables re-dispatch.
      poll_s: dispatcher poll interval (seconds).
      lost_task_timeout_s: watchdog — if nothing completes for this long
        while all workers are idle, unclaimed points are re-queued
        (covers the claim-message race on a crashed worker).
      artifact_dir: when set, every computed point's packed artifact is
        checkpointed here via ``runtime.checkpoint.save_artifact``.
      chaos: fault-injection directive (:class:`ChaosSpec`), None = off.
    """

    workers: int | None = None
    max_restarts: int = 2
    backoff_s: float = 0.05
    straggler_window: int = 32
    straggler_z: float = 4.0
    straggler_min_samples: int = 3
    speculate: bool = True
    poll_s: float = 0.1
    lost_task_timeout_s: float = 300.0
    artifact_dir: str | None = None
    chaos: str | None = None


def _default_workers(n_points: int) -> int:
    return max(1, min(n_points, os.cpu_count() or 1, 4))


def _device_hints(n_workers: int) -> list:
    """Round-robin device pins for accelerator hosts; None entries on
    CPU (plain multiprocessing)."""
    try:
        import jax
        ndev = jax.local_device_count()
        platform = jax.default_backend()
    except Exception:                                 # pragma: no cover
        return [None] * n_workers
    if ndev > 1 and platform in ("gpu", "cuda", "rocm"):
        return [str(i % ndev) for i in range(n_workers)]
    return [None] * n_workers


# ---------------------------------------------------------------------------
# worker process
# ---------------------------------------------------------------------------

def _worker_main(worker_id: int, task_q, result_q, settings_dict: dict,
                 cache_dir: str | None, artifact_dir: str | None,
                 chaos_text: str | None, max_restarts: int,
                 backoff_s: float, device_hint: str | None) -> None:
    """One worker: pull (index, point, attempt) tasks, run each point
    under a supervised retry loop, commit to the cache (and artifact
    store), report on the result queue.  Runs in a *spawned* process."""
    if device_hint is not None:
        os.environ.setdefault("CUDA_VISIBLE_DEVICES", device_hint)
    # workers never own the preemption signal: the dispatcher drains the
    # run; a TERM'd worker is treated as a node loss and re-dispatched
    from ..runtime.fault import FaultInjector, RestartPolicy, Supervisor
    from .pipeline import SweepRunner

    settings = SweepSettings(**settings_dict)
    chaos = ChaosSpec.parse(chaos_text)
    cache = SweepCache(cache_dir)
    runner = None
    completed = 0
    if chaos.raise_always:
        injector = FaultInjector(set(range(1 << 20)), every_step=True)
    else:
        crash = set() if chaos.raise_after is None else {chaos.raise_after}
        injector = FaultInjector(crash)

    while True:
        task = task_q.get()
        if task is None:
            result_q.put(("bye", worker_id))
            return
        index, point_dict, attempt = task
        result_q.put(("claim", worker_id, index, attempt))
        point = SweepPoint.from_dict(point_dict)
        t0 = time.perf_counter()
        key = point_key(point, settings)
        if attempt > 1:
            # a re-dispatched point may already be committed (its first
            # worker died *after* the cache write, or its "done" message
            # was lost with the dying process) — never recompute it
            hit = cache.get(key)
            if hit is not None:
                result_q.put(("done", worker_id, index, attempt, hit,
                              time.perf_counter() - t0, 0, True))
                completed += 1
                continue

        def compute():
            nonlocal runner
            if chaos.stall_index == index and attempt == 1:
                time.sleep(chaos.stall_s)
            if chaos.raise_point == index:
                raise RuntimeError(
                    f"injected persistent fault at grid index {index}")
            injector.maybe_crash(completed)
            if runner is None:                 # lazy: data + jit caches
                runner = SweepRunner(settings)
            return runner.run_point(point)

        # earlier attempts (worker deaths, in-worker retries) draw from
        # the same per-point budget the dispatcher enforces
        budget = max(0, max_restarts - (attempt - 1))
        sup = Supervisor(cache_dir or ".",
                         policy=RestartPolicy(max_restarts=budget,
                                              backoff_s=backoff_s))
        try:
            res = sup.supervise(compute, label=point.label)
        except Exception as e:                 # budget exhausted: terminal
            # sup.restarts counts crashes; the last crash aborted rather
            # than retried, so the retry count is one fewer
            result_q.put(("failed", worker_id, index, attempt,
                          f"{type(e).__name__}: {e}", sup.restarts - 1))
            continue
        cache.put(key, res.to_dict())
        persist_artifact(runner, point, key, artifact_dir)
        wall = time.perf_counter() - t0
        result_q.put(("done", worker_id, index, attempt, res.to_dict(),
                      wall, sup.restarts, False))
        completed += 1
        if chaos.kill_after is not None and completed >= chaos.kill_after:
            # flush the queue's feeder thread first: the point is already
            # committed to the cache, and the parent should learn that
            # before it sees the corpse (lost messages are still safe —
            # the re-dispatch hits the worker-side cache check above)
            result_q.close()
            result_q.join_thread()
            os._exit(17)                       # simulated node loss


# ---------------------------------------------------------------------------
# dispatcher
# ---------------------------------------------------------------------------

class _Dispatcher:
    """Parent-side state machine: task/result queues, worker lifecycle,
    restart accounting, straggler speculation, preemption draining."""

    def __init__(self, points, todo, settings, cache, ex, preemption, log):
        self.points = points
        self.settings = settings
        self.cache = cache
        self.ex = ex
        self.preemption = preemption
        self.log = log or (lambda m: None)
        self.ctx = mp.get_context("spawn")
        self.task_q = self.ctx.Queue()
        self.result_q = self.ctx.Queue()
        self.todo = list(todo)
        self.results: dict[int, PointResult] = {}
        self.failed: dict[int, str] = {}
        self.attempts: dict[int, int] = {i: 0 for i in todo}
        self.in_flight: dict[int, tuple] = {}      # wid -> (idx, att, t0)
        self.procs: dict[int, mp.Process] = {}
        self.speculated: set[int] = set()
        self.counters = {"computed": 0, "restarts": 0, "worker_deaths": 0,
                         "stragglers_redispatched": 0, "superseded": 0,
                         "in_worker_retries": 0, "workers_spawned": 0,
                         "worker_cache_hits": 0}
        self.draining = False
        self._next_wid = 0
        from ..runtime.straggler import StragglerMonitor
        self.monitor = StragglerMonitor(
            window=ex.straggler_window, z_threshold=ex.straggler_z,
            min_samples=ex.straggler_min_samples)

    # -- lifecycle ------------------------------------------------------

    def spawn_worker(self, device_hint=None):
        wid = self._next_wid
        self._next_wid += 1
        p = self.ctx.Process(
            target=_worker_main,
            args=(wid, self.task_q, self.result_q,
                  dataclasses.asdict(self.settings),
                  str(self.cache.root) if self.cache.root else None,
                  self.ex.artifact_dir, self.ex.chaos,
                  self.ex.max_restarts, self.ex.backoff_s, device_hint),
            daemon=True)
        p.start()
        self.procs[wid] = p
        self.counters["workers_spawned"] += 1
        return wid

    def dispatch(self, index: int):
        self.attempts[index] += 1
        self.task_q.put((index, self.points[index].to_dict(),
                         self.attempts[index]))

    def unresolved(self) -> list:
        return [i for i in self.todo
                if i not in self.results and i not in self.failed]

    # -- event handling -------------------------------------------------

    def _on_message(self, msg) -> None:
        kind = msg[0]
        if kind == "claim":
            _, wid, index, attempt = msg
            self.in_flight[wid] = (index, attempt, time.perf_counter())
        elif kind == "done":
            _, wid, index, attempt, res_dict, wall, retries, cached = msg
            self.in_flight.pop(wid, None)
            self.counters["in_worker_retries"] += retries
            if index in self.results or index in self.failed:
                self.counters["superseded"] += 1
                return
            self.results[index] = PointResult.from_dict(res_dict)
            if cached:
                self.results[index].cached = True
                self.counters["worker_cache_hits"] += 1
            else:
                self.counters["computed"] += 1
                self.monitor.report(wall)
            n = len(self.results) + len(self.failed)
            self.log(f"[{n}/{len(self.todo)}] "
                     f"{self.points[index].label}: "
                     f"{self.results[index].total_luts} LUTs "
                     f"({wall:.1f}s, worker {wid}"
                     + (f", attempt {attempt}" if attempt > 1 else "") + ")")
        elif kind == "failed":
            _, wid, index, attempt, error, retries = msg
            self.in_flight.pop(wid, None)
            self.counters["in_worker_retries"] += retries
            if index not in self.results and index not in self.failed:
                self.failed[index] = error
                self.log(f"POINT FAILED {self.points[index].label}: {error} "
                         f"(restart budget exhausted)")
        elif kind == "bye":
            _, wid = msg
            self.in_flight.pop(wid, None)
            p = self.procs.pop(wid, None)
            if p is not None:
                p.join(timeout=5)

    def _reap_dead_workers(self) -> None:
        """A dead worker's in-flight point re-dispatches (bounded); a
        replacement worker spawns while work remains."""
        for wid in [w for w, p in self.procs.items() if not p.is_alive()]:
            self.procs.pop(wid).join(timeout=1)
            self.counters["worker_deaths"] += 1
            task = self.in_flight.pop(wid, None)
            if task is not None:
                index, attempt, _ = task
                if index in self.results or index in self.failed:
                    pass                        # superseded: nothing lost
                elif attempt > self.ex.max_restarts:
                    self.failed[index] = (
                        f"worker died (attempt {attempt}, "
                        f"restart budget {self.ex.max_restarts} exhausted)")
                    self.log(f"POINT FAILED {self.points[index].label}: "
                             f"{self.failed[index]}")
                else:
                    self.counters["restarts"] += 1
                    self.log(f"worker {wid} died at "
                             f"{self.points[index].label}; re-dispatching "
                             f"(attempt {attempt + 1})")
                    self.dispatch(index)
            if self.unresolved() and not self.draining:
                self.spawn_worker()

    def _check_stragglers(self) -> None:
        if not self.ex.speculate or self.draining:
            return
        thr = self.monitor.threshold_s()
        if thr is None:
            return
        now = time.perf_counter()
        for wid, (index, attempt, t0) in list(self.in_flight.items()):
            if (now - t0 > thr and index not in self.speculated
                    and index not in self.results
                    and index not in self.failed
                    and attempt <= self.ex.max_restarts):
                self.speculated.add(index)
                self.counters["stragglers_redispatched"] += 1
                self.log(f"straggler: {self.points[index].label} in flight "
                         f"{now - t0:.1f}s > {thr:.1f}s; speculatively "
                         f"re-dispatching to a fresh worker")
                self.dispatch(index)
                self.spawn_worker()             # never gate on the slow one

    def _drain_task_queue(self) -> None:
        try:
            while True:
                self.task_q.get_nowait()
        except queue_mod.Empty:
            pass

    # -- main loop ------------------------------------------------------

    def run(self) -> None:
        n_workers = self.ex.workers or _default_workers(len(self.todo))
        n_workers = max(1, min(n_workers, len(self.todo)))
        for hint in _device_hints(n_workers):
            self.spawn_worker(device_hint=hint)
        for i in self.todo:
            self.dispatch(i)
        last_progress = time.perf_counter()
        while self.unresolved():
            if self.preemption.requested and not self.draining:
                self.draining = True
                self._drain_task_queue()
                self.log(f"preemption: draining — finishing "
                         f"{len(self.in_flight)} in-flight point(s), "
                         f"cache is flushed per point")
            if self.draining and not self.in_flight:
                break
            try:
                msg = self.result_q.get(timeout=self.ex.poll_s)
            except queue_mod.Empty:
                msg = None
            if msg is not None:
                self._on_message(msg)
                last_progress = time.perf_counter()
                # drain whatever else is already queued
                try:
                    while True:
                        self._on_message(self.result_q.get_nowait())
                except queue_mod.Empty:
                    pass
            self._reap_dead_workers()
            self._check_stragglers()
            if (not self.in_flight and msg is None
                    and time.perf_counter() - last_progress
                    > self.ex.lost_task_timeout_s):
                # claim-race watchdog: a worker died between task pickup
                # and its claim message — re-queue every unresolved point
                self.log("watchdog: no progress and no claims; re-queueing "
                         f"{len(self.unresolved())} unresolved point(s)")
                for i in self.unresolved():
                    if self.attempts[i] > self.ex.max_restarts:
                        self.failed[i] = "lost task (restarts exhausted)"
                    else:
                        self.dispatch(i)
                last_progress = time.perf_counter()
        self.shutdown()

    def shutdown(self) -> None:
        # a worker still grinding on a point someone else already won
        # must not gate the run's exit — kill it, its result is moot
        for wid, (index, _, _) in list(self.in_flight.items()):
            if index in self.results or index in self.failed:
                p = self.procs.pop(wid, None)
                if p is not None:
                    p.terminate()
                    p.join(timeout=2)
                self.in_flight.pop(wid, None)
        for _ in range(len(self.procs) + 2):
            try:
                self.task_q.put_nowait(None)
            except Exception:                   # pragma: no cover
                break
        deadline = time.time() + 10
        for p in self.procs.values():
            p.join(timeout=max(0.1, deadline - time.time()))
            if p.is_alive():
                p.terminate()
                p.join(timeout=2)
        self.task_q.cancel_join_thread()
        self.result_q.cancel_join_thread()


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------

def run_grid_parallel(grid, settings: SweepSettings | None = None, *,
                      cache_dir: str | None = "results/sweep_cache",
                      fresh: bool = False,
                      executor: ExecutorSettings | None = None,
                      preemption=None, log=None) -> SweepResult:
    """Run a grid through worker processes with fault tolerance.

    Same contract as :func:`~repro.sweep.pipeline.run_grid` (same cache,
    same :class:`SweepResult`) plus the executor block in the result:
    computed / cache-hit counts, failed points, restart + straggler
    counters, and the ``interrupted`` flag when a preemption drained the
    run early (unfinished points are listed in ``remaining_points`` and
    simply resume from the cache on the next invocation).

    Args:
      grid: named grid / JSON path / list of :class:`SweepPoint`.
      settings: fidelity knobs (:class:`SweepSettings`).
      cache_dir: result-cache root; None disables resume (discouraged —
        a killed run then recomputes everything).
      fresh: ignore (but still refresh) the cache.
      executor: :class:`ExecutorSettings` (workers, restarts, chaos...).
      preemption: injectable ``runtime.fault.PreemptionHandler``; by
        default one is installed on SIGTERM in this (main) thread.
      log: optional ``print``-like progress callback.
    """
    from ..runtime.fault import PreemptionHandler

    settings = settings or SweepSettings()
    ex = executor or ExecutorSettings()
    ChaosSpec.parse(ex.chaos)                  # validate early
    points = load_grid(grid) if isinstance(grid, str) else list(grid)
    name = grid if isinstance(grid, str) else "custom"
    cache = SweepCache(cache_dir)
    t_start = time.perf_counter()
    hits = scan_cache(points, settings, cache, fresh)
    todo = [i for i in range(len(points)) if i not in hits]
    if log:
        log(f"executor: {len(hits)}/{len(points)} points from cache, "
            f"{len(todo)} to compute")
    preemption = preemption or PreemptionHandler(install=True)

    disp = None
    if todo:
        disp = _Dispatcher(points, todo, settings, cache, ex, preemption,
                           log)
        disp.run()

    out, remaining = [], []
    for i, point in enumerate(points):
        if i in hits:
            out.append(hits[i])
        elif disp and i in disp.results:
            out.append(disp.results[i])
        elif disp and i in disp.failed:
            out.append(PointResult(point=point, failed=True,
                                   error=disp.failed[i]))
        else:
            remaining.append(point.label)
    counters = disp.counters if disp else {
        "computed": 0, "restarts": 0, "worker_deaths": 0,
        "stragglers_redispatched": 0, "superseded": 0,
        "in_worker_retries": 0, "workers_spawned": 0,
        "worker_cache_hits": 0}
    executor_block = {
        "mode": "parallel",
        "workers": (ex.workers or _default_workers(max(len(todo), 1))),
        "cache_hits": len(hits),
        "failed": [points[i].label for i in sorted(disp.failed)]
        if disp else [],
        "interrupted": bool(disp.draining) if disp else False,
        "remaining": len(remaining),
        "remaining_points": remaining,
        "chaos": ex.chaos,
        "cache": dict(cache.stats),
        "wall_s": round(time.perf_counter() - t_start, 3),
        **counters,
    }
    return SweepResult(grid=name, settings=dataclasses.asdict(settings),
                       points=out, executor=executor_block)


__all__ = ["ChaosSpec", "ExecutorSettings", "run_grid_parallel"]
