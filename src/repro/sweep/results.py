"""Sweep result containers: one table, Pareto fronts, JSON round-trip.

A sweep emits one :class:`SweepResult` holding a :class:`PointResult` per
grid point.  Each row carries all three co-design axes —

* **accuracy** — hard-inference accuracy through ``apply_hard_packed``
  (fraction in [0, 1]; None when the point ran without an accuracy pass);
* **FPGA cost** — the ``hw.cost.dwn_hw_report`` breakdown: LUT counts per
  component (encoder / lut_layer / popcount / argmax), FFs, estimated
  combinational delay in **ns** and pipelined Fmax in **MHz**;
* **throughput** — fused packed-kernel wall time per batch in **µs** and
  serving-engine throughput in **samples/s** (None when those axes were
  skipped).

plus the paper's reference LUT count and % error where the point lands on
a published Table I/III row.
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Callable, Sequence

from .grid import SweepPoint


@dataclasses.dataclass
class PointResult:
    """Everything measured at one grid point (see module docstring for
    units).  ``cached`` marks rows served from the sweep cache."""

    point: SweepPoint
    accuracy: float | None = None
    luts: dict = dataclasses.field(default_factory=dict)  # component -> LUTs
    total_luts: int = 0
    total_ffs: int = 0
    delay_ns: float = 0.0
    fmax_mhz: float = 0.0
    distinct_comparators: int = 0
    paper_luts: int | None = None
    lut_error_pct: float | None = None
    encoder_share: float | None = None        # encoder LUTs / total LUTs
    kernel_us: float | None = None            # fused packed kernel, per batch
    kernel_batch: int | None = None
    serve_throughput: float | None = None     # samples/s through the engine
    serve_p50_ms: float | None = None         # compute latency per microbatch
    serve_backend: str | None = None
    cached: bool = False
    failed: bool = False                      # executor gave up on this point
    error: str | None = None                  # last failure (when failed)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["point"] = self.point.to_dict()
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "PointResult":
        d = dict(d)
        d["point"] = SweepPoint.from_dict(d["point"])
        return cls(**d)


def pareto_front(items: Sequence, cost: Callable, score: Callable) -> list:
    """Generic Pareto frontier: minimize ``cost``, maximize ``score``.

    Walks items in ascending cost and keeps each one that strictly improves
    the best score seen so far — the classic staircase frontier.  Items
    whose score is None are skipped.  This is the exact frontier rule the
    Fig. 6 benchmark has always used; it lives here so every consumer
    (benchmarks, the sweep CLI, tests) shares one definition.
    """
    front = []
    for it in sorted(items, key=cost):
        s = score(it)
        if s is None:
            continue
        if not front or s > score(front[-1]):
            front.append(it)
    return front


@dataclasses.dataclass
class SweepResult:
    """A completed sweep: grid + settings provenance + per-point rows.

    ``executor`` carries the run's execution provenance (serial or
    parallel): worker count, computed vs cache-hit point counts, failed
    points, straggler re-dispatches, restart count, and whether the run
    was preempted mid-grid — the counters the chaos-resume CI smoke
    asserts on (see docs/sweep_resilience.md).
    """

    grid: str
    settings: dict
    points: list
    executor: dict | None = None

    # -- views ---------------------------------------------------------

    def accuracy_vs_luts_front(self) -> list:
        """Pareto frontier maximizing accuracy, minimizing total LUTs."""
        return pareto_front(self.points, cost=lambda r: r.total_luts,
                            score=lambda r: r.accuracy)

    def throughput_vs_luts_front(self) -> list:
        """Pareto frontier maximizing serving throughput vs LUTs."""
        return pareto_front(self.points, cost=lambda r: r.total_luts,
                            score=lambda r: r.serve_throughput)

    def table(self) -> str:
        """Markdown table over every point (the sweep's printed artifact)."""
        head = ("| point | acc | LUT total | enc | enc% | lut | pop | argmax "
                "| paper | err% | kernel µs | serve/s |\n"
                "|---|---|---|---|---|---|---|---|---|---|---|---|")
        rows = []
        for r in self.points:
            if r.failed:
                rows.append(f"| {r.point.label} | FAILED ({r.error}) "
                            + "| - " * 10 + "|")
                continue
            acc = f"{r.accuracy:.3f}" if r.accuracy is not None else "-"
            err = (f"{r.lut_error_pct:+.1f}"
                   if r.lut_error_pct is not None else "-")
            ker = f"{r.kernel_us:.0f}" if r.kernel_us is not None else "-"
            srv = (f"{r.serve_throughput:.0f}"
                   if r.serve_throughput is not None else "-")
            share = (f"{100 * r.encoder_share:.1f}"
                     if r.encoder_share is not None else "-")
            rows.append(
                f"| {r.point.label} | {acc} | {r.total_luts} "
                f"| {r.luts.get('encoder', 0)} | {share} "
                f"| {r.luts.get('lut_layer', 0)} "
                f"| {r.luts.get('popcount', 0)} | {r.luts.get('argmax', 0)} "
                f"| {r.paper_luts or '-'} | {err} | {ker} | {srv} |")
        return "\n".join([head] + rows)

    # -- (de)serialization ---------------------------------------------

    def to_dict(self) -> dict:
        out = {"grid": self.grid, "settings": self.settings,
               "points": [r.to_dict() for r in self.points],
               "pareto": {
                   "accuracy_vs_luts":
                       [r.point.label for r in self.accuracy_vs_luts_front()],
                   "throughput_vs_luts":
                       [r.point.label
                        for r in self.throughput_vs_luts_front()],
               }}
        if self.executor is not None:
            out["executor"] = self.executor
        return out

    def save(self, path: str | Path) -> None:
        """Write the sweep (points + frontiers) as one JSON artifact."""
        with open(path, "w") as fh:
            json.dump(self.to_dict(), fh, indent=1)

    @classmethod
    def load(cls, path: str | Path) -> "SweepResult":
        with open(path) as fh:
            d = json.load(fh)
        return cls(grid=d["grid"], settings=d["settings"],
                   points=[PointResult.from_dict(p) for p in d["points"]],
                   executor=d.get("executor"))


__all__ = ["PointResult", "SweepResult", "pareto_front"]
