"""The shared sweep pipeline: every grid point takes the same path.

For each :class:`~repro.sweep.grid.SweepPoint` the runner

1. instantiates the model config (`core.model.DWNConfig` with the point's
   LUT-layer width, encoder resolution T, and threshold placement) and
   builds/trains it once per unique (preset, T, placement) — TEN and PEN
   variants of the same model share weights, as in the paper.  Points that
   agree on (preset, T) train together as ONE vmapped scan-compiled
   program (``repro.training.batch``) instead of sequential loops;
2. computes **hard-inference accuracy** through ``apply_hard_packed``
   (the packed uint32 datapath, bit-exact vs the float oracle);
3. scores **FPGA cost** via ``hw.cost.dwn_hw_report`` — the full
   encoder / LUT-layer / popcount / argmax breakdown;
4. times the **fused packed Pallas kernel** (µs per batch, best of k) and
   the **serving engine** (samples/s through the scheduler + backend that
   production serving uses) on that exact config.

Results cache by config hash (``repro.sweep.cache``) so re-running a grid
recomputes only new points.

Fidelity knobs live in :class:`SweepSettings`.  The default
``train_epochs=0`` trains nothing and relies on the correlation warmstart
(``core.warmstart``), which is enough for the hardware axes (TEN LUT
counts are training-invariant) and gives indicative — not paper-grade —
accuracies; raise ``--epochs`` for the real accuracy axis.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..core import (JSC_PRESETS, eval_accuracy_hard_packed, freeze,
                    init_dwn, train_dwn)
from ..core.model import DWNConfig, FrozenDWN
from ..core.warmstart import warmstart_dwn
from ..data.jsc import load_jsc
from ..hw.cost import dwn_hw_report
from ..kernels.fused import ops as fused_ops
from .artifacts import lut_error_pct, paper_reference
from .cache import SweepCache, point_key
from .grid import SweepPoint, load_grid
from .results import PointResult, SweepResult


@dataclasses.dataclass(frozen=True)
class SweepSettings:
    """Fidelity/measurement knobs shared by every point of one sweep.

    Attributes:
      n_train / n_test: JSC split sizes (samples).
      data_seed / seed: dataset and model-init PRNG seeds.
      train_epochs: gradient epochs per model; 0 = warmstart only.
      train_batch / lr: training shape (match ``benchmarks/common.py``).
      warmstart: correlation-based LUT init (``core.warmstart``).
      accuracy: run the packed hard-accuracy pass.
      kernel: time the fused packed kernel.
      kernel_batch: samples per timed kernel call.
      kernel_iters: timing repetitions (best-of, compile excluded).
      serve: run the serving-engine throughput axis.
      serve_backend: datapath backend the engine times.
      serve_requests / serve_batch: request stream shape (count x size).
    """

    n_train: int = 4000
    n_test: int = 2000
    data_seed: int = 0
    seed: int = 0
    train_epochs: int = 0
    train_batch: int = 128
    lr: float = 1e-3
    warmstart: bool = True
    accuracy: bool = True
    kernel: bool = True
    kernel_batch: int = 256
    kernel_iters: int = 3
    serve: bool = False
    serve_backend: str = "fused-packed"
    serve_requests: int = 2
    serve_batch: int = 64


class SweepRunner:
    """Runs grid points through the shared pipeline, memoizing models and
    serving engines across points that share them."""

    def __init__(self, settings: SweepSettings):
        self.settings = settings
        self.data = load_jsc(settings.n_train, settings.n_test,
                             seed=settings.data_seed)
        self._models: dict[tuple, tuple] = {}       # (preset,T,pl) -> (cfg,p,b)
        self._serve: dict[tuple, tuple] = {}        # same key -> (thru, p50)

    # -- model / frozen ------------------------------------------------

    @staticmethod
    def _cfg_for(point: SweepPoint) -> DWNConfig:
        return dataclasses.replace(JSC_PRESETS[point.preset],
                                   bits_per_feature=point.bits,
                                   encoding=point.placement)

    def _init_model(self, cfg: DWNConfig):
        s = self.settings
        if s.warmstart:
            return warmstart_dwn(jax.random.PRNGKey(s.seed), cfg,
                                 self.data.x_train, self.data.y_train)
        return init_dwn(jax.random.PRNGKey(s.seed), cfg, self.data.x_train)

    def prepare_models(self, points) -> int:
        """Batch-train the models several grid points share, ahead of the
        per-point loop.

        Points group by (preset, T): members differ only in threshold
        placement, so their params/buffers are same-shape arrays and a
        whole group trains as ONE vmapped scan-compiled program
        (``repro.training.batch.train_dwn_batch``) instead of sequential
        loops.  Groups of one fall through to :meth:`model_for`.

        Determinism caveat: a point's group is the set of *uncached*
        points sharing its (preset, T), so in principle vmap-level fp
        drift could vary with which grid subset runs together.  In
        practice the parity tests pin batched == sequential trajectories
        bit-exactly on this backend, and any residual drift is in the
        ~1e-6 class the sweep's accuracy tolerances already absorb.

        Returns the number of models trained in batched calls.
        """
        s = self.settings
        if s.train_epochs <= 0:
            return 0
        groups: dict[tuple, list] = {}
        for pt in points:
            key = (pt.preset, pt.bits, pt.placement)
            if key in self._models:
                continue
            grp = groups.setdefault((pt.preset, pt.bits), [])
            if key not in [k for k, _ in grp]:
                grp.append((key, pt))
        from ..training import train_dwn_batch
        trained = 0
        for members in groups.values():
            if len(members) < 2:
                continue
            cfgs = [self._cfg_for(pt) for _, pt in members]
            models = [self._init_model(c) for c in cfgs]
            out = train_dwn_batch(
                cfgs[0], self.data, epochs=s.train_epochs,
                seeds=[s.seed] * len(members), models=models,
                batch=s.train_batch, lr=s.lr, eval_final=False)
            for (key, _), cfg, res in zip(members, cfgs, out.results):
                self._models[key] = (cfg, res.params, res.buffers)
                trained += 1
        return trained

    def model_for(self, point: SweepPoint):
        """(DWNConfig, params, buffers) for the point's model shape —
        built once per unique (preset, T, placement)."""
        key = (point.preset, point.bits, point.placement)
        if key not in self._models:
            s = self.settings
            cfg = self._cfg_for(point)
            params, buffers = self._init_model(cfg)
            if s.train_epochs > 0:
                res = train_dwn(cfg, self.data, epochs=s.train_epochs,
                                batch=s.train_batch, lr=s.lr, seed=s.seed,
                                params=params, buffers=buffers,
                                eval_every=0, verbose=False)
                params, buffers = res.params, res.buffers
            self._models[key] = (cfg, params, buffers)
        return self._models[key]

    def frozen_for(self, point: SweepPoint) -> tuple[DWNConfig, FrozenDWN]:
        """Freeze the point's model to hardware semantics (PEN points
        quantize thresholds to the point's (1, n) fixed-point grid)."""
        cfg, params, buffers = self.model_for(point)
        return cfg, freeze(params, buffers, cfg,
                           input_frac_bits=point.frac_bits)

    # -- measurement axes ----------------------------------------------

    def _time_kernel(self, frozen: FrozenDWN, cfg: DWNConfig) -> float:
        """Fused packed kernel wall time in µs per kernel_batch call."""
        s = self.settings
        fwd = jax.jit(fused_ops.make_forward_packed(
            jnp.asarray(frozen.thresholds),
            [jnp.asarray(i) for i in frozen.mapping_idx],
            [jnp.asarray(t) for t in frozen.tables_bin],
            cfg.num_classes))
        n = self.data.x_test.shape[0]
        reps = -(-s.kernel_batch // n)             # tile if the split is small
        x = jnp.asarray(np.tile(self.data.x_test,
                                (reps, 1))[:s.kernel_batch])
        fwd(x)[1].block_until_ready()              # compile outside timing
        best = float("inf")
        for _ in range(max(s.kernel_iters, 1)):
            t0 = time.perf_counter()
            fwd(x)[1].block_until_ready()
            best = min(best, time.perf_counter() - t0)
        return best * 1e6

    def _serve_point(self, point: SweepPoint) -> tuple[float, float]:
        """(throughput samples/s, p50 compute ms) through the engine —
        measured once per unique (preset, T, placement)."""
        key = (point.preset, point.bits, point.placement)
        if key not in self._serve:
            from ..configs.dwn_jsc import sweep_arch
            from ..serving import ServingEngine
            s = self.settings
            engine = ServingEngine(
                sweep_arch(point.preset, bits=point.bits,
                           placement=point.placement,
                           datapath=s.serve_backend),
                backend=s.serve_backend, max_bucket=s.serve_batch,
                min_bucket=min(8, s.serve_batch),
                n_train=min(s.n_train, 2000), seed=s.seed)
            engine.warmup(s.serve_batch)
            for i in range(s.serve_requests):
                engine.submit(engine.make_request(s.serve_batch, seed=i))
            engine.drain()
            rep = engine.report()
            self._serve[key] = (
                rep["throughput_samples_per_s"],
                rep["latency"]["compute_ms"]["p50"])
        return self._serve[key]

    # -- one point -----------------------------------------------------

    def run_point(self, point: SweepPoint) -> PointResult:
        """Run every enabled axis at one grid point."""
        s = self.settings
        cfg, frozen = self.frozen_for(point)
        rep = dwn_hw_report(frozen, variant=point.variant, name=point.preset,
                            input_bits=point.input_bits)
        paper = paper_reference(point)
        res = PointResult(
            point=point,
            luts=dict(rep.luts), total_luts=rep.total_luts,
            total_ffs=rep.total_ffs, delay_ns=round(rep.delay_ns, 3),
            fmax_mhz=round(rep.fmax_mhz, 1),
            distinct_comparators=rep.distinct_comparators,
            paper_luts=paper,
            lut_error_pct=lut_error_pct(rep.total_luts, paper))
        if s.accuracy:
            res.accuracy = eval_accuracy_hard_packed(
                frozen, self.data.x_test, self.data.y_test)
        if s.kernel:
            res.kernel_us = round(self._time_kernel(frozen, cfg), 1)
            res.kernel_batch = s.kernel_batch
        if s.serve:
            thru, p50 = self._serve_point(point)
            res.serve_throughput = thru
            res.serve_p50_ms = p50
            res.serve_backend = s.serve_backend
        return res


def run_grid(grid: str | list, settings: SweepSettings | None = None, *,
             cache_dir: str | None = "results/sweep_cache",
             fresh: bool = False, log=None) -> SweepResult:
    """Run a whole grid through the pipeline, with incremental caching.

    Args:
      grid: a named grid / JSON path (see ``grid.load_grid``) or an
        explicit list of :class:`SweepPoint`.
      settings: fidelity knobs; defaults to :class:`SweepSettings`().
      cache_dir: result-cache root; None disables caching.
      fresh: ignore (but still refresh) the cache.
      log: optional ``print``-like progress callback.

    Returns the :class:`SweepResult` over every point.
    """
    settings = settings or SweepSettings()
    points = load_grid(grid) if isinstance(grid, str) else list(grid)
    name = grid if isinstance(grid, str) else "custom"
    cache = SweepCache(cache_dir)
    runner: SweepRunner | None = None
    hits: dict[int, PointResult] = {}
    for i, point in enumerate(points):
        hit = None if fresh else cache.get(point_key(point, settings))
        if hit is not None:
            try:
                res = PointResult.from_dict(hit)
                res.cached = True
                hits[i] = res
            except (TypeError, KeyError):      # stale schema: recompute
                pass
    misses = [p for i, p in enumerate(points) if i not in hits]
    if misses:                                 # lazy: all-hit runs are free
        runner = SweepRunner(settings)
        # train shape-compatible models of the uncached points as one
        # vmapped program each, before the per-point measurement loop
        n_batched = runner.prepare_models(misses)
        if log and n_batched:
            log(f"batch-trained {n_batched} models "
                f"({settings.train_epochs} epochs, one program per group)")
    out = []
    for i, point in enumerate(points):
        res = hits.get(i)
        if res is None:
            t0 = time.perf_counter()
            res = runner.run_point(point)
            cache.put(point_key(point, settings), res.to_dict())
            if log:
                log(f"[{i + 1}/{len(points)}] {point.label}: "
                    f"{res.total_luts} LUTs "
                    f"({time.perf_counter() - t0:.1f}s)")
        elif log:
            log(f"[{i + 1}/{len(points)}] {point.label}: cached")
        out.append(res)
    return SweepResult(grid=name, settings=dataclasses.asdict(settings),
                       points=out)


__all__ = ["SweepRunner", "SweepSettings", "run_grid"]
