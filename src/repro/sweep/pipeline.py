"""The shared sweep pipeline: every grid point takes the same path.

Each :class:`~repro.sweep.grid.SweepPoint` becomes one
``repro.dwn.DWNArtifact`` (typed spec → trained → frozen → packed) and
every measurement axis reads from that artifact.  The runner

1. derives the point's :class:`~repro.dwn.spec.DWNSpec` and trains its
   model once per unique (preset, T, placement) — TEN and PEN variants of
   the same model share weights, as in the paper, by ``adopt``-ing the
   shared trained state into each variant's artifact.  Points that agree
   on (preset, T) train together as ONE vmapped scan-compiled program
   (``repro.training.batch``) instead of sequential loops;
2. computes **hard-inference accuracy** through ``apply_hard_packed``
   (the packed uint32 datapath, bit-exact vs the float oracle);
3. scores **FPGA cost** via ``hw.cost.dwn_hw_report`` — the full
   encoder / LUT-layer / popcount / argmax breakdown;
4. times the **fused packed Pallas kernel** (µs per batch, best of k) and
   the **serving engine** (samples/s through the scheduler + backend that
   production serving uses) on that exact config.

Results cache by config hash (``repro.sweep.cache``) so re-running a grid
recomputes only new points.

Fidelity knobs live in :class:`SweepSettings`.  The default
``train_epochs=0`` trains nothing and relies on the correlation warmstart
(``core.warmstart``), which is enough for the hardware axes (TEN LUT
counts are training-invariant) and gives indicative — not paper-grade —
accuracies; raise ``--epochs`` for the real accuracy axis.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..core import eval_accuracy_hard_packed, init_dwn, train_dwn
from ..core.model import DWNConfig
from ..core.warmstart import warmstart_dwn
from ..dwn import DWNArtifact, DWNSpec
from ..hw.cost import dwn_hw_report
from ..kernels.fused import ops as fused_ops
from .artifacts import lut_error_pct, paper_reference
from .cache import SweepCache, point_key
from .grid import SweepPoint, load_grid
from .results import PointResult, SweepResult


@dataclasses.dataclass(frozen=True)
class SweepSettings:
    """Fidelity/measurement knobs shared by every point of one sweep.

    Attributes:
      n_train / n_test: dataset split sizes (samples; per-workload caps
        in ``repro.workloads`` may clamp them).
      data_seed / seed: dataset and model-init PRNG seeds.
      train_epochs: gradient epochs per model; 0 = warmstart only.
      train_batch / lr: training shape (match ``benchmarks/common.py``).
      warmstart: correlation-based LUT init (``core.warmstart``).
      accuracy: run the packed hard-accuracy pass.
      kernel: time the fused packed kernel.
      kernel_batch: samples per timed kernel call.
      kernel_iters: timing repetitions (best-of, compile excluded).
      serve: run the serving-engine throughput axis.
      serve_backend: datapath backend the engine times.
      serve_requests / serve_batch: request stream shape (count x size).
    """

    n_train: int = 4000
    n_test: int = 2000
    data_seed: int = 0
    seed: int = 0
    train_epochs: int = 0
    train_batch: int = 128
    lr: float = 1e-3
    warmstart: bool = True
    accuracy: bool = True
    kernel: bool = True
    kernel_batch: int = 256
    kernel_iters: int = 3
    serve: bool = False
    serve_backend: str = "fused-packed"
    serve_requests: int = 2
    serve_batch: int = 64


class SweepRunner:
    """Runs grid points through the shared pipeline.

    Every point is materialized as ONE ``repro.dwn.DWNArtifact``
    (spec → trained → frozen → packed); trained params are still shared
    across points that differ only in TEN/PEN + input width (the paper's
    weight-sharing protocol) via the ``_models`` memo, and each variant's
    artifact ``adopt``s them before freezing to its own operating point.
    """

    def __init__(self, settings: SweepSettings):
        self.settings = settings
        self._data: dict[str, object] = {}          # workload -> split
        self._models: dict[tuple, tuple] = {}       # (wl,preset,T,pl) -> (cfg,p,b)
        self._artifacts: dict[SweepPoint, DWNArtifact] = {}
        self._serve: dict[tuple, tuple] = {}        # point key -> (thru, p50)

    # -- data ----------------------------------------------------------

    def data_for(self, workload: str):
        """The workload's canonical split at the sweep's fidelity knobs
        (loaded once per workload per runner)."""
        if workload not in self._data:
            from ..workloads import load_workload
            s = self.settings
            self._data[workload] = load_workload(
                workload, s.n_train, s.n_test, seed=s.data_seed)
        return self._data[workload]

    @property
    def data(self):
        """Back-compat alias: the JSC split (pre-registry callers)."""
        return self.data_for("jsc")

    # -- spec / model / artifact ---------------------------------------

    def spec_for(self, point: SweepPoint) -> DWNSpec:
        """The validated spec of one grid point (carries the serving
        datapath the point is timed on)."""
        return DWNSpec.from_point(point,
                                  datapath=self.settings.serve_backend)

    @staticmethod
    def _cfg_for(point: SweepPoint) -> DWNConfig:
        return DWNSpec.from_point(point).dwn_config()

    def _init_model(self, cfg: DWNConfig, workload: str = "jsc"):
        s = self.settings
        data = self.data_for(workload)
        if s.warmstart:
            return warmstart_dwn(jax.random.PRNGKey(s.seed), cfg,
                                 data.x_train, data.y_train)
        return init_dwn(jax.random.PRNGKey(s.seed), cfg, data.x_train)

    def prepare_models(self, points) -> int:
        """Batch-train the models several grid points share, ahead of the
        per-point loop.

        Points group by (workload, preset, T): members differ only in
        threshold placement, so their params/buffers are same-shape
        arrays and a whole group trains as ONE vmapped scan-compiled
        program
        (``repro.training.batch.train_dwn_batch``) instead of sequential
        loops.  Groups of one fall through to :meth:`model_for`.

        Determinism caveat: a point's group is the set of *uncached*
        points sharing its (preset, T), so in principle vmap-level fp
        drift could vary with which grid subset runs together.  In
        practice the parity tests pin batched == sequential trajectories
        bit-exactly on this backend, and any residual drift is in the
        ~1e-6 class the sweep's accuracy tolerances already absorb.

        Returns the number of models trained in batched calls.
        """
        s = self.settings
        if s.train_epochs <= 0:
            return 0
        groups: dict[tuple, list] = {}
        for pt in points:
            key = (pt.workload, pt.preset, pt.bits, pt.placement)
            if key in self._models:
                continue
            grp = groups.setdefault((pt.workload, pt.preset, pt.bits), [])
            if key not in [k for k, _ in grp]:
                grp.append((key, pt))
        from ..training import train_dwn_batch
        trained = 0
        for (workload, _, _), members in groups.items():
            if len(members) < 2:
                continue
            cfgs = [self._cfg_for(pt) for _, pt in members]
            models = [self._init_model(c, workload) for c in cfgs]
            out = train_dwn_batch(
                cfgs[0], self.data_for(workload), epochs=s.train_epochs,
                seeds=[s.seed] * len(members), models=models,
                batch=s.train_batch, lr=s.lr, eval_final=False)
            for (key, _), cfg, res in zip(members, cfgs, out.results):
                self._models[key] = (cfg, res.params, res.buffers)
                trained += 1
        return trained

    def model_for(self, point: SweepPoint):
        """(DWNConfig, params, buffers) for the point's model shape —
        built once per unique (workload, preset, T, placement)."""
        key = (point.workload, point.preset, point.bits, point.placement)
        if key not in self._models:
            s = self.settings
            cfg = self._cfg_for(point)
            params, buffers = self._init_model(cfg, point.workload)
            if s.train_epochs > 0:
                res = train_dwn(cfg, self.data_for(point.workload),
                                epochs=s.train_epochs,
                                batch=s.train_batch, lr=s.lr, seed=s.seed,
                                params=params, buffers=buffers,
                                eval_every=0, verbose=False)
                params, buffers = res.params, res.buffers
            self._models[key] = (cfg, params, buffers)
        return self._models[key]

    def artifact_for(self, point: SweepPoint) -> DWNArtifact:
        """The point's frozen :class:`DWNArtifact` — built once per point;
        trained state is adopted from the shared ``model_for`` memo, then
        frozen at the point's own operating point (PEN points quantize
        thresholds to the spec's (1, n) fixed-point grid)."""
        if point not in self._artifacts:
            _, params, buffers = self.model_for(point)
            art = DWNArtifact(self.spec_for(point))
            art.adopt(params, buffers, note="sweep").freeze()
            self._artifacts[point] = art
        return self._artifacts[point]

    # -- measurement axes ----------------------------------------------

    def _time_kernel(self, art: DWNArtifact) -> float:
        """Fused packed kernel wall time in µs per kernel_batch call.

        PEN points quantize inputs to the spec's (1, n) grid inside the
        timed step, exactly like the production fused backend — the
        kernel axis times the same datapath serving runs.
        """
        s = self.settings
        packed = art.pack().packed
        inner = fused_ops.make_forward_packed(
            packed.thresholds, packed.mappings, packed.tables,
            art.spec.dwn_config().num_classes)
        frac = art.frozen.input_frac_bits

        def step(x):
            if frac is not None:
                from ..core.thermometer import quantize_fixed_point
                x = quantize_fixed_point(x, frac)
            return inner(x)

        fwd = jax.jit(step)
        data = self.data_for(art.spec.workload)
        n = data.x_test.shape[0]
        reps = -(-s.kernel_batch // n)             # tile if the split is small
        x = jnp.asarray(np.tile(data.x_test,
                                (reps, 1))[:s.kernel_batch])
        fwd(x)[1].block_until_ready()              # compile outside timing
        best = float("inf")
        for _ in range(max(s.kernel_iters, 1)):
            t0 = time.perf_counter()
            fwd(x)[1].block_until_ready()
            best = min(best, time.perf_counter() - t0)
        return best * 1e6

    def _serve_point(self, point: SweepPoint) -> tuple[float, float]:
        """(throughput samples/s, p50 compute ms) through the engine —
        the point's own packed artifact is what gets served (PEN points
        serve the quantized datapath, bit-exact vs the oracle)."""
        key = (point.workload, point.preset, point.bits, point.placement,
               point.variant, point.input_bits)
        if key not in self._serve:
            from ..serving import ServingEngine
            s = self.settings
            engine = ServingEngine(
                self.artifact_for(point).pack(),
                backend=s.serve_backend, max_bucket=s.serve_batch,
                min_bucket=min(8, s.serve_batch),
                n_train=min(s.n_train, 2000), seed=s.seed)
            engine.warmup(s.serve_batch)
            for i in range(s.serve_requests):
                engine.submit(engine.make_request(s.serve_batch, seed=i))
            engine.drain()
            rep = engine.report()
            self._serve[key] = (
                rep["throughput_samples_per_s"],
                rep["latency"]["compute_ms"]["p50"])
        return self._serve[key]

    # -- one point -----------------------------------------------------

    def run_point(self, point: SweepPoint) -> PointResult:
        """Run every enabled axis at one grid point."""
        s = self.settings
        art = self.artifact_for(point)
        rep = dwn_hw_report(art)
        paper = paper_reference(point)
        res = PointResult(
            point=point,
            luts=dict(rep.luts), total_luts=rep.total_luts,
            total_ffs=rep.total_ffs, delay_ns=round(rep.delay_ns, 3),
            fmax_mhz=round(rep.fmax_mhz, 1),
            distinct_comparators=rep.distinct_comparators,
            paper_luts=paper,
            lut_error_pct=lut_error_pct(rep.total_luts, paper),
            encoder_share=round(rep.luts.get("encoder", 0)
                                / max(rep.total_luts, 1), 4))
        if s.accuracy:
            data = self.data_for(point.workload)
            res.accuracy = eval_accuracy_hard_packed(
                art.frozen, data.x_test, data.y_test)
        if s.kernel:
            res.kernel_us = round(self._time_kernel(art), 1)
            res.kernel_batch = s.kernel_batch
        if s.serve:
            thru, p50 = self._serve_point(point)
            res.serve_throughput = thru
            res.serve_p50_ms = p50
            res.serve_backend = s.serve_backend
        return res


def scan_cache(points, settings: SweepSettings, cache: SweepCache,
               fresh: bool = False) -> dict[int, PointResult]:
    """Index -> cached :class:`PointResult` for every point whose key is
    present and loadable.  Corrupt entries and stale schemas read as
    misses (the point recomputes); ``fresh`` misses everything.
    """
    hits: dict[int, PointResult] = {}
    for i, point in enumerate(points):
        hit = None if fresh else cache.get(point_key(point, settings))
        if hit is not None:
            try:
                res = PointResult.from_dict(hit)
                res.cached = True
                hits[i] = res
            except (TypeError, KeyError):      # stale schema: recompute
                pass
    return hits


def persist_artifact(runner: SweepRunner, point: SweepPoint, key: str,
                     artifact_dir: str | None) -> str | None:
    """Save the point's packed :class:`~repro.dwn.DWNArtifact` under
    ``artifact_dir/<label>-<key[:8]>`` via ``runtime.checkpoint.
    save_artifact`` (atomic, sha256-verified).  Returns the checkpoint
    path, or None when ``artifact_dir`` is unset."""
    if not artifact_dir:
        return None
    from pathlib import Path

    from ..runtime.checkpoint import save_artifact
    art = runner.artifact_for(point)
    art.pack()
    safe = point.label.replace("/", "_").replace("@", "")
    dest = Path(artifact_dir) / f"{safe}-{key[:8]}"
    return str(save_artifact(dest, art))


def run_grid(grid: str | list, settings: SweepSettings | None = None, *,
             cache_dir: str | None = "results/sweep_cache",
             fresh: bool = False, log=None,
             artifact_dir: str | None = None) -> SweepResult:
    """Run a whole grid through the pipeline, with incremental caching.

    This is the **serial** in-process runner; the fault-tolerant parallel
    executor (worker processes, bounded restarts, straggler re-dispatch,
    preemption draining) is :func:`repro.sweep.executor.run_grid_parallel`
    — both persist through the same cache, so runs can be freely resumed
    across the two.

    Args:
      grid: a named grid / JSON path (see ``grid.load_grid``) or an
        explicit list of :class:`SweepPoint`.
      settings: fidelity knobs; defaults to :class:`SweepSettings`().
      cache_dir: result-cache root; None disables caching.
      fresh: ignore (but still refresh) the cache.
      log: optional ``print``-like progress callback.
      artifact_dir: when set, every computed point's packed artifact is
        checkpointed here (``runtime.checkpoint.save_artifact``).

    Returns the :class:`SweepResult` over every point.
    """
    settings = settings or SweepSettings()
    points = load_grid(grid) if isinstance(grid, str) else list(grid)
    name = grid if isinstance(grid, str) else "custom"
    cache = SweepCache(cache_dir)
    t_start = time.perf_counter()
    runner: SweepRunner | None = None
    hits = scan_cache(points, settings, cache, fresh)
    misses = [p for i, p in enumerate(points) if i not in hits]
    if misses:                                 # lazy: all-hit runs are free
        runner = SweepRunner(settings)
        # train shape-compatible models of the uncached points as one
        # vmapped program each, before the per-point measurement loop
        n_batched = runner.prepare_models(misses)
        if log and n_batched:
            log(f"batch-trained {n_batched} models "
                f"({settings.train_epochs} epochs, one program per group)")
    out = []
    for i, point in enumerate(points):
        res = hits.get(i)
        if res is None:
            t0 = time.perf_counter()
            res = runner.run_point(point)
            key = point_key(point, settings)
            cache.put(key, res.to_dict())
            persist_artifact(runner, point, key, artifact_dir)
            if log:
                log(f"[{i + 1}/{len(points)}] {point.label}: "
                    f"{res.total_luts} LUTs "
                    f"({time.perf_counter() - t0:.1f}s)")
        elif log:
            log(f"[{i + 1}/{len(points)}] {point.label}: cached")
        out.append(res)
    executor = {"mode": "serial", "workers": 0,
                "computed": len(misses), "cache_hits": len(hits),
                "failed": [], "restarts": 0,
                "stragglers_redispatched": 0, "interrupted": False,
                "remaining": 0, "cache": dict(cache.stats),
                "wall_s": round(time.perf_counter() - t_start, 3)}
    return SweepResult(grid=name, settings=dataclasses.asdict(settings),
                       points=out, executor=executor)


__all__ = ["SweepRunner", "SweepSettings", "persist_artifact", "run_grid",
           "scan_cache"]
