"""Config-hash keyed result cache: grid re-runs are incremental.

Every (point, settings) pair hashes to a stable key; a completed point's
:class:`~repro.sweep.results.PointResult` is stored as one JSON file under
the cache root.  Re-running a grid recomputes only the points whose key is
missing — extend a grid by one axis value and only the new column runs.

The key covers everything that changes the numbers: the grid point, the
full settings (timing knobs deliberately included — a cached throughput
measured at a different batch is not the same measurement), and a
fingerprint of the number-determining source modules (cost model, core
semantics, the pipeline itself), so editing e.g. a constant in
``hw/cost.py`` invalidates old entries instead of silently serving them.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import uuid
from pathlib import Path


def _code_fingerprint() -> str:
    """Hash of the source files whose edits change sweep numbers.

    Includes the trainer stack (core/training, core/lut_layer, optim/adam,
    and the scan engine + batch trainer) and the ``repro.dwn`` lifecycle
    package (spec + artifact): cached results were produced by those
    semantics, so editing them must invalidate, not silently serve, old
    entries.
    """
    import repro.core.model as m1
    import repro.core.thermometer as m2
    import repro.hw.cost as m3
    from . import pipeline as m4
    import repro.core.training as m5
    import repro.core.lut_layer as m6
    import repro.optim.adam as m7
    import repro.training.engine as m8
    import repro.training.batch as m9
    import repro.dwn.spec as m10
    import repro.dwn.artifact as m11
    h = hashlib.sha256()
    for mod in (m1, m2, m3, m4, m5, m6, m7, m8, m9, m10, m11):
        try:
            with open(mod.__file__, "rb") as fh:
                h.update(fh.read())
        except OSError:
            h.update(mod.__name__.encode())    # no source (frozen): name only
    return h.hexdigest()[:16]


_FINGERPRINT: str | None = None


def config_hash(payload: dict) -> str:
    """Stable short hash of a JSON-able payload (sorted-key canonical form).

    Returns the first 16 hex chars of the sha256 — enough to never collide
    over any realistic grid, short enough for filenames.
    """
    blob = json.dumps(payload, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def point_key(point, settings) -> str:
    """Cache key for one (SweepPoint, SweepSettings) pair — also keyed by
    the point's resolved :class:`~repro.dwn.spec.DWNSpec` (the typed
    identity every artifact is built from) and the code fingerprint
    (computed once per process)."""
    global _FINGERPRINT
    if _FINGERPRINT is None:
        _FINGERPRINT = _code_fingerprint()
    from ..dwn.spec import DWNSpec
    spec = DWNSpec.from_point(point, datapath=settings.serve_backend)
    return config_hash({"point": point.to_dict(),
                        "settings": dataclasses.asdict(settings),
                        "spec": spec.to_dict(),
                        "code": _FINGERPRINT})


class SweepCache:
    """Filesystem cache of completed sweep points.

    Crash/concurrency contract (what the resilient executor relies on):

    * ``put`` is **atomic**: the entry is serialized to a uniquely-named
      temp file in the cache root, fsync'd, and published with
      ``os.replace`` — a reader never observes a half-written entry, and
      a writer killed mid-``put`` leaves only an orphan ``*.tmp`` (swept
      by the next ``put``), never a corrupt key.  Unique temp names make
      concurrent writers (parallel sweep workers, possibly of the *same*
      key after a straggler re-dispatch) last-writer-wins safe.
    * ``get`` treats a corrupt or non-dict entry as a **miss** — the
      point recomputes; the bad file is unlinked so it cannot shadow the
      recomputed result.

    ``stats`` counts hits / misses / corrupt entries for the run, which
    is how the chaos-resume CI smoke asserts "zero recomputed points".

    Args:
      root: cache directory (created on first ``put``); None disables
        caching entirely (``get`` always misses, ``put`` is a no-op).
    """

    def __init__(self, root: str | Path | None):
        self.root = Path(root) if root else None
        self.stats = {"hits": 0, "misses": 0, "corrupt": 0}

    def _path(self, key: str) -> Path:
        assert self.root is not None
        return self.root / f"{key}.json"

    def get(self, key: str) -> dict | None:
        """Return the cached result dict for ``key``, or None on miss.

        A corrupt cache file (e.g. a non-atomic writer killed mid-write,
        or disk damage) reads as a miss, never an error — the entry is
        unlinked and the point just recomputes.
        """
        if self.root is None:
            return None
        p = self._path(key)
        try:
            with open(p) as fh:
                out = json.load(fh)
            if not isinstance(out, dict):
                raise json.JSONDecodeError("not an object", "", 0)
        except FileNotFoundError:
            self.stats["misses"] += 1
            return None
        except (json.JSONDecodeError, OSError):
            self.stats["corrupt"] += 1
            self.stats["misses"] += 1
            p.unlink(missing_ok=True)
            return None
        self.stats["hits"] += 1
        return out

    def put(self, key: str, result: dict) -> None:
        """Store a result dict under ``key`` (atomic, concurrent-safe)."""
        if self.root is None:
            return
        self.root.mkdir(parents=True, exist_ok=True)
        self._sweep_orphans()
        tmp = self.root / f".{key}.{os.getpid()}.{uuid.uuid4().hex[:8]}.tmp"
        try:
            with open(tmp, "w") as fh:
                json.dump(result, fh, indent=1)
                fh.flush()
                os.fsync(fh.fileno())
            os.replace(tmp, self._path(key))
        finally:
            tmp.unlink(missing_ok=True)

    def _sweep_orphans(self) -> None:
        """Delete temp files abandoned by killed writers (best-effort;
        a *live* concurrent writer's temp is at most re-created)."""
        for orphan in self.root.glob(".*.tmp"):
            try:
                if orphan.stat().st_mtime < _now() - 3600:
                    orphan.unlink(missing_ok=True)
            except OSError:
                pass


def _now() -> float:
    import time
    return time.time()


__all__ = ["SweepCache", "config_hash", "point_key"]
