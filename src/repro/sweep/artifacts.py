"""Paper-artifact regeneration: Table I/III rows, Fig. 2/5/6 data.

The four paper benchmarks (``benchmarks/{table1,fig2,fig5,fig6}*``) used
to each carry their own model/cost plumbing; that logic lives here now and
the benchmarks are thin printing wrappers.  Everything returns plain data
(rows, dicts, points) so the sweep CLI, the benchmarks, and the tests all
regenerate the same numbers from the same code.

Units: LUT/FF counts are physical LUT6/flip-flop counts from the
technology-mapped cost model (``hw.cost``); accuracies are fractions in
[0, 1] except where a row explicitly stores the paper's percent figures.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..hw.cost import dwn_hw_report
from ..hw.report import PAPER_TABLE1, PAPER_TABLE2, PAPER_TABLE3
from .grid import SweepPoint

#: Documented reproduction tolerance of the Table I TEN LUT counts
#: (relative error of our structural generator vs the paper's Vivado
#: results; see docs/reproduction.md).  Vivado cross-optimizes the tiny
#: sm-10 design further than any structural generator can, hence its
#: looser bound.
TABLE1_TEN_TOLERANCE = {"sm-10": 0.45, "sm-50": 0.10,
                        "md-360": 0.05, "lg-2400": 0.05}

#: Model sizes in Table I order — every per-preset artifact walks these.
PRESETS = ("sm-10", "sm-50", "md-360", "lg-2400")


def paper_reference(point: SweepPoint) -> int | None:
    """The paper's LUT count for a sweep point, if it matches a published
    operating point (T=200, distributive placement), else None.

    TEN points land on the Table I/III TEN rows.  PEN points land on the
    PEN+FT row when ``input_bits`` equals the fine-tuned width, or the
    plain PEN row when it equals the PTQ-only width.
    """
    if point.bits != 200 or point.placement != "distributive":
        return None
    row = PAPER_TABLE3.get(point.preset)
    if row is None:
        return None
    if point.variant == "TEN":
        return row["ten_luts"]
    if point.input_bits == row["ft_bits"]:
        return row["ft_luts"]
    if point.input_bits == row["pen_bits"]:
        return row["pen_luts"]
    return None


def lut_error_pct(total_luts: int, paper_luts: int | None) -> float | None:
    """Signed relative LUT error vs the paper, in percent (None w/o ref)."""
    if not paper_luts:
        return None
    return 100.0 * (total_luts - paper_luts) / paper_luts


# ---------------------------------------------------------------------------
# Table I — hardware comparison rows (TEN and PEN+FT per preset)
# ---------------------------------------------------------------------------

def table1_model_rows(bundle: dict, name: str) -> list[tuple]:
    """Table I rows for one trained bundle (see ``benchmarks/common.py``).

    Args:
      bundle: trained-model dict with ``frozen_ten``, ``frozen_ft`` and
        ``ft_bits`` keys (what ``load_trained`` returns).
      name: preset name, used for the paper lookup.

    Returns ``[(variant, HWReport, paper_row_dict, err_pct), ...]`` for
    the TEN and PEN+FT variants — exactly the numbers the pre-refactor
    benchmark computed inline.
    """
    rep_ten = dwn_hw_report(bundle["frozen_ten"], variant="TEN", name=name)
    rep_ft = dwn_hw_report(bundle["frozen_ft"], variant="PEN+FT", name=name,
                           input_bits=bundle["ft_bits"])
    rows = []
    for variant, rep in (("TEN", rep_ten), ("PEN+FT", rep_ft)):
        paper = PAPER_TABLE1.get((name, variant), {})
        err = (100.0 * (rep.total_luts - paper["luts"]) / paper["luts"]
               if paper else float("nan"))
        rows.append((variant, rep, paper, err))
    return rows


# ---------------------------------------------------------------------------
# Fig. 2 — distributive vs uniform encoding (sample rows + accuracy delta)
# ---------------------------------------------------------------------------

def placement_popcounts(data, modes=("distributive", "uniform"),
                        num_features: int = 16, bits: int = 200,
                        sample: int = 0) -> dict:
    """Per-feature set-bit counts of one JSC sample under each placement.

    Returns {mode: (num_features,) array, entry f in [0, bits]} — how
    many of feature f's thresholds the sample exceeds, the encodings'
    side-by-side comparison (Fig. 2's left panel).
    """
    from ..core.thermometer import ThermometerSpec, fit_thresholds, encode_np
    x0 = data.x_train[sample:sample + 1]
    out = {}
    for mode in modes:
        spec = ThermometerSpec(num_features, bits, mode)
        th = fit_thresholds(data.x_train, spec)
        out[mode] = encode_np(x0, th, flatten=False)[0].sum(axis=1)
    return out


def encoding_mode_accuracy(data, preset: str, mode: str, *,
                           epochs: int = 6, batch: int = 128,
                           lr: float = 1e-3, seed: int = 0) -> float:
    """Hard-inference accuracy of ``preset`` trained under one placement.

    The training recipe (warmstart, epochs, batch, lr, seed) matches the
    pre-refactor Fig. 2 benchmark exactly, so the regenerated accuracy
    delta is the same number.
    """
    import jax
    from ..core import JSC_PRESETS, train_dwn, freeze, eval_accuracy_hard
    from ..core.warmstart import warmstart_dwn
    cfg = dataclasses.replace(JSC_PRESETS[preset], encoding=mode)
    params, buffers = warmstart_dwn(jax.random.PRNGKey(seed), cfg,
                                    data.x_train, data.y_train)
    res = train_dwn(cfg, data, epochs=epochs, batch=batch, lr=lr,
                    params=params, buffers=buffers, verbose=False)
    return eval_accuracy_hard(freeze(res.params, res.buffers, cfg),
                              data.x_test, data.y_test)


# ---------------------------------------------------------------------------
# Fig. 5 — component LUT breakdown vs input bit-width
# ---------------------------------------------------------------------------

def breakdown_rows(frozen, name: str,
                   bits_range=(6, 7, 8, 9, 10, 11, 12)) -> list[tuple]:
    """PEN+FT component breakdown per input bit-width for one model.

    Returns ``[(input_bits, {component: LUTs}, total_luts), ...]`` — the
    Fig. 5 stacked-bar data.
    """
    rows = []
    for bits in bits_range:
        rep = dwn_hw_report(frozen, variant="PEN+FT", name=name,
                            input_bits=bits)
        rows.append((bits, rep.luts, max(rep.total_luts, 1)))
    return rows


# ---------------------------------------------------------------------------
# Fig. 6 — accuracy vs LUTs scatter (literature + our points)
# ---------------------------------------------------------------------------

def literature_points() -> list[tuple]:
    """Table II's non-DWN rows as ``(label, acc_pct, luts)`` points."""
    return [(m, a, l) for (m, a, l, *_r) in PAPER_TABLE2
            if not m.startswith("DWN")]


def our_points(bundle: dict, name: str) -> list[tuple]:
    """Our TEN and PEN+FT operating points for one trained bundle,
    as ``(label, acc_pct, luts)`` (accuracy in percent, Fig. 6's axis)."""
    ten = dwn_hw_report(bundle["frozen_ten"], variant="TEN", name=name)
    ft = dwn_hw_report(bundle["frozen_ft"], variant="PEN+FT", name=name,
                       input_bits=bundle["ft_bits"])
    return [(f"DWN-TEN({name})[ours]", 100 * bundle["float_acc"],
             ten.total_luts),
            (f"DWN-PEN+FT({name})[ours]", 100 * bundle["ft_acc"],
             ft.total_luts)]


__all__ = [
    "PRESETS", "TABLE1_TEN_TOLERANCE", "breakdown_rows",
    "encoding_mode_accuracy", "literature_points", "lut_error_pct",
    "our_points", "paper_reference", "placement_popcounts",
    "table1_model_rows",
]
