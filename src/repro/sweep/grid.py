"""Design-space grids: which encoding points a sweep visits.

A *grid point* fixes everything the paper shows can dominate DWN hardware
cost: the JSC preset (LUT-layer width m), the encoding variant (TEN — the
accelerator receives thermometer bits; PEN — it receives fixed-point
features and encodes on chip), the encoder resolution (thermometer bits
per feature T), the threshold placement (distributive / uniform /
gaussian), and — for PEN — the input bit-width the on-chip comparators
see.  ``repro.sweep.pipeline`` runs every point through one shared
pipeline (accuracy x FPGA cost x kernel/serving throughput).

Named grids:

* ``tiny``     — 2 presets x {TEN, PEN@4b, PEN@9b}: the CI smoke and the
                 monotonicity test bed (6 points, seconds on CPU).
* ``paper``    — the 4 paper presets x {TEN, PEN at Table I's fine-tuned
                 bit-widths}: regenerates the Table I TEN LUT counts
                 (checked against tolerances in docs/reproduction.md).
* ``encoding`` — sm-50 x 3 placements x T in {50, 100, 200} at PEN 9-bit:
                 the encoding-cost curve (Fig. 2's axis, extended).

Custom grids load from a JSON list of point dicts (see ``load_grid``).
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path

#: encoding variants a sweep point may take (PEN+FT is a training recipe,
#: not a datapath — the sweep treats fine-tuned models as PEN points).
VARIANTS = ("TEN", "PEN")

#: Table I's fine-tuned input bit-widths (total width, sign included) —
#: the PEN operating points the paper grid visits per preset.
PAPER_FT_BITS = {"sm-10": 6, "sm-50": 8, "md-360": 9, "lg-2400": 9}


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    """One point of the encoding design space.

    Attributes:
      preset: workload tier (JSC: "sm-10" | "sm-50" | "md-360" |
        "lg-2400"; MNIST: "mnist-{sm,md,lg}") — fixes the LUT-layer
        width m.
      variant: "TEN" (off-chip encoding, bits arrive pre-encoded) or
        "PEN" (on-chip encoder at ``input_bits``).
      bits: thermometer bits per feature T (encoder resolution).
      placement: threshold placement mode ("distributive" | "uniform" |
        "gaussian").
      input_bits: PEN input width in total bits (1 sign + n fractional);
        None for TEN.
      workload: registered workload name the point trains/evaluates on
        (default "jsc"; see ``repro.workloads``).
    """

    preset: str
    variant: str = "TEN"
    bits: int = 200
    placement: str = "distributive"
    input_bits: int | None = None
    workload: str = "jsc"

    def __post_init__(self):
        assert self.variant in VARIANTS, self.variant
        assert (self.input_bits is None) == (self.variant == "TEN"), self

    @property
    def frac_bits(self) -> int | None:
        """Fractional bits of the (1, n) fixed-point grid; None for TEN."""
        return None if self.input_bits is None else self.input_bits - 1

    @property
    def label(self) -> str:
        b = "" if self.input_bits is None else f"@{self.input_bits}b"
        wl = "" if self.workload == "jsc" else f"{self.workload}:"
        return (f"{wl}{self.preset}/{self.variant}{b}/T{self.bits}/"
                f"{self.placement}")

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        # the default workload is omitted so pre-workload cache keys and
        # saved grid/result JSON stay valid
        if d["workload"] == "jsc":
            del d["workload"]
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "SweepPoint":
        return cls(**d)


def tiny_grid() -> list[SweepPoint]:
    """2 presets x {TEN, PEN@4b, PEN@9b} — the smoke/test grid."""
    pts = []
    for preset in ("sm-10", "sm-50"):
        pts.append(SweepPoint(preset, "TEN"))
        for ib in (4, 9):
            pts.append(SweepPoint(preset, "PEN", input_bits=ib))
    return pts


def paper_grid() -> list[SweepPoint]:
    """4 presets x {TEN, PEN at Table I's fine-tuned widths}."""
    pts = []
    for preset in ("sm-10", "sm-50", "md-360", "lg-2400"):
        pts.append(SweepPoint(preset, "TEN"))
        pts.append(SweepPoint(preset, "PEN",
                              input_bits=PAPER_FT_BITS[preset]))
    return pts


def encoding_grid() -> list[SweepPoint]:
    """sm-50 x 3 placements x T in {50, 100, 200} at PEN 9-bit."""
    pts = []
    for placement in ("distributive", "uniform", "gaussian"):
        for T in (50, 100, 200):
            pts.append(SweepPoint("sm-50", "PEN", bits=T,
                                  placement=placement, input_bits=9))
    return pts


def mnist_tiny_grid() -> list[SweepPoint]:
    """mnist-sm x {TEN, PEN@5b, PEN@8b} + mnist-md TEN — the MNIST CI
    smoke grid (synthetic fallback; seconds on CPU at small T)."""
    pts = [SweepPoint("mnist-sm", "TEN", bits=8, workload="mnist")]
    for ib in (5, 8):
        pts.append(SweepPoint("mnist-sm", "PEN", bits=8, input_bits=ib,
                              workload="mnist"))
    pts.append(SweepPoint("mnist-md", "TEN", bits=8, workload="mnist"))
    return pts


def mnist_grid() -> list[SweepPoint]:
    """{sm,md,lg} x {TEN, PEN@5b, PEN@8b} — the encoding-LUT-share
    analysis on the second dataset (sm/md at T=8, lg at T=16)."""
    pts = []
    for preset, T in (("mnist-sm", 8), ("mnist-md", 8), ("mnist-lg", 16)):
        pts.append(SweepPoint(preset, "TEN", bits=T, workload="mnist"))
        for ib in (5, 8):
            pts.append(SweepPoint(preset, "PEN", bits=T, input_bits=ib,
                                  workload="mnist"))
    return pts


GRIDS = {"tiny": tiny_grid, "paper": paper_grid, "encoding": encoding_grid,
         "mnist-tiny": mnist_tiny_grid, "mnist": mnist_grid}


def load_grid(name_or_path: str) -> list[SweepPoint]:
    """Resolve a grid: a named grid or a JSON file of point dicts.

    Args:
      name_or_path: one of ``GRIDS`` or a path to a JSON list, e.g.
        ``[{"preset": "sm-50", "variant": "PEN", "input_bits": 8}, ...]``.

    Returns the list of :class:`SweepPoint`.
    """
    if name_or_path in GRIDS:
        return GRIDS[name_or_path]()
    path = Path(name_or_path)
    if not path.exists():
        raise ValueError(f"unknown grid {name_or_path!r}: not a named grid "
                         f"({sorted(GRIDS)}) and no such file")
    with open(path) as fh:
        return [SweepPoint.from_dict(d) for d in json.load(fh)]


__all__ = ["GRIDS", "PAPER_FT_BITS", "SweepPoint", "VARIANTS",
           "encoding_grid", "load_grid", "mnist_grid", "mnist_tiny_grid",
           "paper_grid", "tiny_grid"]
