"""Encoding-aware autodesign: Pareto front -> chosen spec -> verified RTL.

Automates the paper's core finding as a search.  Thermometer encoding
can be up to 3.20x of DWN LUT cost, so the cheapest design meeting an
accuracy target is an *encoding* choice as much as an architecture
choice.  A completed sweep already measured accuracy and LUTs at every
grid point; :func:`choose_design` walks the accuracy-vs-LUTs Pareto
frontier to pick

* the **minimum-LUT** point with ``accuracy >= acc_floor``, or
* the **maximum-accuracy** point with ``total_luts <= lut_budget``,

and :func:`emit_verified` rebuilds that point's artifact deterministically
(same memoized path the sweep used), co-simulates the emitted Verilog
against the packed oracle (``hw.cosim.verify_rtl`` — bit-exact on real
JSC vectors, raising ``RTLMismatch`` on any disagreement), and writes the
*verified* RTL plus a JSON summary.  One command end to end::

    python -m repro.launch.sweep --grid encoding --autodesign --acc-floor 0.70
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path

from .pipeline import SweepRunner, SweepSettings
from .results import SweepResult


class AutodesignError(ValueError):
    """No sweep point satisfies the requested objective."""


@dataclasses.dataclass
class AutodesignChoice:
    """A selected design point plus the objective that selected it."""

    result: object                # the winning PointResult
    objective: str                # "min-luts@acc>=X" | "max-acc@luts<=N"
    acc_floor: float | None = None
    lut_budget: int | None = None
    front_size: int = 0
    candidates: int = 0

    @property
    def point(self):
        return self.result.point

    def to_dict(self) -> dict:
        return {"objective": self.objective,
                "acc_floor": self.acc_floor,
                "lut_budget": self.lut_budget,
                "front_size": self.front_size,
                "candidates": self.candidates,
                "chosen": self.result.to_dict()}


def choose_design(result: SweepResult, *, acc_floor: float | None = None,
                  lut_budget: int | None = None) -> AutodesignChoice:
    """Pick a design from a completed sweep's Pareto frontier.

    Exactly one of ``acc_floor`` / ``lut_budget`` must be given.  The
    accuracy-vs-LUTs front is sorted by ascending LUT cost with strictly
    increasing accuracy, so the first front point clearing the floor IS
    the minimum-LUT qualifying design, and the last front point under
    the budget IS the maximum-accuracy affordable one.

    Raises :class:`AutodesignError` when nothing qualifies (no silent
    fallback — an unmet floor must fail the command).
    """
    if (acc_floor is None) == (lut_budget is None):
        raise AutodesignError(
            "choose_design needs exactly one objective: acc_floor "
            "(min LUTs at an accuracy floor) or lut_budget "
            "(max accuracy under a LUT budget)")
    front = [r for r in result.accuracy_vs_luts_front()
             if r.accuracy is not None]
    if not front:
        raise AutodesignError(
            "sweep has no accuracy measurements (ran with --no-accuracy?) "
            "— autodesign needs the accuracy-vs-LUTs front")
    if acc_floor is not None:
        for r in front:
            if r.accuracy >= acc_floor:
                return AutodesignChoice(
                    result=r, objective=f"min-luts@acc>={acc_floor}",
                    acc_floor=acc_floor, front_size=len(front),
                    candidates=len(result.points))
        best = max(front, key=lambda r: r.accuracy)
        raise AutodesignError(
            f"no sweep point reaches accuracy {acc_floor:.4f}; best on "
            f"the front is {best.accuracy:.4f} ({best.point.label}, "
            f"{best.total_luts} LUTs)")
    chosen = None
    for r in front:
        if r.total_luts <= lut_budget:
            chosen = r                      # front ascends in both axes
    if chosen is None:
        cheapest = front[0]
        raise AutodesignError(
            f"no sweep point fits the {lut_budget}-LUT budget; cheapest "
            f"on the front is {cheapest.total_luts} LUTs "
            f"({cheapest.point.label})")
    return AutodesignChoice(
        result=chosen, objective=f"max-acc@luts<={lut_budget}",
        lut_budget=lut_budget, front_size=len(front),
        candidates=len(result.points))


def emit_verified(choice: AutodesignChoice,
                  settings: SweepSettings | None = None, *,
                  out_dir, n_vectors: int = 256, backend: str = "auto",
                  pipeline: bool = True, log=print) -> dict:
    """Rebuild the chosen point, co-simulate its RTL, write the artifacts.

    The artifact is rebuilt through ``SweepRunner.artifact_for`` — the
    same deterministic memoized path the sweep measured — then
    ``verify_rtl`` proves the emitted netlist bit-exact against
    ``apply_hard_packed`` on ``n_vectors`` held-out JSC vectors.  Any
    disagreement raises ``hw.cosim.RTLMismatch`` (the CLI turns that
    into a non-zero exit); nothing is written for an unverified design
    except the exception itself.

    Writes ``dwn_autodesign.v`` (the verified RTL) and
    ``autodesign.json`` (choice + verification report) into ``out_dir``;
    returns the summary dict.
    """
    runner = SweepRunner(settings or SweepSettings())
    art = runner.artifact_for(choice.point)
    x = runner.data.x_test[:n_vectors]
    report = art.verify_rtl(x, backend=backend, pipeline=pipeline,
                            name="dwn_autodesign")
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    rtl_path = out / "dwn_autodesign.v"
    rtl_path.write_text(report.src)
    summary = {"choice": choice.to_dict(),
               "spec": art.spec.to_dict(),
               "spec_label": art.spec.label,
               "verification": report.to_dict(),
               "rtl": rtl_path.name}
    (out / "autodesign.json").write_text(json.dumps(summary, indent=1))
    if log:
        log(f"autodesign: {choice.objective} -> {choice.point.label} "
            f"({choice.result.total_luts} LUTs, "
            f"acc={choice.result.accuracy:.4f})")
        log(f"autodesign: RTL verified bit-exact on {report.n_vectors} "
            f"vectors ({'+'.join(report.backends)}) -> {rtl_path}")
    return summary


__all__ = ["AutodesignChoice", "AutodesignError", "choose_design",
           "emit_verified"]
