"""Encoding-aware design-space exploration (the repo's experiment platform).

The paper's headline result — thermometer encoding can dominate DWN
hardware cost (up to 3.20x LUTs on JSC) — means encoding choices must be
co-designed with the rest of the accelerator.  ``repro.sweep`` walks that
design space end-to-end: a grid over {JSC preset, TEN/PEN, thermometer
bits, threshold placement} runs through one shared pipeline measuring
accuracy (packed hard inference), FPGA cost (``hw.cost``), and TPU
throughput (fused kernel + serving engine), emitting one ``SweepResult``
table, Pareto fronts, and the regenerated paper artifacts.

Entry points: ``python -m repro.launch.sweep --grid paper`` (CLI),
:func:`run_grid` (library), and ``repro.sweep.artifacts`` (the shared
logic behind ``benchmarks/{table1,fig2,fig5,fig6}*``).  docs/sweep.md has
the walkthrough.
"""

from . import artifacts
from .autodesign import (AutodesignChoice, AutodesignError, choose_design,
                         emit_verified)
from .cache import SweepCache, config_hash, point_key
from .executor import ChaosSpec, ExecutorSettings, run_grid_parallel
from .grid import GRIDS, SweepPoint, load_grid
from .pipeline import SweepRunner, SweepSettings, run_grid
from .results import PointResult, SweepResult, pareto_front

__all__ = [
    "AutodesignChoice", "AutodesignError", "ChaosSpec", "ExecutorSettings",
    "GRIDS", "PointResult", "SweepCache", "SweepPoint", "SweepResult",
    "SweepRunner", "SweepSettings", "artifacts", "choose_design",
    "config_hash", "emit_verified", "load_grid", "pareto_front",
    "point_key", "run_grid", "run_grid_parallel",
]
