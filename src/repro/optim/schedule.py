"""LR schedules: StepLR (paper §III), warmup-cosine, constant.

Schedules are ``step -> lr`` callables over the *optimizer step* counter;
`steps_per_epoch` converts the paper's epoch-based StepLR to step units.
"""

from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def step_lr(base_lr: float, step_size_epochs: int, gamma: float,
            steps_per_epoch: int):
    """Paper §III: StepLR(step_size=30, gamma=0.1) on epochs.

    lr = base_lr * gamma ** floor(epoch / step_size_epochs).

    ``step`` is the optimizer-step counter (traced array inside jit/scan,
    or a plain int when probing the schedule from the host, e.g. for
    logging the epoch-boundary lr in the training history).
    """
    def fn(step):
        step = jnp.asarray(step)
        epoch = step.astype(jnp.float32) / float(max(1, steps_per_epoch))
        k = jnp.floor(epoch / float(step_size_epochs))
        return jnp.asarray(base_lr, jnp.float32) * (gamma ** k)
    return fn


def warmup_cosine(base_lr: float, warmup_steps: int, total_steps: int,
                  min_ratio: float = 0.1):
    def fn(step):
        s = jnp.asarray(step).astype(jnp.float32)
        warm = s / jnp.maximum(1.0, float(warmup_steps))
        prog = jnp.clip((s - warmup_steps) /
                        jnp.maximum(1.0, float(total_steps - warmup_steps)),
                        0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * jnp.where(s < warmup_steps, warm, cos)
    return fn
