"""Gradient-processing utilities for large-scale training.

* global-norm clipping,
* microbatch gradient accumulation via ``lax.scan`` (compute/comm overlap:
  the psum of the *accumulated* gradient happens once per step),
* top-k gradient compression with error feedback (EF-SGD style) for the
  slow cross-pod axis — a distributed-optimization trick validated on CPU.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(x.astype(jnp.float32) ** 2) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda x: x * scale, tree), norm


def accumulate_grads(loss_fn, params, batches, num_micro: int):
    """Average grads over ``num_micro`` microbatches with lax.scan.

    ``batches`` is a pytree whose leaves have a leading (num_micro, ...) dim.
    Returns (mean_loss, mean_grads).
    """
    def body(carry, micro):
        acc_loss, acc_grads = carry
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, micro)
        acc_grads = jax.tree.map(jnp.add, acc_grads, grads)
        return (acc_loss + loss, acc_grads), None

    zero_grads = jax.tree.map(jnp.zeros_like, params)
    (loss_sum, grad_sum), _ = jax.lax.scan(
        body, (jnp.zeros(()), zero_grads), batches, length=num_micro)
    k = 1.0 / num_micro
    return loss_sum * k, jax.tree.map(lambda g: g * k, grad_sum)


class CompressionState(NamedTuple):
    error: object  # pytree of residuals (error feedback memory)


def topk_compress_init(params) -> CompressionState:
    return CompressionState(jax.tree.map(jnp.zeros_like, params))


def topk_compress(grads, state: CompressionState, k_frac: float = 0.01):
    """Keep the top ``k_frac`` fraction of entries (by |g|) per leaf; the
    rest accumulates into the error-feedback residual for the next step.

    Returns (sparse_grads, new_state). The sparse grads are dense tensors
    with zeros outside the top-k support (what would be communicated as
    (index, value) pairs on the wire; the wire format is modeled in the
    roofline as k_frac · bytes).
    """
    def one(g, e):
        g = g + e
        flat = jnp.abs(g).reshape(-1)
        k = max(1, int(flat.shape[0] * k_frac))
        thresh = jax.lax.top_k(flat, k)[0][-1]
        mask = (jnp.abs(g) >= thresh).astype(g.dtype)
        sent = g * mask
        return sent, g - sent

    flat, treedef = jax.tree.flatten(grads)
    err = jax.tree.leaves(state.error)
    out = [one(g, e) for g, e in zip(flat, err)]
    sent = jax.tree.unflatten(treedef, [o[0] for o in out])
    resid = jax.tree.unflatten(treedef, [o[1] for o in out])
    return sent, CompressionState(resid)
