"""Adam / AdamW over arbitrary pytrees (no optax in this container).

API mirrors the (init, update) gradient-transformation style so it composes
with the wrappers in :mod:`repro.optim.grad` (clipping, accumulation,
compression).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jax.Array
    mu: object        # pytree like params
    nu: object


@dataclasses.dataclass(frozen=True)
class Adam:
    lr: float | Callable[[jax.Array], jax.Array] = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0           # AdamW-style decoupled decay
    clamp: tuple | None = None          # optional (lo, hi) param clamp

    def init(self, params) -> AdamState:
        zeros = lambda p: jax.tree.map(jnp.zeros_like, p)
        return AdamState(jnp.zeros((), jnp.int32), zeros(params), zeros(params))

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)

    def update(self, grads, state: AdamState, params):
        step = state.step + 1
        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g,
                          state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(p, m, v):
            mhat = m / bc1
            vhat = v / bc2
            new = p - lr * (mhat / (jnp.sqrt(vhat) + self.eps)
                            + self.weight_decay * p)
            if self.clamp is not None:
                new = jnp.clip(new, self.clamp[0], self.clamp[1])
            return new

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamState(step, mu, nu)
