"""Adam / AdamW over arbitrary pytrees (no optax in this container).

API mirrors the (init, update) gradient-transformation style so it composes
with the wrappers in :mod:`repro.optim.grad` (clipping, accumulation,
compression).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jax.Array
    mu: object        # pytree like params
    nu: object


@dataclasses.dataclass(frozen=True)
class Adam:
    lr: float | Callable[[jax.Array], jax.Array] = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0           # AdamW-style decoupled decay
    clamp: tuple | None = None          # optional (lo, hi) param clamp

    def init(self, params) -> AdamState:
        zeros = lambda p: jax.tree.map(jnp.zeros_like, p)
        return AdamState(jnp.zeros((), jnp.int32), zeros(params), zeros(params))

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)

    def update(self, grads, state: AdamState, params):
        """One Adam step over the pytree; returns (new_params, new_state).

        The moment updates and the parameter update are emitted as ONE
        traversal per leaf (not three) so XLA fuses the whole per-leaf
        chain into a single memory pass — on CPU the optimizer is
        bandwidth-bound and the extra passes were ~40% of a DWN training
        step.  The per-element arithmetic is exactly the classic
        three-pass formulation (same expression tree), so results are
        bit-identical; it is also scan/donation-safe: no leaf of
        ``params``/``state`` is read after the new values are built.
        """
        step = state.step + 1
        b1, b2 = self.b1, self.b2
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def leaf(p, m, v, g):
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            new = p - lr * ((m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
                            + self.weight_decay * p)
            if self.clamp is not None:
                new = jnp.clip(new, self.clamp[0], self.clamp[1])
            return new, m, v

        flat_p, tree = jax.tree.flatten(params)
        flat_m = tree.flatten_up_to(state.mu)
        flat_v = tree.flatten_up_to(state.nu)
        flat_g = tree.flatten_up_to(grads)
        out = [leaf(p, m, v, g)
               for p, m, v, g in zip(flat_p, flat_m, flat_v, flat_g)]
        new_params = tree.unflatten([o[0] for o in out])
        mu = tree.unflatten([o[1] for o in out])
        nu = tree.unflatten([o[2] for o in out])
        return new_params, AdamState(step, mu, nu)
