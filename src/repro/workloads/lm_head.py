"""LM-backbone feature workload: the DWN-head-on-qwen3 task, first-class.

Promotes ``examples/dwn_head_lm.py`` from demo to registry entry.  A
reduced qwen3 backbone (fixed init key, frozen) pools its final logits
into 16 features per sequence; labels come from a fixed teacher
projection of those features, so the task isolates what a DWN head can
learn on top of frozen backbone features.  The loader materializes a
seeded (tokens -> features -> labels) split that the ordinary scan
engine trains on — from the trainer's point of view this is just
another 16-feature 5-class dataset, which is exactly why the registry
abstraction pays off.

:func:`pool_features` is the canonical feature stage: the serving
engine's ``dwn_head`` path applies the *same* pooling to its own
backbone's prefill logits, so a head trained here serves bit-exact on
matching features.

The backbone forward is the expensive part, so the workload caps split
sizes (``cap_train``/``cap_test``) and caches loads in-process.
"""

from __future__ import annotations

import numpy as np

from ..core.model import DWNConfig
from ..data.jsc import JSCData
from .base import Workload, register_workload

FEATS = 16
NUM_CLASSES = 5
SEQ_LEN = 32
BACKBONE = "qwen3-8b"

LM_HEAD_PRESETS = {
    "lm-head-50": DWNConfig(num_features=FEATS, bits_per_feature=64,
                            encoding="uniform", lut_counts=(50,),
                            num_classes=NUM_CLASSES),
}


def pool_features(logits):
    """Pool full-sequence backbone logits into FEATS head features.

    ``tanh(0.3 * mean-over-sequence logits[:, :FEATS])`` — identical to
    the original demo, and shared by the loader and the serving engine's
    ``dwn_head`` path so training and serving see the same features.
    """
    import jax.numpy as jnp
    pooled = logits.mean(axis=1)[:, :FEATS].astype(jnp.float32)
    return jnp.tanh(pooled * 0.3)


_BACKBONE_CACHE: dict | None = None


def _backbone():
    """The frozen reduced backbone + jitted feature fn (built once)."""
    global _BACKBONE_CACHE
    if _BACKBONE_CACHE is None:
        import jax
        from ..configs import get_arch
        from ..models import api
        cfg = get_arch(BACKBONE).reduced()
        mod = api.module_for(cfg)
        params = mod.init_params(jax.random.PRNGKey(0), cfg, tp=1)

        @jax.jit
        def features(toks):
            logits, _, _ = mod.forward(params, cfg, {"tokens": toks}, tp=1)
            return pool_features(logits)

        # fixed teacher projection: labels = argmax(features @ Wt)
        Wt = jax.random.normal(jax.random.PRNGKey(7),
                               (FEATS, NUM_CLASSES)) * 2.0
        _BACKBONE_CACHE = {"cfg": cfg, "features": features, "Wt": Wt}
    return _BACKBONE_CACHE


def teacher_labels(feats) -> np.ndarray:
    import jax.numpy as jnp
    Wt = _backbone()["Wt"]
    return np.asarray(jnp.argmax(feats @ Wt, axis=-1), np.int32)


_SPLIT_CACHE: dict[tuple, JSCData] = {}


def _materialize(n: int, seed: int, chunk: int = 64):
    """Seeded tokens -> pooled features -> teacher labels for n sequences."""
    import jax.numpy as jnp
    bb = _backbone()
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, bb["cfg"].vocab_size, (n, SEQ_LEN)).astype(np.int32)
    xs, ys = [], []
    for i in range(0, n, chunk):
        feats = bb["features"](jnp.asarray(toks[i:i + chunk]))
        xs.append(np.asarray(feats, np.float32))
        ys.append(teacher_labels(feats))
    x = np.concatenate(xs)
    # features are already tanh-squashed to (-1, 1) — the encoder's input
    # contract — so no train-stat renormalization (it would shift the
    # serve-time features away from the train-time ones)
    x = np.clip(x, -1.0, np.nextafter(np.float32(1.0), np.float32(0.0)))
    return x, np.concatenate(ys)


def load_lm_head(n_train: int = 1024, n_test: int = 512,
                 seed: int = 0) -> JSCData:
    key = (n_train, n_test, seed)
    if key not in _SPLIT_CACHE:
        # disjoint seeded token streams per split
        x_tr, y_tr = _materialize(n_train, seed * 2 + 1)
        x_te, y_te = _materialize(n_test, seed * 2 + 2)
        _SPLIT_CACHE[key] = JSCData(x_tr, y_tr, x_te, y_te)
    return _SPLIT_CACHE[key]


LM_HEAD = register_workload(Workload(
    name="lm-head",
    num_features=FEATS,
    num_classes=NUM_CLASSES,
    loader=lambda n_train, n_test, seed=0: load_lm_head(n_train, n_test,
                                                        seed=seed),
    presets=LM_HEAD_PRESETS,
    description=("pooled qwen3-8b (reduced) backbone features, 5-class "
                 "teacher-projection labels; promotes "
                 "examples/dwn_head_lm.py to a first-class workload"),
    backbone=BACKBONE,
    cap_train=1024,
    cap_test=512,
))

__all__ = ["BACKBONE", "FEATS", "LM_HEAD", "LM_HEAD_PRESETS",
           "load_lm_head", "pool_features", "teacher_labels"]
