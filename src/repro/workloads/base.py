"""Typed workload registry: name -> loader + feature schema + presets.

A :class:`Workload` bundles everything the rest of the stack needs to
know about a dataset — feature count, class count, the canonical
train/eval loader (seeded, deterministic, with a synthetic fallback so
CI never downloads), and the DWN preset tiers that make sense at that
feature/class geometry.  ``data/jsc.py`` migrates behind the registry as
the first entry; MNIST and the LM-backbone feature workload ride on top.

Every consumer that used to hardcode JSC (the sweep runner, the serving
engine, the cosim default-vector path, the launch CLIs) now resolves its
dataset through :func:`get_workload` / :func:`load_workload`, so adding
a dataset is one module registering one ``Workload`` — no per-subsystem
edits.

Loaders return a duck-typed split object with ``x_train`` / ``y_train``
/ ``x_test`` / ``y_test`` arrays: float32 features normalized to
[-1, 1) with train-split statistics (what the thermometer encoder
expects) and int32 labels.  ``repro.data.jsc.JSCData`` is the reference
shape; all loaders here reuse it.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

from ..core.model import DWNConfig


@dataclasses.dataclass(frozen=True)
class Workload:
    """One registered dataset/workload.

    Attributes:
      name: registry key (``"jsc"`` | ``"mnist"`` | ``"lm-head"`` | ...).
      num_features: feature count F the encoder sees.
      num_classes: label count C (constrains ``lut_counts[-1] % C == 0``).
      loader: ``(n_train, n_test, seed) -> split`` with x_train/y_train/
        x_test/y_test; deterministic per arguments, never downloads
        unless the workload module says real data is available.
      presets: tier name -> base :class:`DWNConfig` (the per-workload
        analogue of ``JSC_PRESETS``); specs pick ``bits``/``placement``
        on top of these.
      description: one-line provenance / synthetic-fallback note.
      backbone: arch name of a feature-extractor backbone, when features
        are produced by a model rather than read from disk (the LM-head
        workload); None for plain datasets.
      cap_train / cap_test: optional hard caps on split sizes (backbone
        workloads cap how much they will run the extractor for); loaders
        receive the capped sizes.
    """

    name: str
    num_features: int
    num_classes: int
    loader: Callable
    presets: dict[str, DWNConfig]
    description: str = ""
    backbone: str | None = None
    cap_train: int | None = None
    cap_test: int | None = None

    def load(self, n_train: int, n_test: int, seed: int = 0):
        """The canonical split (applies the workload's size caps)."""
        if self.cap_train is not None:
            n_train = min(n_train, self.cap_train)
        if self.cap_test is not None:
            n_test = min(n_test, self.cap_test)
        return self.loader(n_train, n_test, seed)


_REGISTRY: dict[str, Workload] = {}


def register_workload(wl: Workload) -> Workload:
    """Register a workload (idempotent per name; re-registering the same
    name is an error — pick a new name for a variant)."""
    assert wl.name not in _REGISTRY, f"workload {wl.name!r} already registered"
    for tier, cfg in wl.presets.items():
        assert cfg.num_features == wl.num_features, (wl.name, tier)
        assert cfg.num_classes == wl.num_classes, (wl.name, tier)
    _REGISTRY[wl.name] = wl
    return wl


def _ensure_loaded() -> None:
    # workload modules self-register on import, mirroring configs.registry
    from . import jsc, lm_head, mnist  # noqa: F401


def get_workload(name: str) -> Workload:
    """Resolve a registered workload by name.

    Raises ``KeyError`` listing the known names — the error every CLI
    surfaces for a bad ``--workload``.
    """
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown workload {name!r}; registered workloads: "
            f"{sorted(_REGISTRY)} (register new ones via "
            f"repro.workloads.register_workload)")
    return _REGISTRY[name]


def list_workloads() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def load_workload(name: str, n_train: int, n_test: int, seed: int = 0):
    """One-call split loader: ``get_workload(name).load(...)``."""
    return get_workload(name).load(n_train, n_test, seed)


__all__ = [
    "Workload", "get_workload", "list_workloads", "load_workload",
    "register_workload",
]
