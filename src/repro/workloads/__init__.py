"""Workload registry: every dataset the stack trains/serves/sweeps on.

See :mod:`repro.workloads.base` for the registry API and
``docs/workloads.md`` for how to add a dataset.
"""

from .base import (Workload, get_workload, list_workloads, load_workload,
                   register_workload)

__all__ = [
    "Workload", "get_workload", "list_workloads", "load_workload",
    "register_workload",
]
