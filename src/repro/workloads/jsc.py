"""JSC as a registry workload — the migration target of ``data/jsc.py``.

The loader delegates *directly* to :func:`repro.data.jsc.load_jsc`, so
splits obtained through the registry are byte-exact with the legacy
loader (tested in ``tests/test_workloads.py``): same master-seeded
ground truth, same per-seed sampling, same train-stat normalization.
The preset tiers are ``JSC_PRESETS`` verbatim (Table I model sizes).
"""

from __future__ import annotations

from ..core.model import JSC_PRESETS
from ..data.jsc import NUM_CLASSES, NUM_FEATURES, load_jsc
from .base import Workload, register_workload


def _load(n_train: int, n_test: int, seed: int = 0):
    return load_jsc(n_train, n_test, seed=seed)


JSC = register_workload(Workload(
    name="jsc",
    num_features=NUM_FEATURES,
    num_classes=NUM_CLASSES,
    loader=_load,
    presets=dict(JSC_PRESETS),
    description=("Jet Substructure Classification surrogate (16 features, "
                 "5 jet classes; seeded synthetic stand-in for Duarte et "
                 "al. 2018, see repro.data.jsc)"),
))

__all__ = ["JSC"]
