"""MNIST workload: real digits when available, seeded synthetic fallback.

The DWN papers anchor their efficiency claims on MNIST-class image
workloads, so this is the registry's second entry — 14x14 = 196 features
(28x28 real images are 2x2 mean-pooled down to the schema), 10 classes.

Data resolution order:

1. A local npz at ``$REPRO_MNIST`` or ``~/.cache/repro/mnist.npz`` with
   ``x_train/y_train/x_test/y_test`` arrays (the standard Keras
   ``mnist.npz`` layout).
2. If ``REPRO_MNIST_DOWNLOAD=1``, a one-time download into that cache
   path.  **CI never sets this**, so CI never touches the network.
3. Otherwise: a deterministic synthetic fallback — per-class stroke
   prototypes drawn once from a fixed master seed (split-invariant
   ground truth, same scheme as ``data/jsc.py``), per-sample pixel
   shift + gain jitter + noise, labels by construction.  Deterministic
   per ``(n_train, n_test, seed)``.

Both paths normalize features to [-1, 1) with *train-split* statistics
via the shared ``normalize_to_unit``, exactly like JSC, so downstream
thermometer encoding sees the same input contract.
"""

from __future__ import annotations

import os
import warnings
from pathlib import Path

import numpy as np

from ..core.model import DWNConfig
from ..data.jsc import JSCData, normalize_to_unit
from .base import Workload, register_workload

SIDE = 14
NUM_FEATURES = SIDE * SIDE
NUM_CLASSES = 10

MNIST_URL = "https://storage.googleapis.com/tensorflow/tf-keras-datasets/mnist.npz"

#: MNIST DWN tiers. ``lut_counts[-1]`` must divide by 10 classes; the
#: sm/md/lg widths bracket the LUT budgets of the 8-bit MLP comparison
#: points (tinyML-style accelerators) at a fraction of the cost.
MNIST_PRESETS = {
    "mnist-sm": DWNConfig(num_features=NUM_FEATURES, bits_per_feature=8,
                          lut_counts=(100,), num_classes=NUM_CLASSES),
    "mnist-md": DWNConfig(num_features=NUM_FEATURES, bits_per_feature=8,
                          lut_counts=(500,), num_classes=NUM_CLASSES),
    "mnist-lg": DWNConfig(num_features=NUM_FEATURES, bits_per_feature=16,
                          lut_counts=(2000,), num_classes=NUM_CLASSES),
}


# -- synthetic fallback ------------------------------------------------------

class _SyntheticDigits:
    """Fixed per-class stroke prototypes (master-seeded, split-invariant)."""

    def __init__(self):
        master = np.random.default_rng(20260)
        yy, xx = np.mgrid[0:SIDE, 0:SIDE].astype(np.float64) / (SIDE - 1)
        protos = []
        for _ in range(NUM_CLASSES):
            img = np.zeros((SIDE, SIDE))
            for _stroke in range(4):
                cx, cy = master.uniform(0.15, 0.85, 2)
                sx, sy = master.uniform(0.06, 0.22, 2)
                rho = master.uniform(-0.5, 0.5)
                amp = master.uniform(0.6, 1.0)
                dx, dy = (xx - cx) / sx, (yy - cy) / sy
                img += amp * np.exp(
                    -0.5 * (dx * dx - 2 * rho * dx * dy + dy * dy)
                    / (1 - rho * rho))
            protos.append(img / img.max())
        self.protos = np.stack(protos)                    # (10, SIDE, SIDE)


_DIGITS: _SyntheticDigits | None = None


def _digits() -> _SyntheticDigits:
    global _DIGITS
    if _DIGITS is None:
        _DIGITS = _SyntheticDigits()
    return _DIGITS


def _sample_synthetic(n: int, rng: np.random.Generator):
    t = _digits()
    y = rng.integers(0, NUM_CLASSES, n).astype(np.int32)
    imgs = t.protos[y]                                    # (n, SIDE, SIDE)
    # per-sample jitter: +-1 pixel shift, gain, additive pixel noise
    shifts = rng.integers(-1, 2, (n, 2))
    gain = rng.uniform(0.8, 1.2, (n, 1, 1))
    noise = rng.normal(0.0, 0.08, imgs.shape)
    out = np.empty_like(imgs)
    for s in (-1, 0, 1):
        for u in (-1, 0, 1):
            m = (shifts[:, 0] == s) & (shifts[:, 1] == u)
            if m.any():
                out[m] = np.roll(imgs[m], (s, u), axis=(1, 2))
    x = np.clip(out * gain + noise, 0.0, 1.5).astype(np.float32)
    return x.reshape(n, NUM_FEATURES), y


# -- real data path ----------------------------------------------------------

def _cache_path() -> Path:
    env = os.environ.get("REPRO_MNIST")
    if env:
        return Path(env)
    return Path.home() / ".cache" / "repro" / "mnist.npz"


def _pool_28_to_14(x: np.ndarray) -> np.ndarray:
    """2x2 mean-pool 28x28 images down to the 14x14 feature schema."""
    x = x.reshape(-1, 14, 2, 14, 2).mean(axis=(2, 4))
    return x.reshape(-1, NUM_FEATURES)


def _load_real(n_train: int, n_test: int, seed: int):
    """Real MNIST from the npz cache; None when unavailable."""
    path = _cache_path()
    if not path.exists():
        if os.environ.get("REPRO_MNIST_DOWNLOAD") != "1":
            return None
        try:
            import urllib.request
            path.parent.mkdir(parents=True, exist_ok=True)
            urllib.request.urlretrieve(MNIST_URL, path)   # noqa: S310
        except Exception as e:                            # noqa: BLE001
            warnings.warn(f"MNIST download failed ({e}); using the "
                          f"synthetic fallback", stacklevel=3)
            return None
    try:
        with np.load(path) as z:
            xtr, ytr = z["x_train"], z["y_train"]
            xte, yte = z["x_test"], z["y_test"]
    except Exception as e:                                # noqa: BLE001
        warnings.warn(f"MNIST cache {path} unreadable ({e}); using the "
                      f"synthetic fallback", stacklevel=3)
        return None
    rng = np.random.default_rng(seed)
    itr = rng.permutation(len(xtr))[:n_train]
    ite = rng.permutation(len(xte))[:n_test]
    xtr = _pool_28_to_14(xtr[itr].astype(np.float32) / 255.0)
    xte = _pool_28_to_14(xte[ite].astype(np.float32) / 255.0)
    return xtr, ytr[itr].astype(np.int32), xte, yte[ite].astype(np.int32)


# -- loader ------------------------------------------------------------------

def load_mnist(n_train: int = 20000, n_test: int = 5000,
               seed: int = 0) -> JSCData:
    real = _load_real(n_train, n_test, seed)
    if real is not None:
        x_tr, y_tr, x_te, y_te = real
    else:
        rng = np.random.default_rng(seed)
        x_tr, y_tr = _sample_synthetic(n_train, rng)
        x_te, y_te = _sample_synthetic(n_test, rng)
    x_tr, lo, hi = normalize_to_unit(x_tr)
    x_te, _, _ = normalize_to_unit(x_te, lo, hi)
    return JSCData(x_tr, y_tr, x_te, y_te)


MNIST = register_workload(Workload(
    name="mnist",
    num_features=NUM_FEATURES,
    num_classes=NUM_CLASSES,
    loader=lambda n_train, n_test, seed=0: load_mnist(n_train, n_test,
                                                      seed=seed),
    presets=MNIST_PRESETS,
    description=("MNIST digits, 2x2-pooled to 14x14 (196 features, 10 "
                 "classes); real npz when cached or REPRO_MNIST_DOWNLOAD=1, "
                 "seeded synthetic stroke digits otherwise"),
))

__all__ = ["MNIST", "MNIST_PRESETS", "load_mnist"]
