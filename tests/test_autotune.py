"""Fused-kernel autotuner: deterministic winner selection under a stubbed
clock, cache persistence + invalidation on kernel-source changes, and
cold-start fallback when the cache is absent or corrupt."""

import json

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import autotune
from repro.kernels.autotune import (AutotuneCache, DEFAULT_CONFIG,
                                    FusedConfig, candidate_configs,
                                    tune_fused)
from repro.kernels.fused import ops as f_ops
from repro.kernels.fused.ref import fused_dwn_packed_ref


# tiny model: F*T = 32 (one packed word), bucket 8
F, T, M, N, C, BUCKET = 4, 8, 10, 3, 5, 8
SPEC_FP = "cafef00dcafef00d"


class FakeTimer:
    """Deterministic clock: call i advances by deltas[i] seconds.

    ``time_step`` with iters=1 brackets each candidate's timed run with
    two calls, so the measured time is exactly the delta consumed between
    them — the test scripts the race outcome.
    """

    def __init__(self, deltas):
        self._deltas = list(deltas)
        self._t = 0.0
        self.calls = 0

    def __call__(self):
        now = self._t
        if self.calls < len(self._deltas):
            self._t += self._deltas[self.calls]
        else:
            self._t += 1.0
        self.calls += 1
        return now


@pytest.fixture
def model():
    key = jax.random.PRNGKey(0)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    x = jax.random.uniform(k1, (BUCKET, F), minval=-1, maxval=1)
    th = jnp.sort(jax.random.uniform(k2, (F, T), minval=-1, maxval=1), 1)
    mapping = jax.random.randint(k3, (M, N), 0, F * T)
    tables = jax.random.randint(k4, (M, 2 ** N), 0, 2)
    return x, th, mapping, tables


CANDS = [FusedConfig(variant="packed", block_b=8),
         FusedConfig(variant="batch-major", block_b=8)]

# per candidate (iters=1): t0, timed run, t1 -> measured = delta at t0's
# index; scripted so batch-major (5us) beats packed (50us)
DELTAS = [50e-6, 1e-6, 5e-6, 1e-6]


def _tune(model, cache, timer, **kw):
    x, th, mapping, tables = model
    return tune_fused(th, [mapping], [tables], C, x,
                      spec_fingerprint=SPEC_FP, cache=cache,
                      candidates=CANDS, iters=1, timer=timer,
                      interpret=True, **kw)


def test_tuner_deterministic_under_stubbed_clock(tmp_path, model):
    """Same scripted timings -> same winner, twice over."""
    winners = []
    for run in range(2):
        cache = AutotuneCache(tmp_path / f"cache{run}.json")
        winners.append(_tune(model, cache, FakeTimer(DELTAS)))
    assert winners[0] == winners[1] == CANDS[1]


def test_cache_hit_skips_timing(tmp_path, model):
    cache = AutotuneCache(tmp_path / "cache.json")
    first = _tune(model, cache, FakeTimer(DELTAS))
    assert first == CANDS[1]
    # second tune: fresh cache object on the same file, stub clock must
    # never tick — the persisted winner is served without re-timing
    timer = FakeTimer(DELTAS)
    again = _tune(model, AutotuneCache(cache.path), timer)
    assert again == first
    assert timer.calls == 0
    # force=True re-times even on a hit
    forced = _tune(model, AutotuneCache(cache.path), FakeTimer(DELTAS),
                   force=True)
    assert forced == first


def test_cache_invalidated_on_kernel_source_change(tmp_path, model,
                                                   monkeypatch):
    cache = AutotuneCache(tmp_path / "cache.json")
    _tune(model, cache, FakeTimer(DELTAS))
    # simulate a kernel edit: the source fingerprint changes, so the
    # stored entry no longer matches and get() must miss
    monkeypatch.setattr(autotune, "kernel_fingerprint",
                        lambda: "0badc0de0badc0de")
    assert AutotuneCache(cache.path).get(SPEC_FP, BUCKET) is None
    timer = FakeTimer(DELTAS)
    retuned = _tune(model, AutotuneCache(cache.path), timer)
    assert timer.calls > 0          # re-timed, not served stale
    assert retuned == CANDS[1]


def test_cold_start_absent_and_corrupt_cache(tmp_path, model):
    # absent file: miss, tune still succeeds and writes the file
    cache = AutotuneCache(tmp_path / "nope.json")
    assert cache.get(SPEC_FP, BUCKET) is None
    cfg = _tune(model, cache, FakeTimer(DELTAS))
    assert cfg == CANDS[1]
    assert cache.path.exists()
    # corrupt file: miss (never an exception), tune overwrites cleanly
    bad = tmp_path / "corrupt.json"
    bad.write_text("{not json")
    cache = AutotuneCache(bad)
    assert cache.get(SPEC_FP, BUCKET) is None
    cfg = _tune(model, cache, FakeTimer(DELTAS))
    assert cfg == CANDS[1]
    assert json.loads(bad.read_text())["entries"]


def test_all_candidates_failing_falls_back_to_default(tmp_path, model,
                                                      monkeypatch):
    def boom(*a, **kw):
        raise RuntimeError("no kernel for you")
    monkeypatch.setattr(f_ops, "make_forward_packed", boom)
    cache = AutotuneCache(tmp_path / "cache.json")
    cfg = _tune(model, cache, FakeTimer(DELTAS))
    assert cfg == DEFAULT_CONFIG
    assert not cache.path.exists()      # nothing persisted for a non-race


def test_cache_entry_records_timings_and_roundtrips(tmp_path, model):
    cache = AutotuneCache(tmp_path / "cache.json")
    _tune(model, cache, FakeTimer(DELTAS))
    raw = json.loads(cache.path.read_text())["entries"]
    (key, entry), = raw.items()
    assert key == autotune.cache_key(SPEC_FP, BUCKET)
    assert entry["code"] == autotune.kernel_fingerprint()
    assert entry["timings_us"][CANDS[1].label] == pytest.approx(5.0)
    assert entry["timings_us"][CANDS[0].label] == pytest.approx(50.0)
    assert FusedConfig.from_dict(entry["config"]) == CANDS[1]


def test_candidate_configs_cover_both_variants():
    cands = candidate_configs(64)
    assert {c.variant for c in cands} == set(autotune.VARIANTS)
    assert {c.block_b for c in cands} == {64, 32}
    # tiny buckets don't split below themselves
    assert {c.block_b for c in candidate_configs(8)} == {8}


def test_tuned_configs_stay_bit_exact(model):
    """Every candidate the tuner can pick produces oracle-identical
    (counts, argmax) — tuning is a pure perf decision."""
    x, th, mapping, tables = model
    ref_counts, ref_idx = fused_dwn_packed_ref(x, th, [mapping], [tables], C)
    for cfg in [None] + list(candidate_configs(BUCKET)):
        counts, idx = f_ops.forward_packed(x, th, mapping, tables, C,
                                           interpret=True, config=cfg)
        np.testing.assert_array_equal(np.asarray(counts),
                                      np.asarray(ref_counts), err_msg=str(cfg))
        np.testing.assert_array_equal(np.asarray(idx), np.asarray(ref_idx),
                                      err_msg=str(cfg))
