"""DWN LUT layer: EFD gradients, mapping, hard-path equivalence."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.lut_layer import (LUTLayerSpec, init_lut_layer,
                                  lut_layer_apply, finalize_mapping,
                                  binarize_tables, lut_eval_hard,
                                  _lut_lookup_efd)


def test_forward_binary_outputs():
    spec = LUTLayerSpec(8, 4, 32)
    params = init_lut_layer(jax.random.PRNGKey(0), spec)
    bits = jax.random.bernoulli(jax.random.PRNGKey(1), 0.5, (16, 32)) \
        .astype(jnp.float32)
    out = lut_layer_apply(params, bits)
    assert out.shape == (16, 8)
    assert set(np.unique(np.asarray(out))) <= {0.0, 1.0}


def test_train_forward_equals_hard_path():
    """The binarized training forward must equal the frozen hardware path."""
    spec = LUTLayerSpec(10, 6, 64)
    params = init_lut_layer(jax.random.PRNGKey(0), spec)
    bits = jax.random.bernoulli(jax.random.PRNGKey(1), 0.5, (32, 64)) \
        .astype(jnp.float32)
    train_out = lut_layer_apply(params, bits)
    hard_out = lut_eval_hard(bits, finalize_mapping(params),
                             binarize_tables(params))
    np.testing.assert_array_equal(np.asarray(train_out), np.asarray(hard_out))


def test_efd_gradient_is_table_difference():
    """EFD: d out / d bit_i = T[addr | 2^i] - T[addr & ~2^i]."""
    m, n = 1, 3
    tables = jnp.asarray(
        np.random.default_rng(0).uniform(-1, 1, (m, 2 ** n)), jnp.float32)
    sel = jnp.asarray([[[1.0, 0.0, 1.0]]])        # addr = 0b101 = 5
    g = jax.grad(lambda s: _lut_lookup_efd(s, tables).sum())(sel)
    t = np.asarray(tables)[0]
    expect = np.array([t[0b101] - t[0b100],       # flip bit0
                       t[0b111] - t[0b101],       # flip bit1
                       t[0b101] - t[0b001]])      # flip bit2
    np.testing.assert_allclose(np.asarray(g)[0, 0], expect, rtol=1e-6)


def test_table_gradient_routes_to_addressed_entry():
    m, n = 2, 2
    tables = jnp.asarray([[0.5, -0.5, 0.2, -0.2]] * 2, jnp.float32)
    sel = jnp.asarray([[[1.0, 0.0], [0.0, 1.0]]])  # addrs 1 and 2
    g = jax.grad(lambda t: _lut_lookup_efd(sel, t).sum())(tables)
    g = np.asarray(g)
    assert g[0, 1] != 0 and g[1, 2] != 0
    assert g[0, 0] == 0 and g[0, 2] == 0 and g[0, 3] == 0


def test_mapping_gradient_flows():
    spec = LUTLayerSpec(4, 3, 16)
    params = init_lut_layer(jax.random.PRNGKey(0), spec)
    bits = jax.random.bernoulli(jax.random.PRNGKey(1), 0.5, (8, 16)) \
        .astype(jnp.float32)

    def loss(p):
        return (lut_layer_apply(p, bits) ** 2).sum()

    g = jax.grad(loss)(params)
    assert np.isfinite(np.asarray(g["scores"])).all()
    assert np.abs(np.asarray(g["scores"])).sum() > 0


def test_finalize_shapes():
    spec = LUTLayerSpec(6, 6, 100)
    params = init_lut_layer(jax.random.PRNGKey(2), spec)
    idx = np.asarray(finalize_mapping(params))
    tab = np.asarray(binarize_tables(params))
    assert idx.shape == (6, 6) and idx.min() >= 0 and idx.max() < 100
    assert tab.shape == (6, 64) and set(np.unique(tab)) <= {0, 1}
