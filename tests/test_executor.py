"""Resilient sweep executor: parallel==serial parity, chaos-driven
worker deaths, crash-loop failure reporting, resume-with-zero-recompute,
straggler speculation, preemption draining.

Worker processes are *spawned* (each imports JAX fresh), so every test
here pays a few seconds of process startup — settings are kept minimal
(no kernel/serve axes, tiny splits)."""

import json

import pytest

from repro.runtime.fault import PreemptionHandler
from repro.sweep import SweepPoint, SweepResult, SweepSettings, run_grid
from repro.sweep.executor import (ChaosSpec, ExecutorSettings,
                                  run_grid_parallel)

FAST = SweepSettings(n_train=256, n_test=128, accuracy=False,
                     kernel=False, serve=False)

POINTS = [SweepPoint("sm-10", "TEN"),
          SweepPoint("sm-10", "PEN", input_bits=4),
          SweepPoint("sm-50", "TEN"),
          SweepPoint("sm-50", "PEN", input_bits=4)]


def _labels(result):
    return [r.point.label for r in result.points]


# ---------------------------------------------------------------------------
# parity + resume
# ---------------------------------------------------------------------------

def test_parallel_matches_serial(tmp_path):
    """Same grid through both executors: identical hardware numbers and
    accuracies (workers are seeded identically), plus the executor
    provenance block."""
    settings = SweepSettings(n_train=256, n_test=128, accuracy=True,
                             kernel=False, serve=False)
    pts = POINTS[:2]
    serial = run_grid(pts, settings, cache_dir=None)
    par = run_grid_parallel(pts, settings, cache_dir=tmp_path / "c",
                            executor=ExecutorSettings(workers=2))
    assert _labels(par) == _labels(serial)
    for a, b in zip(par.points, serial.points):
        assert a.total_luts == b.total_luts
        assert a.accuracy == b.accuracy
        assert not a.failed
    assert par.executor["mode"] == "parallel"
    assert par.executor["computed"] == 2
    assert par.executor["failed"] == []
    assert serial.executor["mode"] == "serial"


def test_resume_zero_recomputed_points(tmp_path):
    """The chaos-resume invariant's happy path: a completed run re-runs
    entirely from the cache — zero computed points."""
    first = run_grid_parallel(POINTS, FAST, cache_dir=tmp_path,
                              executor=ExecutorSettings(workers=2))
    assert first.executor["computed"] == len(POINTS)
    again = run_grid_parallel(POINTS, FAST, cache_dir=tmp_path,
                              executor=ExecutorSettings(workers=2))
    assert again.executor["computed"] == 0
    assert again.executor["cache_hits"] == len(POINTS)
    assert all(r.cached for r in again.points)
    # and the serial runner resumes from the same cache
    serial = run_grid(POINTS, FAST, cache_dir=tmp_path)
    assert serial.executor["computed"] == 0
    assert serial.executor["cache_hits"] == len(POINTS)


def test_executor_block_json_roundtrip(tmp_path):
    res = run_grid_parallel(POINTS[:1], FAST, cache_dir=None,
                            executor=ExecutorSettings(workers=1))
    f = tmp_path / "sweep.json"
    res.save(f)
    loaded = SweepResult.load(f)
    assert loaded.executor == res.executor
    assert json.loads(f.read_text())["executor"]["mode"] == "parallel"


# ---------------------------------------------------------------------------
# chaos: worker death, crash loop, per-point failure
# ---------------------------------------------------------------------------

def test_chaos_worker_kill_run_survives(tmp_path):
    """Every worker hard-exits after each completed point (node-loss
    chaos): the dispatcher respawns workers and the grid completes with
    no failed and no recomputed points."""
    res = run_grid_parallel(
        POINTS, FAST, cache_dir=tmp_path,
        executor=ExecutorSettings(workers=1, chaos="kill-after-1"))
    assert res.executor["computed"] == len(POINTS)
    assert res.executor["failed"] == []
    assert res.executor["worker_deaths"] >= len(POINTS) - 1
    assert res.executor["workers_spawned"] >= len(POINTS) - 1
    # all committed before each death -> resume is pure cache
    again = run_grid_parallel(POINTS, FAST, cache_dir=tmp_path,
                              executor=ExecutorSettings(workers=1))
    assert again.executor["computed"] == 0
    assert again.executor["cache_hits"] == len(POINTS)


def test_chaos_crash_loop_fails_points_without_spinning(tmp_path):
    """raise-always: every attempt raises; each point must exhaust its
    bounded restart budget and be reported failed — the run terminates
    instead of spinning."""
    res = run_grid_parallel(
        POINTS[:2], FAST, cache_dir=tmp_path,
        executor=ExecutorSettings(workers=1, chaos="raise-always",
                                  max_restarts=1))
    assert len(res.points) == 2
    assert all(r.failed and r.error for r in res.points)
    assert sorted(res.executor["failed"]) == sorted(_labels(res))
    # max_restarts=1 -> exactly 2 attempts per point, 1 retry each
    assert res.executor["in_worker_retries"] == 2


def test_chaos_one_failed_point_does_not_abort_grid(tmp_path):
    """A single persistently-failing point is reported failed; the rest
    of the grid completes and caches normally."""
    res = run_grid_parallel(
        POINTS, FAST, cache_dir=tmp_path,
        executor=ExecutorSettings(workers=2, chaos="raise-point-0",
                                  max_restarts=1))
    by = {r.point.label: r for r in res.points}
    assert by[POINTS[0].label].failed
    assert "injected persistent fault" in by[POINTS[0].label].error
    ok = [r for r in res.points if not r.failed]
    assert len(ok) == len(POINTS) - 1
    assert res.executor["failed"] == [POINTS[0].label]
    # the failed point renders, the table row says so
    assert "FAILED" in res.table()
    # on re-run the healthy points are cache hits; only the (no longer
    # chaos-injected) failed point computes
    again = run_grid_parallel(POINTS, FAST, cache_dir=tmp_path,
                              executor=ExecutorSettings(workers=2))
    assert again.executor["cache_hits"] == len(POINTS) - 1
    assert again.executor["computed"] == 1
    assert not any(r.failed for r in again.points)


def test_chaos_raise_after_exercises_in_worker_retry(tmp_path):
    """raise-after-N fires once per worker; the in-worker Supervisor
    retries and the point still completes (no parent-side restart)."""
    res = run_grid_parallel(
        POINTS[:2], FAST, cache_dir=tmp_path,
        executor=ExecutorSettings(workers=1, chaos="raise-after-1"))
    assert res.executor["computed"] == 2
    assert res.executor["failed"] == []
    assert res.executor["in_worker_retries"] == 1
    assert res.executor["restarts"] == 0


def test_chaos_spec_parsing():
    assert ChaosSpec.parse(None) == ChaosSpec()
    assert ChaosSpec.parse("kill-after-3").kill_after == 3
    assert ChaosSpec.parse("raise-after-1").raise_after == 1
    assert ChaosSpec.parse("raise-always").raise_always
    assert ChaosSpec.parse("raise-point-2").raise_point == 2
    s = ChaosSpec.parse("stall-0:2.5")
    assert s.stall_index == 0 and s.stall_s == 2.5
    with pytest.raises(ValueError, match="unknown chaos"):
        ChaosSpec.parse("set-fire-to-rack")
    with pytest.raises(ValueError):
        run_grid_parallel(POINTS[:1], FAST, cache_dir=None,
                          executor=ExecutorSettings(chaos="bogus"))


# ---------------------------------------------------------------------------
# stragglers
# ---------------------------------------------------------------------------

def test_straggler_speculative_redispatch(tmp_path):
    """A stalled first attempt is flagged against the robust-z threshold
    of completed-point wall times and speculatively re-dispatched; the
    fresh attempt wins and the grid never gates on the stalled worker."""
    pts = [SweepPoint("sm-10", "TEN")] + \
          [SweepPoint("sm-10", "PEN", input_bits=b) for b in range(4, 9)]
    res = run_grid_parallel(
        pts, FAST, cache_dir=tmp_path,
        executor=ExecutorSettings(workers=2, chaos="stall-0:15.0",
                                  straggler_min_samples=3))
    assert res.executor["stragglers_redispatched"] >= 1
    assert res.executor["failed"] == []
    assert len([r for r in res.points if not r.failed]) == len(pts)
    # the run must have finished long before the 15s stall elapsed
    assert res.executor["wall_s"] < 15.0


# ---------------------------------------------------------------------------
# preemption
# ---------------------------------------------------------------------------

def test_preemption_before_start_interrupts_resumably(tmp_path):
    pre = PreemptionHandler(install=False)
    pre.requested = True
    res = run_grid_parallel(POINTS, FAST, cache_dir=tmp_path,
                            executor=ExecutorSettings(workers=2),
                            preemption=pre)
    assert res.executor["interrupted"]
    assert res.executor["remaining"] == len(POINTS)
    assert res.executor["remaining_points"] == [p.label for p in POINTS]
    assert res.points == []


def test_preemption_mid_run_drains_and_resumes(tmp_path):
    """Preemption requested while the grid is in flight: the run stops
    early but every completed point is cached, so the follow-up run
    computes exactly the complement — zero recomputed points."""
    import threading
    pre = PreemptionHandler(install=False)
    t = threading.Timer(2.0, lambda: setattr(pre, "requested", True))
    t.start()
    try:
        first = run_grid_parallel(POINTS, FAST, cache_dir=tmp_path,
                                  executor=ExecutorSettings(workers=1),
                                  preemption=pre)
    finally:
        t.cancel()
    done = first.executor["computed"]
    resumed = run_grid_parallel(POINTS, FAST, cache_dir=tmp_path,
                                executor=ExecutorSettings(workers=1))
    assert resumed.executor["cache_hits"] == done
    assert resumed.executor["computed"] == len(POINTS) - done
    assert len(resumed.points) == len(POINTS)
    assert not any(r.failed for r in resumed.points)


# ---------------------------------------------------------------------------
# artifacts
# ---------------------------------------------------------------------------

def test_executor_persists_point_artifacts(tmp_path):
    """Every computed point checkpoints as a loadable packed DWNArtifact
    (runtime.checkpoint.save_artifact) when artifact_dir is set."""
    from repro.runtime.checkpoint import load_artifact
    adir = tmp_path / "artifacts"
    res = run_grid_parallel(
        POINTS[:2], FAST, cache_dir=tmp_path / "c",
        executor=ExecutorSettings(workers=2, artifact_dir=str(adir)))
    assert res.executor["computed"] == 2
    subdirs = sorted(p for p in adir.iterdir() if p.is_dir())
    assert len(subdirs) == 2
    art = load_artifact(subdirs[0])
    assert art.stage == "packed"
    assert art.spec.preset in ("sm-10", "sm-50")
