"""Roofline analyzer: HLO collective parsing + term computation + real
dry-run artifacts (when present)."""

import json
from pathlib import Path

import pytest

from repro.roofline.analyze import (parse_collectives, _shape_bytes,
                                    _tuple_bytes, RooflineTerms, model_flops)

HLO = """
HloModule test
ENTRY main {
  %p = bf16[8,128]{1,0} parameter(0)
  %ar = bf16[8,128]{1,0} all-reduce(%p), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
  %ag = bf16[8,512]{1,0} all-gather(%ar), replica_groups=[2,4]<=[8] , dimensions={1}
  %rs = f32[2,128]{1,0} reduce-scatter(%x), replica_groups={{0,1,2,3}}, dimensions={0}
  %cp = f32[16]{0} collective-permute(%y), source_target_pairs={{0,1}}
  %ard = bf16[4]{0} all-reduce-start(%z), replica_groups={{0,1}}
  %done = bf16[4]{0} all-reduce-done(%ard)
}
"""


def test_shape_bytes():
    assert _shape_bytes("bf16[8,128]{1,0}") == 8 * 128 * 2
    assert _shape_bytes("f32[16]{0}") == 64
    assert _shape_bytes("u32[]") == 4
    assert _tuple_bytes("(f32[4], f32[4])") == 32


def test_parse_collectives_counts_and_bytes():
    st = parse_collectives(HLO, total_devices=8)
    assert st.counts == {"all-reduce": 2, "all-gather": 1,
                         "reduce-scatter": 1, "collective-permute": 1}
    # all-reduce payload: 8*128*2 (+ tiny bf16[4] start op)
    assert st.payload_bytes["all-reduce"] == 8 * 128 * 2 + 8
    assert st.payload_bytes["all-gather"] == 8 * 512 * 2
    assert st.wire_bytes > 0


def test_group_size_parsing_affects_wire_bytes():
    a = parse_collectives(
        "%r = f32[1024]{0} all-reduce(%p), replica_groups={{0,1}}\n", 256)
    b = parse_collectives(
        "%r = f32[1024]{0} all-reduce(%p), "
        "replica_groups=[1,256]<=[256]\n", 256)
    assert a.wire_bytes < b.wire_bytes       # (n-1)/n grows with n


def test_roofline_terms_bound_selection():
    t = RooflineTerms(flops_per_chip=197e12, hbm_bytes_per_chip=1.0,
                      wire_bytes_per_chip=1.0, chips=256)
    s = t.seconds()
    assert s["bound"] == "compute" and abs(s["compute_s"] - 1.0) < 1e-9


def test_model_flops_moe_uses_active_params():
    from repro.configs import get_arch, SHAPES
    cfg = get_arch("mixtral-8x7b")
    mf = model_flops(cfg, SHAPES["train_4k"], include_backward=True)
    dense_equiv = 6.0 * cfg.num_params() * 4096 * 256
    assert mf < dense_equiv                  # active << total for top-2/8


RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


@pytest.mark.skipif(not RESULTS.exists() or not list(RESULTS.glob("*.json")),
                    reason="dry-run artifacts not generated yet")
def test_dryrun_artifacts_complete_and_sane():
    """Every (arch x shape x mesh) cell either succeeded or is a
    documented skip; no errors; terms positive for real cells."""
    from repro.configs import SHAPES, cell_supported, get_arch
    from repro.configs.registry import assigned_archs
    for pod in ("pod1", "pod2"):
        for arch in assigned_archs():
            for shape in SHAPES:
                f = RESULTS / f"{arch}__{shape}__{pod}.json"
                assert f.exists(), f"missing cell {f.name}"
                rec = json.loads(f.read_text())
                assert "error" not in rec, (f.name, rec.get("error"))
                ok, _ = cell_supported(get_arch(arch), SHAPES[shape])
                if not ok:
                    assert rec.get("skipped"), f.name
                    continue
                r = rec["roofline"]
                assert r["compute_s"] >= 0 and r["memory_s"] > 0
                assert rec["chips"] == (512 if pod == "pod2" else 256)
                assert 0 < rec["useful_flops_ratio"] <= 1.5, f.name
