"""Optimizer stack: Adam, schedules, clipping, accumulation, top-k
gradient compression with error feedback."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.optim.adam import Adam
from repro.optim.grad import (clip_by_global_norm, global_norm,
                              accumulate_grads, topk_compress,
                              topk_compress_init)
from repro.optim.schedule import step_lr, warmup_cosine, constant


def test_adam_converges_quadratic():
    opt = Adam(lr=0.1)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    grad = jax.grad(lambda p: jnp.sum(p["w"] ** 2))
    for _ in range(200):
        params, state = opt.update(grad(params), state, params)
    assert np.abs(np.asarray(params["w"])).max() < 1e-2


def test_adam_clamp():
    opt = Adam(lr=1.0, clamp=(-1.0, 1.0))
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    params, _ = opt.update({"w": jnp.asarray([-10.0, 0.0, 10.0])},
                           state, params)
    assert np.asarray(params["w"]).min() >= -1.0
    assert np.asarray(params["w"]).max() <= 1.0


def test_step_lr_matches_paper_schedule():
    # paper §III: StepLR(step_size=30 epochs, gamma=0.1)
    fn = step_lr(1e-3, 30, 0.1, steps_per_epoch=10)
    assert float(fn(jnp.asarray(0))) == pytest.approx(1e-3)
    assert float(fn(jnp.asarray(299))) == pytest.approx(1e-3)
    assert float(fn(jnp.asarray(300))) == pytest.approx(1e-4)
    assert float(fn(jnp.asarray(600))) == pytest.approx(1e-5, rel=1e-3)


def test_clip_by_global_norm():
    tree = {"a": jnp.asarray([3.0, 4.0])}         # norm 5
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(5.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    same, _ = clip_by_global_norm(tree, 10.0)
    np.testing.assert_allclose(np.asarray(same["a"]), [3.0, 4.0])


def test_accumulate_grads_equals_full_batch():
    w = jnp.asarray([[1.0, 2.0], [3.0, 4.0]])
    xs = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 2))

    def loss_fn(p, x):
        return ((x @ p) ** 2).mean(), None

    loss, grads = accumulate_grads(loss_fn, w, xs, 4)
    full_loss, full_grads = jax.value_and_grad(
        lambda p: ((xs.reshape(-1, 2) @ p) ** 2).mean())(w)
    np.testing.assert_allclose(np.asarray(grads), np.asarray(full_grads),
                               rtol=1e-5)


def test_topk_compression_error_feedback():
    """Residuals carry dropped mass: over steps the *sum* of sent
    gradients approaches the sum of true gradients (EF-SGD property)."""
    k_frac = 0.1
    g = {"w": jnp.asarray(np.random.default_rng(0)
                          .normal(0, 1, 64).astype(np.float32))}
    state = topk_compress_init(g)
    sent_total = jnp.zeros(64)
    rel_at = {}
    for i in range(1, 101):
        sent, state = topk_compress(g, state, k_frac=k_frac)
        sent_total = sent_total + sent["w"]
        if i in (40, 100):
            resid = np.abs(np.asarray(g["w"] * i - sent_total))
            rel_at[i] = resid.sum() / float(
                np.abs(np.asarray(g["w"] * i)).sum())
            # EF theory: steady-state residual per coordinate is bounded
            # by |g_i| / k_frac (one send every ~1/k_frac steps)
            gmax = float(np.abs(np.asarray(g["w"])).max())
            assert resid.max() <= gmax / k_frac + 1e-4
    # bounded residual => relative error vanishes as steps grow
    assert rel_at[100] < rel_at[40]
    assert rel_at[100] < 0.06


def test_warmup_cosine_monotone_phases():
    fn = warmup_cosine(1.0, 10, 100)
    ws = [float(fn(jnp.asarray(i))) for i in range(10)]
    assert all(b >= a for a, b in zip(ws, ws[1:]))   # warmup rises
    cs = [float(fn(jnp.asarray(i))) for i in range(10, 100, 10)]
    assert all(b <= a + 1e-6 for a, b in zip(cs, cs[1:]))  # cosine decays
