"""Serving subsystem: backend parity, scheduler bucketing, engine + CLI.

Covers the three layers of ``repro.serving``:

* scheduler: admission order, power-of-two bucket padding, coalescing,
  oversize splitting, queue-vs-compute latency accounting (pure numpy —
  no jax needed);
* backends: every registered non-oracle backend bit-exact against the
  ``apply_hard`` float oracle on all three JSC serving presets, verified
  by the engine's startup gate;
* engine: ragged request streams compile at most once per
  (backend, bucket); data-parallel shard_map serving stays bit-exact
  (8-device subprocess); the serve CLI smoke-runs end to end.
"""

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

from repro.serving import (MicrobatchScheduler, ServingEngine,
                           available_backends, power_of_two_buckets)

ROOT = Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# scheduler (no jax)
# ---------------------------------------------------------------------------

def test_bucket_ladder():
    sched = MicrobatchScheduler(max_bucket=64, min_bucket=8)
    assert sched.buckets == (8, 16, 32, 64)
    assert power_of_two_buckets(16, 16) == (16,)
    assert sched.bucket_for(1) == 8
    assert sched.bucket_for(8) == 8
    assert sched.bucket_for(9) == 16
    assert sched.bucket_for(64) == 64
    with pytest.raises(AssertionError):
        power_of_two_buckets(12, 64)          # min not a power of two


def _row_id_step(shapes_seen):
    """Step fn whose per-row output identifies the input row exactly."""
    def step(x):
        shapes_seen.append(x.shape[0])
        return (x[:, 0].copy(),)              # row tag
    return step


def test_scheduler_ragged_admission_order_and_padding():
    sched = MicrobatchScheduler(max_bucket=64, min_bucket=8)
    sizes = [5, 17, 40, 3, 64, 1, 100, 2]
    reqs = []
    for i, n in enumerate(sizes):
        # payload rows tagged with (request id, row) so results are traceable
        x = np.full((n, 4), float(i), np.float32)
        x[:, 0] = i * 1000 + np.arange(n)
        reqs.append(sched.submit(x))
    shapes = []
    done = sched.drain_batched(_row_id_step(shapes))

    # every request served, results routed back to the right request
    assert len(done) == len(sizes)
    for i, r in enumerate(sorted(done, key=lambda r: r.rid)):
        expect = i * 1000 + np.arange(sizes[i], dtype=np.float32)
        np.testing.assert_array_equal(r.result[0], expect)

    # admission order: service start times never decrease with rid
    starts = [r.t_start for r in sorted(done, key=lambda r: r.rid)]
    assert all(a <= b + 1e-9 for a, b in zip(starts, starts[1:]))

    # only ladder shapes ever reach the step fn (bounded JIT signatures)
    assert set(shapes) <= set(sched.buckets)

    # oversize request (100 > 64) split into max_bucket chunks
    big = next(r for r in done if r.size == 100)
    assert big.buckets == (64, 64)
    assert len(big.result[0]) == 100

    # latency accounting is populated and ordered
    for r in done:
        assert r.t_submit <= r.t_start <= r.t_done
        assert r.queue_ms >= 0 and r.compute_ms >= 0
        assert r.total_ms >= r.compute_ms


def test_scheduler_coalesces_small_requests():
    sched = MicrobatchScheduler(max_bucket=32, min_bucket=8)
    for i in range(6):
        sched.submit(np.full((4, 2), i, np.float32))
    shapes = []
    sched.drain_batched(_row_id_step(shapes))
    # 6 x 4 samples coalesce into one 24-sample microbatch -> one 32 pad
    assert shapes == [32]


def test_scheduler_serial_latency_accounting():
    sched = MicrobatchScheduler(max_bucket=8)
    sched.submit({"tokens": np.zeros((2, 4))}, size=2)
    done = sched.drain_serial(lambda payload: {"ok": True})
    assert done[0].result == {"ok": True}
    assert done[0].t_done >= done[0].t_start >= done[0].t_submit


class _FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_split_timing_attributed_from_original_submit():
    """Oversize requests split into max-bucket chunks keep their queue
    time anchored at the original submit: the clock never restarts per
    chunk, and payload conversion is charged to compute, exactly like
    the coalesced-group path."""
    clock = _FakeClock()
    sched = MicrobatchScheduler(max_bucket=32, min_bucket=8, timer=clock)

    def step(x):
        clock.t += 1.0                     # each chunk costs exactly 1s
        return (x[:, 0].copy(),)

    sched.submit(np.zeros((100, 2), np.float32))   # 4 chunks: 32*3 + 4
    clock.t = 5.0                                  # queued for 5s
    done = sched.drain_batched(step)
    (req,) = done
    assert req.queue_ms == pytest.approx(5_000.0)
    assert req.compute_ms == pytest.approx(4_000.0)
    assert req.buckets == (32, 32, 32, 8)
    # group path under the same fake clock: identical attribution rules
    sched.submit(np.zeros((4, 2), np.float32))
    sched.submit(np.zeros((8, 2), np.float32))
    clock.t = 12.0
    a, b = sorted(sched.drain_batched(step), key=lambda r: r.rid)
    assert a.queue_ms == pytest.approx(3_000.0)    # 12 - 9 (submit time)
    assert b.queue_ms == pytest.approx(3_000.0)
    assert a.compute_ms == b.compute_ms == pytest.approx(1_000.0)


def test_latency_stats_include_p999():
    from repro.serving.scheduler import latency_stats, percentiles
    sched = MicrobatchScheduler(max_bucket=8)
    for i in range(4):
        sched.submit(np.zeros((2, 2), np.float32))
    sched.drain_batched(lambda x: (x[:, 0],))
    stats = latency_stats(sched.completed)
    for kind in ("queue_ms", "compute_ms", "total_ms"):
        assert {"p50", "p99", "p999", "mean"} <= set(stats[kind])
    p = percentiles(range(1, 1001))
    assert p["p50"] == pytest.approx(500.5)
    assert p["p999"] == pytest.approx(1000, abs=1.1)
    assert latency_stats([]) == {}


# ---------------------------------------------------------------------------
# backends: bit-exact parity vs the oracle on all three serving presets
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["dwn-jsc-sm", "dwn-jsc-md", "dwn-jsc-lg"])
def test_backend_parity_vs_oracle(arch):
    engine = ServingEngine(arch, max_bucket=32, min_bucket=8, n_train=1200,
                           verify=True)
    non_oracle = [b for b in available_backends() if b != "float-oracle"]
    assert sorted(engine.bit_exact) == sorted(non_oracle)
    assert all(engine.bit_exact.values()), engine.bit_exact


def test_backend_parity_multiblock_bucket():
    # buckets >= 128 exercise the fused kernel's multi-block batch grid;
    # the startup probe runs at max_bucket so this is verified, not assumed
    engine = ServingEngine("dwn-jsc-sm", max_bucket=256, min_bucket=8,
                           n_train=800, verify=True)
    assert all(engine.bit_exact.values()), engine.bit_exact
    assert 256 in engine.backends["fused-packed"].compiles


def test_backend_registry_and_config_selection():
    assert {"fused-packed", "packed-xla", "float-oracle"} <= set(
        available_backends())
    # dwn_datapath on the arch picks the backend; CLI arg overrides
    eng = ServingEngine("dwn-jsc-sm-xla", max_bucket=16, n_train=600,
                        verify=False)
    assert eng.backend.name == "packed-xla"
    eng = ServingEngine("dwn-jsc-sm", max_bucket=16, n_train=600,
                        backend="float-oracle", verify=False)
    assert eng.backend.name == "float-oracle"


def test_backend_auto_select_calibrates_and_serves():
    """backend="auto": startup calibration times every bit-exact backend
    at every ladder bucket and serves each bucket on the fastest — no
    timed request pays calibration inside its compute window."""
    eng = ServingEngine("dwn-jsc-sm", max_bucket=32, min_bucket=8,
                        n_train=800, backend="auto")
    assert eng.auto is not None
    # startup calibration covered the whole ladder with every eligible
    # backend (all registered ones passed the bit-exactness gate)
    assert sorted(eng.auto.choice) == sorted(eng.scheduler.buckets)
    assert sorted(eng.auto.timings[32]) == sorted(available_backends())
    # choice is the measured winner, except near-ties break toward the
    # fused kernel datapath (within tie_break_pct of the fastest)
    times = eng.auto.timings[32]
    fastest = min(times, key=times.get)
    chosen = eng.auto.choice[32]
    assert (chosen == fastest
            or (chosen == eng.auto.TIE_BREAK_BACKEND
                and times[chosen] <= times[fastest]
                * (1 + eng.auto.tie_break_pct / 100)))
    for n in (32, 5, 17, 32):
        eng.submit(eng.make_request(n, seed=n))
    done = eng.drain()
    assert sum(r.size for r in done) == 32 + 5 + 17 + 32
    # every bucket that served got exactly one calibration entry
    assert set(eng.auto.choice) <= set(eng.scheduler.buckets)
    # results stay bit-exact regardless of which backend won
    oracle = eng.backends["float-oracle"]
    for r in done:
        counts, pred = (np.asarray(a) for a in
                        oracle.step_for(r.payload.shape[0])(r.payload))
        np.testing.assert_array_equal(np.asarray(r.result[0]), counts)
        np.testing.assert_array_equal(np.asarray(r.result[1]), pred)
    rep = eng.report()
    assert rep["datapath"] == "auto"
    assert rep["auto"]["choice"]
    # auto mode autotunes the fused kernel over the whole ladder at
    # startup; the chosen per-bucket configs surface in the report
    assert sorted(eng.tuned_configs) == sorted(eng.scheduler.buckets)
    assert set(rep["autotune"]) == set(eng.scheduler.buckets)
    for cfg in rep["autotune"].values():
        assert cfg["variant"] in ("packed", "batch-major")
    # explicit --backend remains the override path, and switching back to
    # auto restores the startup-calibrated selector (no re-timing)
    auto_before = eng.auto
    eng.use_backend("packed-xla")
    assert eng.auto is None and eng.backend.name == "packed-xla"
    eng.use_backend("auto")
    assert eng.auto is auto_before


# ---------------------------------------------------------------------------
# engine: ragged stream, compile bound, report
# ---------------------------------------------------------------------------

def test_engine_ragged_stream_compiles_once_per_bucket():
    engine = ServingEngine("dwn-jsc-sm", max_bucket=64, min_bucket=8,
                           n_train=800, verify=True)
    rng = np.random.default_rng(0)
    sizes = [5, 17, 64, 3, 100, 23, 64, 9, 2, 31]
    for n in sizes:
        engine.submit(engine.make_request(n, seed=int(rng.integers(2**31))))
    done = engine.drain()
    assert sum(r.size for r in done) == sum(sizes)

    # at most one XLA trace per (backend, bucket), buckets from the ladder
    for backend, per_bucket in engine.compile_counts().items():
        assert set(per_bucket) <= set(engine.scheduler.buckets), backend
        assert all(v == 1 for v in per_bucket.values()), (backend, per_bucket)

    # predictions bit-exact vs the oracle for every request
    oracle = engine.backends["float-oracle"]
    for r in done:
        counts, pred = (np.asarray(a) for a in
                        oracle.step_for(r.payload.shape[0])(r.payload))
        np.testing.assert_array_equal(np.asarray(r.result[0]), counts)
        np.testing.assert_array_equal(np.asarray(r.result[1]), pred)

    rep = engine.report()
    assert rep["served"] == sum(sizes)
    assert rep["latency"]["queue_ms"]["p50"] >= 0
    assert rep["latency"]["compute_ms"]["p50"] > 0
    assert rep["bit_exact_vs_oracle"] == {"fused-packed": True,
                                          "packed-xla": True}


# ---------------------------------------------------------------------------
# data-parallel sharding (8 fake host devices, subprocess)
# ---------------------------------------------------------------------------

DP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys, json
    sys.path.insert(0, sys.argv[1])
    import numpy as np
    from repro.serving import ServingEngine

    eng = ServingEngine("dwn-jsc-sm", max_bucket=64, min_bucket=8,
                        n_train=800, backend="packed-xla")
    for n in (64, 17, 40, 8):
        eng.submit(eng.make_request(n, seed=n))
    done = eng.drain()
    oracle = eng.backends["float-oracle"]
    exact = True
    for r in done:
        counts, pred = (np.asarray(a) for a in
                        oracle.step_for(r.payload.shape[0])(r.payload))
        exact &= np.array_equal(np.asarray(r.result[0]), counts)
        exact &= np.array_equal(np.asarray(r.result[1]), pred)
    rep = eng.report()
    print("RESULT " + json.dumps({
        "devices": rep["devices"], "dp": rep["data_parallel"],
        "exact": bool(exact), "served": rep["served"],
        "startup_check": rep["bit_exact_vs_oracle"]}))
""")


def test_engine_data_parallel_shard_map():
    proc = subprocess.run(
        [sys.executable, "-c", DP_SCRIPT, str(ROOT / "src")],
        capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")]
    assert line, proc.stdout[-2000:]
    out = json.loads(line[0][len("RESULT "):])
    assert out["devices"] == 8 and out["dp"] is True
    assert out["exact"] is True
    assert out["served"] == 64 + 17 + 40 + 8
    assert out["startup_check"] == {"fused-packed": True, "packed-xla": True}


# ---------------------------------------------------------------------------
# CLI smoke
# ---------------------------------------------------------------------------

def test_serve_cli_smoke():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "dwn-jsc-sm",
         "--reduced", "--requests", "4", "--batch", "32", "--ragged"],
        capture_output=True, text=True, timeout=900, env=env,
        cwd=str(ROOT))
    assert proc.returncode == 0, proc.stderr[-3000:]
    rep = json.loads(proc.stdout.strip().splitlines()[-1])
    assert rep["mode"] == "dwn-classify"
    assert rep["datapath"] == "fused-packed"
    assert rep["bit_exact_vs_oracle"] == {"fused-packed": True,
                                          "packed-xla": True}
    assert rep["served"] >= 4
    assert rep["latency_ms_p50"] > 0
    assert all(v == 1 for per in rep["compiles"].values()
               for v in per.values())
