"""Co-simulation harness tests: evaluator primitives (property-based),
netlist parsing, end-to-end RTL-vs-oracle equivalence, mutation
detection, the testbench emitter, and the artifact lifecycle hook."""

import re
import shutil

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.model import DWNConfig, apply_hard_packed, freeze, init_dwn
from repro.core.thermometer import encode_np
from repro.data.jsc import load_jsc
from repro.hw.cosim import (CosimParseError, RTLMismatch, as_signed,
                            emit_testbench, eval_argmax, eval_comparator,
                            eval_lut, eval_popcount, evaluate_netlist,
                            fixed_point_int, parse_netlist,
                            simulator_available, verify_rtl)
from repro.hw.verilog import _fixed_point_const, emit_dwn


# ---------------------------------------------------------------------------
# primitives vs direct numpy models (property-based)
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(st.integers(0, 2**64 - 1), st.integers(1, 6), st.integers(0, 9999))
def test_eval_lut_matches_table(init, n, seed):
    init &= (1 << (1 << n)) - 1
    rng = np.random.default_rng(seed)
    sel = rng.integers(0, 2, size=(17, n))
    got = eval_lut(init, sel)
    for row, g in zip(sel, got):
        addr = sum(int(b) << i for i, b in enumerate(row))
        assert g == (init >> addr) & 1


@settings(max_examples=60, deadline=None)
@given(st.integers(2, 24), st.integers(0, 9999))
def test_eval_comparator_is_signed_compare(width, seed):
    rng = np.random.default_rng(seed)
    lo, hi = -(1 << (width - 1)), (1 << (width - 1)) - 1
    x = rng.integers(lo, hi + 1, size=31)
    thr = int(rng.integers(lo, hi + 1))
    const = thr & ((1 << width) - 1)          # two's-complement literal
    np.testing.assert_array_equal(eval_comparator(x, const, width),
                                  (x > thr).astype(np.uint8))


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 600), st.integers(0, 9999))
def test_eval_popcount_is_sum(width, seed):
    bits = np.random.default_rng(seed).integers(0, 2, size=(9, width))
    np.testing.assert_array_equal(eval_popcount(bits), bits.sum(-1))


@settings(max_examples=60, deadline=None)
@given(st.integers(2, 8), st.integers(0, 9999))
def test_eval_argmax_ties_to_lower(classes, seed):
    c = np.random.default_rng(seed).integers(0, 4, size=(41, classes))
    best, idx = eval_argmax(c)
    for row, b, i in zip(c, best, idx):
        assert b == row.max()
        assert i == min(np.flatnonzero(row == row.max()))


@settings(max_examples=80, deadline=None)
@given(st.integers(1, 16), st.integers(-(1 << 16), (1 << 16) - 1))
def test_as_signed_roundtrips_fixed_point_const(frac, k):
    k = max(-(1 << frac), min((1 << frac) - 1, k))    # clamp to grid
    c = _fixed_point_const(k / (1 << frac), frac)
    assert 0 <= c < (1 << (frac + 1))
    assert int(as_signed(c, frac + 1)) == k


def test_fixed_point_int_matches_oracle_quantization():
    from repro.core.thermometer import quantize_fixed_point
    x = np.linspace(-1.3, 1.3, 97, dtype=np.float32)
    for frac in (3, 5, 8):
        q = np.asarray(quantize_fixed_point(x, frac), np.float64)
        np.testing.assert_array_equal(
            fixed_point_int(x, frac), np.round(q * (1 << frac)))


# ---------------------------------------------------------------------------
# parser
# ---------------------------------------------------------------------------

def _random_frozen(pen, *, layers=(12, 10), seed=1):
    data = load_jsc(512, 128, seed=0)
    cfg = DWNConfig(num_features=16, bits_per_feature=8,
                    lut_counts=layers, num_classes=5)
    params, buffers = init_dwn(jax.random.PRNGKey(seed), cfg, data.x_train)
    fz = freeze(params, buffers, cfg, input_frac_bits=5 if pen else None)
    return fz, data


def test_parse_netlist_structure():
    fz, _ = _random_frozen(pen=True)
    net = parse_netlist(emit_dwn(fz, name="dwn_p"))
    assert net.name == "dwn_p" and net.pen
    assert net.num_features == 16 and net.input_width == 6
    assert len(net.argmax_srcs) == 5
    assert net.meta["variant"] == "PEN"
    assert net.meta["pipeline"] == "1"
    tags = {op[0] for op in net.ops}
    assert {"cmp", "lut", "const", "sum", "vec", "out"} <= tags

    net_ten = parse_netlist(emit_dwn(fz, name="dwn_c", pipeline=False))
    assert "vec" not in {op[0] for op in net_ten.ops if op[1].endswith("_q")}


def test_parse_netlist_rejects_unknown_constructs():
    fz, _ = _random_frozen(pen=False)
    src = emit_dwn(fz)
    for bad in ["  assign foo = bar & baz;",
                "  always @(negedge clk) q <= d;",
                "  wire [3:0] w = a - b;"]:
        with pytest.raises(CosimParseError):
            parse_netlist(src.replace("endmodule", bad + "\nendmodule"))
    with pytest.raises(CosimParseError):
        parse_netlist("// nothing here\n")


# ---------------------------------------------------------------------------
# end-to-end: evaluator vs apply_hard_packed
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pen", [False, True], ids=["TEN", "PEN"])
@pytest.mark.parametrize("pipeline", [True, False], ids=["pipe", "comb"])
def test_evaluator_bit_exact_multilayer(pen, pipeline):
    fz, data = _random_frozen(pen)
    x = data.x_test[:64]
    rep = verify_rtl(fz, x, pipeline=pipeline, backend="python")
    assert rep.counts_checked and rep.backends == ["python"]
    assert rep.variant == ("PEN" if pen else "TEN")


def test_evaluator_matches_oracle_counts_directly():
    import jax.numpy as jnp
    fz, data = _random_frozen(pen=True)
    x = data.x_test[:32]
    res = evaluate_netlist(emit_dwn(fz), x=x)
    counts = np.asarray(apply_hard_packed(fz, jnp.asarray(x)))
    np.testing.assert_array_equal(res.class_counts, counts)
    np.testing.assert_array_equal(res.argmax_idx, counts.argmax(-1))


def test_ten_path_takes_precomputed_bits():
    fz, data = _random_frozen(pen=False)
    x = data.x_test[:16]
    bits = encode_np(x, fz.thresholds)
    res = evaluate_netlist(emit_dwn(fz), ten_bits=bits)
    ref = verify_rtl(fz, x, backend="python")
    assert ref.n_vectors == 16
    np.testing.assert_array_equal(res.argmax_idx,
                                  evaluate_netlist(ref.src,
                                                   ten_bits=bits).argmax_idx)


def test_mutated_truth_table_is_detected():
    fz, data = _random_frozen(pen=True)
    src = emit_dwn(fz)
    m = re.search(r"INIT_0_0 = 64'h([0-9a-f]{16});", src)
    flipped = f"{(~int(m.group(1), 16)) & (2**64 - 1):016x}"
    bad = src.replace(f"INIT_0_0 = 64'h{m.group(1)};",
                      f"INIT_0_0 = 64'h{flipped};")
    with pytest.raises(RTLMismatch, match="disagrees"):
        verify_rtl(fz, data.x_test[:64], backend="python", src=bad)


def test_mutated_threshold_is_detected():
    fz, data = _random_frozen(pen=True)
    src = emit_dwn(fz)
    m = re.search(r"\$signed\(6'h([0-9a-f]+)\)", src)
    orig = int(m.group(1), 16)
    bad = src.replace(f"$signed(6'h{m.group(1)})",
                      f"$signed(6'h{(orig ^ 0x20):x})", 1)  # flip sign bit
    with pytest.raises(RTLMismatch):
        verify_rtl(fz, data.x_test[:64], backend="python", src=bad)


def test_verify_rtl_on_jsc_presets_256_vectors():
    """The acceptance property at tier-1 scale: sm-50 TEN + PEN, 256 real
    JSC vectors, bit-exact counts/argmax (md/lg ride in the CI cosim
    step, same entry point)."""
    from repro.core import JSC_PRESETS
    data = load_jsc(1000, 256, seed=0)
    cfg = JSC_PRESETS["sm-50"]
    params, buffers = init_dwn(jax.random.PRNGKey(0), cfg, data.x_train)
    for frac in (None, 8):
        fz = freeze(params, buffers, cfg, input_frac_bits=frac)
        rep = verify_rtl(fz, data.x_test[:256], backend="python")
        assert rep.n_vectors == 256 and rep.counts_checked


# ---------------------------------------------------------------------------
# testbench emission + simulator backend
# ---------------------------------------------------------------------------

def test_emit_testbench_structure():
    fz, data = _random_frozen(pen=True)
    x = data.x_test[:3]
    tb = emit_testbench(fz, x, name="dwn_p")
    assert "module tb_dwn;" in tb and tb.count("$display") == 3
    assert "dwn_p dut" in tb and ".x(x)" in tb
    assert tb.count("repeat") == 3 and "$finish" in tb

    fz_t, _ = _random_frozen(pen=False)
    tb_t = emit_testbench(fz_t, x, name="dwn_t")
    assert ".ten_bits(ten_bits)" in tb_t
    # LSB-first packing: recompute vector 0's literal from the oracle bits
    bits = encode_np(x, fz_t.thresholds).astype(np.uint64)
    word = 0
    for k in range(bits.shape[1]):
        if bits[0, k]:
            word |= 1 << k
    assert f"ten_bits = {bits.shape[1]}'h{word:x};" in tb_t


def test_simulator_detection_is_consistent():
    sim = simulator_available()
    has = bool(shutil.which("iverilog")) and bool(shutil.which("vvp"))
    assert (sim == "iverilog") == has


@pytest.mark.skipif(simulator_available() is None,
                    reason="iverilog/vvp not on PATH (pure-Python "
                           "evaluator path still covers equivalence)")
@pytest.mark.parametrize("pen", [False, True], ids=["TEN", "PEN"])
def test_iverilog_backend_bit_exact(pen):
    fz, data = _random_frozen(pen)
    rep = verify_rtl(fz, data.x_test[:16], backend="iverilog")
    assert rep.backends == ["iverilog"]


def test_missing_simulator_raises_not_skips():
    from repro.hw.cosim import SimulatorError
    if simulator_available() is None:
        fz, data = _random_frozen(pen=False)
        with pytest.raises(SimulatorError, match="no Verilog simulator"):
            verify_rtl(fz, data.x_test[:4], backend="iverilog")


# ---------------------------------------------------------------------------
# artifact lifecycle + CLI plumbing
# ---------------------------------------------------------------------------

def test_artifact_verify_rtl_lifecycle():
    from repro.dwn import DWNArtifact, LifecycleError
    from repro.dwn.spec import DWNSpec
    data = load_jsc(512, 128, seed=0)
    spec = DWNSpec(preset="sm-10", variant="PEN", input_bits=6)
    art = DWNArtifact(spec)
    with pytest.raises(LifecycleError, match="freeze"):
        art.verify_rtl(data.x_test[:8])
    art.fit(data.x_train).freeze()
    rep = art.verify_rtl(data.x_test[:32], backend="python")
    assert rep.spec == spec.label
    assert art.calibration["rtl_verified"]["n_vectors"] == 32
    assert art.calibration["rtl_verified"]["counts_checked"]


def test_cosim_cli_smoke(tmp_path, capsys):
    from repro.hw.cosim import main
    out = tmp_path / "report.json"
    rc = main(["--presets", "dwn-jsc-sm", "--variants", "TEN",
               "--n", "32", "--n-train", "512", "--backend", "python",
               "--out", str(out)])
    assert rc == 0
    import json
    rep = json.loads(out.read_text())
    assert rep["results"][0]["agree"] is True
    assert "cosim OK" in capsys.readouterr().out


def test_cosim_cli_require_simulator_exit():
    from repro.hw.cosim import main
    if simulator_available() is None:
        assert main(["--require-simulator", "--n", "4"]) == 2
