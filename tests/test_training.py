"""Scan-compiled training engine: parity, donation, batching, schedule.

The engine's correctness bar (ISSUE 4): at fixed seed the scan trainer
must reproduce the pre-PR reference loop — same batch order, same
schedule step count, loss/accuracy trajectory within fp tolerance — so
it replaces, not forks, the paper-protocol trainer.  The pre-PR loop is
frozen verbatim in ``repro.training.reference`` as the oracle.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import JSC_PRESETS, train_dwn
from repro.core.model import init_dwn
from repro.core.training import eval_soft
from repro.data.jsc import load_jsc, batches
from repro.training import (ScanTrainer, train_dwn_batch,
                            train_dwn_reference)
from repro.training.engine import epoch_permutation
from repro.training.evaluator import cached_evaluator, evaluator_cache_info


@pytest.fixture(scope="module")
def data():
    return load_jsc(2000, 500, seed=0)


@pytest.fixture(scope="module")
def parity_pair(data):
    """(reference, scan) runs of the same protocol at fixed seed."""
    cfg = JSC_PRESETS["sm-50"]
    ref = train_dwn_reference(cfg, data, epochs=3, batch=128, seed=0)
    scan = train_dwn(cfg, data, epochs=3, batch=128, seed=0, verbose=False)
    return cfg, ref, scan


def test_batch_order_matches_reference_iterator(data):
    """The engine's host-side permutation reproduces ``batches`` exactly."""
    n = data.x_train.shape[0]
    for epoch in (0, 1, 7):
        perm = epoch_permutation(n, n // 128, 128, seed=3, epoch=epoch)
        got = [xb for xb, _ in batches(data.x_train, data.y_train, 128,
                                       seed=3, epoch=epoch)]
        want = data.x_train[perm].reshape(len(got), 128, -1)
        np.testing.assert_array_equal(np.stack(got), want)


def test_scan_vs_reference_loss_trajectory(parity_pair):
    """Per-epoch loss within 1e-5 of the pre-PR loop (observed ~1e-7:
    the reassociated backward is fp-equal, the binarized forward
    bit-identical)."""
    _, ref, scan = parity_pair
    lr = np.array([h["loss"] for h in ref.history])
    ls = np.array([h["loss"] for h in scan.history])
    assert np.abs(lr - ls).max() < 1e-5


def test_scan_vs_reference_accuracy_and_params(parity_pair):
    _, ref, scan = parity_pair
    for hr, hs in zip(ref.history, scan.history):
        assert abs(hr["test_acc"] - hs["test_acc"]) < 1e-6
    # binarized tables identical; scores within reassociation jitter
    tr = np.asarray(ref.params["layers"][0]["tables"])
    ts = np.asarray(scan.params["layers"][0]["tables"])
    np.testing.assert_array_equal(tr > 0, ts > 0)
    sr = np.asarray(ref.params["layers"][0]["scores"])
    ss = np.asarray(scan.params["layers"][0]["scores"])
    assert np.abs(sr - ss).max() < 1e-4


def test_schedule_step_count_preserved(data):
    """StepLR boundary semantics: the scan trainer takes exactly
    steps_per_epoch optimizer steps per epoch (drop-remainder), so the
    epoch->step conversion of the schedule is unchanged."""
    cfg = JSC_PRESETS["sm-50"]
    tr = ScanTrainer(cfg, data, batch=128, seed=0)
    assert tr.steps_per_epoch == data.x_train.shape[0] // 128
    tr.run_epochs(2)
    assert int(tr.opt_state.step) == 2 * tr.steps_per_epoch
    # the folded schedule crosses its boundary at the same step the
    # reference's host-side schedule would
    sched = tr.opt.lr
    spe = tr.steps_per_epoch
    lr_before = float(sched(jnp.asarray(30 * spe - 1)))
    lr_after = float(sched(jnp.asarray(30 * spe)))
    assert lr_before == pytest.approx(1e-3)
    assert lr_after == pytest.approx(1e-4)


def test_donation_does_not_alias_caller_state(data):
    """params/opt state are donated into the epoch program; the engine
    must train on private copies so caller-held warm starts survive and
    repeated runs from the same start are identical."""
    cfg = JSC_PRESETS["sm-50"]
    params, buffers = init_dwn(jax.random.PRNGKey(0), cfg, data.x_train)
    snap = jax.tree.map(lambda a: np.asarray(a).copy(), params)

    r1 = train_dwn(cfg, data, epochs=2, batch=128, seed=0, params=params,
                   buffers=buffers, verbose=False, eval_every=0)
    # caller arrays still alive and unchanged after the donated run
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), b), params, snap)
    # reusing the same warm start reproduces the run exactly
    r2 = train_dwn(cfg, data, epochs=2, batch=128, seed=0, params=params,
                   buffers=buffers, verbose=False, eval_every=0)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), r1.params, r2.params)
    # returned (donated-program output) arrays are usable
    assert np.isfinite(float(jnp.sum(r1.params["layers"][0]["scores"])))


def test_train_dwn_batch_matches_sequential(data):
    """Vmapped multi-seed training == per-seed sequential scan runs."""
    cfg = JSC_PRESETS["sm-50"]
    seeds = (0, 1)
    out = train_dwn_batch(cfg, data, epochs=2, seeds=seeds, batch=128)
    assert len(out.results) == len(seeds)
    for i, s in enumerate(seeds):
        seq = train_dwn(cfg, data, epochs=2, batch=128, seed=s,
                        verbose=False, eval_every=0)
        lb = np.array([h["loss"] for h in out.results[i].history])
        lq = np.array([h["loss"] for h in seq.history])
        assert np.abs(lb - lq).max() < 1e-5
        assert out.results[i].soft_test_acc == pytest.approx(
            seq.soft_test_acc, abs=1e-6)
        jax.tree.map(
            lambda a, b: np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), atol=1e-5),
            out.results[i].params, seq.params)


def test_evaluator_cache_reused(data):
    """eval_soft compiles once per (cfg, input_frac_bits): repeated calls
    are cache hits, and the cached callable is the same object."""
    cfg = JSC_PRESETS["sm-10"]
    params, buffers = init_dwn(jax.random.PRNGKey(0), cfg, data.x_train)
    ev1 = cached_evaluator(cfg, None)
    before = evaluator_cache_info().hits
    eval_soft(params, buffers, cfg, data.x_test, data.y_test)
    eval_soft(params, buffers, cfg, data.x_test, data.y_test)
    assert cached_evaluator(cfg, None) is ev1
    assert evaluator_cache_info().hits >= before + 2
    # distinct key -> distinct evaluator (PEN quantization changes logits)
    assert cached_evaluator(cfg, 4) is not ev1


DP_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
sys.path.insert(0, sys.argv[1])
import numpy as np
from repro.core import JSC_PRESETS
from repro.data.jsc import load_jsc
from repro.training import train_dwn_batch

data = load_jsc(512, 256, seed=0)
cfg = JSC_PRESETS["sm-10"]
out = train_dwn_batch(cfg, data, epochs=1, seeds=tuple(range(8)),
                      batch=64, eval_final=False)
losses = [r.history[0]["loss"] for r in out.results]
print("RESULT " + json.dumps({
    "dp": out.data_parallel, "n": len(out.results),
    "distinct": len({round(l, 6) for l in losses}),
    "finite": all(np.isfinite(l) for l in losses)}))
"""


def test_train_dwn_batch_shard_map_data_parallel():
    """8 fake host devices: the stacked model axis lays over the
    ("data",) mesh with shard_map; every member still trains its own
    seed (distinct losses) and stays finite."""
    import subprocess, sys, json, os
    from pathlib import Path
    root = Path(__file__).resolve().parents[1]
    proc = subprocess.run(
        [sys.executable, "-c", DP_SCRIPT, str(root / "src")],
        capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")]
    assert line, proc.stdout[-2000:]
    out = json.loads(line[0][len("RESULT "):])
    assert out["dp"] is True
    assert out["n"] == 8 and out["finite"]
    assert out["distinct"] >= 7          # per-seed trajectories differ


def test_eval_every_zero_single_program(data):
    """eval_every=0 runs all epochs as one device program; history and
    final accuracy match the per-epoch-eval run (eval never mutates)."""
    cfg = JSC_PRESETS["sm-50"]
    a = train_dwn(cfg, data, epochs=3, batch=128, seed=0, verbose=False)
    b = train_dwn(cfg, data, epochs=3, batch=128, seed=0, verbose=False,
                  eval_every=0)
    la = [h["loss"] for h in a.history]
    lb = [h["loss"] for h in b.history]
    np.testing.assert_allclose(la, lb, atol=1e-6)
    assert a.soft_test_acc == pytest.approx(b.soft_test_acc, abs=1e-6)
