"""Packed-bitplane path: pack/unpack round-trips, packed kernels vs their
float twins, and bit-exactness of apply_hard_packed against the apply_hard
oracle on every JSC preset (TEN and PEN) plus a multi-layer stack."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.bitpack import (PackedBits, pack_bits, unpack_bits,
                                pack_bits_np, unpack_bits_np, popcount_u32,
                                popcount_u32_np, words_for_bits,
                                group_masks_np)
from repro.core import (JSC_PRESETS, init_dwn, freeze, apply_hard,
                        apply_hard_packed)
from repro.core.model import DWNConfig
from repro.data.jsc import load_jsc


# ---------------------------------------------------------------------------
# pack/unpack round-trip properties
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(st.integers(1, 300), st.integers(1, 8), st.integers(0, 2 ** 31 - 1))
def test_pack_unpack_roundtrip(num_bits, batch, seed):
    """Round-trips for arbitrary widths, including non-multiples of 32."""
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, (batch, num_bits))
    words = pack_bits_np(bits)
    assert words.shape == (batch, words_for_bits(num_bits))
    assert words.dtype == np.uint32
    np.testing.assert_array_equal(unpack_bits_np(words, num_bits), bits)
    # JAX twins agree with NumPy twins exactly
    jwords = pack_bits(jnp.asarray(bits))
    np.testing.assert_array_equal(np.asarray(jwords), words)
    np.testing.assert_array_equal(
        np.asarray(unpack_bits(jwords, num_bits)), bits)


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 9), st.integers(0, 2 ** 31 - 1))
def test_unpack_pack_identity_at_word_boundaries(words_n, seed):
    """The other direction: pack(unpack(words)) is the identity on any
    word content when num_bits fills the words exactly."""
    rng = np.random.default_rng(seed)
    words = rng.integers(0, 2 ** 32, (3, words_n), dtype=np.uint32)
    num_bits = 32 * words_n
    np.testing.assert_array_equal(
        pack_bits_np(unpack_bits_np(words, num_bits)), words)


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 300), st.integers(0, 2 ** 31 - 1))
def test_unpack_pack_identity_modulo_pad(num_bits, seed):
    """At ragged widths the identity holds after zeroing the pad bits —
    and only the pad bits are dropped."""
    rng = np.random.default_rng(seed)
    words = rng.integers(0, 2 ** 32, (3, words_for_bits(num_bits)),
                         dtype=np.uint32)
    masked = words.copy()
    tail = num_bits & 31
    if tail:
        masked[:, -1] &= np.uint32((1 << tail) - 1)
    np.testing.assert_array_equal(
        pack_bits_np(unpack_bits_np(words, num_bits)), masked)


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 300), st.integers(0, 2 ** 31 - 1))
def test_pad_bits_are_zero_and_popcount_matches(num_bits, seed):
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, (4, num_bits))
    words = pack_bits_np(bits)
    # zero-pad invariant: total popcount equals the logical bit count
    np.testing.assert_array_equal(popcount_u32_np(words).sum(-1),
                                  bits.sum(-1))
    np.testing.assert_array_equal(
        np.asarray(popcount_u32(jnp.asarray(words))).sum(-1), bits.sum(-1))


def test_lsb_first_word_order():
    """The documented convention: bit i -> word i>>5, position i&31."""
    bits = np.zeros((1, 70), np.int32)
    bits[0, 0] = 1      # word 0, bit 0
    bits[0, 33] = 1     # word 1, bit 1
    bits[0, 69] = 1     # word 2, bit 5
    words = pack_bits_np(bits)
    assert words.shape == (1, 3)
    assert words[0].tolist() == [1, 2, 32]


def test_group_masks_cover_disjoint():
    masks = group_masks_np(2400, 5)
    assert masks.shape == (5, 75)
    # disjoint and complete over the logical bits
    assert int(popcount_u32_np(masks).sum()) == 2400
    acc = np.zeros(75, np.uint32)
    for g in range(5):
        assert not np.any(acc & masks[g])
        acc |= masks[g]


def test_packedbits_is_pytree():
    p = PackedBits.pack(jnp.asarray(np.eye(3, 50)))
    out = jax.jit(lambda q: q)(p)
    assert out.num_bits == 50
    np.testing.assert_array_equal(np.asarray(out.words), np.asarray(p.words))


# ---------------------------------------------------------------------------
# packed kernels vs float kernels (interpret mode)
# ---------------------------------------------------------------------------

def _rand_model(B, F, T, m, n=6, seed=0):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    x = jax.random.uniform(k1, (B, F), minval=-1, maxval=1)
    th = jnp.sort(jax.random.uniform(k2, (F, T), minval=-1, maxval=1), 1)
    mapping = jax.random.randint(k3, (m, n), 0, F * T)
    tables = jax.random.randint(k4, (m, 2 ** n), 0, 2)
    return x, th, mapping, tables


@pytest.mark.parametrize("B,F,T", [(8, 4, 32), (37, 16, 200), (64, 1, 128)])
def test_encode_packed_kernel_matches_float(B, F, T):
    from repro.kernels.thermometer import ops as th_ops
    x, th, _, _ = _rand_model(B, F, T, 8, seed=B)
    p = th_ops.encode_packed(x, th, interpret=True)
    f = th_ops.encode(x, th, interpret=True)
    assert p.words.dtype == jnp.uint32
    assert p.num_bits == F * T
    np.testing.assert_array_equal(np.asarray(p.unpack()), np.asarray(f))


def test_encode_packed_fallback_non_word_multiple():
    """F*T not a 32-multiple takes the jnp fallback, same layout."""
    from repro.kernels.thermometer import ops as th_ops
    x, th, _, _ = _rand_model(9, 3, 7, 8, seed=5)
    p = th_ops.encode_packed(x, th, interpret=True)
    f = th_ops.encode(x, th, interpret=True)
    assert p.num_bits == 21
    np.testing.assert_array_equal(np.asarray(p.unpack()), np.asarray(f))


@pytest.mark.parametrize("B,m,C", [(16, 10, 320), (33, 50, 3200),
                                   (128, 360, 3200)])
def test_lut_eval_packed_kernel(B, m, C):
    from repro.kernels.lut_eval import ops as lut_ops
    key = jax.random.PRNGKey(m)
    bits = jax.random.bernoulli(key, 0.5, (B, C)).astype(jnp.float32)
    mapping = jax.random.randint(key, (m, 6), 0, C)
    tables = jax.random.randint(key, (m, 64), 0, 2)
    packed = PackedBits.pack(bits)
    out = lut_ops.evaluate_packed(packed, mapping, tables, interpret=True)
    ref = lut_ops.evaluate(bits, mapping, tables.astype(jnp.float32),
                           interpret=True)
    assert out.num_bits == m
    np.testing.assert_array_equal(np.asarray(out.unpack()), np.asarray(ref))


@pytest.mark.parametrize("B,classes,group", [(16, 5, 2), (37, 5, 72),
                                             (512, 10, 13)])
def test_popcount_packed_kernel(B, classes, group):
    from repro.kernels.popcount import ops as pc_ops
    key = jax.random.PRNGKey(B + classes)
    bits = jax.random.bernoulli(key, 0.4, (B, classes * group)) \
        .astype(jnp.float32)
    packed = PackedBits.pack(bits)
    counts, idx = pc_ops.classify_packed(packed, classes, interpret=True)
    rc, ri = pc_ops.classify(bits, classes, interpret=True)
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(rc))
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ri))


@pytest.mark.parametrize("B,m", [(8, 10), (37, 50), (64, 360)])
def test_fused_packed_kernel_single_layer(B, m):
    from repro.kernels.fused import ops as f_ops
    x, th, mapping, tables = _rand_model(B, 16, 200, m, seed=m)
    counts, idx = f_ops.forward_packed(x, th, mapping, tables, 5,
                                       interpret=True)
    ref_counts, ref_idx = f_ops.forward(
        x, th, mapping, tables.astype(jnp.float32), 5, interpret=True)
    np.testing.assert_allclose(np.asarray(counts), np.asarray(ref_counts),
                               atol=1e-4)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ref_idx))


@pytest.mark.parametrize("B,m", [(8, 10), (37, 50), (64, 360)])
@pytest.mark.parametrize("block_b", [256, 16])
def test_fused_batch_major_variant(B, m, block_b):
    """Direct-wire batch-major variant: bit-exact vs the packed oracle
    at every preset width, ragged batches, and with a grid of >1 step."""
    from repro.kernels.autotune import FusedConfig
    from repro.kernels.fused import ops as f_ops
    from repro.kernels.fused.ref import fused_dwn_packed_ref
    x, th, mapping, tables = _rand_model(B, 16, 200, m, seed=m + 1)
    counts, idx = f_ops.forward_packed(
        x, th, mapping, tables, 5, interpret=True,
        config=FusedConfig(variant="batch-major", block_b=block_b))
    ref_counts, ref_idx = fused_dwn_packed_ref(x, th, [mapping], [tables], 5)
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(ref_counts))
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ref_idx))


def test_fused_batch_major_multilayer():
    """Two-layer stack through the batch-major variant (layer 0 direct
    wires -> packed continuation) == the packed-variant kernel."""
    from repro.kernels.autotune import FusedConfig
    from repro.kernels.fused import ops as f_ops
    from repro.kernels.fused.ref import fused_dwn_packed_ref
    key = jax.random.PRNGKey(7)
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    x = jax.random.uniform(k1, (43, 16), minval=-1, maxval=1)
    th = jnp.sort(jax.random.uniform(k2, (16, 200), minval=-1, maxval=1), 1)
    mappings = [jax.random.randint(k3, (96, 6), 0, 3200),
                jax.random.randint(k4, (50, 6), 0, 96)]
    tables = [jax.random.randint(k5, (96, 64), 0, 2),
              jax.random.randint(k5, (50, 64), 0, 2)]
    counts, idx = f_ops.forward_packed(
        x, th, mappings, tables, 5, interpret=True,
        config=FusedConfig(variant="batch-major", block_b=16))
    ref_counts, ref_idx = fused_dwn_packed_ref(x, th, mappings, tables, 5)
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(ref_counts))
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ref_idx))


# ---------------------------------------------------------------------------
# apply_hard_packed: bit-exact vs the float oracle on every preset
# ---------------------------------------------------------------------------

_DATA = None


def _data():
    global _DATA
    if _DATA is None:
        _DATA = load_jsc(2000, 256)
    return _DATA


@pytest.mark.parametrize("preset", sorted(JSC_PRESETS))
@pytest.mark.parametrize("frac_bits", [None, 8])
def test_apply_hard_packed_bit_exact(preset, frac_bits):
    """TEN (frac_bits=None) and PEN-quantized, all four paper presets."""
    data = _data()
    cfg = JSC_PRESETS[preset]
    params, buffers = init_dwn(jax.random.PRNGKey(1), cfg, data.x_train)
    fr = freeze(params, buffers, cfg, input_frac_bits=frac_bits)
    x = jnp.asarray(data.x_test[:96])
    oracle = np.asarray(apply_hard(fr, x))
    packed = np.asarray(apply_hard_packed(fr, x))
    np.testing.assert_array_equal(packed, oracle)


def test_apply_hard_packed_multilayer_and_fused_kernel():
    """Two-layer stack: jnp packed path AND fused packed kernel vs oracle."""
    from repro.kernels.fused import ops as f_ops
    data = _data()
    cfg = DWNConfig(lut_counts=(96, 50))
    params, buffers = init_dwn(jax.random.PRNGKey(2), cfg, data.x_train)
    fr = freeze(params, buffers, cfg)
    x = jnp.asarray(data.x_test[:64])
    oracle = np.asarray(apply_hard(fr, x))
    np.testing.assert_array_equal(np.asarray(apply_hard_packed(fr, x)),
                                  oracle)
    counts, idx = f_ops.forward_packed(
        x, jnp.asarray(fr.thresholds),
        [jnp.asarray(i) for i in fr.mapping_idx],
        [jnp.asarray(t) for t in fr.tables_bin], cfg.num_classes,
        interpret=True)
    np.testing.assert_array_equal(np.asarray(counts), oracle)
    np.testing.assert_array_equal(np.asarray(idx), oracle.argmax(-1))


def test_apply_hard_packed_under_jit():
    data = _data()
    cfg = JSC_PRESETS["sm-50"]
    params, buffers = init_dwn(jax.random.PRNGKey(3), cfg, data.x_train)
    fr = freeze(params, buffers, cfg)
    x = jnp.asarray(data.x_test[:32])
    jitted = jax.jit(lambda xb: apply_hard_packed(fr, xb))
    np.testing.assert_array_equal(np.asarray(jitted(x)),
                                  np.asarray(apply_hard(fr, x)))
