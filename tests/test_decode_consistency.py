"""Serving correctness: prefill + decode_step must equal the full
forward pass at the next position, per architecture family."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch
from repro.models import api

FAMS = ["qwen3-8b", "mixtral-8x7b", "granite-moe-3b-a800m", "mamba2-1.3b",
        "recurrentgemma-2b", "phi3-mini-3.8b", "qwen2-7b", "qwen3-14b",
        "llava-next-34b"]


@pytest.mark.parametrize("arch", FAMS)
def test_prefill_decode_matches_forward(arch):
    cfg = get_arch(arch).reduced()
    if cfg.family == "moe":
        # avoid capacity-drop divergence between the S and S+1 passes
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    mod = api.module_for(cfg)
    key = jax.random.PRNGKey(0)
    params = mod.init_params(key, cfg, tp=1)
    B, S = 2, 32
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (B, cfg.num_patches, cfg.d_model), jnp.float32) * 0.02

    lg_pre, cache = mod.prefill(params, cfg, batch, tp=1, cache_len=S + 4)
    nxt = jnp.full((B, 1), 7, jnp.int32)
    lg_dec, _ = mod.decode_step(params, cfg, cache, nxt, tp=1)

    batch2 = dict(batch, tokens=jnp.concatenate([batch["tokens"], nxt], 1))
    lg_ref, *_ = mod.forward(params, cfg, batch2, tp=1)
    if cfg.family == "vlm":
        lg_ref = lg_ref[:, cfg.num_patches:]
    ref_last = np.asarray(lg_ref[:, -1], np.float32)
    got = np.asarray(lg_dec, np.float32)
    scale = max(np.abs(ref_last).max(), 1e-3)
    err = np.abs(got - ref_last).max() / scale
    assert err < 0.05, (arch, err)


def test_whisper_prefill_decode_matches():
    cfg = get_arch("whisper-large-v3").reduced()
    mod = api.module_for(cfg)
    key = jax.random.PRNGKey(0)
    params = mod.init_params(key, cfg, tp=1)
    B, S = 2, 16
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
             "frames": jax.random.normal(
                 key, (B, cfg.enc_frames, cfg.d_model), jnp.float32) * 0.1}
    lg_pre, cache = mod.prefill(params, cfg, batch, tp=1, cache_len=S + 4)
    nxt = jnp.full((B, 1), 5, jnp.int32)
    lg_dec, _ = mod.decode_step(params, cfg, cache, nxt, tp=1)
    enc = mod.encode(params, cfg, batch["frames"], tp=1)
    toks2 = jnp.concatenate([batch["tokens"], nxt], 1)
    lg_ref, _, _ = mod.decode_train(params, cfg, toks2, enc, tp=1)
    ref = np.asarray(lg_ref[:, -1], np.float32)
    got = np.asarray(lg_dec, np.float32)
    err = np.abs(got - ref).max() / max(np.abs(ref).max(), 1e-3)
    assert err < 0.05, err


def test_multistep_decode_mamba2():
    """Four decode steps equal the 4-longer forward (state recurrence)."""
    cfg = get_arch("mamba2-1.3b").reduced()
    mod = api.module_for(cfg)
    params = mod.init_params(jax.random.PRNGKey(0), cfg, 1)
    B, S = 2, 24
    key = jax.random.PRNGKey(2)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    _, cache = mod.prefill(params, cfg, batch, tp=1)
    toks = batch["tokens"]
    for t in range(4):
        nxt = jnp.full((B, 1), 3 + t, jnp.int32)
        lg, cache = mod.decode_step(params, cfg, cache, nxt, tp=1)
        toks = jnp.concatenate([toks, nxt], 1)
    lg_ref, _ = mod.forward(params, cfg, {"tokens": toks}, tp=1)
    err = np.abs(np.asarray(lg, np.float32)
                 - np.asarray(lg_ref[:, -1], np.float32)).max()
    assert err < 0.05, err


def test_attention_tri_equals_masked_end_to_end():
    """The §Perf block-triangular attention is a drop-in: same logits."""
    cfg = get_arch("qwen3-8b").reduced()
    cfg_tri = dataclasses.replace(cfg, attn_impl="tri")
    mod = api.module_for(cfg)
    params = mod.init_params(jax.random.PRNGKey(0), cfg, 1)
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (2, 48), 0, cfg.vocab_size)}
    a, *_ = mod.forward(params, cfg, batch, tp=1)
    b, *_ = mod.forward(params, cfg_tri, batch, tp=1)
    err = np.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32)).max()
    assert err < 0.05, err
