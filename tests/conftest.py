import importlib.util
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Hermetic autotune: never read or write the user-level fused-kernel
# config cache (~/.cache/repro/autotune) from the test suite.
os.environ.setdefault(
    "REPRO_AUTOTUNE_CACHE",
    os.path.join(os.path.dirname(__file__), "..", ".pytest_cache",
                 "autotune_cache.json"))

# Property tests use `hypothesis` (declared in pyproject.toml). In offline
# environments where it cannot be installed, register the deterministic shim
# from tests/_hypothesis_shim.py under the same module name.
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _spec = importlib.util.spec_from_file_location(
        "hypothesis",
        os.path.join(os.path.dirname(__file__), "_hypothesis_shim.py"))
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _mod.strategies
