"""Trip-count-aware HLO costing: controlled ground-truth checks."""

import jax
import jax.numpy as jnp
import pytest

from repro.roofline.hlo_costs import analyze_hlo, _shape_bytes


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_dot_flops_match_xla_straightline():
    w = jax.ShapeDtypeStruct((256, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((64, 256), jnp.float32)
    c = _compile(lambda a, b: a @ b, x, w)
    ours = analyze_hlo(c.as_text(), 1).flops
    xla = c.cost_analysis()
    xla = (xla[0] if isinstance(xla, (list, tuple)) else xla).get("flops", 0)
    assert ours == pytest.approx(xla)


def test_scan_trip_count_multiplied():
    """The motivating bug: XLA counts a while body once; we multiply."""
    ws = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((16, 128), jnp.float32)

    def scanned(ws, x):
        return jax.lax.scan(lambda h, w: (h @ w, None), x, ws)[0]

    c = _compile(scanned, ws, x)
    ours = analyze_hlo(c.as_text(), 1).flops
    expect = 8 * 2 * 16 * 128 * 128
    assert ours == pytest.approx(expect)
    xla = c.cost_analysis()
    xla = (xla[0] if isinstance(xla, (list, tuple)) else xla).get("flops", 0)
    assert xla < ours                     # the undercount we correct


def test_nested_scan_trips_compose():
    ws = jax.ShapeDtypeStruct((4, 3, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)

    def nested(ws, x):
        def outer(h, wrow):
            h2 = jax.lax.scan(lambda hh, w: (hh @ w, None), h, wrow)[0]
            return h2, None
        return jax.lax.scan(outer, x, ws)[0]

    c = _compile(nested, ws, x)
    ours = analyze_hlo(c.as_text(), 1).flops
    assert ours == pytest.approx(12 * 2 * 8 * 64 * 64)


def test_inplace_dus_bytes_small():
    cache = jax.ShapeDtypeStruct((16, 1024, 64), jnp.bfloat16)
    upd = jax.ShapeDtypeStruct((16, 1, 64), jnp.bfloat16)

    def dus(c, u):
        return jax.lax.dynamic_update_slice(c, u, (0, 5, 0))

    c = jax.jit(dus, donate_argnums=0).lower(cache, upd).compile()
    r = analyze_hlo(c.as_text(), 1)
    full = 16 * 1024 * 64 * 2
    assert r.hbm_bytes < 0.05 * full       # in-place, not full-buffer


def test_shape_bytes_edge_cases():
    assert _shape_bytes("bf16[2,3]{1,0}") == 12
    assert _shape_bytes("pred[7]") == 7
    assert _shape_bytes("token[]") == 0
    assert _shape_bytes("notashape") == 0
