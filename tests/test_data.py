"""Data pipelines: JSC surrogate + LM token stream."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data.jsc import load_jsc, bayes_accuracy, batches
from repro.data.tokens import TokenStream


def test_jsc_deterministic_and_normalized():
    a = load_jsc(256, 64, seed=3)
    b = load_jsc(256, 64, seed=3)
    np.testing.assert_array_equal(a.x_train, b.x_train)
    np.testing.assert_array_equal(a.y_train, b.y_train)
    assert a.x_train.min() >= -1.0 and a.x_train.max() < 1.0
    assert set(np.unique(a.y_train)) <= set(range(5))


def test_jsc_bayes_ceiling_in_paper_band():
    """The surrogate is calibrated so the Bayes ceiling sits just above
    the paper's best model (76.3%)."""
    acc = bayes_accuracy(20_000)
    assert 0.765 <= acc <= 0.82


def test_jsc_class_balance():
    d = load_jsc(5000, 100, seed=0)
    frac = np.bincount(d.y_train, minlength=5) / len(d.y_train)
    assert frac.min() > 0.08 and frac.max() < 0.4


def test_batches_deterministic_resumable():
    d = load_jsc(512, 64, seed=1)
    run1 = [xb.sum() for xb, _ in batches(d.x_train, d.y_train, 64,
                                          seed=5, epoch=2)]
    run2 = [xb.sum() for xb, _ in batches(d.x_train, d.y_train, 64,
                                          seed=5, epoch=2)]
    assert run1 == run2
    run3 = [xb.sum() for xb, _ in batches(d.x_train, d.y_train, 64,
                                          seed=5, epoch=3)]
    assert run1 != run3                      # different epoch, new order


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 4), st.integers(0, 3), st.integers(2, 64))
def test_token_stream_sharding_disjoint_and_deterministic(hosts, step, seq):
    hosts = int(2 ** np.ceil(np.log2(hosts)))
    streams = [TokenStream(1000, seq, 8 * hosts, seed=1, num_hosts=hosts,
                           host_id=h, step=step) for h in range(hosts)]
    batches_ = [s.next_batch()["tokens"] for s in streams]
    for b in batches_:
        assert b.shape == (8, seq)
    # deterministic per (seed, step, host)
    again = TokenStream(1000, seq, 8 * hosts, seed=1, num_hosts=hosts,
                        host_id=0, step=step).next_batch()["tokens"]
    np.testing.assert_array_equal(batches_[0], again)
    if hosts > 1:
        assert not np.array_equal(batches_[0], batches_[1])


def test_token_stream_resume():
    s = TokenStream(500, 16, 4, seed=9)
    s.next_batch(); s.next_batch()
    state = s.state()
    b3 = s.next_batch()
    s2 = TokenStream(500, 16, 4, seed=9)
    s2.restore(state)
    np.testing.assert_array_equal(b3["tokens"], s2.next_batch()["tokens"])


def test_token_stream_learnable_structure():
    """The Markov backbone makes next-token prediction beat chance."""
    s = TokenStream(100, 256, 8, seed=2)
    b = s.next_batch()["tokens"]
    # successor entropy given prev token is far below log2(V)
    pairs = {}
    for row in b:
        for a, c in zip(row[:-1], row[1:]):
            pairs.setdefault(int(a), []).append(int(c))
    top1 = np.mean([max(np.bincount(v).max() / len(v), 0)
                    for v in pairs.values() if len(v) >= 5])
    assert top1 > 0.2                        # >> 1/V = 0.01
