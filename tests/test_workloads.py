"""Workload registry tests.

Covers: registry resolution (unknown-name errors that list the known
names), synthetic-fallback determinism, byte-exact JSC parity between
the registry loader and the legacy ``data.jsc.load_jsc`` path,
spec/sweep-point fingerprint stability (the default workload is omitted
from serialized dicts so pre-registry cache keys survive), the MNIST
end-to-end smoke (train -> freeze/pack -> serve bit-exact vs the packed
oracle -> cosim verify), and the LM-head workload + the engine's
``dwn_head`` path (one engine serving LM decode and a packed DWN head).
"""

import numpy as np
import pytest

from repro.data.jsc import load_jsc
from repro.dwn import DWNArtifact, DWNSpec, resolve_spec
from repro.workloads import (Workload, get_workload, list_workloads,
                             load_workload, register_workload)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_lists_builtin_workloads():
    names = list_workloads()
    assert {"jsc", "mnist", "lm-head"} <= set(names)


def test_unknown_workload_error_lists_known_names():
    with pytest.raises(KeyError, match="unknown workload 'cifar'.*jsc"):
        get_workload("cifar")
    with pytest.raises(KeyError, match="mnist"):
        load_workload("nope", 32, 16)


def test_workload_schema_matches_presets():
    for name in ("jsc", "mnist", "lm-head"):
        wl = get_workload(name)
        for tier, cfg in wl.presets.items():
            assert cfg.num_features == wl.num_features, (name, tier)
            assert cfg.num_classes == wl.num_classes, (name, tier)


def test_reregistering_name_is_an_error():
    wl = get_workload("jsc")
    with pytest.raises(AssertionError, match="already registered"):
        register_workload(Workload(
            name="jsc", num_features=wl.num_features,
            num_classes=wl.num_classes, loader=wl.loader,
            presets=wl.presets))


def test_jsc_parity_registry_vs_legacy_loader_byte_exact():
    old = load_jsc(256, 64, seed=3)
    new = load_workload("jsc", 256, 64, seed=3)
    for field in ("x_train", "y_train", "x_test", "y_test"):
        a, b = getattr(old, field), getattr(new, field)
        assert a.dtype == b.dtype
        assert np.array_equal(a, b), field


def test_workload_caps_clamp_split_sizes():
    wl = get_workload("lm-head")
    assert wl.cap_train is not None and wl.cap_test is not None
    # a request over the cap must come back clamped, not error
    d = wl.load(wl.cap_train + 999, wl.cap_test + 999, seed=0)
    assert d.x_train.shape[0] == wl.cap_train
    assert d.x_test.shape[0] == wl.cap_test


# ---------------------------------------------------------------------------
# MNIST synthetic fallback
# ---------------------------------------------------------------------------

def test_mnist_synthetic_deterministic(monkeypatch):
    monkeypatch.delenv("REPRO_MNIST_DOWNLOAD", raising=False)
    monkeypatch.setenv("REPRO_MNIST", "/nonexistent/mnist.npz")
    a = load_workload("mnist", 128, 32, seed=7)
    b = load_workload("mnist", 128, 32, seed=7)
    for field in ("x_train", "y_train", "x_test", "y_test"):
        assert np.array_equal(getattr(a, field), getattr(b, field)), field
    c = load_workload("mnist", 128, 32, seed=8)
    assert not np.array_equal(a.x_train, c.x_train)


def test_mnist_schema_and_input_contract(monkeypatch):
    monkeypatch.setenv("REPRO_MNIST", "/nonexistent/mnist.npz")
    d = load_workload("mnist", 128, 32, seed=0)
    assert d.x_train.shape == (128, 196) and d.x_test.shape == (32, 196)
    assert d.x_train.dtype == np.float32
    assert d.y_train.dtype == np.int32
    # thermometer input contract: features normalized into [-1, 1)
    assert d.x_train.min() >= -1.0 and d.x_train.max() < 1.0
    assert set(np.unique(d.y_train)) <= set(range(10))


def test_mnist_real_npz_roundtrip(tmp_path, monkeypatch):
    # a tiny fake "real" npz in the Keras layout exercises the non-
    # synthetic path: 28x28 uint8 images pooled down to the 196 schema
    rng = np.random.default_rng(0)
    np.savez(tmp_path / "mnist.npz",
             x_train=rng.integers(0, 256, (64, 28, 28), dtype=np.uint8),
             y_train=rng.integers(0, 10, 64, dtype=np.int64),
             x_test=rng.integers(0, 256, (32, 28, 28), dtype=np.uint8),
             y_test=rng.integers(0, 10, 32, dtype=np.int64))
    monkeypatch.setenv("REPRO_MNIST", str(tmp_path / "mnist.npz"))
    d = load_workload("mnist", 48, 16, seed=1)
    assert d.x_train.shape == (48, 196) and d.x_test.shape == (16, 196)
    assert d.x_train.min() >= -1.0 and d.x_train.max() < 1.0


# ---------------------------------------------------------------------------
# spec / sweep integration: fingerprints stay stable, presets validate
# ---------------------------------------------------------------------------

def test_jsc_spec_dict_has_no_workload_key():
    # pre-registry fingerprints, sweep-cache keys, and checkpoints hash
    # the spec dict: the default workload must not appear in it
    d = DWNSpec(preset="sm-50").to_dict()
    assert "workload" not in d and "backbone" not in d
    d2 = DWNSpec(preset="mnist-sm", bits=8, workload="mnist").to_dict()
    assert d2["workload"] == "mnist"
    assert DWNSpec.from_dict(d2).workload == "mnist"


def test_spec_rejects_preset_workload_mismatch():
    with pytest.raises(ValueError, match="workload 'mnist'.*mnist-sm"):
        DWNSpec(preset="sm-50", workload="mnist")
    with pytest.raises(ValueError, match="workload 'jsc'"):
        DWNSpec(preset="mnist-sm")


def test_spec_rejects_unknown_workload():
    with pytest.raises(ValueError, match="unknown workload"):
        DWNSpec(preset="sm-50", workload="cifar")


def test_mnist_spec_presets_registered():
    for tier in ("sm", "md", "lg"):
        spec = resolve_spec(f"dwn-mnist-{tier}")
        assert spec.workload == "mnist"
        cfg = spec.dwn_config()
        assert cfg.num_features == 196 and cfg.num_classes == 10
        assert cfg.lut_counts[-1] % 10 == 0
        arch = spec.arch_config()
        assert arch.d_model == 196 and arch.vocab_size == 10


def test_sweep_point_workload_label_and_dict_stability():
    from repro.sweep.grid import SweepPoint
    jsc = SweepPoint("sm-50", "TEN")
    assert "workload" not in jsc.to_dict()
    mn = SweepPoint("mnist-sm", "TEN", bits=8, workload="mnist")
    assert mn.to_dict()["workload"] == "mnist"
    assert mn.label.startswith("mnist:")
    assert SweepPoint.from_dict(mn.to_dict()) == mn


def test_mnist_grids_registered():
    from repro.sweep.grid import load_grid
    tiny = load_grid("mnist-tiny")
    assert all(p.workload == "mnist" for p in tiny)
    assert any(p.variant == "PEN" for p in tiny)
    full = load_grid("mnist")
    assert {p.preset for p in full} == {"mnist-sm", "mnist-md", "mnist-lg"}


# ---------------------------------------------------------------------------
# MNIST end-to-end smoke: train -> pack -> serve bit-exact -> cosim
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mnist_artifact():
    import jax.numpy as jnp  # noqa: F401 (jax init before data)
    data = load_workload("mnist", 512, 96, seed=0)
    spec = resolve_spec("dwn-mnist-sm")
    art = DWNArtifact(spec).train(data, epochs=1, batch=128, seed=0)
    art.freeze().pack()
    return art, data


def test_mnist_end_to_end_serve_bit_exact(mnist_artifact):
    import jax.numpy as jnp
    from repro.core.model import apply_hard_packed
    from repro.serving import ServingEngine

    art, data = mnist_artifact
    assert art.stage == "packed"
    engine = ServingEngine(art, max_bucket=32, min_bucket=8,
                           n_train=256, seed=0)
    engine.warmup(32)
    engine.submit(engine.make_request(32, seed=1))
    done = engine.drain()
    rep = engine.report()
    assert all(rep["bit_exact_vs_oracle"].values())
    assert sum(r.size for r in done) == 32
    # the engine's own data comes from the registry (mnist geometry)
    assert engine.data.x_test.shape[1] == 196
    # direct packed-oracle agreement on real split vectors
    counts = np.asarray(apply_hard_packed(art.frozen,
                                          jnp.asarray(data.x_test[:32])))
    assert counts.shape == (32, 10)


def test_mnist_end_to_end_cosim_verify(mnist_artifact):
    art, data = mnist_artifact
    rep = art.verify_rtl(data.x_test[:24], backend="python")
    assert rep.counts_checked and rep.n_vectors == 24
    # the default-vector path resolves the spec's own workload
    rep2 = art.verify_rtl(n=8, backend="python")
    assert rep2.n_vectors == 8


def test_mnist_hw_report_encoder_share(mnist_artifact):
    art, _ = mnist_artifact
    rep = art.hw_report()
    assert rep.total_luts > 0
    assert rep.luts.get("encoder", 0) == 0        # TEN: encoding off-chip
    import dataclasses
    pen = dataclasses.replace(art.spec, variant="PEN", input_bits=8)
    pen_art = DWNArtifact(pen).adopt(art.params, art.buffers).freeze()
    pen_rep = pen_art.hw_report()
    assert pen_rep.luts["encoder"] > 0            # PEN pays it on-chip


# ---------------------------------------------------------------------------
# LM-head workload + the engine's dwn_head path
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_lm_head_workload_deterministic_and_trainable():
    data = load_workload("lm-head", 96, 32, seed=0)
    again = load_workload("lm-head", 96, 32, seed=0)
    assert np.array_equal(data.x_train, again.x_train)
    assert data.x_train.shape == (96, 16)
    assert data.x_train.min() >= -1.0 and data.x_train.max() < 1.0
    assert set(np.unique(data.y_train)) <= set(range(5))


@pytest.mark.slow
def test_one_engine_serves_lm_decode_and_dwn_head():
    from repro.serving import ServingEngine

    data = load_workload("lm-head", 96, 32, seed=0)
    spec = resolve_spec("dwn-lm-head")
    art = DWNArtifact(spec).train(data, epochs=1, batch=32, seed=0)
    art.freeze().pack()

    engine = ServingEngine("qwen3-8b", reduced=True, prompt_len=8, gen=2,
                           seed=0, dwn_head=art)
    assert engine.head_bit_exact is True          # startup oracle gate
    engine.submit(engine.make_request(2, seed=0))                 # LM
    engine.submit(engine.make_request(4, seed=1, classify=True))  # head
    done = engine.drain()
    kinds = {"head" if "pred" in r.result else "lm" for r in done}
    assert kinds == {"lm", "head"}
    head = next(r for r in done if "pred" in r.result)
    assert head.result["pred"].shape == (4,)
    assert head.result["counts"].shape == (4, 5)
    rep = engine.report()
    assert rep["dwn_head"]["bit_exact_vs_oracle"] is True
    assert rep["dwn_head"]["served"] == 4


def test_dwn_engine_rejects_dwn_head():
    from repro.serving import ServingEngine
    with pytest.raises(AssertionError, match="LM engine"):
        ServingEngine("dwn-jsc-sm", dwn_head="dwn-lm-head")
