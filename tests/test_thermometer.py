"""Thermometer encoding: unit + property tests."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.thermometer import (ThermometerSpec, fit_thresholds, encode,
                                    encode_np, quantize_fixed_point,
                                    quantize_thresholds, used_threshold_mask,
                                    distinct_used_thresholds,
                                    normalize_to_unit, total_bits_for_frac)


def _data(n=512, f=4, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 0.5, (n, f)).astype(np.float32)
    return normalize_to_unit(x)[0]


def test_fit_shapes_and_order():
    x = _data()
    for mode in ("uniform", "distributive"):
        spec = ThermometerSpec(4, 16, mode)
        th = fit_thresholds(x, spec)
        assert th.shape == (4, 16)
        assert (np.diff(th, axis=1) >= 0).all()


def test_encode_matches_numpy_twin():
    x = _data()
    spec = ThermometerSpec(4, 16, "distributive")
    th = fit_thresholds(x, spec)
    a = np.asarray(encode(jnp.asarray(x), jnp.asarray(th)))
    b = encode_np(x, th)
    np.testing.assert_array_equal(a, b)


def test_thermometer_property():
    """A thermometer code is a unary staircase: bits sorted descending."""
    x = _data()
    spec = ThermometerSpec(4, 32, "distributive")
    th = fit_thresholds(x, spec)
    bits = encode_np(x, th, flatten=False)       # (n, F, T)
    assert ((np.diff(bits, axis=2) <= 0).all())  # monotone within feature


@settings(max_examples=50, deadline=None)
@given(st.floats(-1.0, 0.999), st.floats(-1.0, 0.999),
       st.integers(1, 10))
def test_encode_order_preserving(a, b, frac):
    """x <= y implies popcount(enc(x)) <= popcount(enc(y)) per feature."""
    spec = ThermometerSpec(1, 16, "uniform")
    th = fit_thresholds(np.zeros((4, 1), np.float32), spec)
    ea = encode_np(np.array([[a]], np.float32), th).sum()
    eb = encode_np(np.array([[b]], np.float32), th).sum()
    if a <= b:
        assert ea <= eb
    else:
        assert ea >= eb


@settings(max_examples=100, deadline=None)
@given(st.floats(-2.0, 2.0), st.integers(1, 12))
def test_quantize_grid(v, frac):
    q = float(quantize_fixed_point(np.float32(v), frac))
    scale = 2.0 ** frac
    # on-grid and within the signed (1, n) range
    assert abs(q * scale - round(q * scale)) < 1e-5
    assert -1.0 <= q <= (scale - 1) / scale
    assert total_bits_for_frac(frac) == frac + 1


def test_quantize_monotone_nonexpansive():
    v = np.linspace(-1, 1, 1001).astype(np.float32)
    q = np.asarray(quantize_fixed_point(v, 4))
    assert (np.diff(q) >= 0).all()
    assert np.abs(q - np.clip(v, -1, 1 - 2.0 ** -4)).max() <= 2.0 ** -5 + 1e-6


def test_used_mask_and_dedup():
    spec = ThermometerSpec(2, 8)
    mapping = np.array([[0, 1, 1, 8, 15, 15]])   # uses f0:{0,1}, f1:{0,7}
    mask = used_threshold_mask(mapping, spec)
    assert mask.sum() == 4
    th = np.array([[0.1, 0.12, 0.2, .3, .4, .5, .6, .7],
                   [0.1, 0.12, 0.2, .3, .4, .5, .6, .71]], np.float32)
    # at 2 fractional bits 0.1 and 0.12 collide -> dedup
    n, per = distinct_used_thresholds(th, mask, frac_bits=2)
    assert n <= 4 and per[0] >= 1
    n_full, _ = distinct_used_thresholds(th, mask, frac_bits=None)
    assert n_full == 4


def test_distinct_per_feature_edge_cases():
    """T=1, constant features, and fully-unused features — the encoder
    cost model's degenerate corners."""
    # T=1: a single threshold per feature is one comparator when used
    spec1 = ThermometerSpec(3, 1)
    mapping = np.array([[0, 0, 1, 1, 0, 1]])     # uses f0, f1; never f2
    mask = used_threshold_mask(mapping, spec1)
    th = np.array([[0.25], [0.25], [0.75]], np.float32)
    n, per = distinct_used_thresholds(th, mask, frac_bits=None)
    assert (n, per) == (2, [1, 1, 0])

    # constant feature: every threshold identical -> one comparator after
    # CSE no matter how many bits are wired
    spec = ThermometerSpec(2, 4)
    mapping = np.array([[0, 1, 2, 3, 4, 5]])     # all of f0, f1:{0,1}
    mask = used_threshold_mask(mapping, spec)
    th = np.array([[0.5, 0.5, 0.5, 0.5],
                   [-0.25, 0.3, 0.6, 0.9]], np.float32)
    n, per = distinct_used_thresholds(th, mask, frac_bits=None)
    assert per[0] == 1 and per[1] == 2 and n == 3

    # quantization can only merge, never split
    for frac in (1, 2, 4, 8):
        nq, _ = distinct_used_thresholds(th, mask, frac_bits=frac)
        assert nq <= n


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 6), st.integers(1, 16), st.integers(0, 9999))
def test_distinct_counts_bounded_by_used_bits(F, T, seed):
    rng = np.random.default_rng(seed)
    spec = ThermometerSpec(F, T)
    mapping = rng.integers(0, F * T, size=(max(2, F), 6))
    mask = used_threshold_mask(mapping, spec)
    th = np.sort(rng.uniform(-1, 1, (F, T)).astype(np.float32), axis=1)
    n, per = distinct_used_thresholds(th, mask, frac_bits=3)
    assert len(per) == F
    for f in range(F):
        assert 0 <= per[f] <= int(mask[f].sum())
    assert n == sum(per)


def test_normalize_range():
    x = np.random.default_rng(0).normal(0, 3, (100, 3)).astype(np.float32)
    xn, lo, hi = normalize_to_unit(x)
    assert xn.min() >= -1.0 and xn.max() < 1.0
