"""Runtime: checkpoint roundtrip/atomicity, supervisor crash-resume,
straggler monitor."""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.runtime import checkpoint as ckpt
from repro.runtime.fault import (Supervisor, RestartPolicy, FaultInjector,
                                 TrainHandle, PreemptionHandler)
from repro.runtime.straggler import StragglerMonitor


def _state(v=0.0):
    return {"w": jnp.full((4, 3), v), "opt": {"mu": jnp.zeros(5),
                                              "step": jnp.asarray(7)}}


def test_checkpoint_roundtrip(tmp_path):
    s = _state(1.5)
    ckpt.save(tmp_path, 3, s, extra={"data": {"step": 3}})
    assert ckpt.latest_step(tmp_path) == 3
    out, extra = ckpt.restore(tmp_path, 3, jax.eval_shape(lambda: s))
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(s["w"]))
    assert extra == {"data": {"step": 3}}


def test_checkpoint_integrity_check(tmp_path):
    s = _state()
    path = ckpt.save(tmp_path, 1, s)
    # corrupt one shard
    f = next(path.glob("*.npy"))
    f.write_bytes(b"corrupt" + f.read_bytes()[7:])
    with pytest.raises(IOError):
        ckpt.restore(tmp_path, 1, s)


def test_checkpoint_gc_and_latest(tmp_path):
    for step in (1, 2, 3, 4):
        ckpt.save(tmp_path, step, _state(step))
    ckpt.garbage_collect(tmp_path, keep=2)
    assert ckpt.latest_step(tmp_path) == 4
    assert not (tmp_path / "step_00000001").exists()
    out, _ = ckpt.restore(tmp_path, 3, _state())
    assert float(out["w"][0, 0]) == 3.0


def test_checkpoint_atomic_no_partial(tmp_path):
    """A tmp dir from a crashed writer is never picked up."""
    s = _state()
    ckpt.save(tmp_path, 1, s)
    bogus = tmp_path / "step_00000009.tmp-999"
    bogus.mkdir()
    (bogus / "garbage.npy").write_bytes(b"xx")
    assert ckpt.latest_step(tmp_path) == 1
    ckpt.garbage_collect(tmp_path, keep=3)
    assert not bogus.exists()


def test_supervisor_crash_resume_deterministic(tmp_path):
    """Crashes at injected steps; the final state must equal the
    uninterrupted run (checkpoint/restart correctness)."""

    def run(crash_steps, d):
        inj = FaultInjector(crash_steps)

        def step(handle: TrainHandle) -> TrainHandle:
            inj.maybe_crash(handle.step)
            w = handle.state["w"] + 1.0
            handle.state = {"w": w}
            handle.step += 1
            return handle

        sup = Supervisor(str(d), save_every=2,
                         policy=RestartPolicy(max_restarts=10, backoff_s=0))
        h = sup.run(step, init_state={"w": jnp.zeros(2)}, total_steps=9)
        return np.asarray(h.state["w"]), h.step, sup.restarts

    w_clean, s_clean, _ = run(set(), tmp_path / "clean")
    w_faulty, s_faulty, restarts = run({3, 7}, tmp_path / "faulty")
    assert restarts == 2
    assert s_clean == s_faulty == 9
    np.testing.assert_array_equal(w_clean, w_faulty)


def test_supervisor_restart_budget(tmp_path):
    def step(handle):
        raise RuntimeError("always broken")

    sup = Supervisor(str(tmp_path), save_every=2,
                     policy=RestartPolicy(max_restarts=2, backoff_s=0))
    with pytest.raises(RuntimeError):
        sup.run(step, init_state={"w": jnp.zeros(1)}, total_steps=5)
    assert sup.restarts == 3        # 2 allowed + the aborting one


def test_supervisor_preemption_drains(tmp_path):
    pre = PreemptionHandler(install=False)

    def step(handle):
        handle.state = {"w": handle.state["w"] + 1}
        handle.step += 1
        if handle.step == 4:
            pre.requested = True
        return handle

    sup = Supervisor(str(tmp_path), save_every=100, preemption=pre)
    h = sup.run(step, init_state={"w": jnp.zeros(1)}, total_steps=50)
    assert h.step == 4
    assert ckpt.latest_step(tmp_path) == 4    # drained with a checkpoint


def test_straggler_monitor_flags_outliers():
    fired = []
    mon = StragglerMonitor(window=32, z_threshold=4.0, patience=2,
                           min_samples=8, action=fired.append)
    for _ in range(20):
        mon.report(0.100)
    assert mon.report(0.500) is not None       # flagged
    assert not fired                           # patience=2 not yet met
    mon.report(0.600)
    assert fired and fired[0].z > 4
    # baseline not poisoned by the slow samples
    assert sorted(mon.times)[len(mon.times) // 2] == pytest.approx(0.1)


def test_straggler_monitor_tolerates_jitter():
    mon = StragglerMonitor(min_samples=8)
    rng = np.random.default_rng(0)
    events = [mon.report(0.1 + 0.002 * rng.standard_normal())
              for _ in range(100)]
    assert all(e is None for e in events)
