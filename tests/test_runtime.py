"""Runtime: checkpoint roundtrip/atomicity, supervisor crash-resume,
straggler monitor."""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.runtime import checkpoint as ckpt
from repro.runtime.fault import (Supervisor, RestartPolicy, FaultInjector,
                                 TrainHandle, PreemptionHandler)
from repro.runtime.straggler import StragglerMonitor


def _state(v=0.0):
    return {"w": jnp.full((4, 3), v), "opt": {"mu": jnp.zeros(5),
                                              "step": jnp.asarray(7)}}


def test_checkpoint_roundtrip(tmp_path):
    s = _state(1.5)
    ckpt.save(tmp_path, 3, s, extra={"data": {"step": 3}})
    assert ckpt.latest_step(tmp_path) == 3
    out, extra = ckpt.restore(tmp_path, 3, jax.eval_shape(lambda: s))
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(s["w"]))
    assert extra == {"data": {"step": 3}}


def test_checkpoint_integrity_check(tmp_path):
    s = _state()
    path = ckpt.save(tmp_path, 1, s)
    # corrupt one shard
    f = next(path.glob("*.npy"))
    f.write_bytes(b"corrupt" + f.read_bytes()[7:])
    with pytest.raises(IOError):
        ckpt.restore(tmp_path, 1, s)


def test_checkpoint_gc_and_latest(tmp_path):
    for step in (1, 2, 3, 4):
        ckpt.save(tmp_path, step, _state(step))
    ckpt.garbage_collect(tmp_path, keep=2)
    assert ckpt.latest_step(tmp_path) == 4
    assert not (tmp_path / "step_00000001").exists()
    out, _ = ckpt.restore(tmp_path, 3, _state())
    assert float(out["w"][0, 0]) == 3.0


def test_checkpoint_atomic_no_partial(tmp_path):
    """A tmp dir from a crashed writer is never picked up."""
    s = _state()
    ckpt.save(tmp_path, 1, s)
    bogus = tmp_path / "step_00000009.tmp-999"
    bogus.mkdir()
    (bogus / "garbage.npy").write_bytes(b"xx")
    assert ckpt.latest_step(tmp_path) == 1
    ckpt.garbage_collect(tmp_path, keep=3)
    assert not bogus.exists()


def test_supervisor_crash_resume_deterministic(tmp_path):
    """Crashes at injected steps; the final state must equal the
    uninterrupted run (checkpoint/restart correctness)."""

    def run(crash_steps, d):
        inj = FaultInjector(crash_steps)

        def step(handle: TrainHandle) -> TrainHandle:
            inj.maybe_crash(handle.step)
            w = handle.state["w"] + 1.0
            handle.state = {"w": w}
            handle.step += 1
            return handle

        sup = Supervisor(str(d), save_every=2,
                         policy=RestartPolicy(max_restarts=10, backoff_s=0))
        h = sup.run(step, init_state={"w": jnp.zeros(2)}, total_steps=9)
        return np.asarray(h.state["w"]), h.step, sup.restarts

    w_clean, s_clean, _ = run(set(), tmp_path / "clean")
    w_faulty, s_faulty, restarts = run({3, 7}, tmp_path / "faulty")
    assert restarts == 2
    assert s_clean == s_faulty == 9
    np.testing.assert_array_equal(w_clean, w_faulty)


def test_supervisor_restart_budget(tmp_path):
    def step(handle):
        raise RuntimeError("always broken")

    sup = Supervisor(str(tmp_path), save_every=2,
                     policy=RestartPolicy(max_restarts=2, backoff_s=0))
    with pytest.raises(RuntimeError):
        sup.run(step, init_state={"w": jnp.zeros(1)}, total_steps=5)
    assert sup.restarts == 3        # 2 allowed + the aborting one


def test_supervisor_preemption_drains(tmp_path):
    pre = PreemptionHandler(install=False)

    def step(handle):
        handle.state = {"w": handle.state["w"] + 1}
        handle.step += 1
        if handle.step == 4:
            pre.requested = True
        return handle

    sup = Supervisor(str(tmp_path), save_every=100, preemption=pre)
    h = sup.run(step, init_state={"w": jnp.zeros(1)}, total_steps=50)
    assert h.step == 4
    assert ckpt.latest_step(tmp_path) == 4    # drained with a checkpoint


def test_straggler_monitor_flags_outliers():
    fired = []
    mon = StragglerMonitor(window=32, z_threshold=4.0, patience=2,
                           min_samples=8, action=fired.append)
    for _ in range(20):
        mon.report(0.100)
    assert mon.report(0.500) is not None       # flagged
    assert not fired                           # patience=2 not yet met
    mon.report(0.600)
    assert fired and fired[0].z > 4
    # baseline not poisoned by the slow samples
    assert sorted(mon.times)[len(mon.times) // 2] == pytest.approx(0.1)


def test_straggler_monitor_tolerates_jitter():
    mon = StragglerMonitor(min_samples=8)
    rng = np.random.default_rng(0)
    events = [mon.report(0.1 + 0.002 * rng.standard_normal())
              for _ in range(100)]
    assert all(e is None for e in events)


def test_straggler_monitor_constant_times_mad_zero():
    """MAD == 0 (perfectly constant window) must not divide-by-zero or
    flag sub-percent jitter; the z-scale floors at 5% of the median."""
    mon = StragglerMonitor(min_samples=8, z_threshold=4.0)
    for _ in range(20):
        assert mon.report(0.100) is None
    # 4% above median: inside the floored threshold, not a straggler
    assert mon.report(0.104) is None
    # 25x the median clearly is
    ev = mon.report(2.5)
    assert ev is not None and ev.mad_s == 0.0 and ev.z > 4


def test_straggler_monitor_zero_median_window():
    mon = StragglerMonitor(min_samples=4)
    for _ in range(8):
        mon.report(0.0)
    assert mon.report(0.0) is None             # no ZeroDivisionError
    assert mon.report(1.0) is not None


def test_straggler_threshold_s():
    mon = StragglerMonitor(min_samples=8, z_threshold=4.0)
    assert mon.threshold_s() is None           # below min_samples
    for _ in range(10):
        mon.report(0.100)
    thr = mon.threshold_s()
    # med + 4 * max(1.4826*MAD, 1e-6, 0.05*med) = 0.1 + 4*0.005
    assert thr == pytest.approx(0.120)
    # the flag rule agrees with the advertised threshold
    assert mon.report(thr * 0.99) is None
    assert mon.report(thr * 1.50) is not None


def test_preemption_handler_injectable_register():
    calls = []
    pre = PreemptionHandler(register=lambda s, h: calls.append((s, h)),
                            signum=15)
    assert pre.installed
    assert calls == [(15, pre._on_signal)]
    pre._on_signal(15, None)
    assert pre.requested


def test_preemption_handler_fallback_logged(caplog):
    """Off the main thread signal.signal raises ValueError; the handler
    must degrade to the cooperative flag and LOG the fallback."""
    def register(signum, handler):
        raise ValueError("signal only works in main thread")

    import logging
    with caplog.at_level(logging.WARNING, logger="repro.runtime.fault"):
        pre = PreemptionHandler(register=register)
    assert not pre.installed
    assert any("falling back" in r.message for r in caplog.records)
    pre.requested = True                       # cooperative path still works
    assert pre.requested


def test_supervisor_crash_loop_aborts_not_spins(tmp_path):
    """A fault firing on *every* visit to the same step is a crash loop:
    the bounded RestartPolicy must abort after max_restarts, not retry
    forever."""
    inj = FaultInjector({3}, every_step=True)
    steps_run = []

    def step(handle):
        inj.maybe_crash(handle.step)
        steps_run.append(handle.step)
        handle.state = {"w": handle.state["w"] + 1}
        handle.step += 1
        return handle

    sup = Supervisor(str(tmp_path), save_every=2,
                     policy=RestartPolicy(max_restarts=3, backoff_s=0))
    with pytest.raises(RuntimeError, match="injected fault at step 3"):
        sup.run(step, init_state={"w": jnp.zeros(1)}, total_steps=9)
    assert inj.fired == 4                      # 3 retries + aborting attempt
    assert sup.restarts == 4
    # each retry resumed from the committed step-2 checkpoint: only step 2
    # re-runs per attempt, the loop never spins past the faulty step
    assert max(steps_run) == 2


def test_supervisor_resumes_from_latest_committed_checkpoint(tmp_path):
    """A transient fault restores from the *latest committed* checkpoint
    (step 4 with save_every=2 when crashing at step 5), not from scratch."""
    inj = FaultInjector({5})
    resumed_from = []

    def step(handle):
        resumed_from.append(handle.step)
        inj.maybe_crash(handle.step)
        handle.state = {"w": handle.state["w"] + 1}
        handle.step += 1
        return handle

    sup = Supervisor(str(tmp_path), save_every=2,
                     policy=RestartPolicy(max_restarts=2, backoff_s=0))
    h = sup.run(step, init_state={"w": jnp.zeros(1)}, total_steps=8)
    assert h.step == 8
    assert float(h.state["w"][0]) == 8.0
    # the attempt after the crash started at 4 (latest committed), not 0
    i = resumed_from.index(5)
    assert resumed_from[i + 1] == 4


def test_supervise_retries_transient_then_succeeds(tmp_path):
    attempts = []
    retries = []

    def flaky():
        attempts.append(1)
        if len(attempts) < 3:
            raise RuntimeError("transient")
        return "ok"

    sup = Supervisor(str(tmp_path),
                     policy=RestartPolicy(max_restarts=5, backoff_s=0))
    out = sup.supervise(flaky, label="unit", on_retry=retries.append)
    assert out == "ok"
    assert len(attempts) == 3
    assert retries == [1, 2]
    assert sup.restarts == 2


def test_supervise_budget_exhausted_raises(tmp_path):
    n = [0]

    def broken():
        n[0] += 1
        raise ValueError("always broken")

    sup = Supervisor(str(tmp_path),
                     policy=RestartPolicy(max_restarts=2, backoff_s=0))
    with pytest.raises(ValueError, match="always broken"):
        sup.supervise(broken)
    assert n[0] == 3                           # bounded: 1 + max_restarts
