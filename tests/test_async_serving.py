"""Continuous-batching serving: scheduling, SLO control, load generator.

Covers the async layer added on top of the sync microbatch scheduler:

* ContinuousScheduler, no threads/jax: ``step_once`` is driven directly
  with a fake clock and a tagged step fn, so completion order, dense
  packing, priority/EDF ordering, admission shedding, expiry, late
  marking, and backpressure are all deterministic assertions;
* ServingEngine async facade: bit-exact parity with the sync
  submit/drain path on the same payloads, and the SLO invariant under
  genuine saturation (a deadline-constrained request is never returned
  late without being marked shed);
* the open-loop load generator: seeded Poisson schedules are
  reproducible bit-for-bit, burst windows scale the arrival rate, and
  the tenant mix propagates sizes/deadlines/priorities.
"""

import numpy as np
import pytest

from repro.serving.continuous import (
    SHED_ADMISSION, SHED_EXPIRED, SHED_LATE, SHED_SHUTDOWN,
    ContinuousScheduler, QueueFull, SLOConfig)


class FakeClock:
    """Deterministic timer: advances only when told."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _tag_payload(rid, n):
    """Rows tagged (rid * 1000 + row) so results are traceable."""
    x = np.zeros((n, 2), np.float64)
    x[:, 0] = rid * 1000 + np.arange(n)
    return x


def _tag_step(clock=None, step_s=0.0, shapes=None):
    def step(x):
        if clock is not None:
            clock.advance(step_s)
        if shapes is not None:
            shapes.append(x.shape[0])
        return (x[:, 0].copy(),)
    return step


class ConstEstimator:
    """Stub estimator: every bucket costs ``seconds`` per step."""

    def __init__(self, seconds):
        self.seconds = seconds
        self.updates = []

    def estimate(self, bucket):
        return self.seconds

    def update(self, bucket, seconds):
        self.updates.append((bucket, seconds))


# ---------------------------------------------------------------------------
# scheduling core (no threads, no jax)
# ---------------------------------------------------------------------------

def test_out_of_order_completion_by_priority():
    clock = FakeClock()
    sched = ContinuousScheduler(_tag_step(), max_bucket=8, min_bucket=8,
                                timer=clock)
    low = sched.submit(_tag_payload(0, 8), priority=0)
    high = sched.submit(_tag_payload(1, 8), priority=1)
    sched.step_once()
    # the later, higher-priority submit completes first
    assert high.future.done() and not low.future.done()
    sched.step_once()
    assert low.future.done()
    for req, rid in ((high, 1), (low, 0)):
        res = req.future.result()
        assert res.ok and res.shed is None and res.rid == req.rid
        np.testing.assert_array_equal(
            res.value[0], rid * 1000 + np.arange(8, dtype=np.float64))


def test_edf_within_priority_class():
    clock = FakeClock()
    sched = ContinuousScheduler(_tag_step(), max_bucket=8, min_bucket=8,
                                timer=clock)
    loose = sched.submit(_tag_payload(0, 8), deadline_ms=10_000.0)
    tight = sched.submit(_tag_payload(1, 8), deadline_ms=1_000.0)
    sched.step_once()
    # earliest deadline first: the tighter request jumps the queue
    assert tight.future.done() and not loose.future.done()


def test_dense_packing_and_oversize_chunking():
    clock = FakeClock()
    shapes = []
    sched = ContinuousScheduler(_tag_step(shapes=shapes), max_bucket=8,
                                min_bucket=8, timer=clock)
    a = sched.submit(_tag_payload(0, 5))
    b = sched.submit(_tag_payload(1, 5))
    big = sched.submit(_tag_payload(2, 20))
    # step 1: a(5) + b's head(3) — the boundary request is split, no pad
    assert sched.step_once() == 8
    assert a.future.done() and not b.future.done()
    # steps 2-4 finish b then chunk through the oversize request
    while not big.future.done():
        assert sched.step_once() > 0
    assert b.future.done()
    assert set(shapes) == {8}              # only ladder shapes ever run
    for req, rid, n in ((a, 0, 5), (b, 1, 5), (big, 2, 20)):
        np.testing.assert_array_equal(
            req.future.result().value[0],
            rid * 1000 + np.arange(n, dtype=np.float64))
    # out-of-order completion timestamps: a first, big last
    assert a.t_done <= b.t_done <= big.t_done


def test_admission_shed_on_unmeetable_deadline():
    clock = FakeClock()
    sched = ContinuousScheduler(_tag_step(), max_bucket=8, min_bucket=8,
                                estimator=ConstEstimator(1.0), timer=clock)
    # one step costs ~1s; a 10ms deadline is provably unmeetable
    req = sched.submit(_tag_payload(0, 4), deadline_ms=10.0)
    res = req.future.result(timeout=0)     # resolved before queueing
    assert not res.ok and res.shed == SHED_ADMISSION
    assert res.value is None
    assert sched.pending == 0
    # same deadline with a feasible estimator is admitted
    sched2 = ContinuousScheduler(_tag_step(), max_bucket=8, min_bucket=8,
                                 estimator=ConstEstimator(1e-4), timer=clock)
    ok = sched2.submit(_tag_payload(0, 4), deadline_ms=10.0)
    assert not ok.future.done() and sched2.pending == 1


def test_queued_deadline_expires_at_step_boundary():
    clock = FakeClock()
    sched = ContinuousScheduler(_tag_step(), max_bucket=8, min_bucket=8,
                                timer=clock)
    req = sched.submit(_tag_payload(0, 4), deadline_ms=50.0)
    clock.advance(0.06)                    # deadline passes while queued
    sched.step_once()
    res = req.future.result(timeout=0)
    assert not res.ok and res.shed == SHED_EXPIRED and res.value is None
    assert sched.counters()["shed_by_reason"] == {SHED_EXPIRED: 1}


def test_late_completion_is_marked_never_silent():
    clock = FakeClock()
    # the step itself overruns the deadline: served, but marked
    sched = ContinuousScheduler(_tag_step(clock, step_s=0.1), max_bucket=8,
                                min_bucket=8, timer=clock)
    req = sched.submit(_tag_payload(0, 4), deadline_ms=50.0)
    sched.step_once()
    res = req.future.result(timeout=0)
    assert not res.ok and res.shed == SHED_LATE
    assert res.value is not None           # the work was done, just late
    np.testing.assert_array_equal(res.value[0],
                                  np.arange(4, dtype=np.float64))


def test_backpressure_queue_full_then_drains():
    clock = FakeClock()
    slo = SLOConfig(max_queue_samples=8, submit_timeout_s=0.0)
    sched = ContinuousScheduler(_tag_step(), max_bucket=8, min_bucket=8,
                                slo=slo, timer=clock)
    sched.submit(_tag_payload(0, 8))
    with pytest.raises(QueueFull):
        sched.submit(_tag_payload(1, 1))
    sched.step_once()                      # frees the queue
    ok = sched.submit(_tag_payload(1, 1))
    sched.step_once()
    assert ok.future.result(timeout=0).ok
    assert sched.counters()["queue_depth_max_samples"] == 8


def test_stop_without_drain_sheds_shutdown():
    import threading
    import time as _time
    gate = threading.Event()

    def step(x):
        gate.wait(timeout=10.0)
        return (x[:, 0].copy(),)

    sched = ContinuousScheduler(step, max_bucket=8, min_bucket=8)
    sched.start()
    a = sched.submit(_tag_payload(0, 8))
    deadline = _time.monotonic() + 10.0
    while sched.pending and _time.monotonic() < deadline:
        _time.sleep(0.001)             # wait until a is in flight
    b = sched.submit(_tag_payload(1, 8))   # queued behind the held step
    stopper = threading.Thread(target=lambda: sched.stop(drain=False))
    stopper.start()
    # the queued request is shed immediately, before the in-flight step
    # (still holding the gate) ever finishes
    res_b = b.future.result(timeout=5.0)
    assert not res_b.ok and res_b.shed == SHED_SHUTDOWN
    gate.set()
    stopper.join(timeout=10.0)
    assert not stopper.is_alive()
    assert a.future.result(timeout=5.0).ok   # in-flight work still lands


def test_queue_time_attributed_from_original_submit_across_chunks():
    clock = FakeClock()
    sched = ContinuousScheduler(_tag_step(clock, step_s=1.0), max_bucket=8,
                                min_bucket=8, timer=clock)
    req = sched.submit(_tag_payload(0, 20))
    clock.advance(5.0)                     # waits 5s before the loop runs
    while not req.future.done():
        sched.step_once()
    # queue time = submit -> first chunk launch, exactly; the clock never
    # restarts for chunks 2 and 3, whose time lands in compute
    assert req.queue_ms == pytest.approx(5_000.0)
    assert req.compute_ms == pytest.approx(3_000.0)
    assert req.buckets == (8, 8, 8)


def test_estimator_and_counters_updated_per_step():
    clock = FakeClock()
    est = ConstEstimator(1e-6)
    sched = ContinuousScheduler(_tag_step(clock, step_s=0.25), max_bucket=8,
                                min_bucket=8, estimator=est, timer=clock)
    sched.submit(_tag_payload(0, 6))
    sched.step_once()
    assert est.updates == [(8, pytest.approx(0.25))]
    c = sched.counters()
    assert c["steps"] == 1 and c["served_requests"] == 1
    assert c["served_samples"] == 6
    assert c["busy_s"] == pytest.approx(0.25)


# ---------------------------------------------------------------------------
# engine facade: sync parity + SLO invariant under saturation
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def engine():
    from repro.serving import ServingEngine
    return ServingEngine("dwn-jsc-sm", max_bucket=32, min_bucket=8,
                         n_train=800, backend="packed-xla", verify=False)


def test_async_bit_exact_with_sync_facade(engine):
    sizes = [5, 17, 32, 100, 3]
    payloads = [engine.make_request(n, seed=n) for n in sizes]
    for p in payloads:
        engine.submit(p)
    sync_done = {r.size: r.result for r in engine.drain()}

    with engine.serve():
        reqs = [engine.submit_async(p) for p in payloads]
        results = [r.future.result(timeout=60.0) for r in reqs]
    for n, res in zip(sizes, results):
        assert res.ok and res.shed is None
        for got, want in zip(res.value, sync_done[n]):
            np.testing.assert_array_equal(np.asarray(got),
                                          np.asarray(want))


def test_slo_invariant_under_saturation(engine):
    """Saturate the loop with tight deadlines: every deadline-carrying
    request either meets its deadline or is returned marked shed —
    never silently late."""
    from repro.serving.continuous import SLOConfig as SLO
    rng = np.random.default_rng(7)
    payloads = [(int(rng.integers(1, 33)), 3.0) for _ in range(120)]
    # oversize requests (4 max_bucket chunks) against a 0.5 ms deadline
    # provably cannot finish in time whatever the machine speed: they are
    # shed at admission, expired in queue, or at worst marked late —
    # saturation evidence is deterministic, not a race the producer must
    # win against the step loop
    payloads += [(4 * engine.scheduler.max_bucket, 0.5)] * 3
    payloads = [(engine.make_request(n, seed=i), dl)
                for i, (n, dl) in enumerate(payloads)]
    engine.start_serving(slo=SLO(max_queue_samples=128,
                                 submit_timeout_s=0.0))
    reqs = []
    rejected = 0
    for p, deadline_ms in payloads:
        try:
            reqs.append(engine.submit_async(p, deadline_ms=deadline_ms))
        except QueueFull:
            rejected += 1
    results = [r.future.result(timeout=60.0) for r in reqs]
    engine.stop_serving()

    assert len(results) + rejected == len(payloads)
    # the invariant: ok implies on-time (t_done within the deadline)
    for req, res in zip(reqs, results):
        assert res.shed in (None, SHED_ADMISSION, SHED_EXPIRED, SHED_LATE)
        if res.ok:
            assert req.deadline is not None
            assert req.t_done <= req.deadline
        else:
            assert res.value is None or res.shed == SHED_LATE
    # saturation really happened: something was shed or rejected
    assert rejected + sum(1 for r in results if not r.ok) > 0
    # counters surface the same story through the engine report
    rep = engine.report()
    assert rep["shed"]["requests"] == sum(1 for r in results if not r.ok)
    assert set(rep["shed"]["by_reason"]) <= {SHED_ADMISSION, SHED_EXPIRED,
                                             SHED_LATE}
    assert rep["async"]["steps"] > 0
    assert rep["straggler"]["window"] >= 0


# ---------------------------------------------------------------------------
# open-loop load generator
# ---------------------------------------------------------------------------

def test_loadgen_poisson_deterministic():
    from repro.launch import loadgen
    spec = loadgen.LoadSpec(rate_rps=500.0, duration_s=2.0, seed=42,
                            burst_factor=3.0, burst_every_s=1.0,
                            burst_len_s=0.25)
    a, b = loadgen.make_arrivals(spec), loadgen.make_arrivals(spec)
    assert a == b and len(a) > 500
    assert all(x.t < spec.duration_s for x in a)
    assert all(a[i].t < a[i + 1].t for i in range(len(a) - 1))
    # a different seed yields a different schedule
    c = loadgen.make_arrivals(
        loadgen.LoadSpec(rate_rps=500.0, duration_s=2.0, seed=43,
                         burst_factor=3.0, burst_every_s=1.0,
                         burst_len_s=0.25))
    assert c != a


def test_loadgen_burst_windows_scale_rate():
    from repro.launch import loadgen
    spec = loadgen.LoadSpec(rate_rps=400.0, duration_s=8.0, seed=3,
                            burst_factor=4.0, burst_every_s=1.0,
                            burst_len_s=0.5)
    arrivals = loadgen.make_arrivals(spec)
    in_burst = sum(1 for a in arrivals if (a.t % 1.0) < 0.5)
    outside = len(arrivals) - in_burst
    # burst windows run at 4x the base rate (generous noise margin)
    assert 2.5 < in_burst / outside < 5.5


def test_loadgen_tenant_mix_propagates():
    from repro.launch import loadgen
    tenants = (
        loadgen.Tenant(name="rt", weight=3.0, size="fixed:16",
                       deadline_ms=10.0, priority=1, preset="sm"),
        loadgen.Tenant(name="batch", weight=1.0, size="uniform:32:64",
                       deadline_ms=None, priority=0, preset="md"),
    )
    spec = loadgen.LoadSpec(rate_rps=1000.0, duration_s=2.0, seed=11,
                            tenants=tenants)
    arrivals = loadgen.make_arrivals(spec)
    rt = [a for a in arrivals if a.tenant == "rt"]
    batch = [a for a in arrivals if a.tenant == "batch"]
    assert len(rt) + len(batch) == len(arrivals)
    assert 2.0 < len(rt) / len(batch) < 4.5          # ~3:1 weights
    assert all(a.size == 16 and a.deadline_ms == 10.0 and a.priority == 1
               and a.preset == "sm" for a in rt)
    assert all(32 <= a.size <= 64 and a.deadline_ms is None
               and a.preset == "md" for a in batch)
    with pytest.raises(ValueError):
        loadgen.Tenant(size="gamma:1:2").sample_size(
            np.random.default_rng(0))
