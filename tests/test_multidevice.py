"""Multi-device (8 fake host CPUs) integration: sharded train step runs,
activation hints apply, and checkpoints restore elastically across mesh
shapes.  Runs in a subprocess so the 8-device XLA_FLAGS never leaks into
the main test process."""

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys, json
    sys.path.insert(0, sys.argv[1])
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import get_arch
    from repro.models import api
    from repro.sharding.partition import Partitioner
    from repro.runtime import checkpoint as ckpt

    cfg = get_arch("qwen3-8b").reduced()
    out = {}

    def train_on(mesh_shape, axes, ckpt_dir, restore):
        mesh = jax.make_mesh(mesh_shape, axes)
        tp = mesh.shape["model"]
        part = Partitioner(mesh)
        ap = api.abstract_params(cfg, tp)
        p_shard = part.tree_shardings(ap, api.param_axes(cfg))
        mod = api.module_for(cfg)
        with mesh:
            params = jax.jit(lambda k: mod.init_params(k, cfg, tp),
                             out_shardings=p_shard)(jax.random.PRNGKey(0))
        if restore:
            step, params, extra = ckpt.restore_latest(
                ckpt_dir, jax.eval_shape(lambda: params),
                shardings=p_shard)
            assert step is not None
        step_fn, opt = api.make_train_step(cfg, tp)
        opt_state = opt.init(params)
        key = jax.random.PRNGKey(1)
        batch = {"tokens": jax.random.randint(key, (8, 32), 0,
                                              cfg.vocab_size),
                 "labels": jax.random.randint(key, (8, 32), 0,
                                              cfg.vocab_size)}
        jstep = jax.jit(step_fn, in_shardings=(p_shard, None, None),
                        out_shardings=(p_shard, None, None))
        with mesh:
            params, opt_state, metrics = jstep(params, opt_state, batch)
        loss = float(metrics["loss"])
        assert np.isfinite(loss)
        ckpt.save(ckpt_dir, 1 if not restore else 2, params)
        return loss, params

    d = sys.argv[2]
    # phase 1: 4x2 mesh (FSDP=4, TP=2)
    loss1, params1 = train_on((4, 2), ("data", "model"), d, restore=False)
    # phase 2: elastic restart onto a 2x4 mesh (FSDP=2, TP=4)
    loss2, params2 = train_on((2, 4), ("data", "model"), d, restore=True)
    out["loss1"], out["loss2"] = loss1, loss2
    out["devices"] = len(jax.devices())
    # determinism: the restored params equal the saved ones
    print("RESULT " + json.dumps(out))
""")


def test_multidevice_train_and_elastic_restore(tmp_path):
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT, str(ROOT / "src"), str(tmp_path)],
        capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")]
    assert line, proc.stdout[-2000:]
    out = json.loads(line[0][len("RESULT "):])
    assert out["devices"] == 8
    assert out["loss1"] > 0 and out["loss2"] > 0
