"""Pallas flash-attention kernel vs oracle: shape/dtype sweep, causal
block-skip correctness, GQA folding."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attn import ops as fa
from repro.kernels.flash_attn.kernel import flash_attention
from repro.kernels.flash_attn.ref import attention_ref


@pytest.mark.parametrize("BH,S,hd,blk", [(2, 64, 16, 16), (4, 128, 32, 32),
                                         (1, 32, 8, 8), (3, 96, 16, 32)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_ref(BH, S, hd, blk, causal):
    key = jax.random.PRNGKey(S + hd)
    q = jax.random.normal(key, (BH, S, hd), jnp.float32) * 0.5
    k = jax.random.normal(jax.random.PRNGKey(1), (BH, S, hd), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (BH, S, hd), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, block_q=blk, block_k=blk,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_dtypes(dtype):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, 64, 16), dtype) * 0.5
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 16), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 64, 16), dtype)
    out = flash_attention(q, k, v, causal=True, block_q=16, block_k=16,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)
    assert out.dtype == dtype


def test_flash_gqa_fold_and_padding():
    """ops.attend: GQA repeat + non-block-multiple S."""
    B, S, H, K, hd = 2, 56, 4, 2, 16   # S=56 pads to 64
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (B, S, H, hd), jnp.float32) * 0.5
    k = jax.random.normal(jax.random.PRNGKey(4), (B, S, K, hd), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(5), (B, S, K, hd), jnp.float32)
    out = fa.attend(q, k, v, causal=True, block=8, interpret=True)
    from repro.models import layers as L
    hl = L.make_head_layout(H, K, 1)
    ref = L.attention_chunked(q, k, v, hl, causal=True, kv_chunk=8)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=2e-2, rtol=2e-2)


def test_flash_agrees_with_triangular_variant():
    """Three implementations, one semantics: Pallas kernel == pure-JAX
    block-triangular == masked flash."""
    from repro.models import layers as L
    hl = L.make_head_layout(2, 2, 1)
    key = jax.random.PRNGKey(9)
    B, S, hd = 1, 64, 16
    q = jax.random.normal(key, (B, S, 2, hd), jnp.float32) * 0.5
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, 2, hd), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, 2, hd), jnp.float32)
    a = fa.attend(q, k, v, causal=True, block=16, interpret=True)
    b = L.attention_causal_tri(q, k, v, hl, kv_chunk=16, leaf=16)
    c = L.attention_chunked(q, k, v, hl, causal=True, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=2e-2)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(c, np.float32), atol=2e-2)


def test_pallas_attn_impl_in_model():
    """cfg.attn_impl='pallas' is a drop-in for the model forward."""
    import dataclasses
    from repro.configs import get_arch
    from repro.models import api
    cfg = get_arch("qwen3-8b").reduced()
    mod = api.module_for(cfg)
    params = mod.init_params(jax.random.PRNGKey(0), cfg, 1)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32),
                                          0, cfg.vocab_size)}
    a, *_ = mod.forward(params, cfg, batch, tp=1)
    b, *_ = mod.forward(params,
                        dataclasses.replace(cfg, attn_impl="pallas"),
                        batch, tp=1)
    err = np.abs(np.asarray(a, np.float32)
                 - np.asarray(b, np.float32)).max()
    assert err < 0.06, err
