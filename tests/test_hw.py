"""Hardware generator + cost model tests."""

import math

import numpy as np
import jax
import pytest
from hypothesis import given, settings, strategies as st

from repro.hw.cost import (comparator_luts, popcount_tree, argmax_cost,
                           popcount_cost, lut_layer_cost, encoder_cost,
                           dwn_hw_report)
from repro.hw.verilog import emit_dwn, well_formed
from repro.hw.report import PAPER_TABLE3, compare_with_paper


def test_comparator_luts():
    assert comparator_luts(6) == 1
    assert comparator_luts(4) == 1
    assert comparator_luts(9) == 3       # 2 segments + combine
    assert comparator_luts(12) == 3


@settings(max_examples=100, deadline=None)
@given(st.integers(1, 4096))
def test_popcount_tree_properties(n):
    r = popcount_tree(n)
    width = math.ceil(math.log2(n + 1)) if n > 1 else 1
    assert r.out_bits >= min(width, r.out_bits)
    # LUT count bounded: a popcount is at most ~1.2n LUTs and at least
    # n/6 (each 6:3 removes 3 bits for 3 LUTs)
    if n > 6:
        assert n // 6 <= r.luts <= int(1.2 * n) + 8
    assert r.stages >= (1 if n > 1 else 0)


def test_ten_rows_close_to_paper():
    """Our classification-logic costs vs the paper's TEN column
    (LUT layer + popcount + argmax; Vivado cross-optimizes the tiny
    sm-10 further than a structural generator can — tolerance 40% there,
    10% elsewhere)."""
    for name, m, paper, tol in [("sm-10", 10, 20, 0.45),
                                ("sm-50", 50, 110, 0.10),
                                ("md-360", 360, 720, 0.05),
                                ("lg-2400", 2400, 4972, 0.05)]:
        g = m // 5
        cb = max(1, math.ceil(math.log2(g + 1)))
        total = (m + popcount_cost(g, 5).luts + argmax_cost(5, cb).luts)
        err = abs(total - paper) / paper
        assert err <= tol, (name, total, paper, err)


def _tiny_frozen(pen=True):
    import jax.numpy as jnp
    from repro.core import JSC_PRESETS, init_dwn, freeze
    from repro.data.jsc import load_jsc
    data = load_jsc(512, 128)
    cfg = JSC_PRESETS["sm-10"]
    params, buffers = init_dwn(jax.random.PRNGKey(0), cfg, data.x_train)
    return freeze(params, buffers, cfg,
                  input_frac_bits=5 if pen else None)


def test_hw_report_pen_vs_ten():
    fr_pen = _tiny_frozen(pen=True)
    fr_ten = _tiny_frozen(pen=False)
    rep_pen = dwn_hw_report(fr_pen, variant="PEN+FT", name="sm-10",
                            input_bits=6)
    rep_ten = dwn_hw_report(fr_ten, variant="TEN", name="sm-10")
    assert rep_pen.luts["encoder"] > 0
    assert rep_ten.luts["encoder"] == 0
    assert rep_pen.total_luts > rep_ten.total_luts
    assert rep_pen.distinct_comparators <= 60     # <= wires used
    assert rep_pen.total_ffs > 0 and rep_pen.delay_ns > 0


def test_verilog_emission_well_formed():
    fr = _tiny_frozen(pen=True)
    src = emit_dwn(fr, name="dwn_sm10")
    assert well_formed(src)
    assert "module dwn_sm10" in src and "endmodule" in src
    assert "argmax_idx" in src and "INIT_0_0" in src
    # one distinct comparator line per distinct (feature, threshold)
    assert src.count("$signed(x[") >= 1

    fr_ten = _tiny_frozen(pen=False)
    src2 = emit_dwn(fr_ten, name="dwn_ten")
    assert well_formed(src2) and "ten_bits" in src2


def test_compare_with_paper_has_reference():
    fr = _tiny_frozen(pen=True)
    row = compare_with_paper(fr, model_name="sm-10", variant="PEN+FT",
                             input_bits=6)
    assert row.paper_luts == PAPER_TABLE3["sm-10"]["ft_luts"]
    assert row.lut_error_pct is not None
