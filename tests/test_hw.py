"""Hardware generator + cost model tests."""

import dataclasses
import math
import os
from pathlib import Path

import numpy as np
import jax
import pytest
from hypothesis import given, settings, strategies as st

from repro.hw.cost import (comparator_luts, popcount_tree, argmax_cost,
                           popcount_cost, lut_layer_cost, encoder_cost,
                           dwn_hw_report)
from repro.hw.verilog import emit_dwn, well_formed
from repro.hw.report import PAPER_TABLE3, compare_with_paper


def test_comparator_luts():
    assert comparator_luts(6) == 1
    assert comparator_luts(4) == 1
    assert comparator_luts(9) == 3       # 2 segments + combine
    assert comparator_luts(12) == 3


@settings(max_examples=100, deadline=None)
@given(st.integers(1, 4096))
def test_popcount_tree_properties(n):
    r = popcount_tree(n)
    width = math.ceil(math.log2(n + 1)) if n > 1 else 1
    assert r.out_bits >= min(width, r.out_bits)
    # LUT count bounded: a popcount is at most ~1.2n LUTs and at least
    # n/6 (each 6:3 removes 3 bits for 3 LUTs)
    if n > 6:
        assert n // 6 <= r.luts <= int(1.2 * n) + 8
    assert r.stages >= (1 if n > 1 else 0)


def test_ten_rows_close_to_paper():
    """Our classification-logic costs vs the paper's TEN column
    (LUT layer + popcount + argmax; Vivado cross-optimizes the tiny
    sm-10 further than a structural generator can — tolerance 40% there,
    10% elsewhere)."""
    for name, m, paper, tol in [("sm-10", 10, 20, 0.45),
                                ("sm-50", 50, 110, 0.10),
                                ("md-360", 360, 720, 0.05),
                                ("lg-2400", 2400, 4972, 0.05)]:
        g = m // 5
        cb = max(1, math.ceil(math.log2(g + 1)))
        total = (m + popcount_cost(g, 5).luts + argmax_cost(5, cb).luts)
        err = abs(total - paper) / paper
        assert err <= tol, (name, total, paper, err)


def _tiny_frozen(pen=True):
    import jax.numpy as jnp
    from repro.core import JSC_PRESETS, init_dwn, freeze
    from repro.data.jsc import load_jsc
    data = load_jsc(512, 128)
    cfg = JSC_PRESETS["sm-10"]
    params, buffers = init_dwn(jax.random.PRNGKey(0), cfg, data.x_train)
    return freeze(params, buffers, cfg,
                  input_frac_bits=5 if pen else None)


def test_hw_report_pen_vs_ten():
    fr_pen = _tiny_frozen(pen=True)
    fr_ten = _tiny_frozen(pen=False)
    rep_pen = dwn_hw_report(fr_pen, variant="PEN+FT", name="sm-10",
                            input_bits=6)
    rep_ten = dwn_hw_report(fr_ten, variant="TEN", name="sm-10")
    assert rep_pen.luts["encoder"] > 0
    assert rep_ten.luts["encoder"] == 0
    assert rep_pen.total_luts > rep_ten.total_luts
    assert rep_pen.distinct_comparators <= 60     # <= wires used
    assert rep_pen.total_ffs > 0 and rep_pen.delay_ns > 0


def test_verilog_emission_well_formed():
    fr = _tiny_frozen(pen=True)
    src = emit_dwn(fr, name="dwn_sm10")
    assert well_formed(src)
    assert "module dwn_sm10" in src and "endmodule" in src
    assert "argmax_idx" in src and "INIT_0_0" in src
    # one distinct comparator line per distinct (feature, threshold)
    assert src.count("$signed(x[") >= 1

    fr_ten = _tiny_frozen(pen=False)
    src2 = emit_dwn(fr_ten, name="dwn_ten")
    assert well_formed(src2) and "ten_bits" in src2


def test_compare_with_paper_has_reference():
    fr = _tiny_frozen(pen=True)
    row = compare_with_paper(fr, model_name="sm-10", variant="PEN+FT",
                             input_bits=6)
    assert row.paper_luts == PAPER_TABLE3["sm-10"]["ft_luts"]
    assert row.lut_error_pct is not None


# ---------------------------------------------------------------------------
# _fixed_point_const: pinned two's-complement behavior
# ---------------------------------------------------------------------------

def test_fixed_point_const_explicit_cases():
    """Negative thresholds and boundary rounding, pinned value by value
    (cross-checked against the oracle's quantize_fixed_point grid — the
    cosim equivalence tests prove the comparator semantics end to end)."""
    from repro.hw.verilog import _fixed_point_const
    # frac_bits=4 -> 5-bit two's complement, grid step 1/16
    assert _fixed_point_const(0.0, 4) == 0x00
    assert _fixed_point_const(0.5, 4) == 0x08
    assert _fixed_point_const(-1.0, 4) == 0x10          # most negative
    assert _fixed_point_const(-0.0625, 4) == 0x1f       # -1/16 -> all ones
    assert _fixed_point_const(-0.5, 4) == 0x18
    assert _fixed_point_const(0.9375, 4) == 0x0f        # largest positive
    # saturation at both rails
    assert _fixed_point_const(1.0, 4) == 0x0f
    assert _fixed_point_const(2.5, 4) == 0x0f
    assert _fixed_point_const(-1.5, 4) == 0x10
    # off-grid values round like the oracle (banker's rounding at ties)
    assert _fixed_point_const(0.03125, 4) == 0x00       # 0.5 ulp -> even 0
    assert _fixed_point_const(0.09375, 4) == 0x02       # 1.5 ulp -> even 2
    assert _fixed_point_const(-0.03125, 4) == 0x00
    assert _fixed_point_const(0.07, 4) == 0x01
    # width scales with frac_bits
    assert _fixed_point_const(-1.0, 8) == 0x100
    assert _fixed_point_const(-1.0, 1) == 0x2


@settings(max_examples=80, deadline=None)
@given(st.integers(1, 12), st.floats(-1.0, 0.999))
def test_fixed_point_const_agrees_with_oracle_grid(frac, v):
    """The emitted literal, reinterpreted as signed, equals the oracle's
    quantized value scaled to the grid — for every on-or-off-grid input."""
    from repro.core.thermometer import quantize_fixed_point
    from repro.hw.cosim import as_signed
    from repro.hw.verilog import _fixed_point_const
    c = _fixed_point_const(v, frac)
    q = float(np.asarray(quantize_fixed_point(np.float32(v), frac)))
    assert int(as_signed(c, frac + 1)) == round(q * (1 << frac))


# ---------------------------------------------------------------------------
# well_formed on every registered preset x variant x placement
# ---------------------------------------------------------------------------

def _all_preset_sources():
    """Emit RTL for every registered spec preset x {TEN, PEN} x placement,
    memoizing the expensive fit per unique (tier, T, placement)."""
    from repro.core.thermometer import PLACEMENTS
    from repro.dwn import DWNArtifact
    from repro.dwn.spec import get_spec, spec_presets
    from repro.workloads import load_workload

    splits: dict = {}

    def data_for(workload):
        if workload not in splits:
            splits[workload] = load_workload(workload, 256, 16, seed=0)
        return splits[workload]

    trained: dict = {}
    frozen: dict = {}

    def art_for(spec):
        tkey = (spec.workload, spec.preset, spec.bits, spec.placement)
        if tkey not in trained:
            ten = dataclasses.replace(spec, variant="TEN", input_bits=None)
            a = DWNArtifact(ten).fit(data_for(spec.workload).x_train, seed=0)
            trained[tkey] = (a.params, a.buffers)
        fkey = tkey + (spec.variant,)
        if fkey not in frozen:
            art = DWNArtifact(spec)
            art.adopt(*trained[tkey], note="test").freeze()
            frozen[fkey] = art
        return frozen[fkey]

    for name in spec_presets():
        base = get_spec(name)
        for variant in ("TEN", "PEN"):
            for placement in PLACEMENTS:
                spec = dataclasses.replace(
                    base, variant=variant, placement=placement,
                    input_bits=None if variant == "TEN"
                    else (base.input_bits or 8))
                yield name, spec, art_for(spec).verilog(name="dwn_t")


def test_well_formed_on_every_registered_preset():
    from repro.hw.cosim import parse_netlist
    seen = 0
    for name, spec, src in _all_preset_sources():
        assert well_formed(src), f"{name} {spec.label} not well-formed"
        # and the cosim parser accepts the full emitted subset
        net = parse_netlist(src)
        assert net.pen == (spec.variant == "PEN"), spec.label
        seen += 1
    assert seen >= 48     # >= 8 presets x 2 variants x 3 placements


def test_well_formed_rejects_broken_sources():
    fr = _tiny_frozen(pen=True)
    src = emit_dwn(fr, name="dwn_chk")
    assert well_formed(src)
    assert not well_formed(src.replace("endmodule", ""))
    assert not well_formed(src.replace("always @* begin", "always @*"))
    assert not well_formed(src.replace("(", "", 1))
    assert not well_formed("")


# ---------------------------------------------------------------------------
# golden file: emit_dwn output pinned for one tiny frozen model
# ---------------------------------------------------------------------------

GOLDEN = Path(__file__).parent / "golden" / "dwn_tiny_pen.v"


def _golden_frozen():
    """A fully deterministic tiny PEN model (no RNG, no dataset): covers
    negative thresholds, a duplicate threshold (the CSE alias path), the
    -1.0 two's-complement extreme, and an off-grid rounding case."""
    from repro.core.model import DWNConfig, FrozenDWN
    cfg = DWNConfig(num_features=2, bits_per_feature=3, lut_counts=(10,),
                    fan_in=6, num_classes=5)
    th = np.array([[-0.75, -0.75, 0.5],
                   [-1.0, 0.0, 0.4375]], np.float32)
    mapping = (np.arange(60).reshape(10, 6) % 6).astype(np.int32)
    tables = np.array([[(a * (j + 3) // 5 + j) % 2 for a in range(64)]
                       for j in range(10)], np.int32)
    return FrozenDWN(cfg, th, [mapping], [tables], input_frac_bits=4)


def test_emit_dwn_golden_file():
    """Silent codegen drift fails loudly: the emitted source for the
    frozen golden model must match the checked-in file byte for byte.
    Intentional emitter changes: REPRO_UPDATE_GOLDEN=1 pytest -k golden
    regenerates it (then review the diff)."""
    src = emit_dwn(_golden_frozen(), name="dwn_golden")
    if os.environ.get("REPRO_UPDATE_GOLDEN") == "1":
        GOLDEN.parent.mkdir(parents=True, exist_ok=True)
        GOLDEN.write_text(src)
    assert GOLDEN.exists(), "golden file missing; run with " \
                            "REPRO_UPDATE_GOLDEN=1 to create it"
    assert src == GOLDEN.read_text()
    # the golden source pins the alias + negative-constant paths
    assert "// dup threshold" in src
    assert "$signed(5'h10)" in src              # -1.0 two's complement


def test_golden_model_cosim_agrees():
    """The pinned netlist is not just frozen text — it still computes
    what the oracle computes."""
    from repro.hw.cosim import verify_rtl
    rng = np.random.default_rng(7)
    x = rng.uniform(-1, 1, size=(64, 2)).astype(np.float32)
    rep = verify_rtl(_golden_frozen(), x, backend="python",
                     name="dwn_golden")
    assert rep.counts_checked and rep.n_vectors == 64
