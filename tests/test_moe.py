"""MoE layer: routing invariants + dense-reference equivalence."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models import layers as L


def _setup(B=2, S=16, D=32, F=48, E=4, seed=0):
    key = jax.random.PRNGKey(seed)
    p = L.init_moe(key, D, F, E)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (B, S, D),
                          jnp.float32) * 0.5
    return p, x


def _dense_reference(p, x, top_k):
    """Compute every expert densely, combine with renormalized top-k gates."""
    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, -1)
    gates, idx = jax.lax.top_k(probs, top_k)
    gates = gates / gates.sum(-1, keepdims=True)
    cd = jnp.float32
    g = jnp.einsum("bsd,edf->bsef", x.astype(cd), p["w_gate"].astype(cd))
    u = jnp.einsum("bsd,edf->bsef", x.astype(cd), p["w_up"].astype(cd))
    h = jax.nn.silu(g) * u
    y_all = jnp.einsum("bsef,efd->bsed", h, p["w_down"].astype(cd))
    onehot = jax.nn.one_hot(idx, p["router"].shape[-1])       # (B,S,k,E)
    w = jnp.einsum("bske,bsk->bse", onehot, gates)
    return jnp.einsum("bsed,bse->bsd", y_all, w)


def test_moe_matches_dense_reference_at_high_capacity():
    p, x = _setup()
    y, aux = L.moe_apply(p, x, top_k=2, capacity_factor=8.0)
    ref = _dense_reference(p, x, 2)
    # bf16 compute vs f32 reference
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ref), atol=0.05, rtol=0.05)


def test_moe_aux_loss_near_one_for_uniform_router():
    p, x = _setup(seed=3)
    p = dict(p, router=jnp.zeros_like(p["router"]))    # uniform routing
    _, aux = L.moe_apply(p, x, top_k=2, capacity_factor=8.0)
    assert 0.9 < float(aux) < 1.1                      # E * sum(1/E * 1/E)


def test_moe_capacity_drops_are_bounded():
    """With tight capacity some tokens drop; output stays finite and the
    kept fraction dominates."""
    p, x = _setup(B=1, S=64, seed=5)
    y, _ = L.moe_apply(p, x, top_k=2, capacity_factor=0.5)
    assert np.isfinite(np.asarray(y, np.float32)).all()
    ref = _dense_reference(p, x, 2)
    # at cf=0.5 at most half the slots exist; correlation should persist
    ynp, rnp = np.asarray(y, np.float32).ravel(), np.asarray(ref).ravel()
    corr = np.corrcoef(ynp, rnp)[0, 1]
    assert corr > 0.5


def test_moe_gradients_finite():
    p, x = _setup(seed=7)

    def loss(p_):
        y, aux = L.moe_apply(p_, x, top_k=2)
        return (y.astype(jnp.float32) ** 2).mean() + 0.01 * aux

    g = jax.grad(loss)(p)
    for k, leaf in g.items():
        arr = np.asarray(leaf, np.float32)
        assert np.isfinite(arr).all(), k
    # router must receive gradient (through gate weights)
    assert np.abs(np.asarray(g["router"])).max() > 0
