"""Per-architecture smoke tests (mandated): reduced config, one forward +
one train step on CPU, asserting output shapes and no NaNs."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch, list_archs, SHAPES, cell_supported
from repro.configs.registry import assigned_archs
from repro.models import api

ARCHS = ["granite-moe-3b-a800m", "mixtral-8x7b", "whisper-large-v3",
         "mamba2-1.3b", "qwen3-8b", "phi3-mini-3.8b", "qwen2-7b",
         "qwen3-14b", "recurrentgemma-2b", "llava-next-34b"]


def _batch(cfg, B=2, S=24, key=None):
    key = key or jax.random.PRNGKey(0)
    b = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
         "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        b["frames"] = jax.random.normal(
            key, (B, cfg.enc_frames, cfg.d_model), jnp.float32) * 0.1
    if cfg.family == "vlm":
        b["patches"] = jax.random.normal(
            key, (B, cfg.num_patches, cfg.d_model), jnp.float32) * 0.02
    return b


def test_all_assigned_archs_registered():
    assert sorted(ARCHS) == assigned_archs()


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = get_arch(arch)
    # spot checks of the published dims
    full = {
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "mamba2-1.3b": (48, 2048, 0, 0, 0, 50280),
        "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
        "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
        "qwen2-7b": (28, 3584, 28, 4, 18944, 152064),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == full, (arch, got, full)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_arch(arch).reduced()
    mod = api.module_for(cfg)
    params = mod.init_params(jax.random.PRNGKey(0), cfg, tp=1)
    batch = _batch(cfg)
    B, S = batch["tokens"].shape

    logits, *_ = mod.forward(params, cfg, batch, tp=1)
    exp_S = S + (cfg.num_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (B, exp_S, cfg.vocab_padded(1))
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    step, opt = api.make_train_step(cfg, tp=1)
    opt_state = opt.init(params)
    params2, opt_state2, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    for leaf in jax.tree.leaves(params2):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_arch(arch).reduced()
    mod = api.module_for(cfg)
    params = mod.init_params(jax.random.PRNGKey(1), cfg, tp=1)
    batch = _batch(cfg, B=2, S=16)
    logits, cache = mod.prefill(params, cfg, batch, tp=1, cache_len=20)
    assert logits.shape == (2, cfg.vocab_padded(1))
    nxt = jnp.full((2, 1), 3, jnp.int32)
    logits2, cache2 = mod.decode_step(params, cfg, cache, nxt, tp=1)
    assert logits2.shape == (2, cfg.vocab_padded(1))
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


def test_long_500k_skips_documented():
    skipped = [a for a in ARCHS
               if not cell_supported(get_arch(a), SHAPES["long_500k"])[0]]
    # exactly the pure full-attention archs skip; SSM/hybrid/SWA run
    assert sorted(skipped) == sorted([
        "granite-moe-3b-a800m", "whisper-large-v3", "qwen3-8b",
        "phi3-mini-3.8b", "qwen2-7b", "qwen3-14b", "llava-next-34b"])
    runnable = sorted(set(ARCHS) - set(skipped))
    assert runnable == sorted(["mixtral-8x7b", "mamba2-1.3b",
                               "recurrentgemma-2b"])
