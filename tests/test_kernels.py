"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.thermometer import ops as th_ops
from repro.kernels.thermometer.ref import thermometer_ref
from repro.kernels.lut_eval import ops as lut_ops
from repro.kernels.lut_eval.ref import lut_eval_ref
from repro.kernels.popcount import ops as pc_ops
from repro.kernels.popcount.ref import popcount_ref, classify_ref
from repro.kernels.fused import ops as f_ops
from repro.kernels.fused.ref import fused_dwn_ref


def _xth(B, F, T, seed=0, dtype=jnp.float32):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.uniform(k1, (B, F), dtype, minval=-1, maxval=1)
    th = jnp.sort(jax.random.uniform(k2, (F, T), dtype, minval=-1,
                                     maxval=1), axis=1)
    return x, th


@pytest.mark.parametrize("B,F,T", [(8, 4, 32), (37, 16, 200), (256, 16, 200),
                                   (5, 3, 7), (64, 1, 128)])
def test_thermometer_shapes(B, F, T):
    x, th = _xth(B, F, T, seed=B)
    out = th_ops.encode(x, th, flatten=False, interpret=True)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(thermometer_ref(x, th)))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_thermometer_dtypes(dtype):
    x, th = _xth(16, 4, 64, seed=1, dtype=jnp.float32)
    x, th = x.astype(dtype), th.astype(dtype)
    out = th_ops.encode(x.astype(jnp.float32), th.astype(jnp.float32),
                        flatten=False, interpret=True)
    ref = thermometer_ref(x.astype(jnp.float32), th.astype(jnp.float32))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("B,m,n,C", [(16, 10, 6, 320), (33, 50, 6, 3200),
                                     (8, 7, 4, 64), (128, 360, 6, 3200)])
def test_lut_eval_shapes(B, m, n, C):
    key = jax.random.PRNGKey(m)
    bits = jax.random.bernoulli(key, 0.5, (B, C)).astype(jnp.float32)
    mapping = jax.random.randint(key, (m, n), 0, C)
    tables = jax.random.randint(key, (m, 2 ** n), 0, 2).astype(jnp.float32)
    out = lut_ops.evaluate(bits, mapping, tables, interpret=True)
    ref = lut_eval_ref(bits, mapping, tables)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


@pytest.mark.parametrize("B,classes,group", [(16, 5, 2), (37, 5, 72),
                                             (512, 10, 13), (4, 2, 1)])
def test_popcount_shapes(B, classes, group):
    key = jax.random.PRNGKey(B + classes)
    bits = jax.random.bernoulli(key, 0.4, (B, classes * group)) \
        .astype(jnp.float32)
    counts, idx = pc_ops.classify(bits, classes, interpret=True)
    rc, ri = classify_ref(bits, classes)
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(rc))
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ri))


def test_popcount_tie_break_lower_index():
    bits = jnp.asarray([[1, 1, 1, 1, 0, 0]], jnp.float32)  # counts 2,2,0
    counts, idx = pc_ops.classify(bits, 3, interpret=True)
    assert int(idx[0]) == 0


@pytest.mark.parametrize("B,F,T,m", [(8, 4, 32, 10), (37, 16, 200, 50),
                                     (64, 16, 200, 360)])
def test_fused_shapes(B, F, T, m):
    x, th = _xth(B, F, T, seed=m)
    key = jax.random.PRNGKey(m)
    mapping = jax.random.randint(key, (m, 6), 0, F * T)
    tables = jax.random.randint(key, (m, 64), 0, 2).astype(jnp.float32)
    counts, idx = f_ops.forward(x, th, mapping, tables, 5, interpret=True)
    ref = fused_dwn_ref(x, th, mapping, tables, 5)
    np.testing.assert_allclose(np.asarray(counts), np.asarray(ref),
                               atol=1e-4)
    # in-kernel first-argmax (ties -> lower class) == jnp.argmax semantics
    np.testing.assert_array_equal(np.asarray(idx),
                                  np.asarray(jnp.argmax(ref, -1)))


def test_fused_agrees_with_staged_pipeline():
    """fused == thermometer -> lut_eval -> popcount, kernel to kernel."""
    x, th = _xth(24, 16, 200, seed=9)
    key = jax.random.PRNGKey(9)
    mapping = jax.random.randint(key, (50, 6), 0, 3200)
    tables = jax.random.randint(key, (50, 64), 0, 2).astype(jnp.float32)
    bits = th_ops.encode(x, th, interpret=True)
    stage_counts, stage_idx = pc_ops.classify(
        lut_ops.evaluate(bits, mapping, tables, interpret=True), 5,
        interpret=True)
    counts, idx = f_ops.forward(x, th, mapping, tables, 5, interpret=True)
    np.testing.assert_allclose(np.asarray(counts), np.asarray(stage_counts),
                               atol=1e-4)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(stage_idx))


@pytest.mark.parametrize("B", [13, 37, 64])
def test_fused_ragged_batches_pad_internally(B):
    """Any batch size works: the kernels pad B internally and mask the
    ragged tail, so bucket rounding is not the caller's problem."""
    x, th = _xth(B, 16, 200, seed=B)
    key = jax.random.PRNGKey(B)
    mapping = jax.random.randint(key, (50, 6), 0, 3200)
    tables = jax.random.randint(key, (50, 64), 0, 2).astype(jnp.float32)
    counts, idx = f_ops.forward(x, th, mapping, tables, 5, interpret=True)
    assert counts.shape == (B, 5) and idx.shape == (B,)
    ref = fused_dwn_ref(x, th, mapping, tables, 5)
    np.testing.assert_allclose(np.asarray(counts), np.asarray(ref),
                               atol=1e-4)
