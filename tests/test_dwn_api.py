"""Unified DWN artifact API tests.

Covers: DWNSpec construction validation (actionable errors), the spec
preset registry behind the old ``--arch dwn-jsc-*`` strings, lifecycle
stage ordering, stage-boundary bit-exact parity vs the pre-refactor
construction glue (``build_dwn_model`` / ``sweep_arch`` / engine arch
strings), Table I TEN tolerances through the artifact route, the
checkpoint roundtrip, and the sweep cache's spec fingerprinting.
"""

import json

import numpy as np
import pytest

from repro.dwn import (DWNArtifact, DWNSpec, LifecycleError, get_spec,
                      has_spec, resolve_spec, spec_presets)
from repro.data.jsc import load_jsc


@pytest.fixture(scope="module")
def data():
    return load_jsc(512, 128)


# ---------------------------------------------------------------------------
# spec validation: every invalid combination raises with a usable message
# ---------------------------------------------------------------------------

def test_spec_rejects_invalid_thermometer_bits():
    with pytest.raises(ValueError, match="T must be an integer >= 1"):
        DWNSpec(preset="sm-50", bits=0)
    with pytest.raises(ValueError, match="T must be"):
        DWNSpec(preset="sm-50", bits=-3)


def test_spec_rejects_unknown_placement():
    with pytest.raises(ValueError, match="supported placements.*uniform"):
        DWNSpec(preset="sm-50", placement="triangular")


def test_spec_rejects_unregistered_datapath():
    # "corner"/"gather" are dryrun datapath variants, never serving
    # backends — the spec refuses them with the registered list
    with pytest.raises(ValueError,
                       match="unregistered serving datapath.*fused-packed"):
        DWNSpec(preset="sm-50", datapath="corner")


def test_spec_rejects_pen_ten_mismatch():
    with pytest.raises(ValueError, match="PEN.*requires input_bits"):
        DWNSpec(preset="sm-50", variant="PEN")
    with pytest.raises(ValueError, match="TEN.*must not set input_bits"):
        DWNSpec(preset="sm-50", variant="TEN", input_bits=9)
    with pytest.raises(ValueError, match="at least 2"):
        DWNSpec(preset="sm-50", variant="PEN", input_bits=1)


def test_spec_rejects_unknown_preset_variant_grouping():
    with pytest.raises(ValueError, match="workload 'jsc'.*known tiers"):
        DWNSpec(preset="xl-9000")
    with pytest.raises(ValueError, match="unknown encoding variant"):
        DWNSpec(preset="sm-50", variant="BEN")
    with pytest.raises(ValueError, match="unknown popcount grouping"):
        DWNSpec(preset="sm-50", grouping="diagonal")


def test_spec_roundtrip_and_fingerprint():
    spec = DWNSpec(preset="md-360", variant="PEN", bits=100,
                   placement="gaussian", input_bits=9,
                   datapath="packed-xla")
    assert DWNSpec.from_dict(spec.to_dict()) == spec
    assert spec.frac_bits == 8 and spec.luts == 360
    fp = spec.fingerprint()
    assert fp == spec.fingerprint()                       # stable
    import dataclasses
    assert dataclasses.replace(spec, bits=101).fingerprint() != fp
    assert DWNSpec(preset="sm-10").frac_bits is None


# ---------------------------------------------------------------------------
# preset registry: the old --arch strings are typed specs now
# ---------------------------------------------------------------------------

def test_serving_alias_spec_presets_registered():
    names = spec_presets()
    for tier, preset in (("sm", "sm-50"), ("md", "md-360"),
                         ("lg", "lg-2400")):
        assert f"dwn-jsc-{tier}" in names
        assert get_spec(f"dwn-jsc-{tier}").preset == preset
        assert get_spec(f"dwn-jsc-{tier}").datapath == "fused-packed"
        assert get_spec(f"dwn-jsc-{tier}-xla").datapath == "packed-xla"
    assert get_spec("dwn-jsc-sm-gaussian").placement == "gaussian"
    with pytest.raises(KeyError, match="unknown DWN spec preset"):
        get_spec("dwn-jsc-xxl")


def test_resolve_spec_normalizes_legacy_archs():
    from repro.configs import get_arch
    # dryrun-only datapaths fall back to fused-packed exactly like the
    # engine's pre-spec behavior; grouping survives
    spec = resolve_spec(get_arch("dwn-jsc-lg2400-opt2"))
    assert spec.preset == "lg-2400"
    assert spec.datapath == "fused-packed"
    assert spec.grouping == "strided"
    # name resolution prefers the registered preset
    assert resolve_spec("dwn-jsc-sm-xla").datapath == "packed-xla"
    assert not has_spec("dwn-jsc-sm50")                  # arch, not preset
    assert resolve_spec("dwn-jsc-sm50").preset == "sm-50"


# ---------------------------------------------------------------------------
# lifecycle ordering
# ---------------------------------------------------------------------------

def test_lifecycle_order_enforced(data):
    spec = DWNSpec(preset="sm-10", bits=32)
    art = DWNArtifact(spec)
    assert art.stage == "spec"
    with pytest.raises(LifecycleError, match="call train\\(\\)/fit"):
        art.freeze()
    with pytest.raises(LifecycleError, match="call freeze"):
        art.pack()
    art.fit(data.x_train)
    assert art.stage == "trained"
    with pytest.raises(LifecycleError, match="call pack"):
        art.serving_model()
    with pytest.raises(LifecycleError):
        art.hw_report()
    art.freeze()
    assert art.stage == "frozen"
    art.pack()
    assert art.stage == "packed"
    # re-adopting invalidates downstream stages
    art.adopt(art.params, art.buffers)
    assert art.stage == "trained"


# ---------------------------------------------------------------------------
# bit-exact parity vs the pre-refactor glue (the deprecated shims)
# ---------------------------------------------------------------------------

def test_build_dwn_model_shim_bit_exact(data):
    from repro.configs import get_arch
    from repro.serving.backends import build_dwn_model
    cfg = get_arch("dwn-jsc-sm")
    with pytest.deprecated_call():
        old = build_dwn_model(cfg, data.x_train, seed=0)
    new = (DWNArtifact(get_spec("dwn-jsc-sm")).fit(data.x_train, seed=0)
           .freeze().pack().serving_model())
    assert np.array_equal(np.asarray(old.thresholds),
                          np.asarray(new.thresholds))
    for a, b in zip(old.mappings, new.mappings):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(old.tables, new.tables):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    # packed serve outputs are identical through both bundles
    from repro.core.model import apply_hard_packed
    import jax.numpy as jnp
    x = jnp.asarray(data.x_test[:32])
    assert np.array_equal(np.asarray(apply_hard_packed(old.frozen, x)),
                          np.asarray(apply_hard_packed(new.frozen, x)))


def test_sweep_arch_shim_delegates():
    from repro.configs.dwn_jsc import sweep_arch
    with pytest.deprecated_call():
        cfg = sweep_arch("sm-10", bits=64, placement="uniform",
                         datapath="packed-xla")
    spec = DWNSpec(preset="sm-10", bits=64, placement="uniform",
                   datapath="packed-xla")
    view = spec.arch_config()
    assert (cfg.dwn_luts, cfg.dwn_bits, cfg.dwn_encoding,
            cfg.dwn_datapath) == (view.dwn_luts, view.dwn_bits,
                                  view.dwn_encoding, view.dwn_datapath)
    assert cfg.family == view.family == "dwn"


def test_engine_legacy_arch_and_spec_serve_identically():
    from repro.serving import ServingEngine
    kw = dict(max_bucket=32, min_bucket=8, n_train=256, seed=0)
    e_old = ServingEngine("dwn-jsc-sm", **kw)          # legacy arch string
    e_new = ServingEngine(get_spec("dwn-jsc-sm"), **kw)  # typed spec
    for e in (e_old, e_new):
        e.submit(e.make_request(32, seed=7))
    r_old = e_old.drain()[0].result
    r_new = e_new.drain()[0].result
    assert np.array_equal(r_old[0], r_new[0])          # counts
    assert np.array_equal(r_old[1], r_new[1])          # predictions
    assert e_old.spec == e_new.spec


def test_hw_report_artifact_matches_explicit_args(data):
    from repro.hw.cost import dwn_hw_report
    spec = DWNSpec(preset="sm-50", variant="PEN", bits=64, input_bits=6)
    art = DWNArtifact(spec).fit(data.x_train).freeze()
    r1 = art.hw_report()
    r2 = dwn_hw_report(art)
    r3 = dwn_hw_report(art.frozen, variant="PEN", name="sm-50",
                       input_bits=6)
    assert r1.luts == r2.luts == r3.luts
    assert r1.total_luts == r3.total_luts
    assert r1.total_ffs == r3.total_ffs
    with pytest.raises(TypeError, match="variant"):
        dwn_hw_report(art.frozen)
    with pytest.raises(ValueError, match="freeze"):
        dwn_hw_report(DWNArtifact(spec).fit(data.x_train))


def test_table1_ten_luts_through_artifact_api(data):
    """Table I TEN LUT counts stay within the documented tolerances when
    regenerated purely through the spec → artifact route."""
    from repro.hw.report import PAPER_TABLE3
    from repro.sweep.artifacts import TABLE1_TEN_TOLERANCE
    for preset, tol in TABLE1_TEN_TOLERANCE.items():
        art = DWNArtifact(DWNSpec(preset=preset)).fit(data.x_train).freeze()
        rep = art.hw_report()
        paper = PAPER_TABLE3[preset]["ten_luts"]
        err = abs(rep.total_luts - paper) / paper
        assert err <= tol, (preset, rep.total_luts, paper)
        assert rep.luts["encoder"] == 0                  # TEN: no encoder


def test_verilog_accepts_artifact(data):
    from repro.hw.verilog import emit_dwn, well_formed
    art = DWNArtifact(DWNSpec(preset="sm-10", bits=32)).fit(
        data.x_train).freeze()
    src_art = emit_dwn(art, name="m")
    src_frozen = emit_dwn(art.frozen, name="m")
    assert src_art == src_frozen == art.verilog(name="m")
    assert well_formed(src_art)
    with pytest.raises(ValueError, match="freeze"):
        emit_dwn(DWNArtifact(DWNSpec(preset="sm-10")))


# ---------------------------------------------------------------------------
# checkpoint roundtrip (runtime.checkpoint integration)
# ---------------------------------------------------------------------------

def _packed_xla_outputs(art, x):
    from repro.serving.backends import BoundBackend, get_backend
    counts, pred = BoundBackend(get_backend("packed-xla"),
                                art.serving_model())(np.asarray(x))
    return np.asarray(counts), np.asarray(pred)


def test_artifact_checkpoint_roundtrip_bit_exact(tmp_path, data):
    spec = DWNSpec(preset="sm-10", variant="PEN", bits=32, input_bits=5)
    art = DWNArtifact(spec).train(data, epochs=1, batch=64).freeze().pack()
    art.save(tmp_path)
    art2 = DWNArtifact.load(tmp_path)
    assert art2.spec == spec
    assert art2.stage == "packed"
    assert art2.calibration["epochs"] == 1
    c1, p1 = _packed_xla_outputs(art, data.x_test[:32])
    c2, p2 = _packed_xla_outputs(art2, data.x_test[:32])
    assert np.array_equal(c1, c2) and np.array_equal(p1, p2)
    # trained params survive too (a reloaded artifact can keep training)
    import jax
    for (k, a), (_, b) in zip(
            jax.tree_util.tree_flatten_with_path(art.params)[0],
            jax.tree_util.tree_flatten_with_path(art2.params)[0]):
        assert np.array_equal(np.asarray(a), np.asarray(b)), k


def test_checkpoint_functions_in_runtime_module(tmp_path, data):
    from repro.runtime.checkpoint import load_artifact, save_artifact
    art = DWNArtifact(DWNSpec(preset="sm-10", bits=16)).fit(
        data.x_train).freeze()
    save_artifact(tmp_path, art)
    art2 = load_artifact(tmp_path)
    assert art2.stage == "frozen"
    assert np.array_equal(art2.frozen.thresholds, art.frozen.thresholds)
    with pytest.raises(FileNotFoundError):
        load_artifact(tmp_path / "empty")
    # a non-artifact checkpoint is refused, not misparsed
    from repro.runtime import checkpoint
    checkpoint.save(tmp_path / "raw", 0, {"w": np.zeros(3)})
    with pytest.raises(ValueError, match="not a DWN artifact"):
        load_artifact(tmp_path / "raw")


# ---------------------------------------------------------------------------
# smoke CLI (the CI lifecycle gate)
# ---------------------------------------------------------------------------

def test_smoke_cli_end_to_end(tmp_path):
    from repro.dwn.smoke import main
    out = tmp_path / "artifact.json"
    rc = main(["--preset", "sm-10", "--variant", "TEN", "--bits", "32",
               "--epochs", "0", "--n-train", "256", "--n-test", "64",
               "--ckpt-dir", str(tmp_path / "ckpt"), "--out", str(out),
               "--quiet"])
    assert rc == 0
    rec = json.loads(out.read_text())
    assert rec["roundtrip_bit_exact"] is True
    assert rec["stage"] == rec["reloaded_stage"] == "packed"
    assert rec["hw"]["total_luts"] > 0


# ---------------------------------------------------------------------------
# sweep integration: one artifact per point, spec-covering cache
# ---------------------------------------------------------------------------

def test_sweep_runner_builds_one_artifact_per_point():
    from repro.sweep import SweepSettings
    from repro.sweep.grid import SweepPoint
    from repro.sweep.pipeline import SweepRunner
    runner = SweepRunner(SweepSettings(n_train=256, n_test=64,
                                       kernel=False, serve=False))
    ten = SweepPoint("sm-10", "TEN", bits=32)
    pen = SweepPoint("sm-10", "PEN", bits=32, input_bits=5)
    a_ten, a_pen = runner.artifact_for(ten), runner.artifact_for(pen)
    assert a_ten is runner.artifact_for(ten)             # memoized
    assert a_ten is not a_pen
    # the paper's weight sharing: same trained params object across
    # TEN/PEN variants, different frozen operating points
    assert a_ten.params is a_pen.params
    assert a_ten.frozen.input_frac_bits is None
    assert a_pen.frozen.input_frac_bits == 4
    assert a_pen.spec.input_bits == 5


def test_sweep_cache_fingerprint_covers_dwn_package(tmp_path, monkeypatch):
    """Editing the repro.dwn source must invalidate sweep cache keys."""
    import repro.dwn.artifact as artifact_mod
    from repro.sweep import cache as sweep_cache
    monkeypatch.setattr(sweep_cache, "_FINGERPRINT", None)
    fp1 = sweep_cache._code_fingerprint()
    fake = tmp_path / "artifact.py"
    fake.write_text("# edited lifecycle semantics\n")
    monkeypatch.setattr(artifact_mod, "__file__", str(fake))
    fp2 = sweep_cache._code_fingerprint()
    assert fp1 != fp2
