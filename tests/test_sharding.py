"""Sharding rules: head layouts, divisibility fallback, tree shardings."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.models.layers import make_head_layout
from repro.sharding.partition import Partitioner, logical


@settings(max_examples=200, deadline=None)
@given(st.integers(1, 64), st.integers(1, 64), st.sampled_from([1, 2, 4, 8, 16]))
def test_head_layout_invariants(num_kv, gs, tp):
    num_q = num_kv * gs
    hl = make_head_layout(num_q, num_kv, tp)
    # every TP shard owns whole blocks
    assert hl.q_padded % tp == 0
    assert hl.q_padded >= num_q
    assert hl.kv_padded >= num_kv
    assert hl.q_padded % hl.kv_padded == 0 or hl.kv_padded == num_kv
    if hl.kv_padded % tp == 0 and hl.kv_padded >= tp:
        # shard-local q->kv alignment: q block maps into its kv block
        qb, kb = hl.q_padded // tp, hl.kv_padded // tp
        ratio = hl.q_padded // hl.kv_padded
        for t in range(tp):
            lo, hi = t * qb, (t + 1) * qb - 1
            assert lo // ratio >= t * kb and hi // ratio < (t + 1) * kb


def test_assigned_arch_layouts_tp16():
    # (q, kv) -> expected (Qp, Kp)
    expect = {
        (24, 8): (32, 16), (32, 8): (32, 16), (20, 20): (32, 32),
        (32, 32): (32, 32), (28, 4): (32, 16), (40, 8): (48, 16),
        (10, 1): (16, 16), (56, 8): (64, 16),
    }
    for (q, kv), (qp, kp) in expect.items():
        hl = make_head_layout(q, kv, 16)
        assert (hl.q_padded, hl.kv_padded) == (qp, kp), (q, kv, hl)


def _mesh22():
    n = len(jax.devices())
    return jax.make_mesh((1, 1), ("data", "model")) if n == 1 else \
        jax.make_mesh((n // 2, 2), ("data", "model"))


def test_divisibility_fallback():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    part = Partitioner(mesh)
    # always divisible on a 1x1 mesh
    spec = part.spec(("embed", "ff"), (64, 96), "w")
    assert isinstance(spec, P)


def test_fallback_records_event():
    # fake a mesh with model=1 but data=1; use rule pointing at "model"
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    part = Partitioner(mesh)
    part.spec(("ff",), (7,), "odd")       # model size 1 -> no div check
    assert part.fallbacks == []


def test_tree_shardings_structure():
    from repro.configs import get_arch
    from repro.models import api
    cfg = get_arch("qwen3-8b").reduced()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    part = Partitioner(mesh)
    ap = api.abstract_params(cfg, tp=1)
    shard = part.tree_shardings(ap, api.param_axes(cfg))
    # same treedef, every leaf a NamedSharding
    assert jax.tree.structure(shard) == jax.tree.structure(ap)
    from jax.sharding import NamedSharding
    for s in jax.tree.leaves(shard):
        assert isinstance(s, NamedSharding)


def test_logical_axes_is_leaf():
    la = logical("a", "b", name="x")
    leaves = jax.tree.leaves({"p": la})
    assert leaves == [la]
