"""Sweep subsystem tests: paper-constant calibration through the sweep
path, the tiny end-to-end grid, caching, and the new placement mode."""

import numpy as np
import pytest

from repro.sweep import (SweepPoint, SweepSettings, SweepResult, run_grid,
                         pareto_front, point_key)
from repro.sweep.artifacts import (PRESETS, TABLE1_TEN_TOLERANCE,
                                   paper_reference)
from repro.sweep.grid import load_grid, paper_grid, tiny_grid

FAST = SweepSettings(n_train=512, n_test=256, accuracy=True,
                     kernel=False, serve=False)


@pytest.fixture(scope="module")
def tiny_result():
    return run_grid("tiny", FAST, cache_dir=None)


# ---------------------------------------------------------------------------
# paper-constant calibration through the sweep path
# ---------------------------------------------------------------------------

def test_table1_ten_rows_within_documented_tolerance():
    """Table I TEN LUT counts reproduce through the sweep pipeline within
    the % error tolerances documented in docs/reproduction.md."""
    pts = [p for p in paper_grid() if p.variant == "TEN"]
    assert sorted(p.preset for p in pts) == sorted(PRESETS)
    res = run_grid(pts, FAST, cache_dir=None)
    for r in res.points:
        assert r.paper_luts is not None, r.point
        tol = TABLE1_TEN_TOLERANCE[r.point.preset]
        err = abs(r.total_luts - r.paper_luts) / r.paper_luts
        assert err <= tol, (r.point.preset, r.total_luts, r.paper_luts, err)
        # TEN: no on-chip encoder, and the breakdown must sum to the total
        assert r.luts["encoder"] == 0
        assert sum(r.luts.values()) == r.total_luts


def test_paper_reference_resolution():
    assert paper_reference(SweepPoint("sm-50", "TEN")) == 110
    assert paper_reference(SweepPoint("sm-50", "PEN", input_bits=8)) == 311
    assert paper_reference(SweepPoint("sm-50", "PEN", input_bits=9)) == 345
    # off the published operating point -> no reference
    assert paper_reference(SweepPoint("sm-50", "TEN", bits=100)) is None
    assert paper_reference(
        SweepPoint("sm-50", "TEN", placement="uniform")) is None


# ---------------------------------------------------------------------------
# tiny end-to-end grid
# ---------------------------------------------------------------------------

def test_tiny_grid_encoder_luts_monotone_in_bits(tiny_result):
    """2 presets x 2 PEN bit-widths: encoder LUTs grow with input width
    (wider comparators and finer threshold dedup both push up)."""
    by = {r.point.label: r for r in tiny_result.points}
    for preset in ("sm-10", "sm-50"):
        pen4 = by[f"{preset}/PEN@4b/T200/distributive"]
        pen9 = by[f"{preset}/PEN@9b/T200/distributive"]
        assert 0 < pen4.luts["encoder"] < pen9.luts["encoder"]
        ten = by[f"{preset}/TEN/T200/distributive"]
        assert ten.luts["encoder"] == 0
        assert ten.total_luts < pen4.total_luts < pen9.total_luts


def test_tiny_grid_axes_populated(tiny_result):
    for r in tiny_result.points:
        assert 0.0 <= r.accuracy <= 1.0
        assert r.total_luts > 0 and r.total_ffs > 0
        assert r.delay_ns > 0 and r.fmax_mhz > 0
        assert set(r.luts) == {"encoder", "lut_layer", "popcount", "argmax"}


def test_sweep_result_json_roundtrip(tmp_path, tiny_result):
    f = tmp_path / "sweep.json"
    tiny_result.save(f)
    loaded = SweepResult.load(f)
    assert [r.point for r in loaded.points] == \
        [r.point for r in tiny_result.points]
    assert [r.total_luts for r in loaded.points] == \
        [r.total_luts for r in tiny_result.points]
    assert loaded.settings == tiny_result.settings


def test_pareto_front_rule():
    pts = [("a", 70.0, 10), ("b", 75.0, 100), ("c", 72.0, 50),
           ("d", 75.0, 200), ("none", None, 5)]
    front = pareto_front(pts, cost=lambda p: p[2], score=lambda p: p[1])
    assert [p[0] for p in front] == ["a", "c", "b"]


def test_accuracy_vs_luts_front_is_monotone(tiny_result):
    front = tiny_result.accuracy_vs_luts_front()
    assert front, "tiny grid must yield a non-empty frontier"
    luts = [r.total_luts for r in front]
    accs = [r.accuracy for r in front]
    assert luts == sorted(luts)
    assert accs == sorted(accs)


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------

def test_cache_incremental_rerun(tmp_path):
    pts = [SweepPoint("sm-10", "TEN")]
    first = run_grid(pts, FAST, cache_dir=tmp_path)
    assert not first.points[0].cached
    second = run_grid(pts, FAST, cache_dir=tmp_path)
    assert second.points[0].cached
    assert second.points[0].total_luts == first.points[0].total_luts
    assert second.points[0].accuracy == first.points[0].accuracy
    # different settings -> different key -> recompute
    other = SweepSettings(n_train=513, n_test=256, accuracy=False,
                          kernel=False, serve=False)
    assert point_key(pts[0], FAST) != point_key(pts[0], other)


def test_cache_corrupt_entry_is_a_miss(tmp_path):
    """A corrupt cache file (killed non-atomic writer, disk damage) must
    read as a miss — unlinked and recomputed, never an exception."""
    from repro.sweep import SweepCache
    cache = SweepCache(tmp_path)
    cache.put("k1", {"x": 1})
    assert cache.get("k1") == {"x": 1}
    # truncated JSON
    (tmp_path / "k2.json").write_text('{"x": ')
    assert cache.get("k2") is None
    assert not (tmp_path / "k2.json").exists()     # unlinked, can't shadow
    # valid JSON but not an object
    (tmp_path / "k3.json").write_text('[1, 2]')
    assert cache.get("k3") is None
    assert cache.get("nope") is None               # plain miss
    assert cache.stats == {"hits": 1, "misses": 3, "corrupt": 2}


def test_cache_put_is_atomic(tmp_path):
    """put publishes via unique-temp + os.replace: no *.tmp survives a
    completed put, and a same-key overwrite is last-writer-wins."""
    from repro.sweep import SweepCache
    cache = SweepCache(tmp_path)
    cache.put("k", {"v": 1})
    cache.put("k", {"v": 2})
    assert cache.get("k") == {"v": 2}
    assert list(tmp_path.glob("*.tmp")) == []
    assert list(tmp_path.glob(".*.tmp")) == []
    # disabled cache is inert
    off = SweepCache(None)
    off.put("k", {"v": 3})
    assert off.get("k") is None


def test_cache_corrupt_entry_recomputes_in_pipeline(tmp_path):
    """End to end: corrupting the cached entry forces a recompute that
    repairs the cache (same numbers afterwards)."""
    pts = [SweepPoint("sm-10", "TEN")]
    first = run_grid(pts, FAST, cache_dir=tmp_path)
    key = point_key(pts[0], FAST)
    (tmp_path / f"{key}.json").write_text("garbage{{{")
    second = run_grid(pts, FAST, cache_dir=tmp_path)
    assert not second.points[0].cached             # recomputed, no crash
    assert second.points[0].total_luts == first.points[0].total_luts
    third = run_grid(pts, FAST, cache_dir=tmp_path)
    assert third.points[0].cached                  # cache repaired


def test_grid_resolution(tmp_path):
    assert len(tiny_grid()) == 6
    assert len(paper_grid()) == 8
    with pytest.raises(ValueError):
        load_grid("no-such-grid")
    f = tmp_path / "grid.json"
    f.write_text('[{"preset": "sm-10", "variant": "PEN", "input_bits": 5}]')
    pts = load_grid(str(f))
    assert pts == [SweepPoint("sm-10", "PEN", input_bits=5)]


# ---------------------------------------------------------------------------
# gaussian placement + config threading
# ---------------------------------------------------------------------------

def test_gaussian_placement_thresholds():
    from repro.core.thermometer import ThermometerSpec, fit_thresholds
    rng = np.random.default_rng(0)
    x = np.clip(rng.normal(0, 0.4, (2048, 4)), -1, 0.999).astype(np.float32)
    th = fit_thresholds(x, ThermometerSpec(4, 32, "gaussian"))
    assert th.shape == (4, 32)
    assert np.all(np.diff(th, axis=1) >= 0)          # ascending
    assert th.min() >= -1.0 and th.max() < 1.0
    # symmetric input -> median threshold near the feature mean
    assert np.allclose(th[:, 15], x.mean(axis=0), atol=0.1)


def test_norm_ppf_matches_known_quantiles():
    from repro.core.thermometer import _norm_ppf
    q = np.array([0.001, 0.025, 0.5, 0.841344746, 0.975, 0.999])
    z = _norm_ppf(q)
    ref = np.array([-3.0902, -1.9600, 0.0, 1.0, 1.9600, 3.0902])
    assert np.allclose(z, ref, atol=2e-4)


def test_sweep_arch_threads_encoding_into_serving_model():
    # both entry points are deprecated shims over repro.dwn now, but must
    # keep threading the encoding axis exactly as before
    from repro.configs.dwn_jsc import sweep_arch
    from repro.serving.backends import build_dwn_model
    from repro.data.jsc import load_jsc
    with pytest.deprecated_call():
        cfg = sweep_arch("sm-10", bits=64, placement="gaussian")
    assert cfg.dwn_bits == 64 and cfg.dwn_encoding == "gaussian"
    data = load_jsc(256, 64)
    with pytest.deprecated_call():
        model = build_dwn_model(cfg, data.x_train)
    assert model.dcfg.encoding == "gaussian"
    assert model.thresholds.shape == (16, 64)


# ---------------------------------------------------------------------------
# autodesign: Pareto choice + verified emission
# ---------------------------------------------------------------------------

def _fake_result(rows):
    """SweepResult from (label-ish point, accuracy, luts) triples."""
    from repro.sweep.results import PointResult
    pts = []
    for i, (acc, luts) in enumerate(rows):
        p = SweepPoint("sm-10", "TEN", bits=8 * (i + 1))
        pts.append(PointResult(point=p, accuracy=acc, total_luts=luts))
    return SweepResult(grid="fake", settings={}, points=pts)


def test_choose_design_min_luts_at_floor():
    from repro.sweep.autodesign import AutodesignError, choose_design
    res = _fake_result([(0.60, 100), (0.70, 200), (0.75, 400),
                        (0.50, 150), (0.74, 390)])
    c = choose_design(res, acc_floor=0.65)
    assert c.result.total_luts == 200          # cheapest point >= floor
    assert c.objective.startswith("min-luts")
    # floor above the best accuracy -> hard failure, never a fallback
    with pytest.raises(AutodesignError, match="best on"):
        choose_design(res, acc_floor=0.90)


def test_choose_design_max_acc_under_budget():
    from repro.sweep.autodesign import AutodesignError, choose_design
    res = _fake_result([(0.60, 100), (0.70, 200), (0.75, 400)])
    c = choose_design(res, lut_budget=250)
    assert c.result.accuracy == 0.70           # best affordable
    assert choose_design(res, lut_budget=5000).result.accuracy == 0.75
    with pytest.raises(AutodesignError, match="budget"):
        choose_design(res, lut_budget=50)


def test_choose_design_needs_exactly_one_objective():
    from repro.sweep.autodesign import AutodesignError, choose_design
    res = _fake_result([(0.6, 100)])
    with pytest.raises(AutodesignError, match="exactly one"):
        choose_design(res)
    with pytest.raises(AutodesignError, match="exactly one"):
        choose_design(res, acc_floor=0.5, lut_budget=100)
    # a sweep without accuracy measurements cannot drive autodesign
    res_noacc = _fake_result([(None, 100)])
    res_noacc.points[0].accuracy = None
    with pytest.raises(AutodesignError, match="accuracy"):
        choose_design(res_noacc, acc_floor=0.5)


def test_autodesign_emits_verified_rtl(tmp_path, tiny_result):
    """End to end on the real tiny sweep: choose, rebuild, co-simulate,
    write RTL + summary."""
    import json
    from repro.hw.verilog import well_formed
    from repro.sweep.autodesign import choose_design, emit_verified
    choice = choose_design(tiny_result, acc_floor=0.30)
    summary = emit_verified(choice, FAST, out_dir=tmp_path,
                            n_vectors=64, backend="python", log=None)
    rtl = (tmp_path / "dwn_autodesign.v").read_text()
    assert well_formed(rtl) and "module dwn_autodesign" in rtl
    on_disk = json.loads((tmp_path / "autodesign.json").read_text())
    assert on_disk["verification"]["n_vectors"] == 64
    assert on_disk["verification"]["counts_checked"]
    assert on_disk["choice"]["chosen"]["point"] == \
        choice.point.to_dict()
    assert summary["spec_label"] == on_disk["spec_label"]


def test_autodesign_cli_flags(tmp_path, capsys):
    """--autodesign through the sweep CLI: one command, verified RTL out,
    non-zero exit when the floor is unreachable."""
    from repro.launch.sweep import main
    out = tmp_path / "ad"
    rc = main(["--grid", "tiny", "--no-kernel", "--no-serve",
               "--n-train", "512", "--n-test", "256", "--cache-dir", "",
               "--autodesign", "--acc-floor", "0.30", "--cosim-n", "32",
               "--autodesign-out", str(out)])
    assert rc == 0
    assert (out / "dwn_autodesign.v").exists()
    assert "RTL verified bit-exact" in capsys.readouterr().out

    rc_fail = main(["--grid", "tiny", "--no-kernel", "--no-serve",
                    "--n-train", "512", "--n-test", "256",
                    "--cache-dir", "",
                    "--autodesign", "--acc-floor", "0.99",
                    "--autodesign-out", str(tmp_path / "ad2")])
    assert rc_fail == 1
    assert not (tmp_path / "ad2" / "dwn_autodesign.v").exists()
