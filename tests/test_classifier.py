"""Classification logic: popcount groups + argmax tie-breaking."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core.classifier import (group_popcount, predict, accuracy,
                                   cross_entropy, logits_from_counts)


def test_group_popcount():
    bits = jnp.asarray([[1, 0, 1, 1, 0, 0],
                        [1, 1, 1, 1, 1, 1]], jnp.float32)
    counts = group_popcount(bits, 3)
    np.testing.assert_array_equal(np.asarray(counts),
                                  [[1, 2, 0], [2, 2, 2]])


def test_argmax_tie_lower_index():
    """Paper §IV: equal popcounts resolve to the lower class index."""
    counts = jnp.asarray([[3, 3, 1], [0, 2, 2], [5, 5, 5]], jnp.float32)
    np.testing.assert_array_equal(np.asarray(predict(counts)), [0, 1, 0])


@settings(max_examples=50, deadline=None)
@given(st.integers(2, 6), st.integers(1, 8), st.integers(1, 64))
def test_popcount_matches_numpy(classes, group, batch):
    rng = np.random.default_rng(batch)
    bits = rng.integers(0, 2, (batch, classes * group)).astype(np.float32)
    counts = np.asarray(group_popcount(jnp.asarray(bits), classes))
    expect = bits.reshape(batch, classes, group).sum(-1)
    np.testing.assert_array_equal(counts, expect)
    # hardware argmax semantics == numpy argmax (first max wins)
    np.testing.assert_array_equal(
        np.asarray(predict(jnp.asarray(counts))), counts.argmax(1))


def test_cross_entropy_sane():
    logits = jnp.asarray([[10.0, 0.0, 0.0]])
    labels = jnp.asarray([0])
    assert float(cross_entropy(logits, labels)) < 1e-3
    assert float(cross_entropy(-logits, labels)) > 5.0


def test_temperature_scaling():
    counts = jnp.asarray([[4.0, 2.0]])
    np.testing.assert_allclose(
        np.asarray(logits_from_counts(counts, 2.0)), [[2.0, 1.0]])
