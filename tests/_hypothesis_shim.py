"""Minimal deterministic stand-in for `hypothesis` (offline container).

The repo's property tests declare `hypothesis` as a test dependency
(pyproject.toml / requirements.txt), but this container cannot install
packages.  conftest.py registers this shim under ``sys.modules["hypothesis"]``
only when the real library is absent, so the same test code runs in both
environments.

Supported surface (what the test-suite uses):

    from hypothesis import given, settings, strategies as st
    st.floats(lo, hi)  st.integers(lo, hi)  st.sampled_from(seq)
    st.booleans()      st.just(v)

Semantics: ``@given`` re-runs the test ``max_examples`` times with values
drawn from a PRNG seeded by the test's qualified name — deterministic across
runs.  The first draws are the strategy's boundary values (min/max/every
sampled element) so edge cases are always exercised; there is no shrinking.
"""

from __future__ import annotations

import functools
import random
import zlib

_DEFAULT_MAX_EXAMPLES = 50


class _Strategy:
    def __init__(self, corners, draw):
        self._corners = list(corners)
        self._draw = draw

    def example(self, rng: random.Random, i: int):
        if i < len(self._corners):
            return self._corners[i]
        return self._draw(rng)


class _Strategies:
    @staticmethod
    def floats(min_value=-1e9, max_value=1e9, **_):
        return _Strategy([min_value, max_value],
                         lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def integers(min_value=0, max_value=2 ** 31 - 1, **_):
        return _Strategy([min_value, max_value],
                         lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(elements, lambda rng: rng.choice(elements))

    @staticmethod
    def booleans():
        return _Strategy([False, True], lambda rng: rng.random() < 0.5)

    @staticmethod
    def just(value):
        return _Strategy([value], lambda rng: value)


strategies = _Strategies()


class _UnsatisfiedAssumption(Exception):
    """Raised by assume(False); the current example is discarded."""


def given(*strats, **kwstrats):
    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            conf = getattr(wrapper, "_shim_settings", {})
            n = conf.get("max_examples") or _DEFAULT_MAX_EXAMPLES
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for i in range(n):
                vals = [s.example(rng, i) for s in strats]
                kwvals = {k: s.example(rng, i) for k, s in kwstrats.items()}
                try:
                    fn(*args, *vals, **kwargs, **kwvals)
                except _UnsatisfiedAssumption:
                    continue
        # pytest resolves fixtures from inspect.signature, which follows
        # __wrapped__; drop it so the injected parameters are not mistaken
        # for fixtures.
        del wrapper.__wrapped__
        wrapper.is_hypothesis_test = True
        return wrapper
    return decorate


def settings(max_examples: int | None = None, deadline=None, **_):
    def decorate(fn):
        fn._shim_settings = {"max_examples": max_examples}
        return fn
    return decorate


class HealthCheck:
    all = staticmethod(lambda: [])
    too_slow = "too_slow"
    data_too_large = "data_too_large"


def assume(condition: bool) -> bool:
    # Like real hypothesis: an unsatisfied assumption aborts the current
    # example (the shim moves on to the next draw instead of re-drawing).
    if not condition:
        raise _UnsatisfiedAssumption()
    return True
